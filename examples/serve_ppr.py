"""End-to-end serving driver: the paper's product as a running service.

    PYTHONPATH=src python examples/serve_ppr.py

Simulates an online workload against :class:`repro.serving.PPRService`:
requests arrive one by one, the buffer batches them (paper Section 3.3),
the VERD shared decomposition answers them, and latency/throughput stats
are reported — the Table 3 scenario as a live loop.
"""

import jax
import numpy as np

from repro.core.index import build_index
from repro.core.query import QueryConfig
from repro.graphs import synthetic
from repro.serving import PPRService, ServiceConfig
from repro.serving.batching import BatchingConfig


def main():
    print("== PowerWalk serving demo ==")
    g = synthetic.rmat(11, avg_deg=10.0, seed=0)
    index, _ = build_index(g, r=100, l=256, key=jax.random.PRNGKey(0),
                           source_batch=512)
    svc = PPRService(
        g, index,
        ServiceConfig(
            query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=20),
            batching=BatchingConfig(max_batch=256, max_wait_s=0.005),
        ),
    )
    rng = np.random.default_rng(1)
    workload = rng.integers(0, g.n, size=2000)
    answers, stats = svc.run_closed_loop(workload)
    print(f"served {stats['served']:.0f} requests in "
          f"{stats['wall_s']:.2f}s ({stats['qps']:.0f} q/s), "
          f"{stats['batches']:.0f} batches")
    print(f"latency mean={stats['mean_latency'] * 1e3:.1f}ms "
          f"max={stats['max_latency'] * 1e3:.1f}ms")
    a = answers[0]
    print(f"sample answer: query v{a.vertex} -> "
          f"top vertices {a.top_vertices[:5].tolist()}")
    assert stats["served"] == len(workload)
    print("OK")


if __name__ == "__main__":
    main()
