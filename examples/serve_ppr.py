"""End-to-end serving driver: the paper's product as a running service.

    PYTHONPATH=src python examples/serve_ppr.py

Simulates an online workload against :class:`repro.serving.PPRService`:
requests arrive one by one, the buffer batches them (paper Section 3.3),
the VERD shared decomposition answers them through the async pipeline
(docs/serving_path.md), and latency/throughput stats are reported — the
Table 3 scenario as a live loop, first closed-loop (capacity) then
open-loop at a fixed offered rate with an interactive/bulk traffic mix.
"""

import jax
import numpy as np

from repro.core.index import build_index
from repro.core.query import QueryConfig
from repro.graphs import synthetic
from repro.serving import (PipelineConfig, PPRService, ServiceConfig,
                           run_open_loop)
from repro.serving.batching import BatchingConfig, TierPolicy


def make_service(g, index, depth=4):
    return PPRService(
        g, index,
        ServiceConfig(
            query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=20),
            batching=BatchingConfig(
                max_batch=256, max_wait_s=0.005,
                # bulk traffic may wait longer so interactive stays snappy
                interactive=TierPolicy(max_wait_s=0.005),
                bulk=TierPolicy(max_wait_s=0.050),
            ),
            pipeline=PipelineConfig(depth=depth),
        ),
    )


def main():
    print("== PowerWalk serving demo ==")
    g = synthetic.rmat(11, avg_deg=10.0, seed=0)
    index, _ = build_index(g, r=100, l=256, key=jax.random.PRNGKey(0),
                           source_batch=512)
    rng = np.random.default_rng(1)

    # -- closed loop: capacity ------------------------------------------------
    svc = make_service(g, index)
    workload = rng.integers(0, g.n, size=2000)
    answers, stats = svc.run_closed_loop(workload)
    print(f"closed loop: served {stats['served']:.0f} requests in "
          f"{stats['wall_s']:.2f}s ({stats['qps']:.0f} q/s, "
          f"{stats['qps_excl_first_batch']:.0f} q/s excl. first batch), "
          f"{stats['batches']:.0f} batches, depth={stats['pipeline_depth']}")
    print(f"  latency mean={stats['mean_latency'] * 1e3:.1f}ms "
          f"p99={stats['latency_p99'] * 1e3:.1f}ms")
    a = answers[0]
    print(f"  sample answer: query v{a.vertex} -> "
          f"top vertices {a.top_vertices[:5].tolist()}")
    assert stats["served"] == len(workload)

    # -- open loop: offered-rate workload with a tier mix ---------------------
    svc2 = make_service(g, index)
    mixed = [(int(v), "bulk" if i % 4 == 0 else "interactive")
             for i, v in enumerate(rng.integers(0, g.n, size=1000))]
    offered = 0.5 * stats["qps"]
    answers2, s2 = run_open_loop(svc2, mixed, qps=offered)
    by_tier = {"interactive": [], "bulk": []}
    for a in answers2:
        by_tier[a.tier].append(a.latency_s)
    print(f"open loop @ {offered:.0f} q/s offered: achieved "
          f"{s2['qps']:.0f} q/s, p50={s2['latency_p50'] * 1e3:.1f}ms "
          f"p99={s2['latency_p99'] * 1e3:.1f}ms, "
          f"in_flight_peak={s2['pipeline_in_flight_peak']:.0f}")
    for tier, lats in by_tier.items():
        print(f"  {tier}: {len(lats)} answers, "
              f"mean={np.mean(lats) * 1e3:.1f}ms")
    assert s2["served"] == len(mixed)
    print("OK")


if __name__ == "__main__":
    main()
