"""PowerWalk x RecSys: PPR candidate generation + model scoring.

    PYTHONPATH=src python examples/recsys_retrieval.py

The two-stage recommender the paper motivates (Twitter's WTF): PowerWalk
answers "which items does this user's random walk reach" (candidate
generation over the user-item bipartite graph), then SASRec scores the
candidates.  Compares PPR retrieval against random candidates by recall of
held-out interactions.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.query import BatchQueryEngine, QueryConfig
from repro.core.index import build_index
from repro.graphs import synthetic
from repro.models.recsys import sasrec
from repro.models.recsys.sasrec import SASRecConfig


def main():
    print("== PPR candidate generation + SASRec scoring ==")
    n_users, n_items = 500, 400
    g = synthetic.bipartite_recsys(n_users, n_items, avg_deg=12.0, seed=0)

    # hold out each user's last interaction (the retrieval target)
    rng = np.random.default_rng(0)
    held = {}
    src = np.asarray(g.src)
    dst = np.asarray(g.col_idx)
    for u in range(n_users):
        items = dst[(src == u)]
        if len(items):
            held[u] = int(items[-1])

    index, _ = build_index(g, r=100, l=64, key=jax.random.PRNGKey(0),
                           source_batch=256)
    engine = BatchQueryEngine(
        g, index, QueryConfig(mode="powerwalk", t_iterations=2, top_k=60))

    users = np.asarray(sorted(held)[:200], dtype=np.int32)
    out = engine.run(users)
    # keep only item vertices among the top-k answers
    cand = out["indices"]
    item_mask = cand >= n_users

    hits = 0
    k_eff = 50
    rand_hits = 0
    for i, u in enumerate(users):
        items = cand[i][item_mask[i]][:k_eff]
        hits += int(held[u] in set(items.tolist()))
        rand = rng.integers(n_users, n_users + n_items, size=k_eff)
        rand_hits += int(held[u] in set(rand.tolist()))
    recall = hits / len(users)
    recall_rand = rand_hits / len(users)
    print(f"recall@{k_eff}: PPR={recall:.3f} vs random={recall_rand:.3f}")
    assert recall > recall_rand, "PPR retrieval must beat random"

    # --- stage 2: SASRec scores the PPR candidates ----------------------
    cfg = SASRecConfig(n_items=n_items, embed_dim=32, n_blocks=2,
                       n_heads=1, seq_len=16, d_ff=64)
    params = sasrec.init(cfg, jax.random.PRNGKey(1))
    u = users[0]
    hist_items = (dst[(src == u)] - n_users)[:16]
    hist = np.zeros(16, np.int32)
    hist[-len(hist_items):] = hist_items % n_items
    cands_u = (cand[0][item_mask[0]][:k_eff] - n_users) % n_items
    scores = sasrec.retrieval_scores(
        cfg, params,
        dict(item_seq=jnp.asarray(hist[None]),
             candidates=jnp.asarray(cands_u)),
    )
    order = np.argsort(-np.asarray(scores))
    print(f"user {u}: top-5 scored candidates "
          f"{cands_u[order[:5]].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
