"""PowerWalk x RecSys: seed-set PPR candidate generation + model scoring.

    PYTHONPATH=src python examples/recsys_retrieval.py

The two-stage recommender the paper motivates (Twitter's WTF): PowerWalk
answers "which items does this user's random walk reach" (candidate
generation over the user-item bipartite graph), then SASRec scores the
candidates.  Retrieval queries are *weighted seed sets* — the user vertex
plus their most recent interacted items, the classic session-aware restart
distribution (restart near where the user just was, not only at their
profile vertex).  Compares seed-set PPR against single-vertex PPR and
random candidates by recall of held-out interactions.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.query import BatchQueryEngine, QueryConfig
from repro.core.index import build_index
from repro.graphs import synthetic
from repro.models.recsys import sasrec
from repro.models.recsys.sasrec import SASRecConfig


def main():
    print("== PPR candidate generation + SASRec scoring ==")
    n_users, n_items = 500, 400
    g = synthetic.bipartite_recsys(n_users, n_items, avg_deg=12.0, seed=0)

    # hold out each user's last interaction (the retrieval target)
    rng = np.random.default_rng(0)
    held = {}
    src = np.asarray(g.src)
    dst = np.asarray(g.col_idx)
    for u in range(n_users):
        items = dst[(src == u)]
        if len(items):
            held[u] = int(items[-1])

    index, _ = build_index(g, r=100, l=64, key=jax.random.PRNGKey(0),
                           source_batch=256)
    max_seeds = 4
    engine = BatchQueryEngine(
        g, index, QueryConfig(mode="powerwalk", t_iterations=2, top_k=60,
                              max_seeds=max_seeds))

    users = np.asarray(sorted(held)[:200], dtype=np.int32)
    # weighted seed set per user: the user vertex (weight 1) plus up to 3
    # recent history items (weight 0.5 each, held-out target excluded);
    # short histories are weight-0 padded to the stable S_max width
    seeds = np.zeros((len(users), max_seeds), np.int32)
    weights = np.zeros((len(users), max_seeds), np.float32)
    for i, u in enumerate(users):
        seeds[i, 0] = u
        weights[i, 0] = 1.0
        recent = dst[(src == u)][:-1][-(max_seeds - 1):]
        seeds[i, 1 : 1 + len(recent)] = recent
        weights[i, 1 : 1 + len(recent)] = 0.5
    out = engine.run(seeds, weights=weights)
    out_single = engine.run(users)

    k_eff = 50
    hits = single_hits = rand_hits = 0
    for i, u in enumerate(users):
        # keep only item vertices among the top-k answers
        cand = out["indices"][i]
        items = cand[cand >= n_users][:k_eff]
        hits += int(held[u] in set(items.tolist()))
        cand_s = out_single["indices"][i]
        items_s = cand_s[cand_s >= n_users][:k_eff]
        single_hits += int(held[u] in set(items_s.tolist()))
        rand = rng.integers(n_users, n_users + n_items, size=k_eff)
        rand_hits += int(held[u] in set(rand.tolist()))
    recall = hits / len(users)
    recall_single = single_hits / len(users)
    recall_rand = rand_hits / len(users)
    print(f"recall@{k_eff}: seed-set PPR={recall:.3f} "
          f"vs single-vertex PPR={recall_single:.3f} "
          f"vs random={recall_rand:.3f}")
    assert recall > recall_rand, "PPR retrieval must beat random"

    # --- stage 2: SASRec scores the PPR candidates ----------------------
    cfg = SASRecConfig(n_items=n_items, embed_dim=32, n_blocks=2,
                       n_heads=1, seq_len=16, d_ff=64)
    params = sasrec.init(cfg, jax.random.PRNGKey(1))
    u = users[0]
    hist_items = (dst[(src == u)] - n_users)[:16]
    hist = np.zeros(16, np.int32)
    hist[-len(hist_items):] = hist_items % n_items
    cand0 = out["indices"][0]
    cands_u = (cand0[cand0 >= n_users][:k_eff] - n_users) % n_items
    scores = sasrec.retrieval_scores(
        cfg, params,
        dict(item_seq=jnp.asarray(hist[None]),
             candidates=jnp.asarray(cands_u)),
    )
    order = np.argsort(-np.asarray(scores))
    print(f"user {u}: top-5 scored candidates "
          f"{cands_u[order[:5]].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
