"""End-to-end LM training driver with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py            # reduced, ~200 steps
    PYTHONPATH=src python examples/train_lm.py --steps 50 # shorter
    PYTHONPATH=src python examples/train_lm.py --full     # real smollm-135m

Demonstrates the production loop on the smollm arch: synthetic token
pipeline, AdamW, loss curve, periodic async checkpointing, a simulated
failure + restore, and the straggler watchdog.
"""

import argparse
import os
import shutil
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.elastic import StepTimer
from repro.launch import steps as steps_mod
from repro.training import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full 135M config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/powerwalk_lm_ckpt")
    args = ap.parse_args()

    arch = get_arch("smollm-135m")
    bundle = steps_mod.build(arch, "train_4k", reduced=not args.full)
    params = bundle.init_fn(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"== training smollm ({'full' if args.full else 'reduced'}): "
          f"{n_params / 1e6:.1f}M params ==")

    opt_state = train_loop.init_state(
        bundle.opt_cfg or steps_mod.SMOKE_OPT, params)
    step_fn = jax.jit(bundle.step_fn, donate_argnums=(0, 1))

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    timer = StepTimer()
    losses = []
    for step in range(args.steps):
        batch = bundle.make_batch(jax.random.PRNGKey(1000 + step))
        t0 = time.perf_counter()
        params, opt_state, m = step_fn(params, opt_state, batch)
        jax.block_until_ready(m["loss"])
        advice = timer.record(time.perf_counter() - t0)
        losses.append(float(m["loss"]))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}"
                  + (f"  [watchdog: {advice}]" if advice else ""))
        if step % 50 == 49:
            ckpt.save(step, (params, opt_state),
                      extra=dict(data_step=step), blocking=False)
    ckpt.wait()

    assert losses[-1] < losses[0], "loss did not improve"

    # --- simulated failure + restart from the last committed checkpoint ---
    last = ckpt.latest_step()
    if last is not None:
        print(f"simulating failure; restoring step {last}")
        (params2, opt2), extra = ckpt.restore(last, (params, opt_state))
        batch = bundle.make_batch(jax.random.PRNGKey(1000 + last + 1))
        _, _, m = jax.jit(bundle.step_fn)(params2, opt2, batch)
        print(f"resumed at data step {extra['data_step'] + 1}, "
              f"loss {float(m['loss']):.4f}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}  OK")


if __name__ == "__main__":
    main()
