"""Quickstart: build a PPR index offline, answer queries online.

    PYTHONPATH=src python examples/quickstart.py

Walks through the whole PowerWalk pipeline on a laptop-scale graph:
  1. synthesize a power-law graph,
  2. offline: MCFP random walks -> top-L PPR index (memory-budget planned),
  3. online: VERD batch query against the index,
  4. validate against power-iteration ground truth (RAG@k, paper metric).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.index import build_index, plan_for_budget
from repro.core.power_iteration import power_iteration
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.graphs import synthetic


def main():
    print("== PowerWalk quickstart ==")
    g = synthetic.rmat(12, avg_deg=12.0, seed=0)
    print(f"graph: n={g.n} m={g.m}")

    # 1. plan the index for a memory budget (paper Section 3)
    budget = 8 << 20  # 8 MiB
    plan = plan_for_budget(g.n, budget)
    print(f"budget={budget >> 20} MiB -> R={plan.r} L={plan.l} "
          f"T_online={plan.t_online}")

    # 2. offline preprocessing (MCFP)
    t0 = time.perf_counter()
    index, stats = build_index(
        g, r=max(plan.r, 10), l=max(plan.l, 16), key=jax.random.PRNGKey(0),
        source_batch=512,
    )
    print(f"index built in {time.perf_counter() - t0:.1f}s; "
          f"{stats['nbytes'] >> 20} MiB, dropped tail mass "
          f"{stats['drop_fraction']:.3f}")

    # 3. online batch query
    engine = BatchQueryEngine(
        g, index, QueryConfig(mode="powerwalk",
                              t_iterations=plan.t_online, top_k=50),
    )
    rng = np.random.default_rng(0)
    queries = rng.integers(0, g.n, size=512).astype(np.int32)
    out = engine.run(queries)           # includes compile
    out = engine.run(queries)           # steady state
    print(f"{out['queries']} queries in {out['seconds']:.3f}s "
          f"({out['qps']:.0f} q/s)")

    # 4. accuracy vs ground truth on a subsample
    sample = queries[:32]
    exact = power_iteration(g, jnp.asarray(sample), n_iter=100)
    approx = engine.query_dense(jnp.asarray(sample))
    rag = metrics.mean_rag(exact, approx, k=50)
    print(f"RAG@50 vs power iteration: {rag:.4f}")
    assert rag > 0.98, "accuracy regression"
    print("OK")


if __name__ == "__main__":
    main()
