"""PowerWalk x GNN: PPR-propagation (APPNP/PPRGo style) vs plain GCN.

    PYTHONPATH=src python examples/gnn_ppr.py

Uses the PowerWalk index as the propagation operator of a GNN: instead of
stacking message-passing layers, each node aggregates an MLP's outputs over
its top-L PPR neighborhood (the paper's technique as a first-class GNN
feature).  Trains both models on a synthetic community graph and compares
accuracy.  Also demonstrates *class-prototype seed-set queries*: one
weighted seed-set PPR query per class (its labeled training nodes restart
together) is already a label-propagation classifier with no training at
all.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import build_index
from repro.graphs import sampler
from repro.models import gcn as gcn_mod
from repro.models.gcn import GCNConfig
from repro.training import optimizer as opt_mod


def community_graph(n_comm=6, per_comm=60, d_feat=16, seed=0):
    """Stochastic block model-ish graph with community-correlated features."""
    rng = np.random.default_rng(seed)
    n = n_comm * per_comm
    labels = np.repeat(np.arange(n_comm), per_comm)
    src, dst = [], []
    for i in range(n):
        same = rng.choice(np.nonzero(labels == labels[i])[0], size=8)
        other = rng.integers(0, n, size=2)
        for j in np.concatenate([same, other]):
            if j != i:
                src.append(i)
                dst.append(int(j))
    feats = rng.normal(size=(n, d_feat)).astype(np.float32)
    onehot = np.eye(n_comm)[labels].astype(np.float32)  # [n, n_comm]
    feats[:, : n_comm] += 2.0 * onehot
    from repro.core.graph import Graph
    return Graph.from_edges(src, dst, n=n), feats, labels.astype(np.int32)


def accuracy(logits, labels, mask):
    pred = np.asarray(jnp.argmax(logits, -1))
    return float((pred[mask] == labels[mask]).mean())


def main():
    print("== PPR-propagation GNN vs plain GCN ==")
    g, feats, labels = community_graph()
    n = g.n
    rng = np.random.default_rng(0)
    train_mask = rng.random(n) < 0.3
    test_mask = ~train_mask

    cfg = GCNConfig(n_layers=2, d_feat=feats.shape[1], d_hidden=32,
                    n_classes=labels.max() + 1, aggregator="sym")
    batch = dict(
        features=jnp.asarray(feats),
        edge_src=g.src, edge_dst=g.col_idx,
        labels=jnp.asarray(labels),
        label_mask=jnp.asarray(train_mask.astype(np.float32)),
    )

    def train(loss_fn, params, batch, steps=150, lr=0.05):
        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        for _ in range(steps):
            loss, grads = grad_fn(params, batch)
            params = opt_mod.sgd_update(params, grads, lr)
        return params, float(loss)

    # --- plain GCN -----------------------------------------------------
    p0 = gcn_mod.init(cfg, jax.random.PRNGKey(0))
    p_gcn, loss_gcn = train(
        lambda p, b: gcn_mod.loss_full(cfg, p, b), p0, batch)
    logits = gcn_mod.forward_full(cfg, p_gcn, batch["features"],
                                  batch["edge_src"], batch["edge_dst"])
    acc_gcn = accuracy(logits, labels, test_mask)

    # --- PPR-propagation model ------------------------------------------
    index, _ = build_index(g, r=100, l=32, key=jax.random.PRNGKey(1),
                           source_batch=256)
    nbr, w = sampler.ppr_importance_sample(
        np.asarray(index.values), np.asarray(index.indices),
        np.arange(n), budget=16,
    )
    ppr_batch = dict(
        feats=jnp.asarray(feats),
        ppr_idx=jnp.asarray(nbr), ppr_vals=jnp.asarray(w),
        labels=jnp.asarray(labels),
    )

    def ppr_loss(p, b):
        h = b["feats"]
        for i in range(cfg.n_layers):
            from repro.models import layers as L
            h = L.dense_apply(p[f"layer_{i}"], h)
            if i < cfg.n_layers - 1:
                h = jax.nn.relu(h)
        logits = gcn_mod.ppr_propagate(h, b["ppr_vals"], b["ppr_idx"])
        from repro.models import layers as L
        nll = L.softmax_cross_entropy(
            logits, b["labels"], jnp.asarray(train_mask.astype(np.float32)))
        return nll

    p1 = gcn_mod.init(cfg, jax.random.PRNGKey(2))
    p_ppr, loss_ppr = train(ppr_loss, p1, ppr_batch)
    from repro.models import layers as L
    h = ppr_batch["feats"]
    for i in range(cfg.n_layers):
        h = L.dense_apply(p_ppr[f"layer_{i}"], h)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    logits_ppr = gcn_mod.ppr_propagate(
        h, ppr_batch["ppr_vals"], ppr_batch["ppr_idx"])
    acc_ppr = accuracy(logits_ppr, labels, test_mask)

    # --- class-prototype seed-set queries -------------------------------
    # one weighted seed-set query per class: up to 8 labeled training
    # nodes restart together, and the resulting PPR mass over the graph is
    # a soft class assignment — label propagation with zero training,
    # straight through the seed-set query API
    from repro.core.query import BatchQueryEngine, QueryConfig

    n_classes = int(labels.max() + 1)
    max_seeds = 8
    proto_seeds = np.zeros((n_classes, max_seeds), np.int32)
    proto_weights = np.zeros((n_classes, max_seeds), np.float32)
    for c in range(n_classes):
        pool = np.flatnonzero(train_mask & (labels == c))[:max_seeds]
        proto_seeds[c, : len(pool)] = pool
        proto_weights[c, : len(pool)] = 1.0       # uniform over prototypes
    engine = BatchQueryEngine(g, index, QueryConfig(
        mode="powerwalk", t_iterations=2, top_k=32, max_seeds=max_seeds))
    class_mass = np.asarray(engine.query_dense(
        jnp.asarray(proto_seeds), weights=jnp.asarray(proto_weights)))
    pred = class_mass.argmax(axis=0)              # [n]: best class per node
    acc_seed = float((pred[test_mask] == labels[test_mask]).mean())

    print(f"plain GCN:      loss {loss_gcn:.3f}  test acc {acc_gcn:.3f}")
    print(f"PPR-prop:       loss {loss_ppr:.3f}  test acc {acc_ppr:.3f}")
    print(f"seed-set proto: (no training)   test acc {acc_seed:.3f}")
    assert acc_ppr > 0.5 and acc_gcn > 0.5
    assert acc_seed > 0.5, "class-prototype seed sets must beat chance"
    print("OK")


if __name__ == "__main__":
    main()
