"""Sparse-format conversions (CSR <-> COO <-> padded-ELL).

TPUs want dense, regular tiles.  The padded-ELL view turns the pull-mode
frontier push (one VERD iteration) into a gather + masked reduction with
static shapes.  Power-law graphs have huge maximum in-degree, so a plain
``[n, max_in_deg]`` ELL would be catastrically padded; instead we use
*row-chunked ELL*: every vertex occupies ``ceil(in_deg / k)`` rows of width
``k`` and a ``row2vertex`` map folds partial rows back with a segment-sum.
Hub vertices simply own many rows — the padding overhead is bounded by
``k - 1`` slots per vertex.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllChunks:
    """Row-chunked ELL view of the *reversed* graph (pull by destination).

    Attributes:
      nbr:     int32[rows, k]   in-neighbor ids (padded with 0).
      weight:  f32[rows, k]     1/out_deg[nbr] (0 at padding).
      row2vertex: int32[rows]   destination vertex of each chunk row.
      rows, k: static shape info.
      n:       static number of vertices.
    """

    nbr: jax.Array
    weight: jax.Array
    row2vertex: jax.Array
    rows: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))


def to_ell_chunks(graph: Graph, k: int = 16, pad_rows_to: int = 1) -> EllChunks:
    """Build the row-chunked ELL pull view of ``graph``.

    Each chunk row holds up to ``k`` in-edges of one destination vertex.
    ``rows`` is padded up to a multiple of ``pad_rows_to`` (kernel tiling).
    """
    src = np.asarray(graph.src)
    dst = np.asarray(graph.col_idx)
    n = graph.n
    inv_deg = np.zeros(n, dtype=np.float32)
    deg = np.asarray(graph.out_deg)
    nz = deg > 0
    inv_deg[nz] = 1.0 / deg[nz]

    order = np.argsort(dst, kind="stable")
    src_by_dst = src[order]
    dst_sorted = dst[order]
    in_deg = np.bincount(dst, minlength=n)
    chunks_per_v = np.maximum((in_deg + k - 1) // k, 0)
    rows = int(chunks_per_v.sum())
    rows_padded = max(((rows + pad_rows_to - 1) // pad_rows_to) * pad_rows_to, pad_rows_to)

    nbr = np.zeros((rows_padded, k), dtype=np.int32)
    weight = np.zeros((rows_padded, k), dtype=np.float32)
    row2vertex = np.zeros(rows_padded, dtype=np.int32)

    row_start_per_v = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(chunks_per_v, out=row_start_per_v[1:])
    edge_start_per_v = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(in_deg, out=edge_start_per_v[1:])

    # position of each (sorted) edge within its destination's in-list
    pos_in_v = np.arange(len(dst_sorted)) - edge_start_per_v[dst_sorted]
    row_of_edge = row_start_per_v[dst_sorted] + pos_in_v // k
    col_of_edge = pos_in_v % k
    nbr[row_of_edge, col_of_edge] = src_by_dst
    weight[row_of_edge, col_of_edge] = inv_deg[src_by_dst]

    # map every chunk row back to its destination vertex
    v_ids = np.repeat(np.arange(n, dtype=np.int32), chunks_per_v)
    row2vertex[: len(v_ids)] = v_ids
    # padding rows point at vertex 0 with zero weight -> harmless
    return EllChunks(
        nbr=jnp.asarray(nbr),
        weight=jnp.asarray(weight),
        row2vertex=jnp.asarray(row2vertex),
        rows=rows_padded,
        k=k,
        n=n,
    )


def ell_pull(ell: EllChunks, frontier: jax.Array) -> jax.Array:
    """Pure-jnp pull: ``frontier @ A0`` via the chunked-ELL view.

    ``frontier``: f32[q, n] -> returns f32[q, n].  Reference implementation
    for the Pallas ``ell_spmm`` kernel (and a perfectly good TPU path on its
    own: one gather + one segment-sum).
    """
    gathered = jnp.take(frontier, ell.nbr.reshape(-1), axis=1)
    gathered = gathered.reshape(frontier.shape[0], ell.rows, ell.k)
    partial = jnp.sum(gathered * ell.weight[None, :, :], axis=-1)  # [q, rows]
    return jax.ops.segment_sum(
        partial.T, ell.row2vertex, num_segments=ell.n
    ).T


def to_coo_sorted_by_dst(graph: Graph):
    """(src, dst, weight) sorted by destination — the push-mode edge list."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.col_idx)
    order = np.argsort(dst, kind="stable")
    w = np.zeros(len(src), dtype=np.float32)
    deg = np.asarray(graph.out_deg).astype(np.float32)
    w = 1.0 / deg[src]
    return (
        jnp.asarray(src[order].astype(np.int32)),
        jnp.asarray(dst[order].astype(np.int32)),
        jnp.asarray(w[order]),
    )


def pad_edges(graph: Graph, multiple: int) -> Graph:
    """Pad the edge list to a multiple (self-loops on a ghost row are not
    possible without growing n, so we pad with zero-weight duplicate edges of
    vertex 0 guarded by out_deg bookkeeping).  Used only by kernels that need
    edge-count alignment; the weight array computed from ``out_deg`` keeps the
    padded copies harmless because they are marked via ``pad_mask``."""
    m = graph.m
    m_pad = ((m + multiple - 1) // multiple) * multiple
    if m_pad == m:
        return graph
    extra = m_pad - m
    src = np.concatenate([np.asarray(graph.src), np.zeros(extra, np.int32)])
    dst = np.concatenate([np.asarray(graph.col_idx), np.zeros(extra, np.int32)])
    # NOTE: out_deg must stay the *true* degree; rebuild manually.
    row_ptr = np.asarray(graph.row_ptr)
    return Graph(
        row_ptr=jnp.asarray(row_ptr),
        col_idx=jnp.asarray(dst.astype(np.int32)),
        src=jnp.asarray(src.astype(np.int32)),
        out_deg=graph.out_deg,
        n=graph.n,
        m=m,  # logical edge count unchanged; arrays are longer
    )
