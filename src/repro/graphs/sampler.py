"""Neighbor samplers for GNN minibatch training.

``minibatch_lg`` (232k nodes / 114M edges, batch_nodes=1024, fanout 15-10)
needs a real sampler: we provide the classic GraphSAGE uniform fanout
sampler plus a PPR-importance sampler built on the PowerWalk index (the
PPRGo/GBP lineage) — the paper's technique applied to GNN data loading.

Sampling runs on host (numpy) and emits fixed-shape padded blocks so the
jitted train step sees static shapes.  The sampler is deterministic given
(seed, step) which makes data-pipeline checkpointing trivial.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One message-passing layer block, fixed shapes for jit.

    nodes:    int32[n_dst + n_dst * fanout] unique node ids of the block
              (first n_dst are the destinations), padded with -1 -> index 0.
    edge_src: int32[n_dst * fanout] positions into ``nodes``.
    edge_dst: int32[n_dst * fanout] positions into the first n_dst entries.
    edge_mask: f32[n_dst * fanout] 1.0 for real sampled edges.
    """

    nodes: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray


def _sample_neighbors(
    row_ptr: np.ndarray,
    col_idx: np.ndarray,
    seeds: np.ndarray,
    fanout: int,
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray]:
    """Uniform with-replacement fanout sample. Returns (nbrs, mask)."""
    deg = row_ptr[seeds + 1] - row_ptr[seeds]
    # random offsets in [0, deg); deg==0 -> mask out
    offs = (rng.random((len(seeds), fanout)) * np.maximum(deg, 1)[:, None]).astype(
        np.int64
    )
    nbrs = col_idx[row_ptr[seeds][:, None] + offs]
    mask = (deg > 0)[:, None].astype(np.float32) * np.ones(
        (1, fanout), np.float32
    )
    nbrs = np.where(mask > 0, nbrs, 0)
    return nbrs.astype(np.int32), mask


def fanout_sample(
    graph: Graph,
    batch_nodes: np.ndarray,
    fanouts: Sequence[int],
    seed: int = 0,
    step: int = 0,
) -> List[SampledBlock]:
    """Multi-hop fanout sampling, innermost layer first (GraphSAGE order).

    Returns one :class:`SampledBlock` per fanout, outermost hop last; the
    model consumes them in reverse.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    row_ptr = np.asarray(graph.row_ptr).astype(np.int64)
    col_idx = np.asarray(graph.col_idx).astype(np.int64)
    blocks: List[SampledBlock] = []
    frontier = np.asarray(batch_nodes, dtype=np.int64)
    for fanout in fanouts:
        nbrs, mask = _sample_neighbors(row_ptr, col_idx, frontier, fanout, rng)
        n_dst = len(frontier)
        nodes = np.concatenate([frontier, nbrs.reshape(-1)])
        edge_src = np.arange(n_dst, n_dst + n_dst * fanout, dtype=np.int32)
        edge_dst = np.repeat(np.arange(n_dst, dtype=np.int32), fanout)
        blocks.append(
            SampledBlock(
                nodes=nodes.astype(np.int32),
                edge_src=edge_src,
                edge_dst=edge_dst,
                edge_mask=mask.reshape(-1),
            )
        )
        frontier = nodes  # next hop expands from all block nodes
    return blocks


def ppr_importance_sample(
    index_values: np.ndarray,
    index_indices: np.ndarray,
    batch_nodes: np.ndarray,
    budget: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """PPRGo-style sampling: keep the ``budget`` highest-PPR neighbors of
    each seed according to the PowerWalk index.

    index_values/indices: [n, L] top-L PPR index (from core.index).
    Returns (nbr_ids int32[batch, budget], weights f32[batch, budget]) —
    a fixed-shape importance-weighted neighborhood that replaces multi-hop
    expansion with a single PPR-weighted aggregation (the paper's index put
    to work as a GNN data structure).
    """
    vals = index_values[batch_nodes]  # [b, L]
    idxs = index_indices[batch_nodes]
    b = min(budget, vals.shape[1])
    top = np.argsort(-vals, axis=1)[:, :b]
    rows = np.arange(len(batch_nodes))[:, None]
    w = vals[rows, top]
    nbr = idxs[rows, top]
    norm = np.maximum(w.sum(axis=1, keepdims=True), 1e-12)
    return nbr.astype(np.int32), (w / norm).astype(np.float32)
