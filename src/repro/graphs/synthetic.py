"""Synthetic graph generators.

Deterministic (seeded) numpy generators for tests, benchmarks, and smoke
configs.  The RMAT generator produces the power-law degree distributions the
paper's datasets exhibit (Table 1); named tiny graphs mirror the paper's
illustrative figures.
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def figure2_graph() -> Graph:
    """The 8-vertex graph of paper Figure 2 (v1..v8 -> 0..7).

    v1 -> v2, v3; v2 -> v4, v5; v3 -> v6, v7;
    v4 -> v8; v5..v8 sinks except enough edges to be interesting:
    the paper draws v4..v8 with out-edges omitted; we keep v4 -> v8 and
    leave v5..v8 dangling so dangling semantics get exercised.
    """
    src = [0, 0, 1, 1, 2, 2, 3]
    dst = [1, 2, 3, 4, 5, 6, 7]
    return Graph.from_edges(src, dst, n=8)


def cycle(n: int) -> Graph:
    src = np.arange(n)
    return Graph.from_edges(src, (src + 1) % n, n=n)


def complete(n: int) -> Graph:
    src, dst = np.nonzero(~np.eye(n, dtype=bool))
    return Graph.from_edges(src, dst, n=n)


def star(n: int) -> Graph:
    """Hub 0 -> spokes and spokes -> hub (extreme degree skew)."""
    spokes = np.arange(1, n)
    src = np.concatenate([np.zeros(n - 1, np.int64), spokes])
    dst = np.concatenate([spokes, np.zeros(n - 1, np.int64)])
    return Graph.from_edges(src, dst, n=n)


def erdos_renyi(n: int, avg_deg: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    keep = src != dst
    return Graph.from_edges(src[keep], dst[keep], n=n)


def rmat(
    n_log2: int,
    avg_deg: float = 16.0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    dedup: bool = True,
) -> Graph:
    """R-MAT / Kronecker generator (Graph500 parameters by default).

    Produces the heavy-tailed in/out degree distributions typical of the
    paper's web/social graphs.
    """
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    m = int(n * avg_deg)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(n_log2):
        r = rng.random(m)
        # quadrant probabilities with slight noise per level (standard trick
        # to avoid exact self-similarity artifacts)
        go_right = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        go_down = (r >= a) & (r < a + b) | (r >= a + b + c)
        src += go_down.astype(np.int64) << level
        dst += go_right.astype(np.int64) << level
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if dedup:
        key = src * n + dst
        _, idx = np.unique(key, return_index=True)
        src, dst = src[idx], dst[idx]
    return Graph.from_edges(src, dst, n=n)


def bipartite_recsys(
    n_users: int, n_items: int, avg_deg: float = 8.0, seed: int = 0
) -> Graph:
    """User->item + item->user bipartite interaction graph.

    Vertices [0, n_users) are users, [n_users, n_users + n_items) items.
    Item popularity is Zipf-distributed, matching click-log skew; used by the
    PPR-based candidate-retrieval example.
    """
    rng = np.random.default_rng(seed)
    m = int(n_users * avg_deg)
    users = rng.integers(0, n_users, size=m)
    # Zipf over items, clipped into range
    items = (rng.zipf(1.5, size=m) - 1) % n_items + n_users
    src = np.concatenate([users, items])
    dst = np.concatenate([items, users])
    return Graph.from_edges(src, dst, n=n_users + n_items)


def batched_molecules(
    n_graphs: int, nodes_per_graph: int, edges_per_graph: int, seed: int = 0
) -> Graph:
    """A block-diagonal union of small random molecule-like graphs."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for g in range(n_graphs):
        off = g * nodes_per_graph
        # random connected-ish: a ring plus random chords, symmetrized
        ring = np.arange(nodes_per_graph)
        s = np.concatenate(
            [ring, rng.integers(0, nodes_per_graph, edges_per_graph)]
        )
        d = np.concatenate(
            [(ring + 1) % nodes_per_graph,
             rng.integers(0, nodes_per_graph, edges_per_graph)]
        )
        keep = s != d
        s, d = s[keep], d[keep]
        srcs.append(np.concatenate([s, d]) + off)
        dsts.append(np.concatenate([d, s]) + off)
    return Graph.from_edges(
        np.concatenate(srcs), np.concatenate(dsts), n=n_graphs * nodes_per_graph
    )
