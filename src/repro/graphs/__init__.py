"""Graph substrate: formats, synthetic generators, partitioning, sampling."""

from repro.graphs import formats, partition, sampler, synthetic  # noqa: F401
