"""Vertex-interval partitioning (paper Section 3.1).

The paper's master divides ``V`` into disjoint intervals and hands each to a
slave.  On an SPMD mesh there is no master: intervals become static shard
assignments over the flattened (pod, data) axes.  Balanced partitioning by
*edge count* (not vertex count) avoids stragglers on power-law graphs — a
straggler-mitigation feature the MPI original lacks.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: int
    hi: int  # exclusive
    edges: int

    @property
    def size(self) -> int:
        return self.hi - self.lo


def vertex_intervals(graph: Graph, parts: int) -> List[Interval]:
    """Contiguous intervals with ~equal vertex counts."""
    bounds = np.linspace(0, graph.n, parts + 1).astype(np.int64)
    row_ptr = np.asarray(graph.row_ptr)
    return [
        Interval(int(lo), int(hi), int(row_ptr[hi] - row_ptr[lo]))
        for lo, hi in zip(bounds[:-1], bounds[1:])
    ]


def edge_balanced_intervals(graph: Graph, parts: int) -> List[Interval]:
    """Contiguous intervals with ~equal *edge* counts (straggler-aware).

    Walk work per source interval is proportional to walks x mean walk
    length, but index-build scatter cost scales with local edge mass; edge
    balancing equalizes the dominant cost on skewed graphs.
    """
    row_ptr = np.asarray(graph.row_ptr).astype(np.int64)
    m = int(row_ptr[-1])
    targets = np.linspace(0, m, parts + 1)
    cut = np.searchsorted(row_ptr, targets, side="left")
    cut[0], cut[-1] = 0, graph.n
    cut = np.maximum.accumulate(cut)  # monotone even on degenerate graphs
    out = []
    for lo, hi in zip(cut[:-1], cut[1:]):
        out.append(Interval(int(lo), int(hi), int(row_ptr[hi] - row_ptr[lo])))
    return out


def balance_stats(intervals: List[Interval]) -> Tuple[float, float]:
    """(vertex imbalance, edge imbalance) = max/mean ratios."""
    sizes = np.array([iv.size for iv in intervals], dtype=np.float64)
    edges = np.array([iv.edges for iv in intervals], dtype=np.float64)
    v = float(sizes.max() / max(sizes.mean(), 1e-9))
    e = float(edges.max() / max(edges.mean(), 1e-9)) if edges.sum() else 1.0
    return v, e


def assign_sources_to_shards(
    sources: np.ndarray, n_shards: int
) -> List[np.ndarray]:
    """Round-robin query/source assignment — the online analogue of the
    master handing intervals to idle slaves."""
    return [np.asarray(sources[i::n_shards]) for i in range(n_shards)]
