"""Serving: request batching + the async pipelined online PPR service."""

from repro.serving.engine import Answer, PPRService, ServiceConfig  # noqa: F401
from repro.serving.loadgen import run_closed_loop, run_open_loop  # noqa: F401
from repro.serving.pipeline import PipelineConfig, ServingPipeline  # noqa: F401
