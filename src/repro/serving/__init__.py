"""Serving: request batching + the online PPR query service."""

from repro.serving.engine import PPRService, ServiceConfig  # noqa: F401
