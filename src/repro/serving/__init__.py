"""Serving: request batching + the async pipelined online PPR service."""

from repro.serving.cache import (  # noqa: F401
    AnswerCache, CacheConfig, canonicalize_seed_set,
)
from repro.serving.engine import Answer, PPRService, ServiceConfig  # noqa: F401
from repro.serving.loadgen import (  # noqa: F401
    run_closed_loop, run_open_loop, zipf_seed_workload,
)
from repro.serving.pipeline import PipelineConfig, ServingPipeline  # noqa: F401
