"""The online PPR service: buffer -> shared decomposition -> top-k answers.

End-to-end serving loop for the paper's product: clients submit query
vertices; the service batches them (Section 3.3), runs the VERD shared
decomposition against the PPR index, and returns top-k (vertex, score)
lists.  Collects the latency/throughput metrics the paper's Table 3
reports.

Since PR 6 the service is pipelined: ``poll()`` *dispatches* ready batches
without syncing (JAX async dispatch keeps up to ``pipeline.depth`` batches
in flight on the device stream) and *harvests* whichever in-flight batches
have finished — see ``serving/pipeline.py`` and docs/serving_path.md.
``pipeline.depth=1`` reproduces the old blocking poll exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.index import PPRIndex
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.serving.batching import BatchingConfig, RequestBuffer
from repro.serving.pipeline import CompletedBatch, PipelineConfig, ServingPipeline


@dataclasses.dataclass
class ServiceConfig:
    query: QueryConfig = dataclasses.field(default_factory=QueryConfig)
    batching: BatchingConfig = dataclasses.field(default_factory=BatchingConfig)
    pipeline: PipelineConfig = dataclasses.field(default_factory=PipelineConfig)


@dataclasses.dataclass
class Answer:
    request_id: int
    vertex: int
    top_vertices: np.ndarray
    top_scores: np.ndarray
    latency_s: float
    tier: str = "interactive"


class PPRService:
    """Serves PPR answers against a :class:`PPRIndex`.

    The index may be the output of ``index.build_index_sharded``: its
    ``values/indices`` arrays stay device-sharded over the model axis
    (``P("model", None)``) and may carry zeroed pad rows (``index.n >=
    graph.n``) — the query paths only ever gather real rows, so nothing is
    replicated or re-laid-out to serve from it.  Answer width is the
    engine's ``effective_top_k`` (``top_k`` clamped to the graph), so
    ``poll()`` rows always match the configured buffers.
    """

    def __init__(self, graph: Graph, index: Optional[PPRIndex],
                 cfg: Optional[ServiceConfig] = None, clock=None):
        self.cfg = cfg or ServiceConfig()
        self.engine = BatchQueryEngine(graph, index, self.cfg.query)
        self.buffer = RequestBuffer(self.cfg.batching, clock=clock)
        self.clock = clock or time.monotonic
        self.pipeline = ServingPipeline(
            self.engine, self.buffer, self.cfg.pipeline, clock=self.clock
        )
        # which execution the engine routed to (docs/query_path.md): part of
        # the serving telemetry so capacity planning can see Q x K vs Q x n
        self.frontier_path = (
            "sparse" if self.engine.uses_sparse_path() else "dense"
        )
        self.answer_k = self.engine.effective_top_k
        # index layout telemetry: pad rows of a sharded build + whether the
        # backing arrays are device-sharded (capacity planning reads this)
        self.index_rows = index.n if index is not None else 0
        self.index_sharded = bool(
            index is not None
            and getattr(index.values, "sharding", None) is not None
            and not index.values.sharding.is_fully_replicated
        )
        self.stats: Dict[str, float] = dict(
            served=0, batches=0, total_latency=0.0, max_latency=0.0,
            pad_rows=0, first_batch_service_s=0.0,
        )

    # -- client API ----------------------------------------------------------
    def submit(self, vertex: int, tier: str = "interactive",
               arrival: Optional[float] = None) -> int:
        return self.buffer.submit(vertex, tier=tier, arrival=arrival)

    @property
    def in_flight(self) -> int:
        return self.pipeline.in_flight

    def poll(self, force: bool = False) -> List[Answer]:
        """Advance the pipeline; returns completed answers.

        Dispatches every ready batch (``force`` drains the buffer
        regardless of deadlines) and harvests finished ones.  At
        ``pipeline.depth=1`` — or with ``force`` — the harvest blocks, so
        every dispatched batch's answers come back from the same call,
        matching the pre-pipeline blocking ``poll()``.
        """
        if (not len(self.buffer) or not (self.buffer.ready() or force)) \
                and not self.pipeline.in_flight:
            return []
        drain = force or self.cfg.pipeline.depth <= 1
        completed = self.pipeline.dispatch(force=force)
        completed.extend(self.pipeline.harvest(drain=drain))
        # harvesting freed pipeline slots; a deadline-fired batch deferred
        # while the device was busy can launch now instead of next poll
        more = self.pipeline.dispatch(force=force)
        if more or (drain and self.pipeline.in_flight):
            completed.extend(more)
            completed.extend(self.pipeline.harvest(drain=drain))
        return self._absorb(completed)

    # -- bookkeeping ---------------------------------------------------------
    def _absorb(self, completed: List[CompletedBatch]) -> List[Answer]:
        out: List[Answer] = []
        for batch in completed:
            if not self.stats["batches"]:
                # satellite fix: record first-batch service time (dominated
                # by jit compilation on a cold service) so load harnesses
                # can report wall_s_excl_first_batch alongside raw wall
                self.stats["first_batch_service_s"] = (
                    batch.completed_at - batch.dispatched_at
                )
            self.stats["pad_rows"] += batch.padded - len(batch.requests)
            self.stats["batches"] += 1
            for i, r in enumerate(batch.requests):
                lat = batch.completed_at - r.arrival
                out.append(Answer(
                    r.request_id, r.vertex, batch.indices[i],
                    batch.values[i], lat, r.tier,
                ))
                self.stats["served"] += 1
                self.stats["total_latency"] += lat
                self.stats["max_latency"] = max(self.stats["max_latency"], lat)
        return out

    def reset_stats(self) -> None:
        """Zero counters (e.g. after warmup dispatches in a benchmark)."""
        for k in self.stats:
            self.stats[k] = 0 if isinstance(self.stats[k], int) else 0.0
        for k in self.pipeline.stats:
            self.pipeline.stats[k] = 0
        self.pipeline.batch_hist.clear()

    def snapshot_stats(self) -> dict:
        """Service + pipeline telemetry as one flat dict (JSON-safe)."""
        s = dict(self.stats)
        s["frontier_path"] = self.frontier_path
        s["answer_k"] = self.answer_k
        s["index_rows"] = self.index_rows
        s["index_sharded"] = self.index_sharded
        s["pipeline_depth"] = self.cfg.pipeline.depth
        s["dispatch_path"] = self.cfg.pipeline.dispatch
        s["combine_path"] = (
            "scatter" if self.engine.uses_scatter_combine(
                self.cfg.batching.max_batch) else "sparse"
        ) if self.frontier_path == "sparse" else "dense"
        s.update({f"pipeline_{k}": v for k, v in self.pipeline.stats.items()})
        s["batch_hist"] = {
            int(k): int(v) for k, v in sorted(self.pipeline.batch_hist.items())
        }
        s["mean_latency"] = s["total_latency"] / max(s["served"], 1)
        s["pad_fraction"] = s["pad_rows"] / max(s["served"] + s["pad_rows"], 1)
        return s

    def run_closed_loop(self, vertices: Sequence[int]) -> Tuple[List[Answer], dict]:
        """Serve a fixed workload to completion (benchmark mode).

        Thin wrapper over the open-loop harness at unbounded offer rate —
        see ``serving/loadgen.py`` for the rate-controlled version.
        """
        from repro.serving import loadgen
        return loadgen.run_closed_loop(self, vertices)
