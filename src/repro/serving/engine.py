"""The online PPR service: buffer -> shared decomposition -> top-k answers.

End-to-end serving loop for the paper's product: clients submit query
vertices; the service batches them (Section 3.3), runs the VERD shared
decomposition against the PPR index, and returns top-k (vertex, score)
lists.  Collects the latency/throughput metrics the paper's Table 3
reports.

Since PR 6 the service is pipelined: ``poll()`` *dispatches* ready batches
without syncing (JAX async dispatch keeps up to ``pipeline.depth`` batches
in flight on the device stream) and *harvests* whichever in-flight batches
have finished — see ``serving/pipeline.py`` and docs/serving_path.md.
``pipeline.depth=1`` reproduces the old blocking poll exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.index import PPRIndex
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.serving.batching import (BatchingConfig, BufferOverloadError,
                                    RequestBuffer)
from repro.serving.cache import AnswerCache, CacheConfig, canonicalize_seed_set
from repro.serving.pipeline import CompletedBatch, PipelineConfig, ServingPipeline


@dataclasses.dataclass
class ServiceConfig:
    query: QueryConfig = dataclasses.field(default_factory=QueryConfig)
    batching: BatchingConfig = dataclasses.field(default_factory=BatchingConfig)
    pipeline: PipelineConfig = dataclasses.field(default_factory=PipelineConfig)
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)


@dataclasses.dataclass
class Answer:
    request_id: int
    vertex: int
    top_vertices: np.ndarray
    top_scores: np.ndarray
    latency_s: float
    tier: str = "interactive"
    cached: bool = False          # served from the answer cache (no dispatch)
    rejected: bool = False        # shed by admission control: empty top-k,
                                  # never dispatched (client should back off)


class PPRService:
    """Serves PPR answers against a :class:`PPRIndex`.

    The index may be the output of ``index.build_index_sharded``: its
    ``values/indices`` arrays stay device-sharded over the model axis
    (``P("model", None)``) and may carry zeroed pad rows (``index.n >=
    graph.n``) — the query paths only ever gather real rows, so nothing is
    replicated or re-laid-out to serve from it.  Answer width is the
    engine's ``effective_top_k`` (``top_k`` clamped to the graph), so
    ``poll()`` rows always match the configured buffers.
    """

    def __init__(self, graph: Graph, index: Optional[PPRIndex],
                 cfg: Optional[ServiceConfig] = None, clock=None,
                 maintainer=None):
        self.cfg = cfg or ServiceConfig()
        # maintainer: a core.updates.MaintainableIndex — enables
        # apply_updates() (incremental index repair + exact cache
        # invalidation).  With index=None the maintainer's index serves.
        self.maintainer = maintainer
        if index is None and maintainer is not None:
            index = maintainer.index
        self.graph = graph
        self.engine = BatchQueryEngine(graph, index, self.cfg.query)
        self.buffer = RequestBuffer(self.cfg.batching, clock=clock)
        self.clock = clock or time.monotonic
        # the cache exists before the pipeline so dispatches can stamp its
        # epoch onto their tickets (invalidate-vs-in-flight fencing)
        self.cache = AnswerCache(self.cfg.cache)
        self.pipeline = ServingPipeline(
            self.engine, self.buffer, self.cfg.pipeline, clock=self.clock,
            epoch_fn=lambda: self.cache.epoch,
        )
        # which execution the engine routed to (docs/query_path.md): part of
        # the serving telemetry so capacity planning can see Q x K vs Q x n
        self.frontier_path = (
            "sparse" if self.engine.uses_sparse_path() else "dense"
        )
        self.answer_k = self.engine.effective_top_k
        # index layout telemetry: pad rows of a sharded build + whether the
        # backing arrays are device-sharded (capacity planning reads this)
        self.index_rows = index.n if index is not None else 0
        self.index_sharded = bool(
            index is not None
            and getattr(index.values, "sharding", None) is not None
            and not index.values.sharding.is_fully_replicated
        )
        self.stats: Dict[str, float] = dict(
            served=0, batches=0, total_latency=0.0, max_latency=0.0,
            pad_rows=0, first_batch_service_s=0.0, cache_served=0,
            updates_applied=0, rows_repaired=0, cache_stale_drops=0,
            shed=0, update_rollbacks=0,
        )
        # answer cache (serving/cache.py): consulted at submit, filled at
        # absorb.  _pending_cached holds hit answers awaiting the next
        # poll(); _inflight_keys maps computed requests back to their
        # canonical key so their answers populate the cache.
        self._pending_cached: List[Tuple[int, int, str, float, Tuple]] = []
        self._inflight_keys: Dict[int, Tuple] = {}
        # requests shed by admission control awaiting their rejected answer
        self._pending_rejected: List[Tuple[int, int, str, float]] = []

    @classmethod
    def from_checkpoint(cls, graph: Graph, checkpoint_dir: str,
                        cfg: Optional[ServiceConfig] = None,
                        clock=None) -> "PPRService":
        """Boot a service from a *complete* committed build checkpoint.

        The crash-safe restart path: after a (possibly resumed) build, the
        final ``complete=True`` step under ``checkpoint_dir`` holds the
        assembled index, so a server restart reloads it without
        re-simulating any walks.  A maintainable build (touch sketch in
        the checkpoint) reloads with a full ``maintainer`` — so
        ``apply_updates`` keeps working across the restart; a plain build
        serves read-only.  Mid-build partial steps, ``.tmp`` dirs, and
        checksum-corrupted steps are never booted from
        (:func:`repro.core.index.load_index_checkpoint`).
        """
        from repro.core.index import load_index_checkpoint
        from repro.core.updates import load_maintainable_index

        try:
            m, _ = load_maintainable_index(checkpoint_dir)
        except ValueError:  # no touch sketch: not a maintainable build
            index, _ = load_index_checkpoint(checkpoint_dir)
            return cls(graph, index, cfg, clock=clock)
        if m.real_n != graph.n:
            raise ValueError(
                f"checkpoint was built on {m.real_n} vertices but the "
                f"graph has {graph.n}")
        return cls(graph, None, cfg, clock=clock, maintainer=m)

    # -- client API ----------------------------------------------------------
    def submit(self, vertex: Optional[int] = None, tier: str = "interactive",
               arrival: Optional[float] = None,
               seeds: Optional[Sequence[int]] = None,
               weights: Optional[Sequence[float]] = None) -> int:
        """Enqueue a query: a single ``vertex`` or a weighted seed set
        (``seeds``/``weights``, uniform when weights omitted; at most
        ``query.max_seeds`` seeds).  With the answer cache enabled, a
        request whose canonical seed set is cached never reaches the
        request buffer — its answer is delivered by the next ``poll()``.

        Under admission control (``batching.max_queue_depth``) a submit
        against a full buffer is *shed*: it still gets a request id, but
        the next ``poll()`` delivers an empty answer with
        ``rejected=True`` instead of queueing the request into a latency
        cliff.  Cache hits bypass the buffer and are never shed.
        """
        if seeds is not None:
            # contract: allow(host-sync): validates a host-side seed list
            # at submit time, before anything touches the device
            s_arr = np.asarray(seeds, dtype=np.int64).reshape(-1)
            if s_arr.size > self.cfg.query.max_seeds:
                raise ValueError(
                    f"seed set of {s_arr.size} exceeds "
                    f"query.max_seeds={self.cfg.query.max_seeds}"
                )
        if self.cache.enabled:
            key = canonicalize_seed_set(
                [vertex] if seeds is None else seeds,
                None if seeds is None else weights,
                weight_quantum=self.cfg.cache.weight_quantum,
            )
            if key[0]:  # non-degenerate seed set: cacheable
                primary = (
                    int(vertex) if seeds is None
                    # contract: allow(host-sync): host-side seed list
                    else int(np.asarray(seeds).reshape(-1)[0])
                )
                hit = self.cache.get(key)
                if hit is not None:
                    rid = self.buffer.allocate_id()
                    t = self.clock() if arrival is None else arrival
                    self._pending_cached.append((rid, primary, tier, t, hit))
                    return rid
                # miss: dispatch the *canonical* spelling (sorted seeds,
                # quantized normalized weights) — every spelling of this
                # key then computes byte-identical answers, so the cached
                # answer is exact for all of them, not just the first
                quantum = self.cfg.cache.weight_quantum
                try:
                    rid = self.buffer.submit(
                        primary, tier=tier, arrival=arrival,
                        seeds=list(key[0]),
                        weights=[q * quantum for q in key[1]],
                    )
                except BufferOverloadError:
                    return self._reject(primary, tier, arrival)
                self._inflight_keys[rid] = key
                return rid
        try:
            return self.buffer.submit(
                vertex, tier=tier, arrival=arrival, seeds=seeds,
                weights=weights,
            )
        except BufferOverloadError:
            primary = (
                int(vertex) if seeds is None
                # contract: allow(host-sync): host-side seed list
                else int(np.asarray(seeds).reshape(-1)[0])
            )
            return self._reject(primary, tier, arrival)

    def _reject(self, vertex: int, tier: str, arrival: Optional[float]) -> int:
        """Record a shed request; its ``rejected=True`` answer (empty
        top-k) is delivered by the next ``poll()``."""
        rid = self.buffer.allocate_id()
        t = self.clock() if arrival is None else arrival
        self._pending_rejected.append((rid, int(vertex), tier, t))
        self.stats["shed"] += 1
        return rid

    def invalidate(self, vertices: Iterable[int]) -> int:
        """Drop cached answers whose seed sets touch ``vertices`` (the hook
        an index/graph update calls); returns entries removed.  Also bumps
        the cache epoch, so in-flight batches dispatched before this call
        are not absorbed into the cache when harvested."""
        return self.cache.invalidate(vertices)

    def apply_updates(self, inserts=None, deletes=None) -> dict:
        """Apply an edge-update batch to the live graph + index.

        Requires the service to have been constructed with a
        ``maintainer`` (``core.updates.build_maintainable_index``).  Runs
        incremental repair (``core.updates.apply_updates``), swaps the
        engine onto the updated graph/index, then invalidates exactly the
        dirtied fingerprint rows in the answer cache — which also bumps
        the cache epoch, fencing out any batch still in flight on the old
        index.  Returns the repair report plus ``cache_invalidated``.

        The swap is atomic: every piece of replacement state (repaired
        index, new engine) is constructed *before* any service attribute
        changes, so a failure anywhere — repair or engine construction —
        leaves the service exactly as it was, still serving the old
        graph/index (``stats["update_rollbacks"]`` counts these).  A
        half-applied update (new graph, old engine) would silently serve
        wrong answers, which is strictly worse than failing the update.
        """
        if self.maintainer is None:
            raise ValueError(
                "apply_updates requires a maintainer "
                "(build the index via core.updates.build_maintainable_index "
                "and pass it to PPRService(..., maintainer=...))")
        from repro.core import updates as updates_mod

        try:
            new_graph, new_m, report = updates_mod.apply_updates(
                self.maintainer, self.graph, inserts=inserts, deletes=deletes)
            new_engine = BatchQueryEngine(
                new_graph, new_m.index, self.cfg.query)
        except BaseException:
            self.stats["update_rollbacks"] += 1
            raise
        # commit point: plain attribute assignments only — nothing below
        # this line can raise halfway through the swap
        self.graph = new_graph
        self.maintainer = new_m
        self.engine = new_engine
        self.pipeline.engine = new_engine
        self.frontier_path = (
            "sparse" if self.engine.uses_sparse_path() else "dense")
        self.answer_k = self.engine.effective_top_k
        self.index_rows = new_m.index.n
        # exact invalidation: an answer is stale iff one of its seeds' rows
        # was repaired.  Always runs (even for an empty dirty set) so the
        # epoch bump fences in-flight batches computed on the old index.
        report["cache_invalidated"] = self.cache.invalidate(
            report["dirty_row_ids"])
        self.stats["updates_applied"] += 1
        self.stats["rows_repaired"] += report["dirty_rows"]
        return report

    @property
    def in_flight(self) -> int:
        return self.pipeline.in_flight

    def poll(self, force: bool = False) -> List[Answer]:
        """Advance the pipeline; returns completed answers.

        Dispatches every ready batch (``force`` drains the buffer
        regardless of deadlines) and harvests finished ones.  At
        ``pipeline.depth=1`` — or with ``force`` — the harvest blocks, so
        every dispatched batch's answers come back from the same call,
        matching the pre-pipeline blocking ``poll()``.  Cache-hit answers
        pending since ``submit`` are always delivered, pipeline or not.
        """
        cached = self._drain_cached() + self._drain_rejected()
        if (not len(self.buffer) or not (self.buffer.ready() or force)) \
                and not self.pipeline.in_flight:
            return cached
        drain = force or self.cfg.pipeline.depth <= 1
        completed = self.pipeline.dispatch(force=force)
        completed.extend(self.pipeline.harvest(drain=drain))
        # harvesting freed pipeline slots; a deadline-fired batch deferred
        # while the device was busy can launch now instead of next poll
        more = self.pipeline.dispatch(force=force)
        if more or (drain and self.pipeline.in_flight):
            completed.extend(more)
            completed.extend(self.pipeline.harvest(drain=drain))
        return cached + self._absorb(completed)

    # -- bookkeeping ---------------------------------------------------------
    def _drain_cached(self) -> List[Answer]:
        """Materialize answers for cache hits recorded at submit time.
        Latency runs from the (possibly backdated) arrival to *now* — a hit
        still pays its queueing delay in the metrics, it just skips the
        device."""
        if not self._pending_cached:
            return []
        out: List[Answer] = []
        now = self.clock()
        for rid, vertex, tier, arrival, (tv, ts) in self._pending_cached:
            lat = now - arrival
            out.append(Answer(rid, vertex, tv, ts, lat, tier, cached=True))
            self.stats["served"] += 1
            self.stats["cache_served"] += 1
            self.stats["total_latency"] += lat
            self.stats["max_latency"] = max(self.stats["max_latency"], lat)
        self._pending_cached.clear()
        return out

    def _drain_rejected(self) -> List[Answer]:
        """Materialize ``rejected=True`` answers for shed requests.  Shed
        traffic never occupied a batch row, so it stays out of the
        served/latency metrics — ``stats["shed"]`` is its ledger."""
        if not self._pending_rejected:
            return []
        out: List[Answer] = []
        now = self.clock()
        empty_v = np.zeros(0, dtype=np.int64)
        empty_s = np.zeros(0, dtype=np.float32)
        for rid, vertex, tier, arrival in self._pending_rejected:
            out.append(Answer(
                rid, vertex, empty_v, empty_s, now - arrival, tier,
                rejected=True,
            ))
        self._pending_rejected.clear()
        return out

    def _absorb(self, completed: List[CompletedBatch]) -> List[Answer]:
        out: List[Answer] = []
        for batch in completed:
            if not self.stats["batches"]:
                # satellite fix: record first-batch service time (dominated
                # by jit compilation on a cold service) so load harnesses
                # can report wall_s_excl_first_batch alongside raw wall
                self.stats["first_batch_service_s"] = (
                    batch.completed_at - batch.dispatched_at
                )
            self.stats["pad_rows"] += batch.padded - len(batch.requests)
            self.stats["batches"] += 1
            for i, r in enumerate(batch.requests):
                lat = batch.completed_at - r.arrival
                out.append(Answer(
                    r.request_id, r.vertex, batch.indices[i],
                    batch.values[i], lat, r.tier,
                ))
                key = self._inflight_keys.pop(r.request_id, None)
                if key is not None:
                    # invalidate-vs-in-flight fence: a batch dispatched
                    # before an invalidate/apply_updates carries an older
                    # cache epoch — its answer was computed on the old
                    # index, so it is returned to the client (the request
                    # predates the update) but never written into the
                    # cache, where it would outlive the invalidation.
                    if batch.epoch == self.cache.epoch:
                        self.cache.put(key, batch.indices[i], batch.values[i])
                    else:
                        self.stats["cache_stale_drops"] += 1
                self.stats["served"] += 1
                self.stats["total_latency"] += lat
                self.stats["max_latency"] = max(self.stats["max_latency"], lat)
        return out

    def reset_stats(self) -> None:
        """Zero counters (e.g. after warmup dispatches in a benchmark)."""
        for k in self.stats:
            self.stats[k] = 0 if isinstance(self.stats[k], int) else 0.0
        for k in self.pipeline.stats:
            self.pipeline.stats[k] = 0
        self.pipeline.batch_hist.clear()
        for k in self.buffer.stats:
            self.buffer.stats[k] = 0
        for k in self.cache.stats:  # counters only; cached entries persist
            self.cache.stats[k] = 0

    def snapshot_stats(self) -> dict:
        """Service + pipeline telemetry as one flat dict (JSON-safe)."""
        s = dict(self.stats)
        s["frontier_path"] = self.frontier_path
        s["answer_k"] = self.answer_k
        s["index_rows"] = self.index_rows
        s["index_sharded"] = self.index_sharded
        s["pipeline_depth"] = self.cfg.pipeline.depth
        s["dispatch_path"] = self.cfg.pipeline.dispatch
        s["max_queue_depth"] = self.cfg.batching.max_queue_depth
        s["buffer_shed"] = self.buffer.stats["shed"]
        s["combine_path"] = (
            "scatter" if self.engine.uses_scatter_combine(
                self.cfg.batching.max_batch) else "sparse"
        ) if self.frontier_path == "sparse" else "dense"
        s.update({f"pipeline_{k}": v for k, v in self.pipeline.stats.items()})
        s["batch_hist"] = {
            int(k): int(v) for k, v in sorted(self.pipeline.batch_hist.items())
        }
        s["mean_latency"] = s["total_latency"] / max(s["served"], 1)
        # pad_fraction is a *batch* occupancy metric: cache-served answers
        # never occupied a batch row, so they stay out of the denominator
        computed = s["served"] - s["cache_served"]
        s["pad_fraction"] = s["pad_rows"] / max(computed + s["pad_rows"], 1)
        s.update({f"cache_{k}": v for k, v in self.cache.stats.items()})
        s["cache_size"] = len(self.cache)
        s["cache_capacity"] = self.cfg.cache.capacity
        s["cache_hit_rate"] = self.cache.stats["hits"] / max(
            self.cache.stats["hits"] + self.cache.stats["misses"], 1
        )
        s["cache_epoch"] = self.cache.epoch
        s["cache_reverse_entries"] = self.cache.reverse_index_entries()
        # eviction/invalidation hygiene: the reverse index must exactly
        # mirror the live entries — asserts here so any churn regression
        # surfaces in every stats snapshot, not just dedicated tests
        self.cache.check_integrity()
        return s

    def run_closed_loop(self, vertices: Sequence[int]) -> Tuple[List[Answer], dict]:
        """Serve a fixed workload to completion (benchmark mode).

        Thin wrapper over the open-loop harness at unbounded offer rate —
        see ``serving/loadgen.py`` for the rate-controlled version.
        """
        from repro.serving import loadgen
        return loadgen.run_closed_loop(self, vertices)
