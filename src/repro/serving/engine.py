"""The online PPR service: buffer -> shared decomposition -> top-k answers.

End-to-end serving loop for the paper's product: clients submit query
vertices; the service batches them (Section 3.3), runs the VERD shared
decomposition against the PPR index, and returns top-k (vertex, score)
lists.  Collects the latency/throughput metrics the paper's Table 3
reports.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.index import PPRIndex
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.serving.batching import BatchingConfig, RequestBuffer


@dataclasses.dataclass
class ServiceConfig:
    query: QueryConfig = dataclasses.field(default_factory=QueryConfig)
    batching: BatchingConfig = dataclasses.field(default_factory=BatchingConfig)


@dataclasses.dataclass
class Answer:
    request_id: int
    vertex: int
    top_vertices: np.ndarray
    top_scores: np.ndarray
    latency_s: float


class PPRService:
    """Serves PPR answers against a :class:`PPRIndex`.

    The index may be the output of ``index.build_index_sharded``: its
    ``values/indices`` arrays stay device-sharded over the model axis
    (``P("model", None)``) and may carry zeroed pad rows (``index.n >=
    graph.n``) — the query paths only ever gather real rows, so nothing is
    replicated or re-laid-out to serve from it.  Answer width is the
    engine's ``effective_top_k`` (``top_k`` clamped to the graph), so
    ``poll()`` rows always match the configured buffers.
    """

    def __init__(self, graph: Graph, index: Optional[PPRIndex],
                 cfg: Optional[ServiceConfig] = None, clock=None):
        self.cfg = cfg or ServiceConfig()
        self.engine = BatchQueryEngine(graph, index, self.cfg.query)
        self.buffer = RequestBuffer(self.cfg.batching, clock=clock)
        self.clock = clock or time.monotonic
        # which execution the engine routed to (docs/query_path.md): part of
        # the serving telemetry so capacity planning can see Q x K vs Q x n
        self.frontier_path = (
            "sparse" if self.engine.uses_sparse_path() else "dense"
        )
        self.answer_k = self.engine.effective_top_k
        # index layout telemetry: pad rows of a sharded build + whether the
        # backing arrays are device-sharded (capacity planning reads this)
        self.index_rows = index.n if index is not None else 0
        self.index_sharded = bool(
            index is not None
            and getattr(index.values, "sharding", None) is not None
            and not index.values.sharding.is_fully_replicated
        )
        self.stats: Dict[str, float] = dict(
            served=0, batches=0, total_latency=0.0, max_latency=0.0,
            pad_rows=0,
        )

    def submit(self, vertex: int) -> int:
        return self.buffer.submit(vertex)

    def poll(self, force: bool = False) -> List[Answer]:
        """Flush the buffer if ready; returns completed answers."""
        if not (self.buffer.ready() or (force and len(self.buffer))):
            return []
        requests, padded = self.buffer.drain()
        n_real = len(requests)
        verts = np.array([r.vertex for r in requests], dtype=np.int32)
        if padded > n_real:  # pad with vertex 0 to a stable jit shape
            verts = np.concatenate(
                [verts, np.zeros(padded - n_real, np.int32)]
            )
        vals, idx = self.engine.query_topk(jnp.asarray(verts))
        vals.block_until_ready()
        now = self.clock()
        # pad rows never reach answers or stats: slice them off on device so
        # only the real rows' top-k is materialized on the host
        vals = np.asarray(vals[:n_real])
        idx = np.asarray(idx[:n_real])
        self.stats["pad_rows"] += padded - n_real
        out = []
        for i, r in enumerate(requests):
            lat = now - r.arrival
            out.append(Answer(r.request_id, r.vertex, idx[i], vals[i], lat))
            self.stats["served"] += 1
            self.stats["total_latency"] += lat
            self.stats["max_latency"] = max(self.stats["max_latency"], lat)
        self.stats["batches"] += 1
        return out

    def run_closed_loop(self, vertices: Sequence[int]) -> Tuple[List[Answer], dict]:
        """Serve a fixed workload to completion (benchmark mode)."""
        answers: List[Answer] = []
        t0 = self.clock()
        for v in vertices:
            self.submit(v)
            answers.extend(self.poll())
        while len(self.buffer):
            answers.extend(self.poll(force=True))
        wall = self.clock() - t0
        s = dict(self.stats)
        s["frontier_path"] = self.frontier_path
        s["answer_k"] = self.answer_k
        s["index_rows"] = self.index_rows
        s["index_sharded"] = self.index_sharded
        s["wall_s"] = wall
        s["qps"] = len(answers) / max(wall, 1e-9)
        s["mean_latency"] = s["total_latency"] / max(s["served"], 1)
        s["pad_fraction"] = s["pad_rows"] / max(s["served"] + s["pad_rows"], 1)
        return answers, s
