"""LRU answer cache keyed on canonicalized seed sets.

PPR is scale-invariant in its restart distribution and blind to seed
order, so ``{a: 2, b: 1}``, ``[(b, 0.5), (a, 1.0)]``, and ``[a, a, b]``
(uniform) are all the *same* query.  :func:`canonicalize_seed_set` maps
every spelling onto one key — dedup-sum duplicate vertices, sort by vertex
id, normalize weights to sum 1, quantize — so hot seed sets hit one cache
entry no matter how clients spell them.  The quantization step
(``CacheConfig.weight_quantum``) bounds how far two weight vectors may
drift while still sharing an entry; the served answer is whichever
canonical-equivalent query was computed first, exact for every spelling
because the engine normalizes weights the same way.

The cache is consulted in ``PPRService.submit`` *before* a request reaches
the ``RequestBuffer`` — a hit skips batching, dispatch, and the device
entirely — and filled when computed answers are absorbed.  ``invalidate``
removes exactly the entries touching given vertices (the hook an evolving-
graph index update will call; today's staleness counter tracks how much it
drops).  Host-side and tiny: capacity entries of ``2 * k`` numbers each.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, Optional, OrderedDict, Sequence, Set, Tuple

import numpy as np

# (sorted unique vertex ids, matching quantized normalized weights)
CacheKey = Tuple[Tuple[int, ...], Tuple[int, ...]]


@dataclasses.dataclass
class CacheConfig:
    capacity: int = 0             # max cached answers; 0 disables the cache
    weight_quantum: float = 1e-4  # normalized-weight quantization step for
                                  # the cache key (1e-4 ~ 0.01% of restart
                                  # mass: far below any top-k rank change)


def canonicalize_seed_set(
    seeds: Sequence[int],
    weights: Optional[Sequence[float]] = None,
    *,
    weight_quantum: float = 1e-4,
) -> CacheKey:
    """Canonical cache key of a weighted seed set.

    Dedup-sums duplicate vertices (a vertex listed twice carries the sum of
    its weights — same semantics as the engine's scatter-add seeding),
    drops weight-0 pad slots, sorts by vertex id, normalizes to sum 1, and
    quantizes to ``weight_quantum`` steps.  Permutations, rescalings, and
    duplicate spellings of one distribution all map to the same key.
    ``weights=None`` means uniform.  All-zero / empty seed sets map to the
    empty key ``((), ())`` (never cached — nothing to answer).
    """
    s = np.asarray(seeds, dtype=np.int64).reshape(-1)
    w = (
        np.ones(s.shape, np.float64) if weights is None
        else np.asarray(weights, dtype=np.float64).reshape(-1)
    )
    if w.shape != s.shape:
        raise ValueError(f"weights shape {w.shape} != seeds shape {s.shape}")
    keep = w > 0
    s, w = s[keep], w[keep]
    if s.size == 0:
        return ((), ())
    uniq, inv = np.unique(s, return_inverse=True)
    acc = np.zeros(uniq.shape, np.float64)
    np.add.at(acc, inv, w)
    acc /= acc.sum()
    q = np.round(acc / max(weight_quantum, 1e-30)).astype(np.int64)
    return (
        tuple(int(v) for v in uniq),
        tuple(int(x) for x in q),
    )


class AnswerCache:
    """LRU map ``CacheKey -> (top_vertices, top_scores)`` with a reverse
    vertex index for exact invalidation.

    Counters (all monotonic): ``hits`` / ``misses`` (get outcomes),
    ``evictions`` (capacity pressure), ``invalidated`` (entries dropped by
    :meth:`invalidate` — the staleness ledger for index updates).
    """

    def __init__(self, cfg: Optional[CacheConfig] = None):
        self.cfg = cfg or CacheConfig()
        self._data: OrderedDict[CacheKey, Tuple[np.ndarray, np.ndarray]] = (
            collections.OrderedDict()
        )
        # seed vertex -> keys of cached entries whose seed set contains it
        self._by_vertex: Dict[int, Set[CacheKey]] = {}
        self.stats: Dict[str, int] = dict(
            hits=0, misses=0, evictions=0, invalidated=0,
        )
        # bumped whenever cached semantics change (invalidate / clear /
        # an index update): in-flight batches dispatched under an older
        # epoch must not be absorbed (the invalidate-vs-in-flight race —
        # see ServingPipeline's epoch stamping and PPRService._absorb)
        self.epoch: int = 0

    @property
    def enabled(self) -> bool:
        return self.cfg.capacity > 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: CacheKey) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Answer for ``key`` (freshening its LRU position), or None."""
        if not self.enabled:
            return None
        hit = self._data.get(key)
        if hit is None:
            self.stats["misses"] += 1
            return None
        self._data.move_to_end(key)
        self.stats["hits"] += 1
        return hit

    def put(
        self, key: CacheKey, top_vertices: np.ndarray, top_scores: np.ndarray
    ) -> None:
        """Insert/refresh an answer; evicts LRU entries over capacity."""
        if not self.enabled or not key[0]:
            return
        # copies: cached answers must not alias the (reused) batch buffers
        self._data[key] = (
            np.array(top_vertices, copy=True),
            np.array(top_scores, copy=True),
        )
        self._data.move_to_end(key)
        for v in key[0]:
            self._by_vertex.setdefault(v, set()).add(key)
        while len(self._data) > self.cfg.capacity:
            old_key, _ = self._data.popitem(last=False)
            self._unindex(old_key)
            self.stats["evictions"] += 1

    def invalidate(self, vertices: Iterable[int]) -> int:
        """Drop every cached entry whose *seed set* contains any of
        ``vertices``; returns how many entries were removed.

        This is the hook an index/graph update calls: an answer is stale
        once any of its seeds' fingerprints changed.  (Answers whose *top-k
        results* mention a vertex are not tracked — that inversion costs
        k entries per answer; seed-level invalidation is the conservative
        contract the evolving-graph follow-up needs first.)
        """
        doomed: Set[CacheKey] = set()
        for v in vertices:
            doomed |= self._by_vertex.get(int(v), set())
        removed = 0
        for key in doomed:
            # count only entries actually live in the LRU map: a reverse-
            # index entry without a live answer (were the index ever to
            # drift) must not inflate the staleness ledger
            if self._data.pop(key, None) is not None:
                removed += 1
            self._unindex(key)
        self.stats["invalidated"] += removed
        self.epoch += 1
        return removed

    def clear(self) -> None:
        self._data.clear()
        self._by_vertex.clear()
        self.epoch += 1

    def reverse_index_entries(self) -> int:
        """Total ``(vertex -> key)`` links — must equal the live entries'
        seed-set sizes (see :meth:`check_integrity`)."""
        return sum(len(ks) for ks in self._by_vertex.values())

    def check_integrity(self) -> None:
        """Assert the reverse index exactly mirrors the live entries.

        Every live key contributes one bucket link per seed vertex and
        nothing else: ``sum(len(bucket)) == sum(len(key.seeds))``, no
        empty buckets linger, and every bucket link points at a live
        entry that really contains the bucket's vertex.  O(entries * S);
        called from ``PPRService.snapshot_stats`` so churn regressions
        (eviction or invalidation leaving stale links) fail loudly.
        """
        live_links = sum(len(key[0]) for key in self._data)
        got = self.reverse_index_entries()
        assert got == live_links, (
            f"reverse index holds {got} links, live entries imply "
            f"{live_links}")
        for v, ks in self._by_vertex.items():
            assert ks, f"empty bucket left behind for vertex {v}"
            for key in ks:
                assert key in self._data, (
                    f"stale bucket link {key} for vertex {v}")
                assert v in key[0], (
                    f"bucket {v} links key {key} that does not seed it")

    def _unindex(self, key: CacheKey) -> None:
        for v in key[0]:
            ks = self._by_vertex.get(v)
            if ks is not None:
                ks.discard(key)
                if not ks:
                    del self._by_vertex[v]
