"""Async serving pipeline: multi-batch in-flight dispatch + completion queue.

JAX dispatch is asynchronous — a jitted call returns device arrays as soon
as the work is *enqueued* on the device stream.  The old ``poll()`` threw
that away by calling ``block_until_ready()`` per batch, so host buffering,
device compute, and top-k readout ran strictly in series.  This module
splits serving into two phases:

* **dispatch** — drain the request buffer, pad to a stable jit shape,
  launch ``engine.query_topk_async`` (one fused XLA computation, no sync),
  and push a :class:`PendingBatch` ticket holding the device arrays plus
  request metadata onto a bounded :class:`CompletionQueue`;
* **harvest** — pop tickets whose arrays report ready
  (``jax.Array.is_ready``), slice off the pad rows, and materialize only
  the ``n_real`` top-k rows to the host.

The queue depth bounds how many batches are in flight at once (device
memory for ``depth`` result buffers plus their transient scratch); when
the queue is full the dispatcher harvests the head *blocking* before
launching more, which is the natural backpressure.  ``depth=1`` makes
every dispatch wait for the previous batch — exactly the old blocking
behavior — and is the baseline the serving benchmark compares against.

``dispatch="legacy"`` additionally routes through the eager
``engine.query_topk`` + ``block_until_ready`` path (today's code), so the
benchmark can separate the fused-dispatch win from the pipelining win.
"""

from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.batching import Request, RequestBuffer


@dataclasses.dataclass
class PipelineConfig:
    depth: int = 4                # max batches in flight (1 = blocking)
    dispatch: str = "fused"       # fused (query_topk_async) | legacy
                                  # (eager query_topk + block, PR-5 behavior)
    reuse_buffers: bool = True    # ring harvested result buffers back into
                                  # dispatch (donated to the fused query),
                                  # so a steady-state loop allocates no new
                                  # per-dispatch result arrays
    stall_timeout_s: Optional[float] = None  # stuck-ticket watchdog: a
                                  # head-of-queue batch still not ready this
                                  # long after dispatch counts as stalled
                                  # (stats["stalled"] + one warning per
                                  # ticket).  None disables the watchdog.

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {self.depth}")
        if self.dispatch not in ("fused", "legacy"):
            raise ValueError(f"unknown dispatch {self.dispatch!r}")
        if self.stall_timeout_s is not None and self.stall_timeout_s <= 0:
            raise ValueError(
                f"stall_timeout_s must be positive, got {self.stall_timeout_s}"
            )


@dataclasses.dataclass
class PendingBatch:
    """One in-flight batch: device arrays + the metadata needed to turn
    them into answers later.  Holding this ticket is what keeps the result
    buffers alive; nothing here has synced with the device."""
    seq: int
    requests: List[Request]
    padded: int
    values: jax.Array             # [padded, k] f32, possibly unfinished
    indices: jax.Array            # [padded, k] i32
    dispatched_at: float
    # cache epoch at dispatch time (AnswerCache.epoch via the pipeline's
    # epoch_fn).  A ticket computed under an older epoch predates some
    # invalidate()/apply_updates() and must not be absorbed into the cache
    # — the invalidate-vs-in-flight race fix.  Answers are still correct to
    # *return* (the request was accepted before the update).
    epoch: int = 0
    stall_warned: bool = False    # watchdog fired for this ticket (each
                                  # stuck batch counts/warns exactly once)

    def is_ready(self) -> bool:
        """Non-blocking completion probe via ``jax.Array.is_ready``."""
        try:
            return bool(self.values.is_ready() and self.indices.is_ready())
        except AttributeError:  # plain numpy (stub engines in tests)
            return True


@dataclasses.dataclass
class CompletedBatch:
    """A harvested batch: host arrays sliced to the real rows."""
    seq: int
    requests: List[Request]
    padded: int
    values: np.ndarray            # [n_real, k]
    indices: np.ndarray           # [n_real, k]
    dispatched_at: float
    completed_at: float
    epoch: int = 0                # cache epoch stamped at dispatch


class CompletionQueue:
    """Bounded FIFO of in-flight batches.  On a single device stream XLA
    completes computations in dispatch order, so harvesting from the head
    only is both correct and optimal."""

    def __init__(self, depth: int):
        self.depth = depth
        self._q: Deque[PendingBatch] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    def full(self) -> bool:
        return len(self._q) >= self.depth

    def push(self, ticket: PendingBatch) -> None:
        if self.full():
            raise RuntimeError(
                f"completion queue full (depth={self.depth}); harvest first"
            )
        self._q.append(ticket)

    def pop(self, block: bool = False) -> Optional[PendingBatch]:
        """Pop the head ticket if finished (or unconditionally when
        ``block``); returns ``None`` when nothing is harvestable."""
        if not self._q:
            return None
        head = self._q[0]
        if not block and not head.is_ready():
            return None
        self._q.popleft()
        return head

    def head(self) -> Optional[PendingBatch]:
        """Peek the oldest in-flight ticket (watchdog probe; no pop)."""
        return self._q[0] if self._q else None


class ServingPipeline:
    """Glue between a :class:`RequestBuffer` and a query engine.

    Owns the dispatch sequence counter (folded into the engine's config
    seed key so Monte-Carlo answers replay identically at any depth), the
    completion queue, and the pipeline telemetry the benchmark reads.
    """

    def __init__(self, engine, buffer: RequestBuffer, cfg: PipelineConfig,
                 clock: Optional[Callable[[], float]] = None,
                 epoch_fn: Optional[Callable[[], int]] = None):
        self.engine = engine
        self.buffer = buffer
        self.cfg = cfg
        self.clock = clock or time.monotonic
        # reads the cache epoch at dispatch time (None = always epoch 0)
        self.epoch_fn = epoch_fn
        self.queue = CompletionQueue(cfg.depth)
        self._seq = 0
        self.stats: Dict[str, float] = dict(
            dispatched=0, harvested=0, queue_full_stalls=0, in_flight_peak=0,
            buffers_allocated=0, buffers_reused=0, stalled=0,
        )
        # padded batch width -> count; the benchmark's batch-size histogram
        self.batch_hist: Dict[int, int] = collections.Counter()
        # per-shape ring of harvested result buffers, re-donated to the
        # next dispatch of the same padded width: once every shape has been
        # seen ``depth`` times the steady state performs no per-dispatch
        # result allocation at all (only jax arrays ring — stub engines
        # returning numpy never populate it)
        self._ring: Dict[int, Deque] = {}

    @property
    def in_flight(self) -> int:
        return len(self.queue)

    # -- dispatch phase ------------------------------------------------------
    def _should_dispatch(self, force: bool) -> bool:
        if not len(self.buffer):
            return False
        if force or self.buffer.size_ready():
            return True
        # Deadline-fired batches only launch into an *idle* pipeline: on a
        # serialized device stream a partial batch dispatched behind another
        # batch starts no sooner, but its pad rows burn capacity.  Deferring
        # it lets the buffer keep filling while the device works, so the
        # next dispatch carries more real rows per launch.
        return self.in_flight == 0 and self.buffer.ready()

    def dispatch(self, force: bool = False) -> List[CompletedBatch]:
        """Drain-and-launch until the buffer is quiet.  Returns any batches
        that had to be harvested to make room (queue-full backpressure) —
        callers must not drop them."""
        out: List[CompletedBatch] = []
        while self._should_dispatch(force):
            out.extend(self._dispatch_one())
        return out

    def _batch_arrays(self, requests: List[Request], padded: int):
        """Marshal a drained batch into the engine's input arrays.

        With the engine configured for seed sets (``config.max_seeds > 1``)
        every request — single-vertex or not — becomes one ``[S_max]`` row
        of (seeds, weights), weight-0 padded; pad *rows* are all-zero
        weights, which the engine's normalization turns into all-zero
        answers.  Single-vertex engines keep the historical 1-D vertex
        vector (stub engines in tests rely on that call shape).
        """
        max_seeds = getattr(
            getattr(self.engine, "config", None), "max_seeds", 1
        )
        if max_seeds <= 1:
            # contract: allow(host-sync): marshals host-side Request
            # objects into the dispatch batch; nothing here is a device
            # array yet
            verts = np.array([r.vertex for r in requests], dtype=np.int32)
            if padded > len(requests):  # pad with vertex 0
                verts = np.concatenate(
                    [verts, np.zeros(padded - len(requests), np.int32)]
                )
            return verts, None
        seeds = np.zeros((padded, max_seeds), np.int32)
        weights = np.zeros((padded, max_seeds), np.float32)
        for j, r in enumerate(requests):
            if r.seeds is not None:
                s = r.seeds[:max_seeds]
                seeds[j, : len(s)] = s
                weights[j, : len(s)] = r.weights[: len(s)]
            else:
                seeds[j, 0] = r.vertex
                weights[j, 0] = 1.0
        return seeds, weights

    def _dispatch_one(self) -> List[CompletedBatch]:
        out: List[CompletedBatch] = []
        if self.queue.full():  # backpressure: block on the oldest batch
            self.stats["queue_full_stalls"] += 1
            out.append(self._complete(self.queue.pop(block=True)))
        requests, padded = self.buffer.drain()
        verts, weights = self._batch_arrays(requests, padded)
        if self.cfg.dispatch == "legacy":
            if weights is None:
                vals, idx = self.engine.query_topk(jnp.asarray(verts))
            else:
                vals, idx = self.engine.query_topk(
                    jnp.asarray(verts), weights=jnp.asarray(weights)
                )
            # contract: allow(host-sync): the legacy dispatch mode IS the
            # blocking baseline the async pipeline is benchmarked against
            vals.block_until_ready()
        else:
            kwargs = {}
            if weights is not None:
                kwargs["weights"] = jnp.asarray(weights)
            if self.cfg.reuse_buffers:
                ring = self._ring.get(padded)
                if ring:
                    kwargs["out"] = ring.popleft()
                    self.stats["buffers_reused"] += 1
                else:
                    self.stats["buffers_allocated"] += 1
            vals, idx = self.engine.query_topk_async(
                verts, key=self.engine.dispatch_key(self._seq), **kwargs
            )
        ticket = PendingBatch(
            self._seq, requests, padded, vals, idx, self.clock(),
            epoch=self.epoch_fn() if self.epoch_fn is not None else 0,
        )
        self._seq += 1
        self.queue.push(ticket)
        self.stats["dispatched"] += 1
        self.stats["in_flight_peak"] = max(
            self.stats["in_flight_peak"], len(self.queue)
        )
        self.batch_hist[padded] += 1
        return out

    # -- completion phase ----------------------------------------------------
    def harvest(self, drain: bool = False) -> List[CompletedBatch]:
        """Pop finished batches from the queue head.  ``drain`` blocks until
        *everything* in flight has completed (flush semantics); otherwise
        only ready batches are taken and the call never syncs."""
        out: List[CompletedBatch] = []
        while len(self.queue):
            ticket = self.queue.pop(block=drain)
            if ticket is None:
                self._watch_stall()
                break
            out.append(self._complete(ticket))
        return out

    def _watch_stall(self) -> None:
        """Stuck-ticket watchdog: the head batch has had the device stream
        to itself since dispatch, so an age past ``stall_timeout_s`` means
        the stream is wedged (deadlocked collective, runaway kernel, host
        callback hang) — surface it instead of polling forever silently.
        Detection only: the ticket stays in flight (harvest with
        ``drain=True`` still blocks on it), but the counter/warning give
        load harnesses and operators a tripwire."""
        if self.cfg.stall_timeout_s is None:
            return
        head = self.queue.head()
        if head is None or head.stall_warned:
            return
        age = self.clock() - head.dispatched_at
        if age >= self.cfg.stall_timeout_s:
            head.stall_warned = True
            self.stats["stalled"] += 1
            warnings.warn(
                f"serving pipeline batch seq={head.seq} "
                f"({len(head.requests)} requests) has been in flight for "
                f"{age:.3f}s (stall_timeout_s="
                f"{self.cfg.stall_timeout_s}) — device stream may be stuck",
                RuntimeWarning,
                stacklevel=3,
            )

    def flush(self) -> List[CompletedBatch]:
        """Dispatch whatever is buffered, then block for all of it."""
        out = self.dispatch(force=True)
        out.extend(self.harvest(drain=True))
        return out

    def _complete(self, ticket: PendingBatch) -> CompletedBatch:
        n_real = len(ticket.requests)
        # pad rows never reach answers or stats: slice them off on device so
        # only the real rows' top-k is materialized on the host
        # contract: allow(host-sync): post-is_ready harvest — the ticket's
        # arrays are already resident when _complete runs, so these copies
        # never stall the dispatch thread
        vals = np.asarray(ticket.values[:n_real])
        # contract: allow(host-sync): post-is_ready harvest (see above)
        idx = np.asarray(ticket.indices[:n_real])
        self.stats["harvested"] += 1
        if (
            self.cfg.reuse_buffers
            and self.cfg.dispatch == "fused"
            and hasattr(ticket.values, "is_ready")  # jax arrays only
        ):
            # the host copies above are independent of the device buffers,
            # so the full-width result arrays go back in the ring to be
            # donated to the next dispatch of this padded width
            self._ring.setdefault(
                ticket.padded, collections.deque()
            ).append((ticket.values, ticket.indices))
        return CompletedBatch(
            ticket.seq, ticket.requests, ticket.padded, vals, idx,
            ticket.dispatched_at, self.clock(), epoch=ticket.epoch,
        )
