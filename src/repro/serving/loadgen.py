"""Open-loop load generation for the PPR service.

A closed-loop driver (submit, wait, submit) hides saturation: when the
service slows down, the offered load slows down with it, so measured
latency stays flat right past the capacity cliff.  The open-loop harness
offers request ``i`` at its *scheduled* time ``t0 + i/qps`` regardless of
service backpressure, backdates the request's arrival to that schedule,
and measures latency from it — so queueing delay under overload shows up
in p99 exactly as clients would see it.  ``qps=None`` degenerates to the
closed-loop mode (offer as fast as the loop runs), which is what
``PPRService.run_closed_loop`` wraps.

The clock comes from the service (injectable for deterministic tests);
``sleep`` is injectable the same way.
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

# a workload item is a vertex, an explicit (vertex, tier) pair, or a
# seed-set dict: {"seeds": [...], "weights": [...], "tier": "..."}
# (weights/tier optional — uniform weights, interactive tier)
WorkItem = Union[int, Tuple[int, str], dict]


def _percentile(xs: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p)) if xs else 0.0


def _submit(service, item: WorkItem, arrival: Optional[float] = None) -> None:
    """Submit one work item of any spelling."""
    if isinstance(item, dict):
        service.submit(
            tier=item.get("tier", "interactive"), arrival=arrival,
            seeds=item["seeds"], weights=item.get("weights"),
        )
        return
    v, tier = item if isinstance(item, tuple) else (item, "interactive")
    service.submit(v, tier=tier, arrival=arrival)


def zipf_seed_workload(
    n_vertices: int,
    n_requests: int,
    *,
    skew: float = 1.1,
    max_seeds: int = 4,
    pool: int = 1024,
    singles_fraction: float = 0.0,
    tier: str = "interactive",
    seed: int = 0,
) -> List[WorkItem]:
    """Zipf-skewed hot-seed traffic: the cache benchmark's arrival stream.

    Draws a ``pool`` of distinct weighted seed sets once, then samples each
    request's set from a Zipf(``skew``) rank distribution over the pool —
    the classic hot-key shape of real personalization traffic (a few hot
    users/communities dominate), which is what gives an answer cache
    something to hit.  Repeated picks are spelled with *permuted* seeds and
    *rescaled* weights, so cache hit rate exercises canonicalization, not
    memcmp.  ``singles_fraction`` of requests degrade to plain single-vertex
    items (the set's primary seed) for mixed single/seed-set traffic.
    """
    rng = np.random.default_rng(seed)
    pool = max(1, pool)
    sizes = rng.integers(1, max_seeds + 1, pool)
    pool_seeds = [
        rng.integers(0, n_vertices, int(sz)).tolist() for sz in sizes
    ]
    pool_weights = [
        (rng.random(int(sz)) + 0.1).tolist() for sz in sizes
    ]
    ranks = np.arange(1, pool + 1, dtype=np.float64) ** (-skew)
    picks = rng.choice(pool, size=n_requests, p=ranks / ranks.sum())
    items: List[WorkItem] = []
    for j in picks:
        s = pool_seeds[j]
        w = pool_weights[j]
        if singles_fraction > 0 and rng.random() < singles_fraction:
            items.append(int(s[0]))
            continue
        perm = rng.permutation(len(s))
        scale = float(rng.uniform(0.5, 2.0))
        items.append(dict(
            seeds=[s[i] for i in perm],
            weights=[w[i] * scale for i in perm],
            tier=tier,
        ))
    return items


def run_open_loop(
    service,
    vertices: Sequence[WorkItem],
    qps: Optional[float] = None,
    *,
    sleep: Callable[[float], None] = time.sleep,
    max_sleep_s: float = 0.002,
) -> Tuple[list, dict]:
    """Offer ``vertices`` at ``qps`` (None = as fast as possible); returns
    ``(answers, stats)`` once every request has been served.

    While waiting for the next scheduled arrival the loop keeps polling, so
    in-flight batches are harvested (and deadline-expired buffers flushed)
    even when no new request shows up — the pipeline never idles on offered
    gaps.  Per-request latency is measured from the *scheduled* offer time.
    """
    clock = service.clock
    answers: list = []
    t0 = clock()
    i = 0
    while i < len(vertices):
        if qps:
            now = clock()
            if now < t0 + i / qps:  # next arrival not due yet: keep serving
                answers.extend(service.poll())
                now = clock()
                t_sched = t0 + i / qps
                if now < t_sched:
                    sleep(min(t_sched - now, max_sleep_s))
                continue
            # submit *every* request already due before polling again: an
            # open-loop arrival process doesn't wait for the server, so when
            # the service falls behind, due requests land in its queue as a
            # group (and batch up) instead of trickling one per poll
            while i < len(vertices) and t0 + i / qps <= now:
                _submit(service, vertices[i], arrival=t0 + i / qps)
                i += 1
        else:
            _submit(service, vertices[i])
            i += 1
        answers.extend(service.poll())
    answers.extend(service.poll(force=True))
    wall = clock() - t0

    s = service.snapshot_stats()
    lat = [a.latency_s for a in answers]
    s["wall_s"] = wall
    # satellite fix: a cold service's first batch is dominated by jit
    # compilation; report throughput with and without it so benchmark
    # trajectories aren't dominated by compile time
    s["wall_s_excl_first_batch"] = max(wall - s["first_batch_service_s"], 1e-9)
    s["offered_qps"] = float(qps) if qps else 0.0
    s["qps"] = len(answers) / max(wall, 1e-9)
    s["qps_excl_first_batch"] = len(answers) / s["wall_s_excl_first_batch"]
    s["latency_p50"] = _percentile(lat, 50)
    s["latency_p99"] = _percentile(lat, 99)
    return answers, s


def run_closed_loop(service, vertices: Sequence[WorkItem]) -> Tuple[list, dict]:
    """Serve a fixed workload to completion with no rate control."""
    return run_open_loop(service, vertices, qps=None)
