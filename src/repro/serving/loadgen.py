"""Open-loop load generation for the PPR service.

A closed-loop driver (submit, wait, submit) hides saturation: when the
service slows down, the offered load slows down with it, so measured
latency stays flat right past the capacity cliff.  The open-loop harness
offers request ``i`` at its *scheduled* time ``t0 + i/qps`` regardless of
service backpressure, backdates the request's arrival to that schedule,
and measures latency from it — so queueing delay under overload shows up
in p99 exactly as clients would see it.  ``qps=None`` degenerates to the
closed-loop mode (offer as fast as the loop runs), which is what
``PPRService.run_closed_loop`` wraps.

The clock comes from the service (injectable for deterministic tests);
``sleep`` is injectable the same way.
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

# a workload item is a vertex or an explicit (vertex, tier) pair
WorkItem = Union[int, Tuple[int, str]]


def _percentile(xs: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p)) if xs else 0.0


def run_open_loop(
    service,
    vertices: Sequence[WorkItem],
    qps: Optional[float] = None,
    *,
    sleep: Callable[[float], None] = time.sleep,
    max_sleep_s: float = 0.002,
) -> Tuple[list, dict]:
    """Offer ``vertices`` at ``qps`` (None = as fast as possible); returns
    ``(answers, stats)`` once every request has been served.

    While waiting for the next scheduled arrival the loop keeps polling, so
    in-flight batches are harvested (and deadline-expired buffers flushed)
    even when no new request shows up — the pipeline never idles on offered
    gaps.  Per-request latency is measured from the *scheduled* offer time.
    """
    clock = service.clock
    answers: list = []
    t0 = clock()
    i = 0
    while i < len(vertices):
        if qps:
            now = clock()
            if now < t0 + i / qps:  # next arrival not due yet: keep serving
                answers.extend(service.poll())
                now = clock()
                t_sched = t0 + i / qps
                if now < t_sched:
                    sleep(min(t_sched - now, max_sleep_s))
                continue
            # submit *every* request already due before polling again: an
            # open-loop arrival process doesn't wait for the server, so when
            # the service falls behind, due requests land in its queue as a
            # group (and batch up) instead of trickling one per poll
            while i < len(vertices) and t0 + i / qps <= now:
                item = vertices[i]
                v, tier = item if isinstance(item, tuple) else (item, "interactive")
                service.submit(v, tier=tier, arrival=t0 + i / qps)
                i += 1
        else:
            item = vertices[i]
            v, tier = item if isinstance(item, tuple) else (item, "interactive")
            service.submit(v, tier=tier)
            i += 1
        answers.extend(service.poll())
    answers.extend(service.poll(force=True))
    wall = clock() - t0

    s = service.snapshot_stats()
    lat = [a.latency_s for a in answers]
    s["wall_s"] = wall
    # satellite fix: a cold service's first batch is dominated by jit
    # compilation; report throughput with and without it so benchmark
    # trajectories aren't dominated by compile time
    s["wall_s_excl_first_batch"] = max(wall - s["first_batch_service_s"], 1e-9)
    s["offered_qps"] = float(qps) if qps else 0.0
    s["qps"] = len(answers) / max(wall, 1e-9)
    s["qps_excl_first_batch"] = len(answers) / s["wall_s_excl_first_batch"]
    s["latency_p50"] = _percentile(lat, 50)
    s["latency_p99"] = _percentile(lat, 99)
    return answers, s


def run_closed_loop(service, vertices: Sequence[WorkItem]) -> Tuple[list, dict]:
    """Serve a fixed workload to completion with no rate control."""
    return run_open_loop(service, vertices, qps=None)
