"""Request buffering (paper Section 3.3: "PowerWalk buffers the incoming
PPR queries and computes a batch of PPR queries at a time").

The buffer flushes on either (a) reaching ``max_batch`` or (b) a deadline —
the standard latency/throughput knob for online services.  Requests carry a
tier (``interactive`` | ``bulk``), each with its own deadline/batch policy;
drains take interactive requests first so bulk traffic cannot starve the
latency-sensitive class — unless a bulk deadline has already fired, in
which case the drain goes oldest-deadline-first so sustained interactive
load cannot starve bulk indefinitely (the tier deadline is an *aging
bound*, not a hint).  Deterministic and clock-injectable for tests.

Requests are single vertices or weighted seed sets (``seeds``/``weights``
arrays); the buffer treats both identically — seed-set padding to the
engine's ``S_max`` happens at dispatch (``serving/pipeline.py``), not here.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

TIERS = ("interactive", "bulk")


class BufferOverloadError(RuntimeError):
    """Raised by :meth:`RequestBuffer.submit` when admission control is on
    (``BatchingConfig.max_queue_depth``) and the buffer is full.  The
    service layer turns this into a *rejected* answer instead of queueing
    the request into a latency cliff (``PPRService.submit``)."""


@dataclasses.dataclass
class Request:
    request_id: int
    vertex: int                   # single-vertex queries; seed sets keep
                                  # their primary (first) seed here so
                                  # telemetry/answers stay uniform
    arrival: float
    tier: str = "interactive"
    seeds: Optional[np.ndarray] = None    # int[S] seed vertices (None =
                                          # classic single-vertex request)
    weights: Optional[np.ndarray] = None  # f32[S] nonnegative seed weights


@dataclasses.dataclass
class TierPolicy:
    """Per-tier batching knobs; ``None`` inherits the top-level value."""
    max_batch: Optional[int] = None
    max_wait_s: Optional[float] = None


@dataclasses.dataclass
class BatchingConfig:
    max_batch: int = 4096
    max_wait_s: float = 0.010     # flush deadline
    pad_to_power_of_two: bool = True   # pad drains to a closed set of jit
                                  # shapes (historical name; see pad_width —
                                  # widths above pad_quantum are bucketed to
                                  # multiples of the quantum, not pow2)
    pad_quantum: int = 64         # bucket size above which padded widths go
                                  # to the next multiple instead of the next
                                  # power of two (pow2 jumps waste ~25-30%
                                  # of batch capacity near saturation)
    min_pad: int = 1              # floor for the padded width (bounds the
                                  # set of jit shapes a service can compile)
    max_queue_depth: Optional[int] = None  # admission control: pending
                                  # requests beyond this are *shed*
                                  # (BufferOverloadError) instead of queued
                                  # — bounds worst-case queueing delay under
                                  # overload.  None = unbounded (legacy).
    # per-request-class overrides; by default both tiers inherit the
    # top-level deadline/batch so single-tier callers see one policy
    interactive: TierPolicy = dataclasses.field(default_factory=TierPolicy)
    bulk: TierPolicy = dataclasses.field(default_factory=TierPolicy)

    def tier_policy(self, tier: str) -> Tuple[int, float]:
        """Resolved ``(max_batch, max_wait_s)`` for ``tier``."""
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r} (expected one of {TIERS})")
        p: TierPolicy = getattr(self, tier)
        return (
            self.max_batch if p.max_batch is None else p.max_batch,
            self.max_wait_s if p.max_wait_s is None else p.max_wait_s,
        )

    def pad_width(self, n: int) -> int:
        """Padded jit width for a batch of ``n`` real requests.

        Powers of two up to ``pad_quantum``, then multiples of the quantum
        — the pow2 tail doubled the pad overhead right where saturated
        services live (a 129-row drain padded to 256; bucketing pads it to
        192), while the shape set stays closed and small:
        ``log2(quantum) + max_batch/quantum`` widths.  Clamped to
        ``[min_pad, max_batch]`` (a 3000-wide config must never compile a
        3072-wide jit shape).
        """
        if n <= 0 or not self.pad_to_power_of_two:
            return n
        q = max(1, self.pad_quantum)
        if n <= q:
            padded = 1
            while padded < n:
                padded *= 2
        else:
            padded = ((n + q - 1) // q) * q
        padded = max(padded, min(self.min_pad, self.max_batch))
        return min(padded, self.max_batch)

    def padded_shapes(self) -> List[int]:
        """The closed set of widths :meth:`pad_width` can emit — what a
        warmup loop should compile instead of guessing powers of two."""
        return sorted({self.pad_width(n) for n in range(1, self.max_batch + 1)})


class RequestBuffer:
    def __init__(self, cfg: BatchingConfig,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.clock = clock or time.monotonic
        self._pending: Dict[str, List[Request]] = {t: [] for t in TIERS}
        self._next_id = 0
        self.stats: Dict[str, int] = dict(shed=0)

    def allocate_id(self) -> int:
        """Reserve a request id without enqueuing anything — cache-served
        answers (``serving/engine.py``) draw from the same sequence so ids
        stay unique across cached and computed responses."""
        rid = self._next_id
        self._next_id += 1
        return rid

    def submit(self, vertex: Optional[int] = None, tier: str = "interactive",
               arrival: Optional[float] = None,
               seeds: Optional[Sequence[int]] = None,
               weights: Optional[Sequence[float]] = None) -> int:
        """Enqueue one request; ``arrival`` defaults to the clock but an
        open-loop load generator may backdate it to the *scheduled* offer
        time so latency includes queueing delay under backpressure.

        Either ``vertex`` (single-vertex query) or ``seeds`` (weighted
        seed-set query; ``weights`` defaults to uniform) must be given.

        With ``cfg.max_queue_depth`` set, a submit that would push the
        pending count past the bound is shed: nothing is enqueued, the
        ``shed`` counter bumps, and :class:`BufferOverloadError` is raised
        (argument validation still runs first — a malformed request is a
        caller bug, not overload).
        """
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r} (expected one of {TIERS})")
        s_arr = w_arr = None
        if seeds is not None:
            s_arr = np.asarray(seeds, dtype=np.int32).reshape(-1)
            if s_arr.size == 0:
                raise ValueError("seed set must contain at least one vertex")
            w_arr = (
                np.ones(s_arr.shape, np.float32) if weights is None
                else np.asarray(weights, dtype=np.float32).reshape(-1)
            )
            if w_arr.shape != s_arr.shape:
                raise ValueError(
                    f"weights shape {w_arr.shape} != seeds shape {s_arr.shape}"
                )
            if vertex is None:  # primary seed labels answers/telemetry
                vertex = int(s_arr[0])
        elif vertex is None:
            raise ValueError("submit() needs a vertex or a seed set")
        depth = self.cfg.max_queue_depth
        if depth is not None and len(self) >= depth:
            self.stats["shed"] += 1
            raise BufferOverloadError(
                f"request buffer at max_queue_depth={depth}; request shed"
            )
        rid = self.allocate_id()
        t = self.clock() if arrival is None else arrival
        self._pending[tier].append(
            Request(rid, int(vertex), t, tier, seeds=s_arr, weights=w_arr)
        )
        return rid

    def size_ready(self) -> bool:
        """True when any tier (or the buffer overall) hit its batch size —
        the flush trigger that does *not* depend on the clock."""
        if sum(len(v) for v in self._pending.values()) >= self.cfg.max_batch:
            return True
        return any(
            len(self._pending[tier]) >= self.cfg.tier_policy(tier)[0]
            for tier in TIERS
        )

    def ready(self) -> bool:
        """True when any tier hit its batch size or its *oldest pending*
        request crossed that tier's deadline."""
        if self.size_ready():
            return True
        now = None
        for tier in TIERS:
            pending = self._pending[tier]
            if not pending:
                continue
            _, t_wait = self.cfg.tier_policy(tier)
            now = self.clock() if now is None else now
            if (now - pending[0].arrival) >= t_wait:
                return True
        return False

    def _drain_order(self) -> List[str]:
        """Tier drain order: interactive-first, *unless* some tier's oldest
        request has crossed its deadline — then fired tiers go first,
        oldest deadline first.  This is what makes ``max_wait_s`` an aging
        bound: under sustained interactive load a bulk request waits at
        most one deadline before it outranks fresher interactive traffic,
        instead of starving behind it forever.
        """
        fired: List[Tuple[float, str]] = []
        now = None
        for tier in TIERS:
            pending = self._pending[tier]
            if not pending:
                continue
            _, t_wait = self.cfg.tier_policy(tier)
            now = self.clock() if now is None else now
            deadline = pending[0].arrival + t_wait
            if now >= deadline:
                fired.append((deadline, tier))
        if not fired:
            return list(TIERS)
        fired.sort()
        fired_tiers = [t for _, t in fired]
        return fired_tiers + [t for t in TIERS if t not in fired_tiers]

    def drain(self) -> Tuple[List[Request], int]:
        """Pop up to max_batch requests (tier order: :meth:`_drain_order`);
        returns ``(requests, padded_size)`` with the bucketed padded width
        from :meth:`BatchingConfig.pad_width`."""
        batch: List[Request] = []
        room = self.cfg.max_batch
        for tier in self._drain_order():  # FIFO within a tier
            t_batch, _ = self.cfg.tier_policy(tier)
            take = min(room, t_batch)
            batch.extend(self._pending[tier][:take])
            self._pending[tier] = self._pending[tier][take:]
            room = self.cfg.max_batch - len(batch)
            if room <= 0:
                break
        return batch, self.cfg.pad_width(len(batch))

    def __len__(self) -> int:
        return sum(len(v) for v in self._pending.values())
