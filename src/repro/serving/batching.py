"""Request buffering (paper Section 3.3: "PowerWalk buffers the incoming
PPR queries and computes a batch of PPR queries at a time").

The buffer flushes on either (a) reaching ``max_batch`` or (b) a deadline —
the standard latency/throughput knob for online services.  Deterministic
and clock-injectable for tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class Request:
    request_id: int
    vertex: int
    arrival: float


@dataclasses.dataclass
class BatchingConfig:
    max_batch: int = 4096
    max_wait_s: float = 0.010     # flush deadline
    pad_to_power_of_two: bool = True   # avoid jit recompiles per size


class RequestBuffer:
    def __init__(self, cfg: BatchingConfig,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.clock = clock or time.monotonic
        self._pending: List[Request] = []
        self._next_id = 0

    def submit(self, vertex: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self._pending.append(Request(rid, int(vertex), self.clock()))
        return rid

    def ready(self) -> bool:
        if not self._pending:
            return False
        if len(self._pending) >= self.cfg.max_batch:
            return True
        return (self.clock() - self._pending[0].arrival) >= self.cfg.max_wait_s

    def drain(self) -> Tuple[List[Request], int]:
        """Pop up to max_batch requests; returns (requests, padded_size)."""
        batch = self._pending[: self.cfg.max_batch]
        self._pending = self._pending[self.cfg.max_batch:]
        n = len(batch)
        padded = n
        if self.cfg.pad_to_power_of_two and n > 0:
            padded = 1
            while padded < n:
                padded *= 2
        return batch, padded

    def __len__(self) -> int:
        return len(self._pending)
