"""Request buffering (paper Section 3.3: "PowerWalk buffers the incoming
PPR queries and computes a batch of PPR queries at a time").

The buffer flushes on either (a) reaching ``max_batch`` or (b) a deadline —
the standard latency/throughput knob for online services.  Requests carry a
tier (``interactive`` | ``bulk``), each with its own deadline/batch policy;
drains take interactive requests first so bulk traffic cannot starve the
latency-sensitive class.  Deterministic and clock-injectable for tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

TIERS = ("interactive", "bulk")


@dataclasses.dataclass
class Request:
    request_id: int
    vertex: int
    arrival: float
    tier: str = "interactive"


@dataclasses.dataclass
class TierPolicy:
    """Per-tier batching knobs; ``None`` inherits the top-level value."""
    max_batch: Optional[int] = None
    max_wait_s: Optional[float] = None


@dataclasses.dataclass
class BatchingConfig:
    max_batch: int = 4096
    max_wait_s: float = 0.010     # flush deadline
    pad_to_power_of_two: bool = True   # avoid jit recompiles per size
    min_pad: int = 1              # floor for the padded width (bounds the
                                  # set of jit shapes a service can compile)
    # per-request-class overrides; by default both tiers inherit the
    # top-level deadline/batch so single-tier callers see one policy
    interactive: TierPolicy = dataclasses.field(default_factory=TierPolicy)
    bulk: TierPolicy = dataclasses.field(default_factory=TierPolicy)

    def tier_policy(self, tier: str) -> Tuple[int, float]:
        """Resolved ``(max_batch, max_wait_s)`` for ``tier``."""
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r} (expected one of {TIERS})")
        p: TierPolicy = getattr(self, tier)
        return (
            self.max_batch if p.max_batch is None else p.max_batch,
            self.max_wait_s if p.max_wait_s is None else p.max_wait_s,
        )


class RequestBuffer:
    def __init__(self, cfg: BatchingConfig,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.clock = clock or time.monotonic
        self._pending: Dict[str, List[Request]] = {t: [] for t in TIERS}
        self._next_id = 0

    def submit(self, vertex: int, tier: str = "interactive",
               arrival: Optional[float] = None) -> int:
        """Enqueue one request; ``arrival`` defaults to the clock but an
        open-loop load generator may backdate it to the *scheduled* offer
        time so latency includes queueing delay under backpressure."""
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r} (expected one of {TIERS})")
        rid = self._next_id
        self._next_id += 1
        t = self.clock() if arrival is None else arrival
        self._pending[tier].append(Request(rid, int(vertex), t, tier))
        return rid

    def size_ready(self) -> bool:
        """True when any tier (or the buffer overall) hit its batch size —
        the flush trigger that does *not* depend on the clock."""
        if sum(len(v) for v in self._pending.values()) >= self.cfg.max_batch:
            return True
        return any(
            len(self._pending[tier]) >= self.cfg.tier_policy(tier)[0]
            for tier in TIERS
        )

    def ready(self) -> bool:
        """True when any tier hit its batch size or its *oldest pending*
        request crossed that tier's deadline."""
        if self.size_ready():
            return True
        now = None
        for tier in TIERS:
            pending = self._pending[tier]
            if not pending:
                continue
            _, t_wait = self.cfg.tier_policy(tier)
            now = self.clock() if now is None else now
            if (now - pending[0].arrival) >= t_wait:
                return True
        return False

    def drain(self) -> Tuple[List[Request], int]:
        """Pop up to max_batch requests, interactive-first; returns
        ``(requests, padded_size)`` with the power-of-two padded width
        clamped to ``max_batch`` (a 3000-wide config must never compile a
        4096-wide jit shape)."""
        batch: List[Request] = []
        room = self.cfg.max_batch
        for tier in TIERS:  # interactive before bulk, FIFO within a tier
            t_batch, _ = self.cfg.tier_policy(tier)
            take = min(room, t_batch)
            batch.extend(self._pending[tier][:take])
            self._pending[tier] = self._pending[tier][take:]
            room = self.cfg.max_batch - len(batch)
            if room <= 0:
                break
        n = len(batch)
        padded = n
        if self.cfg.pad_to_power_of_two and n > 0:
            padded = 1
            while padded < n:
                padded *= 2
            padded = max(padded, min(self.cfg.min_pad, self.cfg.max_batch))
            padded = min(padded, self.cfg.max_batch)
        return batch, padded

    def __len__(self) -> int:
        return sum(len(v) for v in self._pending.values())
