"""MIND (arXiv:1904.08030): multi-interest capsule network for retrieval."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    pow_p: float = 2.0          # label-aware attention sharpness
    n_negatives: int = 127      # sampled-softmax negatives (the paper's
                                # serving-scale alternative to in-batch)
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        d = self.embed_dim
        return self.n_items * d + d * d + 2 * d * d


def init(cfg: MINDConfig, key) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "item_embed": L.embedding_init(k1, cfg.n_items, d, cfg.param_dtype),
        # shared bilinear map S of B2I routing (behavior -> interest space)
        "s_map": L.dense_init(k2, d, d, dtype=cfg.param_dtype),
        "out": L.mlp_init(k3, [d, 2 * d, d], dtype=cfg.param_dtype),
    }


def _squash(x: jax.Array, axis: int = -1) -> jax.Array:
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x * jax.lax.rsqrt(n2 + 1e-9)


def user_interests(cfg: MINDConfig, params, hist: jax.Array,
                   hist_mask: jax.Array) -> jax.Array:
    """B2I dynamic routing. hist int32[B, H] -> interests [B, K, d]."""
    dt = cfg.compute_dtype
    b, hlen = hist.shape
    e = L.embedding_apply(params["item_embed"], hist, compute_dtype=dt)
    eh = L.dense_apply(params["s_map"], e, compute_dtype=dt)      # [B, H, d]
    eh = eh * hist_mask[..., None].astype(dt)
    # routing logits fixed-init at 0 (the paper samples; 0 is deterministic)
    blog = jnp.zeros((b, hlen, cfg.n_interests), jnp.float32)
    interests = jnp.zeros((b, cfg.n_interests, cfg.embed_dim), dt)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(blog, axis=-1) * hist_mask[..., None]  # [B, H, K]
        z = jnp.einsum("bhk,bhd->bkd", w.astype(dt), eh)
        interests = _squash(z)
        blog = blog + jnp.einsum("bhd,bkd->bhk", eh, interests).astype(jnp.float32)
    # per-interest output MLP (H-layers of the paper's two-layer head)
    return L.mlp_apply(params["out"], interests, compute_dtype=dt)


def label_aware_scores(cfg: MINDConfig, interests: jax.Array,
                       target_e: jax.Array) -> jax.Array:
    """Label-aware attention: softmax(pow(u.e, p)) weighted score. [B]."""
    sims = jnp.einsum("bkd,bd->bk", interests, target_e)
    att = jax.nn.softmax(cfg.pow_p * sims, axis=-1)
    return jnp.sum(att * sims, axis=-1)


def loss_fn(cfg: MINDConfig, params, batch) -> jax.Array:
    """Sampled-softmax: target vs ``n_negatives`` sampled items per row.

    batch: hist [B, H], hist_mask [B, H], target [B], neg [B, n_negatives].
    (In-batch negatives would build a [B, K, B] tensor — 17 GB at the
    assigned B=65536 — so negatives are sampled, as the paper's production
    setting does.)
    """
    dt = cfg.compute_dtype
    interests = user_interests(cfg, params, batch["hist"], batch["hist_mask"])
    table = params["item_embed"]["table"].astype(dt)
    cand = jnp.concatenate(
        [batch["target"][:, None], batch["neg"]], axis=1
    )                                                             # [B, 1+N]
    ce = jnp.take(table, cand.reshape(-1), axis=0).reshape(
        cand.shape + (cfg.embed_dim,)
    )                                                             # [B, C, d]
    sims = jnp.einsum("bkd,bcd->bkc", interests, ce)              # [B, K, C]
    att = jax.nn.softmax(cfg.pow_p * sims, axis=1)
    scores = jnp.sum(att * sims, axis=1)                          # [B, C]
    labels = jnp.zeros((scores.shape[0],), jnp.int32)  # target at column 0
    return L.softmax_cross_entropy(scores, labels)


def retrieval_scores(cfg: MINDConfig, params, batch) -> jax.Array:
    """1 user vs n_candidates: max over interests (the paper's serving rule).

    batch: hist [1, H], hist_mask [1, H], candidates int32 [n_cand].
    """
    interests = user_interests(cfg, params, batch["hist"], batch["hist_mask"])
    table = params["item_embed"]["table"].astype(interests.dtype)
    cand = jnp.take(table, batch["candidates"], axis=0)           # [n_cand, d]
    sims = jnp.einsum("kd,cd->kc", interests[0], cand)
    return sims.max(axis=0)
