"""Sharded sparse-embedding substrate for the recsys archs.

JAX has no ``nn.EmbeddingBag`` and no CSR — we build the lookup from
``jnp.take`` + mask/segment reductions (this *is* part of the system).  All
categorical fields share one fused table ``[n_fields * vocab_per_field, dim]``
with per-field offsets; row-sharding that single table over the ``model``
axis is the DLRM-style table placement (GSPMD turns the sharded ``take``
into the expected all-to-all / all-gather pair).

The Pallas ``embedding_bag`` kernel is the fused VMEM path for the per-shard
local lookup; this module is the portable production path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    n_fields: int
    vocab_per_field: int
    dim: int
    combiner: str = "sum"      # sum | mean (for multi-hot bags)
    param_dtype: Any = jnp.float32

    @property
    def total_rows(self) -> int:
        return self.n_fields * self.vocab_per_field

    def param_count(self) -> int:
        return self.total_rows * self.dim


def init(cfg: EmbeddingConfig, key) -> Dict[str, jax.Array]:
    table = jax.random.normal(
        key, (cfg.total_rows, cfg.dim), jnp.float32
    ) * (cfg.dim ** -0.5)
    return {"table": table.astype(cfg.param_dtype)}


def field_offsets(cfg: EmbeddingConfig) -> jax.Array:
    return (jnp.arange(cfg.n_fields, dtype=jnp.int32) * cfg.vocab_per_field)


def lookup(cfg: EmbeddingConfig, params, ids: jax.Array,
           compute_dtype=jnp.float32) -> jax.Array:
    """One-hot fields: ids int32[B, n_fields] -> [B, n_fields, dim]."""
    flat = (ids + field_offsets(cfg)[None, :]).reshape(-1)
    rows = jnp.take(params["table"].astype(compute_dtype), flat, axis=0)
    return rows.reshape(ids.shape[0], cfg.n_fields, cfg.dim)


def bag_lookup(cfg: EmbeddingConfig, params, ids: jax.Array,
               mask: jax.Array, compute_dtype=jnp.float32) -> jax.Array:
    """Multi-hot: ids int32[B, n_fields, bag], mask f32 same shape ->
    [B, n_fields, dim] (sum or mean combiner)."""
    b, nf, bag = ids.shape
    flat = (ids + field_offsets(cfg)[None, :, None]).reshape(-1)
    rows = jnp.take(params["table"].astype(compute_dtype), flat, axis=0)
    rows = rows.reshape(b, nf, bag, cfg.dim) * mask[..., None]
    out = rows.sum(axis=2)
    if cfg.combiner == "mean":
        out = out / jnp.maximum(mask.sum(axis=2), 1.0)[..., None]
    return out


def item_lookup(table: jax.Array, ids: jax.Array,
                compute_dtype=jnp.float32) -> jax.Array:
    """Plain row gather (sequence models / candidate scoring)."""
    return jnp.take(table.astype(compute_dtype), ids, axis=0)
