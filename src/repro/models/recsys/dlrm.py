"""DLRM RM2 (arXiv:1906.00091): bottom MLP + dot interaction + top MLP."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys import embedding as E


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    bot_mlp: Sequence[int] = (13, 512, 256, 64)
    top_mlp: Sequence[int] = (512, 512, 256, 1)
    vocab_per_field: int = 1_000_000
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def n_vectors(self) -> int:
        return self.n_sparse + 1  # embeddings + bottom-MLP output

    @property
    def n_interactions(self) -> int:
        return self.n_vectors * (self.n_vectors - 1) // 2

    @property
    def top_in(self) -> int:
        return self.n_interactions + self.embed_dim

    @property
    def embedding(self) -> E.EmbeddingConfig:
        return E.EmbeddingConfig(
            self.n_sparse, self.vocab_per_field, self.embed_dim,
            param_dtype=self.param_dtype,
        )

    def param_count(self) -> int:
        bot = sum(a * b + b for a, b in zip(self.bot_mlp[:-1], self.bot_mlp[1:]))
        dims = [self.top_in] + list(self.top_mlp)
        top = sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return self.embedding.param_count() + bot + top


def init(cfg: DLRMConfig, key) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embedding": E.init(cfg.embedding, k1),
        "bot": L.mlp_init(k2, list(cfg.bot_mlp), dtype=cfg.param_dtype),
        "top": L.mlp_init(k3, [cfg.top_in] + list(cfg.top_mlp),
                          dtype=cfg.param_dtype),
    }


def _interact(vectors: jax.Array) -> jax.Array:
    """Pairwise dots, lower triangle. vectors [B, V, d] -> [B, V(V-1)/2]."""
    b, v, d = vectors.shape
    gram = jnp.einsum("bvd,bwd->bvw", vectors, vectors)
    ii, jj = jnp.tril_indices(v, k=-1)
    return gram[:, ii, jj]


def forward(cfg: DLRMConfig, params, batch) -> jax.Array:
    dt = cfg.compute_dtype
    d0 = L.mlp_apply(params["bot"], batch["dense"].astype(dt),
                     act=jax.nn.relu, final_act=jax.nn.relu, compute_dtype=dt)
    emb = E.lookup(cfg.embedding, params["embedding"], batch["sparse_ids"], dt)
    vectors = jnp.concatenate([d0[:, None, :], emb], axis=1)  # [B, 27, 64]
    inter = _interact(vectors)
    top_in = jnp.concatenate([inter, d0], axis=-1)
    return L.mlp_apply(params["top"], top_in, compute_dtype=dt)[:, 0]


def loss_fn(cfg: DLRMConfig, params, batch) -> jax.Array:
    return L.binary_cross_entropy(forward(cfg, params, batch), batch["label"])


def retrieval_scores(cfg: DLRMConfig, params, batch) -> jax.Array:
    """1 user vs n_candidates (candidate id -> sparse field 0)."""
    n_cand = batch["candidates"].shape[0]
    ids = jnp.broadcast_to(batch["sparse_ids"], (n_cand, cfg.n_sparse))
    ids = ids.at[:, 0].set(batch["candidates"])
    dense = jnp.broadcast_to(batch["dense"], (n_cand, cfg.n_dense))
    return forward(cfg, params, dict(dense=dense, sparse_ids=ids))
