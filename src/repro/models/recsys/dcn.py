"""DCN-v2 (arXiv:2008.13535): explicit cross network + deep tower."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys import embedding as E


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: Sequence[int] = (1024, 1024, 512)
    vocab_per_field: int = 1_000_000
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def x0_dim(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    @property
    def embedding(self) -> E.EmbeddingConfig:
        return E.EmbeddingConfig(
            self.n_sparse, self.vocab_per_field, self.embed_dim,
            param_dtype=self.param_dtype,
        )

    def param_count(self) -> int:
        d = self.x0_dim
        cross = self.n_cross_layers * (d * d + d)
        dims = [d] + list(self.mlp)
        deep = sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        head = (d + self.mlp[-1]) + 1
        return self.embedding.param_count() + cross + deep + head


def init(cfg: DCNConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 3 + cfg.n_cross_layers)
    d = cfg.x0_dim
    p: Dict[str, Any] = {
        "embedding": E.init(cfg.embedding, keys[0]),
        "deep": L.mlp_init(keys[1], [d] + list(cfg.mlp), dtype=cfg.param_dtype),
        "head": L.dense_init(keys[2], d + cfg.mlp[-1], 1, bias=True,
                             dtype=cfg.param_dtype),
    }
    for i in range(cfg.n_cross_layers):
        p[f"cross_{i}"] = L.dense_init(
            keys[3 + i], d, d, bias=True, dtype=cfg.param_dtype
        )
    return p


def forward(cfg: DCNConfig, params, batch) -> jax.Array:
    """batch: dense [B, n_dense] f32, sparse_ids [B, n_sparse] int32."""
    dt = cfg.compute_dtype
    emb = E.lookup(cfg.embedding, params["embedding"], batch["sparse_ids"], dt)
    x0 = jnp.concatenate(
        [batch["dense"].astype(dt), emb.reshape(emb.shape[0], -1)], axis=-1
    )
    # cross tower: x_{l+1} = x0 * (W x_l + b) + x_l
    x = x0
    for i in range(cfg.n_cross_layers):
        x = x0 * L.dense_apply(params[f"cross_{i}"], x, compute_dtype=dt) + x
    deep = L.mlp_apply(params["deep"], x0, compute_dtype=dt)
    feats = jnp.concatenate([x, deep], axis=-1)
    return L.dense_apply(params["head"], feats, compute_dtype=dt)[:, 0]


def loss_fn(cfg: DCNConfig, params, batch) -> jax.Array:
    logits = forward(cfg, params, batch)
    return L.binary_cross_entropy(logits, batch["label"])


def retrieval_scores(cfg: DCNConfig, params, batch) -> jax.Array:
    """Score 1 user context against ``n_candidates`` items: the candidate id
    replaces sparse field 0; all other features broadcast.

    batch: dense [1, n_dense], sparse_ids [1, n_sparse],
    candidates int32 [n_cand].  Returns [n_cand] scores.
    """
    n_cand = batch["candidates"].shape[0]
    ids = jnp.broadcast_to(batch["sparse_ids"], (n_cand, cfg.n_sparse))
    ids = ids.at[:, 0].set(batch["candidates"])
    dense = jnp.broadcast_to(batch["dense"], (n_cand, cfg.n_dense))
    return forward(cfg, params, dict(dense=dense, sparse_ids=ids))
