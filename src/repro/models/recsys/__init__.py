"""RecSys model zoo: DCN-v2, DLRM-RM2, SASRec, MIND + embedding substrate."""

from repro.models.recsys import dcn, dlrm, embedding, mind, sasrec  # noqa: F401
