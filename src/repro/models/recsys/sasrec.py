"""SASRec (arXiv:1808.09781): self-attentive sequential recommendation."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import chunked_attention


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    d_ff: int = 200
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        d = self.embed_dim
        attn = 4 * d * d
        ffn = 2 * d * self.d_ff
        per_block = attn + ffn + 4 * d
        return (self.n_items + self.seq_len) * d + self.n_blocks * per_block


def init(cfg: SASRecConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 2 + cfg.n_blocks)
    p: Dict[str, Any] = {
        "item_embed": L.embedding_init(keys[0], cfg.n_items, cfg.embed_dim,
                                       cfg.param_dtype),
        "pos_embed": L.embedding_init(keys[1], cfg.seq_len, cfg.embed_dim,
                                      cfg.param_dtype),
    }
    d = cfg.embed_dim
    for i, k in enumerate(keys[2:]):
        ks = jax.random.split(k, 6)
        p[f"block_{i}"] = {
            "ln1": L.layernorm_init(d, cfg.param_dtype),
            "ln2": L.layernorm_init(d, cfg.param_dtype),
            "wq": L.dense_init(ks[0], d, d, dtype=cfg.param_dtype),
            "wk": L.dense_init(ks[1], d, d, dtype=cfg.param_dtype),
            "wv": L.dense_init(ks[2], d, d, dtype=cfg.param_dtype),
            "wo": L.dense_init(ks[3], d, d, dtype=cfg.param_dtype),
            "ff1": L.dense_init(ks[4], d, cfg.d_ff, bias=True, dtype=cfg.param_dtype),
            "ff2": L.dense_init(ks[5], cfg.d_ff, d, bias=True, dtype=cfg.param_dtype),
        }
    return p


def encode(cfg: SASRecConfig, params, item_seq: jax.Array) -> jax.Array:
    """item_seq int32[B, S] -> hidden [B, S, d] (causal)."""
    b, s = item_seq.shape
    dt = cfg.compute_dtype
    hd = cfg.embed_dim // cfg.n_heads
    h = L.embedding_apply(params["item_embed"], item_seq, compute_dtype=dt)
    h = h + L.embedding_apply(
        params["pos_embed"], jnp.arange(s)[None, :], compute_dtype=dt
    )
    for i in range(cfg.n_blocks):
        p = params[f"block_{i}"]
        x = L.layernorm_apply(p["ln1"], h)
        q = L.dense_apply(p["wq"], x, compute_dtype=dt).reshape(b, s, cfg.n_heads, hd)
        k = L.dense_apply(p["wk"], x, compute_dtype=dt).reshape(b, s, cfg.n_heads, hd)
        v = L.dense_apply(p["wv"], x, compute_dtype=dt).reshape(b, s, cfg.n_heads, hd)
        o = chunked_attention(q, k, v, n_kv_heads=cfg.n_heads, causal=True,
                              chunk=min(s, 512))
        h = h + L.dense_apply(p["wo"], o.reshape(b, s, -1), compute_dtype=dt)
        x = L.layernorm_apply(p["ln2"], h)
        h = h + L.dense_apply(
            p["ff2"], jax.nn.relu(L.dense_apply(p["ff1"], x, compute_dtype=dt)),
            compute_dtype=dt,
        )
    return h


def loss_fn(cfg: SASRecConfig, params, batch) -> jax.Array:
    """Next-item BPR-style loss with sampled negatives.

    batch: item_seq [B, S], pos [B, S], neg [B, S], mask [B, S].
    """
    h = encode(cfg, params, batch["item_seq"])
    table = params["item_embed"]["table"].astype(h.dtype)
    pos_e = jnp.take(table, batch["pos"], axis=0)
    neg_e = jnp.take(table, batch["neg"], axis=0)
    pos_s = jnp.sum(h * pos_e, axis=-1)
    neg_s = jnp.sum(h * neg_e, axis=-1)
    mask = batch["mask"]
    nll = -jnp.log(jax.nn.sigmoid(pos_s - neg_s) + 1e-9) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def user_embedding(cfg: SASRecConfig, params, item_seq: jax.Array) -> jax.Array:
    """Last hidden state = the user representation for retrieval."""
    return encode(cfg, params, item_seq)[:, -1, :]


def retrieval_scores(cfg: SASRecConfig, params, batch) -> jax.Array:
    """1 user history vs n_candidates: one dot per candidate.

    batch: item_seq [1, S], candidates int32 [n_cand] -> [n_cand].
    """
    u = user_embedding(cfg, params, batch["item_seq"])  # [1, d]
    table = params["item_embed"]["table"].astype(u.dtype)
    cand = jnp.take(table, batch["candidates"], axis=0)  # [n_cand, d]
    return cand @ u[0]
