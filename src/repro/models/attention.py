"""GQA attention: chunked (flash-style) training path + KV-cache decode.

The training/prefill path scans over KV chunks with an online softmax, so
peak memory is O(S * chunk) instead of O(S^2) — required for the
``prefill_32k`` cells and keeps the HLO small (a scan, not 32k unrolled).
This is the TPU analogue of FlashAttention: the chunk loop is sequential in
HLO but XLA pipelines the matmuls through the MXU; VMEM tiling happens at
the XLA level for jnp einsums (a hand-Pallas attention kernel is not the
paper's contribution, so we stay at the jnp layer here).

Decode: one new token against a length-sharded cache.  The partial-softmax
carry (m, l, acc) is associative, so GSPMD turns the seq-sharded reduction
into the flash-decoding split-K pattern (psum of rescaled partials).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Decode cache. k/v: [layers, batch, max_seq, kv_heads, head_dim]."""
    k: jax.Array
    v: jax.Array
    length: jax.Array  # int32 [] tokens currently valid


def _gqa_scores(q, k):
    """q: [B, Sq, Hkv, G, hd]; k: [B, C, Hkv, hd] -> [B, Hkv, G, Sq, C]."""
    return jnp.einsum(
        "bqhgd,bchd->bhgqc", q, k, preferred_element_type=jnp.float32
    )


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_kv_heads: int,
    causal: bool = True,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax attention.

    q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd]. Returns [B, Sq, Hq, hd].
    """
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    g = hq // n_kv_heads
    qg = q.reshape(b, sq, n_kv_heads, g, hd) * (hd ** -0.5)
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    n_chunks = skv // chunk
    kc = k.reshape(b, n_chunks, chunk, n_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, n_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        s = _gqa_scores(qg, kb)  # [B, Hkv, G, Sq, C] fp32
        if causal:
            k_pos = ci * chunk + jnp.arange(chunk)
            mask = q_pos[:, None] >= k_pos[None, :]  # [Sq, C]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l = l * scale + p.sum(axis=-1)
        # probabilities in compute dtype for the PV matmul (f32 accumulate):
        # the score-shaped buffers dominate HBM traffic on memory-bound
        # cells; bf16 p is the standard flash-attention trade.
        acc = acc * scale[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), ()

    m0 = jnp.full((b, n_kv_heads, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv_heads, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv_heads, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_length: jax.Array,
    *,
    n_kv_heads: int,
) -> jax.Array:
    """One-token attention against the cache.

    q: [B, 1, Hq, hd]; k_cache/v_cache: [B, S, Hkv, hd];
    positions >= cache_length are masked.  The softmax reduction over the
    (possibly seq-sharded) cache is a single fused pass; GSPMD inserts the
    split-K combine when S is sharded.
    """
    b, _, hq, hd = q.shape
    s = k_cache.shape[1]
    g = hq // n_kv_heads
    qg = q.reshape(b, n_kv_heads, g, hd) * (hd ** -0.5)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    valid = jnp.arange(s)[None, :] < cache_length
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)
