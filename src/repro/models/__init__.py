"""Model zoo: transformer LM (dense + MoE), GCN, recsys models."""

from repro.models import attention, gcn, layers, recsys, transformer  # noqa: F401
