"""Shared neural-net layers: pure-jax, pytree params, no framework.

Conventions: params are nested dicts of jnp arrays; every ``init_*`` takes a
PRNG key; every ``apply`` is a pure function.  Compute dtype is configurable
(bf16 on TPU); params stay in their stored dtype until cast at use.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x, *, compute_dtype=None):
    dt = compute_dtype or x.dtype
    y = x.astype(dt) @ p["w"].astype(dt)
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y


def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, dim), jnp.float32)
            .astype(dtype) * 0.02}


def embedding_apply(p, ids, *, compute_dtype=None):
    dt = compute_dtype or p["table"].dtype
    return jnp.take(p["table"].astype(dt), ids, axis=0)


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def mlp_init(key, dims: Sequence[int], *, bias: bool = True,
             dtype=jnp.float32):
    """Plain MLP tower (recsys towers, GCN heads)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": dense_init(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
        for i, k in enumerate(keys)
    }


def mlp_apply(p, x, *, act=jax.nn.relu, final_act=None, compute_dtype=None):
    n = len(p)
    for i in range(n):
        x = dense_apply(p[f"layer_{i}"], x, compute_dtype=compute_dtype)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL.  logits [..., V] fp any; labels int [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None], axis=-1
    ).squeeze(-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def binary_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
