"""GCN (Kipf & Welling, arXiv:1609.02907) on segment-sum message passing.

Three execution modes matching the assigned shapes:

* **full-batch** (cora / ogb_products): symmetric-normalized propagation
  ``H' = D~^-1/2 A~ D~^-1/2 H W`` over the full edge list — one gather +
  one ``segment_sum`` per layer (JAX has no CSR SpMM; this IS the SpMM).
* **sampled minibatch** (minibatch_lg): consumes the fixed-shape
  :class:`repro.graphs.sampler.SampledBlock`s (fanout 15-10) with mean
  aggregation over sampled neighbors.
* **batched small graphs** (molecule): block-diagonal edges + segment-mean
  readout per graph -> classification head.

PowerWalk integration: ``ppr_propagate`` replaces multi-hop propagation with
a single PPR-weighted aggregation over the PowerWalk index (APPNP/PPRGo
lineage) — the paper's technique as a first-class GNN feature.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    n_layers: int
    d_feat: int
    d_hidden: int
    n_classes: int
    aggregator: str = "mean"     # mean | sym
    dropout: float = 0.0
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    readout: Optional[str] = None   # None | "mean" (graph-level)

    def param_count(self) -> int:
        dims = [self.d_feat] + [self.d_hidden] * (self.n_layers - 1) + [self.n_classes]
        return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))


def init(cfg: GCNConfig, key) -> Dict[str, Any]:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer_{i}": L.dense_init(k, dims[i], dims[i + 1], bias=True,
                                   dtype=cfg.param_dtype)
        for i, k in enumerate(keys)
    }


def _propagate(h, edge_src, edge_dst, n, norm_src, norm_dst, add_self=True):
    """One normalized aggregation: gather -> weight -> segment_sum."""
    msgs = jnp.take(h, edge_src, axis=0) * norm_src[:, None]
    agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n)
    agg = agg * norm_dst[:, None]
    if add_self:
        agg = agg + h * 0  # self handled via norm terms when using A~
    return agg


def sym_norm_coeffs(edge_src, edge_dst, n, edge_mask=None):
    """1/sqrt(d~_src d~_dst) per edge plus 1/d~_v self-loop weights,
    d~ = deg + 1 (the A~ = A + I normalization).  Masked (padding) edges
    contribute nothing to degrees."""
    ones = jnp.ones_like(edge_src, dtype=jnp.float32)
    if edge_mask is not None:
        ones = ones * edge_mask
    deg = jax.ops.segment_sum(ones, edge_dst, num_segments=n) + 1.0
    out_deg = jax.ops.segment_sum(ones, edge_src, num_segments=n) + 1.0
    inv_sq_in = jax.lax.rsqrt(deg)
    inv_sq_out = jax.lax.rsqrt(out_deg)
    w_edge = jnp.take(inv_sq_out, edge_src) * jnp.take(inv_sq_in, edge_dst)
    w_self = inv_sq_in * inv_sq_out
    return w_edge, w_self


def forward_full(cfg: GCNConfig, params, features, edge_src, edge_dst,
                 edge_mask=None) -> jax.Array:
    """Full-graph forward. features [N, F] -> logits [N, C]."""
    n = features.shape[0]
    h = features.astype(cfg.compute_dtype)
    if cfg.aggregator == "sym":
        w_edge, w_self = sym_norm_coeffs(edge_src, edge_dst, n, edge_mask)
    else:  # mean over in-neighbors (+ self)
        ones = jnp.ones(edge_src.shape, jnp.float32)
        if edge_mask is not None:
            ones = ones * edge_mask
        deg = jax.ops.segment_sum(ones, edge_dst, num_segments=n) + 1.0
        w_edge, w_self = 1.0 / jnp.take(deg, edge_dst), 1.0 / deg
    if edge_mask is not None:
        w_edge = w_edge * edge_mask
    for i in range(cfg.n_layers):
        msgs = jnp.take(h, edge_src, axis=0) * w_edge[:, None].astype(h.dtype)
        agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n)
        agg = agg + h * w_self[:, None].astype(h.dtype)
        h = L.dense_apply(params[f"layer_{i}"], agg, compute_dtype=cfg.compute_dtype)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h


def loss_full(cfg: GCNConfig, params, batch) -> jax.Array:
    """batch: features, edge_src, edge_dst, labels [N], label_mask [N]."""
    logits = forward_full(
        cfg, params, batch["features"], batch["edge_src"], batch["edge_dst"],
        batch.get("edge_mask"),
    )
    if cfg.readout == "mean":
        # graph-level: segment-mean by graph id then classify
        gid = batch["graph_ids"]
        n_graphs = batch["graph_labels"].shape[0]
        pooled = jax.ops.segment_sum(logits, gid, num_segments=n_graphs)
        cnt = jax.ops.segment_sum(
            jnp.ones((logits.shape[0],), logits.dtype), gid,
            num_segments=n_graphs,
        )
        pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
        return L.softmax_cross_entropy(pooled, batch["graph_labels"])
    return L.softmax_cross_entropy(
        logits, batch["labels"], batch.get("label_mask")
    )


def forward_sampled(cfg: GCNConfig, params, block_feats: Sequence[jax.Array],
                    blocks_edges: Sequence[dict]) -> jax.Array:
    """Minibatch forward over sampled blocks (innermost hop last).

    block_feats[i]: [n_nodes_i, F or d] features of block i's node set.
    blocks_edges[i]: dict(edge_src, edge_dst, edge_mask, n_dst).
    Consumed outermost-first: layer i aggregates block -(i+1) into block -i.
    """
    h = block_feats[-1].astype(cfg.compute_dtype)
    for i in range(cfg.n_layers):
        be = blocks_edges[-(i + 1)]
        n_dst = be["n_dst"]
        ones = be["edge_mask"]
        deg = jax.ops.segment_sum(ones, be["edge_dst"], num_segments=n_dst) + 1.0
        msgs = jnp.take(h, be["edge_src"], axis=0) * ones[:, None].astype(h.dtype)
        agg = jax.ops.segment_sum(msgs, be["edge_dst"], num_segments=n_dst)
        agg = (agg + h[:n_dst]) / deg[:, None].astype(h.dtype)
        h = L.dense_apply(params[f"layer_{i}"], agg, compute_dtype=cfg.compute_dtype)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h


def loss_sampled(cfg: GCNConfig, params, batch) -> jax.Array:
    """batch: block_feats_0.. (list packed), edges per block, seed labels."""
    logits = forward_sampled(
        cfg, params, batch["block_feats"], batch["block_edges"]
    )
    return L.softmax_cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# PowerWalk integration: PPR-weighted propagation (APPNP / PPRGo style)
# ---------------------------------------------------------------------------

def ppr_propagate(h: jax.Array, ppr_vals: jax.Array, ppr_idx: jax.Array) -> jax.Array:
    """h' [B, d] = sum_l ppr_vals[b, l] * h[ppr_idx[b, l]].

    Replaces n_layers of graph propagation with one aggregation over each
    seed's top-L PPR neighborhood (from the PowerWalk index / sampler).
    """
    nbr = jnp.take(h, ppr_idx.reshape(-1), axis=0).reshape(
        ppr_idx.shape + (h.shape[-1],)
    )
    return jnp.einsum("bl,bld->bd", ppr_vals.astype(nbr.dtype), nbr)


def loss_ppr(cfg: GCNConfig, params, batch) -> jax.Array:
    """PPRGo-style: MLP on raw features, then PPR aggregation of logits.

    batch: feats [n_unique, F] (features of all index neighbors),
    ppr_vals/ppr_idx [B, L] (positions into feats), labels [B].
    """
    h = batch["feats"].astype(cfg.compute_dtype)
    for i in range(cfg.n_layers):
        h = L.dense_apply(params[f"layer_{i}"], h, compute_dtype=cfg.compute_dtype)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    logits = ppr_propagate(h, batch["ppr_vals"], batch["ppr_idx"])
    return L.softmax_cross_entropy(logits, batch["labels"])
