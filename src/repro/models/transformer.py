"""Decoder-only transformer LM: GQA + RoPE + SwiGLU, optional MoE.

Design notes (pod-scale discipline):

* **scan over layers**: params are stacked with a leading ``n_layers`` dim
  and the stack is applied with ``lax.scan`` -> HLO size is O(1) in depth,
  which keeps 64-layer × 512-device lowering tractable and makes remat
  policy uniform.
* **remat**: each layer body is ``jax.checkpoint``-ed (save boundaries,
  recompute interior) when ``cfg.remat``.
* **chunked loss**: logits for a [B, S, V] block can dominate peak memory
  (command-r: V=256k); ``loss_chunk`` computes CE per sequence chunk inside
  a scan.
* **MoE**: capacity-based dispatch via sort + scatter (static shapes, no
  [T, E, C] one-hots).  When ``n_experts`` < the model-axis size, experts
  are *split* into ``ep_split`` virtual experts along the SwiGLU ff dim
  (exactly tensor-parallelism inside each expert) so the expert dim always
  matches the mesh — grok's 8 experts become 16 virtual experts on a
  16-way axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.attention import chunked_attention, decode_attention


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    ep_split: int = 1          # virtual experts per expert (ff-dim split)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ActSharding:
    """Activation-sharding hints (mesh axis names), applied via
    with_sharding_constraint when lowering under a mesh.  ``None`` (the
    default on the config) keeps the model mesh-agnostic for CPU tests.

    ``mesh`` (a concrete jax Mesh) additionally enables the shard_map MoE
    dispatch path: local per-data-shard routing + FSDP weight all-gather +
    psum combine.  Without it, GSPMD lowers the global scatter dispatch to
    full-capacity-buffer all-reduces (measured 60 TB/step on grok).
    ``fsdp_axis`` is the axis expert weights' d-dim is sharded over.
    """

    batch: Tuple[str, ...] = ("data",)
    model: str = "model"
    mesh: Any = None
    fsdp_axis: str = "data"
    # Megatron-style sequence parallelism: the residual stream (and thus
    # every remat boundary the backward pass stores) is sharded over the
    # model axis along seq.  Costs one all-gather + reduce-scatter pair per
    # layer; divides boundary-activation HBM by the model-axis size.
    seq_shard: bool = True


def _constrain(x: jax.Array, spec) -> jax.Array:
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10000.0
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    attn_chunk: int = 1024
    loss_chunk: int = 0        # 0 = unchunked
    remat: bool = True
    act_shard: Optional[ActSharding] = None
    # Pre-cast params to compute dtype once per step, *before* any FSDP
    # all-gather: the convert runs on the local shard, so gathers move bf16
    # instead of fp32 — halves FSDP wire bytes (§Perf command-r iteration).
    precast_params: bool = False
    # int8 KV cache (per-token, per-head dynamic scales): halves-to-quarters
    # decode HBM; required for MHA archs (qwen kv=40) at 32k+ contexts.
    kv_quant: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.hd * 2 + d * self.n_kv_heads * self.hd * 2
        if self.moe:
            ffn = self.moe.n_experts * (2 * d * ff + ff * d) + d * self.moe.n_experts
        else:
            ffn = 2 * d * ff + ff * d
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        full_ffn = self.moe.n_experts * 3 * d * ff
        active_ffn = self.moe.top_k * 3 * d * ff
        return self.param_count() - self.n_layers * (full_ffn - active_ffn)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: TransformerConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, hd = cfg.d_model, cfg.hd
    dt = cfg.param_dtype
    p: Dict[str, Any] = {
        "ln_attn": L.rmsnorm_init(d, dt),
        "ln_ffn": L.rmsnorm_init(d, dt),
        "wq": L.dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wk": L.dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wv": L.dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dt),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d, dtype=dt),
    }
    if cfg.moe:
        e = cfg.moe.n_experts * cfg.moe.ep_split
        ffs = cfg.d_ff // cfg.moe.ep_split
        def ew(key, a, b):
            return (jax.random.normal(key, (e, a, b), jnp.float32)
                    * (a ** -0.5)).astype(dt)
        p["router"] = L.dense_init(ks[4], d, cfg.moe.n_experts, dtype=jnp.float32)
        p["w_gate"] = ew(ks[5], d, ffs)
        p["w_up"] = ew(ks[6], d, ffs)
        p["w_down"] = ew(ks[7], ffs, d)
    else:
        p["w_gate"] = L.dense_init(ks[5], d, cfg.d_ff, dtype=dt)
        p["w_up"] = L.dense_init(ks[6], d, cfg.d_ff, dtype=dt)
        p["w_down"] = L.dense_init(ks[7], cfg.d_ff, d, dtype=dt)
    return p


def init(cfg: TransformerConfig, key) -> Dict[str, Any]:
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    return {
        "embed": L.embedding_init(k_embed, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": stacked,
        "ln_final": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab, dtype=cfg.param_dtype),
    }


# ---------------------------------------------------------------------------
# MoE ffn
# ---------------------------------------------------------------------------

def _moe_ffn(cfg: TransformerConfig, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [T, d] -> ([T, d], aux_loss). Capacity-based sort dispatch."""
    moe = cfg.moe
    t, d = x.shape
    e_real, k = moe.n_experts, moe.top_k
    split = moe.ep_split
    e_virt = e_real * split
    kv = k * split  # each selected expert contributes `split` virtual slots
    cap = max(int(t * kv * moe.capacity_factor / e_virt), 1)

    logits = x.astype(jnp.float32) @ p["router"]["w"]          # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                      # [T, k]
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.zeros((e_real,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * k)
    )
    aux = moe.aux_loss_weight * e_real * jnp.sum(me * ce)

    # virtual-expert expansion: expert e -> slots e*split .. e*split+split-1
    offs = jnp.arange(split, dtype=top_e.dtype)
    flat_e = (top_e[:, :, None] * split + offs).reshape(-1)     # [T*kv]
    flat_w = jnp.broadcast_to(top_g[:, :, None], (t, k, split)).reshape(-1)
    flat_tok = jnp.broadcast_to(
        jnp.arange(t)[:, None, None], (t, k, split)
    ).reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    counts = jnp.zeros((e_virt,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * kv, dtype=jnp.int32) - starts[se]
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    xt = x.astype(cfg.compute_dtype)
    buf = jnp.zeros((e_virt, cap, d), cfg.compute_dtype)
    vals = jnp.take(xt, stok, axis=0) * keep[:, None].astype(xt.dtype)
    buf = buf.at[se, pos_c].add(vals)
    if cfg.act_shard is not None:
        # expert dim over 'model' (EP) AND capacity over the data axes —
        # without the latter every data row recomputes the full expert FFN
        # (measured 16x flops blow-up on grok before this constraint).
        buf = _constrain(buf, P(cfg.act_shard.model, cfg.act_shard.batch, None))

    wg = p["w_gate"].astype(cfg.compute_dtype)
    wu = p["w_up"].astype(cfg.compute_dtype)
    wd = p["w_down"].astype(cfg.compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wu
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)                 # [E, cap, d]
    if cfg.act_shard is not None:
        out_buf = _constrain(
            out_buf, P(cfg.act_shard.model, cfg.act_shard.batch, None)
        )

    tok_out = out_buf[se, pos_c] * (keep.astype(jnp.float32) * sw)[:, None].astype(
        out_buf.dtype
    )
    out = jnp.zeros((t, d), cfg.compute_dtype).at[stok].add(tok_out)
    return out, aux


def _moe_ffn_shardmap(cfg: TransformerConfig, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map: the production dispatch path.

    Layout: tokens sharded over the batch axes, virtual experts over the
    model axis, expert weights' d-dim FSDP-sharded over ``fsdp_axis``.
    Per shard: route/sort/scatter locally (zero communication), all-gather
    only *my* experts' weights over the FSDP axis, run the expert FFN on my
    experts' local slots, and psum partial token outputs over the model
    axis.  Wire cost per layer = FSDP weight gather + one activation psum —
    versus GSPMD's full-capacity-buffer all-reduces for the same math.
    """
    ash = cfg.act_shard
    mesh = ash.mesh
    moe = cfg.moe
    e_virt = moe.n_experts * moe.ep_split
    ep = int(mesh.shape[ash.model])
    assert e_virt % ep == 0, (e_virt, ep)
    e_local = e_virt // ep
    kv = moe.top_k * moe.ep_split

    def local(x_blk, rw, wg, wu, wd):
        t_l, d = x_blk.shape
        cap = max(int(t_l * kv * moe.capacity_factor / e_virt), 1)
        # --- routing (local tokens, replicated router) -------------------
        logits = x_blk.astype(jnp.float32) @ rw
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, moe.top_k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(gates, axis=0)
        ce = jnp.zeros((moe.n_experts,), jnp.float32).at[
            top_e.reshape(-1)].add(1.0 / (t_l * moe.top_k))
        aux = moe.aux_loss_weight * moe.n_experts * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, ash.batch)

        # --- local dispatch (sort + positions, no comms) ------------------
        offs = jnp.arange(moe.ep_split, dtype=top_e.dtype)
        flat_e = (top_e[:, :, None] * moe.ep_split + offs).reshape(-1)
        flat_w = jnp.broadcast_to(
            top_g[:, :, None], top_g.shape + (moe.ep_split,)).reshape(-1)
        flat_tok = jnp.broadcast_to(
            jnp.arange(t_l)[:, None, None], (t_l, moe.top_k, moe.ep_split)
        ).reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
        counts = jnp.zeros((e_virt,), jnp.int32).at[se].add(1)
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(t_l * kv, dtype=jnp.int32) - starts[se]
        keep = pos < cap
        pos_c = jnp.minimum(pos, cap - 1)
        xt = x_blk.astype(cfg.compute_dtype)
        buf = jnp.zeros((e_virt, cap, d), cfg.compute_dtype)
        vals = jnp.take(xt, stok, axis=0) * keep[:, None].astype(xt.dtype)
        buf = buf.at[se, pos_c].add(vals)

        # --- my experts only ----------------------------------------------
        m_idx = jax.lax.axis_index(ash.model)
        my = jax.lax.dynamic_slice_in_dim(buf, m_idx * e_local, e_local, 0)
        wg = jax.lax.all_gather(wg, ash.fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, ash.fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, ash.fsdp_axis, axis=2, tiled=True)
        wg = wg.astype(cfg.compute_dtype)
        wu = wu.astype(cfg.compute_dtype)
        wd = wd.astype(cfg.compute_dtype)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", my, wg)) * jnp.einsum(
            "ecd,edf->ecf", my, wu)
        out_my = jnp.einsum("ecf,efd->ecd", h, wd)          # [e_local, cap, d]

        # --- combine: partial (my experts) then psum over model -----------
        full = jnp.zeros((e_virt, cap, d), cfg.compute_dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, out_my, m_idx * e_local, 0)
        tok_out = full[se, pos_c] * (
            keep.astype(jnp.float32) * sw)[:, None].astype(full.dtype)
        out = jnp.zeros((t_l, d), cfg.compute_dtype).at[stok].add(tok_out)
        out = jax.lax.psum(out, ash.model)
        return out, aux

    # decode at tiny batch (long_500k: T=1) can't shard tokens over data:
    # replicate instead (redundant but negligible at 1 token).
    import numpy as _np
    dsize = int(_np.prod([mesh.shape[a] for a in ash.batch]))
    tok_axes = ash.batch if x.shape[0] % dsize == 0 and x.shape[0] >= dsize \
        else None
    in_specs = (
        P(tok_axes, None),                        # x
        P(None, None),                            # router
        P(ash.model, ash.fsdp_axis, None),        # w_gate
        P(ash.model, ash.fsdp_axis, None),        # w_up
        P(ash.model, None, ash.fsdp_axis),        # w_down
    )
    out_specs = (P(tok_axes, None), P())
    from repro.compat import shard_map

    fn = shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
    return fn(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])


def _dense_ffn(cfg: TransformerConfig, p, x: jax.Array) -> jax.Array:
    dt = cfg.compute_dtype
    g = jax.nn.silu(L.dense_apply(p["w_gate"], x, compute_dtype=dt))
    u = L.dense_apply(p["w_up"], x, compute_dtype=dt)
    return L.dense_apply(p["w_down"], g * u, compute_dtype=dt)


# ---------------------------------------------------------------------------
# layer + forward
# ---------------------------------------------------------------------------

def _attn(cfg: TransformerConfig, p, h: jax.Array, q_offset: int = 0) -> jax.Array:
    b, s, d = h.shape
    dt = cfg.compute_dtype
    hd = cfg.hd
    q = L.dense_apply(p["wq"], h, compute_dtype=dt).reshape(b, s, cfg.n_heads, hd)
    k = L.dense_apply(p["wk"], h, compute_dtype=dt).reshape(b, s, cfg.n_kv_heads, hd)
    v = L.dense_apply(p["wv"], h, compute_dtype=dt).reshape(b, s, cfg.n_kv_heads, hd)
    pos = q_offset + jnp.arange(s)
    q = L.apply_rope(q, jnp.broadcast_to(pos, (b, s)), cfg.rope_theta)
    k = L.apply_rope(k, jnp.broadcast_to(pos, (b, s)), cfg.rope_theta)
    o = chunked_attention(
        q, k, v, n_kv_heads=cfg.n_kv_heads, causal=True, chunk=cfg.attn_chunk
    )
    return L.dense_apply(p["wo"], o.reshape(b, s, cfg.n_heads * hd), compute_dtype=dt)


def _layer_body(cfg: TransformerConfig, h: jax.Array, p) -> Tuple[jax.Array, jax.Array]:
    b, s, d = h.shape
    ash = cfg.act_shard
    seq_sp = (P(ash.batch, ash.model, None)
              if ash is not None and ash.seq_shard else None)
    h = h + _attn(cfg, p, L.rmsnorm_apply(p["ln_attn"], h))
    if seq_sp is not None:
        # residual stays sequence-sharded: the TP projection's output
        # reduction becomes a reduce-scatter instead of a full all-reduce
        h = _constrain(h, seq_sp)
    x = L.rmsnorm_apply(p["ln_ffn"], h)
    if cfg.moe:
        moe_fn = (
            _moe_ffn_shardmap
            if cfg.act_shard is not None and cfg.act_shard.mesh is not None
            else _moe_ffn
        )
        y, aux = moe_fn(cfg, p, x.reshape(b * s, d))
        y = y.reshape(b, s, d)
    else:
        y, aux = _dense_ffn(cfg, p, x), jnp.zeros((), jnp.float32)
    out = h + y
    if seq_sp is not None:
        out = _constrain(out, seq_sp)
    return out, aux


def _maybe_precast(cfg: TransformerConfig, params):
    if not cfg.precast_params:
        return params
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(cfg.compute_dtype)
        return x
    return jax.tree.map(cast, params)


def forward(cfg: TransformerConfig, params, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (hidden [B, S, d] in compute dtype, aux_loss)."""
    params = _maybe_precast(cfg, params)
    h = L.embedding_apply(params["embed"], tokens, compute_dtype=cfg.compute_dtype)
    if cfg.act_shard is not None:
        h = _constrain(h, P(cfg.act_shard.batch, None, None))

    body = functools.partial(_layer_body, cfg)
    if cfg.remat:
        body = jax.checkpoint(body)

    ash = cfg.act_shard

    def scan_fn(h, layer_params):
        if ash is not None and ash.seq_shard:
            h = _constrain(h, P(ash.batch, ash.model, None))
        h, aux = body(h, layer_params)
        return h, aux

    h, auxes = jax.lax.scan(scan_fn, h, params["layers"])
    h = L.rmsnorm_apply(params["ln_final"], h)
    return h, auxes.sum()


def loss_fn(cfg: TransformerConfig, params, batch) -> Tuple[jax.Array, dict]:
    """Next-token CE. batch: {tokens [B,S], labels [B,S], mask [B,S]}."""
    h, aux = forward(cfg, params, batch["tokens"])
    head = params["lm_head"]
    labels, mask = batch["labels"], batch["mask"]
    if cfg.loss_chunk and h.shape[1] % cfg.loss_chunk == 0:
        b, s, d = h.shape
        nc = s // cfg.loss_chunk
        hc = h.reshape(b, nc, cfg.loss_chunk, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(b, nc, cfg.loss_chunk).transpose(1, 0, 2)
        mc = mask.reshape(b, nc, cfg.loss_chunk).transpose(1, 0, 2)

        # remat: without it the scan saves every chunk's logits for the
        # backward pass, recreating the full [B, S, V] buffer it exists to
        # avoid (dry-run measured 492 GB/device on smollm before this).
        @jax.checkpoint
        def chunk_nll(hx, lx, mx):
            logits = L.dense_apply(head, hx, compute_dtype=cfg.compute_dtype)
            if cfg.act_shard is not None:
                logits = _constrain(
                    logits, P(cfg.act_shard.batch, None, cfg.act_shard.model)
                )
            logits32 = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(logits32, axis=-1)
            gold = jnp.take_along_axis(logits32, lx[..., None], -1).squeeze(-1)
            return jnp.sum((logz - gold) * mx), jnp.sum(mx)

        def chunk_ce(carry, args):
            tot, cnt = carry
            t, c = chunk_nll(*args)
            return (tot + t, cnt + c), ()

        (tot, cnt), _ = jax.lax.scan(
            chunk_ce, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, lc, mc),
        )
        ce = tot / jnp.maximum(cnt, 1.0)
    else:
        logits = L.dense_apply(head, h, compute_dtype=cfg.compute_dtype)
        if cfg.act_shard is not None:
            logits = _constrain(
                logits, P(cfg.act_shard.batch, None, cfg.act_shard.model)
            )
        ce = L.softmax_cross_entropy(logits, labels, mask)
    loss = ce + aux
    return loss, dict(ce=ce, aux=aux)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_quant:
        sshape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.bfloat16),
            "v_scale": jnp.zeros(sshape, jnp.bfloat16),
            "length": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def _quantize_kv(x: jax.Array):
    """[B, 1, H, hd] -> (int8 values, bf16 per-(token,head) scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.bfloat16)


def decode_step(cfg: TransformerConfig, params, cache, tokens: jax.Array):
    """One decode step. tokens [B, 1] -> (logits [B, 1, V], new cache).

    The cache is scanned alongside the layer stack; each layer writes its
    new K/V at position ``length``.
    """
    b = tokens.shape[0]
    dt = cfg.compute_dtype
    hd = cfg.hd
    length = cache["length"]
    h = L.embedding_apply(params["embed"], tokens, compute_dtype=dt)

    def layer(h, args):
        if cfg.kv_quant:
            p, kc, vc, ks, vs = args
        else:
            p, kc, vc = args
            ks = vs = None
        x = L.rmsnorm_apply(p["ln_attn"], h)
        q = L.dense_apply(p["wq"], x, compute_dtype=dt).reshape(b, 1, cfg.n_heads, hd)
        k = L.dense_apply(p["wk"], x, compute_dtype=dt).reshape(b, 1, cfg.n_kv_heads, hd)
        v = L.dense_apply(p["wv"], x, compute_dtype=dt).reshape(b, 1, cfg.n_kv_heads, hd)
        pos = jnp.broadcast_to(length, (b, 1))
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        if cfg.kv_quant:
            kq, k_sc = _quantize_kv(k)
            vq, v_sc = _quantize_kv(v)
            kc = jax.lax.dynamic_update_slice(kc, kq, (0, length, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, vq, (0, length, 0, 0))
            ks = jax.lax.dynamic_update_slice(ks, k_sc, (0, length, 0))
            vs = jax.lax.dynamic_update_slice(vs, v_sc, (0, length, 0))
            k_deq = kc.astype(dt) * ks[..., None].astype(dt)
            v_deq = vc.astype(dt) * vs[..., None].astype(dt)
        else:
            kc = jax.lax.dynamic_update_slice(
                kc, k.astype(kc.dtype), (0, length, 0, 0)
            )
            vc = jax.lax.dynamic_update_slice(
                vc, v.astype(vc.dtype), (0, length, 0, 0)
            )
            k_deq, v_deq = kc, vc
        o = decode_attention(q, k_deq, v_deq, length + 1,
                             n_kv_heads=cfg.n_kv_heads)
        h = h + L.dense_apply(
            p["wo"], o.reshape(b, 1, cfg.n_heads * hd), compute_dtype=dt
        )
        x2 = L.rmsnorm_apply(p["ln_ffn"], h)
        if cfg.moe:
            moe_fn = (
                _moe_ffn_shardmap
                if cfg.act_shard is not None and cfg.act_shard.mesh is not None
                else _moe_ffn
            )
            y, _ = moe_fn(cfg, p, x2.reshape(b, cfg.d_model))
            y = y.reshape(b, 1, cfg.d_model)
        else:
            y = _dense_ffn(cfg, p, x2)
        if cfg.kv_quant:
            return h + y, (kc, vc, ks, vs)
        return h + y, (kc, vc)

    if cfg.kv_quant:
        h, (nk, nv, nks, nvs) = jax.lax.scan(
            layer, h, (params["layers"], cache["k"], cache["v"],
                       cache["k_scale"], cache["v_scale"])
        )
        new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs,
                     "length": length + 1}
    else:
        h, (nk, nv) = jax.lax.scan(
            layer, h, (params["layers"], cache["k"], cache["v"])
        )
        new_cache = {"k": nk, "v": nv, "length": length + 1}
    h = L.rmsnorm_apply(params["ln_final"], h)
    logits = L.dense_apply(params["lm_head"], h, compute_dtype=dt)
    return logits, new_cache
