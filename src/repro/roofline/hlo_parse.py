"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every computation ONCE — a ``while``
body (every ``lax.scan``: our layer stacks, attention chunks, loss chunks,
grad-accum) is under-counted by its trip count.  Verified in this container:
a scan of 8 matmuls reports 1/8 of the unrolled flops.

This parser rebuilds per-device cost from the post-optimization HLO text:

  1. split the module into computations,
  2. build a symbol table (op -> shape) per computation,
  3. find ``while`` ops, extract trip counts from their condition's integer
     constant, and propagate multipliers ENTRY -> body (nesting multiplies),
  4. FLOPs: ``dot`` ops = 2 * prod(out) * prod(contracted lhs dims); other
     arithmetic ops approximated at 1 flop/output element,
  5. HBM bytes: every materializing op reads operands + writes outputs once
     (fusions = single kernels; parameters/GTE/tuple/bitcast are free) —
     the classic roofline traffic model,
  6. collective bytes: output-shape bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute, x multiplier.

Accuracy contract: exact on matmul-dominated graphs (validated in tests
against analytic flops), approximate on elementwise traffic — consistent
across iterations, which is what §Perf needs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

# one shape like  f32[128,256]{1,0:T(8,128)}  or  s32[]
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

# an op line:  %name = SHAPES opcode(operands...), attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\((.*)$"
)

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->", re.S)

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "copy-start", "copy-done",
    "opt-barrier",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, OpInfo]
    order: List[str]


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _shape_bytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_operands(s: str) -> List[str]:
    """Operand names up to the closing paren at depth 0."""
    names = []
    depth = 0
    cur = []
    for ch in s:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            names.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    names.append("".join(cur))
    out = []
    for n in names:
        m = re.search(r"%([\w.\-]+)", n)
        if m:
            out.append(m.group(1))
    return out


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        op = OpInfo(
            name=name, opcode=opcode,
            out_shapes=_parse_shapes(shape_str),
            operands=_split_operands(rest),
            attrs=rest,
        )
        cur.ops[name] = op
        cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition ~ trip count."""
    best = 1
    for op in cond.ops.values():
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.attrs)
            if m:
                best = max(best, int(m.group(1)))
    # also catch inline fused compare constants
    return best


def computation_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution-count multiplier per computation (ENTRY = 1)."""
    entry = None
    called = set()
    for c in comps.values():
        for op in c.ops.values():
            for m in re.finditer(r"(?:body|condition|calls|to_apply|branch_computations)=\{?%?([\w.\-]+(?:, *%?[\w.\-]+)*)\}?",
                                 op.attrs):
                for nm in re.split(r", *%?", m.group(1)):
                    called.add(nm)
    for name in comps:
        if name not in called and (entry is None or "main" in name):
            entry = name
    if entry is None:
        entry = next(iter(comps))

    mult: Dict[str, float] = {entry: 1.0}
    # BFS from entry
    stack = [entry]
    seen = set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        cmult = mult.get(cname, 1.0)
        for op in comps[cname].ops.values():
            body = re.search(r"body=%?([\w.\-]+)", op.attrs)
            cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
            if op.opcode == "while" and body and cond:
                trips = _trip_count(comps[cond.group(1)]) if cond.group(1) in comps else 1
                for target in (body.group(1), cond.group(1)):
                    mult[target] = max(mult.get(target, 0.0), cmult * trips)
                    stack.append(target)
                continue
            for attr in ("calls", "to_apply"):
                m = re.search(attr + r"=%?([\w.\-]+)", op.attrs)
                if m:
                    mult[m.group(1)] = max(mult.get(m.group(1), 0.0), cmult)
                    stack.append(m.group(1))
            m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
            if m:
                for nm in re.split(r", *%?", m.group(1).replace("%", "")):
                    if nm:
                        mult[nm] = max(mult.get(nm, 0.0), cmult)
                        stack.append(nm)
    return mult


_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "rsqrt", "sqrt", "maximum", "minimum", "compare", "select",
    "negate", "abs", "floor", "ceil", "sign", "cosine", "sine", "and", "or",
    "xor", "not", "exponential-minus-one", "log-plus-one", "logistic",
}


def _dot_flops(op: OpInfo, table: Dict[str, OpInfo]) -> float:
    out_elems = 1
    for _, dims in op.out_shapes:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contract = 1
    if m and op.operands:
        lhs = table.get(op.operands[0])
        if lhs and lhs.out_shapes:
            dims = lhs.out_shapes[0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    n_while_loops: int
    multipliers: Dict[str, float]


def _elems(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _fusion_internal_comps(comps: Dict[str, Computation]) -> set:
    """Computations reachable only as bodies of fusion/reduce/scatter ops:
    their ops execute inside a single kernel — no extra HBM traffic; flops
    of internal dots still counted (at the caller's multiplier)."""
    out = set()
    for comp in comps.values():
        for op in comp.ops.values():
            if op.opcode in ("fusion", "reduce", "scatter", "sort", "map",
                             "reduce-window", "select-and-scatter"):
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs)
                if m:
                    out.add(m.group(1))
    return out


def analyze(text: str) -> HloCost:
    comps = parse_module(text)
    mult = computation_multipliers(comps)
    fused = _fusion_internal_comps(comps)
    flops = 0.0
    hbm = 0.0
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    n_while = 0
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        in_fusion = cname in fused
        for op in comp.ops.values():
            if op.opcode == "while":
                n_while += 1
                continue
            base_kind = op.opcode.replace("-start", "")
            if base_kind in _COLLECTIVES and not op.opcode.endswith("-done"):
                shapes = op.out_shapes
                if op.opcode.endswith("-start") and len(shapes) > 1:
                    shapes = shapes[len(shapes) // 2:]  # (operands, results)
                b = _shape_bytes(shapes)
                coll[base_kind] += m * b
                hbm += m * b
                continue
            if op.opcode in _FREE_OPS:
                continue
            # flops (counted even inside fusions)
            if op.opcode == "dot":
                flops += m * _dot_flops(op, comp.ops)
            elif op.opcode in _ARITH_OPS or op.opcode == "reduce":
                flops += m * _elems(op.out_shapes)
            # HBM traffic: one kernel = read operands + write outputs.
            if in_fusion:
                continue  # charged at the fusion op's call site
            out_b = _shape_bytes(op.out_shapes)
            if op.opcode in ("dynamic-slice", "gather"):
                # reads only the sliced region, not the whole operand
                hbm += m * 2 * out_b
                continue
            if op.opcode == "dynamic-update-slice":
                # in-place read-modify-write of the update region
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                upd_b = _shape_bytes(upd.out_shapes) if upd else out_b
                hbm += m * 2 * upd_b
                continue
            if op.opcode == "scatter":
                upd = comp.ops.get(op.operands[-1]) if op.operands else None
                upd_b = _shape_bytes(upd.out_shapes) if upd else out_b
                hbm += m * 2 * upd_b
                continue
            in_b = 0
            for o in op.operands:
                src = comp.ops.get(o)
                if src is not None:
                    in_b += _shape_bytes(src.out_shapes)
            hbm += m * (out_b + in_b)
    return HloCost(
        flops=flops, hbm_bytes=hbm,
        collective_bytes=sum(coll.values()),
        collective_breakdown=coll,
        n_while_loops=n_while,
        multipliers=mult,
    )
