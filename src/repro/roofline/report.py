"""Roofline report generator: results/dryrun/*.json -> markdown tables.

    PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]

Emits the §Dry-run and §Roofline tables consumed by EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(f))
        r["mesh_tag"] = "multipod" if "multipod" in f else "pod"
        out.append(r)
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def roofline_table(recs: List[dict], mesh_tag: str) -> str:
    rows = [
        "| arch | shape | dominant | compute | memory | collective | "
        "useful-FLOPs | HBM GB | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok") or r["mesh_tag"] != mesh_tag:
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{rf['dominant']}** | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | "
            f"{rf['useful_flops_ratio']:.3f} | "
            f"{r['hbm_used'] / 1e9:.1f} | "
            f"{'yes' if r['hbm_fits'] else 'no*'} |"
        )
    return "\n".join(rows)


def dryrun_table(recs: List[dict]) -> str:
    rows = [
        "| arch | shape | pod compile | multipod compile | per-dev FLOPs | "
        "per-dev HBM bytes | collective bytes |",
        "|---|---|---|---|---|---|---|",
    ]
    by_key: Dict[tuple, dict] = {}
    for r in recs:
        if r.get("ok"):
            by_key[(r["arch"], r["shape"], r["mesh_tag"])] = r
    seen = []
    for (arch, shape, _), r in by_key.items():
        if (arch, shape) in seen:
            continue
        seen.append((arch, shape))
        pod = by_key.get((arch, shape, "pod"))
        mp = by_key.get((arch, shape, "multipod"))
        rf = (pod or mp)["roofline"]
        rows.append(
            f"| {arch} | {shape} | "
            f"{'ok ' + str(pod['seconds']) + 's' if pod else '-'} | "
            f"{'ok ' + str(mp['seconds']) + 's' if mp else '-'} | "
            f"{rf['flops']:.2e} | {rf['hbm_bytes']:.2e} | "
            f"{rf['collective_bytes']:.2e} |"
        )
    return "\n".join(rows)


def summary(recs: List[dict]) -> dict:
    ok = [r for r in recs if r.get("ok")]
    fails = [r for r in recs if not r.get("ok")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    return dict(total=len(recs), ok=len(ok), failed=len(fails),
                dominant_counts=doms)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Summary\n")
    print(json.dumps(summary(recs), indent=1))
    print("\n## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single pod, 16x16)\n")
    print(roofline_table(recs, "pod"))
    print("\n## Roofline (multi-pod, 2x16x16)\n")
    print(roofline_table(recs, "multipod"))


if __name__ == "__main__":
    main()
