"""Roofline analysis from compiled dry-run artifacts."""

from repro.roofline.analysis import (  # noqa: F401
    HW,
    RooflineTerms,
    collective_bytes_from_hlo,
    roofline_from_compiled,
)
