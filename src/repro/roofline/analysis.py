"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory     = HLO_bytes / HBM_bw                (per chip)
    collective = collective_bytes / link_bw        (per chip)

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
flops/bytes.  Collective bytes are not in cost_analysis: we parse the
post-optimization HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e-class hardware constants (per chip), per the assignment.
@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    hbm_bytes: float = 16e9           # capacity, for fit checks


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum *output* shape bytes per collective kind.

    The output shape of a collective is what lands on the wire per device
    (all-gather output = gathered bytes received; all-reduce ~ tensor size;
    reduce-scatter output = reduced shard;
    all-to-all = exchanged buffer).  ``-start``/``-done`` async pairs are
    counted once (on start).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue  # counted at -start
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                   # per-device HLO flops
    hbm_bytes: float               # per-device HLO bytes accessed
    collective_bytes: float        # per-device bytes on the wire
    collective_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    per_device_mem: Optional[dict] = None

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def roofline_from_compiled(
    compiled,
    hlo_text: Optional[str] = None,
    *,
    hw: Hardware = HW,
    model_flops_total: float = 0.0,
    n_devices: int = 1,
) -> RooflineTerms:
    """Build the three terms from a compiled executable.

    Uses the trip-count-aware HLO parser (:mod:`repro.roofline.hlo_parse`) —
    XLA's own cost_analysis counts while-loop bodies once, which undercounts
    every ``lax.scan`` in the framework.  ``model_flops_total`` is the
    *global* useful-model FLOPs per step (6*N*D etc.); divided by
    ``n_devices`` for the per-device ratio.
    """
    from repro.roofline import hlo_parse

    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_parse.analyze(text)
    flops = cost.flops
    hbm = cost.hbm_bytes
    coll = dict(cost.collective_breakdown)
    counts = dict(n_while_loops=cost.n_while_loops)
    coll_bytes = float(cost.collective_bytes)
    # XLA's own (loop-body-once) numbers kept for cross-checking
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    counts["xla_flops_body_once"] = float(ca.get("flops", 0.0))
    counts["xla_bytes_body_once"] = float(ca.get("bytes accessed", 0.0))

    compute_s = flops / hw.peak_flops
    memory_s = hbm / hw.hbm_bw
    collective_s = coll_bytes / hw.ici_bw
    terms = dict(compute=compute_s, memory=memory_s, collective=collective_s)
    dominant = max(terms, key=terms.get)

    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = dict(
                argument_bytes=getattr(ma, "argument_size_in_bytes", None),
                output_bytes=getattr(ma, "output_size_in_bytes", None),
                temp_bytes=getattr(ma, "temp_size_in_bytes", None),
                generated_code_bytes=getattr(
                    ma, "generated_code_size_in_bytes", None),
                alias_bytes=getattr(ma, "alias_size_in_bytes", None),
            )
    except Exception:
        mem = None

    model_flops_dev = model_flops_total / max(n_devices, 1)
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll_bytes,
        collective_breakdown={**coll, "counts": counts},
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops_dev,
        useful_flops_ratio=(model_flops_dev / flops) if flops else 0.0,
        per_device_mem=mem,
    )


def fit_check(terms: RooflineTerms, hw: Hardware = HW) -> Tuple[bool, float]:
    """Does (args + outputs + temps) fit per-chip HBM?"""
    m = terms.per_device_mem or {}
    used = sum(
        v for k, v in m.items()
        if k in ("argument_bytes", "output_bytes", "temp_bytes")
        and isinstance(v, (int, float))
    )
    # alias'd (donated) buffers are counted in both args and outputs
    alias = m.get("alias_bytes") or 0
    used -= alias
    return used <= hw.hbm_bytes, used
