"""Training substrate: optimizer, train-step factory, compression."""

from repro.training.optimizer import AdamState, AdamWConfig  # noqa: F401
from repro.training.train_loop import init_state, make_train_step  # noqa: F401
