"""AdamW + schedules, from scratch (no optax in this environment).

Production details that matter at pod scale:

* **Mixed-precision states**: master weights fp32; first/second moments can
  be stored bf16 (halves optimizer HBM — the difference between grok-314B
  fitting one pod or not).  Error from bf16 moments is second-order; widely
  used (e.g. 8-bit Adam goes further).
* **Global-norm clipping** fused into the update (one psum'd norm).
* **Decoupled weight decay** (AdamW).
* States are plain pytrees so the checkpointer and the sharding policy treat
  them like params (2-D sharded over (data, model) by default).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array          # int32 []
    mu: Any                  # pytree like params (maybe bf16)
    nu: Any                  # pytree like params (maybe bf16)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # Split moment dtypes: mu tolerates fp8 (FP8-LM, arXiv:2310.18313);
    # nu needs more range -> bf16 floor.  Both fp32 by default.
    moment_dtype: Any = jnp.float32   # sets both when mu/nu not given
    mu_dtype: Any = None
    nu_dtype: Any = None
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1

    @property
    def mu_dt(self):
        return self.mu_dtype if self.mu_dtype is not None else self.moment_dtype

    @property
    def nu_dt(self):
        return self.nu_dtype if self.nu_dtype is not None else self.moment_dtype


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init(cfg: AdamWConfig, params: Any) -> AdamState:
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.mu_dt), params),
        nu=jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.nu_dt), params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamState,
    params: Any,
) -> Tuple[Any, AdamState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1.0 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1.0 - cfg.b2) * jnp.square(g)
        upd = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (upd + cfg.weight_decay * p32)
        return (
            p32.astype(p.dtype),
            m32.astype(cfg.mu_dt),
            v32.astype(cfg.nu_dt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [one(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, AdamState(step=step, mu=new_m, nu=new_v), metrics


def sgd_update(params: Any, grads: Any, lr: float) -> Any:
    """Plain SGD (tiny tests / GCN full-batch baselines)."""
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params,
        grads,
    )
