"""Train-step factory: value_and_grad -> (compressed) grads -> AdamW.

Production features:
  * optional micro-batch **gradient accumulation** (scan over microbatches;
    activation memory / grad-noise knob),
  * pluggable **gradient transform** hook (the compression module registers
    bf16 + error-feedback here),
  * metrics (loss, grad-norm, lr) returned every step.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt_mod
from repro.training.optimizer import AdamState, AdamWConfig


def _constrain_tree(tree, pspecs):
    """Guarded with_sharding_constraint (no-op outside a mesh context)."""
    if pspecs is None:
        return tree
    def one(x, spec):
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except (ValueError, RuntimeError, TypeError):
            return x
    return jax.tree.map(one, tree, pspecs)


def make_train_step(
    loss_fn: Callable[[Any, Any], Any],
    opt_cfg: AdamWConfig,
    *,
    grad_transform: Optional[Callable[[Any], Any]] = None,
    microbatches: int = 1,
    accum_dtype=jnp.float32,
    grad_pspecs: Any = None,
):
    """loss_fn(params, batch) -> scalar or (scalar, metrics dict).

    ``grad_pspecs``: PartitionSpec tree matching params.  Without it the
    grad-accumulation buffer is unsharded and GSPMD replicates it — every
    microbatch then ALL-REDUCES full per-layer gradients (measured 6.4 TB
    per step on command-r) instead of reduce-scattering 1/16th.
    """

    def scalar_loss(params, batch):
        out = loss_fn(params, batch)
        if isinstance(out, tuple):
            return out[0], out[1]
        return out, {}

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(scalar_loss, has_aux=True)(
            params, batch
        )
        return loss, aux, grads

    def train_step(params, opt_state: AdamState, batch):
        if microbatches > 1:
            def mb(carry, micro):
                loss_acc, grad_acc = carry
                loss, _, grads = grads_of(params, micro)
                grad_acc = jax.tree.map(
                    lambda a, g: (a.astype(jnp.float32)
                                  + g.astype(jnp.float32)).astype(a.dtype),
                    grad_acc, grads,
                )
                return (loss_acc + loss, grad_acc), ()

            micro = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )
            zero = _constrain_tree(
                jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                             params),
                grad_pspecs,
            )
            (loss, grads), _ = jax.lax.scan(
                mb, (jnp.zeros((), jnp.float32), zero), micro
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            aux = {}
        else:
            loss, aux, grads = grads_of(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_state, om = opt_mod.update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(loss=loss, **{k: v for k, v in aux.items()}, **om)
        return new_params, new_state, metrics

    return train_step


def init_state(opt_cfg: AdamWConfig, params) -> AdamState:
    return opt_mod.init(opt_cfg, params)
