"""Contract auditor CLI: ``python -m repro.analysis [--only RULE] [--json]``.

Exit code is nonzero on any unsuppressed finding.  The environment is
prepared *before* jax is imported: the ``no-replicated-index`` rule needs
a multi-device mesh to be meaningful (with one device a shard's legal
block IS ``[n, L]``), so the runner forces a 4-way host-platform split the
same way ``tests/dist_engine_check.py`` does.
"""

from __future__ import annotations

import argparse
import os
import sys


def _prepare_env() -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4"
        ).strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Run the performance/determinism contract auditor.",
    )
    parser.add_argument(
        "--only", action="append", metavar="RULE",
        help="run only this rule (repeatable); default: all rules",
    )
    parser.add_argument(
        "--json", action="store_true", help="emit the JSON report"
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="list known rules and exit",
    )
    args = parser.parse_args(argv)

    _prepare_env()
    # Deferred: rules imports jax (and traces kernels); env must be set first.
    from repro.analysis import report as report_mod
    from repro.analysis import rules as rules_mod

    if args.list_rules:
        for name, runner in rules_mod.RULES.items():
            print(name)
        return 0

    results = rules_mod.run_rules(only=args.only)
    if args.json:
        print(report_mod.render_json(results))
    else:
        print(report_mod.render_text(results))
    return report_mod.exit_code(results)


if __name__ == "__main__":
    sys.exit(main())
