"""The contract-rule catalog: what the auditor checks and where.

Jaxpr rules run over *registered entry points* — kernel and build modules
call :func:`repro.analysis.registry.register_entry_point` at import time
with a lazy spec builder, and importing the modules in ``_HOOK_MODULES``
below is what populates the registry.  Lint rules run over explicit module
scope lists (the "hot-path allowlist" &c.), resolved relative to
``src/repro``.

Spec schemas returned by entry-point ``build()`` thunks (any builder may
instead return ``{"skip": reason}``):

    hbm-residency        {"fn", "args", "kwargs"?, "hbm_shapes", "vmem_budget"}
    no-replicated-index  {"jaxpr", "n", "l"}
    dense-state-bound    {"jaxpr", "budget", "floor"}
    retrace-guard        {"jit_fn", "widths", "variants", "call"}
"""

from __future__ import annotations

import dataclasses
import importlib
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

from repro.analysis import jaxpr as jx
from repro.analysis import lint
from repro.analysis.registry import Finding, entry_points

# Importing these modules registers the traced entry points the jaxpr
# rules audit (each module's registration block sits at its bottom).
_HOOK_MODULES = (
    "repro.kernels.frontier_push",
    "repro.kernels.index_combine",
    "repro.kernels.walk_step",
    "repro.core.index",
    "repro.core.query",
    "repro.core.distributed_engine",
)

_SRC_REPRO = Path(__file__).resolve().parents[1]   # .../src/repro

# Hot-path allowlist for the host-sync rule: dispatch and harvest code
# where one stray sync serializes the whole pipeline.
HOST_SYNC_SCOPE = (
    "serving/pipeline.py",
    "serving/engine.py",
    "core/query.py",
    "core/verd.py",
    "core/walks.py",
)

# Build/repair code where RNG keys must stay positional for bitwise
# resume (PR 9) and bitwise repair (PR 8).
RNG_SCOPE = (
    "core/index.py",
    "core/walks.py",
    "core/updates.py",
    "core/distributed_engine.py",
    "distributed/checkpoint.py",
)

# Modules allowed to read wall clocks / global randomness: the load
# generator exists to model wall-clock arrival processes.
BARE_TIME_EXEMPT = ("serving/loadgen.py",)


def load_entry_points() -> None:
    for mod in _HOOK_MODULES:
        importlib.import_module(mod)


@dataclasses.dataclass
class RuleResult:
    rule: str
    kind: str                     # "jaxpr" | "lint"
    description: str
    findings: List[Finding]
    skipped: List[str] = dataclasses.field(default_factory=list)
    audited: List[str] = dataclasses.field(default_factory=list)

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def status(self) -> str:
        if self.unsuppressed:
            return "FAIL"
        if not self.audited and self.skipped:
            return "SKIP"
        return "PASS"


def _anchor_for(module: str) -> str:
    return module


# -- jaxpr rules -------------------------------------------------------------

def _run_hbm_residency() -> RuleResult:
    res = RuleResult(
        rule="hbm-residency", kind="jaxpr",
        description="CSR / [n, L] index operands stay HBM-resident "
                    "(memory_space=ANY) in every Pallas kernel; VMEM blocks "
                    "respect the per-tile budget",
        findings=[],
    )
    for ep in entry_points("hbm-residency"):
        spec = ep.build()
        if "skip" in spec:
            res.skipped.append(f"{ep.name}: {spec['skip']}")
            continue
        blocks = jx.pallas_block_specs(
            spec["fn"], *spec.get("args", ()), **spec.get("kwargs", {})
        )
        res.findings.extend(jx.hbm_contract_findings(
            blocks,
            hbm_shapes=spec["hbm_shapes"],
            vmem_budget=spec["vmem_budget"],
            anchor=_anchor_for(ep.module),
        ))
        res.audited.append(ep.name)
    return res


def _run_no_replicated_index() -> RuleResult:
    res = RuleResult(
        rule="no-replicated-index", kind="jaxpr",
        description="no per-device array >= [n, L] inside the sharded "
                    "build's shard_map bodies (the index must stay "
                    "model-sharded, never replicated)",
        findings=[],
    )
    for ep in entry_points("no-replicated-index"):
        spec = ep.build()
        if "skip" in spec:
            res.skipped.append(f"{ep.name}: {spec['skip']}")
            continue
        res.findings.extend(jx.replicated_index_findings(
            spec["jaxpr"], n=spec["n"], l=spec["l"],
            anchor=_anchor_for(ep.module),
        ))
        res.audited.append(ep.name)
    return res


def _run_dense_state_bound() -> RuleResult:
    res = RuleResult(
        rule="dense-state-bound", kind="jaxpr",
        description="no f32[rows, n] intermediate in the sparse walk chunk "
                    "and no f32[Q, n] in the sparse query path (budget must "
                    "stay below the dense floor)",
        findings=[],
    )
    for ep in entry_points("dense-state-bound"):
        spec = ep.build()
        if "skip" in spec:
            res.skipped.append(f"{ep.name}: {spec['skip']}")
            continue
        res.findings.extend(jx.dense_state_findings(
            spec["jaxpr"], budget=spec["budget"], floor=spec["floor"],
            anchor=_anchor_for(ep.module),
        ))
        res.audited.append(ep.name)
    return res


def _run_retrace_guard() -> RuleResult:
    res = RuleResult(
        rule="retrace-guard", kind="jaxpr",
        description="jitted serving entry points compile exactly one cache "
                    "entry per bucketed pad width (no weak-type/dtype "
                    "retraces)",
        findings=[],
    )
    for ep in entry_points("retrace-guard"):
        spec = ep.build()
        if "skip" in spec:
            res.skipped.append(f"{ep.name}: {spec['skip']}")
            continue
        jit_fn = spec["jit_fn"]
        if not (hasattr(jit_fn, "_clear_cache")
                and hasattr(jit_fn, "_cache_size")):
            res.skipped.append(
                f"{ep.name}: jit function exposes no cache introspection "
                f"on this jax version"
            )
            continue
        widths: Sequence[int] = spec["widths"]
        variants: int = spec.get("variants", 1)
        call: Callable[[int, int], None] = spec["call"]
        jit_fn._clear_cache()
        for width in widths:
            for variant in range(variants):
                call(width, variant)
        n_entries = jit_fn._cache_size()
        if n_entries != len(widths):
            res.findings.append(Finding(
                rule="retrace-guard", file=_anchor_for(ep.module), line=0,
                message=f"{ep.name}: {n_entries} compile-cache entries for "
                        f"{len(widths)} pad-width buckets {list(widths)} "
                        f"x {variants} input spellings — a width or input "
                        f"spelling is retracing",
            ))
        res.audited.append(ep.name)
    return res


# -- lint rules --------------------------------------------------------------

def _lint_paths(scope: Sequence[str]) -> List[Path]:
    return [_SRC_REPRO / rel for rel in scope]


def _run_lint_rule(rule: str, description: str,
                   paths: Sequence[Path]) -> RuleResult:
    res = RuleResult(rule=rule, kind="lint", description=description,
                     findings=[])
    for path in paths:
        anchor = "src/repro/" + str(path.relative_to(_SRC_REPRO))
        if not path.exists():
            res.skipped.append(f"{anchor}: file not found")
            continue
        res.findings.extend(lint.lint_file(path, anchor, [rule]))
        res.audited.append(anchor)
    return res


def _run_host_sync() -> RuleResult:
    return _run_lint_rule(
        lint.HOST_SYNC,
        "no host syncs (float()/bool() on device values, .item(), "
        "np.asarray, block_until_ready, device truthiness) in hot "
        "dispatch/harvest modules",
        _lint_paths(HOST_SYNC_SCOPE),
    )


def _run_rng_discipline() -> RuleResult:
    return _run_lint_rule(
        lint.RNG_DISCIPLINE,
        "build/repair RNG keys stay positional: no split() stored into "
        "mutable state, no fold_in with non-literal non-offset data",
        _lint_paths(RNG_SCOPE),
    )


def _run_bare_time() -> RuleResult:
    paths = [
        p for p in sorted(_SRC_REPRO.rglob("*.py"))
        if str(p.relative_to(_SRC_REPRO)) not in BARE_TIME_EXEMPT
    ]
    return _run_lint_rule(
        lint.BARE_TIME,
        "no bare time.time() / stdlib random.* outside loadgen and "
        "benchmarks",
        paths,
    )


RULES: Dict[str, Callable[[], RuleResult]] = {
    "hbm-residency": _run_hbm_residency,
    "no-replicated-index": _run_no_replicated_index,
    "dense-state-bound": _run_dense_state_bound,
    "retrace-guard": _run_retrace_guard,
    "host-sync": _run_host_sync,
    "rng-discipline": _run_rng_discipline,
    "bare-time": _run_bare_time,
}


def run_rules(only: Optional[Sequence[str]] = None) -> List[RuleResult]:
    """Run the catalog (or the ``only`` subset) and return per-rule results.

    Jaxpr entry points are loaded first; lint rules need no tracing and run
    even when jax-level tracing is unavailable.
    """
    names = list(RULES) if not only else list(only)
    unknown = [n for n in names if n not in RULES]
    if unknown:
        raise ValueError(
            f"unknown rule(s) {unknown}; known: {sorted(RULES)}"
        )
    if any(RULES[n] in (_run_hbm_residency, _run_no_replicated_index,
                        _run_dense_state_bound, _run_retrace_guard)
           for n in names):
        load_entry_points()
    return [RULES[name]() for name in names]
