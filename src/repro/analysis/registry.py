"""Entry-point registry for the contract auditor.

This module is deliberately dependency-light (stdlib only): kernel and
core modules import it at definition time to register the traced entry
points the jaxpr rules audit, and pulling in jax/numpy here would make
every kernel import pay for the analyzer.  The heavy work lives in the
``build`` thunks, which run only when a rule executes.

An :class:`EntryPoint` names *one traced program* a rule audits — e.g.
"the frontier_push pallas_call on a tiny synthetic graph".  ``build()``
returns a rule-specific spec dict (see ``analysis/rules.py`` for the
schema each rule expects) or ``{"skip": reason}`` when the check cannot
run in this process (e.g. ``no-replicated-index`` with one device).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or suppressed would-be violation)."""

    rule: str
    file: str            # repo-relative path anchor
    line: int            # 1-based; 0 = whole-file / traced-program finding
    message: str
    suppressed: bool = False
    justification: Optional[str] = None

    def anchor(self) -> str:
        return f"{self.file}:{self.line}" if self.line else self.file

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """A traced program registered for auditing under one jaxpr rule."""

    name: str                        # unique within the rule, e.g. "frontier-push"
    rule: str                        # rule id, e.g. "hbm-residency"
    module: str                      # repo-relative anchor file for findings
    build: Callable[[], Dict[str, Any]]  # lazy spec builder (may return {"skip": ...})


_ENTRY_POINTS: List[EntryPoint] = []


def register_entry_point(
    name: str,
    rule: str,
    module: str,
    build: Callable[[], Dict[str, Any]],
) -> EntryPoint:
    """Register a traced entry point; idempotent per (rule, name) so module
    reloads (pytest importmode quirks) don't double-register."""
    ep = EntryPoint(name=name, rule=rule, module=module, build=build)
    for i, existing in enumerate(_ENTRY_POINTS):
        if existing.rule == rule and existing.name == name:
            _ENTRY_POINTS[i] = ep
            return ep
    _ENTRY_POINTS.append(ep)
    return ep


def entry_points(rule: Optional[str] = None) -> List[EntryPoint]:
    if rule is None:
        return list(_ENTRY_POINTS)
    return [ep for ep in _ENTRY_POINTS if ep.rule == rule]


def clear_entry_points() -> None:
    """Test hook: reset the registry (fixtures register throwaway entries)."""
    _ENTRY_POINTS.clear()
