"""Jaxpr auditing primitives: recursive equation iteration, pallas_call
block-spec extraction, and the contract predicates behind the jaxpr rules.

This generalizes what ``tests/jaxpr_utils.py`` + per-suite helpers used to
hand-roll (``tests/test_kernels.py::_pallas_block_specs`` etc.) into one
importable engine, so the kernel contract logic cannot drift across
copies.  Functions here return :class:`~repro.analysis.registry.Finding`
lists (for the runner) with thin ``assert_*`` wrappers (for pytest).

Memory-space vocabulary (TPU Pallas on jax 0.4.x): a block mapping whose
``transformed_block_aval.memory_space`` stringifies to ``"any"`` stays in
HBM and is DMA'd manually by the kernel; anything else (``None`` = default
VMEM) is staged into VMEM by the pipeline — which is exactly what the
CSR / ``[n, L]`` index operands must never do.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.core as jcore

from repro.analysis.registry import Finding

Jaxpr = Any          # jax.core.Jaxpr (kept loose across jax versions)
BlockSpecs = List[Tuple[Tuple[Optional[int], ...], str]]


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Yield every equation in ``jaxpr``, recursing into sub-jaxprs held in
    equation params (pjit bodies, scan/while bodies, shard_map bodies...).
    Accepts an open or closed jaxpr."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                if isinstance(u, jcore.ClosedJaxpr):
                    yield from iter_eqns(u.jaxpr)
                elif isinstance(u, jcore.Jaxpr):
                    yield from iter_eqns(u)


def iter_outvars(jaxpr) -> Iterator[Tuple[Any, Any]]:
    """Yield ``(eqn, outvar)`` for every output var of every (nested) eqn —
    the provenance stream the dense-state rules scan for oversized arrays."""
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            yield eqn, var


def subjaxprs_of(jaxpr, primitive_name: str) -> List[Any]:
    """All sub-jaxprs belonging to equations of ``primitive_name`` (e.g.
    ``"shard_map"`` bodies: what runs *per device*)."""
    found: List[Any] = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != primitive_name:
            continue
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                if isinstance(u, jcore.ClosedJaxpr):
                    found.append(u.jaxpr)
                elif isinstance(u, jcore.Jaxpr):
                    found.append(u)
    return found


def pallas_block_specs(fn, *args, **kwargs) -> BlockSpecs:
    """Trace ``fn(*args, **kwargs)`` and return every pallas_call operand /
    result block as ``(block_shape, memory_space_str)``.

    ``memory_space_str`` is ``"any"`` for HBM-resident operands the kernel
    DMAs manually, ``"None"`` for pipeline-staged VMEM blocks.
    """
    jaxpr = jax.make_jaxpr(functools.partial(fn, **kwargs))(*args)
    blocks: BlockSpecs = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        for bm in gm.block_mappings:
            aval = bm.transformed_block_aval
            blocks.append((tuple(bm.block_shape), str(aval.memory_space)))
    return blocks


def _block_elems(shape: Sequence[Optional[int]]) -> int:
    n = 1
    for d in shape:
        if isinstance(d, int):
            n *= d
    return n


def hbm_contract_findings(
    blocks: BlockSpecs,
    *,
    hbm_shapes: Iterable[Tuple[int, ...]],
    vmem_budget: int,
    rule: str = "hbm-residency",
    anchor: str = "",
) -> List[Finding]:
    """The kernel memory contract as findings:

    1. every shape in ``hbm_shapes`` must appear among the blocks with
       memory space ``"any"`` (HBM-resident, kernel-managed DMA);
    2. no ``hbm_shapes`` block may be staged into VMEM;
    3. every VMEM-staged block must hold <= ``vmem_budget`` elements.
    """
    findings: List[Finding] = []
    if not blocks:
        findings.append(Finding(
            rule=rule, file=anchor, line=0,
            message="no pallas_call found in traced entry point "
                    "(kernel contract cannot be audited)",
        ))
        return findings
    wanted = [tuple(s) for s in hbm_shapes]
    hbm_resident = [shape for shape, space in blocks if space == "any"]
    for shape in wanted:
        if shape not in hbm_resident:
            findings.append(Finding(
                rule=rule, file=anchor, line=0,
                message=f"operand block {shape} is not HBM-resident "
                        f"(expected memory_space=ANY; got blocks {blocks})",
            ))
    for shape, space in blocks:
        if space == "any":
            continue
        if tuple(shape) in wanted:
            findings.append(Finding(
                rule=rule, file=anchor, line=0,
                message=f"contract block {tuple(shape)} lowered into VMEM "
                        f"(memory_space={space!r}); must stay in HBM",
            ))
            continue
        elems = _block_elems(shape)
        if elems > vmem_budget:
            findings.append(Finding(
                rule=rule, file=anchor, line=0,
                message=f"VMEM block {tuple(shape)} holds {elems} elements, "
                        f"over the per-tile budget {vmem_budget}",
            ))
    return findings


def assert_hbm_contract(
    blocks: BlockSpecs,
    *,
    hbm_shapes: Iterable[Tuple[int, ...]],
    vmem_budget: int,
) -> None:
    """Pytest front door: raise AssertionError on any contract violation."""
    findings = hbm_contract_findings(
        blocks, hbm_shapes=hbm_shapes, vmem_budget=vmem_budget
    )
    if findings:
        raise AssertionError(
            "HBM residency contract violated:\n  "
            + "\n  ".join(f.message for f in findings)
        )


def replicated_index_findings(
    jaxpr,
    *,
    n: int,
    l: int,
    rule: str = "no-replicated-index",
    anchor: str = "",
) -> List[Finding]:
    """Scan every shard_map body (the per-device program) for an array of
    shape ``[..., >=n, >=l]`` — a replicated full-index block that would
    erase the sharded build's memory asymptotics.  ``n`` is the *global*
    vertex count; a legal per-shard block is ``[n/ep, L]``-sized."""
    findings: List[Finding] = []
    bodies = subjaxprs_of(jaxpr, "shard_map")
    if not bodies:
        findings.append(Finding(
            rule=rule, file=anchor, line=0,
            message="traced build step contains no shard_map "
                    "(sharded-build contract cannot be audited)",
        ))
        return findings
    for body in bodies:
        for eqn, var in iter_outvars(body):
            aval = var.aval
            shape = getattr(aval, "shape", ())
            if len(shape) < 2:
                continue
            if shape[-2] >= n and shape[-1] >= l:
                findings.append(Finding(
                    rule=rule, file=anchor, line=0,
                    message=f"per-device array {tuple(shape)} "
                            f"(primitive {eqn.primitive.name!r}) covers the "
                            f"full [{n}, {l}] index — replicated, not sharded",
                ))
    return findings


def assert_no_replicated_index(jaxpr, *, n: int, l: int) -> None:
    findings = replicated_index_findings(jaxpr, n=n, l=l)
    if findings:
        raise AssertionError(
            "replicated-index contract violated:\n  "
            + "\n  ".join(f.message for f in findings)
        )


def dense_state_findings(
    jaxpr,
    *,
    budget: int,
    floor: int,
    rule: str = "dense-state-bound",
    anchor: str = "",
    dtype_name: str = "float32",
) -> List[Finding]:
    """Flag any intermediate ``dtype_name`` array over ``budget`` elements.

    ``floor`` is the dense-state size the sparse path exists to avoid
    (``rows * n`` / ``Q * n``); the rule demands ``budget < floor`` so a
    budget inflation can never silently re-admit dense state ("teeth").
    """
    findings: List[Finding] = []
    if budget >= floor:
        findings.append(Finding(
            rule=rule, file=anchor, line=0,
            message=f"budget {budget} >= dense floor {floor}: the bound has "
                    f"no teeth (would admit a dense [rows, n] intermediate)",
        ))
        return findings
    for eqn, var in iter_outvars(jaxpr):
        aval = var.aval
        dt = getattr(aval, "dtype", None)
        if dt is None or dt.name != dtype_name:
            continue
        size = int(getattr(aval, "size", 0))
        if size > budget:
            findings.append(Finding(
                rule=rule, file=anchor, line=0,
                message=f"{dtype_name}{list(aval.shape)} intermediate "
                        f"({size} elements, primitive "
                        f"{eqn.primitive.name!r}) exceeds the sparse-state "
                        f"budget {budget} (dense floor {floor})",
            ))
    return findings


def assert_dense_state_bound(jaxpr, *, budget: int, floor: int) -> None:
    findings = dense_state_findings(jaxpr, budget=budget, floor=floor)
    if findings:
        raise AssertionError(
            "dense-state-bound contract violated:\n  "
            + "\n  ".join(f.message for f in findings)
        )
