"""Contract auditor: static analysis that enforces the performance and
determinism invariants the scaling PRs rest on.

Two engines under one rule registry:

* **jaxpr auditor** (:mod:`repro.analysis.jaxpr`) — traces registered
  entry points on tiny synthetic graphs and checks the traced program:
  ``hbm-residency``, ``no-replicated-index``, ``dense-state-bound``,
  ``retrace-guard``.
* **AST lint** (:mod:`repro.analysis.lint`) — parses hot-path modules for
  contracts tracing can't see: ``host-sync``, ``rng-discipline``,
  ``bare-time``.

Run with ``python -m repro.analysis`` (``make lint-contracts``); suppress
an intentional violation in source with
``# contract: allow(<rule>): <justification>``.  See
``docs/static_analysis.md`` for the rule catalog and how to register a
new entry point.

This package root stays import-light (registry only): kernel modules
import :mod:`repro.analysis.registry` at definition time to register
their entry points, and must not pay for (or cycle into) the rule
implementations, which import the kernels back.
"""

from repro.analysis.registry import (    # noqa: F401
    EntryPoint,
    Finding,
    clear_entry_points,
    entry_points,
    register_entry_point,
)

__all__ = [
    "EntryPoint",
    "Finding",
    "clear_entry_points",
    "entry_points",
    "register_entry_point",
]
