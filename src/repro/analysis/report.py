"""Findings report: text and JSON renderings of a rule run.

The text report is what ``make lint-contracts`` prints; the JSON form
(``--json``) is stable enough for CI annotation (one object per rule,
findings carry repo-relative ``file:line`` anchors and the suppression
justification when present).
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Sequence

from repro.analysis.rules import RuleResult


def exit_code(results: Sequence[RuleResult]) -> int:
    """Nonzero iff any rule has an unsuppressed finding.  SKIPs do not fail
    the run (they are environment limits, e.g. a 1-device process for
    ``no-replicated-index``) but are always surfaced in the report."""
    return 1 if any(r.unsuppressed for r in results) else 0


def render_text(results: Sequence[RuleResult]) -> str:
    lines: List[str] = ["contract auditor — repro.analysis", ""]
    for r in results:
        n_sup = sum(1 for f in r.findings if f.suppressed)
        head = f"[{r.status}] {r.rule} ({r.kind})"
        if r.audited:
            head += f" — {len(r.audited)} target(s)"
        if n_sup:
            head += f", {n_sup} suppressed"
        lines.append(head)
        for f in r.unsuppressed:
            lines.append(f"    FINDING {f.anchor()}: {f.message}")
        for f in r.findings:
            if f.suppressed:
                lines.append(
                    f"    allowed {f.anchor()}: {f.justification}"
                )
        for s in r.skipped:
            lines.append(f"    skipped {s}")
    total = sum(len(r.unsuppressed) for r in results)
    lines.append("")
    lines.append(
        f"{total} unsuppressed finding(s) across {len(results)} rule(s)"
    )
    return "\n".join(lines)


def render_json(results: Sequence[RuleResult]) -> str:
    payload: List[Dict[str, Any]] = []
    for r in results:
        payload.append(dict(
            rule=r.rule,
            kind=r.kind,
            status=r.status,
            description=r.description,
            audited=list(r.audited),
            skipped=list(r.skipped),
            findings=[f.to_json() for f in r.findings],
        ))
    return json.dumps(
        dict(results=payload, exit_code=exit_code(results)), indent=2
    )
