"""AST lint engine: host-sync, RNG-discipline, and bare-time rules.

These are *textual* contracts that jaxpr tracing can't see — a
``float(device_value)`` host sync never shows up in a jaxpr (it happens at
dispatch), and reusing an RNG key traces fine but silently breaks bitwise
resume/repair.  The engine parses each module once, collects candidate
violations per rule, then applies the suppression contract:

    some_host_sync()  # contract: allow(host-sync): harvested post-is_ready

A suppression must name the rule AND carry a non-empty justification after
the colon; an allow() with no justification is itself reported (and the
finding stays unsuppressed).  Suppression comments attach to the flagged
line or the line directly above it.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.registry import Finding

HOST_SYNC = "host-sync"
RNG_DISCIPLINE = "rng-discipline"
BARE_TIME = "bare-time"

LINT_RULES = (HOST_SYNC, RNG_DISCIPLINE, BARE_TIME)

_ALLOW_RE = re.compile(
    r"#\s*contract:\s*allow\(([A-Za-z0-9_-]+)\)\s*(?::\s*(.*?))?\s*$"
)


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted path of a Name/Attribute chain ('' otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _root_name(node: ast.AST) -> str:
    dotted = _dotted(node)
    return dotted.split(".", 1)[0] if dotted else ""


def _is_device_rooted(node: ast.AST) -> bool:
    """Heuristic: an expression whose call/attr chain roots at jnp/jax/lax
    produces a device array — truthiness on it forces a host sync."""
    if isinstance(node, ast.Call):
        return _root_name(node.func) in ("jnp", "jax", "lax")
    return _root_name(node) in ("jnp", "lax")


class _Hit:
    __slots__ = ("rule", "line", "message")

    def __init__(self, rule: str, line: int, message: str):
        self.rule = rule
        self.line = line
        self.message = message


class _Visitor(ast.NodeVisitor):
    def __init__(self, rules: Sequence[str], imports_stdlib_random: bool):
        self.rules = set(rules)
        self.imports_stdlib_random = imports_stdlib_random
        self.hits: List[_Hit] = []

    def _hit(self, rule: str, node: ast.AST, message: str) -> None:
        if rule in self.rules:
            self.hits.append(_Hit(rule, getattr(node, "lineno", 0), message))

    # -- host-sync -----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        dotted = _dotted(func)
        if isinstance(func, ast.Name) and func.id == "float" and node.args:
            if not isinstance(node.args[0], ast.Constant):
                self._hit(HOST_SYNC, node,
                          "float() on a runtime value blocks on the device "
                          "stream when the value is a jax.Array")
        elif isinstance(func, ast.Name) and func.id == "bool" and node.args:
            if any(_is_device_rooted(a) for a in node.args):
                self._hit(HOST_SYNC, node,
                          "bool() of a device expression forces a host sync")
        elif isinstance(func, ast.Attribute) and func.attr == "item":
            self._hit(HOST_SYNC, node,
                      ".item() materializes a device scalar on the host")
        elif isinstance(func, ast.Attribute) and func.attr == "block_until_ready":
            self._hit(HOST_SYNC, node,
                      "block_until_ready() stalls the dispatch thread")
        elif dotted in ("np.asarray", "np.array", "numpy.asarray",
                        "numpy.array"):
            self._hit(HOST_SYNC, node,
                      f"{dotted}() copies device memory to the host when fed "
                      f"a jax.Array")
        elif dotted in ("jax.device_get", "device_get"):
            self._hit(HOST_SYNC, node, "jax.device_get() is a blocking "
                                       "device-to-host transfer")
        # -- rng-discipline: fold_in with non-positional second arg ---------
        if dotted.endswith("random.fold_in") or dotted == "fold_in":
            if len(node.args) >= 2 and not self._positional_arg(node.args[1]):
                self._hit(RNG_DISCIPLINE, node,
                          "fold_in() data argument is not a literal/offset "
                          "expression — per-chunk keys must be positional "
                          "(chunk id / offset) for bitwise resume and repair")
        # -- bare-time -------------------------------------------------------
        if dotted == "time.time":
            self._hit(BARE_TIME, node,
                      "time.time() in library code makes runs wall-clock "
                      "dependent; inject a clock or use loadgen timing")
        elif (self.imports_stdlib_random
              and _root_name(func) == "random"
              and isinstance(func, ast.Attribute)):
            self._hit(BARE_TIME, node,
                      f"stdlib {dotted}() draws unseeded global randomness; "
                      f"use jax.random with a positional key")
        self.generic_visit(node)

    @staticmethod
    def _positional_arg(node: ast.AST) -> bool:
        """Is a fold_in data argument 'positional' — a literal, a named
        offset, or arithmetic over those?  Device-coordinate calls
        (``jax.lax.axis_index``) count: they are positional by construction.
        """
        if isinstance(node, (ast.Constant, ast.Name, ast.Attribute)):
            return True
        if isinstance(node, ast.BinOp):
            return (_Visitor._positional_arg(node.left)
                    and _Visitor._positional_arg(node.right))
        if isinstance(node, ast.Call):
            return _dotted(node.func).endswith("axis_index")
        return False

    # -- host-sync: array truthiness ----------------------------------------
    def _check_truthiness(self, test: ast.AST) -> None:
        queue = [test]
        while queue:
            node = queue.pop()
            if isinstance(node, ast.BoolOp):
                queue.extend(node.values)
            elif isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
                queue.append(node.operand)
            elif _is_device_rooted(node):
                self._hit(HOST_SYNC, node,
                          "truthiness of a device expression in a branch "
                          "condition forces a host sync")

    def visit_If(self, node: ast.If) -> None:
        self._check_truthiness(node.test)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_truthiness(node.test)
        self.generic_visit(node)

    # -- rng-discipline: split stored into mutable state --------------------
    def _check_key_store(self, targets: Sequence[ast.AST],
                         value: ast.AST, node: ast.AST) -> None:
        if not any(isinstance(t, (ast.Attribute, ast.Subscript))
                   for t in self._flatten_targets(targets)):
            return
        for sub in ast.walk(value):
            if isinstance(sub, ast.Call):
                dotted = _dotted(sub.func)
                if dotted.endswith("random.split") or dotted == "split":
                    self._hit(
                        RNG_DISCIPLINE, node,
                        "jax.random.split result stored into mutable state — "
                        "build/repair keys must derive positionally "
                        "(fold_in(base, chunk)) so resume replays bitwise")

    @staticmethod
    def _flatten_targets(targets: Sequence[ast.AST]) -> List[ast.AST]:
        flat: List[ast.AST] = []
        queue = list(targets)
        while queue:
            t = queue.pop()
            if isinstance(t, (ast.Tuple, ast.List)):
                queue.extend(t.elts)
            else:
                flat.append(t)
        return flat

    def visit_Assign(self, node: ast.Assign) -> None:
        self._check_key_store(node.targets, node.value, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_key_store([node.target], node.value, node)
        self.generic_visit(node)


def parse_suppressions(source: str) -> Dict[int, Tuple[str, str]]:
    """Map line number -> (rule, justification) for every allow() comment."""
    out: Dict[int, Tuple[str, str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = (m.group(1), (m.group(2) or "").strip())
    return out


def lint_source(
    source: str,
    anchor: str,
    rules: Sequence[str],
) -> List[Finding]:
    """Lint one module's source under ``rules``; ``anchor`` is the
    repo-relative path stamped on findings."""
    tree = ast.parse(source, filename=anchor)
    imports_random = any(
        (isinstance(n, ast.Import)
         and any(a.name == "random" for a in n.names))
        or (isinstance(n, ast.ImportFrom) and n.module == "random")
        for n in ast.walk(tree)
    )
    visitor = _Visitor(rules, imports_random)
    visitor.visit(tree)
    suppressions = parse_suppressions(source)
    src_lines = source.splitlines()
    findings: List[Finding] = []
    for hit in visitor.hits:
        sup: Optional[Tuple[str, str]] = None
        # the flagged line itself, then upward through the contiguous
        # comment block directly above it (multi-line justifications)
        candidates = [hit.line]
        line = hit.line - 1
        while 1 <= line <= len(src_lines) and \
                src_lines[line - 1].lstrip().startswith("#"):
            candidates.append(line)
            line -= 1
        for line in candidates:
            entry = suppressions.get(line)
            if entry and entry[0] == hit.rule:
                sup = entry
                break
        if sup is None:
            findings.append(Finding(
                rule=hit.rule, file=anchor, line=hit.line,
                message=hit.message,
            ))
        elif not sup[1]:
            findings.append(Finding(
                rule=hit.rule, file=anchor, line=hit.line,
                message=f"{hit.message} [allow({hit.rule}) present but "
                        f"missing the required justification text]",
            ))
        else:
            findings.append(Finding(
                rule=hit.rule, file=anchor, line=hit.line,
                message=hit.message, suppressed=True, justification=sup[1],
            ))
    return findings


def lint_file(path: Path, anchor: str, rules: Sequence[str]) -> List[Finding]:
    return lint_source(path.read_text(), anchor, rules)
