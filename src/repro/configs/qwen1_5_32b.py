"""qwen1.5-32b [dense]: 64L d5120 40H(kv40, MHA) ff27392 vocab152064, QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf].  40 heads don't divide the 16-way model axis;
attention shards with GSPMD padding (40 -> 48 virtual head slots), while the
ff dim (27392 = 16*1712) and vocab (152064 = 16*9504) shard exactly.
"""

import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ArchSpec
from repro.models.transformer import TransformerConfig

ID = "qwen1.5-32b"


def full() -> TransformerConfig:
    return TransformerConfig(
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
        vocab=152064, qkv_bias=True,
        compute_dtype=jnp.bfloat16, loss_chunk=512, attn_chunk=1024,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab=256, qkv_bias=True,
        compute_dtype=jnp.float32, attn_chunk=16, remat=False,
    )


SPEC = ArchSpec(
    id=ID, family="lm", model_kind="transformer",
    config=full(), reduced=reduced(), shapes=LM_SHAPES,
    notes="dense MHA with QKV bias; uneven head sharding (40/16) via padding",
    source="hf:Qwen/Qwen1.5-0.5B",
)
