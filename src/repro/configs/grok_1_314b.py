"""grok-1-314b [moe]: 64L d6144 48H(kv8) ff32768 vocab131072, 8 experts top-2.

[hf:xai-org/grok-1; unverified].  8 experts < 16-way model axis ->
ep_split=2: each expert splits into two ff-half virtual experts (TP inside
the expert), giving 16 virtual experts that shard cleanly.
"""

import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ArchSpec
from repro.models.transformer import MoEConfig, TransformerConfig

ID = "grok-1-314b"


def full() -> TransformerConfig:
    return TransformerConfig(
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
        vocab=131072, qkv_bias=False,
        moe=MoEConfig(n_experts=8, top_k=2, ep_split=2),
        compute_dtype=jnp.bfloat16, loss_chunk=512, attn_chunk=1024,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab=256, moe=MoEConfig(n_experts=2, top_k=2, ep_split=2),
        compute_dtype=jnp.float32, attn_chunk=16, remat=False,
    )


SPEC = ArchSpec(
    id=ID, family="lm", model_kind="transformer",
    config=full(), reduced=reduced(), shapes=LM_SHAPES,
    notes="8 experts top-2; ep_split=2 -> 16 virtual experts",
    source="hf:xai-org/grok-1",
)
