"""dcn-v2 [recsys]: 13 dense + 26 sparse fields, embed 16, 3 cross layers,
MLP 1024-1024-512. [arXiv:2008.13535; paper].

Per-field vocab is not pinned by the assignment; we use Criteo-scale 10^6
rows/field (26M embedding rows total), row-sharded over the model axis.
"""

import jax.numpy as jnp

from repro.configs.base import REC_SHAPES, ArchSpec
from repro.models.recsys.dcn import DCNConfig

ID = "dcn-v2"


def full() -> DCNConfig:
    return DCNConfig(
        n_dense=13, n_sparse=26, embed_dim=16, n_cross_layers=3,
        mlp=(1024, 1024, 512), vocab_per_field=1_000_000,
        compute_dtype=jnp.bfloat16,
    )


def reduced() -> DCNConfig:
    return DCNConfig(
        n_dense=13, n_sparse=26, embed_dim=8, n_cross_layers=2,
        mlp=(32, 16), vocab_per_field=100, compute_dtype=jnp.float32,
    )


SPEC = ArchSpec(
    id=ID, family="recsys", model_kind="dcn",
    config=full(), reduced=reduced(), shapes=REC_SHAPES,
    notes="cross interaction; PowerWalk PPR used as candidate generator",
    source="arXiv:2008.13535",
)
