"""dlrm-rm2 [recsys]: embed 64, bot 13-512-256-64, top 512-512-256-1, dot
interaction. [arXiv:1906.00091; paper].  Criteo-scale 10^6 rows/field.
"""

import jax.numpy as jnp

from repro.configs.base import REC_SHAPES, ArchSpec
from repro.models.recsys.dlrm import DLRMConfig

ID = "dlrm-rm2"


def full() -> DLRMConfig:
    return DLRMConfig(
        n_dense=13, n_sparse=26, embed_dim=64,
        bot_mlp=(13, 512, 256, 64), top_mlp=(512, 512, 256, 1),
        vocab_per_field=1_000_000, compute_dtype=jnp.bfloat16,
    )


def reduced() -> DLRMConfig:
    return DLRMConfig(
        n_dense=13, n_sparse=26, embed_dim=16,
        bot_mlp=(13, 32, 16), top_mlp=(32, 16, 1),
        vocab_per_field=100, compute_dtype=jnp.float32,
    )


SPEC = ArchSpec(
    id=ID, family="recsys", model_kind="dlrm",
    config=full(), reduced=reduced(), shapes=REC_SHAPES,
    notes="dot interaction; embedding rows sharded over model axis",
    source="arXiv:1906.00091",
)
