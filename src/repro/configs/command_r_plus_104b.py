"""command-r-plus-104b [dense]: 64L d12288 96H(kv8) ff33792 vocab256000.

[hf:CohereForAI/c4ai-command-r-v01; unverified].  GQA, no bias.  The 256k
vocab makes the loss the peak-memory hazard -> loss_chunk=512.
"""

import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ArchSpec
from repro.models.transformer import TransformerConfig

ID = "command-r-plus-104b"


def full() -> TransformerConfig:
    return TransformerConfig(
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
        vocab=256000, qkv_bias=False,
        compute_dtype=jnp.bfloat16, loss_chunk=512, attn_chunk=1024,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab=512, compute_dtype=jnp.float32, attn_chunk=16, remat=False,
    )


SPEC = ArchSpec(
    id=ID, family="lm", model_kind="transformer",
    config=full(), reduced=reduced(), shapes=LM_SHAPES,
    notes="GQA kv=8, no-bias; 256k vocab -> chunked loss",
    source="hf:CohereForAI/c4ai-command-r-v01",
)
