"""mind [recsys]: embed 64, 4 interests, 3 capsule routing iters,
multi-interest retrieval. [arXiv:1904.08030; unverified].  Catalog 10^6.
"""

import jax.numpy as jnp

from repro.configs.base import REC_SHAPES, ArchSpec
from repro.models.recsys.mind import MINDConfig

ID = "mind"


def full() -> MINDConfig:
    return MINDConfig(
        n_items=1_000_000, embed_dim=64, n_interests=4, capsule_iters=3,
        hist_len=50, compute_dtype=jnp.bfloat16,
    )


def reduced() -> MINDConfig:
    return MINDConfig(
        n_items=500, embed_dim=16, n_interests=2, capsule_iters=2,
        hist_len=10, compute_dtype=jnp.float32,
    )


SPEC = ArchSpec(
    id=ID, family="recsys", model_kind="mind",
    config=full(), reduced=reduced(), shapes=REC_SHAPES,
    notes="capsule routing; retrieval scores = max over interests",
    source="arXiv:1904.08030",
)
