"""The paper's own workload configs (Table 1 graphs + engine settings).

The small graphs run for real (accuracy benchmarks); the billion-edge
graphs exist as *shape* configs for the dry-run/roofline of the PPR engine
itself (walk engine + VERD batch query on the production mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class GraphShape:
    name: str
    n: int
    m: int
    runnable: bool          # small enough to materialize in this container


# Paper Table 1
PAPER_GRAPHS: Dict[str, GraphShape] = {
    "wiki-Vote": GraphShape("wiki-Vote", 7_115, 103_689, True),
    "web-BerkStan": GraphShape("web-BerkStan", 685_230, 7_600_595, False),
    "web-Google": GraphShape("web-Google", 875_713, 5_105_039, False),
    "uk-1m": GraphShape("uk-1m", 1_000_000, 41_247_159, False),
    "twitter-2010": GraphShape("twitter-2010", 41_652_230, 1_468_365_182, False),
    "uk-union": GraphShape("uk-union", 133_633_040, 5_507_679_822, False),
}


@dataclasses.dataclass(frozen=True)
class PowerWalkEngineConfig:
    """Engine knobs (paper defaults)."""
    c: float = 0.15
    r_offline: int = 100          # walks/vertex for the index (paper's sweet spot)
    index_l: int = 667            # ~R/c nonzeros per fingerprint
    t_online: int = 2             # VERD iterations at R=100 (paper 4.2)
    max_walk_steps: int = 64      # tail (1-c)^64 ~ 3e-5
    query_batch: int = 10_000     # paper's headline batch size
    top_k: int = 200


@dataclasses.dataclass(frozen=True)
class PPRDryRunShape:
    """Shape cell for the distributed PPR engine dry-run."""
    name: str
    n: int                        # vertices
    ell_rows: int                 # chunked-ELL rows (~m / k + n)
    ell_k: int
    queries: int
    index_l: int
    walks_per_shard: int


def engine_dryrun_shapes() -> Tuple[PPRDryRunShape, ...]:
    """twitter-2010-scale VERD batch query + MCFP walk cells."""
    tw = PAPER_GRAPHS["twitter-2010"]
    uk = PAPER_GRAPHS["uk-union"]
    return (
        PPRDryRunShape(
            name="twitter_q10k",
            n=tw.n, ell_rows=tw.m // 16 + tw.n, ell_k=16,
            queries=10_000, index_l=667, walks_per_shard=1 << 20,
        ),
        PPRDryRunShape(
            name="ukunion_q10k",
            n=uk.n, ell_rows=uk.m // 32 + uk.n, ell_k=32,
            queries=10_000, index_l=667, walks_per_shard=1 << 20,
        ),
    )
