"""gcn-cora [gnn]: 2 layers, d_hidden=16, mean/sym-norm aggregation.

[arXiv:1609.02907; paper].  Feature/class dims vary per shape (cora 1433/7,
reddit-like minibatch 602/41, ogbn-products 100/47, molecule 32/2), so the
concrete GCNConfig is assembled per (arch, shape) in launch/steps.py from
this template.  PowerWalk integration: PPR-propagation mode + PPR sampler
(see models/gcn.py and graphs/sampler.py).
"""

import dataclasses

import jax.numpy as jnp

from repro.configs.base import GNN_SHAPES, ArchSpec


@dataclasses.dataclass(frozen=True)
class GCNTemplate:
    n_layers: int = 2
    d_hidden: int = 16
    aggregator: str = "mean"
    norm: str = "sym"
    compute_dtype: object = jnp.float32


ID = "gcn-cora"


def full() -> GCNTemplate:
    return GCNTemplate()


def reduced() -> GCNTemplate:
    return GCNTemplate(n_layers=2, d_hidden=8)


SPEC = ArchSpec(
    id=ID, family="gnn", model_kind="gcn",
    config=full(), reduced=reduced(), shapes=GNN_SHAPES,
    notes="segment_sum message passing; minibatch_lg uses the fanout sampler",
    source="arXiv:1609.02907",
)
