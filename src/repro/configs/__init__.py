"""Architecture registry: ``get_arch("<id>")`` -> :class:`ArchSpec`.

Ten assigned architectures + the paper's own PPR workload configs
(``powerwalk`` module).
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    command_r_plus_104b,
    dbrx_132b,
    dcn_v2,
    dlrm_rm2,
    gcn_cora,
    grok_1_314b,
    mind,
    powerwalk,
    qwen1_5_32b,
    sasrec,
    smollm_135m,
)
from repro.configs.base import ArchSpec, ShapeSpec  # noqa: F401

_MODULES = (
    dbrx_132b,
    grok_1_314b,
    qwen1_5_32b,
    command_r_plus_104b,
    smollm_135m,
    gcn_cora,
    dcn_v2,
    dlrm_rm2,
    sasrec,
    mind,
)

REGISTRY: Dict[str, ArchSpec] = {m.SPEC.id: m.SPEC for m in _MODULES}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[arch_id]


def all_arch_ids() -> List[str]:
    return list(REGISTRY)


def all_cells() -> List[tuple]:
    """Every (arch_id, shape_name) cell of the assignment (40 total)."""
    return [
        (spec.id, shape.name) for spec in REGISTRY.values()
        for shape in spec.shapes
    ]
