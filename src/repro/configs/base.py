"""Config schema: architectures x input shapes (the 40-cell assignment).

Every assigned architecture gets one module exporting ``full()`` (the exact
public-literature config), ``reduced()`` (CPU smoke size), and ``SHAPES``
(its own shape set).  ``launch/steps.py`` turns (arch, shape) into concrete
init/train_step/serve_step callables and ShapeDtypeStruct input specs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell.

    kind:
      lm_train | lm_prefill | lm_decode          (LM family)
      gnn_full | gnn_minibatch | gnn_batched      (GNN family)
      rec_train | rec_serve | rec_retrieval       (RecSys family)
    """

    name: str
    kind: str
    seq_len: int = 0
    global_batch: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """An architecture entry in the registry."""

    id: str
    family: str                  # lm | gnn | recsys
    model_kind: str              # transformer | gcn | dcn | dlrm | sasrec | mind
    config: Any                  # family-specific model config (full size)
    reduced: Any                 # reduced smoke config
    shapes: Tuple[ShapeSpec, ...]
    notes: str = ""
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.id} has no shape {name!r}")


# -- shared shape sets -------------------------------------------------------

LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "lm_train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "lm_prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "lm_decode", seq_len=32768, global_batch=128),
    # long-context decode: the serve step is O(S) per token (linear, not
    # quadratic); the KV cache is sequence-sharded.  See DESIGN.md §4.
    ShapeSpec("long_500k", "lm_decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("full_graph_sm", "gnn_full", extra=dict(
        n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    ShapeSpec("minibatch_lg", "gnn_minibatch", extra=dict(
        n_nodes=232965, n_edges=114615892, batch_nodes=1024,
        fanout=(15, 10), d_feat=602, n_classes=41)),
    ShapeSpec("ogb_products", "gnn_full", extra=dict(
        n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47)),
    ShapeSpec("molecule", "gnn_batched", extra=dict(
        n_nodes=30, n_edges=64, batch=128, d_feat=32, n_classes=2)),
)

REC_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_batch", "rec_train", global_batch=65536),
    ShapeSpec("serve_p99", "rec_serve", global_batch=512),
    ShapeSpec("serve_bulk", "rec_serve", global_batch=262144),
    ShapeSpec("retrieval_cand", "rec_retrieval", global_batch=1,
              extra=dict(n_candidates=1_000_000)),
)
