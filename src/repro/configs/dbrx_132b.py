"""dbrx-132b [moe]: 40L d6144 48H(kv8) ff10752 vocab100352, 16 experts top-4.

[hf:databricks/dbrx-base; unverified].  16 experts land exactly on the
16-way model axis (EP=16, ep_split=1).
"""

import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ArchSpec
from repro.models.transformer import MoEConfig, TransformerConfig

ID = "dbrx-132b"


def full() -> TransformerConfig:
    return TransformerConfig(
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
        vocab=100352, qkv_bias=False,
        moe=MoEConfig(n_experts=16, top_k=4, ep_split=1),
        compute_dtype=jnp.bfloat16, loss_chunk=512, attn_chunk=1024,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab=256, qkv_bias=False,
        moe=MoEConfig(n_experts=4, top_k=2, ep_split=1),
        compute_dtype=jnp.float32, attn_chunk=16, remat=False,
    )


SPEC = ArchSpec(
    id=ID, family="lm", model_kind="transformer",
    config=full(), reduced=reduced(), shapes=LM_SHAPES,
    notes="fine-grained MoE, 16e top-4; EP=16 on the model axis",
    source="hf:databricks/dbrx-base",
)
