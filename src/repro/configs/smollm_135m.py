"""smollm-135m [dense]: 30L d576 9H(kv3) ff1536 vocab49152 (llama-arch small).

[hf:HuggingFaceTB/SmolLM-135M; hf].  Small enough to actually train on CPU
in the end-to-end example (examples/train_lm.py); on the pod mesh it is
data-parallel dominated (TP gains nothing at d576).
"""

import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ArchSpec
from repro.models.transformer import TransformerConfig

ID = "smollm-135m"


def full() -> TransformerConfig:
    return TransformerConfig(
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
        vocab=49152, qkv_bias=False,
        compute_dtype=jnp.bfloat16, loss_chunk=0, attn_chunk=2048,
    )


def reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=4, d_model=96, n_heads=3, n_kv_heads=3, d_ff=256,
        vocab=512, compute_dtype=jnp.float32, attn_chunk=16, remat=False,
    )


SPEC = ArchSpec(
    id=ID, family="lm", model_kind="transformer",
    config=full(), reduced=reduced(), shapes=LM_SHAPES,
    notes="llama-arch small; the ~100M end-to-end training target",
    source="hf:HuggingFaceTB/SmolLM-135M",
)
