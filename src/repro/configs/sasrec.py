"""sasrec [recsys]: embed 50, 2 blocks, 1 head, seq 50, self-attn-seq
interaction. [arXiv:1808.09781; paper].  Item catalog 10^6.
"""

import jax.numpy as jnp

from repro.configs.base import REC_SHAPES, ArchSpec
from repro.models.recsys.sasrec import SASRecConfig

ID = "sasrec"


def full() -> SASRecConfig:
    return SASRecConfig(
        n_items=1_000_000, embed_dim=50, n_blocks=2, n_heads=1, seq_len=50,
        d_ff=200, compute_dtype=jnp.bfloat16,
    )


def reduced() -> SASRecConfig:
    return SASRecConfig(
        n_items=500, embed_dim=16, n_blocks=2, n_heads=1, seq_len=12,
        d_ff=32, compute_dtype=jnp.float32,
    )


SPEC = ArchSpec(
    id=ID, family="recsys", model_kind="sasrec",
    config=full(), reduced=reduced(), shapes=REC_SHAPES,
    notes="sequential self-attention; retrieval = user-emb dot item table",
    source="arXiv:1808.09781",
)
