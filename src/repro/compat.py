"""Version shims for the moving parts of the JAX API.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` and along the
way renamed ``check_rep`` to ``check_vma``; depending on the installed
version exactly one of the spellings exists.  Every call site in this repo
goes through :func:`shard_map` so the difference lives here only.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Optional

import jax


def _resolve_shard_map() -> Callable:
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as impl  # jax <= 0.4.x

    return impl


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: Optional[bool] = None,
) -> Callable:
    """``jax.shard_map`` if present, else the experimental one.

    ``check_vma`` maps onto the old ``check_rep`` flag (same meaning:
    validate replication/varying-manual-axes of outputs).
    """
    impl = _resolve_shard_map()
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check_vma is not None:
        params = inspect.signature(impl).parameters
        flag = "check_vma" if "check_vma" in params else "check_rep"
        kwargs[flag] = check_vma
    return impl(f, **kwargs)
