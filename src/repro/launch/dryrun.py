import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax-importing module: jax locks
# the device count at first init; only the dry-run sees 512 host devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the full-size StepBundle (ShapeDtypeStruct inputs, no allocation),
  * shard params/optimizer/batch/cache via the per-arch policy,
  * ``jax.jit(step).lower(...).compile()`` on the 16x16 pod mesh and the
    2x16x16 multi-pod mesh,
  * record ``memory_analysis()`` (fits-HBM proof), ``cost_analysis()``
    (FLOPs/bytes) and the HLO collective bytes for §Roofline.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all --mesh pod --out results/dryrun
    python -m repro.launch.dryrun --all --mesh multipod
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, get_arch
from repro.distributed import sharding as shpol
from repro.launch import steps as steps_mod
from repro.launch.mesh import describe, make_production_mesh
from repro.roofline import analysis as roof
from repro.training import train_loop


def _batch_pspecs(arch, shape, bundle, mesh):
    """PartitionSpec per input tensor (see DESIGN.md §5)."""
    ba = shpol.batch_axes(mesh)
    dsize = shpol.data_axis_size(mesh)
    kind = shape.kind

    def bshard(b):
        return ba if b >= dsize and b % dsize == 0 else None

    small_lm = arch.family == "lm" and shpol.lm_is_small(arch.config)
    specs = {}
    for name, sds in bundle.batch_spec.items():
        if arch.family == "lm":
            # small models: sequence-parallel over the model axis (TP gains
            # nothing at d_model < 2k; replicating attention 16x is worse)
            seq_ax = "model" if (small_lm and len(sds.shape) > 1
                                 and sds.shape[-1] > 1) else None
            specs[name] = P(bshard(sds.shape[0]),
                            *([None] * (len(sds.shape) - 2) + [seq_ax]
                              if len(sds.shape) > 1 else []))
        elif arch.family == "gnn":
            if kind == "gnn_full" and name in ("features", "labels",
                                               "label_mask"):
                specs[name] = P("model", *([None] * (len(sds.shape) - 1)))
            elif kind == "gnn_minibatch" and name == "feats":
                specs[name] = P("model", None)
            else:  # edge arrays, minibatch labels, molecule tensors
                specs[name] = P(bshard(sds.shape[0]),
                                *([None] * (len(sds.shape) - 1)))
        else:  # recsys
            if name == "candidates":
                specs[name] = P(bshard(sds.shape[0]))
            else:
                specs[name] = P(bshard(sds.shape[0]),
                                *([None] * (len(sds.shape) - 1)))
    return specs


def _serve_params(params_shape):
    """Serving holds bf16 weights (no optimizer): cast float leaves."""
    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(cast, params_shape)


def lower_cell(arch_id: str, shape_name: str, mesh, *, donate: bool = True,
               config_overrides=None):
    """Returns (lowered, compiled, context dict)."""
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    overrides = dict(config_overrides or {})
    if (arch.family == "lm" and "act_shard" not in overrides
            and not shpol.lm_is_small(arch.config)):
        from repro.models.transformer import ActSharding
        overrides["act_shard"] = ActSharding(
            batch=shpol.batch_axes(mesh), mesh=mesh
        )
    bundle = steps_mod.build(arch, shape_name, reduced=False,
                             config_overrides=overrides or None)

    params_shape = jax.eval_shape(bundle.init_fn, jax.random.PRNGKey(0))
    pspecs = shpol.param_specs(arch.family, params_shape, arch.config)
    p_sh = shpol.named(mesh, pspecs)
    batch_specs = _batch_pspecs(arch, shape, bundle, mesh)
    b_sh = {k: NamedSharding(mesh, v) for k, v in batch_specs.items()}
    batch_sds = bundle.batch_spec

    with mesh:
        if bundle.kind == "train":
            opt_shape = jax.eval_shape(
                lambda p: train_loop.init_state(
                    bundle.opt_cfg or steps_mod.DEFAULT_OPT, p),
                params_shape,
            )
            o_sh = shpol.named(mesh, shpol.opt_state_specs(pspecs))
            jitted = jax.jit(
                bundle.step_fn,
                in_shardings=(p_sh, o_sh, b_sh),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_shape, opt_shape, batch_sds)
        elif bundle.cache_spec is not None:
            sparams = _serve_params(params_shape)
            c_sh = shpol.named(
                mesh, shpol.cache_spec(
                    mesh, shape.global_batch,
                    quantized="k_scale" in bundle.cache_spec,
                )
            )
            jitted = jax.jit(
                bundle.step_fn,
                in_shardings=(p_sh, c_sh, b_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(sparams, bundle.cache_spec, batch_sds)
        else:
            sparams = _serve_params(params_shape)
            jitted = jax.jit(bundle.step_fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(sparams, batch_sds)
        compiled = lowered.compile()
    ctx = dict(
        arch=arch_id, shape=shape_name, kind=bundle.kind,
        model_flops=bundle.model_flops_per_step,
        mesh=describe(mesh),
    )
    return lowered, compiled, ctx


def run_cell(arch_id, shape_name, mesh, out_dir=None, mesh_tag="pod"):
    t0 = time.monotonic()
    try:
        lowered, compiled, ctx = lower_cell(arch_id, shape_name, mesh)
        hlo = compiled.as_text()
        terms = roof.roofline_from_compiled(
            compiled, hlo,
            model_flops_total=ctx["model_flops"],
            n_devices=ctx["mesh"]["n_devices"],
        )
        fits, used = roof.fit_check(terms)
        rec = dict(
            ok=True, seconds=round(time.monotonic() - t0, 1), **ctx,
            roofline=terms.as_dict(), hbm_used=used, hbm_fits=fits,
        )
    except Exception as e:  # recorded, not raised: the sweep must finish
        rec = dict(
            ok=False, seconds=round(time.monotonic() - t0, 1),
            arch=arch_id, shape=shape_name, mesh_tag=mesh_tag,
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-2000:],
        )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_tag}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    status = "OK " if rec.get("ok") else "FAIL"
    extra = ""
    if rec.get("ok"):
        r = rec["roofline"]
        extra = (f"dom={r['dominant']} comp={r['compute_s']:.3e}s "
                 f"mem={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
                 f"hbm={rec['hbm_used']/1e9:.1f}GB fits={rec['hbm_fits']}")
    else:
        extra = rec["error"][:160]
    print(f"[{status}] {arch_id:22s} {shape_name:14s} {mesh_tag:8s} "
          f"{rec['seconds']:7.1f}s  {extra}", flush=True)
    return rec


# ---------------------------------------------------------------------------
# The paper's own workload: distributed PPR engine cells
# ---------------------------------------------------------------------------

# (name, n, m, q_tile, index_l, exchange/widths, walks)
PPR_CELLS = {
    # twitter-2010: 41.65M vertices / 1.47B edges; sparse-frontier wire
    # format (the default): degree_cap caps each slot's gather budget and
    # hub splitting keeps every gather axis at 256
    "ppr_verd_twitter": dict(n=41_652_240, m=1_468_365_182, q_tile=8,
                             index_l=256, frontier_k=4096, wire_k=4096,
                             degree_cap=4096, hub_split_degree=256),
    # legacy dense-slab exchange (the oracle path, for roofline comparison)
    "ppr_verd_twitter_dense": dict(n=41_652_240, m=1_468_365_182, q_tile=4,
                                   index_l=256, exchange="dense"),
    # uk-union: 133.6M vertices / 5.51B edges
    "ppr_verd_ukunion": dict(n=133_633_040, m=5_507_679_822, q_tile=2,
                             index_l=48, frontier_k=2048, wire_k=2048,
                             degree_cap=2048, hub_split_degree=256),
    # MCFP offline indexing step on twitter (graph replicated: 6.2 GB)
    "ppr_walk_twitter": dict(n=41_652_240, m=1_468_365_182, q_tile=32,
                             walks=True),
}


def lower_ppr_cell(name: str, mesh):
    from repro.core import distributed_engine as de

    spec = PPR_CELLS[name]
    ep = int(mesh.shape["model"])
    ba = shpol.batch_axes(mesh)
    n = ((spec["n"] + ep - 1) // ep) * ep
    cfg = de.DistConfig(
        n=n, ep=ep, q_tile=spec["q_tile"], t_iterations=2,
        index_l=spec.get("index_l", 0),
        exchange=spec.get("exchange", "sparse"),
        frontier_k=spec.get("frontier_k", 0),
        wire_k=spec.get("wire_k", 0),
        degree_cap=spec.get("degree_cap", 0),
        hub_split_degree=spec.get("hub_split_degree", 0),
        wire_dtype=jnp.bfloat16,
        batch_axes=ba,
    )
    sds = jax.ShapeDtypeStruct
    if spec.get("walks"):
        w_per_shard = 1 << 16
        w = w_per_shard * shpol.data_axis_size(mesh)
        step = de.make_walk_counts_step(cfg, mesh, max_steps=64)
        args = (
            sds((spec["n"] + 1,), jnp.int32),      # row_ptr (replicated)
            sds((spec["m"],), jnp.int32),          # col_idx
            sds((spec["n"],), jnp.int32),          # out_deg
            sds((w,), jnp.int32),                  # walk sources
            sds((w,), jnp.int32),                  # walk count rows
            sds((2,), jnp.uint32),                 # key
        )
        shards = (
            NamedSharding(mesh, P(None)), NamedSharding(mesh, P(None)),
            NamedSharding(mesh, P(None)), NamedSharding(mesh, P(ba)),
            NamedSharding(mesh, P(ba)), NamedSharding(mesh, P()),
        )
        with mesh:
            lowered = jax.jit(step, in_shardings=shards).lower(*args)
            compiled = lowered.compile()
        model_flops = 8.0 * w * 64   # gather/PRNG bound; nominal flop count
    else:
        m_shard = (spec["m"] + ep - 1) // ep
        m_shard = ((m_shard + 1023) // 1024) * 1024
        slabs = de.ShardedGraph.specs(cfg, m_shard)
        slab_sh = de.ShardedGraph.shardings(cfg, mesh)
        step = de.make_verd_tile_step(cfg, mesh)
        ivals = sds((ep, cfg.n_shard, cfg.index_l), jnp.bfloat16)
        iidx = sds((ep, cfg.n_shard, cfg.index_l), jnp.int32)
        args = (slabs, sds((cfg.q_tile,), jnp.int32), ivals, iidx)
        ish = NamedSharding(mesh, P("model", None, None))
        shards = (slab_sh, NamedSharding(mesh, P()), ish, ish)
        with mesh:
            lowered = jax.jit(step, in_shardings=shards).lower(*args)
            compiled = lowered.compile()
        model_flops = (cfg.t_iterations * 2.0 * spec["m"] * cfg.q_tile
                       + 2.0 * cfg.q_tile * n * cfg.index_l)
    ctx = dict(arch="powerwalk-engine", shape=name, kind="serve",
               model_flops=model_flops, mesh=describe(mesh))
    return lowered, compiled, ctx


def run_ppr_cell(name, mesh, out_dir=None, mesh_tag="pod"):
    t0 = time.monotonic()
    try:
        lowered, compiled, ctx = lower_ppr_cell(name, mesh)
        hlo = compiled.as_text()
        terms = roof.roofline_from_compiled(
            compiled, hlo, model_flops_total=ctx["model_flops"],
            n_devices=ctx["mesh"]["n_devices"],
        )
        fits, used = roof.fit_check(terms)
        rec = dict(ok=True, seconds=round(time.monotonic() - t0, 1), **ctx,
                   roofline=terms.as_dict(), hbm_used=used, hbm_fits=fits)
    except Exception as e:
        rec = dict(ok=False, seconds=round(time.monotonic() - t0, 1),
                   arch="powerwalk-engine", shape=name, mesh_tag=mesh_tag,
                   error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"powerwalk__{name}__{mesh_tag}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    status = "OK " if rec.get("ok") else "FAIL"
    extra = (rec["error"][:160] if not rec.get("ok") else
             f"dom={rec['roofline']['dominant']} "
             f"coll={rec['roofline']['collective_s']:.3e}s "
             f"hbm={rec['hbm_used']/1e9:.1f}GB fits={rec['hbm_fits']}")
    print(f"[{status}] powerwalk-engine       {name:22s} {mesh_tag:8s} "
          f"{rec['seconds']:7.1f}s  {extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ppr", action="store_true",
                    help="run the PowerWalk engine cells")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod", make_production_mesh(multi_pod=True)))

    n_fail = 0
    if args.ppr:
        for mesh_tag, mesh in meshes:
            for name in PPR_CELLS:
                rec = run_ppr_cell(name, mesh, args.out, mesh_tag)
                n_fail += 0 if rec.get("ok") else 1
        print(f"done; failures: {n_fail}", flush=True)
        raise SystemExit(1 if n_fail else 0)

    if args.all:
        cells = [(s.id, sh.name) for s in REGISTRY.values()
                 for sh in s.shapes]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for mesh_tag, mesh in meshes:
        for arch_id, shape_name in cells:
            rec = run_cell(arch_id, shape_name, mesh, args.out, mesh_tag)
            n_fail += 0 if rec.get("ok") else 1
    print(f"done; failures: {n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
