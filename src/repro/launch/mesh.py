"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init, and tests/benches must keep seeing 1 device.

Topology assumption (TPU v5e-style): 16x16 = 256 chips per pod, 2 pods via
DCN.  Axis roles: ``model`` = fast ICI ring (TP/EP), ``data`` = second ICI
dim (DP + FSDP), ``pod`` = DCN (pure DP).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for in-process sharding tests (requires >= n_data*n_model
    visible devices, e.g. via xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def describe(mesh) -> dict:
    return dict(
        shape=dict(zip(mesh.axis_names, mesh.devices.shape)),
        n_devices=int(mesh.devices.size),
        axis_names=list(mesh.axis_names),
    )
