"""PPR serving launcher (the paper's online phase as a process).

    PYTHONPATH=src python -m repro.launch.serve \
        [--n-log2 11] [--r 100] [--t 2] [--queries 2000] [--mode powerwalk]

Builds (or loads) the index, starts the batched service, and runs a
closed-loop workload, printing Table-3-style latency/throughput numbers.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.index import build_index
from repro.core.query import QueryConfig
from repro.graphs import synthetic
from repro.serving import PPRService, ServiceConfig
from repro.serving.batching import BatchingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-log2", type=int, default=11)
    ap.add_argument("--r", type=int, default=100)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--mode", default="powerwalk",
                    choices=["powerwalk", "verd", "fppr", "mcfp", "pi"])
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--top-k", type=int, default=50)
    args = ap.parse_args()

    g = synthetic.rmat(args.n_log2, avg_deg=10.0, seed=0)
    print(f"graph n={g.n} m={g.m}; building index R={args.r}")
    index = None
    if args.mode in ("powerwalk", "fppr"):
        index, stats = build_index(
            g, r=args.r, l=max(32, int(args.r / 0.15)),
            key=jax.random.PRNGKey(0), source_batch=512)
        print(f"index: {stats['nbytes'] >> 20} MiB "
              f"(dropped {stats['drop_fraction']:.3f})")

    svc = PPRService(
        g, index,
        ServiceConfig(
            query=QueryConfig(mode=args.mode, t_iterations=args.t,
                              top_k=args.top_k),
            batching=BatchingConfig(max_batch=args.max_batch),
        ),
    )
    workload = np.random.default_rng(0).integers(0, g.n, size=args.queries)
    _, stats = svc.run_closed_loop(workload)
    print(f"mode={args.mode}: {stats['served']:.0f} queries "
          f"{stats['wall_s']:.2f}s  {stats['qps']:.0f} q/s  "
          f"mean_latency {stats['mean_latency'] * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
