"""Step factory: (arch, shape) -> init / step callables + input specs.

This is the single place that knows how every architecture family maps onto
train/serve steps, what its batch pytree looks like, and how to fabricate
both ShapeDtypeStruct specs (dry-run) and concrete synthetic batches (smoke
tests, examples).  ``launch/dryrun.py`` and the smoke tests consume the same
:class:`StepBundle`, so "what compiles on 512 devices" and "what runs on
CPU" can never drift apart.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models import gcn as gcn_mod
from repro.models import transformer as tfm
from repro.models.gcn import GCNConfig
from repro.models.recsys import dcn, dlrm, mind, sasrec
from repro.training import train_loop
from repro.training.optimizer import AdamWConfig

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower or run one (arch x shape) cell."""

    arch_id: str
    shape_name: str
    kind: str                       # train | serve
    init_fn: Callable[[jax.Array], Any]
    step_fn: Callable[..., Any]     # train: (params, opt, batch); serve: (params, [cache,] batch)
    batch_spec: Dict[str, jax.ShapeDtypeStruct]
    make_batch: Callable[[jax.Array], Dict[str, jax.Array]]
    cache_spec: Optional[Dict[str, jax.ShapeDtypeStruct]] = None
    model_flops_per_step: float = 0.0   # 6*N*D style model FLOPs
    notes: str = ""
    opt_cfg: Optional[AdamWConfig] = None   # the config step_fn actually uses


DEFAULT_OPT = AdamWConfig(moment_dtype=jnp.bfloat16)
SMOKE_OPT = AdamWConfig(moment_dtype=jnp.float32, warmup_steps=2, total_steps=100)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _reduce_lm_shape(shape: ShapeSpec) -> ShapeSpec:
    table = {
        "lm_train": dict(seq_len=32, global_batch=4),
        "lm_prefill": dict(seq_len=64, global_batch=2),
        "lm_decode": dict(seq_len=64, global_batch=2),
    }
    t = table[shape.kind]
    return dataclasses.replace(shape, **t)


def _lm_bundle(arch: ArchSpec, shape: ShapeSpec, cfg: tfm.TransformerConfig,
               opt_cfg: AdamWConfig) -> StepBundle:
    b, s = shape.global_batch, shape.seq_len
    init_fn = lambda key: tfm.init(cfg, key)
    n_params_active = cfg.active_param_count()

    if shape.kind == "lm_train":
        spec = dict(
            tokens=_sds((b, s), I32), labels=_sds((b, s), I32),
            mask=_sds((b, s), F32),
        )
        # gradient accumulation scales activation memory down with model
        # size (grok-314B at mb=1 needs ~62 GB/chip of temps; mb=8 fits),
        # and the biggest models also take reduced-precision optimizer
        # state (fp8 mu per FP8-LM, bf16 nu, bf16 grad accumulation).
        n_params = cfg.param_count()
        mb = 8 if n_params > 1.2e11 else 4 if n_params > 6e10 else \
            2 if n_params > 1.5e10 else 1
        mb = mb if b % max(mb, 1) == 0 else 1
        accum = jnp.float32
        if n_params > 6e10 and opt_cfg is DEFAULT_OPT:
            opt_cfg = dataclasses.replace(
                opt_cfg, mu_dtype=jnp.float8_e4m3fn, nu_dtype=jnp.bfloat16,
            )
            accum = jnp.bfloat16
        grad_pspecs = None
        if cfg.act_shard is not None:
            # shard the grad accumulator like the params: without this the
            # microbatch loop all-reduces *full* layer grads (see train_loop)
            from repro.distributed import sharding as shpol
            pshape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
            grad_pspecs = shpol.param_specs("lm", pshape, cfg)
        step = train_loop.make_train_step(
            functools.partial(tfm.loss_fn, cfg), opt_cfg, microbatches=mb,
            accum_dtype=accum, grad_pspecs=grad_pspecs,
        )

        def make_batch(key):
            toks = jax.random.randint(key, (b, s), 0, cfg.vocab, I32)
            return dict(tokens=toks, labels=jnp.roll(toks, -1, axis=1),
                        mask=jnp.ones((b, s), F32))

        flops = 6.0 * n_params_active * b * s  # fwd+bwd 6ND
        return StepBundle(arch.id, shape.name, "train", init_fn, step, spec,
                          make_batch, model_flops_per_step=flops,
                          opt_cfg=opt_cfg)

    if shape.kind == "lm_prefill":
        spec = dict(tokens=_sds((b, s), I32))

        def serve_prefill(params, batch):
            h, _ = tfm.forward(cfg, params, batch["tokens"])
            logits = (h[:, -1:, :].astype(cfg.compute_dtype)
                      @ params["lm_head"]["w"].astype(cfg.compute_dtype))
            return logits

        def make_batch(key):
            return dict(tokens=jax.random.randint(key, (b, s), 0, cfg.vocab, I32))

        flops = 2.0 * n_params_active * b * s
        return StepBundle(arch.id, shape.name, "serve", init_fn, serve_prefill,
                          spec, make_batch, model_flops_per_step=flops)

    if shape.kind == "lm_decode":
        # int8 KV cache with per-token scales whenever the bf16 cache would
        # exceed ~0.5 TB globally (qwen's MHA at 32k is 5.5 TB; grok /
        # command-r / dbrx land 0.7-1.1 TB).
        cache_bytes_bf16 = (cfg.n_layers * b * s * cfg.n_kv_heads
                            * cfg.hd * 2 * 2)
        if cache_bytes_bf16 > 0.5e12 and cfg.compute_dtype == jnp.bfloat16:
            cfg = dataclasses.replace(cfg, kv_quant=True)
        cache_dt = jnp.bfloat16 if cfg.compute_dtype == jnp.bfloat16 else F32
        cshape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.hd)
        if cfg.kv_quant:
            sshape = (cfg.n_layers, b, s, cfg.n_kv_heads)
            cache_spec = dict(
                k=_sds(cshape, jnp.int8), v=_sds(cshape, jnp.int8),
                k_scale=_sds(sshape, jnp.bfloat16),
                v_scale=_sds(sshape, jnp.bfloat16),
                length=_sds((), I32),
            )
        else:
            cache_spec = dict(k=_sds(cshape, cache_dt),
                              v=_sds(cshape, cache_dt),
                              length=_sds((), I32))
        spec = dict(tokens=_sds((b, 1), I32))

        def serve_decode(params, cache, batch):
            return tfm.decode_step(cfg, params, cache, batch["tokens"])

        def make_batch(key):
            return dict(tokens=jax.random.randint(key, (b, 1), 0, cfg.vocab, I32))

        flops = 2.0 * n_params_active * b  # one token per row
        return StepBundle(arch.id, shape.name, "serve", init_fn, serve_decode,
                          spec, make_batch, cache_spec=cache_spec,
                          model_flops_per_step=flops)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _gnn_cfg(template, shape: ShapeSpec, reduced: bool) -> GCNConfig:
    x = shape.extra
    return GCNConfig(
        n_layers=template.n_layers, d_feat=x["d_feat"],
        d_hidden=template.d_hidden, n_classes=x["n_classes"],
        aggregator="sym" if shape.kind == "gnn_full" else "mean",
        readout="mean" if shape.kind == "gnn_batched" else None,
        compute_dtype=template.compute_dtype,
    )


def _reduce_gnn_shape(shape: ShapeSpec) -> ShapeSpec:
    x = dict(shape.extra)
    if shape.kind == "gnn_full":
        x.update(n_nodes=120, n_edges=480, d_feat=32, n_classes=7)
    elif shape.kind == "gnn_minibatch":
        x.update(n_nodes=500, n_edges=4000, batch_nodes=8, fanout=(3, 2),
                 d_feat=16, n_classes=5)
    else:  # batched molecules
        x.update(n_nodes=10, n_edges=16, batch=8, d_feat=8, n_classes=2)
    return dataclasses.replace(shape, extra=x)


def _gnn_bundle(arch: ArchSpec, shape: ShapeSpec, template,
                opt_cfg: AdamWConfig, reduced: bool) -> StepBundle:
    cfg = _gnn_cfg(template, shape, reduced)
    init_fn = lambda key: gcn_mod.init(cfg, key)
    x = shape.extra

    if shape.kind == "gnn_full":
        # pad node/edge counts to 512-multiples: explicit input shardings
        # need divisibility; masks keep the math exact on the padding
        n = ((x["n_nodes"] + 511) // 512) * 512
        m = ((x["n_edges"] + 511) // 512) * 512
        n_real, m_real = x["n_nodes"], x["n_edges"]
        spec = dict(
            features=_sds((n, cfg.d_feat), F32),
            edge_src=_sds((m,), I32), edge_dst=_sds((m,), I32),
            edge_mask=_sds((m,), F32),
            labels=_sds((n,), I32), label_mask=_sds((n,), F32),
        )
        step = train_loop.make_train_step(
            functools.partial(gcn_mod.loss_full, cfg), opt_cfg
        )

        def make_batch(key):
            k1, k2, k3 = jax.random.split(key, 3)
            return dict(
                features=jax.random.normal(k1, (n, cfg.d_feat), F32),
                edge_src=jax.random.randint(k2, (m,), 0, n_real, I32),
                edge_dst=jax.random.randint(k3, (m,), 0, n_real, I32),
                edge_mask=(jnp.arange(m) < m_real).astype(F32),
                labels=jax.random.randint(k1, (n,), 0, cfg.n_classes, I32),
                label_mask=(jnp.arange(n) < n_real).astype(F32),
            )

        # SpMM flops: 2 * m * d per layer (gather-mac) + dense n*d_in*d_out
        dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
        flops = 3.0 * sum(
            2.0 * m * dims[i] + 2.0 * n * dims[i] * dims[i + 1]
            for i in range(cfg.n_layers)
        )  # x3 for fwd+bwd
        return StepBundle(arch.id, shape.name, "train", init_fn, step, spec,
                          make_batch, model_flops_per_step=flops,
                          opt_cfg=opt_cfg)

    if shape.kind == "gnn_minibatch":
        seeds = x["batch_nodes"]
        f1, f2 = x["fanout"]
        n1 = seeds + seeds * f1                 # block-1 node set
        n2 = n1 + n1 * f2                       # block-2 node set
        e1, e2 = seeds * f1, n1 * f2
        spec = dict(
            feats=_sds((n2, cfg.d_feat), F32),
            e2_src=_sds((e2,), I32), e2_dst=_sds((e2,), I32),
            e2_mask=_sds((e2,), F32),
            e1_src=_sds((e1,), I32), e1_dst=_sds((e1,), I32),
            e1_mask=_sds((e1,), F32),
            labels=_sds((seeds,), I32),
        )

        def loss(params, batch):
            blocks_edges = [
                dict(edge_src=batch["e1_src"], edge_dst=batch["e1_dst"],
                     edge_mask=batch["e1_mask"], n_dst=seeds),
                dict(edge_src=batch["e2_src"], edge_dst=batch["e2_dst"],
                     edge_mask=batch["e2_mask"], n_dst=n1),
            ]
            logits = gcn_mod.forward_sampled(
                cfg, params, [None, batch["feats"]], blocks_edges
            )
            from repro.models import layers as L
            return L.softmax_cross_entropy(logits, batch["labels"])

        step = train_loop.make_train_step(loss, opt_cfg)

        def make_batch(key):
            ks = jax.random.split(key, 4)
            return dict(
                feats=jax.random.normal(ks[0], (n2, cfg.d_feat), F32),
                e2_src=jax.random.randint(ks[1], (e2,), 0, n2, I32),
                e2_dst=jax.random.randint(ks[1], (e2,), 0, n1, I32),
                e2_mask=jnp.ones((e2,), F32),
                e1_src=jax.random.randint(ks[2], (e1,), 0, n1, I32),
                e1_dst=jax.random.randint(ks[2], (e1,), 0, seeds, I32),
                e1_mask=jnp.ones((e1,), F32),
                labels=jax.random.randint(ks[3], (seeds,), 0, cfg.n_classes, I32),
            )

        flops = 3.0 * (2.0 * e2 * cfg.d_feat + 2.0 * n1 * cfg.d_feat * cfg.d_hidden
                       + 2.0 * e1 * cfg.d_hidden
                       + 2.0 * seeds * cfg.d_hidden * cfg.n_classes)
        return StepBundle(arch.id, shape.name, "train", init_fn, step, spec,
                          make_batch, model_flops_per_step=flops,
                          opt_cfg=opt_cfg)

    # batched molecules
    bsz, npg, epg = x["batch"], x["n_nodes"], x["n_edges"]
    n, m = bsz * npg, bsz * epg * 2
    spec = dict(
        features=_sds((n, cfg.d_feat), F32),
        edge_src=_sds((m,), I32), edge_dst=_sds((m,), I32),
        edge_mask=_sds((m,), F32),
        graph_ids=_sds((n,), I32), graph_labels=_sds((bsz,), I32),
    )

    def loss(params, batch):
        return gcn_mod.loss_full(cfg, params, batch)

    step = train_loop.make_train_step(loss, opt_cfg)

    def make_batch(key):
        ks = jax.random.split(key, 3)
        gid = jnp.repeat(jnp.arange(bsz, dtype=I32), npg)
        edge_off = jnp.repeat(jnp.arange(bsz, dtype=I32) * npg, 2 * epg)
        src = jax.random.randint(ks[0], (m,), 0, npg, I32)
        dst = jax.random.randint(ks[1], (m,), 0, npg, I32)
        return dict(
            features=jax.random.normal(ks[2], (n, cfg.d_feat), F32),
            edge_src=src + edge_off,
            edge_dst=dst + edge_off,
            edge_mask=jnp.ones((m,), F32),
            graph_ids=gid,
            graph_labels=jax.random.randint(ks[2], (bsz,), 0, cfg.n_classes, I32),
        )

    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    flops = 3.0 * sum(
        2.0 * m * dims[i] + 2.0 * n * dims[i] * dims[i + 1]
        for i in range(cfg.n_layers)
    )
    return StepBundle(arch.id, shape.name, "train", init_fn, step, spec,
                      make_batch, model_flops_per_step=flops,
                      opt_cfg=opt_cfg)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

_REC_MODS = {"dcn": dcn, "dlrm": dlrm, "sasrec": sasrec, "mind": mind}


def _reduce_rec_shape(shape: ShapeSpec) -> ShapeSpec:
    if shape.kind == "rec_retrieval":
        return dataclasses.replace(
            shape, extra=dict(n_candidates=256), global_batch=1
        )
    return dataclasses.replace(shape, global_batch=32)


def _rec_batch_spec(kind_model: str, cfg, b: int, with_label: bool) -> dict:
    if kind_model in ("dcn", "dlrm"):
        spec = dict(dense=_sds((b, cfg.n_dense), F32),
                    sparse_ids=_sds((b, cfg.n_sparse), I32))
    elif kind_model == "sasrec":
        spec = dict(item_seq=_sds((b, cfg.seq_len), I32))
        if with_label:
            spec.update(pos=_sds((b, cfg.seq_len), I32),
                        neg=_sds((b, cfg.seq_len), I32),
                        mask=_sds((b, cfg.seq_len), F32))
    else:  # mind
        spec = dict(hist=_sds((b, cfg.hist_len), I32),
                    hist_mask=_sds((b, cfg.hist_len), F32))
        if with_label:
            spec.update(target=_sds((b,), I32),
                        neg=_sds((b, cfg.n_negatives), I32))
    if with_label and kind_model in ("dcn", "dlrm"):
        spec["label"] = _sds((b,), F32)
    return spec


def _rec_make_batch(kind_model: str, cfg, b: int, with_label: bool):
    def make_batch(key):
        ks = jax.random.split(key, 4)
        if kind_model in ("dcn", "dlrm"):
            out = dict(
                dense=jax.random.normal(ks[0], (b, cfg.n_dense), F32),
                sparse_ids=jax.random.randint(
                    ks[1], (b, cfg.n_sparse), 0, cfg.vocab_per_field, I32),
            )
            if with_label:
                out["label"] = jax.random.bernoulli(ks[2], 0.3, (b,)).astype(F32)
        elif kind_model == "sasrec":
            out = dict(item_seq=jax.random.randint(
                ks[0], (b, cfg.seq_len), 0, cfg.n_items, I32))
            if with_label:
                out.update(
                    pos=jax.random.randint(ks[1], (b, cfg.seq_len), 0,
                                           cfg.n_items, I32),
                    neg=jax.random.randint(ks[2], (b, cfg.seq_len), 0,
                                           cfg.n_items, I32),
                    mask=jnp.ones((b, cfg.seq_len), F32),
                )
        else:
            out = dict(
                hist=jax.random.randint(ks[0], (b, cfg.hist_len), 0,
                                        cfg.n_items, I32),
                hist_mask=jnp.ones((b, cfg.hist_len), F32),
            )
            if with_label:
                out["target"] = jax.random.randint(ks[1], (b,), 0,
                                                   cfg.n_items, I32)
                out["neg"] = jax.random.randint(
                    ks[2], (b, cfg.n_negatives), 0, cfg.n_items, I32)
        return out
    return make_batch


def _rec_dense_flops(kind_model: str, cfg, b: int) -> float:
    """Dense-compute model FLOPs per example (excl. embedding gathers)."""
    if kind_model == "dcn":
        d = cfg.x0_dim
        cross = cfg.n_cross_layers * 2 * d * d
        dims = [d] + list(cfg.mlp)
        deep = sum(2 * a * o for a, o in zip(dims[:-1], dims[1:]))
        return b * float(cross + deep)
    if kind_model == "dlrm":
        bot = sum(2 * a * o for a, o in
                  zip(cfg.bot_mlp[:-1], cfg.bot_mlp[1:]))
        dims = [cfg.top_in] + list(cfg.top_mlp)
        top = sum(2 * a * o for a, o in zip(dims[:-1], dims[1:]))
        inter = 2 * cfg.n_vectors ** 2 * cfg.embed_dim
        return b * float(bot + top + inter)
    if kind_model == "sasrec":
        d = cfg.embed_dim
        per_block = 8 * d * d * cfg.seq_len + 4 * d * cfg.d_ff * cfg.seq_len \
            + 4 * cfg.seq_len ** 2 * d
        return b * float(cfg.n_blocks * per_block)
    d = cfg.embed_dim
    routing = cfg.capsule_iters * 4 * cfg.hist_len * cfg.n_interests * d
    return b * float(2 * cfg.hist_len * d * d + routing)


def _rec_bundle(arch: ArchSpec, shape: ShapeSpec, cfg,
                opt_cfg: AdamWConfig) -> StepBundle:
    mod = _REC_MODS[arch.model_kind]
    init_fn = lambda key: mod.init(cfg, key)
    b = shape.global_batch

    if shape.kind == "rec_train":
        spec = _rec_batch_spec(arch.model_kind, cfg, b, with_label=True)
        step = train_loop.make_train_step(
            functools.partial(mod.loss_fn, cfg), opt_cfg
        )
        flops = 3.0 * _rec_dense_flops(arch.model_kind, cfg, b)
        return StepBundle(arch.id, shape.name, "train", init_fn, step, spec,
                          _rec_make_batch(arch.model_kind, cfg, b, True),
                          model_flops_per_step=flops, opt_cfg=opt_cfg)

    if shape.kind == "rec_serve":
        spec = _rec_batch_spec(arch.model_kind, cfg, b, with_label=False)

        def serve(params, batch):
            if arch.model_kind in ("dcn", "dlrm"):
                return mod.forward(cfg, params, batch)
            if arch.model_kind == "sasrec":
                return sasrec.user_embedding(cfg, params, batch["item_seq"])
            return mind.user_interests(cfg, params, batch["hist"],
                                       batch["hist_mask"])

        flops = _rec_dense_flops(arch.model_kind, cfg, b)
        return StepBundle(arch.id, shape.name, "serve", init_fn, serve, spec,
                          _rec_make_batch(arch.model_kind, cfg, b, False),
                          model_flops_per_step=flops)

    # retrieval: 1 user x n_candidates
    nc = shape.extra["n_candidates"]
    spec = _rec_batch_spec(arch.model_kind, cfg, 1, with_label=False)
    spec["candidates"] = _sds((nc,), I32)

    def retrieve(params, batch):
        return mod.retrieval_scores(cfg, params, batch)

    base_make = _rec_make_batch(arch.model_kind, cfg, 1, False)

    def make_batch(key):
        out = base_make(key)
        vocab = getattr(cfg, "n_items", getattr(cfg, "vocab_per_field", 1000))
        out["candidates"] = jax.random.randint(key, (nc,), 0, vocab, I32)
        return out

    if arch.model_kind in ("dcn", "dlrm"):
        flops = _rec_dense_flops(arch.model_kind, cfg, nc)
    else:
        flops = 2.0 * nc * cfg.embed_dim
    return StepBundle(arch.id, shape.name, "serve", init_fn, retrieve, spec,
                      make_batch, model_flops_per_step=flops)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def reduce_shape(arch: ArchSpec, shape: ShapeSpec) -> ShapeSpec:
    if arch.family == "lm":
        return _reduce_lm_shape(shape)
    if arch.family == "gnn":
        return _reduce_gnn_shape(shape)
    return _reduce_rec_shape(shape)


def build(arch: ArchSpec, shape_name: str, *, reduced: bool = False,
          opt_cfg: Optional[AdamWConfig] = None,
          config_overrides: Optional[Dict[str, Any]] = None) -> StepBundle:
    """Build the StepBundle for one cell.

    ``reduced=True`` swaps in the smoke config *and* the reduced shape —
    this is what the per-arch smoke tests and CPU examples run.
    ``config_overrides`` does a dataclasses.replace on the model config
    (the dry-run injects activation-sharding hints here).
    """
    shape = arch.shape(shape_name)
    cfg = arch.reduced if reduced else arch.config
    if reduced:
        shape = reduce_shape(arch, shape)
    if config_overrides:
        cfg = dataclasses.replace(cfg, **config_overrides)
    opt = opt_cfg or (SMOKE_OPT if reduced else DEFAULT_OPT)
    if arch.family == "lm":
        return _lm_bundle(arch, shape, cfg, opt)
    if arch.family == "gnn":
        return _gnn_bundle(arch, shape, cfg, opt, reduced)
    return _rec_bundle(arch, shape, cfg, opt)
