"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --shape train_4k [--reduced] [--steps 100] [--ckpt-dir DIR]

The restart loop around the train step: checkpoint periodically (async),
watch step times (straggler mitigation), and on failure restore from the
last committed checkpoint — optionally onto a *smaller* mesh via the
elastic planner (`--simulate-failure` demonstrates the path end-to-end on
CPU).  On a real cluster this binary runs once per host under the usual
TPU runtime; jax.distributed handles cross-host init.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.elastic import StepTimer, plan_mesh
from repro.launch import steps as steps_mod
from repro.training import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="step at which to simulate a crash + restore")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    bundle = steps_mod.build(arch, args.shape, reduced=args.reduced)
    if bundle.kind != "train":
        raise SystemExit(f"{args.arch}/{args.shape} is a serving shape")

    params = bundle.init_fn(jax.random.PRNGKey(0))
    opt_state = train_loop.init_state(
        bundle.opt_cfg or steps_mod.SMOKE_OPT, params)
    step_fn = jax.jit(bundle.step_fn, donate_argnums=(0, 1))
    ckpt = Checkpointer(args.ckpt_dir)
    timer = StepTimer()

    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), extra = ckpt.restore(latest, (params, opt_state))
        start = extra.get("data_step", latest) + 1
        print(f"resumed from checkpoint step {latest}")

    step = start
    while step < args.steps:
        batch = bundle.make_batch(jax.random.PRNGKey(10_000 + step))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        advice = timer.record(time.perf_counter() - t0)
        if advice == "checkpoint":
            print(f"[watchdog] persistent straggler at step {step}: "
                  f"snapshotting")
            ckpt.save(step, (params, opt_state),
                      extra=dict(data_step=step), blocking=True)
        if step % 10 == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f}")
        if step % args.ckpt_every == args.ckpt_every - 1:
            ckpt.save(step, (params, opt_state),
                      extra=dict(data_step=step), blocking=False)
        if args.simulate_failure and step == args.simulate_failure:
            ckpt.wait()
            latest = ckpt.latest_step()
            print(f"[failure injected] restoring from step {latest}; "
                  f"elastic plan for 448 devices: "
                  f"{plan_mesh(448, prior_data_parallel=16)}")
            if latest is not None:
                (params, opt_state), extra = ckpt.restore(
                    latest, (params, opt_state))
                step = extra["data_step"]
            args.simulate_failure = 0  # only once
        step += 1
    ckpt.wait()
    print(f"done at step {step}; median step time {timer.median:.3f}s")


if __name__ == "__main__":
    main()
