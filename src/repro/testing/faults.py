"""Fault injection for the crash-safe index build.

A :class:`FaultPlan` is handed to ``build_index(...)`` /
``build_index_sharded(...)`` (the ``fault_plan=`` testing seam) and fires
at the two places a preempted build actually dies:

* **chunk boundaries** — ``chunk_boundary(i)`` is called right before the
  build processes source chunk ``i``; a configured chunk raises
  :class:`InjectedFault` (clean Python crash: committed checkpoints stay,
  in-memory progress is lost) or SIGKILLs the process outright (no
  ``finally`` blocks, no atexit — the subprocess driver
  ``tests/fault_injection_check.py`` uses this to model preemption);
* **mid-checkpoint-write** — ``pre_commit(step)`` runs inside
  ``Checkpointer.save`` after the step's files are fully written but
  *before* the atomic rename, so a configured step dies leaving exactly
  the ``.tmp`` dir the restore contract must ignore.

Plans are stateless and re-fire every time a configured point is reached;
a resumed run that must get *past* a fault point is given a fresh plan.
"""

from __future__ import annotations

import dataclasses
import os
import signal
from typing import Tuple


class InjectedFault(RuntimeError):
    """Deterministic crash raised by a :class:`FaultPlan`."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Where a build run should die.  All fields are global chunk indices
    (or checkpoint step numbers, which the build keeps equal to the count
    of committed chunks)."""

    raise_at_chunks: Tuple[int, ...] = ()     # InjectedFault before chunk i
    raise_mid_commit: Tuple[int, ...] = ()    # InjectedFault pre-rename of
                                              # checkpoint step s
    kill_at_chunks: Tuple[int, ...] = ()      # SIGKILL before chunk i
    kill_mid_commit: Tuple[int, ...] = ()     # SIGKILL pre-rename of step s

    def chunk_boundary(self, chunk: int) -> None:
        """Called by the build immediately before processing ``chunk``."""
        if chunk in self.kill_at_chunks:
            os.kill(os.getpid(), signal.SIGKILL)
        if chunk in self.raise_at_chunks:
            raise InjectedFault(f"injected fault before chunk {chunk}")

    def pre_commit(self, step: int) -> None:
        """Called by the checkpointer between write-out and atomic rename."""
        if step in self.kill_mid_commit:
            os.kill(os.getpid(), signal.SIGKILL)
        if step in self.raise_mid_commit:
            raise InjectedFault(
                f"injected fault mid-commit of checkpoint step {step}"
            )
