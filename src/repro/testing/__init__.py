"""Test-support utilities shipped with the library (fault injection)."""

from repro.testing.faults import FaultPlan, InjectedFault

__all__ = ["FaultPlan", "InjectedFault"]
