"""Pallas TPU kernel: fused bulk walk advance with HBM-resident ``col_idx``.

One device step of the offline walk engine advances every cursor one edge:
gather the degree and CSR offset of each cursor, sample an out-edge, read its
destination, and send dangling walks back to their personalization source.
The jnp path does this with three ``jnp.take`` gathers; at billion-edge
scale the ``col_idx`` gather is the one that matters — it must not require
``col_idx`` resident in VMEM.

Same memory discipline as ``frontier_push.py`` (PR 3's DMA infrastructure,
reused directly):

* ``col_idx`` stays in ``pltpu.ANY`` (HBM), never blocked into VMEM.
* ``row_ptr``/``out_deg`` never enter the kernel: the launcher turns the
  cursors into per-walk ``deg`` + *sampled* edge addresses via two O(W)
  gathers and :func:`repro.core.walks.sample_edge_offsets` (the same
  edge-sampling law as the jnp engine, so kernel == jnp bit-for-bit under
  one key).  The clipped flat addresses ride in as the
  ``PrefetchScalarGridSpec`` scalar-prefetch argument — exactly the per-walk
  DMA offsets the kernel body needs in SMEM before it runs.
* Each grid step DMA-gathers only its tile's ``w_tile`` single-edge windows
  (``frontier_push.dma_pipeline`` depth-2 double buffering), then applies
  the dangling fix in registers.

VMEM per grid step is O(w_tile) — independent of ``n`` and ``nnz`` (see
:func:`vmem_bytes`).  ``interpret=True`` is the validated mode in this
container; pass ``interpret=False`` on a real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import walks as walks_mod
from repro.kernels.frontier_push import _dma_gather_windows


def vmem_bytes(w_tile: int) -> int:
    """Per-grid-step VMEM of the fused walk advance: deg/src/out tiles +
    the single-edge gather scratch.  Independent of ``n`` and ``nnz``."""
    return w_tile * 4 * 3 + w_tile * 4


def _walk_step_kernel(addr_ref, deg_ref, src_ref, col_hbm, out_ref,
                      scratch, sem):
    i = pl.program_id(0)
    w_tile = deg_ref.shape[1]
    # one width-1 window per walk: scratch[r, 0] <- col_idx[addr[base + r]]
    _dma_gather_windows(
        col_hbm, addr_ref, scratch, sem, rows=w_tile, h=1, base=i * w_tile
    )
    nxt = scratch[...].reshape(1, w_tile)
    deg = deg_ref[...]
    out_ref[...] = jnp.where(deg == 0, src_ref[...], nxt)


@functools.partial(jax.jit, static_argnames=("w_tile", "interpret"))
def walk_step(
    cursors: jax.Array,
    sources: jax.Array,
    u: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
    *,
    w_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused degree-gather + edge-sample + dangling-fix for ``W`` walks.

    cursors/sources: int32[W]; u: f32[W] uniform edge-choice draws.  ``W``
    must be a multiple of ``w_tile`` (``ops.walk_step`` is the padding
    wrapper).  Requires ``col_idx`` non-empty (the edgeless case is the
    wrapper's jnp fallback).  Returns the next cursors, int32[W] — equal to
    :func:`repro.core.walks.advance_cursors` bit-for-bit.
    """
    (w,) = cursors.shape
    assert sources.shape == (w,) and u.shape == (w,)
    assert w % w_tile == 0, (w, w_tile)
    m = col_idx.shape[0]
    cur32 = cursors.astype(jnp.int32)
    deg = jnp.take(out_deg, cur32).astype(jnp.int32)
    start = jnp.take(row_ptr, cur32).astype(jnp.int32)
    # the edge-sample: same law as the jnp engine (bitwise parity); dangling
    # rows get a clipped dummy address, overwritten by the in-kernel fix
    addr = jnp.clip(
        start + walks_mod.sample_edge_offsets(u, deg), 0, m - 1
    )
    tiles = w // w_tile
    deg2d = deg.reshape(tiles, w_tile)
    src2d = sources.reshape(tiles, w_tile).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # the flat sampled addresses
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, w_tile), lambda i, a: (i, 0)),
            pl.BlockSpec((1, w_tile), lambda i, a: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # col_idx: HBM resident
        ],
        out_specs=pl.BlockSpec((1, w_tile), lambda i, a: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((w_tile, 1), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        _walk_step_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((tiles, w_tile), jnp.int32),
        interpret=interpret,
    )(addr, deg2d, src2d, col_idx)
    return out.reshape(w)


# ---------------------------------------------------------------------------
# Contract-auditor entry point (repro.analysis): col_idx rides as an
# ANY/HBM ref and every VMEM block stays O(w_tile).
# ---------------------------------------------------------------------------

from repro.analysis.registry import register_entry_point as _register_ep


def _contract_spec_walk_step():
    import functools

    import numpy as np
    from repro.graphs import synthetic

    rng = np.random.default_rng(0)
    n, w, w_tile = 4096, 256, 128
    g = synthetic.erdos_renyi(n, 5.0, seed=13)
    cur = jnp.asarray(rng.integers(0, n, w), jnp.int32)
    src = jnp.asarray(rng.integers(0, n, w), jnp.int32)
    u = jnp.asarray(rng.random(w), jnp.float32)
    return dict(
        fn=functools.partial(walk_step, w_tile=w_tile, interpret=True),
        args=(cur, src, u, g.row_ptr, g.out_deg, g.col_idx),
        hbm_shapes=[(g.m,)],
        vmem_budget=vmem_bytes(w_tile) // 4 + w_tile,
    )


_register_ep("walk-step", "hbm-residency",
             "src/repro/kernels/walk_step.py", _contract_spec_walk_step)
