"""Pallas TPU kernels: HBM-resident sparse-frontier gather-push.

Two kernels share the DMA-gather machinery: :func:`frontier_push` is the
single-device fused push (gather + merge + compact), and
:func:`sharded_frontier_push` is the distributed half-iteration (local
gather + per-owner top-k exchange buckets) used by
``core/distributed_engine.py``'s sparse wire format.  Both support ELL hub
splitting (``hub_split_degree``) so no gather axis exceeds the split width.

Memory layout (the PowerWalk discipline: one iteration touches only the
frontier's out-edges, never the graph):

* ``col_idx`` stays in ``pltpu.ANY`` (HBM) — it is never blocked into VMEM.
* The CSR ``row_ptr``/``out_deg`` arrays never enter the kernel at all: the
  launcher turns them into per-slot ``start``/``deg`` via two O(Q*K)
  gathers, and the per-sub-slot gather-window starts
  (:func:`repro.core.verd.push_window_starts`) ride in as a
  ``PrefetchScalarGridSpec`` scalar-prefetch argument, available in SMEM
  before the kernel body runs — exactly what the per-slot DMA addresses
  need.
* Each grid step DMA-gathers only the width-``h`` edge windows its
  ``q_tile`` frontier rows touch (``make_async_copy`` HBM -> VMEM scratch,
  depth-2 double-buffered), then masks them with the same
  :func:`repro.core.verd.masked_push_from_windows` math the jnp path uses.

VMEM per step is therefore O(q_tile * K * s * h) — independent of ``n`` and
``nnz`` (see :func:`vmem_bytes` / :func:`vmem_bytes_legacy` for the
before/after accounting).  ``interpret=True`` (the validated mode in this
container) runs the same DMA schedule through the Pallas interpreter; on a
real TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import frontier as frontier_mod
from repro.core import verd as verd_mod


def dma_pipeline(rows, make_dmas):
    """Depth-2 pipelined DMA drain: the one double-buffer schedule every
    gather kernel here shares.

    ``make_dmas(r)`` returns the async copies of pipeline row ``r`` (each
    built with its own ``sem.at[..., r % 2]`` slot, so two rows may be in
    flight).  Row ``r + 1``'s copies are started before waiting on row
    ``r``'s, overlapping HBM latency with the previous row's drain.
    """
    for dma in make_dmas(0):
        dma.start()

    def body(r, carry):
        @pl.when(r + 1 < rows)
        def _start_next():
            for dma in make_dmas(r + 1):
                dma.start()

        for dma in make_dmas(r):
            dma.wait()
        return carry

    jax.lax.fori_loop(0, rows, body, 0)


def _dma_gather_windows(col_hbm, win_ref, scratch, sem, *, rows, h, base):
    """DMA gather of ``rows`` width-``h`` edge windows via
    :func:`dma_pipeline`: ``scratch[r] <- col_idx[win[base + r] : + h]``.
    ``win_ref`` is the scalar-prefetched flat window-start array (SMEM),
    ``base`` the first window of this grid step."""

    def make_dmas(r):
        return (pltpu.make_async_copy(
            col_hbm.at[pl.ds(win_ref[base + r], h)],
            scratch.at[r],
            sem.at[r % 2],
        ),)

    dma_pipeline(rows, make_dmas)


def vmem_bytes(
    q_tile: int, k: int, k_out: int, *,
    degree_cap: int, hub_split_degree: int = 0,
) -> int:
    """Per-grid-step VMEM of the HBM-resident push: frontier blocks +
    gather scratch + outputs.  Independent of ``n`` and ``nnz``."""
    h, s = verd_mod.resolve_hub_splits(degree_cap, hub_split_degree)
    blocks = q_tile * k * 12 + q_tile * 4      # fv f32 + start/deg i32 + src
    scratch = q_tile * k * s * h * 4           # gathered edge windows
    return blocks + scratch + q_tile * k_out * 8


def vmem_bytes_legacy(
    q_tile: int, k: int, k_out: int, *,
    n: int, m: int, degree_cap: int, hub_split_degree: int = 0,
) -> int:
    """What the pre-HBM-resident kernel held per step: the same tiles plus
    the whole CSR (``row_ptr``/``out_deg``/``col_idx``) as resident
    whole-array blocks — O(nnz) VMEM that made ``interpret=False``
    impossible at scale."""
    csr = (n + 1) * 4 + n * 4 + m * 4
    return vmem_bytes(
        q_tile, k, k_out,
        degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    ) + csr


def _dma_gathered_push(
    win_ref, fv_ref, start_ref, deg_ref, col_hbm, scratch, sem, *,
    c: float, degree_cap: int, hub_split_degree: int, m: int,
):
    """The gather half both kernel bodies share: DMA this grid step's edge
    windows out of HBM and mask them into ``(push_v, nbrs)`` candidates.
    Also returns the tile's ``(fv, deg)`` for the callers' epilogues
    (dangling mass / bucketing)."""
    i = pl.program_id(0)
    q_tile, k = fv_ref.shape
    h, s = verd_mod.resolve_hub_splits(degree_cap, hub_split_degree)
    rows = q_tile * k * s
    _dma_gather_windows(
        col_hbm, win_ref, scratch, sem, rows=rows, h=h, base=i * rows
    )
    fv, start, deg = fv_ref[...], start_ref[...], deg_ref[...]
    # recompute the (clipped) window starts for the masking math — the same
    # pure function that produced the prefetched DMA addresses
    windows = verd_mod.push_window_starts(
        start, degree_cap=degree_cap, hub_split_degree=hub_split_degree, m=m
    )
    gathered = scratch[...].reshape(q_tile, k, s, h)
    push_v, nbrs = verd_mod.masked_push_from_windows(
        fv, deg, start, windows, gathered,
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )
    return fv, deg, push_v, nbrs


def _frontier_push_kernel(
    win_ref, fv_ref, start_ref, deg_ref, src_ref, col_hbm,
    ov_ref, oi_ref, nbr_scratch, sem, *,
    c: float, degree_cap: int, threshold: float, hub_split_degree: int,
    m: int,
):
    fv, deg, push_v, nbrs = _dma_gathered_push(
        win_ref, fv_ref, start_ref, deg_ref, col_hbm, nbr_scratch, sem,
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree, m=m,
    )
    dm = jnp.sum(jnp.where(deg == 0, fv, 0.0), axis=1)  # dangling mass
    cand_v = jnp.concatenate([push_v, (1.0 - c) * dm[:, None]], axis=1)
    cand_i = jnp.concatenate([nbrs, src_ref[...]], axis=1)
    ov, oi = frontier_mod.compact_arrays(
        cand_v, cand_i, ov_ref.shape[1], threshold=threshold
    )
    ov_ref[...] = ov
    oi_ref[...] = oi


@functools.partial(
    jax.jit,
    static_argnames=("c", "degree_cap", "threshold", "k_out", "q_tile",
                     "hub_split_degree", "interpret"),
)
def frontier_push(
    fv: jax.Array,
    fi: jax.Array,
    sources: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    k_out: int,
    threshold: float = 0.0,
    q_tile: int = 8,
    hub_split_degree: int = 0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused sparse push; Q must be a multiple of ``q_tile`` (see
    ``ops.frontier_push`` for the padding wrapper).  ``hub_split_degree``
    bounds the per-sub-slot gather width (ELL hub splitting) without
    changing the result.  Requires ``col_idx`` non-empty (the edgeless case
    is the wrapper's jnp fallback)."""
    q, k = fv.shape
    assert fi.shape == (q, k) and sources.shape[0] == q
    assert q % q_tile == 0, (q, q_tile)
    m = col_idx.shape[0]
    degree_cap = min(degree_cap, max(m, 1))  # no row has more than m edges
    h, s = verd_mod.resolve_hub_splits(degree_cap, hub_split_degree)
    fi32 = fi.astype(jnp.int32)
    # per-slot CSR offsets: two O(Q*K) gathers — row_ptr/out_deg themselves
    # never enter the kernel
    start = jnp.take(row_ptr, fi32).astype(jnp.int32)
    deg = jnp.take(out_deg, fi32).astype(jnp.int32)
    windows = verd_mod.push_window_starts(
        start, degree_cap=degree_cap, hub_split_degree=hub_split_degree, m=m
    ).reshape(-1)
    src2d = sources.reshape(q, 1).astype(jnp.int32)
    kernel = functools.partial(
        _frontier_push_kernel, c=c, degree_cap=degree_cap,
        threshold=threshold, hub_split_degree=hub_split_degree, m=m,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # the flat window starts
        grid=(q // q_tile,),
        in_specs=[
            pl.BlockSpec((q_tile, k), lambda i, w: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i, w: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i, w: (i, 0)),
            pl.BlockSpec((q_tile, 1), lambda i, w: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # col_idx: HBM resident
        ],
        out_specs=[
            pl.BlockSpec((q_tile, k_out), lambda i, w: (i, 0)),
            pl.BlockSpec((q_tile, k_out), lambda i, w: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_tile * k * s, h), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q, k_out), jnp.float32),
            jax.ShapeDtypeStruct((q, k_out), jnp.int32),
        ],
        interpret=interpret,
    )(windows, fv, start, deg, src2d, col_idx)


# ---------------------------------------------------------------------------
# sharded push: local gather + per-owner top-k buckets (the pre-exchange
# compute of the distributed sparse wire format)
# ---------------------------------------------------------------------------

def _sharded_push_kernel(
    win_ref, fv_ref, start_ref, deg_ref, col_hbm, ov_ref, oi_ref,
    nbr_scratch, sem, *,
    c: float, degree_cap: int, hub_split_degree: int, ep: int,
    n_shard: int, m: int,
):
    _, _, push_v, nbrs = _dma_gathered_push(
        win_ref, fv_ref, start_ref, deg_ref, col_hbm, nbr_scratch, sem,
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree, m=m,
    )
    bv, bi = frontier_mod.bucket_by_owner(
        push_v, nbrs, ep, n_shard, ov_ref.shape[2]
    )
    ov_ref[...] = bv
    oi_ref[...] = bi


@functools.partial(
    jax.jit,
    static_argnames=("c", "degree_cap", "hub_split_degree", "ep", "n_shard",
                     "wire_k", "q_tile", "interpret"),
)
def sharded_frontier_push(
    fv: jax.Array,
    fi: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    ep: int,
    n_shard: int,
    wire_k: int,
    hub_split_degree: int = 0,
    q_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One shard's half-iteration of the distributed sparse exchange.

    ``fv/fi f32|int32[Q, K]``: the shard's local frontier slice (indices are
    local row ids).  ``row_ptr int32[n_shard + 1]`` / ``col_idx int32[m]``:
    the shard's CSR slab, destination ids global.  ``row_ptr`` is consumed
    outside the kernel (per-slot ``start``/``deg`` gathers + the
    scalar-prefetched window starts); ``col_idx`` stays HBM resident and is
    DMA-gathered per grid step.  Emits the per-owner top-``wire_k`` exchange
    buckets ``(vals f32[Q, ep, wire_k], idx int32[Q, ep, wire_k])`` with
    owner-local indices — exactly what ``all_to_all`` puts on the wire.
    Dangling mass is the caller's business (it needs a cross-shard psum).
    Same grid/tiling contract as :func:`frontier_push`; Q must be a multiple
    of ``q_tile``.
    """
    q, k = fv.shape
    assert fi.shape == (q, k)
    assert q % q_tile == 0, (q, q_tile)
    m = col_idx.shape[0]
    degree_cap = min(degree_cap, max(m, 1))
    h, s = verd_mod.resolve_hub_splits(degree_cap, hub_split_degree)
    fi32 = fi.astype(jnp.int32)
    local_deg = row_ptr[1:] - row_ptr[:-1]
    start = jnp.take(row_ptr, fi32).astype(jnp.int32)
    deg = jnp.take(local_deg, fi32).astype(jnp.int32)
    windows = verd_mod.push_window_starts(
        start, degree_cap=degree_cap, hub_split_degree=hub_split_degree, m=m
    ).reshape(-1)
    kernel = functools.partial(
        _sharded_push_kernel, c=c, degree_cap=degree_cap,
        hub_split_degree=hub_split_degree, ep=ep, n_shard=n_shard, m=m,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q // q_tile,),
        in_specs=[
            pl.BlockSpec((q_tile, k), lambda i, w: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i, w: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i, w: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # col_idx: HBM resident
        ],
        out_specs=[
            pl.BlockSpec((q_tile, ep, wire_k), lambda i, w: (i, 0, 0)),
            pl.BlockSpec((q_tile, ep, wire_k), lambda i, w: (i, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_tile * k * s, h), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q, ep, wire_k), jnp.float32),
            jax.ShapeDtypeStruct((q, ep, wire_k), jnp.int32),
        ],
        interpret=interpret,
    )(windows, fv, start, deg, col_idx)


# ---------------------------------------------------------------------------
# Contract-auditor entry points (repro.analysis): register both push kernels
# under the hbm-residency rule.  The builders are lazy — they construct tiny
# synthetic fixtures only when `python -m repro.analysis` runs the rule —
# and mirror tests/test_kernels.py's memory-contract parameters.
# ---------------------------------------------------------------------------

from repro.analysis.registry import register_entry_point as _register_ep


def _contract_spec_frontier_push():
    import numpy as np
    from repro.core import verd as verd_mod
    from repro.graphs import synthetic

    rng = np.random.default_rng(0)
    n, q, k, q_tile, k_out = 2048, 16, 8, 8, 16
    g = synthetic.erdos_renyi(n, 6.0, seed=7)
    cap = verd_mod.resolve_degree_cap(g)
    srcs = jnp.asarray(rng.integers(0, n, q), jnp.int32)
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32)
    h, s = verd_mod.resolve_hub_splits(cap, 0)
    return dict(
        fn=functools.partial(
            frontier_push, c=0.15, degree_cap=cap, k_out=k_out,
            q_tile=q_tile, interpret=True,
        ),
        args=(fv, fi, srcs, g.row_ptr, g.out_deg, g.col_idx),
        hbm_shapes=[(g.m,)],
        vmem_budget=q_tile * k * s * h + q_tile * max(k, k_out),
    )


def _contract_spec_sharded_push():
    import numpy as np
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph
    from repro.graphs import synthetic

    rng = np.random.default_rng(0)
    n, q, k, q_tile, wire_k = 2048, 16, 8, 4, 8
    g = synthetic.erdos_renyi(n, 6.0, seed=7)
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(n=n, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.clip(jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32), 0, ns - 1)
    m_shard = slabs.col_idx.shape[1]
    h, s = verd_mod.resolve_hub_splits(cap, 0)
    return dict(
        fn=functools.partial(
            sharded_frontier_push, c=0.15, degree_cap=cap, ep=2, n_shard=ns,
            wire_k=wire_k, q_tile=q_tile, interpret=True,
        ),
        args=(fv, fi, slabs.row_ptr[0], slabs.col_idx[0]),
        hbm_shapes=[(m_shard,)],
        vmem_budget=q_tile * k * s * h + q_tile * 2 * wire_k,
    )


_register_ep("frontier-push", "hbm-residency",
             "src/repro/kernels/frontier_push.py", _contract_spec_frontier_push)
_register_ep("sharded-frontier-push", "hbm-residency",
             "src/repro/kernels/frontier_push.py", _contract_spec_sharded_push)
