"""Pallas TPU kernels: sparse-frontier gather-push + top-K compaction.

Two kernels share the gather machinery: :func:`frontier_push` is the
single-device fused push (gather + merge + compact), and
:func:`sharded_frontier_push` is the distributed half-iteration (local
gather + per-owner top-k exchange buckets) used by
``core/distributed_engine.py``'s sparse wire format.  Both support ELL hub
splitting (``hub_split_degree``) so no gather axis exceeds the split width.

One VERD iteration on a fixed-width sparse frontier (``values f32[Q, K]`` +
``indices int32[Q, K]``), fused per query tile:

    1. gather: each frontier slot reads up to ``degree_cap`` out-edges of its
       vertex from the CSR arrays (``row_ptr``/``col_idx``/``out_deg``) and
       emits one weighted candidate per edge; dangling mass returns to the
       query's source,
    2. compact: duplicate destination hits are merged (sort + run-sum, see
       :func:`repro.core.frontier.merge_duplicates`) and the row is re-packed
       to the top-``k_out`` entries.

The grid is 1-D over query tiles; each step touches ``q_tile * (K *
degree_cap + 1)`` candidates — never a ``[Q, n]`` slab.  The CSR arrays ride
along as whole-array blocks: on a real TPU those belong in HBM with
scalar-prefetched row offsets and per-tile DMA (see
``PrefetchScalarGridSpec``); in this container the kernel is validated in
interpret mode, which is also the fallback registered in ``kernels.ops``.

VMEM per step: q_tile*K*8 (frontier) + q_tile*K*degree_cap*8 (candidates)
+ q_tile*k_out*8 (out) bytes, plus the resident CSR blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import frontier as frontier_mod
from repro.core import verd as verd_mod


def _frontier_push_kernel(
    fv_ref, fi_ref, src_ref, row_ptr_ref, out_deg_ref, col_idx_ref,
    ov_ref, oi_ref, *, c: float, degree_cap: int, threshold: float,
    hub_split_degree: int,
):
    # same array-level math as the jnp core op — single source of truth
    cand_v, cand_i = verd_mod.gather_push_candidates(
        fv_ref[...], fi_ref[...], src_ref[...],
        row_ptr_ref[...], out_deg_ref[...], col_idx_ref[...],
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )
    ov, oi = frontier_mod.compact_arrays(
        cand_v, cand_i, ov_ref.shape[1], threshold=threshold
    )
    ov_ref[...] = ov
    oi_ref[...] = oi


@functools.partial(
    jax.jit,
    static_argnames=("c", "degree_cap", "threshold", "k_out", "q_tile",
                     "hub_split_degree", "interpret"),
)
def frontier_push(
    fv: jax.Array,
    fi: jax.Array,
    sources: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    k_out: int,
    threshold: float = 0.0,
    q_tile: int = 8,
    hub_split_degree: int = 0,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused sparse push; Q must be a multiple of ``q_tile`` (see
    ``ops.frontier_push`` for the padding wrapper).  ``hub_split_degree``
    bounds the per-sub-slot gather width (ELL hub splitting) without
    changing the result."""
    q, k = fv.shape
    assert fi.shape == (q, k) and sources.shape[0] == q
    assert q % q_tile == 0, (q, q_tile)
    n1 = row_ptr.shape[0]
    n = out_deg.shape[0]
    m = col_idx.shape[0]
    src2d = sources.reshape(q, 1).astype(jnp.int32)
    grid = (q // q_tile,)
    kernel = functools.partial(
        _frontier_push_kernel, c=c, degree_cap=degree_cap,
        threshold=threshold, hub_split_degree=hub_split_degree,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((q_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((n1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((q_tile, k_out), lambda i: (i, 0)),
            pl.BlockSpec((q_tile, k_out), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k_out), jnp.float32),
            jax.ShapeDtypeStruct((q, k_out), jnp.int32),
        ],
        interpret=interpret,
    )(fv, fi, src2d, row_ptr, out_deg, col_idx)


# ---------------------------------------------------------------------------
# sharded push: local gather + per-owner top-k buckets (the pre-exchange
# compute of the distributed sparse wire format)
# ---------------------------------------------------------------------------

def _sharded_push_kernel(
    fv_ref, fi_ref, row_ptr_ref, col_idx_ref, ov_ref, oi_ref,
    *, c: float, degree_cap: int, hub_split_degree: int, ep: int,
    n_shard: int,
):
    fv, fi = fv_ref[...], fi_ref[...]
    rp = row_ptr_ref[...]
    local_deg = rp[1:] - rp[:-1]
    push_v, nbrs = verd_mod.gather_push_edges(
        fv, fi, jnp.take(rp, fi), jnp.take(local_deg, fi), col_idx_ref[...],
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )
    bv, bi = frontier_mod.bucket_by_owner(
        push_v, nbrs, ep, n_shard, ov_ref.shape[2]
    )
    ov_ref[...] = bv
    oi_ref[...] = bi


@functools.partial(
    jax.jit,
    static_argnames=("c", "degree_cap", "hub_split_degree", "ep", "n_shard",
                     "wire_k", "q_tile", "interpret"),
)
def sharded_frontier_push(
    fv: jax.Array,
    fi: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    ep: int,
    n_shard: int,
    wire_k: int,
    hub_split_degree: int = 0,
    q_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One shard's half-iteration of the distributed sparse exchange.

    ``fv/fi f32|int32[Q, K]``: the shard's local frontier slice (indices are
    local row ids).  ``row_ptr int32[n_shard + 1]`` / ``col_idx int32[m]``:
    the shard's CSR slab, destination ids global.  Emits the per-owner
    top-``wire_k`` exchange buckets ``(vals f32[Q, ep, wire_k], idx
    int32[Q, ep, wire_k])`` with owner-local indices — exactly what
    ``all_to_all`` puts on the wire.  Dangling mass is the caller's
    business (it needs a cross-shard psum).  Same grid/tiling contract as
    :func:`frontier_push`; Q must be a multiple of ``q_tile``.
    """
    q, k = fv.shape
    assert fi.shape == (q, k)
    assert q % q_tile == 0, (q, q_tile)
    n1 = row_ptr.shape[0]
    m = col_idx.shape[0]
    grid = (q // q_tile,)
    kernel = functools.partial(
        _sharded_push_kernel, c=c, degree_cap=degree_cap,
        hub_split_degree=hub_split_degree, ep=ep, n_shard=n_shard,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i: (i, 0)),
            pl.BlockSpec((n1,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((q_tile, ep, wire_k), lambda i: (i, 0, 0)),
            pl.BlockSpec((q_tile, ep, wire_k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, ep, wire_k), jnp.float32),
            jax.ShapeDtypeStruct((q, ep, wire_k), jnp.int32),
        ],
        interpret=interpret,
    )(fv, fi, row_ptr, col_idx)
