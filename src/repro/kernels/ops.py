"""Jit'd public wrappers around the Pallas kernels.

These handle tile padding, fold hub-split ELL rows back to vertices, and
expose drop-in replacements for the pure-jnp core ops:

* :func:`ell_push`      <-> :func:`repro.graphs.formats.ell_pull`
* :func:`index_combine` <-> :func:`repro.core.verd.combine_with_index`
* :func:`frontier_push` <-> :func:`repro.core.verd.sparse_push_candidates`
  (+ :func:`repro.core.frontier.compact`)
* :func:`sharded_frontier_push` <-> :func:`repro.core.verd.gather_push_edges`
  (+ :func:`repro.core.frontier.bucket_by_owner`) — the distributed wire step
* :func:`index_combine_sparse` <-> :func:`repro.core.verd.combine_with_index_sparse`
* :func:`walk_step` <-> :func:`repro.core.walks.advance_cursors` (jnp path) —
  the offline walk engine's fused bulk advance
* :func:`embedding_bag` <-> :func:`repro.models.recsys.embedding` bag path

``interpret=True`` (default here) runs the kernel bodies in Python on CPU —
the validation mode for this container; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.core import frontier as _frontier
from repro.core import verd as _verd
from repro.core.frontier import SparseFrontier
from repro.core.graph import Graph
from repro.graphs.formats import EllChunks
from repro.kernels import ell_spmm as _ell
from repro.kernels import embedding_bag as _bag
from repro.kernels import frontier_push as _push
from repro.kernels import index_combine as _comb
from repro.kernels import walk_step as _walk


# Trace-time invocation counts per wrapper: incremented when a wrapper body
# runs, i.e. once per jit trace (cached re-executions of a traced graph do
# not re-count).  "Did this path go through the fused kernel?" is exactly a
# trace-time question, which is what the engine-routing regression in
# tests/test_parity.py asserts.
_invocations: collections.Counter = collections.Counter()


def kernel_invocations() -> dict:
    """Snapshot of the per-wrapper trace-time invocation counts."""
    return dict(_invocations)


def reset_kernel_invocations() -> None:
    _invocations.clear()


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("q_tile", "r_tile", "interpret")
)
def ell_push(
    frontier: jax.Array,
    ell: EllChunks,
    *,
    q_tile: int = 8,
    r_tile: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """``frontier @ A0`` via the Pallas kernel; f32[Q, n] -> f32[Q, n].

    Pads Q and the ELL rows to tile multiples, then folds hub chunks with a
    segment-sum keyed by ``row2vertex``.
    """
    q, n = frontier.shape
    f = _pad_to(frontier, 0, q_tile)
    nbr = _pad_to(ell.nbr, 0, r_tile)
    w = _pad_to(ell.weight, 0, r_tile)
    r2v = _pad_to(ell.row2vertex, 0, r_tile)  # pad rows -> vertex 0, weight 0
    partial = _ell.ell_spmm(
        f, nbr, w, q_tile=q_tile, r_tile=r_tile, interpret=interpret
    )
    out = jax.ops.segment_sum(partial.T, r2v, num_segments=n).T
    return out[:q]


@functools.partial(
    jax.jit, static_argnames=("q_tile", "v_tile", "interpret")
)
def index_combine(
    s: jax.Array,
    f: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    q_tile: int = 8,
    v_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused ``s + f @ P_hat``; pads Q and the vertex axis to tiles."""
    q, n = s.shape
    s_p = _pad_to(s, 0, q_tile)
    f_p = _pad_to(_pad_to(f, 0, q_tile), 1, v_tile)
    vals_p = _pad_to(vals, 0, v_tile)
    idx_p = _pad_to(idx, 0, v_tile)
    out = _comb.index_combine(
        s_p, f_p, vals_p, idx_p, q_tile=q_tile, v_tile=v_tile,
        interpret=interpret,
    )
    return out[:q]


def frontier_push(
    f: SparseFrontier,
    graph: Graph,
    sources: jax.Array,
    *,
    c: float,
    degree_cap: int,
    k_out: int,
    threshold: float = 0.0,
    q_tile: int = 8,
    hub_split_degree: int = 0,
    interpret: bool = True,
) -> SparseFrontier:
    """One fused sparse VERD push via the Pallas kernel; pads Q to the tile.

    Drop-in for ``verd.sparse_push_candidates`` + ``frontier.compact``:
    returns the new frontier, compacted to ``k_out``.
    """
    if graph.m == 0:  # edgeless graph: nothing to gather, pure jnp path
        cv, ci = _verd.sparse_push_candidates(
            graph, f.values, f.indices, sources, c=c, degree_cap=degree_cap
        )
        return _frontier.compact(
            cv, ci, k_out, graph.n, threshold=threshold
        )
    _invocations["frontier_push"] += 1  # counted only when the kernel runs
    q = f.values.shape[0]
    fv = _pad_to(f.values, 0, q_tile)
    fi = _pad_to(f.indices, 0, q_tile)
    src = _pad_to(sources.astype(jnp.int32), 0, q_tile)
    ov, oi = _push.frontier_push(
        fv, fi, src, graph.row_ptr, graph.out_deg, graph.col_idx,
        c=c, degree_cap=degree_cap, k_out=k_out, threshold=threshold,
        q_tile=q_tile, hub_split_degree=hub_split_degree,
        interpret=interpret,
    )
    return SparseFrontier(
        values=ov[:q], indices=oi[:q], k=k_out, n=graph.n
    )


def sharded_frontier_push(
    fv: jax.Array,
    fi: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    ep: int,
    n_shard: int,
    wire_k: int,
    hub_split_degree: int = 0,
    q_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One shard's local push + per-owner exchange buckets; pads Q.

    Drop-in for the pre-``all_to_all`` compute of the distributed sparse
    wire format (``verd.gather_push_edges`` + ``frontier.bucket_by_owner``);
    returns ``(vals f32[Q, ep, wire_k], idx int32[Q, ep, wire_k])`` with
    owner-local indices.
    """
    _invocations["sharded_frontier_push"] += 1
    q = fv.shape[0]
    fv_p = _pad_to(fv, 0, q_tile)
    fi_p = _pad_to(fi, 0, q_tile)
    ov, oi = _push.sharded_frontier_push(
        fv_p, fi_p, row_ptr, col_idx,
        c=c, degree_cap=degree_cap, ep=ep, n_shard=n_shard, wire_k=wire_k,
        hub_split_degree=hub_split_degree, q_tile=q_tile,
        interpret=interpret,
    )
    return ov[:q], oi[:q]


def index_combine_sparse(
    s: SparseFrontier,
    f: SparseFrontier,
    vals: jax.Array,
    idx: jax.Array,
    *,
    k_out: int,
    q_tile: int = 8,
    interpret: bool = True,
) -> SparseFrontier:
    """Fused sparse ``s + f @ P_hat`` + top-k via the Pallas kernel; pads Q.

    Drop-in for ``verd.combine_with_index_sparse`` at ``out_k=k_out``.
    """
    _invocations["index_combine_sparse"] += 1
    q = f.values.shape[0]
    sv = _pad_to(s.values, 0, q_tile)
    si = _pad_to(s.indices, 0, q_tile)
    fv = _pad_to(f.values, 0, q_tile)
    fi = _pad_to(f.indices, 0, q_tile)
    ov, oi = _comb.index_combine_sparse(
        sv, si, fv, fi, vals, idx, k_out=k_out, q_tile=q_tile,
        interpret=interpret,
    )
    n = vals.shape[0]
    return SparseFrontier(values=ov[:q], indices=oi[:q], k=k_out, n=n)


def walk_step(
    cursors: jax.Array,
    sources: jax.Array,
    u: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
    *,
    w_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """One fused bulk walk advance via the Pallas kernel; pads W to the tile.

    Drop-in for the jnp path of :func:`repro.core.walks.advance_cursors`
    (bit-identical under the same uniforms): accepts any cursor shape,
    flattens, pads the walk axis with harmless dangling-style rows (pad
    cursors/sources are vertex 0 — their sampled address is clipped in
    range and the result rows are sliced off), and restores the shape.
    """
    if col_idx.shape[0] == 0:  # edgeless graph: every walk jumps home
        return jnp.broadcast_to(sources, cursors.shape).astype(jnp.int32)
    _invocations["walk_step"] += 1
    shape = cursors.shape
    cur = cursors.reshape(-1)
    src = jnp.broadcast_to(sources, shape).reshape(-1)
    uu = u.reshape(-1)
    w = cur.shape[0]
    cur_p = _pad_to(cur, 0, w_tile)
    src_p = _pad_to(src, 0, w_tile)
    u_p = _pad_to(uu, 0, w_tile)
    out = _walk.walk_step(
        cur_p, src_p, u_p, row_ptr, out_deg, col_idx,
        w_tile=w_tile, interpret=interpret,
    )
    return out[:w].reshape(shape)


@functools.partial(
    jax.jit, static_argnames=("b_tile", "d_tile", "interpret")
)
def embedding_bag(
    ids: jax.Array,
    mask: jax.Array,
    table: jax.Array,
    *,
    b_tile: int = 64,
    d_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Bag-sum lookup; pads batch and embedding dims to tiles."""
    b, _ = ids.shape
    v, d = table.shape
    ids_p = _pad_to(ids, 0, b_tile)
    mask_p = _pad_to(mask, 0, b_tile)
    d_t = min(d_tile, d) if d % min(d_tile, d) == 0 else d
    table_p = _pad_to(table, 1, d_t)
    out = _bag.embedding_bag(
        ids_p, mask_p, table_p, b_tile=b_tile, d_tile=d_t,
        interpret=interpret,
    )
    return out[:b, :d]
