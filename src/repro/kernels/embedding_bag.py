"""Pallas TPU kernel: embedding-bag (multi-hot gather + in-bag reduce).

JAX has no native ``nn.EmbeddingBag``; the framework's production path is
``jnp.take`` + mask + sum (see :mod:`repro.models.recsys.embedding`), and
this kernel is the fused VMEM-tiled version for the *sharded* case: after
row-sharding a 10^6..10^9-row table over the ``model`` axis each shard holds
a few thousand rows — small enough to pin in VMEM — and looks up only
locally-resident ids (non-local slots arrive masked-out; partial bags are
summed with a psum by the caller).

    out[b, :] = sum_i mask[b, i] * table[ids[b, i], :]

Grid: ``(bag_blocks, d_blocks)``; the table is blocked over the embedding
dim only (``(V_local, d_tile)``), so VMEM = V_local*d_tile*4 +
b_tile*bag*8 + b_tile*d_tile*4 bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _embedding_bag_kernel(ids_ref, mask_ref, table_ref, o_ref):
    ids = ids_ref[...]                    # [b_tile, bag]
    mask = mask_ref[...]                  # [b_tile, bag]
    table = table_ref[...]                # [v_local, d_tile]
    b_tile, bag = ids.shape
    rows = jnp.take(table, ids.reshape(-1), axis=0)      # [b_tile*bag, d_tile]
    rows = rows.reshape(b_tile, bag, -1) * mask[:, :, None]
    o_ref[...] = rows.sum(axis=1).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("b_tile", "d_tile", "interpret")
)
def embedding_bag(
    ids: jax.Array,
    mask: jax.Array,
    table: jax.Array,
    *,
    b_tile: int = 64,
    d_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused bag-sum ``f32[B, D]``; inputs must be tile-aligned."""
    b, bag = ids.shape
    v, d = table.shape
    assert mask.shape == (b, bag)
    assert b % b_tile == 0 and d % d_tile == 0, (b, d, b_tile, d_tile)
    grid = (b // b_tile, d // d_tile)
    return pl.pallas_call(
        _embedding_bag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_tile, bag), lambda i, j: (i, 0)),
            pl.BlockSpec((b_tile, bag), lambda i, j: (i, 0)),
            pl.BlockSpec((v, d_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b_tile, d_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(ids, mask, table)
