"""Pallas TPU kernel: chunked-ELL frontier push (one VERD iteration's SpMM).

The VERD hot loop is ``F @ A`` for a dense query-frontier ``F[Q, n]`` and the
sparse transition ``A``.  In chunked-ELL form (see
:mod:`repro.graphs.formats`) each ELL row holds up to ``K`` in-edges of one
destination vertex, so the kernel computes

    partial[q, r] = sum_k  w[r, k] * F[q, nbr[r, k]]

a gather + multiply + K-reduction; duplicate rows of hub vertices are folded
outside with a segment-sum (``ops.ell_spmm_apply``).

TPU adaptation notes (vs. the paper's PowerGraph scatter):
* PowerGraph scatters tiny ``f_map`` packets per edge over Ethernet; here one
  VMEM-resident tile of ``F`` serves an entire block of destinations — the
  "bulk transfer" insight implemented as tiling instead of message batching.
* BlockSpec keeps a ``(q_tile, n)`` slab of ``F`` in VMEM: the gather never
  leaves the chip.  VMEM budget = q_tile*n*4 + r_tile*K*8 + q_tile*r_tile*4
  bytes; the wrapper asserts it fits a 16 MiB budget.  At n beyond ~4e5 the
  vertex-sharded distributed path splits ``F`` columns over the mesh first
  (each shard pulls only its local columns), so the kernel bound binds per
  *shard*, not per graph.
* The K-reduction is laid out so the compiler sees a static inner loop
  (K is a compile-time constant, typically 16/32) that vectorizes on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def _ell_spmm_kernel(f_ref, nbr_ref, w_ref, o_ref):
    f = f_ref[...]                      # [q_tile, n]
    nbr = nbr_ref[...]                  # [r_tile, K]
    w = w_ref[...]                      # [r_tile, K]
    q_tile = f.shape[0]
    r_tile, k = nbr.shape
    gathered = jnp.take(f, nbr.reshape(-1), axis=1)       # [q_tile, r_tile*K]
    gathered = gathered.reshape(q_tile, r_tile, k)
    o_ref[...] = jnp.sum(gathered * w[None, :, :], axis=-1).astype(o_ref.dtype)


def vmem_bytes(q_tile: int, r_tile: int, k: int, n: int) -> int:
    return q_tile * n * 4 + r_tile * k * 8 + q_tile * r_tile * 4


@functools.partial(
    jax.jit, static_argnames=("q_tile", "r_tile", "interpret")
)
def ell_spmm(
    f: jax.Array,
    nbr: jax.Array,
    w: jax.Array,
    *,
    q_tile: int = 8,
    r_tile: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Raw partials ``f32[Q, rows]``; inputs must already be tile-aligned."""
    q, n = f.shape
    rows, k = nbr.shape
    assert q % q_tile == 0 and rows % r_tile == 0, (q, rows, q_tile, r_tile)
    grid = (q // q_tile, rows // r_tile)
    return pl.pallas_call(
        _ell_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, n), lambda i, j: (i, 0)),
            pl.BlockSpec((r_tile, k), lambda i, j: (j, 0)),
            pl.BlockSpec((r_tile, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((q_tile, r_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, rows), f.dtype),
        interpret=interpret,
    )(f, nbr, w)
