"""Pallas TPU kernel: fused VERD index combine (Algorithm 4 line 10).

    out[q, :] = s[q, :] + sum_v f[q, v] * scatter(vals[v, :] at idx[v, :])

The vertex dimension is the reduction axis: the grid is ``(q_blocks,
v_blocks)`` with ``v`` innermost, and the output block (a full ``[q_tile, n]``
slab) is revisited across ``v`` steps — initialized from ``s`` at ``v == 0``
and accumulated in place afterwards (the standard Pallas reduction pattern).

Per grid step the kernel expands the ``[v_tile, L]`` index block against the
``[q_tile, v_tile]`` frontier block and scatter-adds ``q_tile`` rows at
``v_tile * L`` dynamic columns.  VMEM: q_tile*n*4 (out) + q_tile*n*4 (s,
v==0 only) + q_tile*v_tile*4 + v_tile*L*8 bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import frontier as frontier_mod
from repro.core import verd as verd_mod
from repro.kernels.frontier_push import dma_pipeline


def _index_combine_kernel(s_ref, f_ref, vals_ref, idx_ref, o_ref):
    vj = pl.program_id(1)

    @pl.when(vj == 0)
    def _init():
        o_ref[...] = s_ref[...]

    f = f_ref[...]                        # [q_tile, v_tile]
    vals = vals_ref[...]                  # [v_tile, L]
    idx = idx_ref[...]                    # [v_tile, L]
    q_tile = f.shape[0]
    contrib = f[:, :, None] * vals[None, :, :]        # [q_tile, v_tile, L]
    acc = o_ref[...]
    acc = acc.at[:, idx.reshape(-1)].add(
        contrib.reshape(q_tile, -1).astype(acc.dtype)
    )
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("q_tile", "v_tile", "interpret")
)
def index_combine(
    s: jax.Array,
    f: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    q_tile: int = 8,
    v_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused combine; inputs must be tile-aligned (see ops.index_combine).

    ``f``'s column axis (vertices, length nv) and ``s``'s column axis (output
    vertex ids, length n) are distinct: nv may be padded past n.
    """
    q, nv = f.shape
    n = s.shape[1]
    l = vals.shape[1]
    assert s.shape[0] == q and idx.shape == (nv, l) and vals.shape == (nv, l)
    assert q % q_tile == 0 and nv % v_tile == 0, (q, nv, q_tile, v_tile)
    grid = (q // q_tile, nv // v_tile)
    return pl.pallas_call(
        _index_combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, n), lambda i, j: (i, 0)),
            pl.BlockSpec((q_tile, v_tile), lambda i, j: (i, j)),
            pl.BlockSpec((v_tile, l), lambda i, j: (j, 0)),
            pl.BlockSpec((v_tile, l), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((q_tile, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, n), s.dtype),
        interpret=interpret,
    )(s, f, vals, idx)


# ---------------------------------------------------------------------------
# Sparse-frontier variant: contracts f[Q, K] against only the K touched index
# rows — DMA-gathered from the HBM-resident index, no [q_tile, n] slab and no
# whole-array index blocks anywhere.
# ---------------------------------------------------------------------------

def _index_combine_sparse_kernel(
    fi_ref, sv_ref, si_ref, fv_ref, vals_hbm, idx_hbm, ov_ref, oi_ref,
    vals_scratch, idx_scratch, sem,
):
    i = pl.program_id(0)
    q_tile, k = fv_ref.shape
    rows = q_tile * k

    # DMA the K touched index rows of this tile out of HBM; fi_ref is the
    # scalar-prefetched flat row-id array (SMEM)
    def make_dmas(r):
        row = fi_ref[i * rows + r]
        return (
            pltpu.make_async_copy(
                vals_hbm.at[pl.ds(row, 1), :],
                vals_scratch.at[pl.ds(r, 1), :],
                sem.at[0, r % 2],
            ),
            pltpu.make_async_copy(
                idx_hbm.at[pl.ds(row, 1), :],
                idx_scratch.at[pl.ds(r, 1), :],
                sem.at[1, r % 2],
            ),
        )

    dma_pipeline(rows, make_dmas)

    l = vals_scratch.shape[1]
    iv = vals_scratch[...].reshape(q_tile, k, l)
    ii = idx_scratch[...].reshape(q_tile, k, l)
    # same array-level math as the jnp core op — single source of truth
    cand_v, cand_i = verd_mod.combine_candidates_from_rows(
        sv_ref[...], si_ref[...], fv_ref[...], iv, ii
    )
    ov, oi = frontier_mod.compact_arrays(cand_v, cand_i, ov_ref.shape[1])
    ov_ref[...] = ov
    oi_ref[...] = oi


@functools.partial(
    jax.jit, static_argnames=("k_out", "q_tile", "interpret")
)
def index_combine_sparse(
    sv: jax.Array,
    si: jax.Array,
    fv: jax.Array,
    fi: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    k_out: int,
    q_tile: int = 8,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused sparse combine + top-k; Q must be a multiple of ``q_tile``
    (``ops.index_combine_sparse`` pads).  The ``[n, L]`` index arrays stay
    in ``pltpu.ANY`` (HBM); the ``K`` touched rows per tile are
    scalar-prefetch addressed and DMA-gathered into VMEM scratch, so VMEM
    per step is O(q_tile * K * L) — independent of ``n``."""
    q, k = fv.shape
    s_w = sv.shape[1]
    n, l = vals.shape
    assert si.shape == (q, s_w) and fi.shape == (q, k)
    assert idx.shape == (n, l)
    assert q % q_tile == 0, (q, q_tile)
    fi_flat = jnp.clip(fi.astype(jnp.int32), 0, n - 1).reshape(-1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # the flat touched-row ids
        grid=(q // q_tile,),
        in_specs=[
            pl.BlockSpec((q_tile, s_w), lambda i, r: (i, 0)),
            pl.BlockSpec((q_tile, s_w), lambda i, r: (i, 0)),
            pl.BlockSpec((q_tile, k), lambda i, r: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # index values: HBM
            pl.BlockSpec(memory_space=pltpu.ANY),   # index columns: HBM
        ],
        out_specs=[
            pl.BlockSpec((q_tile, k_out), lambda i, r: (i, 0)),
            pl.BlockSpec((q_tile, k_out), lambda i, r: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((q_tile * k, l), vals.dtype),
            pltpu.VMEM((q_tile * k, l), jnp.int32),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
    )
    return pl.pallas_call(
        _index_combine_sparse_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q, k_out), jnp.float32),
            jax.ShapeDtypeStruct((q, k_out), jnp.int32),
        ],
        interpret=interpret,
    )(fi_flat, sv, si, fv, vals, idx)


def sparse_vmem_bytes(q_tile: int, k: int, s_w: int, l: int, k_out: int) -> int:
    """Per-grid-step VMEM of the HBM-resident sparse combine."""
    blocks = q_tile * (2 * s_w * 4 + k * 4)    # sv/si + fv tiles
    scratch = q_tile * k * l * 8               # gathered vals + idx rows
    return blocks + scratch + q_tile * k_out * 8


def sparse_vmem_bytes_legacy(
    q_tile: int, k: int, s_w: int, l: int, k_out: int, *, n: int
) -> int:
    """Pre-HBM-resident accounting: the same tiles plus both whole ``[n,
    L]`` index arrays resident per step."""
    return sparse_vmem_bytes(q_tile, k, s_w, l, k_out) + 2 * n * l * 4


# ---------------------------------------------------------------------------
# Contract-auditor entry point (repro.analysis): the sparse combine's two
# [n, L] index arrays must ride as HBM refs, never as VMEM blocks.
# ---------------------------------------------------------------------------

from repro.analysis.registry import register_entry_point as _register_ep


def _contract_spec_index_combine():
    import functools

    import numpy as np

    rng = np.random.default_rng(0)
    n, l, q, k, s_w = 600, 16, 16, 8, 8
    q_tile, k_out = 8, 16
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    sv = jnp.asarray(rng.random((q, s_w)), jnp.float32)
    si = jnp.asarray(rng.integers(0, n, (q, s_w)), jnp.int32)
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32)
    return dict(
        fn=functools.partial(
            index_combine_sparse, k_out=k_out, q_tile=q_tile, interpret=True,
        ),
        args=(sv, si, fv, fi, vals, idx),
        hbm_shapes=[(n, l)],
        vmem_budget=q_tile * k * l + q_tile * max(s_w, k, k_out) * 2,
    )


_register_ep("index-combine-sparse", "hbm-residency",
             "src/repro/kernels/index_combine.py", _contract_spec_index_combine)
