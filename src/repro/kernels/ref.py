"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmm_ref(f: jax.Array, nbr: jax.Array, w: jax.Array) -> jax.Array:
    """partial[q, r] = sum_k w[r, k] * f[q, nbr[r, k]]."""
    q = f.shape[0]
    rows, k = nbr.shape
    gathered = jnp.take(f, nbr.reshape(-1), axis=1).reshape(q, rows, k)
    return jnp.sum(gathered * w[None, :, :], axis=-1)


def index_combine_ref(
    s: jax.Array, f: jax.Array, vals: jax.Array, idx: jax.Array
) -> jax.Array:
    """out[q, :] = s[q, :] + sum_{v,l} f[q, v] * vals[v, l] at column idx[v, l]."""
    q, n = s.shape
    nv, l = vals.shape
    contrib = f[:, :, None] * vals[None, :, :]          # [q, nv, l]
    return s.at[:, idx.reshape(-1)].add(contrib.reshape(q, nv * l))


def embedding_bag_ref(
    ids: jax.Array, mask: jax.Array, table: jax.Array
) -> jax.Array:
    """out[b, :] = sum_i mask[b, i] * table[ids[b, i], :]."""
    b, bag = ids.shape
    rows = jnp.take(table, ids.reshape(-1), axis=0).reshape(b, bag, -1)
    return (rows * mask[:, :, None]).sum(axis=1)
