"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmm_ref(f: jax.Array, nbr: jax.Array, w: jax.Array) -> jax.Array:
    """partial[q, r] = sum_k w[r, k] * f[q, nbr[r, k]]."""
    q = f.shape[0]
    rows, k = nbr.shape
    gathered = jnp.take(f, nbr.reshape(-1), axis=1).reshape(q, rows, k)
    return jnp.sum(gathered * w[None, :, :], axis=-1)


def index_combine_ref(
    s: jax.Array, f: jax.Array, vals: jax.Array, idx: jax.Array
) -> jax.Array:
    """out[q, :] = s[q, :] + sum_{v,l} f[q, v] * vals[v, l] at column idx[v, l]."""
    q, n = s.shape
    nv, l = vals.shape
    contrib = f[:, :, None] * vals[None, :, :]          # [q, nv, l]
    return s.at[:, idx.reshape(-1)].add(contrib.reshape(q, nv * l))


def frontier_push_ref(
    fv: jax.Array,
    fi: jax.Array,
    sources: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    k_out: int,
    threshold: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Dense-scatter oracle for the sparse gather-push kernel.

    Densifies the frontier, runs one exact ``(1-c) * f @ A`` push (dangling
    mass back to each source), re-sparsifies to top-``k_out``.  Only valid
    when ``degree_cap`` covers the max out-degree (the kernel's exact mode).
    """
    from repro.core import frontier as F
    from repro.core.graph import Graph, transition_with_dangling

    n = out_deg.shape[0]
    g = Graph(
        row_ptr=row_ptr, col_idx=col_idx,
        src=jnp.repeat(
            jnp.arange(n, dtype=jnp.int32), jnp.diff(row_ptr),
            total_repeat_length=col_idx.shape[0],
        ),
        out_deg=out_deg, n=n, m=int(col_idx.shape[0]),
    )
    dense = F.SparseFrontier(
        values=fv, indices=fi, k=fv.shape[1], n=n
    ).densify()
    pushed = (1.0 - c) * transition_with_dangling(g, dense, sources)
    if threshold > 0.0:
        pushed = jnp.where(pushed >= threshold, pushed, 0.0)
    sf = F.from_dense(pushed, k_out)
    v, i = F.topk_compact(sf.values, sf.indices, k_out)
    return v, i


def sharded_push_ref(
    fv: jax.Array,
    fi: jax.Array,
    row_ptr: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    ep: int,
    n_shard: int,
    wire_k: int,
) -> tuple[jax.Array, jax.Array]:
    """Dense-scatter oracle for the sharded push kernel.

    Densifies the local frontier slice over the shard's ``n_shard`` rows,
    pushes every local edge into a dense ``[Q, ep * n_shard]`` global slab
    (the exchange-free reference), then takes the per-owner top-``wire_k``.
    Returns owner-local indices like the kernel.  Only valid when ``wire_k``
    covers each owner's support (the kernel's exact mode).
    """
    from repro.core import frontier as F

    q = fv.shape[0]
    m = col_idx.shape[0]
    n = ep * n_shard
    f_dense = F.SparseFrontier(
        values=fv, indices=fi, k=fv.shape[1], n=n_shard
    ).densify()                                        # [Q, n_shard]
    # per-edge source row recovery + 1/deg weights (mirrors _push_local)
    e_ids = jnp.arange(m, dtype=jnp.int32)
    src_row = jnp.clip(
        jnp.searchsorted(row_ptr, e_ids, side="right") - 1, 0, n_shard - 1
    )
    deg = (row_ptr[1:] - row_ptr[:-1]).astype(jnp.float32)
    w = 1.0 / jnp.maximum(jnp.take(deg, src_row), 1.0)
    real = (e_ids < row_ptr[-1]).astype(jnp.float32)   # mask slab padding
    vals = jnp.take(f_dense, src_row, axis=1) * (w * real)[None, :]
    dense = (1.0 - c) * jax.ops.segment_sum(
        vals.T, col_idx, num_segments=n
    ).T                                                # [Q, n]
    per_owner = dense.reshape(q, ep, n_shard)
    bv, bi = jax.lax.top_k(per_owner, min(wire_k, n_shard))
    bi = jnp.where(bv > 0, bi, 0).astype(jnp.int32)
    if wire_k > n_shard:
        pad = ((0, 0), (0, 0), (0, wire_k - n_shard))
        bv, bi = jnp.pad(bv, pad), jnp.pad(bi, pad)
    return bv, bi


def index_combine_sparse_ref(
    sv: jax.Array,
    si: jax.Array,
    fv: jax.Array,
    fi: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    k_out: int,
) -> tuple[jax.Array, jax.Array]:
    """Dense oracle: densify s and f, run ``index_combine_ref``, top-k."""
    from repro.core import frontier as F

    n = vals.shape[0]
    q = fv.shape[0]
    s_dense = F.SparseFrontier(
        values=sv, indices=si, k=sv.shape[1], n=n
    ).densify()
    f_dense = F.SparseFrontier(
        values=fv, indices=fi, k=fv.shape[1], n=n
    ).densify()
    out = index_combine_ref(s_dense, f_dense, vals, idx)
    sf = F.from_dense(out, min(k_out, n))
    return F.topk_compact(sf.values, sf.indices, k_out)


def walk_step_ref(
    cursors: jax.Array,
    sources: jax.Array,
    u: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
) -> jax.Array:
    """Oracle for the fused bulk walk advance.

    Spelled out independently of ``repro.core.walks.advance_cursors`` (the
    code under test routes through it): gather degree + CSR start, sample
    the out-edge as ``floor(u * deg)``, read its destination, send dangling
    walks back to their source.  Bitwise contract — int outputs must match
    the kernel exactly.
    """
    cur = cursors.astype(jnp.int32)
    deg = jnp.take(out_deg, cur)
    start = jnp.take(row_ptr, cur)
    off = jnp.clip(
        jnp.floor(u * deg.astype(jnp.float32)).astype(jnp.int32),
        0, jnp.maximum(deg - 1, 0),
    )
    m = col_idx.shape[0]
    nxt = jnp.take(col_idx, jnp.clip(start + off, 0, m - 1))
    return jnp.where(deg == 0, sources.astype(jnp.int32), nxt)


def embedding_bag_ref(
    ids: jax.Array, mask: jax.Array, table: jax.Array
) -> jax.Array:
    """out[b, :] = sum_i mask[b, i] * table[ids[b, i], :]."""
    b, bag = ids.shape
    rows = jnp.take(table, ids.reshape(-1), axis=0).reshape(b, bag, -1)
    return (rows * mask[:, :, None]).sum(axis=1)
