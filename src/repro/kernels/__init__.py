"""Pallas TPU kernels for PowerWalk's compute hot-spots.

``ell_spmm``       — dense VERD frontier push (the per-iteration SpMM).
``frontier_push``  — HBM-resident sparse push (+ the sharded exchange
                     half-iteration), scalar-prefetch DMA gathers.
``index_combine``  — fused Algorithm-4 line 10 (s + f @ P_hat), dense and
                     sparse (HBM-resident index) variants.
``embedding_bag``  — sharded-table bag lookup for the recsys archs.

Each kernel module holds the ``pl.pallas_call`` + BlockSpec; ``ops`` wraps
them with padding/jit; ``ref`` holds the pure-jnp oracles the tests sweep
against.
"""

from repro.kernels import ops, ref  # noqa: F401
