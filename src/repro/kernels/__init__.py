"""Pallas TPU kernels for PowerWalk's compute hot-spots.

``ell_spmm``      — VERD frontier push (the per-iteration SpMM).
``index_combine`` — fused Algorithm-4 line 10 (s + f @ P_hat).
``embedding_bag`` — sharded-table bag lookup for the recsys archs.

Each kernel module holds the ``pl.pallas_call`` + BlockSpec; ``ops`` wraps
them with padding/jit; ``ref`` holds the pure-jnp oracles the tests sweep
against.
"""

from repro.kernels import ops, ref  # noqa: F401
