"""Elastic scaling + straggler mitigation planning.

On a real multi-pod deployment, failures arrive as "slice lost k hosts".
The JAX/XLA model cannot resize a live mesh, so elasticity = *restart onto a
new mesh* from the latest committed checkpoint:

  1. the watchdog (below) detects a failure / persistent straggler,
  2. :func:`plan_mesh` picks the largest usable (data x model) grid for the
     surviving device count, holding the model axis fixed if possible
     (param shardings stay valid; only the data axis shrinks),
  3. the checkpoint is restored with ``shard_fn`` targeting the new mesh
     (host numpy -> device_put with new NamedShardings; resharding is free
     because leaves are full arrays on host),
  4. the per-step token budget is preserved by raising grad-accumulation
     (``microbatches``) to cover the lost data-parallel rank(s).

This module provides the *planning* math + a deterministic step-time
watchdog; the restart loop lives in launch/train.py.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    devices_used: int
    devices_idle: int
    microbatch_scale: int      # grad-accum multiplier to keep global batch


def plan_mesh(
    n_devices: int,
    *,
    model_parallel: int = 16,
    prior_data_parallel: Optional[int] = None,
    pods: int = 1,
) -> MeshPlan:
    """Largest (pod, data, model) grid that fits ``n_devices``.

    The model axis is held at ``model_parallel`` (param shardings survive);
    data parallelism shrinks to the largest multiple that fits.  If fewer
    than one model group survives, model_parallel halves until it fits —
    that changes param shardings but restore handles it (host resharding).
    """
    mp = model_parallel
    while mp > 1 and n_devices < mp:
        mp //= 2
    per_pod = n_devices // pods
    dp = max(per_pod // mp, 1)
    used = pods * dp * mp
    scale = 1
    if prior_data_parallel is not None and dp * pods < prior_data_parallel:
        scale = math.ceil(prior_data_parallel / (dp * pods))
    if pods > 1:
        return MeshPlan((pods, dp, mp), ("pod", "data", "model"),
                        used, n_devices - used, scale)
    return MeshPlan((dp, mp), ("data", "model"), used, n_devices - used, scale)


def degraded_sequence(
    total: int, failures: Sequence[int], **kw
) -> List[MeshPlan]:
    """Mesh plans after each cumulative failure count (capacity ladder)."""
    plans = []
    n = total
    for f in failures:
        n -= f
        plans.append(plan_mesh(max(n, 1), **kw))
    return plans


# ---------------------------------------------------------------------------
# Straggler watchdog
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepTimer:
    """Deterministic step-time watchdog.

    Rolling median of step times; a step slower than ``threshold`` x median
    raises a straggler flag.  Two standard mitigations are encoded as
    recommendations the trainer acts on:
      * ``"checkpoint"`` — persistent slowness: snapshot now, plan restart,
      * ``"rebalance"`` — transient: re-issue the same step (XLA retries) /
        shift the data shard (for host-side input stalls).
    """

    window: int = 32
    threshold: float = 2.0
    _times: list = dataclasses.field(default_factory=list)
    slow_streak: int = 0

    def record(self, seconds: float) -> Optional[str]:
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 8:
            return None
        med = sorted(self._times)[len(self._times) // 2]
        if seconds > self.threshold * med:
            self.slow_streak += 1
        else:
            self.slow_streak = 0
        if self.slow_streak >= 3:
            return "checkpoint"   # persistent straggler: snapshot + replan
        if self.slow_streak == 1:
            return "rebalance"
        return None

    @property
    def median(self) -> float:
        ts = sorted(self._times)
        return ts[len(ts) // 2] if ts else 0.0
