"""Per-arch sharding policy: param/batch/cache PartitionSpecs.

Axis roles (see DESIGN.md §5):
  * ``model`` — tensor/expert/vertex parallelism (TP/EP + PPR vertex dim),
  * ``data``  — batch data-parallel + ZeRO/FSDP shard of params & optimizer,
  * ``pod``   — additional data parallelism across pods (slowest links).

Rules are path-based over the param pytree so models stay mesh-agnostic.
Divisibility is *preferred* but not required: GSPMD pads uneven dims (e.g.
qwen's 40 heads on a 16-way axis); the policy only demands that the large
dims (ff, vocab, experts, embedding rows) divide exactly, which every
assigned config satisfies.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_axis_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def model_axis_size(mesh: Mesh) -> int:
    return int(mesh.shape["model"])


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# LM transformer params
# ---------------------------------------------------------------------------

def _lm_spec(path: str, ndim: int, stacked: bool) -> P:
    """PartitionSpec for one transformer param.

    ``stacked`` params carry a leading n_layers dim (inside params['layers']).
    2-D policy: TP over 'model' on the contraction-free big dim, FSDP over
    'data' on the other — every large tensor is fully sharded.
    """
    lead: Tuple = (None,) if stacked else ()

    def spec(*axes):
        return P(*(lead + axes))

    if "embed" in path:                       # [V, d]
        return P("model", "data")
    if "lm_head" in path:                     # [d, V]
        return P("data", "model")
    if re.search(r"w[qkv]/w$", path):         # [d, H*hd]
        return spec("data", "model")
    if re.search(r"w[qkv]/b$", path):         # [H*hd]
        return spec("model")
    if path.endswith("wo/w"):                 # [H*hd, d]
        return spec("model", "data")
    if path.endswith("wo/b"):
        return spec("data")
    if "router" in path:                      # [d, E] small
        return spec(None, None)
    if re.search(r"w_(gate|up)/w$", path):    # dense ffn [d, ff]
        return spec("data", "model")
    if path.endswith("w_down/w"):             # [ff, d]
        return spec("model", "data")
    if re.search(r"w_(gate|up)/b$", path):
        return spec("model")
    if path.endswith("w_down/b"):
        return spec("data")
    if re.search(r"w_(gate|up)$", path):      # MoE [E, d, ffs]
        return spec("model", "data", None)
    if path.endswith("w_down"):               # MoE [E, ffs, d]
        return spec("model", None, "data")
    # norms / scalars / anything small: replicate
    return P(*([None] * ndim))


def lm_is_small(config) -> bool:
    """Models too narrow for 16-way TP (smollm): the model axis is better
    spent on sequence parallelism with replicated params."""
    return getattr(config, "d_model", 1 << 30) < 2048


def lm_param_specs(params_shape: Any, config=None) -> Any:
    if config is not None and lm_is_small(config):
        return jax.tree_util.tree_map(
            lambda leaf: P(*([None] * leaf.ndim)), params_shape
        )

    def one(path, leaf):
        p = _path_str(path)
        stacked = "layers" in p
        base = _lm_spec(p, leaf.ndim, stacked)
        # pad spec to leaf.ndim
        axes = tuple(base) + (None,) * (leaf.ndim - len(tuple(base)))
        return P(*axes[: leaf.ndim])
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# GNN / RecSys params
# ---------------------------------------------------------------------------

def gnn_param_specs(params_shape: Any) -> Any:
    """GCN weights are tiny (d_hidden 16): replicate everything."""
    return jax.tree_util.tree_map(
        lambda leaf: P(*([None] * leaf.ndim)), params_shape
    )


def recsys_param_specs(params_shape: Any) -> Any:
    """Embedding tables row-sharded over 'model' + FSDP'd big MLPs.

    Explicit in_shardings require exact divisibility (unlike constraint
    propagation), so each dim is sharded only if the 16-way axis divides it.
    """
    def one(path, leaf):
        p = _path_str(path)
        if ("table" in p and leaf.ndim == 2 and leaf.shape[0] >= 4096
                and leaf.shape[0] % 16 == 0):
            return P("model", None)
        if leaf.ndim == 2 and leaf.shape[0] * leaf.shape[1] >= 1 << 18:
            d0 = "data" if leaf.shape[0] % 16 == 0 else None
            d1 = "model" if leaf.shape[1] % 16 == 0 else None
            return P(d0, d1)
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_specs(family: str, params_shape: Any, config=None) -> Any:
    if family == "lm":
        return lm_param_specs(params_shape, config)
    return {
        "gnn": gnn_param_specs,
        "recsys": recsys_param_specs,
    }[family](params_shape)


# ---------------------------------------------------------------------------
# Optimizer state & batches
# ---------------------------------------------------------------------------

def opt_state_specs(pspec_tree: Any) -> Any:
    """AdamState(step, mu, nu): moments follow their param's spec."""
    from repro.training.optimizer import AdamState
    return AdamState(step=P(), mu=pspec_tree, nu=pspec_tree)


def batch_spec_lm(mesh: Mesh, kind: str, batch: int) -> dict:
    ba = batch_axes(mesh)
    b_ax = ba if batch >= data_axis_size(mesh) else None
    if kind == "lm_train":
        return dict(tokens=P(b_ax, None), labels=P(b_ax, None),
                    mask=P(b_ax, None))
    if kind == "lm_prefill":
        return dict(tokens=P(b_ax, None))
    raise ValueError(kind)


def cache_spec(mesh: Mesh, batch: int, quantized: bool = False) -> dict:
    """KV cache [L, B, S, H, hd]: B over data (if it divides), S over model.

    When the batch can't use the data axes (long_500k: B=1), the head_dim
    takes them instead (always 64/128, so always divisible — kv-head
    counts like 8 or 40 are not) — otherwise 15/16 of the pod idles while
    one model group holds the whole cache.  The hd-sharded attention
    contractions psum over data (split-K style).
    """
    ba = batch_axes(mesh)
    small_b = batch < data_axis_size(mesh)
    b_ax = None if small_b else ba
    d_ax = ba if small_b else None
    kv = P(None, b_ax, "model", None, d_ax)
    out = dict(k=kv, v=kv, length=P())
    if quantized:
        out["k_scale"] = P(None, b_ax, "model", None)
        out["v_scale"] = P(None, b_ax, "model", None)
    return out


def named(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
