"""Sharded checkpointing with atomic commit and async writes.

Layout (one directory per step)::

    <root>/step_<n>.tmp/            # written first
        meta.json                   # step, tree structure, shapes, dtypes
        arr_<i>.npy                 # one file per leaf (host-gathered)
        extra.json                  # data-iterator state, rng, mesh shape
    <root>/step_<n>/                # atomic rename on success

Fault-tolerance contract:
  * a crash mid-write leaves only a ``.tmp`` dir -> ignored on restore,
  * ``latest_step`` returns the newest *committed* checkpoint,
  * restore re-shards onto whatever mesh the caller provides (elastic
    restart onto fewer/more devices re-uses the same files — see
    :mod:`repro.distributed.elastic`),
  * the async writer overlaps serialization with the next train steps and
    is awaited (or re-raised) on the next save / explicit ``wait()``.

bf16 leaves are stored via a uint16 view (npy has no native bfloat16).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, str(x.dtype)


def _from_numpy(x: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return jnp.asarray(x.view(jnp.bfloat16))
    return jnp.asarray(x)


class Checkpointer:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             *, blocking: bool = True) -> None:
        leaves, treedef = _flatten(tree)
        host_leaves = [_to_numpy(x) for x in leaves]
        meta = dict(
            step=step,
            treedef=str(treedef),
            dtypes=[d for _, d in host_leaves],
            shapes=[list(a.shape) for a, _ in host_leaves],
        )
        extra = extra or {}

        def write():
            tmp = os.path.join(self.root, f"step_{step}.tmp")
            final = os.path.join(self.root, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, (arr, _) in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "extra.json"), "w") as f:
                json.dump(extra, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            def run():
                try:
                    write()
                except BaseException as e:  # surfaced at next wait()
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shard_fn: Optional[Callable[[Any], Any]] = None,
                ) -> Tuple[Any, dict]:
        """Restore into the structure of ``like``.

        ``shard_fn(tree) -> tree`` optionally re-places leaves onto a mesh
        (e.g. ``lambda t: jax.device_put(t, shardings)``) — the elastic
        restart path.
        """
        d = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with open(os.path.join(d, "extra.json")) as f:
            extra = json.load(f)
        leaves_like, treedef = _flatten(like)
        assert len(leaves_like) == len(meta["dtypes"]), (
            "checkpoint/model structure mismatch"
        )
        leaves = [
            _from_numpy(np.load(os.path.join(d, f"arr_{i}.npy")),
                        meta["dtypes"][i])
            for i in range(len(leaves_like))
        ]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shard_fn is not None:
            tree = shard_fn(tree)
        return tree, extra
