"""Sharded checkpointing with atomic commit, checksums, and async writes.

Layout (one directory per step)::

    <root>/step_<n>.tmp/            # written first
        meta.json                   # step, tree structure, shapes, dtypes,
                                    # per-leaf crc32 checksums
        arr_<i>.npy                 # one file per leaf (host-gathered)
        extra.json                  # data-iterator state, rng, mesh shape
    <root>/step_<n>/                # atomic rename on success

Fault-tolerance contract:
  * a crash mid-write leaves only a ``.tmp`` dir -> ignored on restore,
  * ``latest_step`` returns the newest *committed* checkpoint,
  * every leaf's crc32 is recorded in ``meta.json`` at save time and
    verified at restore time — a torn or bit-rotted shard raises
    :class:`CheckpointCorruptionError` instead of restoring silently-wrong
    state, and :meth:`Checkpointer.restore_latest` falls back to the prior
    committed step,
  * restore re-shards onto whatever mesh the caller provides (elastic
    restart onto fewer/more devices re-uses the same files — see
    :mod:`repro.distributed.elastic`),
  * the async writer overlaps serialization with the next train steps and
    is awaited (or re-raised) on the next save / explicit ``wait()``.

bf16 leaves are stored via a uint16 view (npy has no native bfloat16).

Flat ``dict`` payloads (the index-build checkpoints of
``core/index.py``) additionally record their key list in ``meta.json``, so
they can be restored without a ``like`` tree — the partial-restore API a
resuming build uses before it knows how far the crashed run got.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointCorruptionError(RuntimeError):
    """A committed checkpoint failed checksum / structural verification."""


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, str(x.dtype)


def _from_numpy(x: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return jnp.asarray(x.view(jnp.bfloat16))
    return jnp.asarray(x)


def _crc(arr: np.ndarray) -> int:
    """crc32 over the array's raw bytes — cheap relative to the np.save IO
    it guards, and enough to catch torn writes / bit rot (this is an
    integrity check, not an authenticity one)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def serialize_key(key: jax.Array) -> dict:
    """JSON-safe fingerprint of a PRNG key (raw ``uint32`` or typed)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        impl = str(jax.random.key_impl(key))
        data = np.asarray(jax.random.key_data(key))
    else:
        impl = None
        data = np.asarray(key)
    return dict(impl=impl, data=data.astype(np.uint32).tolist())


def deserialize_key(fp: dict) -> jax.Array:
    """Inverse of :func:`serialize_key` — bit-exact key reconstruction."""
    data = jnp.asarray(np.asarray(fp["data"], np.uint32))
    if fp.get("impl"):
        return jax.random.wrap_key_data(data, impl=fp["impl"])
    return data


class Checkpointer:
    """Atomic-commit checkpoint store.

    ``pre_commit(step)`` is an instrumentation seam invoked after a step's
    files are fully written but *before* the atomic rename — fault-injection
    tests (``repro.testing.faults``) raise there to simulate a crash
    mid-write, which must leave only an ignored ``.tmp`` dir behind.
    """

    def __init__(self, root: str, *, keep: int = 3,
                 pre_commit: Optional[Callable[[int], None]] = None):
        self.root = root
        self.keep = keep
        self.pre_commit = pre_commit
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             *, blocking: bool = True) -> None:
        leaves, treedef = _flatten(tree)
        host_leaves = [_to_numpy(x) for x in leaves]
        meta = dict(
            step=step,
            treedef=str(treedef),
            dtypes=[d for _, d in host_leaves],
            shapes=[list(a.shape) for a, _ in host_leaves],
            checksums=[_crc(a) for a, _ in host_leaves],
        )
        if isinstance(tree, dict) and all(isinstance(k, str) for k in tree):
            # flat dict payloads restore without a `like` tree: record the
            # key order tree_flatten used (sorted) so arr_<i> maps back
            meta["keys"] = sorted(tree.keys())
        extra = extra or {}

        def write():
            tmp = os.path.join(self.root, f"step_{step}.tmp")
            final = os.path.join(self.root, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, (arr, _) in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            with open(os.path.join(tmp, "extra.json"), "w") as f:
                json.dump(extra, f)
            if self.pre_commit is not None:
                self.pre_commit(step)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            def run():
                try:
                    write()
                except BaseException as e:  # surfaced at next wait()
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_meta(self, step: int) -> dict:
        with open(os.path.join(self.root, f"step_{step}", "meta.json")) as f:
            return json.load(f)

    def read_extra(self, step: int) -> dict:
        with open(os.path.join(self.root, f"step_{step}", "extra.json")) as f:
            return json.load(f)

    def verify_step(self, step: int) -> bool:
        """True iff the committed step's every shard matches its recorded
        checksum (pre-checksum checkpoints verify structurally only)."""
        try:
            self._load_leaves(step)
        except (CheckpointCorruptionError, OSError, ValueError, KeyError):
            return False
        return True

    def _load_leaves(self, step: int) -> Tuple[dict, List[np.ndarray]]:
        d = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        checksums = meta.get("checksums")
        arrs: List[np.ndarray] = []
        for i in range(len(meta["dtypes"])):
            path = os.path.join(d, f"arr_{i}.npy")
            try:
                arr = np.load(path)
            except (OSError, ValueError) as e:
                raise CheckpointCorruptionError(
                    f"step {step}: shard arr_{i}.npy unreadable: {e}"
                ) from e
            if list(arr.shape) != meta["shapes"][i]:
                raise CheckpointCorruptionError(
                    f"step {step}: shard arr_{i}.npy shape {arr.shape} != "
                    f"recorded {meta['shapes'][i]}"
                )
            if checksums is not None and _crc(arr) != checksums[i]:
                raise CheckpointCorruptionError(
                    f"step {step}: shard arr_{i}.npy failed its checksum"
                )
            arrs.append(arr)
        return meta, arrs

    def restore(self, step: int, like: Any = None,
                shard_fn: Optional[Callable[[Any], Any]] = None,
                ) -> Tuple[Any, dict]:
        """Restore into the structure of ``like``.

        ``like=None`` restores a flat-dict payload by the key list recorded
        at save time (the partial-restore path: a resuming build does not
        know the crashed run's array shapes up front).  Every shard is
        checksum-verified; corruption raises
        :class:`CheckpointCorruptionError`.

        ``shard_fn(tree) -> tree`` optionally re-places leaves onto a mesh
        (e.g. ``lambda t: jax.device_put(t, shardings)``) — the elastic
        restart path.
        """
        meta, arrs = self._load_leaves(step)
        extra = self.read_extra(step)
        leaves = [
            _from_numpy(a, meta["dtypes"][i]) for i, a in enumerate(arrs)
        ]
        if like is None:
            keys = meta.get("keys")
            if keys is None:
                raise ValueError(
                    f"step {step} was not saved as a flat dict; pass `like`"
                )
            tree = dict(zip(keys, leaves))
        else:
            leaves_like, treedef = _flatten(like)
            assert len(leaves_like) == len(meta["dtypes"]), (
                "checkpoint/model structure mismatch"
            )
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shard_fn is not None:
            tree = shard_fn(tree)
        return tree, extra

    def restore_latest(
        self, like: Any = None,
        shard_fn: Optional[Callable[[Any], Any]] = None,
        predicate: Optional[Callable[[dict], bool]] = None,
    ) -> Optional[Tuple[int, Any, dict]]:
        """Restore the newest committed step that verifies.

        Walks committed steps newest-first (``.tmp`` dirs are never
        candidates), skips any whose ``extra`` fails ``predicate``, and on
        a checksum/structure failure *falls back to the prior committed
        step* instead of raising — the resume contract of the crash-safe
        index build.  Returns ``(step, tree, extra)`` or ``None`` when no
        step survives.
        """
        for step in reversed(self.all_steps()):
            if predicate is not None:
                try:
                    if not predicate(self.read_extra(step)):
                        continue
                except (OSError, ValueError):
                    continue
            try:
                tree, extra = self.restore(step, like, shard_fn=shard_fn)
            except (CheckpointCorruptionError, OSError, ValueError):
                continue
            return step, tree, extra
        return None
