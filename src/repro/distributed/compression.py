"""Gradient compression with error feedback (distributed-optimization trick).

At pod scale the gradient all-reduce crosses the slow DCN links; casting
grads to bf16 (or int8-scaled) halves (quarters) that traffic.  Naive
casting biases training; **error feedback** (Seide et al. 2014; Karimireddy
et al. 2019) keeps a residual accumulator so quantization error is re-added
next step — unbiased in the long run.

Usage: ``state = init(params);  grads, state = compress(grads, state)`` and
pass the compressed grads to the optimizer; plug via train_loop's
``grad_transform`` or call explicitly in a custom loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    method: str = "bf16_ef"      # none | bf16 | bf16_ef | int8_ef
    int8_clip: float = 6.0        # stddevs kept before int8 saturation


def init(params: Any) -> Any:
    """Error-feedback residuals, zeros like params (fp32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_bf16(g):
    return g.astype(jnp.bfloat16).astype(jnp.float32)


def _quant_int8(g, clip_sigmas: float):
    sigma = jnp.std(g) + 1e-12
    scale = clip_sigmas * sigma / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def compress(
    cfg: CompressionConfig, grads: Any, residual: Any
) -> Tuple[Any, Any]:
    """Returns (decompressed-after-quantization grads, new residual).

    The returned grads are exactly what the receiving side reconstructs, so
    using them in the optimizer models the lossy collective faithfully.
    """
    if cfg.method == "none":
        return grads, residual

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if cfg.method == "bf16":
            return _quant_bf16(g32), r
        if cfg.method == "bf16_ef":
            target = g32 + r
            q = _quant_bf16(target)
            return q, target - q
        if cfg.method == "int8_ef":
            target = g32 + r
            q = _quant_int8(target, cfg.int8_clip)
            return q, target - q
        raise ValueError(cfg.method)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([p[0] for p in pairs])
    new_r = treedef.unflatten([p[1] for p in pairs])
    return new_g, new_r


def wire_bytes(grads: Any, cfg: CompressionConfig) -> int:
    """Bytes this gradient pytree puts on the wire per all-reduce."""
    per = {"none": 4, "bf16": 2, "bf16_ef": 2, "int8_ef": 1}[cfg.method]
    return sum(x.size * per for x in jax.tree_util.tree_leaves(grads))
