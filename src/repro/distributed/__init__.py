"""Distributed runtime: sharding policy, checkpointing, elasticity,
gradient compression, collective helpers."""
