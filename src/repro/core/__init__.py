"""PowerWalk core: the paper's contribution as composable JAX modules.

Offline:  `walks` (bulk random-walk engine) -> `mcfp` -> `index` (top-L
fingerprints, budget planner).  Online: `verd` (batched vertex-centric
decomposition) -> `query` (shared-decomposition batch engine).  Baselines:
`mcep`, `power_iteration`.  Analysis: `theory` (Theorem 2.1), `metrics`
(RAG@k).
"""

from repro.core.frontier import SparseFrontier  # noqa: F401
from repro.core.graph import Graph  # noqa: F401
from repro.core.index import PPRIndex, build_index, plan_for_budget  # noqa: F401
from repro.core.query import BatchQueryEngine, QueryConfig  # noqa: F401
from repro.core.walks import (  # noqa: F401
    SparseWalkCounts,
    simulate_walks_sparse,
)
