"""The PPR index: top-L truncated fingerprints (paper Section 3.1/3.3).

The paper stores each approximate vector sparsely (hash tables / sorted
vectors) and discards entries below a threshold.  The TPU-native analogue is
a *fixed-width* top-L representation: ``values f32[n, L]`` + ``indices
int32[n, L]`` — dense, regular, vertex-shardable over the ``model`` mesh
axis.  An MCFP run with ``R`` walks yields at most ``~R/c`` nonzeros per
vertex, so ``L ~ R/c`` loses nothing; smaller ``L`` trades memory for the
truncated tail (bounded by the dropped mass, reported by the builder).

The memory-budget planner implements the paper's core knob: "the computation
can be shifted to the offline stage as much as the memory budget allows".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcfp
from repro.core.graph import Graph
from repro.core.walks import DEFAULT_C


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PPRIndex:
    """Top-L truncated PPR fingerprints for every vertex.

    values:  f32[n, L] PPR estimates, descending within a row, 0-padded.
    indices: int32[n, L] target vertex of each value (0 at padding).
    l: static width; n: static vertex count.
    """

    values: jax.Array
    indices: jax.Array
    l: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nbytes(self) -> int:
        return self.n * self.l * 8  # f32 + int32

    def lookup_dense(self, vertices: jax.Array) -> jax.Array:
        """Densify rows: f32[len(vertices), n] (FPPR-style direct answer)."""
        vals = jnp.take(self.values, vertices, axis=0)
        idxs = jnp.take(self.indices, vertices, axis=0)
        out = jnp.zeros((vertices.shape[0], self.n), dtype=vals.dtype)
        rows = jnp.arange(vertices.shape[0])[:, None]
        return out.at[rows, idxs].add(vals)


def truncate_topl(estimates: jax.Array, l: int) -> Tuple[jax.Array, jax.Array]:
    """Keep the top-``l`` entries of each dense row. Returns (vals, idxs)."""
    vals, idxs = jax.lax.top_k(estimates, l)
    vals = jnp.maximum(vals, 0.0)
    # zero-value slots point at vertex 0 but carry weight 0 -> harmless
    idxs = jnp.where(vals > 0, idxs, 0)
    return vals, idxs.astype(jnp.int32)


def build_index(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
    sources: Optional[np.ndarray] = None,
) -> Tuple[PPRIndex, dict]:
    """Offline preprocessing: MCFP for every vertex, truncated to top-L.

    Returns (index, stats) where stats reports the truncated tail mass —
    the accuracy cost of the memory budget.
    """
    n = graph.n
    if sources is None:
        sources = np.arange(n, dtype=np.int32)
    values = np.zeros((n, l), dtype=np.float32)
    indices = np.zeros((n, l), dtype=np.int32)
    dropped = 0.0
    kept = 0.0
    trunc = jax.jit(lambda e: truncate_topl(e, l))
    for chunk_ids, est in mcfp.estimate_ppr_batched(
        graph, sources, r, key, c=c, max_steps=max_steps,
        source_batch=source_batch,
    ):
        vals, idxs = trunc(est)
        values[chunk_ids] = np.asarray(vals)
        indices[chunk_ids] = np.asarray(idxs)
        total = float(jnp.sum(est))
        k = float(jnp.sum(vals))
        kept += k
        dropped += total - k
    stats = dict(
        r=r,
        l=l,
        kept_mass=kept,
        dropped_mass=dropped,
        drop_fraction=dropped / max(kept + dropped, 1e-12),
        nbytes=n * l * 8,
    )
    return (
        PPRIndex(
            values=jnp.asarray(values), indices=jnp.asarray(indices), l=l, n=n
        ),
        stats,
    )


def index_from_dense(estimates: jax.Array, l: int) -> PPRIndex:
    """Build an index from precomputed dense vectors (tests/baselines)."""
    vals, idxs = truncate_topl(estimates, l)
    return PPRIndex(
        values=vals, indices=idxs, l=l, n=int(estimates.shape[1])
    )


# ---------------------------------------------------------------------------
# Memory-budget planning (paper Section 3: offline/online trade-off knob)
# ---------------------------------------------------------------------------

# Paper Figure 5 / Section 4.2: iterations needed for RAG > 0.99 at R.
_PAPER_T_FOR_R = ((0, 7), (10, 5), (100, 2))


@dataclasses.dataclass(frozen=True)
class IndexPlan:
    r: int              # walks per vertex offline
    l: int              # index width (top-L)
    t_online: int       # VERD iterations online
    index_bytes: int
    budget_bytes: int


def plan_for_budget(
    n: int,
    budget_bytes: int,
    *,
    c: float = DEFAULT_C,
    bytes_per_entry: int = 8,
) -> IndexPlan:
    """Choose (R, L, T) for a memory budget.

    ``L = budget / (n * 8B)``; an MCFP vector from ``R`` walks has ``<= R/c``
    support, so ``R = floor(c * L)`` saturates the width; the online
    iteration count interpolates the paper's measured (R -> T) table.
    """
    l = max(int(budget_bytes // (max(n, 1) * bytes_per_entry)), 0)
    r = int(c * l)
    t = 7
    for r_ref, t_ref in _PAPER_T_FOR_R:
        if r >= r_ref:
            t = t_ref
    return IndexPlan(
        r=r, l=l, t_online=t,
        index_bytes=n * l * bytes_per_entry, budget_bytes=budget_bytes,
    )


def preprocessing_cost_model(
    n: int, r: int, *, c: float = DEFAULT_C, step_rate: float = 5e8
) -> dict:
    """Analytic preprocessing cost (paper Table 2 extrapolation).

    Total walk positions ~ n*R/c; ``step_rate`` is positions/sec for the
    bulk engine (fitted from measured small-graph runs by the benchmark).
    Index size is n*min(R/c, L)*8 bytes before compression.
    """
    positions = n * r / c
    return dict(
        walk_positions=positions,
        est_seconds=positions / step_rate,
        index_bytes_uncapped=int(n * (r / c) * 8),
    )
