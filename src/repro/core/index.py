"""The PPR index: top-L truncated fingerprints (paper Section 3.1/3.3).

The paper stores each approximate vector sparsely (hash tables / sorted
vectors) and discards entries below a threshold.  The TPU-native analogue is
a *fixed-width* top-L representation: ``values f32[n, L]`` + ``indices
int32[n, L]`` — dense, regular, vertex-shardable over the ``model`` mesh
axis.  An MCFP run with ``R`` walks yields at most ``~R/c`` nonzeros per
vertex, so ``L ~ R/c`` loses nothing; smaller ``L`` trades memory for the
truncated tail (bounded by the dropped mass, reported by the builder).

The memory-budget planner implements the paper's core knob: "the computation
can be shifted to the offline stage as much as the memory budget allows".
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcfp
from repro.core.graph import Graph
from repro.core.walks import DEFAULT_C, simulate_walks_sparse


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PPRIndex:
    """Top-L truncated PPR fingerprints for every vertex.

    values:  f32[n, L] PPR estimates, descending within a row, 0-padded.
    indices: int32[n, L] target vertex of each value (0 at padding).
    l: static width; n: static vertex count.
    """

    values: jax.Array
    indices: jax.Array
    l: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nbytes(self) -> int:
        return self.n * self.l * 8  # f32 + int32

    def lookup_dense(self, vertices: jax.Array) -> jax.Array:
        """Densify rows: f32[len(vertices), n] (FPPR-style direct answer)."""
        vals = jnp.take(self.values, vertices, axis=0)
        idxs = jnp.take(self.indices, vertices, axis=0)
        out = jnp.zeros((vertices.shape[0], self.n), dtype=vals.dtype)
        rows = jnp.arange(vertices.shape[0])[:, None]
        return out.at[rows, idxs].add(vals)


def truncate_topl(estimates: jax.Array, l: int) -> Tuple[jax.Array, jax.Array]:
    """Keep the top-``l`` entries of each dense row. Returns (vals, idxs)."""
    vals, idxs = jax.lax.top_k(estimates, l)
    vals = jnp.maximum(vals, 0.0)
    # zero-value slots point at vertex 0 but carry weight 0 -> harmless
    idxs = jnp.where(vals > 0, idxs, 0)
    return vals, idxs.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("r", "l", "sketch_l", "c", "max_steps", "compact_every"),
)
def sparse_chunk_estimates(
    graph: Graph,
    chunk_sources: jax.Array,
    key: jax.Array,
    *,
    r: int,
    l: int,
    sketch_l: int,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One source chunk of the sparse index build, entirely on device.

    Runs the compacted sparse-sketch walk engine at width ``sketch_l``,
    normalizes to MCFP estimates, and truncates to the index width ``l``
    (the sketch is already sorted descending, so truncation is a slice).
    Returns ``(vals f32[rows, l], idxs int32[rows, l], kept f32[rows],
    dropped f32[rows])`` — the per-row kept/dropped *estimate* mass, left on
    device so the builder syncs once at the end, never per chunk.  The
    traced computation holds no ``f32[rows, n]`` array (the memory contract
    ``tests/test_walks_sparse.py`` asserts on this function's jaxpr).
    """
    counts = simulate_walks_sparse(
        graph, chunk_sources, r, key, l=sketch_l, ep_l=0, c=c,
        max_steps=max_steps, compact_every=compact_every,
    )
    inv_moves = 1.0 / jnp.maximum(counts.moves[:, None], 1.0)
    est_v = counts.fp.values * inv_moves              # sorted descending
    vals, idxs = est_v[:, :l], counts.fp.indices[:, :l]
    idxs = jnp.where(vals > 0, idxs, 0)
    kept = jnp.sum(vals, axis=1)
    dropped = (
        jnp.sum(est_v[:, l:], axis=1)
        + counts.fp_dropped * inv_moves[:, 0]
    )
    return vals, idxs, kept, dropped


def build_index(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
    sources: Optional[np.ndarray] = None,
    engine: str = "sparse",
    compact_every: int = 8,
) -> Tuple[PPRIndex, dict]:
    """Offline preprocessing: MCFP for every vertex, truncated to top-L.

    ``engine="sparse"`` (default) streams the compacted sparse-sketch walk
    engine straight into the fixed-width index: peak device memory is
    ``O(source_batch * sketch_l)`` per chunk plus the ``[n, L]`` index
    itself — no ``f32[rows, n]`` accumulator, no host numpy round-trip, so
    the build runs at the graph sizes the online sparse path already
    handles.  ``engine="legacy"`` keeps the dense-accumulator oracle.

    Returns (index, stats) where stats reports the truncated tail mass —
    the accuracy cost of the memory budget.  All host syncs are deferred to
    one ``device_get`` at the end.
    """
    n = graph.n
    if sources is None:
        sources = np.arange(n, dtype=np.int32)
    if engine == "sparse":
        return _build_index_sparse(
            graph, r, l, key, c=c, max_steps=max_steps,
            source_batch=source_batch, sources=sources,
            compact_every=compact_every,
        )
    if engine != "legacy":
        raise ValueError(f"unknown engine {engine!r}")

    values = np.zeros((n, l), dtype=np.float32)
    indices = np.zeros((n, l), dtype=np.int32)
    # per-chunk (total, kept) stay device scalars; one sync at the end so
    # the host never blocks the dispatch pipeline mid-stream
    totals = []
    kepts = []

    @jax.jit
    def trunc(e):
        vals, idxs = truncate_topl(e, l)
        return vals, idxs, jnp.sum(e), jnp.sum(vals)

    stats: dict = {}
    for chunk_ids, est in mcfp.estimate_ppr_batched(
        graph, sources, r, key, c=c, max_steps=max_steps,
        source_batch=source_batch, stats=stats,
    ):
        real = est.shape[0]
        if real < source_batch:  # re-pad the ragged tail: trunc compiles
            est = jnp.pad(est, ((0, source_batch - real), (0, 0)))  # once
        vals, idxs, total, k = trunc(est)
        values[chunk_ids] = np.asarray(vals[:real])
        indices[chunk_ids] = np.asarray(idxs[:real])
        totals.append(total)  # pad rows are all-zero: sums unaffected
        kepts.append(k)
    if totals:
        total, kept = jax.device_get(
            (jnp.sum(jnp.stack(totals)), jnp.sum(jnp.stack(kepts)))
        )
    else:  # empty sources: a valid all-zero index
        total = kept = 0.0
    dropped = float(total) - float(kept)
    stats.update(
        r=r,
        l=l,
        engine="legacy",
        kept_mass=float(kept),
        dropped_mass=dropped,
        drop_fraction=dropped / max(float(total), 1e-12),
        nbytes=n * l * 8,
    )
    return (
        PPRIndex(
            values=jnp.asarray(values), indices=jnp.asarray(indices), l=l, n=n
        ),
        stats,
    )


def _build_index_sparse(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    c: float,
    max_steps: int,
    source_batch: int,
    sources: np.ndarray,
    compact_every: int,
) -> Tuple[PPRIndex, dict]:
    """Streaming sparse build: ``SparseWalkCounts -> PPRIndex`` on device."""
    n = graph.n
    l = min(l, n)
    # sketch headroom over the index width keeps the running top-L honest:
    # entries near rank l compete inside the sketch before the final slice
    sketch_l = min(n, max(2 * l, l + 32))
    sources = np.asarray(sources, dtype=np.int32)
    n_src = len(sources)
    pad_rows = (-n_src) % source_batch
    padded = np.concatenate(
        [sources, np.zeros(pad_rows, np.int32)]
    ) if pad_rows else sources
    vals_chunks = []
    idxs_chunks = []
    kept_parts = []
    dropped_parts = []
    for i in range(0, len(padded), source_batch):
        chunk = jnp.asarray(padded[i : i + source_batch])
        real = min(source_batch, n_src - i)
        sub_key = jax.random.fold_in(key, i)
        vals, idxs, kept, dropped = sparse_chunk_estimates(
            graph, chunk, sub_key, r=r, l=l, sketch_l=sketch_l, c=c,
            max_steps=max_steps, compact_every=compact_every,
        )
        # device-level slicing of the ragged tail: no host sync, pad rows
        # never reach the index or the stats
        vals_chunks.append(vals[:real])
        idxs_chunks.append(idxs[:real])
        kept_parts.append(jnp.sum(kept[:real]))
        dropped_parts.append(jnp.sum(dropped[:real]))

    if not n_src:  # empty sources: a valid all-zero index
        values = jnp.zeros((n, l), jnp.float32)
        indices = jnp.zeros((n, l), jnp.int32)
    elif n_src == n and np.array_equal(
        sources, np.arange(n, dtype=np.int32)
    ):
        values = jnp.concatenate(vals_chunks, axis=0)
        indices = jnp.concatenate(idxs_chunks, axis=0)
    else:  # subset build: one scatter into the zero index
        src_dev = jnp.asarray(sources)
        values = jnp.zeros((n, l), jnp.float32).at[src_dev].set(
            jnp.concatenate(vals_chunks, axis=0)
        )
        indices = jnp.zeros((n, l), jnp.int32).at[src_dev].set(
            jnp.concatenate(idxs_chunks, axis=0)
        )
    if kept_parts:
        kept, dropped = jax.device_get(
            (jnp.sum(jnp.stack(kept_parts)),
             jnp.sum(jnp.stack(dropped_parts)))
        )
        kept, dropped = float(kept), float(dropped)
    else:
        kept = dropped = 0.0
    stats = dict(
        r=r,
        l=l,
        engine="sparse",
        sketch_l=sketch_l,
        pad_rows=pad_rows,
        pad_fraction=pad_rows / max(n_src + pad_rows, 1),
        kept_mass=kept,
        dropped_mass=dropped,
        drop_fraction=dropped / max(kept + dropped, 1e-12),
        nbytes=n * l * 8,
    )
    return PPRIndex(values=values, indices=indices, l=l, n=n), stats


def index_from_dense(estimates: jax.Array, l: int) -> PPRIndex:
    """Build an index from precomputed dense vectors (tests/baselines)."""
    vals, idxs = truncate_topl(estimates, l)
    return PPRIndex(
        values=vals, indices=idxs, l=l, n=int(estimates.shape[1])
    )


# ---------------------------------------------------------------------------
# Memory-budget planning (paper Section 3: offline/online trade-off knob)
# ---------------------------------------------------------------------------

# Paper Figure 5 / Section 4.2: iterations needed for RAG > 0.99 at R.
_PAPER_T_FOR_R = ((0, 7), (10, 5), (100, 2))


@dataclasses.dataclass(frozen=True)
class IndexPlan:
    r: int              # walks per vertex offline
    l: int              # index width (top-L)
    t_online: int       # VERD iterations online
    index_bytes: int
    budget_bytes: int


def plan_for_budget(
    n: int,
    budget_bytes: int,
    *,
    c: float = DEFAULT_C,
    bytes_per_entry: int = 8,
) -> IndexPlan:
    """Choose (R, L, T) for a memory budget.

    ``L = budget / (n * 8B)``; an MCFP vector from ``R`` walks has ``<= R/c``
    support, so ``R = floor(c * L)`` saturates the width; the online
    iteration count interpolates the paper's measured (R -> T) table.
    """
    l = max(int(budget_bytes // (max(n, 1) * bytes_per_entry)), 0)
    r = int(c * l)
    t = 7
    for r_ref, t_ref in _PAPER_T_FOR_R:
        if r >= r_ref:
            t = t_ref
    return IndexPlan(
        r=r, l=l, t_online=t,
        index_bytes=n * l * bytes_per_entry, budget_bytes=budget_bytes,
    )


def preprocessing_cost_model(
    n: int, r: int, *, c: float = DEFAULT_C, step_rate: float = 5e8
) -> dict:
    """Analytic preprocessing cost (paper Table 2 extrapolation).

    Total walk positions ~ n*R/c; ``step_rate`` is positions/sec for the
    bulk engine (fitted from measured small-graph runs by the benchmark).
    Index size is n*min(R/c, L)*8 bytes before compression.
    """
    positions = n * r / c
    return dict(
        walk_positions=positions,
        est_seconds=positions / step_rate,
        index_bytes_uncapped=int(n * (r / c) * 8),
    )
