"""The PPR index: top-L truncated fingerprints (paper Section 3.1/3.3).

The paper stores each approximate vector sparsely (hash tables / sorted
vectors) and discards entries below a threshold.  The TPU-native analogue is
a *fixed-width* top-L representation: ``values f32[n, L]`` + ``indices
int32[n, L]`` — dense, regular, vertex-shardable over the ``model`` mesh
axis.  An MCFP run with ``R`` walks yields at most ``~R/c`` nonzeros per
vertex, so ``L ~ R/c`` loses nothing; smaller ``L`` trades memory for the
truncated tail (bounded by the dropped mass, reported by the builder).

The memory-budget planner implements the paper's core knob: "the computation
can be shifted to the offline stage as much as the memory budget allows".
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings
import zlib
from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier as frontier_mod
from repro.core import mcfp
from repro.core.graph import Graph, graph_fingerprint
from repro.core.walks import (DEFAULT_C, BuildLedger, compaction_schedule,
                              respawn_schedule, schedule_slot_area,
                              simulate_walks_sparse)
from repro.distributed.checkpoint import (Checkpointer, serialize_key)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PPRIndex:
    """Top-L truncated PPR fingerprints for every vertex.

    values:  f32[n, L] PPR estimates, descending within a row, 0-padded.
    indices: int32[n, L] target vertex of each value (0 at padding).
    l: static width; n: static vertex count.
    """

    values: jax.Array
    indices: jax.Array
    l: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nbytes(self) -> int:
        return self.n * self.l * 8  # f32 + int32

    def lookup_dense(self, vertices: jax.Array) -> jax.Array:
        """Densify rows: f32[len(vertices), n] (FPPR-style direct answer)."""
        vals = jnp.take(self.values, vertices, axis=0)
        idxs = jnp.take(self.indices, vertices, axis=0)
        out = jnp.zeros((vertices.shape[0], self.n), dtype=vals.dtype)
        rows = jnp.arange(vertices.shape[0])[:, None]
        return out.at[rows, idxs].add(vals)

    def replace_rows(
        self, rows: jax.Array, values: jax.Array, indices: jax.Array
    ) -> "PPRIndex":
        """Functionally replace the fingerprint rows ``rows`` — the repair
        primitive of incremental maintenance (``core/updates.py``).

        Sharded-aware: if this index lives model-sharded (the
        ``build_index_sharded`` ``P(model, None)`` layout) the scattered
        result is ``device_put`` back onto the same sharding, so a repaired
        index keeps the serving path's layout instead of silently
        gathering to one device.
        """
        rows = jnp.asarray(rows, jnp.int32)
        new_v = self.values.at[rows].set(
            jnp.asarray(values, self.values.dtype))
        new_i = self.indices.at[rows].set(
            jnp.asarray(indices, self.indices.dtype))
        sh = getattr(self.values, "sharding", None)
        if sh is not None and not sh.is_fully_replicated:
            new_v = jax.device_put(new_v, sh)
            new_i = jax.device_put(
                new_i, getattr(self.indices, "sharding", sh))
        return PPRIndex(values=new_v, indices=new_i, l=self.l, n=self.n)


def truncate_topl(estimates: jax.Array, l: int) -> Tuple[jax.Array, jax.Array]:
    """Keep the top-``l`` entries of each dense row. Returns (vals, idxs)."""
    vals, idxs = jax.lax.top_k(estimates, l)
    vals = jnp.maximum(vals, 0.0)
    # zero-value slots point at vertex 0 but carry weight 0 -> harmless
    idxs = jnp.where(vals > 0, idxs, 0)
    return vals, idxs.astype(jnp.int32)


def normalize_sketch_to_index_rows(
    fp_v: jax.Array,
    fp_i: jax.Array,
    moves: jax.Array,
    dropped_counts: jax.Array,
    l: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sketch counts -> truncated index rows: the one normalization both
    the single-device chunk (:func:`sparse_chunk_estimates`) and the
    sharded build step (``distributed_engine.make_sparse_index_build_step``)
    apply, so the two builders agree bitwise under the same keys.

    ``fp_v/fp_i [rows, sketch_l]`` is a (merged) visit-count sketch sorted
    descending, ``moves`` the MCFP denominator, ``dropped_counts`` the
    count-domain dropped-mass ledger.  Returns ``(vals, idxs, kept,
    dropped)`` in estimate units, ``vals/idxs`` sliced to width ``l``.
    """
    inv_moves = 1.0 / jnp.maximum(moves[:, None], 1.0)
    est_v = fp_v * inv_moves                          # sorted descending
    vals, idxs = est_v[:, :l], fp_i[:, :l]
    idxs = jnp.where(vals > 0, idxs, 0)
    kept = jnp.sum(vals, axis=1)
    dropped = (
        jnp.sum(est_v[:, l:], axis=1)
        + dropped_counts * inv_moves[:, 0]
    )
    return vals, idxs, kept, dropped


@functools.partial(
    jax.jit,
    static_argnames=(
        "r", "l", "sketch_l", "c", "max_steps", "compact_every", "r_splits",
        "respawn", "touch_bits",
    ),
)
def sparse_chunk_estimates(
    graph: Graph,
    chunk_sources: jax.Array,
    key: jax.Array,
    *,
    r: int,
    l: int,
    sketch_l: int,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
    r_splits: int = 1,
    respawn: bool = False,
    touch_bits: int = 0,
) -> Tuple[jax.Array, ...]:
    """One source chunk of the sparse index build, entirely on device.

    Runs the compacted sparse-sketch walk engine at width ``sketch_l``,
    normalizes to MCFP estimates, and truncates to the index width ``l``
    (the sketch is already sorted descending, so truncation is a slice).
    Returns ``(vals f32[rows, l], idxs int32[rows, l], kept f32[rows],
    dropped f32[rows])`` — the per-row kept/dropped *estimate* mass, left on
    device so the builder syncs once at the end, never per chunk.  The
    traced computation holds no ``f32[rows, n]`` array (the memory contract
    ``tests/test_walks_sparse.py`` asserts on this function's jaxpr).

    ``r_splits > 1`` runs the chunk as that many independent sub-passes of
    ``r / r_splits`` walks (keys ``fold_in(key, split)``) whose sketches are
    concatenated in split order and dedup-merged back to ``sketch_l`` — the
    exact per-chunk key/fold discipline of the sharded builder, so a
    single-device build at ``r_splits = <mesh size>`` reproduces
    :func:`build_index_sharded` row for row.  ``respawn`` selects
    respawn-mode walk scheduling (see
    :func:`repro.core.walks.respawn_schedule`).

    ``touch_bits > 0`` appends a fifth output — the per-row
    "walks-through" Bloom filter ``bool[rows, touch_bits]`` (OR-merged
    across ``r_splits`` sub-passes) that incremental maintenance
    (``core/updates.py``) uses to find the rows an edge update dirties.
    With ``touch_bits=0`` the signature and traced computation are
    unchanged (the jaxpr memory contract in ``tests/test_walks_sparse.py``
    keeps holding as-is).
    """
    if r % r_splits != 0:
        raise ValueError(f"r={r} must divide over r_splits={r_splits}")
    touch = None
    if r_splits > 1:
        vs, is_ = [], []
        moves = jnp.zeros((chunk_sources.shape[0],), jnp.float32)
        dropped = jnp.zeros_like(moves)
        for s in range(r_splits):
            counts = simulate_walks_sparse(
                graph, chunk_sources, r // r_splits,
                jax.random.fold_in(key, s), l=sketch_l, ep_l=0, c=c,
                max_steps=max_steps, compact_every=compact_every,
                respawn=respawn, touch_bits=touch_bits,
            )
            vs.append(counts.fp.values)
            is_.append(counts.fp.indices)
            moves = moves + counts.moves
            dropped = dropped + counts.fp_dropped
            if touch_bits:
                touch = counts.touch if touch is None else touch | counts.touch
        fp_v, fp_i, dropped = frontier_mod.merge_sketch_parts(
            jnp.concatenate(vs, axis=1), jnp.concatenate(is_, axis=1),
            dropped, sketch_l,
        )
    else:
        counts = simulate_walks_sparse(
            graph, chunk_sources, r, key, l=sketch_l, ep_l=0, c=c,
            max_steps=max_steps, compact_every=compact_every,
            respawn=respawn, touch_bits=touch_bits,
        )
        fp_v, fp_i = counts.fp.values, counts.fp.indices
        moves, dropped = counts.moves, counts.fp_dropped
        touch = counts.touch
    out = normalize_sketch_to_index_rows(fp_v, fp_i, moves, dropped, l)
    return out + (touch,) if touch_bits else out


def build_index(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
    sources: Optional[np.ndarray] = None,
    engine: str = "sparse",
    compact_every: int = 8,
    r_splits: int = 1,
    respawn: bool = False,
    touch_bits: int = 0,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
    resume: bool = False,
    checkpoint_keep: int = 3,
    fault_plan=None,
) -> Tuple[PPRIndex, dict]:
    """Offline preprocessing: MCFP for every vertex, truncated to top-L.

    ``engine="sparse"`` (default) streams the compacted sparse-sketch walk
    engine straight into the fixed-width index: peak device memory is
    ``O(source_batch * sketch_l)`` per chunk plus the ``[n, L]`` index
    itself — no ``f32[rows, n]`` accumulator, no host numpy round-trip, so
    the build runs at the graph sizes the online sparse path already
    handles.  ``engine="legacy"`` keeps the dense-accumulator oracle.
    ``r_splits``/``respawn`` (sparse engine only) select the sharded
    builder's per-chunk walk decomposition and respawn-mode scheduling —
    see :func:`sparse_chunk_estimates` and :func:`build_index_sharded`.

    Duplicate ``sources`` entries are deduplicated up front (a repeated id
    would otherwise last-writer-win in the subset scatter *and*
    double-count the kept/dropped mass ledger); the count is reported as
    ``stats["duplicate_sources"]`` and the build runs over the sorted
    unique set.

    **Crash safety** (sparse engine only): with ``checkpoint_dir`` set the
    build commits, every ``checkpoint_every`` source chunks, the partial
    index rows, the conservation ledger, the touch filters, and the
    completed-chunk frontier through
    :class:`repro.distributed.checkpoint.Checkpointer` (atomic rename,
    per-shard checksums).  ``resume=True`` restores the newest *committed*
    step — mid-write ``.tmp`` dirs are ignored, checksum-corrupted steps
    fall back to the prior commit — verifies the build signature (graph
    topology, key, chunk grid), and continues from the first incomplete
    chunk.  Because per-chunk keys are positional (``fold_in(key, chunk
    offset)``), a resumed build equals an uninterrupted one **bitwise**
    (``tests/test_checkpoint_resume.py``).  ``fault_plan`` is the testing
    seam of :mod:`repro.testing.faults`.

    Returns (index, stats) where stats reports the truncated tail mass —
    the accuracy cost of the memory budget.  All host syncs are deferred to
    one ``device_get`` at the end.
    """
    n = graph.n
    l = min(l, n)  # a row holds at most n entries (both engines rely on it)
    if checkpoint_dir is not None and engine != "sparse":
        raise ValueError("checkpointing requires engine='sparse'")
    if sources is None:
        # the default full sweep is unique by construction: skip the
        # O(n log n) host sort + copies the dedup would cost at scale
        sources = np.arange(n, dtype=np.int32)
        duplicate_sources = 0
    else:
        sources = np.asarray(sources, dtype=np.int32)
        unique_sources = np.unique(sources)  # sorted unique set
        duplicate_sources = len(sources) - len(unique_sources)
        sources = unique_sources
    if engine == "sparse":
        index, stats = _build_index_sparse(
            graph, r, l, key, c=c, max_steps=max_steps,
            source_batch=source_batch, sources=sources,
            compact_every=compact_every, r_splits=r_splits, respawn=respawn,
            touch_bits=touch_bits, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, resume=resume,
            checkpoint_keep=checkpoint_keep, fault_plan=fault_plan,
        )
        stats["duplicate_sources"] = duplicate_sources
        return index, stats
    if engine != "legacy":
        raise ValueError(f"unknown engine {engine!r}")
    if r_splits != 1 or respawn or touch_bits:
        raise ValueError(
            "r_splits/respawn/touch_bits apply to the sparse engine only"
        )

    values = np.zeros((n, l), dtype=np.float32)
    indices = np.zeros((n, l), dtype=np.int32)
    # per-chunk (total, kept) stay device scalars; one sync at the end so
    # the host never blocks the dispatch pipeline mid-stream
    totals = []
    kepts = []

    @jax.jit
    def trunc(e):
        vals, idxs = truncate_topl(e, l)
        return vals, idxs, jnp.sum(e), jnp.sum(vals)

    stats: dict = {}
    for chunk_ids, est in mcfp.estimate_ppr_batched(
        graph, sources, r, key, c=c, max_steps=max_steps,
        source_batch=source_batch, stats=stats,
    ):
        real = est.shape[0]
        if real < source_batch:  # re-pad the ragged tail: trunc compiles
            est = jnp.pad(est, ((0, source_batch - real), (0, 0)))  # once
        vals, idxs, total, k = trunc(est)
        values[chunk_ids] = np.asarray(vals[:real])
        indices[chunk_ids] = np.asarray(idxs[:real])
        totals.append(total)  # pad rows are all-zero: sums unaffected
        kepts.append(k)
    if totals:
        total, kept = jax.device_get(
            (jnp.sum(jnp.stack(totals)), jnp.sum(jnp.stack(kepts)))
        )
    else:  # empty sources: a valid all-zero index
        total = kept = 0.0
    dropped = float(total) - float(kept)
    stats.update(
        r=r,
        l=l,
        engine="legacy",
        duplicate_sources=duplicate_sources,
        kept_mass=float(kept),
        dropped_mass=dropped,
        drop_fraction=dropped / max(float(total), 1e-12),
        nbytes=n * l * 8,
    )
    return (
        PPRIndex(
            values=jnp.asarray(values), indices=jnp.asarray(indices), l=l, n=n
        ),
        stats,
    )


def _make_build_checkpointer(
    checkpoint_dir: Optional[str], checkpoint_every: int,
    checkpoint_keep: int, fault_plan,
) -> Optional[Checkpointer]:
    if checkpoint_dir is None:
        return None
    if checkpoint_every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, got {checkpoint_every}")
    return Checkpointer(
        checkpoint_dir, keep=checkpoint_keep,
        pre_commit=None if fault_plan is None else fault_plan.pre_commit,
    )


def _resume_build_state(
    ckpt: Checkpointer, signature: dict,
) -> Optional[Tuple[int, dict, dict]]:
    """Restore the newest committed, checksum-verified build checkpoint.

    Returns ``(next_chunk, tree, extra)`` or ``None`` (no usable step:
    start from scratch).  A committed step whose *signature* differs is an
    error — resuming a different build into this directory would splice
    incompatible RNG streams; corrupted/mid-write steps were already
    filtered by ``restore_latest``.
    """
    hit = ckpt.restore_latest()
    if hit is None:
        return None
    step, tree, extra = hit
    if extra.get("signature") != signature:
        raise ValueError(
            f"checkpoint at {ckpt.root} step {step} was written by a "
            "different build (graph/key/chunk-grid signature mismatch); "
            "refusing to resume"
        )
    return int(extra["next_chunk"]), tree, extra


def _complete_stats(extra: dict, tree: dict, touch_bits: int) -> dict:
    """Stats of a restored *complete* build checkpoint (json round-trip of
    the floats is exact in Python 3)."""
    stats = dict(extra["stats"])
    stats["resumed_complete"] = True
    if touch_bits:
        stats["touch"] = jnp.asarray(tree["touch"])
        stats["touch_bits"] = touch_bits
    return stats


def _build_index_sparse(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    c: float,
    max_steps: int,
    source_batch: int,
    sources: np.ndarray,
    compact_every: int,
    r_splits: int = 1,
    respawn: bool = False,
    touch_bits: int = 0,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
    resume: bool = False,
    checkpoint_keep: int = 3,
    fault_plan=None,
) -> Tuple[PPRIndex, dict]:
    """Streaming sparse build: ``SparseWalkCounts -> PPRIndex`` on device.

    ``sources`` must be unique (``build_index`` dedups before dispatch).
    ``touch_bits > 0`` additionally returns the per-row walks-through Bloom
    filter as ``stats["touch"]`` (``bool[n, touch_bits]``, zero rows for
    unswept sources) — the invalidation sketch of ``core/updates.py``.

    With ``checkpoint_dir`` the chunk loop commits its partial state every
    ``checkpoint_every`` chunks (step number == chunks completed) and a
    final ``complete=True`` step holding the assembled index; see
    :func:`build_index` for the resume contract.
    """
    n = graph.n
    l = min(l, n)
    # sketch headroom over the index width keeps the running top-L honest:
    # entries near rank l compete inside the sketch before the final slice
    sketch_l = min(n, max(2 * l, l + 32))
    sources = np.asarray(sources, dtype=np.int32)
    n_src = len(sources)
    pad_rows = (-n_src) % source_batch
    padded = np.concatenate(
        [sources, np.zeros(pad_rows, np.int32)]
    ) if pad_rows else sources
    n_chunks = len(padded) // source_batch

    ckpt = _make_build_checkpointer(
        checkpoint_dir, checkpoint_every, checkpoint_keep, fault_plan)
    signature = None
    if ckpt is not None:
        signature = dict(
            kind="build_index_sparse",
            r=int(r), l=int(l), sketch_l=int(sketch_l), c=float(c),
            max_steps=int(max_steps), compact_every=int(compact_every),
            r_splits=int(r_splits), respawn=bool(respawn),
            touch_bits=int(touch_bits), source_batch=int(source_batch),
            n=int(n), n_src=int(n_src),
            sources_crc=zlib.crc32(sources.tobytes()) & 0xFFFFFFFF,
            graph_crc=graph_fingerprint(graph),
            key=serialize_key(key),
        )

    vals_chunks: List = []
    idxs_chunks: List = []
    touch_chunks: List = []
    ledger = BuildLedger()
    start_chunk = 0
    commits = 0
    if ckpt is not None and resume:
        restored = _resume_build_state(ckpt, signature)
        if restored is not None:
            start_chunk, tree, extra = restored
            if extra.get("complete"):
                index = PPRIndex(
                    values=jnp.asarray(tree["vals"]),
                    indices=jnp.asarray(tree["idxs"]), l=l, n=n,
                )
                return index, _complete_stats(extra, tree, touch_bits)
            vals_chunks.append(tree["vals"])
            idxs_chunks.append(tree["idxs"])
            ledger = BuildLedger.restore(tree["kept"], tree["dropped"])
            if touch_bits:
                touch_chunks.append(tree["touch"])

    def commit_partial(done: int) -> None:
        nonlocal ledger, commits
        kept_arr, dropped_arr = ledger.export()
        tree = dict(
            vals=np.asarray(jnp.concatenate(vals_chunks, axis=0)),
            idxs=np.asarray(jnp.concatenate(idxs_chunks, axis=0)),
            kept=kept_arr, dropped=dropped_arr,
        )
        if touch_bits:
            tree["touch"] = np.asarray(jnp.concatenate(touch_chunks, axis=0))
        ckpt.save(done, tree, dict(
            signature=signature, complete=False,
            next_chunk=done, n_chunks=n_chunks,
        ))
        commits += 1
        # consolidate: the committed host arrays replace the per-chunk
        # device arrays (concatenation is pure layout, so the final
        # assembly stays bitwise identical) and cap the lists' growth
        vals_chunks[:] = [tree["vals"]]
        idxs_chunks[:] = [tree["idxs"]]
        if touch_bits:
            touch_chunks[:] = [tree["touch"]]
        ledger = BuildLedger.restore(kept_arr, dropped_arr)

    for ci in range(start_chunk, n_chunks):
        if fault_plan is not None:
            fault_plan.chunk_boundary(ci)
        i = ci * source_batch
        chunk = jnp.asarray(padded[i : i + source_batch])
        real = min(source_batch, n_src - i)
        sub_key = jax.random.fold_in(key, i)
        out = sparse_chunk_estimates(
            graph, chunk, sub_key, r=r, l=l, sketch_l=sketch_l, c=c,
            max_steps=max_steps, compact_every=compact_every,
            r_splits=r_splits, respawn=respawn, touch_bits=touch_bits,
        )
        vals, idxs, kept, dropped = out[:4]
        # device-level slicing of the ragged tail: no host sync, pad rows
        # never reach the index or the stats
        vals_chunks.append(vals[:real])
        idxs_chunks.append(idxs[:real])
        ledger.append(jnp.sum(kept[:real]), jnp.sum(dropped[:real]))
        if touch_bits:
            touch_chunks.append(out[4][:real])
        done = ci + 1
        if ckpt is not None and done < n_chunks \
                and done % checkpoint_every == 0:
            commit_partial(done)

    touch = None
    if not n_src:  # empty sources: a valid all-zero index
        values = jnp.zeros((n, l), jnp.float32)
        indices = jnp.zeros((n, l), jnp.int32)
        if touch_bits:
            touch = jnp.zeros((n, touch_bits), bool)
    elif n_src == n and np.array_equal(
        sources, np.arange(n, dtype=np.int32)
    ):
        values = jnp.concatenate(vals_chunks, axis=0)
        indices = jnp.concatenate(idxs_chunks, axis=0)
        if touch_bits:
            touch = jnp.concatenate(touch_chunks, axis=0)
    else:  # subset build: one scatter into the zero index
        src_dev = jnp.asarray(sources)
        values = jnp.zeros((n, l), jnp.float32).at[src_dev].set(
            jnp.concatenate(vals_chunks, axis=0)
        )
        indices = jnp.zeros((n, l), jnp.int32).at[src_dev].set(
            jnp.concatenate(idxs_chunks, axis=0)
        )
        if touch_bits:
            touch = jnp.zeros((n, touch_bits), bool).at[src_dev].set(
                jnp.concatenate(touch_chunks, axis=0)
            )
    kept, dropped = ledger.totals()
    stats = dict(
        r=r,
        l=l,
        engine="sparse",
        sketch_l=sketch_l,
        r_splits=r_splits,
        respawn=bool(respawn),
        source_batch=source_batch,
        pad_rows=pad_rows,
        pad_fraction=pad_rows / max(n_src + pad_rows, 1),
        kept_mass=kept,
        dropped_mass=dropped,
        drop_fraction=dropped / max(kept + dropped, 1e-12),
        nbytes=n * l * 8,
    )
    if ckpt is not None:
        stats["checkpoint_commits"] = commits
        stats["resumed_at_chunk"] = start_chunk
        kept_arr, dropped_arr = ledger.export()
        tree = dict(
            vals=np.asarray(values), idxs=np.asarray(indices),
            kept=kept_arr, dropped=dropped_arr,
        )
        if touch_bits:
            tree["touch"] = np.asarray(touch)
        ckpt.save(n_chunks, tree, dict(
            signature=signature, complete=True,
            next_chunk=n_chunks, n_chunks=n_chunks,
            stats={k: v for k, v in stats.items() if k != "touch"},
        ))
    if touch_bits:
        stats["touch"] = touch
        stats["touch_bits"] = touch_bits
    return PPRIndex(values=values, indices=indices, l=l, n=n), stats


@functools.lru_cache(maxsize=32)
def _cached_sharded_build_step(
    cfg, mesh, r, l, sketch_l, real_n, max_steps, compact_every,
    source_batch, respawn, touch_bits=0, chunk_start=0, chunk_count=None,
):
    """Jitted sharded-build step, memoized on its static config so repeated
    :func:`build_index_sharded` calls (benchmark sweeps, rebuild loops)
    reuse one compilation instead of re-tracing the whole sweep.
    ``chunk_start``/``chunk_count`` select a per-shard chunk segment (the
    checkpointed build); the defaults sweep the whole grid."""
    from repro.core.distributed_engine import make_sparse_index_build_step

    return jax.jit(make_sparse_index_build_step(
        cfg, mesh, r=r, l=l, sketch_l=sketch_l, real_n=real_n,
        max_steps=max_steps, compact_every=compact_every,
        source_batch=source_batch, respawn=respawn, touch_bits=touch_bits,
        chunk_start=chunk_start, chunk_count=chunk_count,
    ))


def build_index_sharded(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    mesh,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
    compact_every: int = 8,
    respawn: bool = True,
    model_axis: str = "model",
    batch_axes: Tuple[str, ...] = ("data",),
    touch_bits: int = 0,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
    resume: bool = False,
    checkpoint_keep: int = 3,
    fault_plan=None,
) -> Tuple[PPRIndex, dict]:
    """Pod-scale offline preprocessing: the full-index build under a mesh.

    The single-device :func:`build_index` drives every source chunk from
    the host on one device; here the whole sweep is one device-side
    computation (``distributed_engine.make_sparse_index_build_step``):

    * **sources shard over the model axis** — each shard sweeps the source
      chunks of its own vertex interval with a ``lax.scan``, so the
      resulting ``PPRIndex`` ``values/indices [n, L]`` come back sharded
      ``P(model, None)`` and no device ever holds (or builds) the full
      index;
    * **walks split over the batch axes** — each data replica runs
      ``r / n_data`` walks per row with a per-replica key and the sketches
      dedup-merge through one ``all_gather`` (the
      ``make_sparse_walk_counts_step`` merge);
    * **respawn-mode scheduling** (default on) keeps walk-slot occupancy
      ~100% through the sweep instead of re-entering the ``(1-c)^t``
      schedule tail for every chunk
      (:func:`repro.core.walks.respawn_schedule`).

    Key discipline: chunk at global source offset ``o`` uses
    ``fold_in(key, o)`` and data-replica ``s`` folds ``s`` on top — exactly
    :func:`build_index` with ``engine="sparse", r_splits=n_data`` over the
    same chunk grid, so the sharded and single-device builds agree row for
    row (the ``tests/dist_engine_check.py`` parity gate).

    The vertex count pads up to ``ep * ceil_to(source_batch)`` so shard
    intervals align with the chunk grid; pad vertices are dangling, their
    rows are zeroed device-side, and the returned index has ``n = n_pad``
    (consumers only ever gather real rows; ``BatchQueryEngine`` accepts
    ``index.n >= graph.n``).  Stats mirror :func:`build_index` plus
    ``n``/``n_pad``/``shards``/``r_splits``.

    **Crash safety.**  With ``checkpoint_dir`` the one-scan sweep is
    segmented at per-shard chunk granularity: every ``checkpoint_every``
    chunks the completed shard blocks (index rows, kept/dropped ledgers,
    touch Bloom filters) commit atomically through
    :class:`repro.distributed.checkpoint.Checkpointer`, and a final
    ``complete=True`` step stores the assembled ``[n_pad, l]`` index.
    Because chunk keys are positional (``fold_in(key, offset)``), the
    segmented sweep — and any ``resume=True`` restart from the newest
    committed, checksum-verified step — reproduces the uninterrupted build
    bit for bit.  Restarting onto a different graph/key/mesh/chunk-grid is
    refused (signature mismatch).  ``fault_plan`` fires its
    ``chunk_boundary`` hook at each segment's first chunk index.
    """
    from repro.core.distributed_engine import DistConfig

    ep = int(mesh.shape[model_axis])
    n_split = 1
    for ax in batch_axes:
        n_split *= int(mesh.shape[ax])
    if r % n_split != 0:
        raise ValueError(
            f"r={r} must divide evenly over the {n_split} walk shards"
        )
    n = graph.n
    l = min(l, n)
    sketch_l = min(n, max(2 * l, l + 32))  # same headroom as single-device
    ns = -(-n // ep)
    if source_batch > ns:
        # clamping changes the chunk grid — and with it the per-chunk keys.
        # Row-for-row parity with the single-device build then requires
        # passing the *effective* batch (stats["source_batch"]) to
        # build_index, not the requested one.  (Rounding the shard interval
        # up to the requested batch instead would sweep r walks for every
        # phantom pad row — worse than the narrower grid.)
        warnings.warn(
            f"source_batch={source_batch} exceeds the per-shard interval; "
            f"clamped to {ns} — single-device parity comparisons must use "
            "the effective batch from stats['source_batch']",
            stacklevel=2,
        )
    source_batch = max(1, min(source_batch, ns))
    ns = -(-ns // source_batch) * source_batch
    n_pad = ns * ep
    cfg = DistConfig(
        n=n_pad, ep=ep, c=c, model_axis=model_axis,
        batch_axes=tuple(batch_axes),
    )
    # pad the graph arrays host-side: pad vertices are dangling, so their
    # (discarded) rows walk in place and never touch real rows' streams
    rp = np.asarray(graph.row_ptr, np.int32)
    od = np.asarray(graph.out_deg, np.int32)
    if n_pad > n:
        rp = np.concatenate([rp, np.full(n_pad - n, rp[-1], np.int32)])
        od = np.concatenate([od, np.zeros(n_pad - n, np.int32)])
    rp_j = jnp.asarray(rp)
    col_j = jnp.asarray(np.asarray(graph.col_idx, np.int32))
    od_j = jnp.asarray(od)
    n_chunks = ns // source_batch
    ckpt = _make_build_checkpointer(
        checkpoint_dir, checkpoint_every, checkpoint_keep, fault_plan)
    extra_stats: dict = {}
    if ckpt is None:
        step = _cached_sharded_build_step(
            cfg, mesh, r, l, sketch_l, n, max_steps, compact_every,
            source_batch, respawn, touch_bits,
        )
        with mesh:
            out = step(rp_j, col_j, od_j, key)
        values, indices, kept_rows, dropped_rows = out[:4]
        touch = out[4] if touch_bits else None
        kept, dropped = jax.device_get(
            (jnp.sum(kept_rows), jnp.sum(dropped_rows))
        )
        kept, dropped = float(kept), float(dropped)
    else:
        signature = dict(
            kind="build_index_sharded",
            r=int(r), l=int(l), sketch_l=int(sketch_l), c=float(c),
            max_steps=int(max_steps), compact_every=int(compact_every),
            source_batch=int(source_batch), respawn=bool(respawn),
            touch_bits=int(touch_bits), n=int(n), n_pad=int(n_pad),
            shards=int(ep), r_splits=int(n_split),
            model_axis=str(model_axis), batch_axes=list(batch_axes),
            mesh_shape={str(ax): int(sz) for ax, sz in mesh.shape.items()},
            graph_crc=graph_fingerprint(graph),
            key=serialize_key(key),
        )
        from jax.sharding import NamedSharding, PartitionSpec
        sh_rows = NamedSharding(mesh, PartitionSpec(model_axis, None))
        # per-shard-major blocks: [ep, done * source_batch, ...] host arrays
        seg_vals: List = []
        seg_idxs: List = []
        seg_kept: List = []
        seg_dropped: List = []
        seg_touch: List = []
        start_chunk = 0
        commits = 0
        if resume:
            restored = _resume_build_state(ckpt, signature)
            if restored is not None:
                start_chunk, tree, extra = restored
                if extra.get("complete"):
                    stats = dict(extra["stats"])
                    stats["resumed_complete"] = True
                    values = jax.device_put(np.asarray(tree["vals"]), sh_rows)
                    indices = jax.device_put(np.asarray(tree["idxs"]), sh_rows)
                    if touch_bits:
                        stats["touch"] = jax.device_put(
                            np.asarray(tree["touch"]), sh_rows)
                        stats["touch_bits"] = touch_bits
                    return (
                        PPRIndex(values=values, indices=indices,
                                 l=l, n=n_pad),
                        stats,
                    )
                seg_vals.append(np.asarray(tree["vals"]))
                seg_idxs.append(np.asarray(tree["idxs"]))
                seg_kept.append(np.asarray(tree["kept"]))
                seg_dropped.append(np.asarray(tree["dropped"]))
                if touch_bits:
                    seg_touch.append(np.asarray(tree["touch"]))
        ci = start_chunk
        while ci < n_chunks:
            if fault_plan is not None:
                fault_plan.chunk_boundary(ci)
            cnt = min(checkpoint_every, n_chunks - ci)
            seg_step = _cached_sharded_build_step(
                cfg, mesh, r, l, sketch_l, n, max_steps, compact_every,
                source_batch, respawn, touch_bits, ci, cnt,
            )
            with mesh:
                out = seg_step(rp_j, col_j, od_j, key)
            rows = cnt * source_batch
            host = jax.device_get(out)
            seg_vals.append(np.asarray(host[0]).reshape(ep, rows, l))
            seg_idxs.append(np.asarray(host[1]).reshape(ep, rows, l))
            seg_kept.append(np.asarray(host[2]).reshape(ep, rows))
            seg_dropped.append(np.asarray(host[3]).reshape(ep, rows))
            if touch_bits:
                seg_touch.append(
                    np.asarray(host[4]).reshape(ep, rows, touch_bits))
            ci += cnt
            if ci < n_chunks:
                tree = dict(
                    vals=np.concatenate(seg_vals, axis=1),
                    idxs=np.concatenate(seg_idxs, axis=1),
                    kept=np.concatenate(seg_kept, axis=1),
                    dropped=np.concatenate(seg_dropped, axis=1),
                )
                if touch_bits:
                    tree["touch"] = np.concatenate(seg_touch, axis=1)
                ckpt.save(ci, tree, dict(
                    signature=signature, complete=False,
                    next_chunk=ci, n_chunks=n_chunks,
                ))
                commits += 1
                seg_vals[:] = [tree["vals"]]
                seg_idxs[:] = [tree["idxs"]]
                seg_kept[:] = [tree["kept"]]
                seg_dropped[:] = [tree["dropped"]]
                if touch_bits:
                    seg_touch[:] = [tree["touch"]]
        # shard-major reassembly: concat segments per shard, then stack
        # shards — exactly the [n_pad, l] row order of the one-scan sweep
        vals_h = np.concatenate(seg_vals, axis=1).reshape(n_pad, l)
        idxs_h = np.concatenate(seg_idxs, axis=1).reshape(n_pad, l)
        kept_h = np.concatenate(seg_kept, axis=1).reshape(n_pad)
        dropped_h = np.concatenate(seg_dropped, axis=1).reshape(n_pad)
        values = jax.device_put(vals_h, sh_rows)
        indices = jax.device_put(idxs_h, sh_rows)
        touch = None
        if touch_bits:
            touch_h = np.concatenate(
                seg_touch, axis=1).reshape(n_pad, touch_bits)
            touch = jax.device_put(touch_h, sh_rows)
        kept = float(jnp.sum(jnp.asarray(kept_h)))
        dropped = float(jnp.sum(jnp.asarray(dropped_h)))
        extra_stats = dict(
            checkpoint_commits=commits, resumed_at_chunk=start_chunk)
    stats = dict(
        r=r,
        l=l,
        engine="sparse-sharded",
        sketch_l=sketch_l,
        r_splits=n_split,
        respawn=bool(respawn),
        n=n,
        n_pad=n_pad,
        shards=ep,
        source_batch=source_batch,
        pad_rows=n_pad - n,
        pad_fraction=(n_pad - n) / max(n_pad, 1),
        duplicate_sources=0,
        kept_mass=kept,
        dropped_mass=dropped,
        drop_fraction=dropped / max(kept + dropped, 1e-12),
        nbytes=n_pad * l * 8,
    )
    stats.update(extra_stats)
    if ckpt is not None:
        tree = dict(vals=vals_h, idxs=idxs_h,
                    kept=kept_h, dropped=dropped_h)
        if touch_bits:
            tree["touch"] = touch_h
        ckpt.save(n_chunks, tree, dict(
            signature=signature, complete=True,
            next_chunk=n_chunks, n_chunks=n_chunks,
            stats={k: v for k, v in stats.items() if k != "touch"},
        ))
    if touch_bits:
        stats["touch"] = touch
        stats["touch_bits"] = touch_bits
    return PPRIndex(values=values, indices=indices, l=l, n=n_pad), stats


def load_index_checkpoint(
    checkpoint_dir: str,
) -> Tuple[PPRIndex, dict]:
    """Load the newest *complete* committed index from a build checkpoint.

    The serving boot path: after a (possibly resumed) build finishes, its
    final ``complete=True`` step holds the assembled index rows plus the
    json-safe build stats — a server restart reloads them without
    re-simulating a single walk.  Partial (mid-build) steps and ``.tmp``
    dirs are never candidates; corrupted steps fall back to the prior
    complete step; no usable step raises ``FileNotFoundError``.
    """
    ckpt = Checkpointer(checkpoint_dir)
    hit = ckpt.restore_latest(
        predicate=lambda extra: bool(extra.get("complete")))
    if hit is None:
        raise FileNotFoundError(
            f"no complete committed index checkpoint under {checkpoint_dir}"
        )
    _, tree, extra = hit
    stats = dict(extra["stats"])
    values = jnp.asarray(tree["vals"])
    indices = jnp.asarray(tree["idxs"])
    n, l = values.shape
    if "touch" in tree:
        touch = jnp.asarray(tree["touch"])
        stats["touch"] = touch
        stats["touch_bits"] = int(touch.shape[1])
    return PPRIndex(values=values, indices=indices,
                    l=int(l), n=int(n)), stats


def index_from_dense(estimates: jax.Array, l: int) -> PPRIndex:
    """Build an index from precomputed dense vectors (tests/baselines)."""
    vals, idxs = truncate_topl(estimates, l)
    return PPRIndex(
        values=vals, indices=idxs, l=l, n=int(estimates.shape[1])
    )


# ---------------------------------------------------------------------------
# Memory-budget planning (paper Section 3: offline/online trade-off knob)
# ---------------------------------------------------------------------------

# Paper Figure 5 / Section 4.2: iterations needed for RAG > 0.99 at R.
_PAPER_T_FOR_R = ((0, 7), (10, 5), (100, 2))


@dataclasses.dataclass(frozen=True)
class IndexPlan:
    r: int              # walks per vertex offline
    l: int              # index width (top-L)
    t_online: int       # VERD iterations online
    index_bytes: int
    budget_bytes: int
    walk_state_bytes: int = 0   # per-chunk walk/event state priced in
    respawn: bool = True        # scheduling mode the plan was priced for


# Walk-state pricing per slot: a live slot holds its cursor (int32) + alive
# flag (bool); each scan round additionally materializes, per slot-step, the
# two pre-drawn uniforms (2 x f32) and the stacked (af, pos, tf) event
# columns (f32 + int32 + f32) the sketch folds consume.
_SLOT_BYTES = 5
_SLOT_STEP_BYTES = 20


def walk_state_cost(
    r: int,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
    source_batch: int = 256,
    respawn: bool = True,
) -> dict:
    """Schedule-derived device cost of one source chunk's walk pass.

    Prices the *actual* static schedule the engine would run — respawn mode
    (``respawn_schedule``: narrow fixed-width slots at ~100% occupancy) vs
    decay mode (``compaction_schedule``: width starts at ``r``) — via
    :func:`repro.core.walks.schedule_slot_area`, the formula
    ``test_respawn_schedule_halves_device_work`` pins.  Returns per-row
    ``slot_area`` (device slot-steps), the peak ``max_width``, the pass
    ``total_steps``, and ``walk_state_bytes`` for a ``source_batch``-row
    chunk.
    """
    if r <= 0:
        return dict(max_width=0, slot_area=0, total_steps=0,
                    walk_state_bytes=0)
    if respawn:
        widths, total_steps = respawn_schedule(
            r, c=c, max_steps=max_steps, compact_every=compact_every)
    else:
        widths = compaction_schedule(
            r, c=c, max_steps=max_steps, compact_every=compact_every)
        total_steps = max_steps
    area = schedule_slot_area(widths, total_steps, compact_every)
    w_max = max(widths)
    per_slot = _SLOT_BYTES + _SLOT_STEP_BYTES * min(compact_every,
                                                    total_steps)
    return dict(
        max_width=w_max,
        slot_area=area,
        total_steps=total_steps,
        walk_state_bytes=int(source_batch * w_max * per_slot),
    )


def plan_for_budget(
    n: int,
    budget_bytes: int,
    *,
    c: float = DEFAULT_C,
    bytes_per_entry: int = 8,
    max_steps: int = 64,
    compact_every: int = 8,
    source_batch: int = 256,
    respawn: bool = True,
) -> IndexPlan:
    """Choose (R, L, T) for a memory budget.

    An MCFP vector from ``R`` walks has ``<= R/c`` support, so ``R =
    floor(c * L)`` saturates the width; the online iteration count
    interpolates the paper's measured (R -> T) table.  ``L`` is the largest
    width whose *total* device footprint fits: index bytes ``n * L * 8``
    plus the walk-state bytes of one build chunk at the schedule the engine
    would actually run (:func:`walk_state_cost`) — respawn mode's narrow
    fixed-width slots (the default) afford a larger ``R`` at the same
    budget than decay-mode pricing, which scales with ``w_max = R``.
    """
    def state_bytes(l: int) -> int:
        return walk_state_cost(
            int(c * l), c=c, max_steps=max_steps,
            compact_every=compact_every, source_batch=source_batch,
            respawn=respawn,
        )["walk_state_bytes"]

    def fits(l: int) -> bool:
        return n * bytes_per_entry * l + state_bytes(l) <= budget_bytes

    # both cost terms are monotone in l: binary-search the largest feasible
    # width, starting from the index-only cap
    lo, hi = 0, max(int(budget_bytes // (max(n, 1) * bytes_per_entry)), 0)
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if fits(mid):
            lo = mid
        else:
            hi = mid - 1
    l = lo
    r = int(c * l)
    t = 7
    for r_ref, t_ref in _PAPER_T_FOR_R:
        if r >= r_ref:
            t = t_ref
    return IndexPlan(
        r=r, l=l, t_online=t,
        index_bytes=n * l * bytes_per_entry, budget_bytes=budget_bytes,
        walk_state_bytes=state_bytes(l), respawn=bool(respawn),
    )


def preprocessing_cost_model(
    n: int,
    r: int,
    *,
    c: float = DEFAULT_C,
    step_rate: float = 5e8,
    max_steps: int = 64,
    compact_every: int = 8,
    source_batch: int = 256,
    respawn: bool = True,
) -> dict:
    """Analytic preprocessing cost (paper Table 2 extrapolation).

    Total walk positions ~ n*R/c; ``step_rate`` is positions/sec for the
    bulk engine (fitted from measured small-graph runs by the benchmark).
    Index size is n*min(R/c, L)*8 bytes before compression.  Device-side
    cost is additionally priced at the *schedule* the engine runs
    (:func:`walk_state_cost`): ``slot_positions`` are the device slot-steps
    of the full sweep, ``slot_occupancy`` how many of those slot-steps move
    a live walk (respawn mode ~doubles it), ``walk_state_bytes`` the
    per-chunk walk/event state the memory planner charges.
    """
    positions = n * r / c
    sc = walk_state_cost(
        r, c=c, max_steps=max_steps, compact_every=compact_every,
        source_batch=source_batch, respawn=respawn,
    )
    slot_positions = n * sc["slot_area"]
    return dict(
        walk_positions=positions,
        est_seconds=positions / step_rate,
        index_bytes_uncapped=int(n * (r / c) * 8),
        respawn=bool(respawn),
        max_slot_width=sc["max_width"],
        slot_positions=slot_positions,
        slot_occupancy=positions / max(slot_positions, 1),
        walk_state_bytes=sc["walk_state_bytes"],
    )


# ---------------------------------------------------------------------------
# Contract-auditor entry point (repro.analysis): the sparse build's
# per-chunk computation holds no f32[rows, n] intermediate — peak device
# memory is O(rows * sketch_l), independent of n beyond the CSR itself.
# Mirrors tests/test_walks_sparse.py::test_build_index_sparse_memory_contract.
# ---------------------------------------------------------------------------

from repro.analysis.registry import register_entry_point as _register_ep


def _contract_spec_sparse_walk_chunk():
    from repro.graphs import synthetic

    g = synthetic.rmat(12, avg_deg=6.0, seed=5)      # n = 4096
    rows, r, l = 64, 16, 32
    sketch_l = max(2 * l, l + 32)
    chunk = jnp.arange(rows, dtype=jnp.int32)
    fn = functools.partial(
        sparse_chunk_estimates, r=r, l=l, sketch_l=sketch_l
    )
    jaxpr = jax.make_jaxpr(fn)(g, chunk, jax.random.PRNGKey(0))
    # widest fold candidate row: sketch + a full pending buffer + the last
    # event segment that tipped it over (<= compact_every * r wide)
    budget = rows * (sketch_l + max(4 * sketch_l, 512) + 8 * r + 8)
    return dict(jaxpr=jaxpr, budget=budget, floor=rows * g.n)


_register_ep("sparse-walk-chunk", "dense-state-bound",
             "src/repro/core/index.py", _contract_spec_sparse_walk_chunk)
