"""Accuracy metrics: RAG@k (paper Section 4.2) and friends."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rag_at_k(exact: jax.Array, approx: jax.Array, k: int) -> jax.Array:
    """Relative Aggregated Goodness per query row.

    ``RAG(k, u) = sum_{v in T_hat_k} p_u(v) / sum_{v in T_k} p_u(v)`` where
    ``T_hat_k`` is the approximate top-k set and ``T_k`` the exact one.
    exact/approx: f32[Q, n].  Returns f32[Q] in [0, 1].
    """
    _, approx_top = jax.lax.top_k(approx, k)
    exact_topv, _ = jax.lax.top_k(exact, k)
    num = jnp.take_along_axis(exact, approx_top, axis=1).sum(axis=1)
    den = jnp.maximum(exact_topv.sum(axis=1), 1e-30)
    return num / den


def mean_rag(exact, approx, k: int) -> float:
    return float(jnp.mean(rag_at_k(exact, approx, k)))


def l1_error(exact: jax.Array, approx: jax.Array) -> jax.Array:
    return jnp.abs(exact - approx).sum(axis=-1)


def linf_error(exact: jax.Array, approx: jax.Array) -> jax.Array:
    return jnp.abs(exact - approx).max(axis=-1)


def precision_at_k(exact: jax.Array, approx: jax.Array, k: int) -> jax.Array:
    """|top_k(exact) ∩ top_k(approx)| / k per row."""
    _, et = jax.lax.top_k(exact, k)
    _, at = jax.lax.top_k(approx, k)
    hit = (et[:, :, None] == at[:, None, :]).any(axis=-1)
    return hit.mean(axis=-1)


def is_stochastic(p: jax.Array, atol: float = 1e-4) -> np.ndarray:
    """Row-wise check that p is a probability vector."""
    p = np.asarray(p)
    return (p >= -atol).all(axis=-1) & (
        np.abs(p.sum(axis=-1) - 1.0) <= atol * max(p.shape[-1], 1)
    )
