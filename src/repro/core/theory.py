"""Theorem 2.1 machinery: concentration bound and walk planning.

    Pr[p_hat_u(v) - p_u(v) >= g] <= (1/sqrt(c)) (1 + g c / 10) exp(-g^2 R / 20)

The bound is *per entry* and symmetric (same for under-estimation).  The
planner inverts it: the number of walks needed for additive error ``g`` with
failure probability ``delta``.  ``mcep_equivalent_walks`` reproduces the
paper's headline ratio (1000 MCFP walks ~ 6700 MCEP walks): MCFP sees
``R / c`` positions per ``R`` walks, so sample efficiency scales by ``1/c``.
"""

from __future__ import annotations

import math

from repro.core.walks import DEFAULT_C


def overestimate_bound(gamma: float, r: int, c: float = DEFAULT_C) -> float:
    """RHS of Theorem 2.1 (also the under-estimation bound)."""
    if gamma < 0:
        raise ValueError("gamma must be >= 0")
    return (
        (1.0 / math.sqrt(c))
        * (1.0 + gamma * c / 10.0)
        * math.exp(-(gamma ** 2) * r / 20.0)
    )


def two_sided_bound(gamma: float, r: int, c: float = DEFAULT_C) -> float:
    return min(1.0, 2.0 * overestimate_bound(gamma, r, c))


def walks_required(
    gamma: float, delta: float, c: float = DEFAULT_C
) -> int:
    """Smallest R with two_sided_bound(gamma, R) <= delta (closed form)."""
    if not (0 < delta < 1):
        raise ValueError("delta in (0,1)")
    coeff = 2.0 * (1.0 + gamma * c / 10.0) / math.sqrt(c)
    r = 20.0 / (gamma ** 2) * math.log(coeff / delta)
    return max(int(math.ceil(r)), 1)


def mcep_equivalent_walks(r_mcfp: int, c: float = DEFAULT_C) -> int:
    """MCEP walks matching the sample count of ``r_mcfp`` MCFP walks.

    Each MCFP walk contributes ``1/c`` (dependent) sample positions versus
    MCEP's single endpoint; the paper measures the dependent samples to be
    nearly as informative (Section 4.2: 1000 vs 6700 at c = 0.15).
    """
    return int(round(r_mcfp / c))


def expected_walk_length(c: float = DEFAULT_C) -> float:
    """Mean positions per walk: geometric(c) => 1/c."""
    return 1.0 / c


def max_steps_for_tail(tail: float, c: float = DEFAULT_C) -> int:
    """Steps needed so the truncated tail mass (1-c)^T <= tail."""
    return int(math.ceil(math.log(tail) / math.log(1.0 - c)))


def index_error_bound(
    r: int, gamma: float, c: float = DEFAULT_C
) -> float:
    """Union-style heuristic for the top-L index: per-entry failure prob at
    additive error gamma, given R walks (used by the budget planner to
    annotate plans)."""
    return two_sided_bound(gamma, r, c)


def verd_error_factor(t: int, c: float = DEFAULT_C) -> float:
    """Per-iteration error contraction of the decomposition (Section 2.3):
    after T unfoldings the index error enters scaled by (1-c)^T."""
    return (1.0 - c) ** t
