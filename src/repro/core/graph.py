"""Graph container used across PowerWalk.

The graph is stored in CSR order (edges sorted by source) together with the
COO view (``src``/``dst``) because TPU-native message passing is built on
``jnp.take`` + ``jax.ops.segment_sum`` over edge lists.  All arrays are JAX
arrays so a :class:`Graph` can be donated to jitted functions and sharded with
``NamedSharding``; ``n``/``m`` are static aux fields.

Semantics follow the paper (Section 2.1):

* ``A`` is the row-stochastic out-edge matrix, ``A[i, j] = 1/|O(i)|``.
* A *dangling* vertex (no out-edge) behaves as if it had a single artificial
  edge back to the personalization source ``u``; operators here expose the
  dangling mass separately so each personalized source can reclaim it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in CSR + COO form.

    Attributes:
      row_ptr: int32[n + 1] CSR row offsets (by source vertex).
      col_idx: int32[m] destination of each edge, CSR order.
      src:     int32[m] source of each edge (expanded row_ptr), CSR order.
      out_deg: int32[n] out-degree per vertex.
      n, m:    static vertex / edge counts.
    """

    row_ptr: jax.Array
    col_idx: jax.Array
    src: jax.Array
    out_deg: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_edges(src, dst, n: int | None = None) -> "Graph":
        """Build from (possibly unsorted) edge lists; dedups nothing."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError("src/dst must be 1-D arrays of equal length")
        if n is None:
            n = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
        order = np.argsort(src, kind="stable")
        src = src[order]
        dst = dst[order]
        out_deg = np.bincount(src, minlength=n).astype(np.int32)
        row_ptr = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(out_deg, out=row_ptr[1:])
        return Graph(
            row_ptr=jnp.asarray(row_ptr),
            col_idx=jnp.asarray(dst.astype(np.int32)),
            src=jnp.asarray(src.astype(np.int32)),
            out_deg=jnp.asarray(out_deg),
            n=int(n),
            m=int(src.shape[0]),
        )

    @staticmethod
    def from_dense(adj: np.ndarray) -> "Graph":
        src, dst = np.nonzero(np.asarray(adj))
        return Graph.from_edges(src, dst, n=adj.shape[0])

    # -- derived quantities ------------------------------------------------
    @property
    def dangling_mask(self) -> jax.Array:
        """bool[n], True where the vertex has no out-edge."""
        return self.out_deg == 0

    @property
    def inv_out_deg(self) -> jax.Array:
        """f32[n] = 1/out_deg with 0 for dangling vertices."""
        deg = self.out_deg.astype(jnp.float32)
        return jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1.0), 0.0)

    @property
    def edge_weight(self) -> jax.Array:
        """f32[m] = 1/out_deg[src e] — the CSR value array of ``A``."""
        return jnp.take(self.inv_out_deg, self.src)

    def out_neighbors(self, v: int) -> np.ndarray:
        lo = int(self.row_ptr[v])
        hi = int(self.row_ptr[v + 1])
        return np.asarray(self.col_idx[lo:hi])

    # -- dense reference (tests / tiny graphs only) ------------------------
    def dense_transition(self, source: int | None = None) -> np.ndarray:
        """Dense row-stochastic ``A`` with dangling rows sent to ``source``.

        If ``source`` is None dangling rows are left all-zero (the
        "substochastic" view); callers then handle dangling mass themselves.
        """
        a = np.zeros((self.n, self.n), dtype=np.float64)
        src = np.asarray(self.src)
        dst = np.asarray(self.col_idx)
        deg = np.asarray(self.out_deg).astype(np.float64)
        np.add.at(a, (src, dst), 1.0 / deg[src])
        if source is not None:
            dang = np.asarray(self.dangling_mask)
            a[dang, :] = 0.0
            a[dang, source] = 1.0
        return a


def graph_fingerprint(graph: Graph) -> int:
    """crc32 over the CSR topology (``row_ptr`` + ``col_idx`` bytes).

    The resume guard of the crash-safe index build: a checkpoint commits
    this fingerprint, and a resumed build refuses to continue on a graph
    whose adjacency differs — per-chunk RNG streams replay bit-identically
    only on the exact topology they were drawn for.
    """
    import zlib

    crc = zlib.crc32(np.ascontiguousarray(
        np.asarray(graph.row_ptr, np.int64)).tobytes())
    crc = zlib.crc32(np.ascontiguousarray(
        np.asarray(graph.col_idx, np.int64)).tobytes(), crc)
    return crc & 0xFFFFFFFF


def _edge_pairs(edges) -> np.ndarray:
    """Coerce an edge batch to an int64 ``[k, 2]`` array (empty ok)."""
    if edges is None:
        return np.zeros((0, 2), dtype=np.int64)
    arr = np.asarray(edges, dtype=np.int64)
    if arr.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    if arr.ndim != 2 or arr.shape[1] != 2:
        raise ValueError(f"edge batch must have shape [k, 2], got {arr.shape}")
    return arr


def apply_edge_updates(
    graph: Graph, inserts=None, deletes=None
) -> Tuple[Graph, np.ndarray]:
    """Apply a batch of edge inserts/deletes; returns ``(new_graph, touched)``.

    ``inserts``/``deletes`` are ``[k, 2]`` arrays of ``(src, dst)`` pairs
    (vertex ids must already exist — ``n`` never changes here).  Deleting an
    edge that is not present raises; inserting a duplicate edge is allowed
    (CSR stores multiplicity).  ``touched`` is the sorted unique set of
    source vertices whose out-neighborhood changed — the seed of the
    index-invalidation set in :mod:`repro.core.updates`.

    Determinism contract (what incremental repair relies on): edges of an
    *untouched* source keep their exact CSR window contents and order, so a
    walk trajectory that never visits a touched vertex re-simulates
    bit-identically on the new graph.  This holds because ``from_edges``
    sorts by source with a *stable* sort and we only remove/append edges of
    touched sources.
    """
    ins = _edge_pairs(inserts)
    dele = _edge_pairs(deletes)
    for name, arr in (("inserts", ins), ("deletes", dele)):
        if arr.size and (arr.min() < 0 or arr.max() >= graph.n):
            raise ValueError(f"{name} contain vertex ids outside [0, {graph.n})")
    if not ins.size and not dele.size:
        return graph, np.zeros(0, dtype=np.int64)

    src = np.asarray(graph.src, dtype=np.int64)
    dst = np.asarray(graph.col_idx, dtype=np.int64)
    if dele.size:
        key = src * graph.n + dst
        order = np.argsort(key, kind="stable")
        skey = key[order]
        dkey, dcnt = np.unique(dele[:, 0] * graph.n + dele[:, 1],
                               return_counts=True)
        lo = np.searchsorted(skey, dkey, side="left")
        hi = np.searchsorted(skey, dkey, side="right")
        missing = dcnt > (hi - lo)
        if missing.any():
            bad = dkey[missing][0]
            raise ValueError(
                f"cannot delete edge ({bad // graph.n}, {bad % graph.n}): "
                "not present (or multiplicity exceeded)")
        remove = np.zeros(src.shape[0], dtype=bool)
        for pos, cnt in zip(lo, dcnt):
            remove[order[pos:pos + cnt]] = True
        keep = ~remove
        src, dst = src[keep], dst[keep]
    if ins.size:
        src = np.concatenate([src, ins[:, 0]])
        dst = np.concatenate([dst, ins[:, 1]])
    touched = np.unique(np.concatenate([ins[:, 0], dele[:, 0]]))
    return Graph.from_edges(src, dst, n=graph.n), touched


def push_forward(graph: Graph, frontier: jax.Array) -> jax.Array:
    """One substochastic push ``frontier @ A0``.

    ``frontier`` is ``f32[..., n]`` (a batch of row vectors).  Dangling mass
    is *dropped* here; use :func:`dangling_mass` to reclaim it per-source.
    Edge-parallel formulation: gather source values, weight by 1/deg, and
    segment-sum into destinations — the TPU-native SpMM.
    """
    vals = jnp.take(frontier, graph.src, axis=-1) * graph.edge_weight
    return jax.ops.segment_sum(
        vals.swapaxes(-1, 0), graph.col_idx, num_segments=graph.n
    ).swapaxes(-1, 0)


def dangling_mass(graph: Graph, frontier: jax.Array) -> jax.Array:
    """Total frontier mass sitting on dangling vertices, shape ``[...]``."""
    return jnp.sum(
        jnp.where(graph.dangling_mask, frontier, 0.0), axis=-1
    )


def transition_with_dangling(
    graph: Graph, frontier: jax.Array, sources: jax.Array
) -> jax.Array:
    """``frontier @ A`` where dangling rows of ``A`` point at ``sources``.

    ``frontier``: f32[q, n]; ``sources``: int32[q] personalization vertex of
    each batch row.  Returns f32[q, n].
    """
    pushed = push_forward(graph, frontier)
    dm = dangling_mass(graph, frontier)
    q = frontier.shape[0]
    return pushed.at[jnp.arange(q), sources].add(dm)


def transition_with_dangling_seeds(
    graph: Graph, frontier: jax.Array, seeds: jax.Array, weights: jax.Array
) -> jax.Array:
    """``frontier @ A`` where dangling rows of ``A`` point at each query's
    *seed distribution*.

    ``seeds``: int32[q, S] seed vertices per batch row; ``weights``:
    f32[q, S], nonnegative, pad slots 0.  Dangling mass is redistributed
    proportionally to the (normalized) weights — for ``S = 1`` this is
    exactly :func:`transition_with_dangling`.  Duplicate seeds simply
    receive the sum of their slots' shares (scatter-add).
    """
    pushed = push_forward(graph, frontier)
    dm = dangling_mass(graph, frontier)
    wsum = jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-30)
    share = dm[:, None] * (weights / wsum)
    q = frontier.shape[0]
    return pushed.at[jnp.arange(q)[:, None], seeds].add(share)


def reverse(graph: Graph) -> Graph:
    """Graph with every edge reversed (used by pull-mode kernels)."""
    return Graph.from_edges(
        np.asarray(graph.col_idx), np.asarray(graph.src), n=graph.n
    )


def degree_histogram(graph: Graph, n_buckets: int = 10) -> np.ndarray:
    """Paper Section 4.2 bucketing: bucket i holds out-degrees in
    ``[2^(i-1), 2^i)``; the last bucket is unbounded."""
    deg = np.asarray(graph.out_deg)
    edges = [0] + [2 ** i for i in range(n_buckets - 1)] + [np.inf]
    return np.histogram(deg, bins=edges)[0]


def bucket_sample_sources(
    graph: Graph, per_bucket: int, n_buckets: int = 10, seed: int = 0
) -> np.ndarray:
    """Sample query vertices stratified by out-degree (paper Section 4.2)."""
    rng = np.random.default_rng(seed)
    deg = np.asarray(graph.out_deg)
    picks = []
    for i in range(1, n_buckets + 1):
        lo = 2 ** (i - 1) if i > 1 else 0
        hi = np.inf if i == n_buckets else 2 ** i
        pool = np.nonzero((deg >= lo) & (deg < hi))[0]
        if pool.size == 0:
            continue
        k = min(per_bucket, pool.size)
        picks.append(rng.choice(pool, size=k, replace=False))
    return np.concatenate(picks) if picks else np.zeros(0, dtype=np.int64)
