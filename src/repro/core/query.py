"""Online batch-query engine (paper Section 3.3).

Buffers incoming PPR queries, executes them as one shared decomposition, and
returns top-k answers.  All four strategies of the paper's Table 3 are
selectable:

* ``powerwalk`` — VERD iterations + index combine (the contribution),
* ``verd``      — VERD with no index (the paper's R = 0 column),
* ``fppr``      — direct index lookup (Fogaras-style full precomputation),
* ``mcfp``      — online Monte-Carlo (no index),
* ``pi``        — power iteration (accuracy reference; impractical at scale).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcfp as mcfp_mod
from repro.core import power_iteration as pi_mod
from repro.core import verd as verd_mod
from repro.core.graph import Graph
from repro.core.index import PPRIndex
from repro.core.walks import DEFAULT_C


# Auto path selection: below this vertex count the dense [Q, n] frontier is
# cheap enough that the sparse bookkeeping (sort-based compaction) isn't
# worth it; above it the dense path's Q*n*8 bytes of state dominates.  See
# docs/query_path.md for the memory formulas.  Retuned 1<<15 -> 1<<14 from
# the recorded bench_query sparse sweep (docs/query_path.md): the sparse
# path already wins 6-8x at n = 16k-20k with L1 within the truncation
# bound, so the old threshold left a 2x band of graphs on the slow path.
AUTO_SPARSE_MIN_N = 1 << 14

# Serving fast path: the *final* index combine may scatter its candidates
# into a dense [Q, n] f32 scratch and lax.top_k it instead of running the
# sort-based sparse compaction (verd.combine_with_index_scatter).  The
# scratch is transient and only exists at the combine — the iterations stay
# Q x K — so "auto" takes it whenever Q * n * 4 bytes fits this budget and
# falls back to the n-independent sparse combine beyond it.
SCATTER_COMBINE_BUDGET_BYTES = 256 * 1024 * 1024


def auto_frontier_floor(top_k: int) -> int:
    """Minimum auto-derived sparse frontier width K: 4x the answer size
    with an absolute floor.  Shared by the engine selector below and
    ``DistConfig.resolved_frontier_k`` so the single-device and distributed
    paths derive the same K at the same config (retune it here once)."""
    return max(4 * top_k, 256)


def normalize_seed_weights(weights: jax.Array) -> jax.Array:
    """Seed-set weights normalized to sum 1 per row (f32).

    The engine's one normalization point: queries are scale-invariant in
    their seed weights (PPR restarts at a *distribution*), so the engine
    divides by the row sum before anything downstream sees the weights —
    which is also what lets ``serving.cache`` canonicalize rescaled seed
    sets onto one cache entry.  Weight-0 pad slots stay 0.  All-zero rows
    (nothing real in the row — pad queries) degrade to all-zero weights
    rather than NaN.
    """
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)


def _fppr_lookup(
    index: PPRIndex, sources: jax.Array, seed_w: Optional[jax.Array]
) -> jax.Array:
    """fppr dense answers: a plain row lookup, or — for seed sets — the
    weighted sum of each seed's index row (fppr has no iterate to seed, but
    PPR linearity makes the lookup combine exact at index precision)."""
    if seed_w is None:
        return index.lookup_dense(sources)
    q, s = sources.shape
    rows = index.lookup_dense(sources.reshape(-1)).reshape(q, s, -1)
    return jnp.sum(seed_w[:, :, None] * rows, axis=1)


@dataclasses.dataclass
class QueryConfig:
    mode: str = "powerwalk"       # powerwalk | verd | fppr | mcfp | pi
    t_iterations: int = 2          # VERD iterations (paper: 2 at R=100)
    c: float = DEFAULT_C
    top_k: int = 200               # answer size (paper evaluates k<=200)
    r_online: int = 2000           # walks for online-MCFP baseline
    pi_iterations: int = 100
    threshold: float = 0.0         # VERD frontier sparsification epsilon
    max_batch: int = 4096          # shared-decomposition batch size
    frontier_k: int = 0            # sparse frontier width (0 = auto-derive)
    frontier_path: str = "auto"    # dense | sparse | auto
    combine_path: str = "auto"     # sparse | scatter | auto — how the sparse
                                   # route merges its final combine candidates
                                   # (auto: scatter while Q*n*4 bytes fits
                                   # SCATTER_COMBINE_BUDGET_BYTES)
    hub_split_degree: int = 0      # ELL row-split width for the sparse push
                                   # (0 = no splitting; see verd.gather_push_edges)
    max_seeds: int = 1             # seed-set width S_max: queries may carry up
                                   # to this many weighted seed vertices per
                                   # row, padded with weight-0 slots to one
                                   # stable jit shape (1 = classic
                                   # single-vertex queries)
    seed: int = 0                  # base PRNG seed for the Monte-Carlo
                                   # modes (mcfp); distinct per process so
                                   # replicas don't share MC noise


class BatchQueryEngine:
    """Executes batches of PPR queries with a shared decomposition."""

    def __init__(
        self,
        graph: Graph,
        index: Optional[PPRIndex] = None,
        config: Optional[QueryConfig] = None,
    ):
        self.graph = graph
        self.index = index
        self.config = config or QueryConfig()
        if self.config.mode in ("powerwalk", "fppr") and index is None:
            raise ValueError(f"mode {self.config.mode} requires a PPR index")
        if index is not None and index.n < graph.n:
            raise ValueError(
                f"index covers {index.n} rows < graph.n={graph.n}"
            )
        if self.config.frontier_path not in ("dense", "sparse", "auto"):
            raise ValueError(
                f"unknown frontier_path {self.config.frontier_path!r}"
            )
        if self.config.combine_path not in ("sparse", "scatter", "auto"):
            raise ValueError(
                f"unknown combine_path {self.config.combine_path!r}"
            )
        if self.config.max_seeds > 1 and self.config.mode in ("mcfp", "pi"):
            raise ValueError(
                f"mode {self.config.mode!r} does not support seed-set "
                "queries (max_seeds > 1): it is not linear in a start "
                "vector the engine can combine"
            )
        # base key is pure config (seed), so a rebuilt engine replays the
        # same MC noise; the stateful split below serves direct query_dense
        # calls, while run() folds chunk offsets for per-chunk determinism
        self._base_key = jax.random.PRNGKey(self.config.seed)
        self._key = self._base_key
        self._degree_cap: Optional[int] = None  # resolved lazily, host-side

    # -- sparse-path plumbing ------------------------------------------------
    @property
    def frontier_k(self) -> int:
        """Effective sparse-frontier width K (cfg.frontier_k or auto).

        The auto width covers the *expected* frontier support after ``t``
        pushes (~ mean_degree**t) so that auto-routed sparse answers are not
        silently truncated; graphs whose support estimate forces K near n
        then fail the ``uses_sparse_path`` guards and stay dense/exact.
        An explicit ``cfg.frontier_k`` overrides the estimate.
        """
        cfg = self.config
        n = self.graph.n
        if cfg.frontier_k > 0:
            return min(cfg.frontier_k, n)
        mean_deg = self.graph.m / max(n, 1)
        # log space: mean_deg ** t overflows float at absurd t; saturate at n.
        # A seed-set query starts from up to max_seeds vertices, so its
        # frontier support scales the single-vertex estimate by S_max.
        log_support = (
            cfg.t_iterations * math.log(max(mean_deg, 1.0))
            + math.log(max(cfg.max_seeds, 1))
        )
        if log_support >= math.log(max(n, 1)):
            # contract: allow(host-sync): n is a static python int
            support = float(n)
        else:
            support = math.exp(log_support)
        return min(
            n, max(auto_frontier_floor(cfg.top_k), int(math.ceil(support)))
        )

    def uses_sparse_path(self) -> bool:
        """Route decision: does query_topk hold Q x K instead of Q x n?

        Only the VERD modes have a frontier; ``auto`` picks sparse once the
        dense state (Q*n*8 bytes/query-pair) dwarfs the sparse state
        (~Q*K*8), i.e. on large graphs where K << n — AND the push's gather
        tile (Q*K*gather-width entries) stays below the dense row width it
        replaces.  The gather width is :meth:`effective_gather_width`: the
        max out-degree, or ``hub_split_degree`` once ELL splitting is on —
        so hub-heavy graphs route sparse as soon as a split width is set,
        because every gather axis (and the kernels' per-step VMEM) is then
        bounded by ``h`` regardless of how large the hubs are.
        """
        cfg = self.config
        if cfg.mode not in ("powerwalk", "verd"):
            return False
        if cfg.frontier_path == "sparse":
            return True
        if cfg.frontier_path == "dense":
            return False
        return (
            self.graph.n >= AUTO_SPARSE_MIN_N
            and 8 * self.frontier_k <= self.graph.n
            and self.frontier_k * self.effective_gather_width() <= self.graph.n
        )

    def uses_scatter_combine(self, q: int) -> bool:
        """Route decision for the sparse route's *final* combine: scatter
        into a transient dense ``[q, n]`` scratch (fast ``lax.top_k``) or
        keep the n-independent sort-based sparse compaction.

        Only the ``powerwalk`` sparse route has an index combine; ``auto``
        scatters while the scratch (``q * n * 4`` bytes) fits
        :data:`SCATTER_COMBINE_BUDGET_BYTES`.  Exact either way — this is a
        cost knob, not an accuracy knob."""
        cfg = self.config
        if cfg.mode != "powerwalk" or not self.uses_sparse_path():
            return False
        if cfg.combine_path == "scatter":
            return True
        if cfg.combine_path == "sparse":
            return False
        return q * self.graph.n * 4 <= SCATTER_COMBINE_BUDGET_BYTES

    def degree_cap(self) -> int:
        """Max out-degree (cached): the exact-mode edge budget per slot."""
        if self._degree_cap is None:
            self._degree_cap = verd_mod.resolve_degree_cap(self.graph)
        return self._degree_cap

    def effective_gather_width(self) -> int:
        """Widest gather axis of one sparse push: ``degree_cap`` unsplit,
        ``hub_split_degree`` once ELL hub splitting bounds every sub-slot
        (``verd.resolve_hub_splits``)."""
        h, _ = verd_mod.resolve_hub_splits(
            self.degree_cap(), self.config.hub_split_degree
        )
        return h

    @property
    def effective_top_k(self) -> int:
        """Served answer width: ``cfg.top_k`` clamped to the graph.

        The one place the clamp lives — an unclamped ``top_k > n`` would
        make ``lax.top_k`` reject the dense rows and the sparse route
        return a different width than the preallocated host buffers in
        :meth:`run` / ``PPRService.poll`` expect."""
        return max(1, min(self.config.top_k, self.graph.n))

    def query_sparse(
        self,
        sources: jax.Array,
        out_k: Optional[int] = None,
        weights: Optional[jax.Array] = None,
    ):
        """Sparse-path answers as a SparseFrontier (never builds [Q, n]).

        ``weights f32[Q, S]`` switches ``sources`` to seed-set rows
        ``int32[Q, S]`` (weights are normalized to sum 1 per row first)."""
        cfg = self.config
        if cfg.mode not in ("powerwalk", "verd"):
            raise ValueError(
                f"mode {cfg.mode!r} has no frontier; query_sparse supports "
                "the VERD modes (powerwalk, verd) only"
            )
        index = self.index if cfg.mode == "powerwalk" else None
        seed_w = None if weights is None else normalize_seed_weights(weights)
        return verd_mod.verd_query_sparse(
            self.graph, sources, index,
            t=cfg.t_iterations, k=self.frontier_k, c=cfg.c,
            threshold=cfg.threshold, out_k=out_k or self.effective_top_k,
            degree_cap=self.degree_cap(),
            hub_split_degree=cfg.hub_split_degree,
            seed_weights=seed_w,
        )

    # -- dense answers -----------------------------------------------------
    def query_dense(
        self,
        sources: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Dense [Q, n] answers.  ``key`` overrides the Monte-Carlo stream
        of the ``mcfp`` mode (``run()`` passes a chunk-offset fold of the
        config seed so reruns are reproducible chunk by chunk); without it
        the engine's stateful key advances.  ``weights`` switches to
        seed-set rows (linear modes only — mcfp/pi raise)."""
        cfg = self.config
        g = self.graph
        seed_w = None if weights is None else normalize_seed_weights(weights)
        if seed_w is not None and cfg.mode in ("mcfp", "pi"):
            raise ValueError(
                f"mode {cfg.mode!r} does not support seed-set queries"
            )
        if cfg.mode == "powerwalk":
            return verd_mod.verd_query(
                g, sources, self.index, t=cfg.t_iterations, c=cfg.c,
                threshold=cfg.threshold, seed_weights=seed_w,
            )
        if cfg.mode == "verd":
            return verd_mod.verd_query(
                g, sources, None, t=cfg.t_iterations, c=cfg.c,
                threshold=cfg.threshold, seed_weights=seed_w,
            )
        if cfg.mode == "fppr":
            return _fppr_lookup(self.index, sources, seed_w)
        if cfg.mode == "mcfp":
            if key is None:
                self._key, key = jax.random.split(self._key)
            return mcfp_mod.estimate_ppr(g, sources, cfg.r_online, key, c=cfg.c)
        if cfg.mode == "pi":
            return pi_mod.power_iteration(
                g, sources, n_iter=cfg.pi_iterations, c=cfg.c
            )
        raise ValueError(f"unknown mode {cfg.mode!r}")

    # -- top-k answers (the served product) ---------------------------------
    def query_topk(
        self,
        sources: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        k = self.effective_top_k
        if self.uses_sparse_path():
            sf = self.query_sparse(sources, out_k=k, weights=weights)
            vals, idx = sf.values, sf.indices
        else:
            dense = self.query_dense(sources, key=key, weights=weights)
            vals, idx = jax.lax.top_k(dense, k)
        # static-shape width contract (trace time): every route must hand
        # back exactly the clamped width the host buffers were sized for
        assert vals.shape[-1] == k and idx.shape[-1] == k, (
            vals.shape, idx.shape, k,
        )
        return vals, idx

    # -- async dispatch (the serving pipeline's entry point) -----------------
    def dispatch_key(self, seq: int) -> jax.Array:
        """Per-dispatch PRNG key: the config-seed base key with the
        dispatch sequence number folded in, so Monte-Carlo answers are
        reproducible for a given (seed, dispatch order) at any pipeline
        depth — the async path never advances the stateful key."""
        return jax.random.fold_in(self._base_key, seq)

    def query_topk_async(
        self,
        sources: jax.Array,
        *,
        key: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None,
        out: Optional[Tuple[jax.Array, jax.Array]] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Top-k answers as *unmaterialized* device arrays.

        The whole query — iterate, combine, top-k — is one jitted
        computation, so this returns as soon as the work is enqueued on the
        device stream (JAX async dispatch): no host sync, no per-op Python
        dispatch between stages.  ``serving.pipeline`` launches several of
        these back to back and harvests them through a completion queue;
        callers that want a blocking answer can ``block_until_ready()`` the
        result, which is bit-identical to :meth:`query_topk` on the same
        route/combine.  ``key`` seeds the ``mcfp`` mode (ignored elsewhere);
        default is the engine's base key — pass :meth:`dispatch_key` for
        distinct, replayable noise per dispatch.

        ``weights f32[Q, S]`` switches ``sources`` to seed-set rows
        ``int32[Q, S]``.  ``out = (vals f32[Q, k], idx int32[Q, k])``
        optionally *donates* a pair of result buffers: the answer is written
        into their device memory instead of a fresh allocation (the passed
        arrays are consumed — use the returned ones), which is how the
        serving pipeline's buffer ring avoids a per-dispatch allocation.
        """
        sources = jnp.asarray(sources, jnp.int32)
        q = int(sources.shape[0])
        cfg = self.config
        if key is None:
            key = self._base_key
        if weights is not None and cfg.mode in ("mcfp", "pi"):
            raise ValueError(
                f"mode {cfg.mode!r} does not support seed-set queries"
            )
        sparse_route = self.uses_sparse_path()
        statics = dict(
            mode=cfg.mode,
            t=cfg.t_iterations,
            c=cfg.c,
            top_k=self.effective_top_k,
            r_online=cfg.r_online,
            pi_iterations=cfg.pi_iterations,
            threshold=cfg.threshold,
            frontier_k=self.frontier_k,
            degree_cap=self.degree_cap() if sparse_route else 0,
            hub_split_degree=cfg.hub_split_degree,
            sparse_route=sparse_route,
            scatter_combine=self.uses_scatter_combine(q),
        )
        index = self.index if cfg.mode in ("powerwalk", "fppr") else None
        if out is None:
            return _fused_topk(
                self.graph, index, sources, key, weights, **statics
            )
        return _fused_topk_into(
            self.graph, index, sources, key, out[0], out[1], weights,
            **statics,
        )

    # -- batched driver ------------------------------------------------------
    def run(self, sources, weights=None) -> dict:
        """Execute a (possibly large) query set in max_batch chunks.

        Returns answers + timing; mirrors the paper's Table 3 measurements.
        The Monte-Carlo mode folds each chunk's offset into the config-seed
        key, so rerunning the same engine (or a rebuilt one with the same
        seed) reproduces every chunk bit for bit.  ``weights f32[N, S]``
        switches ``sources int32[N, S]`` to seed-set rows.
        """
        # contract: allow(host-sync): run() is the offline batched driver —
        # it normalizes host inputs and materializes every chunk by design
        sources = np.asarray(sources, dtype=np.int32)
        weights = (
            # contract: allow(host-sync): host input normalization
            None if weights is None else np.asarray(weights, dtype=np.float32)
        )
        k = self.effective_top_k
        vals = np.zeros((len(sources), k), dtype=np.float32)
        idxs = np.zeros((len(sources), k), dtype=np.int32)
        start = time.perf_counter()
        for i in range(0, len(sources), self.config.max_batch):
            chunk = jnp.asarray(sources[i : i + self.config.max_batch])
            w_chunk = (
                None if weights is None
                else jnp.asarray(weights[i : i + self.config.max_batch])
            )
            v, ix = self.query_topk(
                chunk, key=jax.random.fold_in(self._base_key, i),
                weights=w_chunk,
            )
            v.block_until_ready()  # contract: allow(host-sync): offline driver
            vals[i : i + len(chunk)] = np.asarray(v)  # contract: allow(host-sync): offline driver
            idxs[i : i + len(chunk)] = np.asarray(ix)  # contract: allow(host-sync): offline driver
        elapsed = time.perf_counter() - start
        return dict(
            values=vals,
            indices=idxs,
            seconds=elapsed,
            queries=len(sources),
            qps=len(sources) / max(elapsed, 1e-9),
            mode=self.config.mode,
            top_k=k,
        )


# ---------------------------------------------------------------------------
# Fused top-k query: one jitted computation covering every mode/route, so a
# serving dispatch is a single async XLA launch.  Module-level (not a bound
# method) so the jit cache is shared across engines over the same
# graph/index pytrees and keyed only by the static route arguments.
# ---------------------------------------------------------------------------

_FUSED_STATICS = (
    "mode", "t", "c", "top_k", "r_online", "pi_iterations", "threshold",
    "frontier_k", "degree_cap", "hub_split_degree", "sparse_route",
    "scatter_combine",
)


def _fused_topk_impl(
    graph: Graph,
    index: Optional[PPRIndex],
    sources: jax.Array,
    key: jax.Array,
    weights: Optional[jax.Array],
    *,
    mode: str,
    t: int,
    c: float,
    top_k: int,
    r_online: int,
    pi_iterations: int,
    threshold: float,
    frontier_k: int,
    degree_cap: int,
    hub_split_degree: int,
    sparse_route: bool,
    scatter_combine: bool,
) -> Tuple[jax.Array, jax.Array]:
    seed_w = None if weights is None else normalize_seed_weights(weights)
    if sparse_route:
        if scatter_combine and mode == "powerwalk":
            s, f = verd_mod.verd_iterate_sparse(
                graph, sources, seed_w,
                t=t, k=frontier_k, c=c, threshold=threshold,
                degree_cap=degree_cap, hub_split_degree=hub_split_degree,
            )
            vals, idx = verd_mod.combine_with_index_scatter(
                s, f, index, out_k=top_k,
            )
        else:
            sf = verd_mod.verd_query_sparse(
                graph, sources, index if mode == "powerwalk" else None,
                t=t, k=frontier_k, c=c, threshold=threshold, out_k=top_k,
                degree_cap=degree_cap, hub_split_degree=hub_split_degree,
                seed_weights=seed_w,
            )
            vals, idx = sf.values, sf.indices
    else:
        if mode in ("powerwalk", "verd"):
            dense = verd_mod.verd_query(
                graph, sources, index if mode == "powerwalk" else None,
                t=t, c=c, threshold=threshold, seed_weights=seed_w,
            )
        elif mode == "fppr":
            dense = _fppr_lookup(index, sources, seed_w)
        elif mode == "mcfp":
            dense = mcfp_mod.estimate_ppr(graph, sources, r_online, key, c=c)
        elif mode == "pi":
            dense = pi_mod.power_iteration(graph, sources, n_iter=pi_iterations, c=c)
        else:
            raise ValueError(f"unknown mode {mode!r}")
        vals, idx = jax.lax.top_k(dense, top_k)
        idx = idx.astype(jnp.int32)
    # same static-shape width contract as query_topk
    assert vals.shape[-1] == top_k and idx.shape[-1] == top_k, (
        vals.shape, idx.shape, top_k,
    )
    return vals, idx


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def _fused_topk(
    graph: Graph,
    index: Optional[PPRIndex],
    sources: jax.Array,
    key: jax.Array,
    weights: Optional[jax.Array] = None,
    **statics,
) -> Tuple[jax.Array, jax.Array]:
    return _fused_topk_impl(graph, index, sources, key, weights, **statics)


@functools.partial(
    jax.jit, static_argnames=_FUSED_STATICS, donate_argnums=(4, 5)
)
def _fused_topk_into(
    graph: Graph,
    index: Optional[PPRIndex],
    sources: jax.Array,
    key: jax.Array,
    out_v: jax.Array,
    out_i: jax.Array,
    weights: Optional[jax.Array] = None,
    **statics,
) -> Tuple[jax.Array, jax.Array]:
    """:func:`_fused_topk` writing into *donated* result buffers.

    ``out_v``/``out_i`` (f32/int32 ``[Q, top_k]``) are donated to XLA: the
    answer lands in their device memory, so a steady-state serving loop that
    rings a fixed pool of buffers through dispatch -> harvest -> redispatch
    performs no per-dispatch result allocation at all.
    """
    vals, idx = _fused_topk_impl(graph, index, sources, key, weights, **statics)
    return out_v.at[:].set(vals), out_i.at[:].set(idx.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Contract-auditor entry points (repro.analysis).
#
# dense-state-bound: the sparse query path must hold Q x K state, never an
# f32[Q, n] dense frontier (the scatter combine is budget-gated separately,
# so the audit pins the comparator combine path).  The widest legal f32
# intermediate is the combine candidate row (~K*L wide) plus the push
# gather area (~K*degree_cap), far under the dense floor Q*n.
#
# retrace-guard: the fused serving jit must compile exactly one cache entry
# per bucketed pad width — a weak-type or dtype wobble in the dispatch path
# (e.g. a python-int seed list vs an np.int32 array) would silently double
# compile time and jit-cache footprint in production.
# ---------------------------------------------------------------------------

from repro.analysis.registry import register_entry_point as _register_ep


def _contract_spec_sparse_query():
    import numpy as np

    from repro.graphs import synthetic

    n, q, l = 1 << 14, 8, 16
    g = synthetic.erdos_renyi(n, 3.0, seed=7)
    rng = np.random.default_rng(0)
    index = PPRIndex(
        values=jnp.asarray(rng.random((n, l)), jnp.float32),
        indices=jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32),
        l=l, n=n,
    )
    engine = BatchQueryEngine(g, index, QueryConfig(
        mode="powerwalk", t_iterations=2, top_k=32, frontier_k=128,
        frontier_path="sparse", combine_path="sparse",
    ))
    cap = engine.degree_cap()   # primed outside the trace (host-side max)
    k = engine.frontier_k
    sources = jnp.arange(q, dtype=jnp.int32)
    jaxpr = jax.make_jaxpr(lambda s: engine.query_topk_async(s))(sources)
    budget = q * (k * (cap + l + 8) + 1024)
    return dict(jaxpr=jaxpr, budget=budget, floor=q * n)


def _retrace_spec_fused_topk():
    import numpy as np

    from repro.graphs import synthetic
    from repro.serving.batching import BatchingConfig

    n, l = 256, 8
    g = synthetic.erdos_renyi(n, 4.0, seed=3)
    rng = np.random.default_rng(1)
    index = PPRIndex(
        values=jnp.asarray(rng.random((n, l)), jnp.float32),
        indices=jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32),
        l=l, n=n,
    )
    engine = BatchQueryEngine(
        g, index, QueryConfig(mode="powerwalk", t_iterations=1, top_k=8)
    )
    widths = BatchingConfig(max_batch=64).padded_shapes()

    def call(width: int, variant: int) -> None:
        # three spellings of the same batch a production dispatcher might
        # produce; all must normalize to one (shape, dtype) cache entry
        if variant == 0:
            srcs = np.zeros(width, np.int32)
        elif variant == 1:
            srcs = jnp.zeros(width, jnp.int32)
        else:
            srcs = [0] * width
        engine.query_topk_async(srcs, key=engine.dispatch_key(0))

    return dict(jit_fn=_fused_topk, widths=widths, variants=3, call=call)


_register_ep("sparse-query-path", "dense-state-bound",
             "src/repro/core/query.py", _contract_spec_sparse_query)
_register_ep("fused-topk-serving", "retrace-guard",
             "src/repro/core/query.py", _retrace_spec_fused_topk)
