"""Online batch-query engine (paper Section 3.3).

Buffers incoming PPR queries, executes them as one shared decomposition, and
returns top-k answers.  All four strategies of the paper's Table 3 are
selectable:

* ``powerwalk`` — VERD iterations + index combine (the contribution),
* ``verd``      — VERD with no index (the paper's R = 0 column),
* ``fppr``      — direct index lookup (Fogaras-style full precomputation),
* ``mcfp``      — online Monte-Carlo (no index),
* ``pi``        — power iteration (accuracy reference; impractical at scale).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcfp as mcfp_mod
from repro.core import power_iteration as pi_mod
from repro.core import verd as verd_mod
from repro.core.graph import Graph
from repro.core.index import PPRIndex
from repro.core.walks import DEFAULT_C


@dataclasses.dataclass
class QueryConfig:
    mode: str = "powerwalk"       # powerwalk | verd | fppr | mcfp | pi
    t_iterations: int = 2          # VERD iterations (paper: 2 at R=100)
    c: float = DEFAULT_C
    top_k: int = 200               # answer size (paper evaluates k<=200)
    r_online: int = 2000           # walks for online-MCFP baseline
    pi_iterations: int = 100
    threshold: float = 0.0         # VERD frontier sparsification epsilon
    max_batch: int = 4096          # shared-decomposition batch size


class BatchQueryEngine:
    """Executes batches of PPR queries with a shared decomposition."""

    def __init__(
        self,
        graph: Graph,
        index: Optional[PPRIndex] = None,
        config: Optional[QueryConfig] = None,
    ):
        self.graph = graph
        self.index = index
        self.config = config or QueryConfig()
        if self.config.mode in ("powerwalk", "fppr") and index is None:
            raise ValueError(f"mode {self.config.mode} requires a PPR index")
        self._key = jax.random.PRNGKey(0)

    # -- dense answers -----------------------------------------------------
    def query_dense(self, sources: jax.Array) -> jax.Array:
        cfg = self.config
        g = self.graph
        if cfg.mode == "powerwalk":
            return verd_mod.verd_query(
                g, sources, self.index, t=cfg.t_iterations, c=cfg.c,
                threshold=cfg.threshold,
            )
        if cfg.mode == "verd":
            return verd_mod.verd_query(
                g, sources, None, t=cfg.t_iterations, c=cfg.c,
                threshold=cfg.threshold,
            )
        if cfg.mode == "fppr":
            return self.index.lookup_dense(sources)
        if cfg.mode == "mcfp":
            self._key, sub = jax.random.split(self._key)
            return mcfp_mod.estimate_ppr(g, sources, cfg.r_online, sub, c=cfg.c)
        if cfg.mode == "pi":
            return pi_mod.power_iteration(
                g, sources, n_iter=cfg.pi_iterations, c=cfg.c
            )
        raise ValueError(f"unknown mode {cfg.mode!r}")

    # -- top-k answers (the served product) ---------------------------------
    def query_topk(
        self, sources: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        dense = self.query_dense(sources)
        vals, idx = jax.lax.top_k(dense, self.config.top_k)
        return vals, idx

    # -- batched driver ------------------------------------------------------
    def run(self, sources) -> dict:
        """Execute a (possibly large) query set in max_batch chunks.

        Returns answers + timing; mirrors the paper's Table 3 measurements.
        """
        sources = np.asarray(sources, dtype=np.int32)
        k = self.config.top_k
        vals = np.zeros((len(sources), k), dtype=np.float32)
        idxs = np.zeros((len(sources), k), dtype=np.int32)
        start = time.perf_counter()
        for i in range(0, len(sources), self.config.max_batch):
            chunk = jnp.asarray(sources[i : i + self.config.max_batch])
            v, ix = self.query_topk(chunk)
            v.block_until_ready()
            vals[i : i + len(chunk)] = np.asarray(v)
            idxs[i : i + len(chunk)] = np.asarray(ix)
        elapsed = time.perf_counter() - start
        return dict(
            values=vals,
            indices=idxs,
            seconds=elapsed,
            queries=len(sources),
            qps=len(sources) / max(elapsed, 1e-9),
            mode=self.config.mode,
        )
