"""Fixed-width sparse frontiers for the online VERD path.

After ``T`` VERD iterations the residual frontier of a query touches only a
small neighborhood of its source — the whole point of the paper's epsilon
sparsification (Section 3.3).  The dense ``f32[Q, n]`` row-vector layout of
:mod:`repro.core.verd` throws that away: a 4096-query batch on a 1M-vertex
graph needs 16 GB of frontier alone.

This module is the TPU-native sparse alternative: the same fixed-width top-K
idiom :class:`repro.core.index.PPRIndex` already uses, applied to the query
state.  A :class:`SparseFrontier` holds ``values f32[Q, K]`` + ``indices
int32[Q, K]`` — dense, regular, batchable — with the convention (shared with
``PPRIndex``) that empty slots carry ``value == 0`` at ``index == 0``, which
is harmless because every consumer multiplies by the value.

The two primitives everything else is built from:

* :func:`merge_duplicates` — a push or an index-combine may hit the same
  column from several slots; per-row sort + segment-sum folds duplicate hits
  into one slot so a subsequent top-K cannot under-count split mass.
* :func:`topk_compact` — fixed-width re-compaction after each push.  Exact
  whenever ``K`` covers the row support; otherwise the dropped mass bounds
  the L1 drift (tested in ``tests/test_frontier.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseFrontier:
    """Batch of fixed-width sparse row vectors.

    values:  f32[Q, K] nonnegative entries, 0 on empty slots.
    indices: int32[Q, K] column of each entry (0 on empty slots).
    k: static width; n: static column-space size.
    """

    values: jax.Array
    indices: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nbytes(self) -> int:
        return self.values.shape[0] * self.k * 8  # f32 + int32

    def mass(self) -> jax.Array:
        """Total mass per row, f32[Q]."""
        return jnp.sum(self.values, axis=1)

    def densify(self) -> jax.Array:
        """Scatter back to ``f32[Q, n]`` (oracle path / error measurement)."""
        q = self.values.shape[0]
        out = jnp.zeros((q, self.n), dtype=self.values.dtype)
        rows = jnp.arange(q)[:, None]
        return out.at[rows, self.indices].add(self.values)


def from_sources(sources: jax.Array, n: int) -> SparseFrontier:
    """Width-1 one-hot frontier: each query starts at its source vertex."""
    fv = jnp.ones((sources.shape[0], 1), dtype=jnp.float32)
    fi = sources.reshape(-1, 1).astype(jnp.int32)
    return SparseFrontier(values=fv, indices=fi, k=1, n=n)


def from_seed_sets(
    seeds: jax.Array, weights: jax.Array, n: int
) -> SparseFrontier:
    """Width-``S`` weighted frontier: each query starts at its seed set.

    ``seeds int32[Q, S]`` / ``weights f32[Q, S]`` — pad slots carry weight
    0 (the shared empty-slot convention), so a padded seed set is exactly
    the unpadded one.  Duplicate seeds within a row are fine: they sit in
    separate slots here and every downstream push/combine dedup-merges
    colliding columns, so the state never widens past ``S``.
    """
    return SparseFrontier(
        values=weights.astype(jnp.float32),
        indices=seeds.astype(jnp.int32),
        k=int(seeds.shape[1]),
        n=n,
    )


def from_dense(dense: jax.Array, k: int) -> SparseFrontier:
    """Top-K sparsification of dense rows (drops everything below rank K)."""
    n = dense.shape[1]
    k = min(k, n)
    vals, idxs = jax.lax.top_k(dense, k)
    vals = jnp.maximum(vals, 0.0)
    idxs = jnp.where(vals > 0, idxs, 0)
    return SparseFrontier(
        values=vals, indices=idxs.astype(jnp.int32), k=k, n=n
    )


def merge_duplicates(
    values: jax.Array, indices: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Fold duplicate column hits within each row into a single slot.

    Per row: sort by column id, segment-sum runs of equal ids into the run
    leader, zero the rest.  Width is preserved; empty slots stay
    ``(0.0, 0)``.  O(Q * W log W) — W is the candidate width, not ``n``.
    """
    q, w = values.shape
    order = jnp.argsort(indices, axis=1)
    si = jnp.take_along_axis(indices, order, axis=1)
    sv = jnp.take_along_axis(values, order, axis=1)
    is_new = jnp.concatenate(
        [jnp.ones((q, 1), bool), si[:, 1:] != si[:, :-1]], axis=1
    )
    pos = jnp.broadcast_to(jnp.arange(w), (q, w))
    leader = jax.lax.cummax(jnp.where(is_new, pos, 0), axis=1)
    # flat segment-sum: row-offset the leader positions so rows don't mix
    seg = (leader + jnp.arange(q)[:, None] * w).reshape(-1)
    summed = jax.ops.segment_sum(
        sv.reshape(-1), seg, num_segments=q * w
    ).reshape(q, w)
    out_v = jnp.where(is_new, summed, 0.0)
    out_i = jnp.where(is_new & (out_v > 0), si, 0)
    return out_v, out_i


def topk_compact(
    values: jax.Array, indices: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Keep the top-``k`` entries of each row, sorted descending (no dedup —
    see ``compact``).  Rows narrower than ``k`` are right-padded with empty
    slots so the result width is always exactly ``k``."""
    w = values.shape[1]
    vals, sel = jax.lax.top_k(values, min(k, w))
    idxs = jnp.take_along_axis(indices, sel, axis=1)
    idxs = jnp.where(vals > 0, idxs, 0)
    if w < k:
        pad = ((0, 0), (0, k - w))
        return jnp.pad(vals, pad), jnp.pad(idxs, pad)
    return vals, idxs


def compact_arrays(
    values: jax.Array, indices: jax.Array, k: int, *, threshold: float = 0.0
) -> Tuple[jax.Array, jax.Array]:
    """Dedup -> epsilon-threshold -> top-K: the one re-compaction sequence
    every sparse push and combine applies (core ops and Pallas kernel
    bodies alike — keep them in sync by calling this, not by inlining).

    Merging *before* the threshold/top-K is what makes truncation honest: a
    column hit from several slots competes with its full mass, so the kept
    set is the true per-row top-K and the dropped mass bounds the error.
    """
    v, i = merge_duplicates(values, indices)
    v = threshold_values(v, threshold)
    return topk_compact(v, i, k)


def compact(
    values: jax.Array, indices: jax.Array, k: int, n: int,
    *, threshold: float = 0.0,
) -> SparseFrontier:
    """:func:`compact_arrays` wrapped into a :class:`SparseFrontier`."""
    v, i = compact_arrays(values, indices, k, threshold=threshold)
    return SparseFrontier(values=v, indices=i, k=v.shape[1], n=n)


def fold_topk(
    run_v: jax.Array,
    run_i: jax.Array,
    add_v: jax.Array,
    add_i: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fold a batch of candidate columns into a running top-``k`` sketch.

    The streaming-accumulation primitive shared by
    :func:`repro.core.verd.sparse_push_compact` (frontier-slot chunks) and
    the offline walk engine's visit-count sketches
    (:func:`repro.core.walks.simulate_walks_sparse`): concatenate the new
    candidates onto the running rows, dedup-merge, keep the top-``k``.
    Returns ``(values, indices, dropped)`` where ``dropped`` is the per-row
    mass truncated away by *this* fold — the exact error-budget increment a
    sketch consumer accumulates (dropped mass only ever leaves, so the
    running total bounds the sketch's L1 understatement).
    """
    cand_v = jnp.concatenate([run_v, add_v], axis=1)
    cand_i = jnp.concatenate([run_i, add_i], axis=1)
    out_v, out_i = compact_arrays(cand_v, cand_i, k)
    dropped = jnp.sum(cand_v, axis=1) - jnp.sum(out_v, axis=1)
    return out_v, out_i, jnp.maximum(dropped, 0.0)


def merge_sketch_parts(
    values: jax.Array,
    indices: jax.Array,
    dropped: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Dedup-merge concatenated sketch parts back to width ``k``, folding
    this merge's own truncation into the running ``dropped`` ledger.

    The one merge law shared by the single-device ``r_splits`` chunk
    estimate (``index.sparse_chunk_estimates``) and the distributed sketch
    merge (``distributed_engine._merge_sparse_counts``) — the sharded /
    single-device row-for-row parity gate depends on the two staying
    bit-identical, so both call here instead of inlining the sequence.
    ``values/indices [rows, parts * k']`` are the parts concatenated along
    the width axis (split order == gather order); ``dropped`` carries the
    per-part truncation already accumulated.
    """
    out_v, out_i = compact_arrays(values, indices, k)
    dropped = dropped + jnp.maximum(
        jnp.sum(values, axis=1) - jnp.sum(out_v, axis=1), 0.0
    )
    return out_v, out_i, dropped


def threshold_values(values: jax.Array, threshold: float) -> jax.Array:
    """Epsilon sparsification (paper Section 3.3): zero entries below eps."""
    if threshold <= 0.0:
        return values
    return jnp.where(values >= threshold, values, 0.0)


def bucket_by_owner(
    values: jax.Array,
    indices: jax.Array,
    ep: int,
    n_shard: int,
    k: int,
    *,
    to_local: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Per-(row, owner) top-``k`` buckets: the distributed wire format.

    ``indices`` are global column ids in ``[0, ep * n_shard)`` partitioned
    into ``ep`` contiguous owner intervals of width ``n_shard``.  For each
    owner the candidates falling into its interval are dedup-merged and
    compacted (:func:`compact_arrays`) so the bucket carries the true
    per-owner top-``k`` — exactly what one ``all_to_all`` step exchanges.

    Returns ``(vals f32[Q, ep, k], idx int32[Q, ep, k])``; with
    ``to_local`` (default) indices are owner-local (``global - owner *
    n_shard``), the form the receiving shard consumes directly.  Empty
    slots are ``(0.0, 0)`` as everywhere else.

    Exact whenever ``k >= n_shard`` (an owner can receive at most
    ``n_shard`` distinct columns after the merge); a smaller ``k`` drops
    the per-owner tail mass, bounding the drift like every other top-K
    truncation in this module.
    """
    # one global merge (the expensive sort), then a cheap per-owner top-k:
    # after the merge each column appears in at most one slot per row, so
    # masking + topk_compact yields the same buckets as a per-owner
    # compact_arrays without re-sorting ep times
    values, indices = merge_duplicates(values, indices)
    out_v, out_i = [], []
    for owner in range(ep):
        mask = (indices // n_shard) == owner
        v = jnp.where(mask, values, 0.0)
        # park masked-out slots at the owner's local vertex 0: value 0
        # entries are the shared empty-slot convention
        i = jnp.where(mask, indices, owner * n_shard)
        cv, ci = topk_compact(v, i, k)
        if to_local:
            ci = jnp.where(cv > 0, ci - owner * n_shard, 0)
        out_v.append(cv)
        out_i.append(ci)
    return (
        jnp.stack(out_v, axis=1),
        jnp.stack(out_i, axis=1).astype(jnp.int32),
    )
