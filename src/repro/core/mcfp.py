"""Monte-Carlo Full-Path estimator (paper Algorithm 1).

``p_u(v) ~ x_n(v) / n`` where ``x_n`` counts *every* position on every walk
and ``n`` is the total number of positions.  Theorem 2.1 gives the
exponential concentration; see :mod:`repro.core.theory`.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import frontier
from repro.core.graph import Graph
from repro.core.walks import (
    DEFAULT_C,
    simulate_walks,
    simulate_walks_sparse,
    walks_for_sources,
)


def estimate_ppr(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
) -> jax.Array:
    """MCFP estimate ``f32[S, n]`` of the PPR vectors of ``sources``."""
    walk_sources, walk_rows = walks_for_sources(sources, r)
    counts = simulate_walks(
        graph,
        walk_sources,
        walk_rows,
        key,
        n_rows=sources.shape[0],
        c=c,
        max_steps=max_steps,
    )
    return counts.fp_counts / jnp.maximum(counts.moves[:, None], 1.0)


def estimate_ppr_sparse(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    l: int,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
) -> frontier.SparseFrontier:
    """MCFP estimate as a top-``l`` :class:`~repro.core.frontier.SparseFrontier`.

    The compacted sparse-sketch engine end to end: ``O(rows * l)`` memory,
    no ``f32[S, n]`` anywhere.  Exact (equal in law to :func:`estimate_ppr`)
    whenever ``l`` covers each row's visited support (``<= r/c`` vertices);
    a narrower ``l`` truncates the per-row tail, with the dropped mass
    tracked by the engine (``SparseWalkCounts.fp_dropped``).
    """
    counts = simulate_walks_sparse(
        graph, sources, r, key, l=l, ep_l=0, c=c, max_steps=max_steps,
        compact_every=compact_every,
    )
    vals = counts.fp.values / jnp.maximum(counts.moves[:, None], 1.0)
    return frontier.SparseFrontier(
        values=vals, indices=counts.fp.indices, k=counts.fp.k, n=graph.n
    )


def estimate_ppr_batched(
    graph: Graph,
    sources,
    r: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
    stats: Optional[dict] = None,
):
    """Host-chunked MCFP for many sources (bounds the [S*R] walk array).

    Yields ``(chunk_sources, estimates)`` pairs so callers (the index
    builder) can stream results into the truncated index without ever
    holding all dense vectors.  The ragged last chunk is padded to a fixed
    ``source_batch`` (pad sources are vertex 0) before hitting the walk
    engine, so ``simulate_walks`` compiles once instead of re-jitting on the
    tail shape; pad rows are sliced off before yielding and reported in
    ``stats`` (``pad_rows``/``pad_fraction``, the ``poll()`` convention) —
    filled in eagerly, before the first chunk is consumed.
    """
    import numpy as np

    sources = np.asarray(sources)
    pad_rows = (-len(sources)) % source_batch
    if stats is not None:
        stats["pad_rows"] = pad_rows
        stats["pad_fraction"] = pad_rows / max(len(sources) + pad_rows, 1)

    def chunks():
        for i in range(0, len(sources), source_batch):
            chunk = sources[i : i + source_batch]
            real = len(chunk)
            if real < source_batch:
                chunk = np.concatenate(
                    [chunk, np.zeros(source_batch - real, chunk.dtype)]
                )
            sub_key = jax.random.fold_in(key, i)
            est = estimate_ppr(
                graph, jnp.asarray(chunk), r, sub_key, c=c,
                max_steps=max_steps,
            )
            yield sources[i : i + real], est[:real]

    return chunks()
