"""Monte-Carlo Full-Path estimator (paper Algorithm 1).

``p_u(v) ~ x_n(v) / n`` where ``x_n`` counts *every* position on every walk
and ``n`` is the total number of positions.  Theorem 2.1 gives the
exponential concentration; see :mod:`repro.core.theory`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import Graph
from repro.core.walks import DEFAULT_C, simulate_walks, walks_for_sources


def estimate_ppr(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
) -> jax.Array:
    """MCFP estimate ``f32[S, n]`` of the PPR vectors of ``sources``."""
    walk_sources, walk_rows = walks_for_sources(sources, r)
    counts = simulate_walks(
        graph,
        walk_sources,
        walk_rows,
        key,
        n_rows=sources.shape[0],
        c=c,
        max_steps=max_steps,
    )
    return counts.fp_counts / jnp.maximum(counts.moves[:, None], 1.0)


def estimate_ppr_batched(
    graph: Graph,
    sources,
    r: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
):
    """Host-chunked MCFP for many sources (bounds the [S*R] walk array).

    Yields ``(chunk_sources, estimates)`` pairs so callers (the index
    builder) can stream results into the truncated index without ever
    holding all dense vectors.
    """
    import numpy as np

    sources = np.asarray(sources)
    for i in range(0, len(sources), source_batch):
        chunk = jnp.asarray(sources[i : i + source_batch])
        sub_key = jax.random.fold_in(key, i)
        yield sources[i : i + source_batch], estimate_ppr(
            graph, chunk, r, sub_key, c=c, max_steps=max_steps
        )
