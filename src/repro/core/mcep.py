"""Monte-Carlo End-Point estimator (paper Algorithm 2; Fogaras et al. 2005).

The baseline PowerWalk improves on: only the terminal vertex of each walk is
counted, ``p_u(v) ~ y(v) / R``.  Shares the walk engine with MCFP so the
paper's MCFP-vs-MCEP comparison (Figures 3-4) is apples-to-apples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import frontier
from repro.core.graph import Graph
from repro.core.walks import (
    DEFAULT_C,
    simulate_walks,
    simulate_walks_sparse,
    walks_for_sources,
)


def estimate_ppr(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
) -> jax.Array:
    """MCEP estimate ``f32[S, n]`` of the PPR vectors of ``sources``."""
    walk_sources, walk_rows = walks_for_sources(sources, r)
    counts = simulate_walks(
        graph,
        walk_sources,
        walk_rows,
        key,
        n_rows=sources.shape[0],
        c=c,
        max_steps=max_steps,
    )
    return counts.ep_counts / jnp.maximum(counts.walks[:, None], 1.0)


def estimate_ppr_sparse(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    l: int,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
) -> frontier.SparseFrontier:
    """MCEP estimate as a top-``l`` :class:`~repro.core.frontier.SparseFrontier`.

    An MCEP row from ``r`` walks has at most ``r`` nonzeros (one endpoint
    per walk), so ``l >= min(r, n)`` is exact; the engine reports any
    sketch-truncated endpoint mass in ``SparseWalkCounts.ep_dropped``.
    The visit sketch is disabled (``l=0``) — MCEP never reads it.
    """
    counts = simulate_walks_sparse(
        graph, sources, r, key, l=0, ep_l=l, c=c, max_steps=max_steps,
        compact_every=compact_every,
    )
    vals = counts.ep.values / jnp.maximum(counts.walks[:, None], 1.0)
    return frontier.SparseFrontier(
        values=vals, indices=counts.ep.indices, k=counts.ep.k, n=graph.n
    )
