"""Monte-Carlo End-Point estimator (paper Algorithm 2; Fogaras et al. 2005).

The baseline PowerWalk improves on: only the terminal vertex of each walk is
counted, ``p_u(v) ~ y(v) / R``.  Shares the walk engine with MCFP so the
paper's MCFP-vs-MCEP comparison (Figures 3-4) is apples-to-apples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import Graph
from repro.core.walks import DEFAULT_C, simulate_walks, walks_for_sources


def estimate_ppr(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
) -> jax.Array:
    """MCEP estimate ``f32[S, n]`` of the PPR vectors of ``sources``."""
    walk_sources, walk_rows = walks_for_sources(sources, r)
    counts = simulate_walks(
        graph,
        walk_sources,
        walk_rows,
        key,
        n_rows=sources.shape[0],
        c=c,
        max_steps=max_steps,
    )
    return counts.ep_counts / jnp.maximum(counts.walks[:, None], 1.0)
