"""Distributed PPR engine: PowerWalk at pod scale (the paper's system).

At twitter-2010 scale (N = 41.65M) the dense ``[Q, N]`` frontier of
:mod:`repro.core.verd` is impossible; this module is the vertex-sharded,
query-tiled engine:

* **Graph layout**: vertices partitioned into ``model``-axis intervals
  (paper Section 3.1's master/slave intervals, static here).  Each shard
  owns the *out-edges of its vertices* (local CSR rows, global column ids).
* **VERD iteration** (push mode): each shard pushes its local frontier
  mass through its local edges, bucketing contributions by destination
  owner -> one ``all_to_all`` over the model axis per iteration -> sum
  received partials.  This is PowerGraph's scatter phase turned into a
  single bulk collective — exactly the paper's "small packets multiplexed
  into large payloads", now in hardware.
* **Frontier compression** (beyond-paper, ``compress_k``): before the
  exchange, each destination bucket keeps only its top-k entries per query
  (the paper's epsilon-sparsification made fixed-shape).  Wire bytes drop
  from O(Q x N) to O(Q x shards x k); accuracy cost is the truncated tail,
  measured in tests.
* **MCFP walk step**: walk cursors shard over the data axes (embarrassing
  parallelism over sources, as in the paper); every (data, model) shard
  scatters visits of its walks that land in its vertex interval — visit
  counting needs no communication at all.
* **Index combine + top-k**: local combine against the vertex-sharded
  top-L index, bucket/exchange once, then a local+gathered top-k.

Everything is shard_map'd so the collective schedule is explicit and
auditable in the dry-run HLO.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.graph import Graph
from repro.core.walks import DEFAULT_C


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Distributed engine configuration."""
    n: int                      # padded global vertex count (multiple of ep)
    ep: int                     # model-axis shards (vertex intervals)
    q_tile: int = 32            # queries per shared-decomposition tile
    c: float = DEFAULT_C
    t_iterations: int = 2
    index_l: int = 667
    top_k: int = 200
    compress_k: int = 0         # 0 = dense exchange (paper-faithful bulk)
    edge_chunk: int = 1 << 22   # local edge-scan chunk
    wire_dtype: Any = jnp.float32   # bf16 halves exchange buffers + bytes
    model_axis: str = "model"
    batch_axes: Tuple[str, ...] = ("data",)

    @property
    def n_shard(self) -> int:
        return self.n // self.ep


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Per-shard CSR slabs, stacked on a leading shard dim.

    row_ptr: int32[ep, n_shard + 1]   local rows (offsets into col_idx row)
    col_idx: int32[ep, m_shard]       global destination ids (padded)
    edge_w:  f32[ep, m_shard]         1/out_deg(src), 0 on padding
    dangling: f32[ep, n_shard]        1.0 where the local vertex is dangling
    """

    row_ptr: Any
    col_idx: Any
    edge_w: Any
    dangling: Any

    @staticmethod
    def specs(cfg: DistConfig, m_shard: int) -> "ShardedGraph":
        sds = jax.ShapeDtypeStruct
        return ShardedGraph(
            row_ptr=sds((cfg.ep, cfg.n_shard + 1), jnp.int32),
            col_idx=sds((cfg.ep, m_shard), jnp.int32),
            edge_w=sds((cfg.ep, m_shard), jnp.float32),
            dangling=sds((cfg.ep, cfg.n_shard), jnp.float32),
        )

    @staticmethod
    def shardings(cfg: DistConfig, mesh: Mesh) -> "ShardedGraph":
        s = NamedSharding(mesh, P(cfg.model_axis, None))
        return ShardedGraph(row_ptr=s, col_idx=s, edge_w=s, dangling=s)


def build_sharded_graph(graph: Graph, cfg: DistConfig) -> ShardedGraph:
    """Host-side partitioning of a real graph into per-shard slabs."""
    n, ep, ns = cfg.n, cfg.ep, cfg.n_shard
    row_ptr = np.asarray(graph.row_ptr).astype(np.int64)
    col = np.asarray(graph.col_idx).astype(np.int32)
    deg = np.asarray(graph.out_deg).astype(np.float32)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    m_shard = 0
    slabs = []
    for s in range(ep):
        lo_v, hi_v = s * ns, min((s + 1) * ns, graph.n)
        lo_e, hi_e = row_ptr[lo_v] if lo_v <= graph.n else row_ptr[-1], \
            row_ptr[hi_v] if hi_v <= graph.n else row_ptr[-1]
        local_rp = (row_ptr[lo_v:hi_v + 1] - row_ptr[lo_v]).astype(np.int32)
        # pad vertex rows of the last shard
        if len(local_rp) < ns + 1:
            local_rp = np.concatenate(
                [local_rp,
                 np.full(ns + 1 - len(local_rp), local_rp[-1], np.int32)])
        lc = col[lo_e:hi_e]
        lw = np.repeat(inv[lo_v:hi_v],
                       np.diff(row_ptr[lo_v:hi_v + 1]).astype(np.int64))
        dang = np.zeros(ns, np.float32)
        real = min(hi_v, graph.n) - lo_v
        if real > 0:
            dang[:real] = (deg[lo_v:lo_v + real] == 0).astype(np.float32)
        slabs.append((local_rp, lc, lw.astype(np.float32), dang))
        m_shard = max(m_shard, len(lc))
    m_shard = max(m_shard, 1)
    rp = np.stack([s[0] for s in slabs])
    ci = np.stack([np.pad(s[1], (0, m_shard - len(s[1]))) for s in slabs])
    ew = np.stack([np.pad(s[2], (0, m_shard - len(s[2]))) for s in slabs])
    dg = np.stack([s[3] for s in slabs])
    return ShardedGraph(
        row_ptr=jnp.asarray(rp), col_idx=jnp.asarray(ci),
        edge_w=jnp.asarray(ew), dangling=jnp.asarray(dg),
    )


# ---------------------------------------------------------------------------
# one VERD iteration, per shard
# ---------------------------------------------------------------------------

def _expand_local_sources(row_ptr, f_local, edge_count):
    """Per-edge source value: f_local[q, src(e)] for local CSR order.

    row_ptr: [ns+1]; f_local: [qt, ns].  Edge e belongs to the local row r
    with row_ptr[r] <= e < row_ptr[r+1]; recover r via searchsorted.
    """
    e_ids = jnp.arange(edge_count, dtype=jnp.int32)
    src_row = jnp.searchsorted(row_ptr, e_ids, side="right") - 1
    src_row = jnp.clip(src_row, 0, f_local.shape[1] - 1)
    return jnp.take(f_local, src_row, axis=1)  # [qt, edges]


def _push_local(cfg: DistConfig, g_row_ptr, g_col, g_w, f_local):
    """Local push: [qt, ns] -> contributions [qt, ep, ns] by dest owner."""
    qt = f_local.shape[0]
    m = g_col.shape[0]
    chunk = min(cfg.edge_chunk, m)
    n_chunks = (m + chunk - 1) // chunk
    pad = n_chunks * chunk - m
    col_c = jnp.pad(g_col, (0, pad)).reshape(n_chunks, chunk)
    w_c = jnp.pad(g_w, (0, pad)).reshape(n_chunks, chunk)

    def body(acc, args):
        ci, col_k, w_k = args
        # per-chunk source-row recovery keeps the [m]-sized index arrays
        # out of live memory (only [chunk] at a time)
        e_ids = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        sr_k = jnp.clip(
            jnp.searchsorted(g_row_ptr, e_ids, side="right") - 1,
            0, cfg.n_shard - 1,
        )
        vals = jnp.take(f_local, sr_k, axis=1) * w_k[None, :]   # [qt, chunk]
        # destination bucket = owner * n_shard + local id == global id
        acc = acc + jax.ops.segment_sum(
            vals.T, col_k, num_segments=cfg.n,
        ).T
        return acc, ()

    acc0 = jnp.zeros((qt, cfg.n), jnp.float32)
    acc, _ = jax.lax.scan(
        body, acc0, (jnp.arange(n_chunks, dtype=jnp.int32), col_c, w_c))
    return acc.reshape(qt, cfg.ep, cfg.n_shard)


def _compress_bucket(contrib, k):
    """Top-k per (query, owner-bucket): values + local ids (fixed shape)."""
    vals, idx = jax.lax.top_k(contrib, k)            # [qt, ep, k]
    return vals, idx.astype(jnp.int32)


def make_verd_tile_step(cfg: DistConfig, mesh: Mesh):
    """Returns jit-able fn(graph_slabs, sources[qt], index_vals, index_idx)
    -> (topk_vals [qt, top_k], topk_idx [qt, top_k]).

    One full query tile: T iterations of shared decomposition + index
    combine + distributed top-k.  ``index_vals/idx``: [ep, n_shard, L].
    """
    model = cfg.model_axis

    def local_fn(rp, col, w, dang, sources, ivals, iidx):
        # slabs arrive with leading shard dim of size 1
        rp, col, w, dang = rp[0], col[0], w[0], dang[0]
        ivals, iidx = ivals[0], iidx[0]
        qt = sources.shape[0]
        me = jax.lax.axis_index(model)
        lo = me * cfg.n_shard

        # frontier: local slice of one-hot(sources)
        cols0 = jnp.clip(sources - lo, 0, cfg.n_shard - 1)
        hit0 = (sources >= lo) & (sources < lo + cfg.n_shard)
        src_onehot = jnp.zeros((qt, cfg.n_shard), jnp.float32).at[
            jnp.arange(qt), cols0].add(hit0.astype(jnp.float32))
        f = src_onehot
        s = jnp.zeros_like(f)

        def iteration(carry, _):
            s, f = carry
            s = s + cfg.c * f
            # dangling mass returns to each query's source vertex
            dm = jnp.sum(f * dang[None, :], axis=1)          # [qt]
            dm = jax.lax.psum(dm, model)
            contrib = _push_local(cfg, rp, col, w, f)        # [qt, ep, ns]
            if cfg.compress_k:
                vals, idx = _compress_bucket(contrib, cfg.compress_k)
                vals = jax.lax.all_to_all(
                    vals.astype(cfg.wire_dtype), model,
                    split_axis=1, concat_axis=1, tiled=False)
                idx = jax.lax.all_to_all(
                    idx, model, split_axis=1, concat_axis=1, tiled=False)
                # vals/idx: [qt, ep, k] received from every peer
                new_f = jnp.zeros((qt, cfg.n_shard), jnp.float32)
                qi = jnp.broadcast_to(
                    jnp.arange(qt)[:, None, None], vals.shape)
                new_f = new_f.at[qi.reshape(-1), idx.reshape(-1)].add(
                    vals.reshape(-1).astype(jnp.float32))
            else:
                recv = jax.lax.all_to_all(
                    contrib.astype(cfg.wire_dtype), model,
                    split_axis=1, concat_axis=1, tiled=False)
                new_f = recv.astype(jnp.float32).sum(axis=1)  # [qt, ns]
            new_f = (1.0 - cfg.c) * new_f
            # dangling mass jumps back to each query's source (Section 2.1)
            new_f = new_f + (1.0 - cfg.c) * dm[:, None] * src_onehot
            return (s, new_f), ()

        (s, f), _ = jax.lax.scan(
            iteration, (s, f), None, length=cfg.t_iterations)

        # combine with the local index rows: out columns are global ->
        # bucket by owner and exchange once.  Chunked over local vertices so
        # the [qt, chunk, L] expansion stays bounded (dense fw at twitter
        # scale is 66 GB).
        v_chunk = min(65536, cfg.n_shard)
        n_chunks = (cfg.n_shard + v_chunk - 1) // v_chunk
        pad_v = n_chunks * v_chunk - cfg.n_shard
        f_p = jnp.pad(f, ((0, 0), (0, pad_v)))
        iv_p = jnp.pad(ivals, ((0, pad_v), (0, 0)))
        ii_p = jnp.pad(iidx, ((0, pad_v), (0, 0)))
        fc = f_p.reshape(qt, n_chunks, v_chunk).transpose(1, 0, 2)
        ivc = iv_p.reshape(n_chunks, v_chunk, -1)
        iic = ii_p.reshape(n_chunks, v_chunk, -1)

        def combine_chunk(acc, args):
            f_k, iv_k, ii_k = args
            fw = f_k[:, :, None] * iv_k[None, :, :].astype(jnp.float32)
            acc = acc.at[:, ii_k.reshape(-1)].add(fw.reshape(qt, -1))
            return acc, ()

        contrib, _ = jax.lax.scan(
            combine_chunk, jnp.zeros((qt, cfg.n), jnp.float32),
            (fc, ivc, iic))
        contrib = contrib.reshape(qt, cfg.ep, cfg.n_shard)
        recv = jax.lax.all_to_all(
            contrib.astype(cfg.wire_dtype), model,
            split_axis=1, concat_axis=1, tiled=False)
        p_local = s + recv.astype(jnp.float32).sum(axis=1)    # [qt, ns]

        # distributed top-k: local top-k then gather + re-select
        k = min(cfg.top_k, cfg.n_shard)
        lv, li = jax.lax.top_k(p_local, k)
        gi = (li + lo).astype(jnp.int32)
        av = jax.lax.all_gather(lv, model, axis=1, tiled=True)  # [qt, ep*k]
        ai = jax.lax.all_gather(gi, model, axis=1, tiled=True)
        fv, fi = jax.lax.top_k(av, cfg.top_k)
        out_idx = jnp.take_along_axis(ai, fi, axis=1)
        return fv, out_idx

    in_specs = (
        P(model, None), P(model, None), P(model, None), P(model, None),
        P(),                                  # sources replicated
        P(model, None, None), P(model, None, None),
    )
    out_specs = (P(), P())
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )

    def step(slabs: ShardedGraph, sources, index_vals, index_idx):
        return fn(slabs.row_ptr, slabs.col_idx, slabs.edge_w, slabs.dangling,
                  sources, index_vals, index_idx)

    return step


# ---------------------------------------------------------------------------
# distributed MCFP walk step (offline indexing)
# ---------------------------------------------------------------------------

def make_walk_counts_step(cfg: DistConfig, mesh: Mesh, *, max_steps: int = 64):
    """Returns fn(row_ptr, col_idx, out_deg, sources[S], key) ->
    (fp_counts [S, n] vertex-sharded, moves [S]).

    Graph arrays are replicated (fits for twitter-2010-class graphs);
    walks shard over the batch axes; every (data, model) shard counts the
    visits that land in its vertex interval — no communication until the
    final psum of ``moves`` over data.
    """
    model = cfg.model_axis

    def local_fn(row_ptr, col_idx, out_deg, sources, rows, key):
        w = sources.shape[0]
        me = jax.lax.axis_index(model)
        lo = me * cfg.n_shard
        n_rows = cfg.q_tile  # count rows per tile

        def body(carry, t):
            cursors, active, fp, moves = carry
            k = jax.random.fold_in(key, t)
            for ax in cfg.batch_axes:  # distinct stream per data shard
                k = jax.random.fold_in(k, jax.lax.axis_index(ax))
            k_move, k_term = jax.random.split(k)
            af = active.astype(jnp.float32)
            local = (cursors >= lo) & (cursors < lo + cfg.n_shard)
            fp = fp.at[rows, jnp.clip(cursors - lo, 0, cfg.n_shard - 1)].add(
                af * local.astype(jnp.float32))
            moves = moves.at[rows].add(af)
            term = active & (jax.random.uniform(k_term, (w,)) < cfg.c)
            active = active & ~term
            deg = jnp.take(out_deg, cursors)
            base = jnp.take(row_ptr, cursors)
            off = jax.random.randint(k_move, (w,), 0, jnp.maximum(deg, 1))
            nxt = jnp.take(col_idx, base + off)
            cursors = jnp.where(deg == 0, sources, nxt)
            return (cursors, active, fp, moves), ()

        init = (
            sources,
            jnp.ones((w,), bool),
            jnp.zeros((n_rows, cfg.n_shard), jnp.float32),
            jnp.zeros((n_rows,), jnp.float32),
        )
        (c, a, fp, moves), _ = jax.lax.scan(
            body, init, jnp.arange(max_steps))
        fp = jax.lax.psum(fp, cfg.batch_axes)
        moves = jax.lax.psum(moves, cfg.batch_axes + (model,)) / cfg.ep
        return fp, moves

    in_specs = (
        P(None), P(None), P(None),            # graph replicated
        P(cfg.batch_axes), P(cfg.batch_axes), # walk sources/rows sharded
        P(),
    )
    out_specs = (P(None, model), P())
    return shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
