"""Distributed PPR engine: PowerWalk at pod scale (the paper's system).

At twitter-2010 scale (N = 41.65M) the dense ``[Q, N]`` frontier of
:mod:`repro.core.verd` is impossible; this module is the vertex-sharded,
query-tiled engine:

* **Graph layout**: vertices partitioned into ``model``-axis intervals
  (paper Section 3.1's master/slave intervals, static here).  Each shard
  owns the *out-edges of its vertices* (local CSR rows, global column ids).
* **VERD iteration** (push mode): each shard pushes its local frontier
  mass through its local edges, bucketing contributions by destination
  owner -> one ``all_to_all`` over the model axis per iteration -> sum
  received partials.  This is PowerGraph's scatter phase turned into a
  single bulk collective — exactly the paper's "small packets multiplexed
  into large payloads", now in hardware.
* **Sparse-frontier exchange** (default, ``exchange="sparse"``): the wire
  format is the fixed-width :class:`~repro.core.frontier.SparseFrontier`
  idiom — each shard holds its local ``[Q, K]`` frontier slice, pushes it
  through its local CSR rows (ELL-style hub splitting keeps the gather
  width ``<= hub_split_degree``), buckets candidates by destination owner
  as per-owner top-``wire_k`` ``(values, local-index)`` pairs
  (:func:`repro.core.frontier.bucket_by_owner`), and one ``all_to_all``
  moves O(Q x shards x wire_k) bytes per iteration instead of the dense
  O(Q x N) slab.  Received partials are dedup-merged + re-compacted with
  the same ``frontier.py`` machinery as the single-device sparse path, so
  the two paths agree to <= 1e-5 L1 when the widths cover the frontier
  support (``tests/test_parity.py``).  The legacy dense slab exchange is
  kept under ``exchange="dense"`` as the oracle; its ``compress_k`` knob
  is deprecated (subsumed by ``wire_k``).
* **MCFP walk step**: walk cursors shard over the data axes (embarrassing
  parallelism over sources, as in the paper); every (data, model) shard
  scatters visits of its walks that land in its vertex interval — visit
  counting needs no communication at all.
* **Index combine + top-k**: local combine against the vertex-sharded
  top-L index, bucket/exchange once, then a local+gathered top-k.

Everything is shard_map'd so the collective schedule is explicit and
auditable in the dry-run HLO.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import frontier as frontier_mod
from repro.core.graph import Graph
from repro.core.walks import DEFAULT_C


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Distributed engine configuration."""
    n: int                      # padded global vertex count (multiple of ep)
    ep: int                     # model-axis shards (vertex intervals)
    q_tile: int = 32            # queries per shared-decomposition tile
    c: float = DEFAULT_C
    t_iterations: int = 2
    index_l: int = 667
    top_k: int = 200
    exchange: str = "sparse"    # sparse (SparseFrontier wire) | dense (oracle)
    frontier_k: int = 0         # per-shard local frontier width (0 = derive)
    wire_k: int = 0             # per-owner exchange width (0 = frontier_k)
    combine_wire_k: int = 0     # index-combine exchange width (0 = derive)
    degree_cap: int = 0         # max out-degree; required for sparse exchange
    hub_split_degree: int = 0   # ELL row-split threshold for the sparse push
    kernel_q_tile: int = 8      # query-tile of the fused Pallas push kernel
    kernel_interpret: Optional[bool] = None  # None = auto: interpret except
                                # on a real TPU backend (interpret=False)
    compress_k: int = 0         # DEPRECATED: top-k'd *dense* exchange; use
                                # exchange="sparse" + wire_k instead
    edge_chunk: int = 1 << 22   # local edge-scan chunk
    wire_dtype: Any = jnp.float32   # bf16 halves exchange buffers + bytes
    model_axis: str = "model"
    batch_axes: Tuple[str, ...] = ("data",)

    def __post_init__(self):
        if self.exchange not in ("sparse", "dense"):
            raise ValueError(f"unknown exchange {self.exchange!r}")
        if self.compress_k:
            warnings.warn(
                "DistConfig.compress_k is deprecated: set wire_k instead. "
                "On the default exchange='sparse' path compress_k is only "
                "honored as the wire_k fallback when wire_k is unset; on "
                "the legacy exchange='dense' oracle path it still selects "
                "the compressed slab exchange.",
                DeprecationWarning,
                stacklevel=2,
            )

    @property
    def n_shard(self) -> int:
        return self.n // self.ep

    @property
    def resolved_frontier_k(self) -> int:
        """Local frontier width K (same auto floor as the engine selector)."""
        from repro.core.query import auto_frontier_floor

        if self.frontier_k > 0:
            return min(self.frontier_k, self.n)
        return min(self.n, auto_frontier_floor(self.top_k))

    @property
    def resolved_wire_k(self) -> int:
        """Per-owner exchange width; ``n_shard`` always fully covers (an
        owner sees at most ``n_shard`` distinct columns after the merge)."""
        k = self.wire_k if self.wire_k > 0 else (
            self.compress_k if self.compress_k > 0
            else self.resolved_frontier_k
        )
        return min(k, self.n_shard)

    @property
    def resolved_combine_wire_k(self) -> int:
        k = self.combine_wire_k if self.combine_wire_k > 0 else max(
            self.resolved_wire_k, self.top_k
        )
        return min(k, self.n_shard)

    @property
    def resolved_kernel_interpret(self) -> bool:
        """Interpret mode for the fused push kernel: honor the explicit
        setting, else interpret everywhere but a real TPU backend."""
        if self.kernel_interpret is not None:
            return bool(self.kernel_interpret)
        return jax.default_backend() != "tpu"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Per-shard CSR slabs, stacked on a leading shard dim.

    row_ptr: int32[ep, n_shard + 1]   local rows (offsets into col_idx row)
    col_idx: int32[ep, m_shard]       global destination ids (padded)
    edge_w:  f32[ep, m_shard]         1/out_deg(src), 0 on padding — only
                                      materialized for exchange="dense";
                                      the sparse step re-derives 1/deg from
                                      row lengths, so it gets a [ep, 1] stub
    dangling: f32[ep, n_shard]        1.0 where the local vertex is dangling
    """

    row_ptr: Any
    col_idx: Any
    edge_w: Any
    dangling: Any

    @staticmethod
    def specs(cfg: DistConfig, m_shard: int) -> "ShardedGraph":
        sds = jax.ShapeDtypeStruct
        m_w = m_shard if cfg.exchange == "dense" else 1
        return ShardedGraph(
            row_ptr=sds((cfg.ep, cfg.n_shard + 1), jnp.int32),
            col_idx=sds((cfg.ep, m_shard), jnp.int32),
            edge_w=sds((cfg.ep, m_w), jnp.float32),
            dangling=sds((cfg.ep, cfg.n_shard), jnp.float32),
        )

    @staticmethod
    def shardings(cfg: DistConfig, mesh: Mesh) -> "ShardedGraph":
        s = NamedSharding(mesh, P(cfg.model_axis, None))
        return ShardedGraph(row_ptr=s, col_idx=s, edge_w=s, dangling=s)


def build_sharded_graph(graph: Graph, cfg: DistConfig) -> ShardedGraph:
    """Host-side partitioning of a real graph into per-shard slabs."""
    n, ep, ns = cfg.n, cfg.ep, cfg.n_shard
    row_ptr = np.asarray(graph.row_ptr).astype(np.int64)
    col = np.asarray(graph.col_idx).astype(np.int32)
    deg = np.asarray(graph.out_deg).astype(np.float32)
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    m_shard = 0
    slabs = []
    for s in range(ep):
        lo_v, hi_v = s * ns, min((s + 1) * ns, graph.n)
        lo_e, hi_e = row_ptr[lo_v] if lo_v <= graph.n else row_ptr[-1], \
            row_ptr[hi_v] if hi_v <= graph.n else row_ptr[-1]
        local_rp = (row_ptr[lo_v:hi_v + 1] - row_ptr[lo_v]).astype(np.int32)
        # pad vertex rows of the last shard
        if len(local_rp) < ns + 1:
            local_rp = np.concatenate(
                [local_rp,
                 np.full(ns + 1 - len(local_rp), local_rp[-1], np.int32)])
        lc = col[lo_e:hi_e]
        if cfg.exchange == "dense":
            lw = np.repeat(inv[lo_v:hi_v],
                           np.diff(row_ptr[lo_v:hi_v + 1]).astype(np.int64))
        else:
            lw = np.zeros(0, np.float32)
        dang = np.zeros(ns, np.float32)
        real = min(hi_v, graph.n) - lo_v
        if real > 0:
            dang[:real] = (deg[lo_v:lo_v + real] == 0).astype(np.float32)
        slabs.append((local_rp, lc, lw.astype(np.float32), dang))
        m_shard = max(m_shard, len(lc))
    m_shard = max(m_shard, 1)
    rp = np.stack([s[0] for s in slabs])
    ci = np.stack([np.pad(s[1], (0, m_shard - len(s[1]))) for s in slabs])
    if cfg.exchange == "dense":
        ew = np.stack([np.pad(s[2], (0, m_shard - len(s[2]))) for s in slabs])
    else:  # sparse step re-derives 1/deg; skip the O(m) f32 slab entirely
        ew = np.zeros((cfg.ep, 1), np.float32)
    dg = np.stack([s[3] for s in slabs])
    return ShardedGraph(
        row_ptr=jnp.asarray(rp), col_idx=jnp.asarray(ci),
        edge_w=jnp.asarray(ew), dangling=jnp.asarray(dg),
    )


# ---------------------------------------------------------------------------
# one VERD iteration, per shard
# ---------------------------------------------------------------------------

def _push_local(cfg: DistConfig, g_row_ptr, g_col, g_w, f_local):
    """Local push: [qt, ns] -> contributions [qt, ep, ns] by dest owner."""
    qt = f_local.shape[0]
    m = g_col.shape[0]
    chunk = min(cfg.edge_chunk, m)
    n_chunks = (m + chunk - 1) // chunk
    pad = n_chunks * chunk - m
    col_c = jnp.pad(g_col, (0, pad)).reshape(n_chunks, chunk)
    w_c = jnp.pad(g_w, (0, pad)).reshape(n_chunks, chunk)

    def body(acc, args):
        ci, col_k, w_k = args
        # per-chunk source-row recovery keeps the [m]-sized index arrays
        # out of live memory (only [chunk] at a time)
        e_ids = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        sr_k = jnp.clip(
            jnp.searchsorted(g_row_ptr, e_ids, side="right") - 1,
            0, cfg.n_shard - 1,
        )
        vals = jnp.take(f_local, sr_k, axis=1) * w_k[None, :]   # [qt, chunk]
        # destination bucket = owner * n_shard + local id == global id
        acc = acc + jax.ops.segment_sum(
            vals.T, col_k, num_segments=cfg.n,
        ).T
        return acc, ()

    acc0 = jnp.zeros((qt, cfg.n), jnp.float32)
    acc, _ = jax.lax.scan(
        body, acc0, (jnp.arange(n_chunks, dtype=jnp.int32), col_c, w_c))
    return acc.reshape(qt, cfg.ep, cfg.n_shard)


def _compress_bucket(contrib, k):
    """Top-k per (query, owner-bucket): values + local ids (fixed shape)."""
    vals, idx = jax.lax.top_k(contrib, k)            # [qt, ep, k]
    return vals, idx.astype(jnp.int32)


def make_verd_tile_step(cfg: DistConfig, mesh: Mesh):
    """Returns jit-able fn(graph_slabs, sources[qt], index_vals, index_idx)
    -> (topk_vals [qt, top_k], topk_idx [qt, top_k]).

    One full query tile: T iterations of shared decomposition + index
    combine + distributed top-k.  ``index_vals/idx``: [ep, n_shard, L].
    Dispatches on ``cfg.exchange``: the default ``"sparse"`` wire format
    exchanges per-owner top-``wire_k`` (value, index) pairs; ``"dense"``
    keeps the legacy full-slab exchange as the oracle.
    """
    if cfg.exchange == "sparse":
        return _make_verd_tile_step_sparse(cfg, mesh)
    return _make_verd_tile_step_dense(cfg, mesh)


def _make_verd_tile_step_dense(cfg: DistConfig, mesh: Mesh):
    """Legacy dense-slab exchange: O(Q x N) wire bytes per iteration."""
    model = cfg.model_axis

    def local_fn(rp, col, w, dang, sources, ivals, iidx):
        # slabs arrive with leading shard dim of size 1
        rp, col, w, dang = rp[0], col[0], w[0], dang[0]
        ivals, iidx = ivals[0], iidx[0]
        qt = sources.shape[0]
        me = jax.lax.axis_index(model)
        lo = me * cfg.n_shard

        # frontier: local slice of one-hot(sources)
        cols0 = jnp.clip(sources - lo, 0, cfg.n_shard - 1)
        hit0 = (sources >= lo) & (sources < lo + cfg.n_shard)
        src_onehot = jnp.zeros((qt, cfg.n_shard), jnp.float32).at[
            jnp.arange(qt), cols0].add(hit0.astype(jnp.float32))
        f = src_onehot
        s = jnp.zeros_like(f)

        def iteration(carry, _):
            s, f = carry
            s = s + cfg.c * f
            # dangling mass returns to each query's source vertex
            dm = jnp.sum(f * dang[None, :], axis=1)          # [qt]
            dm = jax.lax.psum(dm, model)
            contrib = _push_local(cfg, rp, col, w, f)        # [qt, ep, ns]
            if cfg.compress_k:
                vals, idx = _compress_bucket(contrib, cfg.compress_k)
                vals = jax.lax.all_to_all(
                    vals.astype(cfg.wire_dtype), model,
                    split_axis=1, concat_axis=1, tiled=False)
                idx = jax.lax.all_to_all(
                    idx, model, split_axis=1, concat_axis=1, tiled=False)
                # vals/idx: [qt, ep, k] received from every peer
                new_f = jnp.zeros((qt, cfg.n_shard), jnp.float32)
                qi = jnp.broadcast_to(
                    jnp.arange(qt)[:, None, None], vals.shape)
                new_f = new_f.at[qi.reshape(-1), idx.reshape(-1)].add(
                    vals.reshape(-1).astype(jnp.float32))
            else:
                recv = jax.lax.all_to_all(
                    contrib.astype(cfg.wire_dtype), model,
                    split_axis=1, concat_axis=1, tiled=False)
                new_f = recv.astype(jnp.float32).sum(axis=1)  # [qt, ns]
            new_f = (1.0 - cfg.c) * new_f
            # dangling mass jumps back to each query's source (Section 2.1)
            new_f = new_f + (1.0 - cfg.c) * dm[:, None] * src_onehot
            return (s, new_f), ()

        (s, f), _ = jax.lax.scan(
            iteration, (s, f), None, length=cfg.t_iterations)

        # combine with the local index rows: out columns are global ->
        # bucket by owner and exchange once.  Chunked over local vertices so
        # the [qt, chunk, L] expansion stays bounded (dense fw at twitter
        # scale is 66 GB).
        v_chunk = min(65536, cfg.n_shard)
        n_chunks = (cfg.n_shard + v_chunk - 1) // v_chunk
        pad_v = n_chunks * v_chunk - cfg.n_shard
        f_p = jnp.pad(f, ((0, 0), (0, pad_v)))
        iv_p = jnp.pad(ivals, ((0, pad_v), (0, 0)))
        ii_p = jnp.pad(iidx, ((0, pad_v), (0, 0)))
        fc = f_p.reshape(qt, n_chunks, v_chunk).transpose(1, 0, 2)
        ivc = iv_p.reshape(n_chunks, v_chunk, -1)
        iic = ii_p.reshape(n_chunks, v_chunk, -1)

        def combine_chunk(acc, args):
            f_k, iv_k, ii_k = args
            fw = f_k[:, :, None] * iv_k[None, :, :].astype(jnp.float32)
            acc = acc.at[:, ii_k.reshape(-1)].add(fw.reshape(qt, -1))
            return acc, ()

        contrib, _ = jax.lax.scan(
            combine_chunk, jnp.zeros((qt, cfg.n), jnp.float32),
            (fc, ivc, iic))
        contrib = contrib.reshape(qt, cfg.ep, cfg.n_shard)
        recv = jax.lax.all_to_all(
            contrib.astype(cfg.wire_dtype), model,
            split_axis=1, concat_axis=1, tiled=False)
        p_local = s + recv.astype(jnp.float32).sum(axis=1)    # [qt, ns]

        # distributed top-k: local top-k then gather + re-select
        k = min(cfg.top_k, cfg.n_shard)
        lv, li = jax.lax.top_k(p_local, k)
        gi = (li + lo).astype(jnp.int32)
        av = jax.lax.all_gather(lv, model, axis=1, tiled=True)  # [qt, ep*k]
        ai = jax.lax.all_gather(gi, model, axis=1, tiled=True)
        fv, fi = jax.lax.top_k(av, cfg.top_k)
        out_idx = jnp.take_along_axis(ai, fi, axis=1)
        return fv, out_idx

    in_specs = (
        P(model, None), P(model, None), P(model, None), P(model, None),
        P(),                                  # sources replicated
        P(model, None, None), P(model, None, None),
    )
    out_specs = (P(), P())
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )

    def step(slabs: ShardedGraph, sources, index_vals, index_idx):
        return fn(slabs.row_ptr, slabs.col_idx, slabs.edge_w, slabs.dangling,
                  sources, index_vals, index_idx)

    return step


def _make_verd_tile_step_sparse(cfg: DistConfig, mesh: Mesh):
    """SparseFrontier wire format: O(Q x shards x wire_k) bytes/iteration.

    Per shard, per iteration: gather-push the local ``[Q, K]`` frontier
    slice through the local CSR rows via the fused HBM-resident Pallas
    kernel ``kernels.ops.sharded_frontier_push`` (hub rows split ELL-style
    so no gather axis exceeds ``hub_split_degree``; the kernel emits the
    per-owner top-``wire_k`` (value, local-index) buckets directly), one
    ``all_to_all``, then dedup-merge + re-compact the received partials back
    to the ``[Q, K]`` slice.  The kernel runs ``interpret=True`` off-TPU and
    compiled on a real TPU (``cfg.resolved_kernel_interpret``).  The
    accumulated ``s`` and the index-combine contributions stay sparse end to
    end; only the final per-shard top-k is gathered.
    """
    from repro.kernels import ops as kernel_ops

    if cfg.degree_cap <= 0:
        raise ValueError(
            "exchange='sparse' requires cfg.degree_cap > 0 (the max "
            "out-degree; resolve it host-side with "
            "repro.core.verd.resolve_degree_cap)"
        )
    model = cfg.model_axis
    ns = cfg.n_shard
    k_front = min(cfg.resolved_frontier_k, ns)   # local slice: <= ns distinct
    kw = cfg.resolved_wire_k
    kc = cfg.resolved_combine_wire_k
    interpret = cfg.resolved_kernel_interpret

    def a2a(x):
        return jax.lax.all_to_all(
            x, model, split_axis=1, concat_axis=1, tiled=False
        )

    def local_fn(rp, col, dang, sources, ivals, iidx):
        # no edge_w input: 1/deg weights are re-derived from the local row
        # lengths, so the O(m) f32 slab never enters the sparse step
        rp, col, dang = rp[0], col[0], dang[0]
        ivals, iidx = ivals[0], iidx[0]
        qt = sources.shape[0]
        me = jax.lax.axis_index(model)
        lo = me * ns

        # local slice of one-hot(sources), in sparse (width-1) form
        hit0 = ((sources >= lo) & (sources < lo + ns)).astype(jnp.float32)
        src_local = jnp.clip(sources - lo, 0, ns - 1).astype(jnp.int32)
        fv = hit0[:, None]
        fi = src_local[:, None]

        s_vals, s_idxs = [], []
        for _ in range(cfg.t_iterations):
            s_vals.append(cfg.c * fv)
            s_idxs.append(fi)
            # dangling mass returns to each query's source (Section 2.1)
            dm = jax.lax.psum(
                jnp.sum(fv * jnp.take(dang, fi), axis=1), model
            )
            # fused local gather push + per-owner top-k buckets (the
            # HBM-resident Pallas kernel) -> one all_to_all of fixed-width
            # (value, local-index) pairs
            bv, bi = kernel_ops.sharded_frontier_push(
                fv, fi, rp, col,
                c=cfg.c, degree_cap=cfg.degree_cap, ep=cfg.ep, n_shard=ns,
                wire_k=kw, hub_split_degree=cfg.hub_split_degree,
                q_tile=cfg.kernel_q_tile, interpret=interpret,
            )
            bv = a2a(bv.astype(cfg.wire_dtype)).astype(jnp.float32)
            bi = a2a(bi)
            cand_v = jnp.concatenate(
                [bv.reshape(qt, -1), ((1.0 - cfg.c) * dm * hit0)[:, None]],
                axis=1,
            )
            cand_i = jnp.concatenate(
                [bi.reshape(qt, -1), src_local[:, None]], axis=1
            )
            fv, fi = frontier_mod.compact_arrays(cand_v, cand_i, k_front)

        # index combine on the sparse slice: gather only the K touched local
        # rows, bucket the (global-column) contributions by owner, exchange
        # once.  ivals/iidx: [ns, L] with global column ids.
        iv = jnp.take(ivals, fi, axis=0).astype(jnp.float32)  # [qt, K, L]
        ii = jnp.take(iidx, fi, axis=0)
        contrib = (fv[..., None] * iv).reshape(qt, -1)
        cv, ci = frontier_mod.bucket_by_owner(
            contrib, ii.reshape(qt, -1), cfg.ep, ns, kc
        )
        cv = a2a(cv.astype(cfg.wire_dtype)).astype(jnp.float32)
        ci = a2a(ci)

        # local p~ entries: accumulated s + received combine partials; both
        # hold local indices, so one compaction yields the local top-k
        p_v = jnp.concatenate(s_vals + [cv.reshape(qt, -1)], axis=1)
        p_i = jnp.concatenate(s_idxs + [ci.reshape(qt, -1)], axis=1)
        lv, li = frontier_mod.compact_arrays(p_v, p_i, cfg.top_k)
        gi = (li + lo).astype(jnp.int32)

        # distributed top-k: gather every shard's local top-k, re-select
        av = jax.lax.all_gather(lv, model, axis=1, tiled=True)
        ai = jax.lax.all_gather(gi, model, axis=1, tiled=True)
        fv_out, sel = jax.lax.top_k(av, cfg.top_k)
        out_idx = jnp.take_along_axis(ai, sel, axis=1)
        return fv_out, out_idx

    in_specs = (
        P(model, None), P(model, None), P(model, None),
        P(),                                  # sources replicated
        P(model, None, None), P(model, None, None),
    )
    out_specs = (P(), P())
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )

    def step(slabs: ShardedGraph, sources, index_vals, index_idx):
        return fn(slabs.row_ptr, slabs.col_idx, slabs.dangling,
                  sources, index_vals, index_idx)

    return step


def exchange_bytes_per_iteration(cfg: DistConfig) -> Dict[str, float]:
    """Wire bytes one shard sends per VERD iteration, per exchange format.

    ``dense``: the full ``[q_tile, n]`` slab in ``wire_dtype``.  ``sparse``:
    ``q_tile * ep * wire_k`` (value, int32 index) pairs.  ``reduction`` is
    dense/sparse — the headline number ``benchmarks/bench_query.py`` reports
    (>= 5x at the acceptance point n=100k, Q=256, K=512).
    """
    item = jnp.dtype(cfg.wire_dtype).itemsize
    dense = float(cfg.q_tile * cfg.n * item)
    sparse = float(cfg.q_tile * cfg.ep * cfg.resolved_wire_k * (item + 4))
    return dict(
        dense=dense, sparse=sparse, reduction=dense / max(sparse, 1.0)
    )


# ---------------------------------------------------------------------------
# distributed MCFP walk step (offline indexing)
# ---------------------------------------------------------------------------

def _walk_graph(row_ptr, col_idx, out_deg) -> Graph:
    """Wrap replicated CSR slabs for the walk engine.

    The walk engine never reads the COO ``src`` field; poison it so any
    future consumer gathers index -1 instead of silently using
    destinations as sources (DCE'd while unused)."""
    m = col_idx.shape[0]
    return Graph(
        row_ptr=row_ptr, col_idx=col_idx,
        src=jnp.broadcast_to(jnp.int32(-1), (m,)),
        out_deg=out_deg, n=out_deg.shape[0], m=m,
    )


def _merge_sparse_counts(counts, axes, l: int):
    """Cross-shard sketch merge: one ``all_gather`` of the per-shard
    ``[rows, l]`` sketches along the width axis + one dedup-merge back to
    ``l``, plus the psum'd ``moves`` and the full ``dropped`` ledger
    (per-shard sketch truncation + whatever this merge compacts away), so
    ``fp_v.sum(1) + dropped == moves`` holds exactly for any ``l``.  The
    one communication step of both the sharded walk-counts step and the
    sharded index build."""
    av = jax.lax.all_gather(counts.fp.values, axes, axis=1, tiled=True)
    ai = jax.lax.all_gather(counts.fp.indices, axes, axis=1, tiled=True)
    moves = jax.lax.psum(counts.moves, axes)
    fp_v, fp_i, dropped = frontier_mod.merge_sketch_parts(
        av, ai, jax.lax.psum(counts.fp_dropped, axes), l
    )
    return fp_v, fp_i, moves, dropped

def make_walk_counts_step(cfg: DistConfig, mesh: Mesh, *, max_steps: int = 64):
    """Returns fn(row_ptr, col_idx, out_deg, sources[S], key) ->
    (fp_counts [S, n] vertex-sharded, moves [S]).

    Graph arrays are replicated (fits for twitter-2010-class graphs);
    walks shard over the batch axes; every (data, model) shard counts the
    visits that land in its vertex interval — no communication until the
    final psum of ``moves`` over data.
    """
    model = cfg.model_axis

    def local_fn(row_ptr, col_idx, out_deg, sources, rows, key):
        w = sources.shape[0]
        me = jax.lax.axis_index(model)
        lo = me * cfg.n_shard
        n_rows = cfg.q_tile  # count rows per tile

        def body(carry, t):
            cursors, active, fp, moves = carry
            k = jax.random.fold_in(key, t)
            for ax in cfg.batch_axes:  # distinct stream per data shard
                k = jax.random.fold_in(k, jax.lax.axis_index(ax))
            k_move, k_term = jax.random.split(k)
            af = active.astype(jnp.float32)
            local = (cursors >= lo) & (cursors < lo + cfg.n_shard)
            fp = fp.at[rows, jnp.clip(cursors - lo, 0, cfg.n_shard - 1)].add(
                af * local.astype(jnp.float32))
            moves = moves.at[rows].add(af)
            term = active & (jax.random.uniform(k_term, (w,)) < cfg.c)
            active = active & ~term
            deg = jnp.take(out_deg, cursors)
            base = jnp.take(row_ptr, cursors)
            off = jax.random.randint(k_move, (w,), 0, jnp.maximum(deg, 1))
            nxt = jnp.take(col_idx, base + off)
            cursors = jnp.where(deg == 0, sources, nxt)
            return (cursors, active, fp, moves), ()

        init = (
            sources,
            jnp.ones((w,), bool),
            jnp.zeros((n_rows, cfg.n_shard), jnp.float32),
            jnp.zeros((n_rows,), jnp.float32),
        )
        (c, a, fp, moves), _ = jax.lax.scan(
            body, init, jnp.arange(max_steps))
        fp = jax.lax.psum(fp, cfg.batch_axes)
        moves = jax.lax.psum(moves, cfg.batch_axes + (model,)) / cfg.ep
        return fp, moves

    in_specs = (
        P(None), P(None), P(None),            # graph replicated
        P(cfg.batch_axes), P(cfg.batch_axes), # walk sources/rows sharded
        P(),
    )
    out_specs = (P(None, model), P())
    return shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )


def make_sparse_walk_counts_step(
    cfg: DistConfig,
    mesh: Mesh,
    *,
    r: int,
    l: int,
    max_steps: int = 64,
    compact_every: int = 8,
):
    """Sharded compacted sparse-sketch walk engine (offline indexing).

    Returns fn(row_ptr, col_idx, out_deg, sources[rows], key) ->
    ``(fp_vals f32[rows, l], fp_idx int32[rows, l], moves f32[rows],
    walks f32[rows], dropped f32[rows])``, replicated.  ``dropped`` is the
    full cross-shard ledger — per-shard sketch truncation plus anything the
    final merge compacts away — so the engine's conservation contract
    ``fp_vals.sum(1) + dropped == moves`` holds exactly for any ``l``.

    Walks are embarrassingly parallel, so the ``r`` walks of every source
    split evenly over *every* mesh axis (batch and model alike — a model
    replica would otherwise recompute identical walks): each shard runs
    ``r / n_shards`` walks per row through
    :func:`repro.core.walks.simulate_walks_sparse` on the replicated graph
    with a per-shard key, entirely communication-free.  The only cross-shard
    step is the final sketch merge: one ``all_gather`` of the per-shard
    ``[rows, l]`` sketches along the width axis plus one
    :func:`repro.core.frontier.compact_arrays` dedup-merge back to ``l``
    (O(rows * n_shards * l) wire bytes total — independent of ``n`` and of
    the walk count), and a psum of the scalar ``moves``/``walks``/
    ``dropped`` counters.  Requires ``r`` divisible by the mesh size.
    """
    from repro.core.walks import simulate_walks_sparse

    axes = tuple(cfg.batch_axes) + (cfg.model_axis,)
    n_shards = 1
    for ax in axes:
        n_shards *= mesh.shape[ax]
    if r % n_shards != 0:
        raise ValueError(
            f"r={r} must divide evenly over the {n_shards} mesh shards"
        )
    r_local = r // n_shards

    def local_fn(row_ptr, col_idx, out_deg, sources, key):
        for ax in axes:  # distinct walk stream per shard
            key = jax.random.fold_in(key, jax.lax.axis_index(ax))
        g = _walk_graph(row_ptr, col_idx, out_deg)
        counts = simulate_walks_sparse(
            g, sources, r_local, key, l=l, ep_l=0, c=cfg.c,
            max_steps=max_steps, compact_every=compact_every,
        )
        fp_v, fp_i, moves, dropped = _merge_sparse_counts(counts, axes, l)
        walks = jax.lax.psum(counts.walks, axes)
        return fp_v, fp_i, moves, walks, dropped

    in_specs = (
        P(None), P(None), P(None),            # graph replicated
        P(),                                  # sources replicated (r splits)
        P(),
    )
    out_specs = (P(), P(), P(), P(), P())
    return shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )


def make_sparse_index_build_step(
    cfg: DistConfig,
    mesh: Mesh,
    *,
    r: int,
    l: int,
    sketch_l: int,
    real_n: int,
    max_steps: int = 64,
    compact_every: int = 8,
    source_batch: int = 256,
    respawn: bool = False,
    touch_bits: int = 0,
    chunk_start: int = 0,
    chunk_count: Optional[int] = None,
):
    """The whole offline index build as one sharded device computation.

    Returns fn(row_ptr, col_idx, out_deg, key) -> ``(values f32[n, l],
    indices int32[n, l], kept f32[n], dropped f32[n])`` with the index
    arrays sharded ``P(model, None)`` — each model shard sweeps the source
    chunks of its own vertex interval with a ``lax.scan`` (so the sweep is
    device-side, not a host chunk loop) and emits only its ``[n_shard, l]``
    block; no device ever holds a replicated ``[n, l]`` index (the jaxpr
    gate in ``tests/dist_engine_check.py``).  Graph arrays arrive
    replicated and padded to ``cfg.n`` rows.

    Per chunk this drives the :func:`make_sparse_walk_counts_step`
    machinery restricted to the batch axes: each data replica runs
    ``r / n_data`` walks per row (:func:`repro.core.walks
    .simulate_walks_sparse`, respawn-mode when ``respawn``) and the
    sketches merge through the same one-``all_gather`` dedup
    (``_merge_sparse_counts``), then normalize/truncate via
    ``index.normalize_sketch_to_index_rows``.  Key discipline: chunk at
    global source offset ``o`` uses ``fold_in(key, o)``; data replica
    ``s`` (the linear index over ``cfg.batch_axes``) folds ``s`` on top —
    the exact fold order of the single-device ``engine="sparse"`` build at
    ``r_splits = n_data``, which is what makes the two builders agree row
    for row under one key.

    Requires ``cfg.n_shard`` divisible by ``source_batch`` (so shard
    intervals align with the single-device chunk grid) and ``r`` divisible
    by the batch-axis shard count.

    ``touch_bits > 0`` appends a fifth output: the per-row walks-through
    Bloom filter ``bool[n, touch_bits]`` (``P(model, None)`` like the index
    rows), OR-merged across data replicas with a psum and zeroed on pad
    rows — the invalidation sketch ``core/updates.py`` consumes.

    ``chunk_start``/``chunk_count`` restrict the sweep to a contiguous
    *per-shard* chunk range (defaults: the whole grid) — the checkpointed
    ``build_index_sharded`` segments the scan at commit boundaries with
    these, and because each chunk's key is positional
    (``fold_in(key, offset)``) a segmented sweep reproduces the full sweep
    bit for bit.  Outputs then cover ``chunk_count * source_batch`` rows
    per shard (``P(model, None)`` as before).
    """
    from repro.core.index import normalize_sketch_to_index_rows
    from repro.core.walks import simulate_walks_sparse

    model = cfg.model_axis
    ns = cfg.n_shard
    axes = tuple(cfg.batch_axes)
    n_split = 1
    for ax in axes:
        n_split *= mesh.shape[ax]
    if r % n_split != 0:
        raise ValueError(
            f"r={r} must divide evenly over the {n_split} walk shards"
        )
    if ns % source_batch != 0:
        raise ValueError(
            f"n_shard={ns} must be a multiple of source_batch={source_batch}"
        )
    r_local = r // n_split
    n_chunks = ns // source_batch
    if chunk_count is None:
        chunk_count = n_chunks - chunk_start
    if not (0 <= chunk_start
            and chunk_count >= 1
            and chunk_start + chunk_count <= n_chunks):
        raise ValueError(
            f"chunk range [{chunk_start}, {chunk_start + chunk_count}) "
            f"outside the [0, {n_chunks}) per-shard chunk grid"
        )
    rows_out = chunk_count * source_batch

    def local_fn(row_ptr, col_idx, out_deg, key):
        me = jax.lax.axis_index(model)
        lo = me * ns
        # linear data-replica id: the split index the single-device
        # r_splits emulation folds (row-major over cfg.batch_axes)
        split = jnp.int32(0)
        for ax in axes:
            split = split * mesh.shape[ax] + jax.lax.axis_index(ax)
        g = _walk_graph(row_ptr, col_idx, out_deg)

        def chunk_body(carry, j):
            offset = lo + j * source_batch
            sources = offset + jnp.arange(source_batch, dtype=jnp.int32)
            chunk_key = jax.random.fold_in(key, offset)
            sub_key = (
                chunk_key if n_split == 1
                else jax.random.fold_in(chunk_key, split)
            )
            counts = simulate_walks_sparse(
                g, sources, r_local, sub_key, l=sketch_l, ep_l=0, c=cfg.c,
                max_steps=max_steps, compact_every=compact_every,
                respawn=respawn, touch_bits=touch_bits,
            )
            if n_split > 1:
                fp_v, fp_i, moves, dropped = _merge_sparse_counts(
                    counts, axes, sketch_l
                )
            else:
                fp_v, fp_i = counts.fp.values, counts.fp.indices
                moves, dropped = counts.moves, counts.fp_dropped
            vals, idxs, kept, dropped_est = normalize_sketch_to_index_rows(
                fp_v, fp_i, moves, dropped, l
            )
            # pad vertices (>= real_n): dangling rows that walked in place —
            # zero them so the sharded index carries no phantom mass
            realm = sources < real_n
            vals = jnp.where(realm[:, None], vals, 0.0)
            idxs = jnp.where(realm[:, None], idxs, 0)
            kept = jnp.where(realm, kept, 0.0)
            dropped_est = jnp.where(realm, dropped_est, 0.0)
            out = (vals, idxs, kept, dropped_est)
            if touch_bits:
                touch = counts.touch
                if n_split > 1:   # OR-merge the replicas' bloom filters
                    touch = jax.lax.psum(
                        touch.astype(jnp.int32), axes) > 0
                touch = jnp.where(realm[:, None], touch, False)
                out = out + (touch,)
            return carry, out

        _, scanned = jax.lax.scan(
            chunk_body, 0,
            chunk_start + jnp.arange(chunk_count, dtype=jnp.int32),
        )
        vals, idxs, kept, dropped = scanned[:4]
        out = (
            vals.reshape(rows_out, l), idxs.reshape(rows_out, l),
            kept.reshape(rows_out), dropped.reshape(rows_out),
        )
        if touch_bits:
            out = out + (scanned[4].reshape(rows_out, touch_bits),)
        return out

    in_specs = (P(None), P(None), P(None), P())   # graph + key replicated
    out_specs = (
        P(model, None), P(model, None), P(model), P(model),
    ) + ((P(model, None),) if touch_bits else ())
    return shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# Contract-auditor entry point (repro.analysis): inside the sharded build's
# shard_map bodies no per-device array may cover the full [n, L] index —
# the index stays model-sharded, never replicated.  Meaningful only on a
# multi-device mesh (with ep=1 a shard's legal block IS [n, L]), so the
# builder skips when the process has a single device; the auditor CLI
# forces a 4-way host-platform split before importing jax.
# ---------------------------------------------------------------------------

from repro.analysis.registry import register_entry_point as _register_ep


def _contract_spec_sharded_build_step():
    if jax.device_count() < 2:
        return dict(skip="needs >= 2 devices for a sharded mesh (run via "
                         "`python -m repro.analysis`, which forces a 4-way "
                         "host-platform split)")
    from repro.graphs import synthetic

    data = 2 if jax.device_count() >= 4 else 1
    mesh = jax.make_mesh((data, 2), ("data", "model"))
    g = synthetic.erdos_renyi(64, 4.0, seed=21)
    cfg = DistConfig(n=64, ep=2)
    l = 16
    step = make_sparse_index_build_step(
        cfg, mesh, r=64, l=l, sketch_l=48, real_n=64, source_batch=16,
    )
    jaxpr = jax.make_jaxpr(step)(
        g.row_ptr, g.col_idx, g.out_deg, jax.random.PRNGKey(3)
    )
    return dict(jaxpr=jaxpr, n=cfg.n, l=l)


_register_ep("sparse-index-build-step", "no-replicated-index",
             "src/repro/core/distributed_engine.py",
             _contract_spec_sharded_build_step)
