"""Incremental index maintenance for evolving graphs.

Production graphs mutate under the service; rebuilding the whole
fingerprint index per edge batch is ``O(n * R / c)`` resampled walk
positions.  Per-vertex fingerprints are independent Monte-Carlo sketches,
so an edge update only invalidates the rows whose walks *could* have
crossed the touched vertices (the incremental scheme of Hou et al. 2022,
PAPERS.md).  This module finds that set and repairs only it:

* **Invalidation.** ``build_maintainable_index`` records, per fingerprint
  row, a "walks-through" Bloom filter over every counted walk position
  (``walks.simulate_walks_sparse(touch_bits=...)``).  A walk only ever
  steps *from* counted positions, so a row whose filter misses every
  touched vertex re-simulates **bit-identically** on the updated graph —
  Bloom false positives cause harmless extra repair, never a stale row.
  The dirty set is the filter hits plus the touched sources themselves.

* **Repair granularity.** The walk engine draws its uniforms per source
  *chunk* (``[rows, w]`` from ``fold_in(key, chunk_offset)``), so a row's
  random stream depends on its position in the chunk — repairing a row
  subset under fresh keys would decorrelate it from a rebuild.  Repair
  therefore recomputes whole *chunks* of the original build grid through
  :func:`repro.core.index.sparse_chunk_estimates` with the build's exact
  per-chunk keys: the repaired index equals a from-scratch
  ``build_index`` on the mutated graph row for row (bitwise on a
  single-device grid; the sharded grid repairs through the documented
  ``r_splits`` emulation, ≤1e-5 L1 on dirty rows).

* **Accounting.** Work is measured in resampled walk positions — chunk
  slots swept times the expected positions per slot (``r / c``), the same
  unit as ``index.preprocessing_cost_model`` — so the headline gate
  (``benchmarks/bench_updates.py``) is simply dirty-chunks over
  total-chunks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import walks as walks_mod
from repro.core.graph import Graph, apply_edge_updates
from repro.core.index import (PPRIndex, build_index, build_index_sharded,
                              sparse_chunk_estimates)

DEFAULT_C = walks_mod.DEFAULT_C


def default_touch_bits(r: int, c: float = DEFAULT_C) -> int:
    """Bloom width for ``r`` walks/row: a row's filter holds ~``r/c``
    distinct positions under ``TOUCH_HASHES`` hashes, so ``bits ~ 256 * r``
    keeps the per-(row, vertex) false-positive rate ~1e-4 — small enough
    that FP-dirty rows stay a rounding error next to truly-dirty ones.
    Power-of-two, clamped to [1024, 65536]."""
    bits = 1024
    while bits < 256 * max(r, 1) and bits < 65536:
        bits *= 2
    return bits


@dataclasses.dataclass(frozen=True)
class TouchSketch:
    """Per-row walks-through Bloom filters: ``bits bool[rows, n_bits]``."""

    bits: jax.Array
    hashes: int = walks_mod.TOUCH_HASHES

    @property
    def rows(self) -> int:
        return int(self.bits.shape[0])

    @property
    def n_bits(self) -> int:
        return int(self.bits.shape[1])

    @property
    def nbytes(self) -> int:
        return self.rows * self.n_bits  # bool storage

    def dirty_rows(self, touched) -> np.ndarray:
        """Rows whose filter contains *any* touched vertex (host query).

        Conservative by construction: no false negatives, so every row
        missing from the result is bit-stable under the update."""
        t = np.unique(np.asarray(touched, np.int64).reshape(-1))
        if t.size == 0:
            return np.zeros(0, dtype=np.int64)
        bits = np.asarray(self.bits)
        hb = np.asarray(walks_mod.touch_hash_bits(
            jnp.asarray(t, jnp.int32), self.n_bits, self.hashes))
        dirty = np.zeros(bits.shape[0], dtype=bool)
        # chunk the touched set so the [rows, chunk, k] gather stays small
        chunk = max(1, (1 << 22) // max(bits.shape[0], 1))
        for i in range(0, t.size, chunk):
            sel = bits[:, hb[i:i + chunk]]          # [rows, tc, k]
            dirty |= sel.all(axis=2).any(axis=1)
        return np.nonzero(dirty)[0].astype(np.int64)

    def replace_rows(self, rows, new_bits) -> "TouchSketch":
        """Functionally replace rows (sharding-preserving, like
        ``PPRIndex.replace_rows``)."""
        b = self.bits.at[jnp.asarray(rows, jnp.int32)].set(
            jnp.asarray(new_bits))
        sh = getattr(self.bits, "sharding", None)
        if sh is not None and not sh.is_fully_replicated:
            b = jax.device_put(b, sh)
        return TouchSketch(bits=b, hashes=self.hashes)


@dataclasses.dataclass(frozen=True)
class BuildParams:
    """Everything a repair needs to replay the build's chunk grid."""

    r: int
    l: int
    sketch_l: int
    c: float
    max_steps: int
    compact_every: int
    source_batch: int
    r_splits: int
    respawn: bool
    engine: str          # "sparse" | "sparse-sharded"


@dataclasses.dataclass(frozen=True)
class MaintainableIndex:
    """A ``PPRIndex`` plus what incremental repair needs: the build key,
    the chunk-grid parameters, and the per-row touch sketch."""

    index: PPRIndex
    touch: TouchSketch
    key: jax.Array
    params: BuildParams
    real_n: int          # graph vertices (index.n may be padded above it)

    @property
    def n_chunks(self) -> int:
        sb = self.params.source_batch
        grid_n = self.index.n if self.params.engine == "sparse-sharded" \
            else self.real_n
        return -(-grid_n // sb)


def build_maintainable_index(
    graph: Graph,
    r: int,
    l: int,
    key: jax.Array,
    *,
    touch_bits: int = 0,
    mesh=None,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    source_batch: int = 256,
    compact_every: int = 8,
    r_splits: int = 1,
    respawn: bool = False,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
    resume: bool = False,
    checkpoint_keep: int = 3,
    fault_plan=None,
    **sharded_kwargs,
) -> Tuple[MaintainableIndex, dict]:
    """Full-sweep index build that also records the maintenance state.

    Single-device (``mesh=None``, via :func:`repro.core.index.build_index`)
    or sharded (via :func:`repro.core.index.build_index_sharded`, which
    forces ``respawn`` to its own default unless overridden here).
    ``touch_bits=0`` auto-sizes the Bloom width from ``r``
    (:func:`default_touch_bits`).  Returns ``(maintainable, stats)`` with
    the touch filter popped out of ``stats`` into the result.

    ``checkpoint_dir``/``checkpoint_every``/``resume`` make the build
    crash-safe (see :func:`repro.core.index.build_index`); the touch
    filter rides in every commit, so an index resumed from a checkpoint
    repairs identically to an uninterrupted one
    (:func:`load_maintainable_index` is the reload path).
    """
    if touch_bits <= 0:
        touch_bits = default_touch_bits(r, c)
    ckpt_kwargs = dict(
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        resume=resume, checkpoint_keep=checkpoint_keep,
        fault_plan=fault_plan,
    )
    if mesh is None:
        index, stats = build_index(
            graph, r, l, key, c=c, max_steps=max_steps,
            source_batch=source_batch, engine="sparse",
            compact_every=compact_every, r_splits=r_splits,
            respawn=respawn, touch_bits=touch_bits, **ckpt_kwargs,
        )
    else:
        index, stats = build_index_sharded(
            graph, r, l, key, mesh=mesh, c=c, max_steps=max_steps,
            source_batch=source_batch, compact_every=compact_every,
            respawn=respawn, touch_bits=touch_bits,
            **ckpt_kwargs, **sharded_kwargs,
        )
    touch = TouchSketch(bits=stats.pop("touch"))
    params = BuildParams(
        r=r, l=stats["l"], sketch_l=stats["sketch_l"], c=c,
        max_steps=max_steps, compact_every=compact_every,
        source_batch=stats["source_batch"], r_splits=stats["r_splits"],
        respawn=bool(stats["respawn"]), engine=stats["engine"],
    )
    m = MaintainableIndex(
        index=index, touch=touch, key=key, params=params, real_n=graph.n)
    return m, stats


def load_maintainable_index(checkpoint_dir: str) -> Tuple[
        MaintainableIndex, dict]:
    """Rebuild a :class:`MaintainableIndex` from a *complete* build
    checkpoint — no walk is re-simulated.

    The final ``complete=True`` step a checkpointed
    :func:`build_maintainable_index` commits carries everything repair
    needs: the index rows, the touch Bloom filter, and (in the build
    signature) the PRNG key plus the exact chunk-grid parameters.  The
    reloaded index therefore repairs bit-identically to the one the build
    returned in-process.  Requires the build to have run with
    ``touch_bits > 0`` (``build_maintainable_index`` always does).
    """
    from repro.core.index import load_index_checkpoint
    from repro.distributed.checkpoint import Checkpointer, deserialize_key

    index, stats = load_index_checkpoint(checkpoint_dir)
    if "touch" not in stats:
        raise ValueError(
            f"checkpoint under {checkpoint_dir} has no touch sketch — not "
            "a maintainable-index build")
    ckpt = Checkpointer(checkpoint_dir)
    hit = ckpt.restore_latest(
        predicate=lambda extra: bool(extra.get("complete")))
    assert hit is not None  # load_index_checkpoint already found it
    sig = hit[2]["signature"]
    key = deserialize_key(sig["key"])
    params = BuildParams(
        r=int(sig["r"]), l=int(stats["l"]), sketch_l=int(sig["sketch_l"]),
        c=float(sig["c"]), max_steps=int(sig["max_steps"]),
        compact_every=int(sig["compact_every"]),
        source_batch=int(sig["source_batch"]),
        r_splits=int(sig["r_splits"]), respawn=bool(sig["respawn"]),
        engine=str(stats["engine"]),
    )
    touch = TouchSketch(bits=stats.pop("touch"))
    m = MaintainableIndex(
        index=index, touch=touch, key=key, params=params,
        real_n=int(sig["n"]))
    return m, stats


def plan_repair(m: MaintainableIndex, touched_sources) -> dict:
    """Invalidation plan for a touched-source set: the dirty rows (touch
    hits ∪ touched sources) and the build-grid chunks covering them."""
    touched = np.unique(np.asarray(touched_sources, np.int64).reshape(-1))
    touched = touched[(touched >= 0) & (touched < m.real_n)]
    dirty = m.touch.dirty_rows(touched)
    dirty = np.union1d(dirty, touched)
    dirty = dirty[dirty < m.real_n]
    sb = m.params.source_batch
    chunks = np.unique(dirty // sb) if dirty.size else np.zeros(0, np.int64)
    return dict(
        touched=touched,
        dirty_rows=dirty,
        chunks=chunks,
        n_chunks_total=m.n_chunks,
    )


def _padded_walk_graph(graph: Graph, n_pad: int) -> Graph:
    """Pad the graph to the sharded index's vertex count: pad vertices are
    dangling, exactly as ``build_index_sharded`` pads its CSR slabs."""
    if n_pad == graph.n:
        return graph
    rp = np.asarray(graph.row_ptr, np.int32)
    od = np.asarray(graph.out_deg, np.int32)
    rp = np.concatenate([rp, np.full(n_pad - graph.n, rp[-1], np.int32)])
    od = np.concatenate([od, np.zeros(n_pad - graph.n, np.int32)])
    return Graph(
        row_ptr=jnp.asarray(rp), col_idx=graph.col_idx,
        src=graph.src, out_deg=jnp.asarray(od),
        n=n_pad, m=graph.m,
    )


def apply_updates(
    m: MaintainableIndex,
    graph: Graph,
    inserts=None,
    deletes=None,
) -> Tuple[Graph, MaintainableIndex, dict]:
    """Apply an edge-update batch and repair exactly the dirtied rows.

    ``graph`` must be the graph ``m`` was built (or last repaired) on.
    Returns ``(new_graph, new_maintainable, report)``; the inputs are not
    mutated.  ``report["dirty_row_ids"]`` is the vertex set serving-layer
    caches must invalidate; the ``resampled_*``/``rebuild_*`` fields carry
    the walk-position accounting the update bench gates on.
    """
    if graph.n != m.real_n:
        raise ValueError(
            f"graph has {graph.n} vertices but the index was built on "
            f"{m.real_n}")
    new_graph, touched = apply_edge_updates(graph, inserts, deletes)
    plan = plan_repair(m, touched)
    p = m.params
    sb = p.source_batch
    n_ins = len(np.asarray(inserts).reshape(-1, 2)) if inserts is not None \
        and np.asarray(inserts).size else 0
    n_del = len(np.asarray(deletes).reshape(-1, 2)) if deletes is not None \
        and np.asarray(deletes).size else 0
    # Work accounting, in walk positions (the preprocessing_cost_model
    # unit): every swept chunk slot expects r/c counted positions, and a
    # rebuild sweeps the full grid including its pad slots.
    pos_per_slot = p.r / p.c
    resampled_slots = int(len(plan["chunks"])) * sb
    rebuild_slots = plan["n_chunks_total"] * sb
    report = dict(
        edges_inserted=int(n_ins),
        edges_deleted=int(n_del),
        touched_sources=int(plan["touched"].size),
        dirty_rows=int(plan["dirty_rows"].size),
        dirty_row_ids=plan["dirty_rows"],
        repaired_chunks=int(len(plan["chunks"])),
        total_chunks=int(plan["n_chunks_total"]),
        resampled_positions=resampled_slots * pos_per_slot,
        rebuild_positions=rebuild_slots * pos_per_slot,
        resample_ratio=rebuild_slots / max(resampled_slots, 1),
    )
    if not len(plan["chunks"]):
        return new_graph, m, report

    walk_g = _padded_walk_graph(new_graph, m.index.n)
    sharded = p.engine == "sparse-sharded"
    rows_parts, vals_parts, idxs_parts, touch_parts = [], [], [], []
    for chunk in plan["chunks"]:
        start = int(chunk) * sb
        if sharded:
            # the sharded grid covers the padded vertex range; pad rows are
            # swept (their key position matters) then zeroed like the build
            src_np = np.arange(start, start + sb, dtype=np.int32)
            real = int(np.sum(src_np < m.real_n))
        else:
            # the single-device grid pads the ragged tail with source 0
            real = min(sb, m.real_n - start)
            src_np = np.concatenate([
                np.arange(start, start + real, dtype=np.int32),
                np.zeros(sb - real, np.int32),
            ])
        out = sparse_chunk_estimates(
            walk_g, jnp.asarray(src_np), jax.random.fold_in(m.key, start),
            r=p.r, l=p.l, sketch_l=p.sketch_l, c=p.c,
            max_steps=p.max_steps, compact_every=p.compact_every,
            r_splits=p.r_splits, respawn=p.respawn,
            touch_bits=m.touch.n_bits,
        )
        vals, idxs, _, _, touch = out
        if sharded:
            realm = jnp.asarray(src_np) < m.real_n
            vals = jnp.where(realm[:, None], vals, 0.0)
            idxs = jnp.where(realm[:, None], idxs, 0)
            touch = jnp.where(realm[:, None], touch, False)
            rows_parts.append(np.arange(start, start + sb, dtype=np.int64))
        else:
            vals, idxs, touch = vals[:real], idxs[:real], touch[:real]
            rows_parts.append(
                np.arange(start, start + real, dtype=np.int64))
        vals_parts.append(vals)
        idxs_parts.append(idxs)
        touch_parts.append(touch)

    rows = np.concatenate(rows_parts)
    new_index = m.index.replace_rows(
        rows, jnp.concatenate(vals_parts, axis=0),
        jnp.concatenate(idxs_parts, axis=0))
    new_touch = m.touch.replace_rows(
        rows, jnp.concatenate(touch_parts, axis=0))
    new_m = MaintainableIndex(
        index=new_index, touch=new_touch, key=m.key, params=p,
        real_n=m.real_n)
    report["rows_replaced"] = int(rows.size)
    return new_graph, new_m, report
