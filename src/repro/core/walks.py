"""Vectorized random-walk engine (the TPU rewrite of DrunkardMob).

DrunkardMob advances billions of walks by streaming the graph from disk and
moving the in-memory (vertex -> walks) map.  On TPU the same insight —
*advance all walks in bulk, never chase one walk* — becomes a dense cursor
array ``int32[W]`` advanced by a ``lax.scan``: one gather for the degrees,
one gather for the sampled out-edge, one scatter-add for the visit counts.
Walk state never leaves the device.

Termination follows the paper: at every position the walk teleports
(terminates) with probability ``c``; a walk sitting on a dangling vertex
jumps back to its personalization source (paper Section 2.1).  Walks are
capped at ``max_steps`` positions; the lost tail mass is ``(1-c)^max_steps``
(3e-5 at the default 64), far below Monte-Carlo noise at practical ``R``.

A single pass produces both estimators:

* **MCFP** (Algorithm 1): counts every visited position; normalize by total
  moves.
* **MCEP** (Algorithm 2, Fogaras et al.): counts only the final position;
  normalize by the number of walks.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import frontier as frontier_mod
from repro.core.graph import Graph

DEFAULT_C = 0.15


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WalkCounts:
    """Aggregated walk statistics grouped into ``rows`` source rows.

    fp_counts: f32[rows, n] full-path visit counts (MCFP numerator).
    ep_counts: f32[rows, n] end-point counts (MCEP numerator).
    moves:     f32[rows]    total counted positions per row (MCFP denom).
    walks:     f32[rows]    number of walks per row (MCEP denominator).
    """

    fp_counts: jax.Array
    ep_counts: jax.Array
    moves: jax.Array
    walks: jax.Array


def _one_step(
    graph: Graph, key: jax.Array, cursors: jax.Array, sources: jax.Array
) -> jax.Array:
    """Advance every walk one edge (dangling vertices jump to source)."""
    deg = jnp.take(graph.out_deg, cursors)
    lo = jnp.take(graph.row_ptr, cursors)
    off = jax.random.randint(
        key, cursors.shape, 0, jnp.maximum(deg, 1), dtype=jnp.int32
    )
    nxt = jnp.take(graph.col_idx, lo + off)
    return jnp.where(deg == 0, sources, nxt)


@functools.partial(
    jax.jit, static_argnames=("n_rows", "max_steps", "unroll")
)
def simulate_walks(
    graph: Graph,
    walk_sources: jax.Array,
    walk_rows: jax.Array,
    key: jax.Array,
    *,
    n_rows: int,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    unroll: int = 1,
) -> WalkCounts:
    """Run one walk per entry of ``walk_sources`` and aggregate counts.

    walk_sources: int32[W] start (= personalization) vertex of each walk.
    walk_rows:    int32[W] output row each walk accumulates into (so ``R``
                  walks of one source share a row).
    """
    w = walk_sources.shape[0]
    n = graph.n

    def body(carry, t):
        cursors, active, fp, ep, moves, walks_done = carry
        step_key = jax.random.fold_in(key, t)
        k_move, k_term = jax.random.split(step_key)
        af = active.astype(fp.dtype)
        # count current position (MCFP numerator + move counter)
        fp = fp.at[walk_rows, cursors].add(af)
        moves = moves.at[walk_rows].add(af)
        # teleport draw at this position
        terminate = active & (
            jax.random.uniform(k_term, cursors.shape) < c
        )
        tf = terminate.astype(ep.dtype)
        ep = ep.at[walk_rows, cursors].add(tf)
        walks_done = walks_done.at[walk_rows].add(tf)
        active = active & ~terminate
        cursors = _one_step(graph, k_move, cursors, walk_sources)
        return (cursors, active, fp, ep, moves, walks_done), ()

    init = (
        walk_sources,
        jnp.ones((w,), dtype=bool),
        jnp.zeros((n_rows, n), dtype=jnp.float32),
        jnp.zeros((n_rows, n), dtype=jnp.float32),
        jnp.zeros((n_rows,), dtype=jnp.float32),
        jnp.zeros((n_rows,), dtype=jnp.float32),
    )
    (cursors, active, fp, ep, moves, walks_done), _ = jax.lax.scan(
        body, init, jnp.arange(max_steps), unroll=unroll
    )
    # Walks still active after the cap: their current position is the
    # endpoint (truncation; tail mass (1-c)^max_steps).
    af = active.astype(ep.dtype)
    ep = ep.at[walk_rows, cursors].add(af)
    walks_done = walks_done.at[walk_rows].add(af)
    return WalkCounts(fp_counts=fp, ep_counts=ep, moves=moves, walks=walks_done)


def walks_for_sources(
    sources: jax.Array, r: int
) -> Tuple[jax.Array, jax.Array]:
    """Expand ``sources[int32[S]]`` into (walk_sources, walk_rows) with ``r``
    walks per source."""
    s = sources.shape[0]
    walk_sources = jnp.repeat(sources, r)
    walk_rows = jnp.repeat(jnp.arange(s, dtype=jnp.int32), r)
    return walk_sources, walk_rows


def sample_walk_lengths(
    key: jax.Array, w: int, c: float = DEFAULT_C, max_steps: int = 64
) -> jax.Array:
    """Walk lengths only (positions per walk) — used by property tests to
    check the geometric(c) law the theory relies on."""
    u = jax.random.uniform(key, (w, max_steps))
    alive = jnp.cumprod((u >= c).astype(jnp.int32), axis=1)
    return 1 + alive.sum(axis=1)


# ---------------------------------------------------------------------------
# Compacted sparse-sketch walk engine (the scalable offline path).
#
# Two structural fixes over ``simulate_walks``:
#
# * **Live-walk compaction**: walk length is geometric(c) with mean ``1/c``
#   (~6.7 at the default), so after ``t`` steps only ``(1-c)^t`` of the walk
#   slots are alive — a fixed-width scan over ``W`` slots for ``max_steps``
#   rounds spends >85% of its device steps moving dead walks.  Here the slot
#   array shrinks through a *static bucket schedule* derived from
#   ``(1-c)^t``: every ``compact_every`` steps the surviving cursors are
#   compacted into the low slots (``jnp.cumsum`` over the active mask — the
#   same compaction idiom as ``frontier.py``) and the working width drops to
#   the next bucket.  Device work tracks live walks, not ``W x max_steps``.
#
# * **Sparse count sketches**: the ``f32[rows, n]`` fp/ep accumulators
#   become per-row fixed-width top-``L`` sketches (the ``SparseFrontier``
#   idiom ``PPRIndex`` already uses): each round's visit events are folded
#   into the running sketch by sort-by-(row, vertex) + segment-sum
#   (:func:`repro.core.frontier.fold_topk`), so memory is ``O(rows * L)``
#   and the truncated mass is tracked exactly per row.
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseWalkCounts:
    """Sketched walk statistics grouped into ``rows`` source rows.

    fp: SparseFrontier[rows, L]  top-L visit-count sketch (MCFP numerator).
    ep: SparseFrontier[rows, Lp] top-Lp end-point sketch (MCEP numerator).
    moves:      f32[rows] counted positions per row (MCFP denominator).
    walks:      f32[rows] finished walks per row (MCEP denominator) —
                terminated + truncated; always exactly ``R`` per row.
    truncated:  f32[rows] walks cut short by the schedule (compaction
                overflow or the max_steps cap); their current position is
                counted as the endpoint, like the legacy engine's cap.
    fp_dropped: f32[rows] visit mass truncated out of the fp sketch.
    ep_dropped: f32[rows] endpoint mass truncated out of the ep sketch.

    Conservation (tested): ``fp.mass() + fp_dropped == moves`` and
    ``ep.mass() + ep_dropped == walks == R`` per row, exactly.
    """

    fp: frontier_mod.SparseFrontier
    ep: frontier_mod.SparseFrontier
    moves: jax.Array
    walks: jax.Array
    truncated: jax.Array
    fp_dropped: jax.Array
    ep_dropped: jax.Array
    # bool[rows, touch_bits] per-row "walks-through" Bloom filter over every
    # *counted* position (None unless ``touch_bits > 0``): the row's walks
    # only ever step *from* counted positions, so if no member vertex's
    # out-neighborhood changed, the row re-simulates bit-identically on the
    # updated graph — the invalidation sketch of ``core/updates.py``.
    touch: Optional[jax.Array] = None


def compaction_schedule(
    r: int,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
    margin: float = 1.35,
    floor: int = 8,
    lane: int = 8,
) -> Tuple[int, ...]:
    """Static per-round slot widths for the compacted engine.

    Round ``j`` covers steps ``[j * compact_every, (j+1) * compact_every)``
    and runs at width ``w_j = min(r, max(floor, margin * r * (1-c)^t_j))``
    rounded up to a ``lane`` multiple — the expected live-walk count at the
    round's first step with a safety margin.  Widths are non-increasing and
    start at exactly ``r`` (every walk launches in round 0).  Survivors that
    exceed a round's width (a ``margin`` tail event) are truncated to their
    endpoint and reported, so the schedule is a performance knob, never a
    correctness one.
    """
    if r <= 0:
        raise ValueError(f"r must be positive, got {r}")
    widths = []
    t = 0
    while t < max_steps:
        live = r * (1.0 - c) ** t
        w = int(math.ceil(margin * live))
        w = ((w + lane - 1) // lane) * lane
        w = min(r, max(floor, w)) if t else r
        widths.append(w)
        t += compact_every
    return tuple(widths)


def respawn_schedule(
    r: int,
    *,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
    margin: float = 1.35,
    width: int = 0,
    slack: float = 1.15,
    floor: int = 4,
    lane: int = 4,
    drain_eps: float = 0.02,
) -> Tuple[Tuple[int, ...], int]:
    """Static rounds for respawn-mode scheduling: ``(widths, total_steps)``.

    Instead of tracking the ``(1-c)^t`` decay with ever-narrower buckets
    (:func:`compaction_schedule`), respawn mode runs a *narrow fixed-width*
    slot array at ~100% occupancy: every step, slots freed by termination
    are refilled with fresh walks from each row's remaining quota (the
    DrunkardMob slot-reuse idea).  The schedule is then

    * ``launch`` rounds at the fixed width ``w0`` — enough rounds that the
      expected launches (``c * w0`` per step) cover the quota ``r - w0``
      with ``slack``; stragglers keep respawning into the drain, and any
      quota still unspent at the very end is flushed as length-1 walks
      (ledgered in ``truncated``), so every row still finishes exactly
      ``r`` walks;
    * a ``drain`` tail — :func:`compaction_schedule` decay from ``w0``,
      truncated once ``(1-c)^t`` falls below ``drain_eps`` (the same
      truncate-to-endpoint semantics as the ``max_steps`` cap).

    Device slots processed — and with them the engine's two real costs,
    scan steps and sketch-fold event columns — drop from ``sum_j w_j *
    compact_every`` (which the floor of the decay schedule dominates at
    small ``r``) to roughly ``slack * r / c`` plus one short drain
    staircase — the ≥2x positions/sec win
    ``benchmarks/bench_preprocess.py`` records.  ``width=0`` auto-derives
    ``w0 ~ r / 3`` (lane-rounded): wide enough that the quota launches in
    one or two rounds (fewer scan steps), narrow enough that the drain
    staircase stays a fraction of the launch area.  ``floor``/``lane``
    default to 4 — narrower than the decay schedule's 8 because the drain
    cohort here is one fixed-width slot row, not the full launch width
    (set ``lane=8`` on sublane-sensitive backends).
    """
    if r <= 0:
        raise ValueError(f"r must be positive, got {r}")
    w0 = width if width > 0 else int(math.ceil(r / 3))
    w0 = ((w0 + lane - 1) // lane) * lane
    w0 = min(r, max(floor, w0))
    quota = r - w0
    if quota > 0:
        per_round = max(c * w0 * compact_every, 1e-9)
        launch_rounds = int(math.ceil(slack * quota / per_round))
        # trace-size bound: the unrolled round loop must stay O(max_steps)
        # even under an explicitly narrow ``width`` (launch otherwise grows
        # as ~r/width rounds).  Quota the capped launch can't place mops up
        # during the drain or flushes as length-1 walks — ledgered, exact.
        launch_rounds = min(
            launch_rounds,
            int(math.ceil(4 * max_steps / max(compact_every, 1))),
        )
    else:
        launch_rounds = 0
    drain_target = int(math.ceil(math.log(drain_eps) / math.log(1.0 - c))) \
        if 0.0 < c < 1.0 else max_steps
    drain_steps = min(
        max_steps,
        ((max(drain_target, 1) + compact_every - 1) // compact_every)
        * compact_every,
    )
    drain = compaction_schedule(
        w0, c=c, max_steps=drain_steps, compact_every=compact_every,
        margin=margin, floor=floor, lane=lane,
    )
    widths = (w0,) * launch_rounds + drain
    return widths, launch_rounds * compact_every + drain_steps


def schedule_slot_area(
    widths: Tuple[int, ...], total_steps: int, compact_every: int = 8
) -> int:
    """Device slot-steps one source row spends on one pass of a schedule.

    Round ``j`` runs at width ``w_j`` for ``min(compact_every, total_steps -
    t0_j)`` steps (the last round may be ragged), so the area is
    ``sum_j w_j * steps_j`` — the quantity
    ``test_respawn_schedule_halves_device_work`` pins and the respawn-aware
    cost model (``index.preprocessing_cost_model``) prices walk state with.
    """
    area, t0 = 0, 0
    for w in widths:
        steps = min(compact_every, total_steps - t0)
        if steps <= 0:
            break
        area += w * steps
        t0 += steps
    return area


TOUCH_HASHES = 4


def touch_hash_bits(
    vertices: jax.Array, n_bits: int, k: int = TOUCH_HASHES
) -> jax.Array:
    """Bloom bit positions of each vertex id: ``vertices.shape + (k,)`` int32.

    ``k`` independent streams of a uint32 avalanche mix (fmix32 over the id
    xor a per-hash odd constant), reduced mod ``n_bits``.  Pure jnp so the
    walk engine can record bits on-device and ``core/updates.py`` can query
    membership with the *same* function on host arrays.
    """
    v = jnp.asarray(vertices).astype(jnp.uint32)
    outs = []
    for j in range(k):
        h = v ^ jnp.uint32((2 * j + 1) * 0x9E3779B9 & 0xFFFFFFFF)
        h = (h ^ (h >> 16)) * jnp.uint32(0x85EBCA6B)
        h = (h ^ (h >> 13)) * jnp.uint32(0xC2B2AE35)
        h = h ^ (h >> 16)
        outs.append((h % jnp.uint32(n_bits)).astype(jnp.int32))
    return jnp.stack(outs, axis=-1)


def sample_edge_offsets(u: jax.Array, deg: jax.Array) -> jax.Array:
    """Edge offset ``~ Uniform{0..deg-1}`` from ``u ~ U[0, 1)``.

    ``floor(u * deg)`` clipped into range — the one sampling law the jnp
    step, the Pallas ``walk_step`` launcher, and its oracle all share, so
    the kernel-routed engine is bit-identical to the jnp engine under the
    same key."""
    off = jnp.floor(u * deg.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(off, 0, jnp.maximum(deg - 1, 0))


def advance_cursors(
    graph: Graph,
    cursors: jax.Array,
    sources: jax.Array,
    u: jax.Array,
    *,
    use_kernel: bool = False,
    kernel_interpret: bool = True,
) -> jax.Array:
    """Advance every cursor one edge (dangling vertices jump to ``sources``).

    ``u`` is the pre-drawn uniform for the edge choice (see
    :func:`sample_edge_offsets`).  ``sources`` must broadcast against
    ``cursors``.  With ``use_kernel`` the degree-gather + edge-sample +
    dangling-fix run fused through the HBM-resident Pallas kernel
    (``repro.kernels.ops.walk_step``), bit-identical to the jnp path.
    """
    if graph.m == 0:  # every vertex dangling: all walks jump home
        return jnp.broadcast_to(sources, cursors.shape).astype(cursors.dtype)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.walk_step(
            cursors, jnp.broadcast_to(sources, cursors.shape), u,
            graph.row_ptr, graph.out_deg, graph.col_idx,
            interpret=kernel_interpret,
        )
    deg = jnp.take(graph.out_deg, cursors)
    lo = jnp.take(graph.row_ptr, cursors)
    addr = jnp.clip(lo + sample_edge_offsets(u, deg), 0, graph.m - 1)
    nxt = jnp.take(graph.col_idx, addr)
    return jnp.where(deg == 0, sources, nxt)


def _compact_slots(
    cursors: jax.Array, alive: jax.Array, w_new: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compact surviving cursors into the low slots of a width-``w_new`` row.

    Per row: rank survivors with ``cumsum`` over the active mask and scatter
    rank ``j`` into slot ``j`` (the ``frontier.py`` compaction idiom applied
    to walk state).  Survivors ranked past ``w_new`` overflow; their
    ``(weight, cursor)`` events are returned so the caller can truncate them
    to endpoints.  Returns ``(cursors[rows, w_new], alive[rows, w_new],
    overflow_w[rows, w_old], overflow_i[rows, w_old])``.
    """
    rows, w = cursors.shape
    rank = jnp.cumsum(alive.astype(jnp.int32), axis=1)       # 1-based
    keep = alive & (rank <= w_new)
    # park dropped/dead slots at a sentinel column that is sliced away
    tgt = jnp.where(keep, rank - 1, w_new)
    packed = jnp.zeros((rows, w_new + 1), cursors.dtype).at[
        jnp.arange(rows)[:, None], tgt
    ].set(jnp.where(keep, cursors, 0), mode="drop")
    n_kept = jnp.minimum(rank[:, -1], w_new)                 # [rows]
    new_alive = jnp.arange(w_new, dtype=jnp.int32)[None, :] < n_kept[:, None]
    over = alive & (rank > w_new)
    return (
        packed[:, :w_new],
        new_alive,
        over.astype(jnp.float32),
        jnp.where(over, cursors, 0),
    )


class _EventSketch:
    """Running top-``k`` sketch fed by buffered event segments.

    Folding (sort + segment-sum + top-k, :func:`frontier.fold_topk`) is the
    expensive primitive on every backend, so event segments queue in a
    pending list and one fold runs whenever the pending width reaches
    ``fold_width`` — the same stream-width batching idea as
    ``verd.sparse_push_compact``, applied across rounds.  Deferring folds is
    only ever *more* accurate (fewer intermediate truncations); the pending
    buffer bounds live memory at ``O(rows * (k + fold_width + one round's
    events))``.  With ``enabled=False`` nothing is sketched and every event
    lands in ``dropped`` (the MCFP-only builds skip the ep sketch this way).
    A trace-time helper: plain Python state, jnp math.
    """

    def __init__(self, rows: int, k: int, fold_width: int, enabled: bool = True):
        self.k = k
        self.enabled = enabled
        self.fold_width = fold_width
        self.values = jnp.zeros((rows, k), jnp.float32)
        self.indices = jnp.zeros((rows, k), jnp.int32)
        self.dropped = jnp.zeros((rows,), jnp.float32)
        self._pend_v: list = []
        self._pend_i: list = []
        self._pend_w = 0

    def add(self, ev_w: jax.Array, ev_i: jax.Array) -> None:
        """Queue an event segment ``[rows, w]`` (zero-weight slots fine)."""
        if not self.enabled:
            self.dropped = self.dropped + jnp.sum(ev_w, axis=1)
            return
        self._pend_v.append(ev_w)
        self._pend_i.append(ev_i)
        self._pend_w += ev_w.shape[1]
        if self._pend_w >= self.fold_width:
            self.flush()

    def flush(self) -> None:
        if not self._pend_w:
            return
        self.values, self.indices, d = frontier_mod.fold_topk(
            self.values, self.indices,
            jnp.concatenate(self._pend_v, axis=1),
            jnp.concatenate(self._pend_i, axis=1),
            self.k,
        )
        self.dropped = self.dropped + d
        self._pend_v, self._pend_i, self._pend_w = [], [], 0


@functools.partial(
    jax.jit,
    static_argnames=(
        "r", "l", "ep_l", "c", "max_steps", "compact_every", "margin",
        "fold_width", "use_kernel", "kernel_interpret", "respawn",
        "respawn_width", "touch_bits",
    ),
)
def simulate_walks_sparse(
    graph: Graph,
    sources: jax.Array,
    r: int,
    key: jax.Array,
    *,
    l: int,
    ep_l: Optional[int] = None,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    compact_every: int = 8,
    margin: float = 1.35,
    fold_width: int = 0,
    use_kernel: bool = False,
    kernel_interpret: bool = True,
    respawn: bool = False,
    respawn_width: int = 0,
    touch_bits: int = 0,
) -> SparseWalkCounts:
    """Run ``r`` walks per source through the compacted sparse-sketch engine.

    sources: int32[rows] personalization vertex of each output row (every
    walk of a row starts there — the :func:`walks_for_sources` layout, made
    structural).  ``l``/``ep_l`` are the fp/ep sketch widths; ``l >=``
    distinct visited vertices per row makes the fp sketch exact (an MCFP
    row from ``r`` walks has support ``<= moves ~ r/c``).  ``ep_l=0``
    disables endpoint sketching entirely (the MCFP-only index build), and
    symmetrically ``l=0`` disables the visit sketch (the MCEP-only
    estimate): the disabled sketch comes back width-1 empty and its whole
    event mass lands in the ``*_dropped`` ledger, so conservation still
    closes.  ``fold_width`` batches
    visit events across rounds before each sketch fold (0 = auto,
    ``max(4 * l, 512)``): larger folds cost fewer sorts *and* truncate less;
    live event memory stays ``O(rows * fold_width)``.

    One jit compilation per (shapes, schedule): the round loop is unrolled
    into a single device computation — per round one ``lax.scan`` of
    ``compact_every`` steps at that round's static width and one slot
    compaction, with sketch folds on the ``fold_width`` cadence.  Walks
    surviving ``max_steps`` total positions are truncated to endpoints
    exactly like the legacy engine's cap.

    ``respawn=True`` switches to respawn-mode scheduling
    (:func:`respawn_schedule`): a narrow fixed-width slot array (width
    ``respawn_width``, 0 = auto) runs at ~100% occupancy — every step,
    slots freed by termination refill with fresh walks from a per-row
    quota counter until all ``r`` walks of the row have launched, then the
    array drains through the usual decay/compaction tail.  Quota still
    unspent when the pass ends is flushed as length-1 walks (one counted
    position at the source — ledgered in ``truncated``), so the
    conservation identities close exactly in both modes.  In respawn mode
    ``max_steps`` caps the *drain* tail (the per-walk cap is enforced by
    the pass length rather than per slot; the geometric tail beyond it is
    the same ``(1-c)^t`` mass either way).

    ``touch_bits > 0`` additionally records a per-row Bloom filter
    (``bool[rows, touch_bits]``, :func:`touch_hash_bits` with
    ``TOUCH_HASHES`` hashes) over every counted position — the reverse
    "walks-through" sketch incremental index maintenance queries to find
    the rows an edge update invalidates.  Bloom membership has no false
    negatives, so a row whose filter misses every touched vertex is
    provably bit-stable under the update; false positives only cause
    harmless extra repair.
    """
    rows = sources.shape[0]
    n = graph.n
    l = min(l, n)
    ep_l = min(ep_l if ep_l is not None else l, n)
    track_fp = l > 0
    track_ep = ep_l > 0
    if fold_width <= 0:
        fold_width = max(4 * l, 512)
    if respawn:
        schedule, total_steps = respawn_schedule(
            r, c=c, max_steps=max_steps, compact_every=compact_every,
            margin=margin, width=respawn_width,
        )
    else:
        schedule = compaction_schedule(
            r, c=c, max_steps=max_steps, compact_every=compact_every,
            margin=margin,
        )
        total_steps = max_steps
    src32 = sources.astype(jnp.int32)
    src2d = src32[:, None]

    launched0 = min(r, schedule[0])
    cursors = jnp.broadcast_to(src2d, (rows, schedule[0])).astype(jnp.int32)
    alive = jnp.broadcast_to(
        jnp.arange(schedule[0], dtype=jnp.int32)[None, :] < launched0,
        (rows, schedule[0]),
    )
    quota = jnp.full((rows,), r - launched0, jnp.int32)
    fp = _EventSketch(rows, max(l, 1), fold_width, enabled=track_fp)
    ep = _EventSketch(rows, max(ep_l, 1), fold_width, enabled=track_ep)
    moves = jnp.zeros((rows,), jnp.float32)
    walks_done = jnp.zeros((rows,), jnp.float32)
    truncated = jnp.zeros((rows,), jnp.float32)
    track_touch = touch_bits > 0
    touch = jnp.zeros((rows, touch_bits), bool) if track_touch else None
    _touch_rows = jnp.arange(rows, dtype=jnp.int32)[:, None, None]

    def record_touch(tch, ev_i, ev_live):
        # set the k bloom bits of every live event's vertex; dead events are
        # parked at bit index ``touch_bits`` and dropped by the scatter
        bits = touch_hash_bits(ev_i, touch_bits)
        bits = jnp.where(ev_live[..., None], bits, touch_bits)
        return tch.at[_touch_rows, bits].set(True, mode="drop")

    def step_body(carry, xs):
        cursors, alive, quota, moves, walks_done = carry
        u_term, u_move = xs
        if respawn:
            # refill freed slots from the row quota: rank dead slots with a
            # cumsum (the _compact_slots idiom) and respawn the first
            # ``quota`` of them at the source — occupancy stays ~100%
            dead = ~alive
            rank = jnp.cumsum(dead.astype(jnp.int32), axis=1)  # 1-based
            spawn = dead & (rank <= quota[:, None])
            quota = quota - jnp.sum(spawn.astype(jnp.int32), axis=1)
            cursors = jnp.where(spawn, src2d, cursors)
            alive = alive | spawn
        af = alive.astype(jnp.float32)
        pos = cursors                      # position counted this step
        moves = moves + jnp.sum(af, axis=1)
        terminate = alive & (u_term < c)
        tf = terminate.astype(jnp.float32)
        walks_done = walks_done + jnp.sum(tf, axis=1)
        alive = alive & ~terminate
        nxt = advance_cursors(
            graph, cursors, src2d, u_move,
            use_kernel=use_kernel, kernel_interpret=kernel_interpret,
        )
        cursors = jnp.where(alive, nxt, cursors)
        return (cursors, alive, quota, moves, walks_done), (af, pos, tf)

    def per_row(ev):
        # [steps, rows, w] -> per-row event columns [rows, steps * w]
        return ev.transpose(1, 0, 2).reshape(rows, -1)

    def round_uniforms(t0, steps, w):
        """Pre-draw the round's step uniforms ``[steps, rows, w]`` in one
        batched RNG call: per step one (term, move) pair from the split of
        ``fold_in(key, t)`` — hoisting the threefry chains out of the scan
        body halves the fixed per-step cost the narrow respawn widths would
        otherwise be dominated by."""
        step_keys = jax.vmap(
            lambda t: jax.random.split(jax.random.fold_in(key, t))
        )(t0 + jnp.arange(steps))
        draw = jax.vmap(
            lambda k: jax.random.uniform(k, (rows, w))
        )
        return draw(step_keys[:, 0]), draw(step_keys[:, 1])

    t0 = 0
    for w in schedule:
        if w < cursors.shape[1]:
            cursors, alive, ov_w, ov_i = _compact_slots(cursors, alive, w)
            # overflow walks: truncate to endpoint (schedule tail event)
            n_over = jnp.sum(ov_w, axis=1)
            walks_done = walks_done + n_over
            truncated = truncated + n_over
            ep.add(ov_w, ov_i)
        # the last round may be ragged: never run past the step budget
        steps = min(compact_every, total_steps - t0)
        u_move, u_term = round_uniforms(t0, steps, w)
        (cursors, alive, quota, moves, walks_done), (vis_w, vis_i, term_w) = (
            jax.lax.scan(
                step_body, (cursors, alive, quota, moves, walks_done),
                (u_term, u_move),
            )
        )
        fp.add(per_row(vis_w), per_row(vis_i))
        ep.add(per_row(term_w), per_row(vis_i))
        if track_touch:
            touch = record_touch(touch, per_row(vis_i), per_row(vis_w) > 0)
        t0 += steps

    # step-budget cap: survivors' current position is the endpoint (the
    # same truncation as the legacy engine; tail mass ~ (1-c)^max_steps)
    af = alive.astype(jnp.float32)
    n_trunc = jnp.sum(af, axis=1)
    walks_done = walks_done + n_trunc
    truncated = truncated + n_trunc
    ep.add(af, jnp.where(alive, cursors, 0))
    if respawn:
        # quota the pass never got to launch: flush as length-1 walks (one
        # counted position at the source) so walks == R stays exact; a
        # slack-tail event, ledgered like any other truncation
        q_rem = quota.astype(jnp.float32)
        moves = moves + q_rem
        walks_done = walks_done + q_rem
        truncated = truncated + q_rem
        fp.add(q_rem[:, None], src2d)
        ep.add(q_rem[:, None], src2d)
        if track_touch:
            touch = record_touch(touch, src2d, q_rem[:, None] > 0)
    fp.flush()
    ep.flush()
    return SparseWalkCounts(
        fp=frontier_mod.SparseFrontier(
            values=fp.values, indices=fp.indices, k=max(l, 1), n=n
        ),
        ep=frontier_mod.SparseFrontier(
            values=ep.values, indices=ep.indices, k=max(ep_l, 1), n=n
        ),
        moves=moves,
        walks=walks_done,
        truncated=truncated,
        fp_dropped=fp.dropped,
        ep_dropped=ep.dropped,
        touch=touch,
    )


# ---------------------------------------------------------------------------
# Conservation-ledger export (crash-safe index builds)
# ---------------------------------------------------------------------------


class BuildLedger:
    """Host-side conservation ledger of a streaming index build.

    The builders (``index._build_index_sparse`` and the sharded segment
    loop) accumulate one kept/dropped estimate-mass entry per swept chunk
    and sum them once at the end.  Checkpointed builds additionally need
    the ledger *exportable* mid-sweep — committed with the partial index
    rows so a resumed run reproduces the uninterrupted run's final sums
    bitwise (same per-chunk f32 entries, same order, same one reduction).

    Entries may be device scalars (``jnp.sum`` per chunk), device vectors
    (per-row ledgers of a sharded segment), or restored numpy arrays — the
    export normalizes everything to one flat f32 host array per side.
    """

    def __init__(self):
        self._kept = []
        self._dropped = []

    def append(self, kept, dropped) -> None:
        self._kept.append(kept)
        self._dropped.append(dropped)

    def __len__(self) -> int:
        return len(self._kept)

    @property
    def empty(self) -> bool:
        return not self._kept

    def _flat(self, parts) -> jnp.ndarray:
        return jnp.concatenate(
            [jnp.asarray(p, jnp.float32).reshape(-1) for p in parts]
        )

    def export(self):
        """``(kept f32[entries], dropped f32[entries])`` host arrays — the
        checkpoint payload.  Exact: f32 values round-trip ``np.save``
        bit-for-bit."""
        import numpy as np
        if self.empty:
            z = np.zeros(0, np.float32)
            return z, z
        return (np.asarray(self._flat(self._kept)),  # contract: allow(host-sync): ledger totals, end of build
                np.asarray(self._flat(self._dropped)))  # contract: allow(host-sync): ledger totals, end of build

    @classmethod
    def restore(cls, kept, dropped) -> "BuildLedger":
        """Rebuild from exported arrays: one vector entry per side, so a
        resumed ledger's flattened stream equals the uninterrupted one."""
        led = cls()
        led.append(kept, dropped)
        return led

    def totals(self):
        """``(kept, dropped)`` floats: one ``jnp.sum`` over the flattened
        entry stream per side, a single host sync."""
        if self.empty:
            return 0.0, 0.0
        # contract: allow(host-sync): single end-of-build conservation sync
        kept, dropped = jax.device_get(
            (jnp.sum(self._flat(self._kept)),
             jnp.sum(self._flat(self._dropped)))
        )
        # contract: allow(host-sync): kept/dropped already on host (above)
        return float(kept), float(dropped)
