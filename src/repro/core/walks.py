"""Vectorized random-walk engine (the TPU rewrite of DrunkardMob).

DrunkardMob advances billions of walks by streaming the graph from disk and
moving the in-memory (vertex -> walks) map.  On TPU the same insight —
*advance all walks in bulk, never chase one walk* — becomes a dense cursor
array ``int32[W]`` advanced by a ``lax.scan``: one gather for the degrees,
one gather for the sampled out-edge, one scatter-add for the visit counts.
Walk state never leaves the device.

Termination follows the paper: at every position the walk teleports
(terminates) with probability ``c``; a walk sitting on a dangling vertex
jumps back to its personalization source (paper Section 2.1).  Walks are
capped at ``max_steps`` positions; the lost tail mass is ``(1-c)^max_steps``
(3e-5 at the default 64), far below Monte-Carlo noise at practical ``R``.

A single pass produces both estimators:

* **MCFP** (Algorithm 1): counts every visited position; normalize by total
  moves.
* **MCEP** (Algorithm 2, Fogaras et al.): counts only the final position;
  normalize by the number of walks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import Graph

DEFAULT_C = 0.15


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WalkCounts:
    """Aggregated walk statistics grouped into ``rows`` source rows.

    fp_counts: f32[rows, n] full-path visit counts (MCFP numerator).
    ep_counts: f32[rows, n] end-point counts (MCEP numerator).
    moves:     f32[rows]    total counted positions per row (MCFP denom).
    walks:     f32[rows]    number of walks per row (MCEP denominator).
    """

    fp_counts: jax.Array
    ep_counts: jax.Array
    moves: jax.Array
    walks: jax.Array


def _one_step(
    graph: Graph, key: jax.Array, cursors: jax.Array, sources: jax.Array
) -> jax.Array:
    """Advance every walk one edge (dangling vertices jump to source)."""
    deg = jnp.take(graph.out_deg, cursors)
    lo = jnp.take(graph.row_ptr, cursors)
    off = jax.random.randint(
        key, cursors.shape, 0, jnp.maximum(deg, 1), dtype=jnp.int32
    )
    nxt = jnp.take(graph.col_idx, lo + off)
    return jnp.where(deg == 0, sources, nxt)


@functools.partial(
    jax.jit, static_argnames=("n_rows", "max_steps", "unroll")
)
def simulate_walks(
    graph: Graph,
    walk_sources: jax.Array,
    walk_rows: jax.Array,
    key: jax.Array,
    *,
    n_rows: int,
    c: float = DEFAULT_C,
    max_steps: int = 64,
    unroll: int = 1,
) -> WalkCounts:
    """Run one walk per entry of ``walk_sources`` and aggregate counts.

    walk_sources: int32[W] start (= personalization) vertex of each walk.
    walk_rows:    int32[W] output row each walk accumulates into (so ``R``
                  walks of one source share a row).
    """
    w = walk_sources.shape[0]
    n = graph.n

    def body(carry, t):
        cursors, active, fp, ep, moves, walks_done = carry
        step_key = jax.random.fold_in(key, t)
        k_move, k_term = jax.random.split(step_key)
        af = active.astype(fp.dtype)
        # count current position (MCFP numerator + move counter)
        fp = fp.at[walk_rows, cursors].add(af)
        moves = moves.at[walk_rows].add(af)
        # teleport draw at this position
        terminate = active & (
            jax.random.uniform(k_term, cursors.shape) < c
        )
        tf = terminate.astype(ep.dtype)
        ep = ep.at[walk_rows, cursors].add(tf)
        walks_done = walks_done.at[walk_rows].add(tf)
        active = active & ~terminate
        cursors = _one_step(graph, k_move, cursors, walk_sources)
        return (cursors, active, fp, ep, moves, walks_done), ()

    init = (
        walk_sources,
        jnp.ones((w,), dtype=bool),
        jnp.zeros((n_rows, n), dtype=jnp.float32),
        jnp.zeros((n_rows, n), dtype=jnp.float32),
        jnp.zeros((n_rows,), dtype=jnp.float32),
        jnp.zeros((n_rows,), dtype=jnp.float32),
    )
    (cursors, active, fp, ep, moves, walks_done), _ = jax.lax.scan(
        body, init, jnp.arange(max_steps), unroll=unroll
    )
    # Walks still active after the cap: their current position is the
    # endpoint (truncation; tail mass (1-c)^max_steps).
    af = active.astype(ep.dtype)
    ep = ep.at[walk_rows, cursors].add(af)
    walks_done = walks_done.at[walk_rows].add(af)
    return WalkCounts(fp_counts=fp, ep_counts=ep, moves=moves, walks=walks_done)


def walks_for_sources(
    sources: jax.Array, r: int
) -> Tuple[jax.Array, jax.Array]:
    """Expand ``sources[int32[S]]`` into (walk_sources, walk_rows) with ``r``
    walks per source."""
    s = sources.shape[0]
    walk_sources = jnp.repeat(sources, r)
    walk_rows = jnp.repeat(jnp.arange(s, dtype=jnp.int32), r)
    return walk_sources, walk_rows


def sample_walk_lengths(
    key: jax.Array, w: int, c: float = DEFAULT_C, max_steps: int = 64
) -> jax.Array:
    """Walk lengths only (positions per walk) — used by property tests to
    check the geometric(c) law the theory relies on."""
    u = jax.random.uniform(key, (w, max_steps))
    alive = jnp.cumprod((u >= c).astype(jnp.int32), axis=1)
    return 1 + alive.sum(axis=1)
