"""Power-iteration baseline (paper Section 4's `PI`) and ground truth.

``p <- (1-c) * p A + c e_u`` with dangling rows of ``A`` pointing back at
each query's source (paper Section 2.1).  Batched over queries: one shared
push per iteration, same structure as VERD — which is why the paper can
compare them head-to-head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import Graph, transition_with_dangling
from repro.core.walks import DEFAULT_C


@functools.partial(jax.jit, static_argnames=("n_iter", "c"))
def power_iteration(
    graph: Graph,
    sources: jax.Array,
    *,
    n_iter: int = 100,
    c: float = DEFAULT_C,
) -> jax.Array:
    """Fixed-iteration batched PI; ``f32[Q, n]``.

    100 iterations leave residual mass ``(1-c)^100 ~ 9e-8`` — ground-truth
    grade for the accuracy benchmarks.
    """
    q = sources.shape[0]
    e_u = jnp.zeros((q, graph.n), dtype=jnp.float32).at[
        jnp.arange(q), sources
    ].set(1.0)
    p = e_u

    def body(p, _):
        p = (1.0 - c) * transition_with_dangling(graph, p, sources) + c * e_u
        return p, ()

    p, _ = jax.lax.scan(body, p, None, length=n_iter)
    return p


def exact_ppr_dense(graph: Graph, c: float = DEFAULT_C):
    """All-pairs exact PPR by direct solve (tiny graphs / oracles only).

    Solves ``p_u (I - (1-c) A_u) = c e_u`` per source with the per-source
    dangling adjustment; O(n^4) worst case — tests only.
    """
    import numpy as np

    n = graph.n
    out = np.zeros((n, n), dtype=np.float64)
    for u in range(n):
        a = graph.dense_transition(source=u)
        mat = np.eye(n) - (1.0 - c) * a.T
        out[u] = np.linalg.solve(mat, c * np.eye(n)[u])
    return out
