"""Vertex-Centric Decomposition (paper Algorithm 4 + Section 3.3 batching).

A batch of queries ``S`` keeps two row vectors per query; stacked they form
dense matrices ``F, S in R^{Q x n}`` and one VERD iteration is

    S <- S + c * F
    F <- (1 - c) * (F @ A)        (dangling rows of A -> each query's source)

i.e. one shared sparse-matrix product per iteration for the *whole batch* —
exactly the paper's "shared decomposition" that amortizes graph access
across queries, here realized as a single segment-sum push (or the Pallas
``ell_spmm`` kernel).  After ``T`` iterations the refined answer is

    p~ = S + F @ P_hat                     (P_hat = the top-L PPR index)

which is Algorithm 4 line 10.  ``recursive_decomp`` (Algorithm 3) is kept as
the oracle for the Theorem 2.3 equivalence tests.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, transition_with_dangling
from repro.core.index import PPRIndex
from repro.core.walks import DEFAULT_C


@functools.partial(jax.jit, static_argnames=("t", "c", "threshold"))
def verd_iterate(
    graph: Graph,
    sources: jax.Array,
    *,
    t: int,
    c: float = DEFAULT_C,
    threshold: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Run ``t`` VERD iterations for a batch of query vertices.

    Returns ``(s, f)``, both ``f32[Q, n]``.  ``threshold`` optionally drops
    tiny frontier entries (the paper's epsilon sparsification) — exactness
    tests use 0.0.
    """
    q = sources.shape[0]
    f = jnp.zeros((q, graph.n), dtype=jnp.float32)
    f = f.at[jnp.arange(q), sources].set(1.0)
    s = jnp.zeros_like(f)

    def body(carry, _):
        s, f = carry
        s = s + c * f
        f = (1.0 - c) * transition_with_dangling(graph, f, sources)
        if threshold > 0.0:
            f = jnp.where(f >= threshold, f, 0.0)
        return (s, f), ()

    (s, f), _ = jax.lax.scan(body, (s, f), None, length=t)
    return s, f


def combine_with_index(
    s: jax.Array,
    f: jax.Array,
    index: PPRIndex,
    *,
    vertex_chunk: int = 4096,
) -> jax.Array:
    """Algorithm 4 line 10: ``p~ = s + sum_v f(v) * p_hat_v``.

    Chunked over index rows so the ``[Q, chunk*L]`` scatter intermediate
    stays bounded; the Pallas ``index_combine`` kernel is the fused
    equivalent.
    """
    q, n = f.shape
    l = index.l
    n_chunks = (n + vertex_chunk - 1) // vertex_chunk
    pad_n = n_chunks * vertex_chunk
    vals = jnp.pad(index.values, ((0, pad_n - n), (0, 0)))
    idxs = jnp.pad(index.indices, ((0, pad_n - n), (0, 0)))
    f_pad = jnp.pad(f, ((0, 0), (0, pad_n - n)))
    vals = vals.reshape(n_chunks, vertex_chunk, l)
    idxs = idxs.reshape(n_chunks, vertex_chunk, l)
    f_chunks = f_pad.reshape(q, n_chunks, vertex_chunk).transpose(1, 0, 2)

    def body(acc, args):
        v, ix, fc = args  # [chunk, L], [chunk, L], [Q, chunk]
        contrib = fc[:, :, None] * v[None, :, :]      # [Q, chunk, L]
        acc = acc.at[:, ix.reshape(-1)].add(
            contrib.reshape(q, -1)
        )
        return acc, ()

    out, _ = jax.lax.scan(body, s, (vals, idxs, f_chunks))
    return out


def verd_query(
    graph: Graph,
    sources: jax.Array,
    index: Optional[PPRIndex],
    *,
    t: int,
    c: float = DEFAULT_C,
    threshold: float = 0.0,
) -> jax.Array:
    """Full online query: iterate then combine (index=None -> return s,
    the paper's R=0 mode)."""
    s, f = verd_iterate(graph, sources, t=t, c=c, threshold=threshold)
    if index is None:
        return s
    return combine_with_index(s, f, index)


# ---------------------------------------------------------------------------
# Algorithm 3 (recursive decomposition) — oracle for Theorem 2.3 tests.
# ---------------------------------------------------------------------------

def recursive_decomp(
    graph: Graph,
    u: int,
    t: int,
    base_vectors: np.ndarray,
    c: float = DEFAULT_C,
) -> np.ndarray:
    """Literal Algorithm 3 on host numpy.

    ``base_vectors[v]`` plays the role of the precomputed ``p_hat_v``; pass
    exact PPR vectors to check Theorem 2.2, or index rows for Theorem 2.3.
    Dangling vertices follow the paper's convention O(u) = {u}'s source --
    i.e. an artificial edge back to the *queried* vertex; since recursion
    re-roots at each vertex, the artificial edge of a dangling v points at
    the recursion root v itself (p_v = e_v for dangling v).
    """
    if t == 0:
        return np.asarray(base_vectors[u], dtype=np.float64)
    out_nbrs = graph.out_neighbors(u)
    n = graph.n
    e_u = np.zeros(n, dtype=np.float64)
    e_u[u] = 1.0
    if len(out_nbrs) == 0:
        # dangling: artificial self-edge => p_u solves p = c e_u + (1-c) p
        return e_u
    acc = np.zeros(n, dtype=np.float64)
    for v in out_nbrs:
        acc += recursive_decomp(graph, int(v), t - 1, base_vectors, c)
    return c * e_u + (1.0 - c) / len(out_nbrs) * acc
