"""Vertex-Centric Decomposition (paper Algorithm 4 + Section 3.3 batching).

A batch of queries ``S`` keeps two row vectors per query; stacked they form
dense matrices ``F, S in R^{Q x n}`` and one VERD iteration is

    S <- S + c * F
    F <- (1 - c) * (F @ A)        (dangling rows of A -> each query's source)

i.e. one shared sparse-matrix product per iteration for the *whole batch* —
exactly the paper's "shared decomposition" that amortizes graph access
across queries, here realized as a single segment-sum push (or the Pallas
``ell_spmm`` kernel).  After ``T`` iterations the refined answer is

    p~ = S + F @ P_hat                     (P_hat = the top-L PPR index)

which is Algorithm 4 line 10.  ``recursive_decomp`` (Algorithm 3) is kept as
the oracle for the Theorem 2.3 equivalence tests.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier
from repro.core.graph import (Graph, transition_with_dangling,
                              transition_with_dangling_seeds)
from repro.core.index import PPRIndex
from repro.core.walks import DEFAULT_C


# ---------------------------------------------------------------------------
# Weighted seed sets.  VERD is linear in its start vector, so a *seed-set*
# query (the shape real PPR consumers issue: personalize over a weighted set
# of vertices, not one source) is the same iterate seeded with a weighted
# one-hot row instead of a single 1.0.  Everywhere below, ``sources`` may be
#
# * ``int32[Q]``            — the classic single-vertex batch (weights None),
# * ``int32[Q, S]`` + ``seed_weights f32[Q, S]`` — one weighted seed set per
#   query row, padded to a stable width ``S`` with weight-0 slots.
#
# Dangling convention: a single-vertex query returns dangling mass to its
# source (paper Section 2.1); a seed-set query returns it to the query's
# *normalized seed distribution* (restart-vector semantics).  On supports
# that reach no dangling vertex the seed-set answer is exactly the weighted
# sum of the single-vertex answers (the linearity the serving cache relies
# on); with dangling flow the two differ only in where the reclaimed mass
# restarts, bounded by the per-seed dangling-mass variation.
# ---------------------------------------------------------------------------

def dangling_seed_candidates(
    dm: jax.Array,
    sources: jax.Array,
    seed_weights: Optional[jax.Array],
    *,
    c: float,
) -> Tuple[jax.Array, jax.Array]:
    """Sparse candidates returning dangling mass ``dm f32[Q]`` to the seeds.

    Single-vertex (``seed_weights is None``): one ``(1-c)*dm`` candidate at
    each query's source — the historical last slot.  Seed sets: ``S``
    candidates splitting ``(1-c)*dm`` proportionally to the normalized
    weights (weight-0 pad slots emit weight-0 candidates, which compact
    away).  Shared by every sparse push so the one-shot and streamed paths
    stay bit-identical.
    """
    if seed_weights is None:
        return (
            (1.0 - c) * dm[:, None],
            sources.reshape(-1, 1).astype(jnp.int32),
        )
    wsum = jnp.maximum(jnp.sum(seed_weights, axis=1, keepdims=True), 1e-30)
    share = dm[:, None] * (seed_weights / wsum)
    return (1.0 - c) * share, sources.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("t", "c", "threshold"))
def verd_iterate(
    graph: Graph,
    sources: jax.Array,
    seed_weights: Optional[jax.Array] = None,
    *,
    t: int,
    c: float = DEFAULT_C,
    threshold: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Run ``t`` VERD iterations for a batch of query vertices.

    Returns ``(s, f)``, both ``f32[Q, n]``.  ``threshold`` optionally drops
    tiny frontier entries (the paper's epsilon sparsification) — exactness
    tests use 0.0.  With ``seed_weights`` (see the seed-set note above),
    ``sources int32[Q, S]`` seeds each row with its weighted one-hot
    combination and dangling mass restarts at the seed distribution.
    """
    q = sources.shape[0]
    if seed_weights is None:
        f = jnp.zeros((q, graph.n), dtype=jnp.float32)
        f = f.at[jnp.arange(q), sources].set(1.0)
    else:
        # .add, not .set: duplicate seeds within a row sum their weights
        f = jnp.zeros((q, graph.n), dtype=jnp.float32)
        f = f.at[jnp.arange(q)[:, None], sources].add(seed_weights)
    s = jnp.zeros_like(f)

    def body(carry, _):
        s, f = carry
        s = s + c * f
        if seed_weights is None:
            f = (1.0 - c) * transition_with_dangling(graph, f, sources)
        else:
            f = (1.0 - c) * transition_with_dangling_seeds(
                graph, f, sources, seed_weights
            )
        if threshold > 0.0:
            f = jnp.where(f >= threshold, f, 0.0)
        return (s, f), ()

    (s, f), _ = jax.lax.scan(body, (s, f), None, length=t)
    return s, f


def combine_with_index(
    s: jax.Array,
    f: jax.Array,
    index: PPRIndex,
    *,
    vertex_chunk: int = 4096,
) -> jax.Array:
    """Algorithm 4 line 10: ``p~ = s + sum_v f(v) * p_hat_v``.

    Chunked over index rows so the ``[Q, chunk*L]`` scatter intermediate
    stays bounded; the Pallas ``index_combine`` kernel is the fused
    equivalent.
    """
    q, n = f.shape
    l = index.l
    n_chunks = (n + vertex_chunk - 1) // vertex_chunk
    pad_n = n_chunks * vertex_chunk
    # a sharded/padded index may carry extra all-zero rows (index.n >= n);
    # the dense frontier can only touch the first n, so slice before padding
    vals = jnp.pad(index.values[:n], ((0, pad_n - n), (0, 0)))
    idxs = jnp.pad(index.indices[:n], ((0, pad_n - n), (0, 0)))
    f_pad = jnp.pad(f, ((0, 0), (0, pad_n - n)))
    vals = vals.reshape(n_chunks, vertex_chunk, l)
    idxs = idxs.reshape(n_chunks, vertex_chunk, l)
    f_chunks = f_pad.reshape(q, n_chunks, vertex_chunk).transpose(1, 0, 2)

    def body(acc, args):
        v, ix, fc = args  # [chunk, L], [chunk, L], [Q, chunk]
        contrib = fc[:, :, None] * v[None, :, :]      # [Q, chunk, L]
        acc = acc.at[:, ix.reshape(-1)].add(
            contrib.reshape(q, -1)
        )
        return acc, ()

    out, _ = jax.lax.scan(body, s, (vals, idxs, f_chunks))
    return out


def verd_query(
    graph: Graph,
    sources: jax.Array,
    index: Optional[PPRIndex],
    *,
    t: int,
    c: float = DEFAULT_C,
    threshold: float = 0.0,
    seed_weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Full online query: iterate then combine (index=None -> return s,
    the paper's R=0 mode).  ``seed_weights`` switches ``sources`` to
    weighted seed-set rows (see the seed-set note at the top)."""
    s, f = verd_iterate(
        graph, sources, seed_weights, t=t, c=c, threshold=threshold
    )
    if index is None:
        return s
    return combine_with_index(s, f, index)


# ---------------------------------------------------------------------------
# Sparse-frontier path: Q x K state instead of Q x n (see core/frontier.py).
# ---------------------------------------------------------------------------

def resolve_degree_cap(graph: Graph) -> int:
    """Max out-degree — the per-slot edge budget that makes the sparse push
    exact.  Must run outside jit (it materializes a device scalar)."""
    if graph.n == 0 or graph.m == 0:
        return 1
    # contract: allow(host-sync): one-time per-graph scalar, cached by every
    # caller (BatchQueryEngine.degree_cap) — never on the per-query path
    return max(int(jax.device_get(jnp.max(graph.out_deg))), 1)


def resolve_hub_splits(degree_cap: int, hub_split_degree: int) -> Tuple[int, int]:
    """ELL-style row-splitting geometry for the sparse push.

    Returns ``(h, s)``: each frontier slot expands into ``s`` sub-slots of
    gather width ``h`` (``s * h >= degree_cap``, so the split push is exact).
    ``hub_split_degree <= 0`` (or ``>= degree_cap``) disables splitting
    (``s == 1``, ``h == degree_cap``).
    """
    if hub_split_degree <= 0 or hub_split_degree >= degree_cap:
        return degree_cap, 1
    h = hub_split_degree
    return h, (degree_cap + h - 1) // h


def push_window_starts(
    start: jax.Array,
    *,
    degree_cap: int,
    hub_split_degree: int = 0,
    m: int,
) -> jax.Array:
    """Clipped per-sub-slot gather-window starts, ``int32[Q, K, s]``.

    Sub-slot ``j`` of a frontier slot owns edges ``[j*h, (j+1)*h)`` of its
    CSR row, so its fixed-width-``h`` gather window starts at ``start +
    j*h``.  Windows are clipped to ``[0, m - h]`` so that reading ``h``
    consecutive entries — a ``jnp.take`` on the jnp path, an HBM DMA in the
    Pallas kernels — never leaves ``col_idx``; every in-budget edge still
    lands inside its (possibly shifted) window, and
    :func:`masked_push_from_windows` compensates for the shift when masking.
    These are exactly the scalar-prefetched offsets the DMA kernels consume.
    Requires ``h <= m`` (guaranteed once ``degree_cap <= m``; no row has
    more than ``m`` edges, so clamping the cap to ``m`` is a no-op).
    """
    h, s = resolve_hub_splits(degree_cap, hub_split_degree)
    st = start[..., None] + h * jnp.arange(s, dtype=jnp.int32)
    return jnp.clip(st, 0, max(m - h, 0))


def masked_push_from_windows(
    fv: jax.Array,
    deg: jax.Array,
    start: jax.Array,
    windows: jax.Array,
    gathered: jax.Array,
    *,
    c: float,
    degree_cap: int,
    hub_split_degree: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Mask fixed-width gather windows into push candidates.

    ``windows int32[Q, K, s]`` are the clipped starts from
    :func:`push_window_starts`; ``gathered int32[Q, K, s, h]`` holds
    ``col_idx[windows + j]`` however it was read (jnp gather or kernel DMA —
    this function is the math both share).  Element ``j`` of a window whose
    clip shifted it down by ``d = start + s_i*h - window`` corresponds to
    edge offset ``s_i*h + (j - d)`` of the row; it is a real pushed edge iff
    ``j >= d`` and that offset is within ``budget = min(deg, degree_cap)``
    (the same tail-truncation as the unsplit gather).  For untouched windows
    ``d == 0`` and this reduces to the plain ``eoff < budget`` mask.

    Returns ``(push_v, nbrs)`` of width ``K * s * h``; weights are
    ``(1 - c) * fv / deg`` on valid lanes, empty slots ``(0.0, 0)``.
    """
    q, k = fv.shape
    h, s = resolve_hub_splits(degree_cap, hub_split_degree)
    sub = h * jnp.arange(s, dtype=jnp.int32)                  # [s]
    d = (start[..., None] + sub - windows)[..., None]         # [Q, K, s, 1]
    j = jnp.arange(h, dtype=jnp.int32)[None, None, None, :]   # [1, 1, 1, h]
    eoff = sub[None, None, :, None] + (j - d)                 # [Q, K, s, h]
    budget = jnp.minimum(deg, degree_cap)[..., None, None]
    valid = (j >= d) & (eoff < budget)
    nbrs = jnp.where(valid, gathered, 0)
    inv = 1.0 / jnp.maximum(deg[..., None, None].astype(jnp.float32), 1.0)
    push_v = jnp.where(valid, (1.0 - c) * fv[..., None, None] * inv, 0.0)
    return push_v.reshape(q, k * s * h), nbrs.reshape(q, k * s * h)


def gather_push_edges(
    fv: jax.Array,
    fi: jax.Array,
    start: jax.Array,
    deg: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    hub_split_degree: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Edge gather shared by the single-device and sharded pushes.

    ``start``/``deg`` are the per-slot CSR offsets and out-degrees
    (``[Q, K]``, already gathered by the caller — the single-device path
    reads the global CSR, the sharded path its local slab).  With hub
    splitting (``hub_split_degree > 0``) each frontier slot becomes ``s =
    ceil(degree_cap / h)`` ELL-style sub-slots of gather width ``h``: a hub
    vertex simply occupies several sub-slots (sub-slot ``j`` owns edges
    ``[j*h, (j+1)*h)`` of its row), so no single gather axis is ever wider
    than ``h``.  Splitting moves mass between sub-slots only — the flat
    candidate multiset is identical to the unsplit gather (tested in
    ``test_properties.py``).

    Implemented as :func:`push_window_starts` + a window gather +
    :func:`masked_push_from_windows` — the same three steps the DMA kernels
    in ``repro.kernels`` run, with the ``jnp.take`` swapped for an HBM DMA.

    Returns ``(push_v, nbrs)`` of width ``K * s * h``; ``nbrs`` are the
    ``col_idx`` destination ids, weights ``(1-c) * fv / deg``.
    """
    m = col_idx.shape[0]
    degree_cap = min(degree_cap, max(m, 1))  # no row has more than m edges
    h, _ = resolve_hub_splits(degree_cap, hub_split_degree)
    windows = push_window_starts(
        start, degree_cap=degree_cap, hub_split_degree=hub_split_degree, m=m
    )
    eidx = windows[..., None] + jnp.arange(h, dtype=jnp.int32)
    gathered = jnp.take(col_idx, eidx)                        # [Q, K, s, h]
    return masked_push_from_windows(
        fv, deg, start, windows, gathered,
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )


def gather_push_candidates(
    fv: jax.Array,
    fi: jax.Array,
    sources: jax.Array,
    row_ptr: jax.Array,
    out_deg: jax.Array,
    col_idx: jax.Array,
    *,
    c: float,
    degree_cap: int,
    hub_split_degree: int = 0,
    seed_weights: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Array-level gather push shared by the core op and the Pallas kernel
    body (``kernels/frontier_push.py``); see :func:`sparse_push_candidates`
    for semantics.  Requires ``col_idx`` non-empty."""
    start = jnp.take(row_ptr, fi)                     # [Q, K]
    deg = jnp.take(out_deg, fi)                       # [Q, K]
    push_v, nbrs = gather_push_edges(
        fv, fi, start, deg, col_idx,
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )
    dm = jnp.sum(jnp.where(deg == 0, fv, 0.0), axis=1)  # dangling mass [Q]
    dang_v, dang_i = dangling_seed_candidates(dm, sources, seed_weights, c=c)
    cand_v = jnp.concatenate([push_v, dang_v], axis=1)
    cand_i = jnp.concatenate([nbrs, dang_i], axis=1)
    return cand_v, cand_i


def sparse_push_candidates(
    graph: Graph,
    fv: jax.Array,
    fi: jax.Array,
    sources: jax.Array,
    *,
    c: float = DEFAULT_C,
    degree_cap: int,
    hub_split_degree: int = 0,
    seed_weights: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """One VERD push ``(1-c) * f @ A`` in sparse form, uncompacted.

    For each frontier slot ``(q, j)`` holding mass ``fv`` at vertex ``fi``,
    gathers up to ``degree_cap`` out-edges from CSR and emits one candidate
    per edge; dangling mass returns to each query's source (last slot) —
    or, with ``seed_weights``, to the query's weighted seed set (last ``S``
    slots, :func:`dangling_seed_candidates`).
    Returns ``(cand_v, cand_i)`` of width ``K * degree_cap + 1`` (``+ S``
    for seed sets; ``K * s * h`` with hub splitting, see
    :func:`gather_push_edges`) — callers dedup + top-K compact
    (``frontier.compact``).

    ``degree_cap`` below the max out-degree of any *frontier* vertex drops
    the tail edges of that vertex (mass ``fv * (deg - cap) / deg``); with
    ``degree_cap >= max out-degree`` the push is exact.  ``hub_split_degree``
    changes only the gather geometry (hub rows split across sub-slots), not
    the pushed mass.
    """
    if graph.m == 0:  # every vertex dangling: all mass returns to the seeds
        dm = jnp.sum(fv, axis=1)
        return dangling_seed_candidates(dm, sources, seed_weights, c=c)
    return gather_push_candidates(
        fv, fi, sources, graph.row_ptr, graph.out_deg, graph.col_idx,
        c=c, degree_cap=degree_cap, hub_split_degree=hub_split_degree,
        seed_weights=seed_weights,
    )


def sparse_push_compact(
    graph: Graph,
    fv: jax.Array,
    fi: jax.Array,
    sources: jax.Array,
    *,
    c: float = DEFAULT_C,
    degree_cap: int,
    k_out: int,
    hub_split_degree: int = 0,
    threshold: float = 0.0,
    stream_width: int = 0,
    seed_weights: Optional[jax.Array] = None,
) -> frontier.SparseFrontier:
    """One VERD push + compaction with bounded live candidate width.

    Semantically :func:`sparse_push_candidates` followed by
    :func:`frontier.compact`, but when the one-shot candidate tensor
    (width ``K * s * h`` ~= ``K * degree_cap``) would dwarf the compacted
    result, the gather is streamed in frontier-slot chunks, each folded
    into a running top-``k_out`` state — live width stays
    ``O(max(stream target, one slot's s*h) + k_out)`` instead of
    ``O(K * degree_cap)``.  This is what makes the relaxed hub auto-route
    guard safe on the single-device path: one hub slot's gather is at most
    ``degree_cap < n`` entries, and only one chunk of slots is live at a
    time, never the K-fold product.  Exact (equal to the one-shot path, up
    to f32 merge rounding) whenever ``k_out`` covers the merged row
    support; below that, every fold truncates by rank like any other
    top-K here, so mass is only dropped and the drift stays bounded by the
    dropped mass.  ``stream_width`` overrides the live-width target
    (0 = auto: ``max(4 * k_out, one slot, 4096)``).
    """
    q, k = fv.shape
    m = graph.m
    # seed-set queries emit S dangling candidates instead of 1 (see
    # dangling_seed_candidates) — the one-shot width grows accordingly
    s_width = 1 if seed_weights is None else int(seed_weights.shape[1])
    if m == 0:  # all-dangling: S candidates per row, nothing to stream
        cv, ci = sparse_push_candidates(
            graph, fv, fi, sources, c=c, degree_cap=degree_cap,
            seed_weights=seed_weights,
        )
        return frontier.compact(
            cv, ci, min(k_out, cv.shape[1]), graph.n, threshold=threshold
        )
    cap = min(degree_cap, max(m, 1))
    h, s = resolve_hub_splits(cap, hub_split_degree)
    slot_w = s * h
    out_w = min(k_out, k * slot_w + s_width)  # same width as one-shot path
    target = stream_width if stream_width > 0 else max(
        4 * out_w, slot_w, 4096
    )
    if k * slot_w + s_width <= 2 * target:    # narrow enough: one-shot
        cv, ci = sparse_push_candidates(
            graph, fv, fi, sources, c=c, degree_cap=degree_cap,
            hub_split_degree=hub_split_degree, seed_weights=seed_weights,
        )
        return frontier.compact(cv, ci, out_w, graph.n, threshold=threshold)
    slots = max(1, target // slot_w)
    # pad the slot axis to a chunk multiple: pad slots carry fv == 0, so
    # their (masked) candidates have zero weight and compact away
    pad = (-k) % slots
    fv_p = jnp.pad(fv, ((0, 0), (0, pad)))
    fi_p = jnp.pad(fi, ((0, 0), (0, pad)))
    start = jnp.take(graph.row_ptr, fi_p)
    deg = jnp.take(graph.out_deg, fi_p)
    n_chunks = (k + pad) // slots
    chunk = lambda x: x.reshape(q, n_chunks, slots).transpose(1, 0, 2)
    # dangling mass seeds the running state (the one-shot path's last
    # slot(s)); duplicate seed candidates dedup-merge on the first fold
    dm = jnp.sum(jnp.where(deg == 0, fv_p, 0.0), axis=1)
    dang_v, dang_i = dangling_seed_candidates(dm, sources, seed_weights, c=c)
    run_v, run_i = frontier.topk_compact(dang_v, dang_i, out_w)

    def fold(carry, xs):
        rv, ri = carry
        cfv, cfi, cst, cdg = xs
        pv, nb = gather_push_edges(
            cfv, cfi, cst, cdg, graph.col_idx, c=c, degree_cap=degree_cap,
            hub_split_degree=hub_split_degree,
        )
        # mid-stream compaction truncates by rank only; the epsilon
        # threshold applies once at the end, like the one-shot path
        rv, ri, _ = frontier.fold_topk(rv, ri, pv, nb, out_w)
        return (rv, ri), ()

    (run_v, run_i), _ = jax.lax.scan(
        fold, (run_v, run_i),
        (chunk(fv_p), chunk(fi_p), chunk(start), chunk(deg)),
    )
    if threshold > 0.0:
        run_v = frontier.threshold_values(run_v, threshold)
        run_v, run_i = frontier.topk_compact(run_v, run_i, out_w)
    return frontier.SparseFrontier(
        values=run_v, indices=run_i, k=out_w, n=graph.n
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "t", "k", "c", "threshold", "degree_cap", "hub_split_degree"
    ),
)
def _verd_iterate_sparse(
    graph: Graph,
    sources: jax.Array,
    seed_weights: Optional[jax.Array] = None,
    *,
    t: int,
    k: int,
    c: float,
    threshold: float,
    degree_cap: int,
    hub_split_degree: int,
) -> Tuple[frontier.SparseFrontier, frontier.SparseFrontier]:
    q = sources.shape[0]
    if seed_weights is None:
        f = frontier.from_sources(sources, graph.n)
    else:
        f = frontier.from_seed_sets(sources, seed_weights, graph.n)
    s_vals, s_idxs = [], []
    for _ in range(t):
        s_vals.append(c * f.values)
        s_idxs.append(f.indices)
        f = sparse_push_compact(
            graph, f.values, f.indices, sources, c=c, k_out=k,
            degree_cap=degree_cap, hub_split_degree=hub_split_degree,
            threshold=threshold, seed_weights=seed_weights,
        )
    if s_vals:
        sv = jnp.concatenate(s_vals, axis=1)
        si = jnp.concatenate(s_idxs, axis=1)
        s = frontier.compact(sv, si, min(sv.shape[1], graph.n), graph.n)
    else:  # t == 0: s is empty
        s = frontier.SparseFrontier(
            values=jnp.zeros((q, 1), jnp.float32),
            indices=jnp.zeros((q, 1), jnp.int32),
            k=1, n=graph.n,
        )
    return s, f


def verd_iterate_sparse(
    graph: Graph,
    sources: jax.Array,
    seed_weights: Optional[jax.Array] = None,
    *,
    t: int,
    k: int,
    c: float = DEFAULT_C,
    threshold: float = 0.0,
    degree_cap: Optional[int] = None,
    hub_split_degree: int = 0,
) -> Tuple[frontier.SparseFrontier, frontier.SparseFrontier]:
    """Sparse-frontier VERD: ``t`` iterations holding ``Q x K`` state.

    Per iteration: one ``col_idx`` gather + segment-sum over ``Q * K *
    degree_cap`` candidate edges instead of the dense ``[Q, n] @ A`` — the
    win is ``O(Q * K * deg)`` vs ``O(Q * m)`` work and ``Q*K*8`` vs ``Q*n*8``
    bytes of state.  Exact (equal to :func:`verd_iterate` densified) whenever
    ``k`` covers the frontier support and ``degree_cap`` covers the max
    out-degree; truncation drops at most the compacted-away mass per
    iteration.  ``hub_split_degree > 0`` splits hub adjacency rows across
    ELL-style sub-slots of width ``<= hub_split_degree`` (same result,
    regular gather tiles — see :func:`gather_push_edges`).

    Returns ``(s, f)`` as :class:`~repro.core.frontier.SparseFrontier`; the
    accumulated ``s`` keeps its natural (un-truncated) width ``<= 1 +
    (t-1)*k``.  ``seed_weights`` switches ``sources`` to weighted seed-set
    rows ``int32[Q, S]`` (see the seed-set note at the top): the initial
    frontier is the width-``S`` weighted seed frontier and dangling mass
    restarts at the seed distribution.
    """
    if degree_cap is None:
        degree_cap = resolve_degree_cap(graph)
    return _verd_iterate_sparse(
        graph, sources, seed_weights, t=t, k=k, c=c, threshold=threshold,
        degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )


def combine_candidates_from_rows(
    sv: jax.Array,
    si: jax.Array,
    fv: jax.Array,
    iv: jax.Array,
    ii: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Sparse-combine math on already-gathered index rows (``iv/ii [Q, K,
    L]``): scale by frontier mass, stack with the ``s`` entries.  Shared by
    the jnp gather below and the DMA kernel body (which reads the rows via
    HBM copies instead of ``jnp.take``).  Uncompacted width ``S + K*L``."""
    q = fv.shape[0]
    contrib = fv[..., None] * iv
    cand_v = jnp.concatenate([sv, contrib.reshape(q, -1)], axis=1)
    cand_i = jnp.concatenate([si, ii.reshape(q, -1)], axis=1)
    return cand_v, cand_i


def gather_combine_candidates(
    sv: jax.Array,
    si: jax.Array,
    fv: jax.Array,
    fi: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Array-level sparse combine shared by the core op and the Pallas
    kernel oracle path: gather the touched index rows, scale by frontier
    mass, stack with the ``s`` entries.  Uncompacted width ``S + K*L``."""
    iv = jnp.take(vals, fi, axis=0)                    # [Q, K, L]
    ii = jnp.take(idx, fi, axis=0)                     # [Q, K, L]
    return combine_candidates_from_rows(sv, si, fv, iv, ii)


def combine_with_index_sparse(
    s: frontier.SparseFrontier,
    f: frontier.SparseFrontier,
    index: PPRIndex,
    *,
    out_k: Optional[int] = None,
) -> frontier.SparseFrontier:
    """Algorithm 4 line 10 on sparse state: contract ``f[Q, K]`` against only
    the ``K`` touched index rows.

    Gathers ``index`` rows at ``f.indices`` (``[Q, K, L]``), scales by the
    frontier mass, merges with the ``s`` entries, and compacts to ``out_k``
    (default: exact, no truncation).  Work is ``O(Q * K * L)`` — independent
    of ``n``.
    """
    cand_v, cand_i = gather_combine_candidates(
        s.values, s.indices, f.values, f.indices,
        index.values, index.indices,
    )
    # compact pads narrow rows, so a requested out_k is always honored
    if out_k is None:
        out_k = min(cand_v.shape[1], index.n)
    return frontier.compact(cand_v, cand_i, out_k, index.n)


def combine_with_index_scatter(
    s: frontier.SparseFrontier,
    f: frontier.SparseFrontier,
    index: PPRIndex,
    *,
    out_k: int,
    n_cols: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Final combine via a dense ``[Q, n]`` scatter-add + ``lax.top_k``.

    Same candidate set as :func:`combine_with_index_sparse`, but duplicates
    are merged by scattering into one zeroed ``[Q, n]`` scratch instead of
    the sort-based ``frontier.compact`` — ``lax.top_k`` is a fast custom
    call while the compaction's comparator sorts dominate the whole query
    at serving widths (``S + K*L`` in the tens of thousands).  Exact:
    scatter-add merges duplicates just like the segment-sum, and slots the
    scatter never touched stay 0 and are masked to the ``(0.0, 0)`` empty
    convention.  The scratch costs ``Q * n * 4`` bytes *once* at the final
    combine only (iterations stay ``Q x K``), so callers gate on a memory
    budget (``query.SCATTER_COMBINE_BUDGET_BYTES``) and keep the
    n-independent sparse combine beyond it.
    """
    cand_v, cand_i = gather_combine_candidates(
        s.values, s.indices, f.values, f.indices,
        index.values, index.indices,
    )
    q = cand_v.shape[0]
    n = index.n if n_cols is None else n_cols
    dense = jnp.zeros((q, n), jnp.float32).at[
        jnp.arange(q)[:, None], cand_i
    ].add(cand_v, mode="drop")
    vals, idx = jax.lax.top_k(dense, min(out_k, n))
    idx = jnp.where(vals > 0, idx, 0).astype(jnp.int32)
    if out_k > n:  # honor the requested width like frontier.compact does
        pad = out_k - n
        vals = jnp.pad(vals, ((0, 0), (0, pad)))
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
    return vals, idx


def verd_query_sparse(
    graph: Graph,
    sources: jax.Array,
    index: Optional[PPRIndex],
    *,
    t: int,
    k: int,
    c: float = DEFAULT_C,
    threshold: float = 0.0,
    out_k: Optional[int] = None,
    degree_cap: Optional[int] = None,
    hub_split_degree: int = 0,
    seed_weights: Optional[jax.Array] = None,
) -> frontier.SparseFrontier:
    """Full online query on the sparse path; answers come back as a
    :class:`~repro.core.frontier.SparseFrontier` of width ``out_k`` with
    entries sorted descending — exactly the served top-k shape, no ``[Q, n]``
    materialization anywhere.  ``seed_weights`` switches ``sources`` to
    weighted seed-set rows (see the seed-set note at the top)."""
    s, f = verd_iterate_sparse(
        graph, sources, seed_weights, t=t, k=k, c=c, threshold=threshold,
        degree_cap=degree_cap, hub_split_degree=hub_split_degree,
    )
    if index is None:
        if out_k is not None:
            return frontier.compact(s.values, s.indices, out_k, graph.n)
        return s
    return combine_with_index_sparse(s, f, index, out_k=out_k)


# ---------------------------------------------------------------------------
# Algorithm 3 (recursive decomposition) — oracle for Theorem 2.3 tests.
# ---------------------------------------------------------------------------

def recursive_decomp(
    graph: Graph,
    u: int,
    t: int,
    base_vectors: np.ndarray,
    c: float = DEFAULT_C,
) -> np.ndarray:
    """Literal Algorithm 3 on host numpy.

    ``base_vectors[v]`` plays the role of the precomputed ``p_hat_v``; pass
    exact PPR vectors to check Theorem 2.2, or index rows for Theorem 2.3.
    Dangling vertices follow the paper's convention O(u) = {u}'s source --
    i.e. an artificial edge back to the *queried* vertex; since recursion
    re-roots at each vertex, the artificial edge of a dangling v points at
    the recursion root v itself (p_v = e_v for dangling v).
    """
    if t == 0:
        # contract: allow(host-sync): recursive_decomp is the float64 host
        # oracle the device paths are tested against
        return np.asarray(base_vectors[u], dtype=np.float64)
    out_nbrs = graph.out_neighbors(u)
    n = graph.n
    e_u = np.zeros(n, dtype=np.float64)
    e_u[u] = 1.0
    if len(out_nbrs) == 0:
        # dangling: artificial self-edge => p_u solves p = c e_u + (1-c) p
        return e_u
    acc = np.zeros(n, dtype=np.float64)
    for v in out_nbrs:
        acc += recursive_decomp(graph, int(v), t - 1, base_vectors, c)
    return c * e_u + (1.0 - c) / len(out_nbrs) * acc
