"""Deliberate contract violations for tests/test_analysis.py.

Each module here is a minimal counter-example for one auditor rule —
imported (jaxpr fixtures) or parsed (lint fixtures) by the analyzer
tests, never by production code.  Lines carrying a violation are tagged
with a ``# [viol:<kind>]`` marker so the tests can assert the reported
file:line anchors without hardcoding line numbers.
"""
