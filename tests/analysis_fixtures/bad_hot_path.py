"""host-sync violations in a fake dispatch/harvest loop, plus one
correctly-suppressed sync and one allow() missing its justification."""

import numpy as np
import jax.numpy as jnp


def drain_and_dispatch(batch):
    if jnp.any(batch > 0):                       # [viol:truthiness]
        total = float(batch.sum())               # [viol:float]
        first = batch[0].item()                  # [viol:item]
        host = np.asarray(batch)                 # [viol:asarray]
        ready = bool(jnp.all(batch < 1.0))       # [viol:bool]
        return total, first, host, ready
    return 0.0, 0, None, False


def harvest(ticket):
    # contract: allow(host-sync): post-is_ready harvest; already resident
    good = np.asarray(ticket)                    # [ok:suppressed]
    # next line: allow() with no justification text -> still a finding
    bad = np.asarray(ticket)  # contract: allow(host-sync)
    return good, bad
