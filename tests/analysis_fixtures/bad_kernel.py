"""hbm-residency violation: a Pallas kernel that stages the whole CSR
``col_idx`` array into VMEM (default BlockSpec, no ``pltpu.ANY``) — the
exact layout the DMA-gather rebuild removed."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(col_ref, out_ref):
    out_ref[...] = col_ref[...]


def vmem_resident_gather(col_idx: jax.Array) -> jax.Array:
    """Pulls the full edge array through VMEM: both the operand and the
    result block are whole-array VMEM blocks of shape ``(m,)``."""
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(col_idx.shape, col_idx.dtype),
        interpret=True,
    )(col_idx)


def make_args(m: int = 4096):
    return (jnp.arange(m, dtype=jnp.int32),)
