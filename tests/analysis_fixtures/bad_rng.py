"""rng-discipline + bare-time violations: a builder that reuses a mutable
key chain (split stored into state), folds in data-dependent values, and
stamps wall-clock time into build artifacts."""

import time

import jax


class StatefulBuilder:
    def __init__(self, seed: int):
        self.key = jax.random.PRNGKey(seed)

    def next_key(self):
        # resume after chunk 7 replays a DIFFERENT key than the original
        # run saw — bitwise resume/repair silently breaks
        self.key, sub = jax.random.split(self.key)   # [viol:split-state]
        return sub

    def chunk_key(self, chunk_ids):
        return jax.random.fold_in(self.key, chunk_ids.sum())  # [viol:fold-data]

    def stamp(self):
        return time.time()                           # [viol:bare-time]
