"""no-replicated-index violation: a shard_map build step whose per-device
body materializes the full ``[n, L]`` index (replicated output spec) —
what a host-driven gather-then-broadcast build would trace."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def make_replicated_build_step(mesh, n: int, l: int):
    def local_fn(contrib):
        # every device holds (and returns) the whole [n, L] index
        dense = jnp.zeros((n, l), jnp.float32) + jnp.sum(contrib)
        return dense

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P("model", None),),
        out_specs=P(None, None),
        check_vma=False,
    )


def trace(n: int = 64, l: int = 16):
    mesh = jax.make_mesh((1,), ("model",))
    step = make_replicated_build_step(mesh, n, l)
    contrib = jnp.ones((8, 4), jnp.float32)
    return jax.make_jaxpr(step)(contrib)
