"""Subprocess body for the distributed-engine equivalence test.

Runs on 4 fake host devices (2 data x 2 model); compares the sharded
VERD tile step against the dense single-shard oracle.  Exits nonzero on
mismatch; tests/test_distributed_engine.py asserts the return code.
"""

import os
import warnings

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verd as verd_mod
from repro.core.distributed_engine import (
    DistConfig, build_sharded_graph, make_sparse_index_build_step,
    make_sparse_walk_counts_step, make_verd_tile_step, make_walk_counts_step,
)
from repro.core.index import build_index, build_index_sharded, index_from_dense
from repro.core.power_iteration import exact_ppr_dense
from repro.graphs import synthetic

from repro.analysis.jaxpr import assert_no_replicated_index, iter_eqns


def densify_rows(values, indices, n):
    """Private copy of the conftest scatter oracle (plain subprocess)."""
    values = np.asarray(values)
    out = np.zeros((values.shape[0], n), np.float32)
    np.add.at(
        out, (np.arange(values.shape[0])[:, None], np.asarray(indices)),
        values,
    )
    return out


def check_sharded_build(mesh):
    """ISSUE 5 acceptance gate: build_index_sharded == single-device
    engine="sparse" build under the same per-chunk keys (same fold order),
    with identical drop_fraction; per-device jaxpr holds no replicated
    [n, L] index arrays; a sharded index serves through the query engine."""
    from repro.core.query import BatchQueryEngine, QueryConfig

    key = jax.random.PRNGKey(3)
    g = synthetic.erdos_renyi(64, 4.0, seed=21)   # n == n_pad: exact grid
    # walk shards = the 2-wide data axis -> single-device r_splits=2
    for respawn in (False, True):
        for l in (64, 6):                          # covering + truncating
            sharded, st_sh = build_index_sharded(
                g, r=64, l=l, key=key, mesh=mesh, source_batch=16,
                respawn=respawn,
            )
            single, st_si = build_index(
                g, r=64, l=l, key=key, source_batch=16, r_splits=2,
                respawn=respawn,
            )
            got = densify_rows(
                np.asarray(sharded.values)[: g.n],
                np.asarray(sharded.indices)[: g.n], g.n,
            )
            want = densify_rows(single.values, single.indices, g.n)
            l1 = np.abs(got - want).sum(axis=1)
            assert l1.max() <= 1e-5, (respawn, l, l1.max())
            ddf = abs(st_sh["drop_fraction"] - st_si["drop_fraction"])
            assert ddf <= 1e-6, (respawn, l, ddf)
    print("sharded build parity OK (covering + truncating, both modes)")

    # memory contract: inside the shard_map body every array's leading dim
    # stays the per-shard interval — a replicated [n, L] index block per
    # device (what the old host-driven build would produce) must not trace
    cfg = DistConfig(n=64, ep=2)
    step = make_sparse_index_build_step(
        cfg, mesh, r=64, l=16, sketch_l=48, real_n=64, source_batch=16,
    )
    rp = jnp.asarray(np.asarray(g.row_ptr))
    ci = jnp.asarray(np.asarray(g.col_idx))
    od = jnp.asarray(np.asarray(g.out_deg))
    jaxpr = jax.make_jaxpr(step)(rp, ci, od, key)
    # an index-shaped per-device block: >= n rows of >= l columns.  The
    # per-device sweep may hold flattened [q*w, 1] scatter intermediates
    # (row count is not vertex count there), but never a full-index [n, L]
    # tile.  The check is the auditor's no-replicated-index rule.
    assert_no_replicated_index(jaxpr, n=cfg.n, l=16)
    checked = sum(
        1 for eqn in iter_eqns(jaxpr.jaxpr)
        if eqn.primitive.name == "shard_map"
    )
    assert checked > 0
    print(f"sharded build memory contract OK ({checked} shard_map eqns)")

    # serving: the model-sharded (and, on g2, row-padded) index answers
    # through the ordinary query engine without re-layout
    sharded, _ = build_index_sharded(
        g, r=64, l=16, key=key, mesh=mesh, source_batch=16,
    )
    single, _ = build_index(
        g, r=64, l=16, key=key, source_batch=16, r_splits=2, respawn=True,
    )
    qcfg = QueryConfig(mode="powerwalk", t_iterations=2, top_k=10)
    out_sh = BatchQueryEngine(g, sharded, qcfg).run([0, 5, 9, 33])
    out_si = BatchQueryEngine(g, single, qcfg).run([0, 5, 9, 33])
    np.testing.assert_allclose(
        out_sh["values"], out_si["values"], rtol=1e-5, atol=1e-7,
    )
    g2 = synthetic.erdos_renyi(60, 4.0, seed=11)   # n=60 -> n_pad=64
    sh2, st2 = build_index_sharded(
        g2, r=32, l=8, key=key, mesh=mesh, source_batch=16,
    )
    assert sh2.n == 64 and st2["pad_rows"] == 4
    assert float(np.abs(np.asarray(sh2.values)[g2.n:]).sum()) == 0.0
    si2, _ = build_index(
        g2, r=32, l=8, key=key, source_batch=16, r_splits=2, respawn=True,
    )
    got2 = densify_rows(
        np.asarray(sh2.values)[: g2.n], np.asarray(sh2.indices)[: g2.n],
        g2.n,
    )
    want2 = densify_rows(si2.values, si2.indices, g2.n)
    assert np.abs(got2 - want2).sum(axis=1).max() <= 1e-5
    out_p = BatchQueryEngine(g2, sh2, qcfg).run([0, 7, 59])
    out_q = BatchQueryEngine(g2, si2, qcfg).run([0, 7, 59])
    np.testing.assert_allclose(
        out_p["values"], out_q["values"], rtol=1e-5, atol=1e-7,
    )
    print("sharded index serving OK (incl. padded rows)")


def main():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    n_pad = 64  # multiple of model axis
    # legacy dense-slab exchange (the sparse wire format is the default and
    # is covered by tests/parity_check.py)
    cfg = DistConfig(n=n_pad, ep=2, q_tile=8, t_iterations=2,
                     index_l=16, top_k=20, exchange="dense")
    slabs = build_sharded_graph(g, cfg)

    # dense oracle index from exact vectors (padded)
    exact = exact_ppr_dense(g)
    dense = np.zeros((n_pad, n_pad), np.float32)
    dense[: g.n, : g.n] = exact
    idx = index_from_dense(jnp.asarray(dense), l=cfg.index_l)
    ivals = idx.values.reshape(cfg.ep, cfg.n_shard, cfg.index_l)
    iidx = idx.indices.reshape(cfg.ep, cfg.n_shard, cfg.index_l)

    sources = jnp.asarray([0, 3, 7, 11, 19, 23, 31, 42], jnp.int32)
    step = make_verd_tile_step(cfg, mesh)
    with mesh:
        tv, ti = jax.jit(step)(slabs, sources, ivals, iidx)

    # oracle: dense verd on the unpadded graph with the same (padded) index
    idx_small = index_from_dense(jnp.asarray(dense[: g.n, : g.n]),
                                 l=cfg.index_l)
    want = verd_mod.verd_query(g, sources, idx_small, t=cfg.t_iterations)
    wv, wi = jax.lax.top_k(want, cfg.top_k)

    np.testing.assert_allclose(
        np.asarray(tv), np.asarray(wv), rtol=2e-4, atol=1e-5)
    # indices may tie-break differently: compare the score of chosen ids
    chosen = np.take_along_axis(np.asarray(want), np.asarray(ti), axis=1)
    np.testing.assert_allclose(
        chosen, np.asarray(wv), rtol=2e-4, atol=1e-5)
    print("verd tile OK")

    # deprecated compress_k on the dense path: still close (top-k tail small)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg_c = DistConfig(n=n_pad, ep=2, q_tile=8, t_iterations=2,
                           index_l=16, top_k=20, exchange="dense",
                           compress_k=32)
    step_c = make_verd_tile_step(cfg_c, mesh)
    with mesh:
        cv, ci = jax.jit(step_c)(slabs, sources, ivals, iidx)
    np.testing.assert_allclose(
        np.asarray(cv), np.asarray(wv), rtol=5e-3, atol=1e-4)
    print("compressed exchange OK")

    # walk counts: estimator consistency on the sharded engine
    wcfg = DistConfig(n=n_pad, ep=2, q_tile=4, t_iterations=2)
    walk_step = make_walk_counts_step(wcfg, mesh, max_steps=64)
    r = 2000
    wsources = jnp.repeat(jnp.asarray([0, 3, 7, 11], jnp.int32), r)
    wrows = jnp.repeat(jnp.arange(4, dtype=jnp.int32), r)
    rp = jnp.asarray(np.asarray(g.row_ptr))
    ci_full = jnp.asarray(np.asarray(g.col_idx))
    od = jnp.asarray(np.asarray(g.out_deg))
    with mesh:
        fp, moves = jax.jit(walk_step)(
            rp, ci_full, od, wsources, wrows, jax.random.PRNGKey(0))
    est = np.asarray(fp)[:, : g.n] / np.asarray(moves)[:, None]
    err = np.abs(est - exact[[0, 3, 7, 11]]).sum(axis=1).mean()
    assert err < 0.15, f"walk L1 err too big: {err}"
    print(f"walk counts OK (L1={err:.4f})")

    # sharded compacted sparse-sketch walks: r splits over the 2 data
    # shards, sketches all_gather+merge — conservation must stay exact and
    # the merged estimate must converge like the single-device engine
    scfg = DistConfig(n=n_pad, ep=2, q_tile=4, t_iterations=2)
    sparse_step = make_sparse_walk_counts_step(scfg, mesh, r=r, l=g.n)
    ssources = jnp.asarray([0, 3, 7, 11], jnp.int32)
    with mesh:
        sv, si, smoves, swalks, sdrop = jax.jit(sparse_step)(
            rp, ci_full, od, ssources, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(swalks), float(r))
    # cross-shard conservation: kept mass + dropped ledger == moves; at
    # full width nothing is dropped
    np.testing.assert_allclose(
        np.asarray(sv).sum(axis=1) + np.asarray(sdrop),
        np.asarray(smoves), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sdrop), 0.0, atol=1e-6)
    # narrow sketch: the ledger must still close the conservation identity
    narrow_step = make_sparse_walk_counts_step(scfg, mesh, r=r, l=4)
    with mesh:
        nv, _, nmoves, _, ndrop = jax.jit(narrow_step)(
            rp, ci_full, od, ssources, jax.random.PRNGKey(0))
    assert float(np.asarray(ndrop).sum()) > 0.0
    np.testing.assert_allclose(
        np.asarray(nv).sum(axis=1) + np.asarray(ndrop),
        np.asarray(nmoves), rtol=1e-6)
    sest = np.zeros((4, g.n), np.float32)
    np.add.at(sest, (np.arange(4)[:, None], np.asarray(si)),
              np.asarray(sv) / np.asarray(smoves)[:, None])
    serr = np.abs(sest - exact[[0, 3, 7, 11]]).sum(axis=1).mean()
    assert serr < 0.15, f"sparse walk L1 err too big: {serr}"
    print(f"sparse walk counts OK (L1={serr:.4f})")

    check_sharded_build(mesh)


if __name__ == "__main__":
    main()
    print("ALL OK")
