"""Subprocess body for the distributed-engine equivalence test.

Runs on 4 fake host devices (2 data x 2 model); compares the sharded
VERD tile step against the dense single-shard oracle.  Exits nonzero on
mismatch; tests/test_distributed_engine.py asserts the return code.
"""

import os
import warnings

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import verd as verd_mod
from repro.core.distributed_engine import (
    DistConfig, build_sharded_graph, make_sparse_walk_counts_step,
    make_verd_tile_step, make_walk_counts_step,
)
from repro.core.index import index_from_dense
from repro.core.power_iteration import exact_ppr_dense
from repro.graphs import synthetic


def main():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    n_pad = 64  # multiple of model axis
    # legacy dense-slab exchange (the sparse wire format is the default and
    # is covered by tests/parity_check.py)
    cfg = DistConfig(n=n_pad, ep=2, q_tile=8, t_iterations=2,
                     index_l=16, top_k=20, exchange="dense")
    slabs = build_sharded_graph(g, cfg)

    # dense oracle index from exact vectors (padded)
    exact = exact_ppr_dense(g)
    dense = np.zeros((n_pad, n_pad), np.float32)
    dense[: g.n, : g.n] = exact
    idx = index_from_dense(jnp.asarray(dense), l=cfg.index_l)
    ivals = idx.values.reshape(cfg.ep, cfg.n_shard, cfg.index_l)
    iidx = idx.indices.reshape(cfg.ep, cfg.n_shard, cfg.index_l)

    sources = jnp.asarray([0, 3, 7, 11, 19, 23, 31, 42], jnp.int32)
    step = make_verd_tile_step(cfg, mesh)
    with mesh:
        tv, ti = jax.jit(step)(slabs, sources, ivals, iidx)

    # oracle: dense verd on the unpadded graph with the same (padded) index
    idx_small = index_from_dense(jnp.asarray(dense[: g.n, : g.n]),
                                 l=cfg.index_l)
    want = verd_mod.verd_query(g, sources, idx_small, t=cfg.t_iterations)
    wv, wi = jax.lax.top_k(want, cfg.top_k)

    np.testing.assert_allclose(
        np.asarray(tv), np.asarray(wv), rtol=2e-4, atol=1e-5)
    # indices may tie-break differently: compare the score of chosen ids
    chosen = np.take_along_axis(np.asarray(want), np.asarray(ti), axis=1)
    np.testing.assert_allclose(
        chosen, np.asarray(wv), rtol=2e-4, atol=1e-5)
    print("verd tile OK")

    # deprecated compress_k on the dense path: still close (top-k tail small)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg_c = DistConfig(n=n_pad, ep=2, q_tile=8, t_iterations=2,
                           index_l=16, top_k=20, exchange="dense",
                           compress_k=32)
    step_c = make_verd_tile_step(cfg_c, mesh)
    with mesh:
        cv, ci = jax.jit(step_c)(slabs, sources, ivals, iidx)
    np.testing.assert_allclose(
        np.asarray(cv), np.asarray(wv), rtol=5e-3, atol=1e-4)
    print("compressed exchange OK")

    # walk counts: estimator consistency on the sharded engine
    wcfg = DistConfig(n=n_pad, ep=2, q_tile=4, t_iterations=2)
    walk_step = make_walk_counts_step(wcfg, mesh, max_steps=64)
    r = 2000
    wsources = jnp.repeat(jnp.asarray([0, 3, 7, 11], jnp.int32), r)
    wrows = jnp.repeat(jnp.arange(4, dtype=jnp.int32), r)
    rp = jnp.asarray(np.asarray(g.row_ptr))
    ci_full = jnp.asarray(np.asarray(g.col_idx))
    od = jnp.asarray(np.asarray(g.out_deg))
    with mesh:
        fp, moves = jax.jit(walk_step)(
            rp, ci_full, od, wsources, wrows, jax.random.PRNGKey(0))
    est = np.asarray(fp)[:, : g.n] / np.asarray(moves)[:, None]
    err = np.abs(est - exact[[0, 3, 7, 11]]).sum(axis=1).mean()
    assert err < 0.15, f"walk L1 err too big: {err}"
    print(f"walk counts OK (L1={err:.4f})")

    # sharded compacted sparse-sketch walks: r splits over the 2 data
    # shards, sketches all_gather+merge — conservation must stay exact and
    # the merged estimate must converge like the single-device engine
    scfg = DistConfig(n=n_pad, ep=2, q_tile=4, t_iterations=2)
    sparse_step = make_sparse_walk_counts_step(scfg, mesh, r=r, l=g.n)
    ssources = jnp.asarray([0, 3, 7, 11], jnp.int32)
    with mesh:
        sv, si, smoves, swalks, sdrop = jax.jit(sparse_step)(
            rp, ci_full, od, ssources, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(swalks), float(r))
    # cross-shard conservation: kept mass + dropped ledger == moves; at
    # full width nothing is dropped
    np.testing.assert_allclose(
        np.asarray(sv).sum(axis=1) + np.asarray(sdrop),
        np.asarray(smoves), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sdrop), 0.0, atol=1e-6)
    # narrow sketch: the ledger must still close the conservation identity
    narrow_step = make_sparse_walk_counts_step(scfg, mesh, r=r, l=4)
    with mesh:
        nv, _, nmoves, _, ndrop = jax.jit(narrow_step)(
            rp, ci_full, od, ssources, jax.random.PRNGKey(0))
    assert float(np.asarray(ndrop).sum()) > 0.0
    np.testing.assert_allclose(
        np.asarray(nv).sum(axis=1) + np.asarray(ndrop),
        np.asarray(nmoves), rtol=1e-6)
    sest = np.zeros((4, g.n), np.float32)
    np.add.at(sest, (np.arange(4)[:, None], np.asarray(si)),
              np.asarray(sv) / np.asarray(smoves)[:, None])
    serr = np.abs(sest - exact[[0, 3, 7, 11]]).sum(axis=1).mean()
    assert serr < 0.15, f"sparse walk L1 err too big: {serr}"
    print(f"sparse walk counts OK (L1={serr:.4f})")


if __name__ == "__main__":
    main()
    print("ALL OK")
