"""Per-arch smoke tests: reduced config, one real step per shape on CPU.

Asserts output shapes and absence of NaNs for every (arch x shape) cell —
the CPU-runnable counterpart of the 512-device dry-run (same StepBundle).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_arch

pytestmark = pytest.mark.slow  # one real train step per (arch x shape) cell
from repro.launch import steps as steps_mod
from repro.training import train_loop


def _finite(tree) -> bool:
    return all(
        bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


ALL_CELLS = [
    (spec.id, sh.name) for spec in REGISTRY.values() for sh in spec.shapes
]


@pytest.mark.parametrize("arch_id,shape_name", ALL_CELLS)
def test_smoke_cell(arch_id, shape_name):
    arch = get_arch(arch_id)
    bundle = steps_mod.build(arch, shape_name, reduced=True)
    key = jax.random.PRNGKey(0)
    params = bundle.init_fn(key)
    batch = bundle.make_batch(jax.random.PRNGKey(1))
    # batch matches its spec
    for name, sds in bundle.batch_spec.items():
        assert batch[name].shape == sds.shape, (name, batch[name].shape, sds.shape)
        assert batch[name].dtype == sds.dtype, name

    if bundle.kind == "train":
        opt_state = train_loop.init_state(bundle.opt_cfg or steps_mod.SMOKE_OPT, params)
        step = jax.jit(bundle.step_fn)
        new_params, new_opt, metrics = step(params, opt_state, batch)
        assert _finite(metrics), (arch_id, shape_name, metrics)
        assert float(metrics["loss"]) > 0.0
        assert _finite(new_params)
        # params actually changed
        changed = any(
            not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
        )
        assert changed
    else:
        if bundle.cache_spec is not None:
            cache = {
                k: jnp.zeros(v.shape, v.dtype)
                for k, v in bundle.cache_spec.items()
            }
            out = jax.jit(bundle.step_fn)(params, cache, batch)
            logits, new_cache = out
            assert _finite(logits)
            assert int(new_cache["length"]) == 1
        else:
            out = jax.jit(bundle.step_fn)(params, batch)
            assert _finite(out)


@pytest.mark.parametrize("arch_id", sorted(REGISTRY))
def test_second_train_step_decreases_or_close(arch_id):
    """Two steps on the first train-like shape: loss must not explode."""
    arch = get_arch(arch_id)
    train_shapes = [s for s in arch.shapes
                    if "train" in s.kind or s.kind.endswith("_full")]
    if not train_shapes:
        pytest.skip("no train shape")
    bundle = steps_mod.build(arch, train_shapes[0].name, reduced=True)
    if bundle.kind != "train":
        pytest.skip("serve-only cell")
    params = bundle.init_fn(jax.random.PRNGKey(0))
    opt_state = train_loop.init_state(bundle.opt_cfg or steps_mod.SMOKE_OPT, params)
    step = jax.jit(bundle.step_fn)
    batch = bundle.make_batch(jax.random.PRNGKey(1))
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert float(m2["loss"]) < float(m1["loss"]) * 1.5


def test_registry_covers_assignment():
    assert len(REGISTRY) == 10
    assert len(ALL_CELLS) == 40
