"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import frontier as frontier_mod
from repro.core import mcfp, metrics, theory
from repro.core import verd as verd_mod
from repro.core.graph import Graph, push_forward, transition_with_dangling
from repro.core.index import index_from_dense, plan_for_budget, truncate_topl
from repro.core.power_iteration import exact_ppr_dense, power_iteration
from repro.core.walks import sample_walk_lengths
from repro.graphs import formats, synthetic

SETTINGS = dict(
    deadline=None, max_examples=12,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def graphs(draw, max_n=24):
    n = draw(st.integers(3, max_n))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        src, dst = np.array([0]), np.array([1 % n])
        keep = np.array([True])
    return Graph.from_edges(src[keep], dst[keep], n=n)


@given(graphs())
@settings(**SETTINGS)
def test_exact_ppr_rows_are_stochastic(g):
    p = exact_ppr_dense(g)
    assert np.all(p >= -1e-12)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-9)


@given(graphs())
@settings(**SETTINGS)
def test_transition_preserves_mass(g):
    sources = jnp.asarray([0, g.n - 1], jnp.int32)
    f = jnp.zeros((2, g.n)).at[jnp.arange(2), sources].set(1.0)
    for _ in range(3):
        f = transition_with_dangling(g, f, sources)
        np.testing.assert_allclose(np.asarray(f.sum(1)), 1.0, rtol=1e-5)


@given(graphs())
@settings(**SETTINGS)
def test_decomposition_theorem_on_dangling_free(g):
    """Thm 2.2 holds exactly on dangling-free graphs.

    (With dangling vertices the per-source adjustment of Section 2.1 makes
    each p_v solve a *different* transition matrix, and the identity is
    only approximate — the same reason Algorithm 4 drops dangling mass.
    We close every dangling vertex with a cycle edge first.)
    """
    deg = np.asarray(g.out_deg)
    if (deg == 0).any():
        extra = np.nonzero(deg == 0)[0]
        src = np.concatenate([np.asarray(g.src), extra])
        dst = np.concatenate([np.asarray(g.col_idx), (extra + 1) % g.n])
        g = Graph.from_edges(src, dst, n=g.n)
    p = exact_ppr_dense(g)
    for u in range(g.n):
        nbrs = g.out_neighbors(u)
        rhs = 0.15 * np.eye(g.n)[u] + 0.85 / len(nbrs) * sum(
            p[int(v)] for v in nbrs)
        np.testing.assert_allclose(p[u], rhs, atol=1e-9)
        break  # one vertex per example keeps runtime bounded


@given(graphs(), st.integers(0, 3))
@settings(**SETTINGS)
def test_verd_matches_recursion(g, t):
    """Thm 2.3 on arbitrary random graphs (incl. dangling-free subcases)."""
    if np.asarray(g.dangling_mask).any():
        # recursion's dangling convention differs (see verd.py docstring);
        # restrict the equivalence property to non-dangling graphs
        return
    rng = np.random.default_rng(0)
    base = rng.random((g.n, g.n))
    base /= base.sum(1, keepdims=True)
    srcs = jnp.asarray([0], jnp.int32)
    s, f = verd_mod.verd_iterate(g, srcs, t=t)
    idx = index_from_dense(jnp.asarray(base, jnp.float32), l=g.n)
    got = np.asarray(verd_mod.combine_with_index(s, f, idx))[0]
    want = verd_mod.recursive_decomp(g, 0, t, base)
    np.testing.assert_allclose(got, want, atol=5e-5)


@given(graphs(), st.integers(1, 6))
@settings(**SETTINGS)
def test_ell_pull_equals_push(g, k):
    ell = formats.to_ell_chunks(g, k=k)
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.random((2, g.n)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(formats.ell_pull(ell, f)),
        np.asarray(push_forward(g, f)),
        rtol=1e-4, atol=1e-5,
    )


@given(st.integers(1, 64), st.integers(2, 32))
@settings(**SETTINGS)
def test_truncation_keeps_largest(l, n):
    rng = np.random.default_rng(l * 31 + n)
    est = jnp.asarray(rng.random((3, n)), jnp.float32)
    vals, idx = truncate_topl(est, min(l, n))
    # kept values are the top ones
    want = np.sort(np.asarray(est), axis=1)[:, ::-1][:, : min(l, n)]
    np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-6)


@given(st.floats(0.02, 0.5), st.integers(10, 5000))
@settings(**SETTINGS)
def test_theory_bound_in_unit_range_and_monotone(gamma, r):
    b = theory.overestimate_bound(gamma, r)
    assert b >= 0
    assert theory.overestimate_bound(gamma, r + 100) <= b + 1e-12


@given(st.integers(1, 10 ** 9), st.integers(0, 2 ** 40))
@settings(**SETTINGS)
def test_budget_plan_within_budget(n, budget):
    plan = plan_for_budget(n, budget)
    assert plan.index_bytes <= max(budget, 0)
    assert plan.r <= plan.l  # R = c*L < L


@given(st.integers(2, 100))
@settings(**SETTINGS)
def test_rag_exact_is_one(k):
    rng = np.random.default_rng(k)
    p = jnp.asarray(rng.random((4, 200)), jnp.float32)
    rag = metrics.rag_at_k(p, p, min(k, 200))
    np.testing.assert_allclose(np.asarray(rag), 1.0, rtol=1e-6)


@given(st.integers(2, 50))
@settings(**SETTINGS)
def test_rag_scale_invariant(k):
    rng = np.random.default_rng(k)
    exact = jnp.asarray(rng.random((3, 100)), jnp.float32)
    approx = jnp.asarray(rng.random((3, 100)), jnp.float32)
    r1 = metrics.rag_at_k(exact, approx, k)
    r2 = metrics.rag_at_k(exact, approx * 7.3, k)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-6)


# ---------------------------------------------------------------------------
# SparseFrontier invariants: dedup-merge, ELL hub splitting, compaction
# ---------------------------------------------------------------------------

@st.composite
def candidate_rows(draw, max_q=4, max_w=24, max_n=16):
    q = draw(st.integers(1, max_q))
    w = draw(st.integers(1, max_w))
    n = draw(st.integers(1, max_n))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    vals = rng.random((q, w)).astype(np.float32)
    vals[rng.random((q, w)) < 0.3] = 0.0  # mix in empty slots
    idxs = rng.integers(0, n, (q, w)).astype(np.int32)
    return vals, idxs, n


from conftest import densify_rows as _densify  # the shared scatter oracle


@given(candidate_rows(), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_merge_duplicates_permutation_invariant(cand, perm_seed):
    """Dedup-merge commutes with any per-row slot permutation: the merged
    result densifies identically regardless of candidate order."""
    vals, idxs, n = cand
    mv, mi = frontier_mod.merge_duplicates(jnp.asarray(vals), jnp.asarray(idxs))
    perm = np.random.default_rng(perm_seed).permutation(vals.shape[1])
    pv, pi = frontier_mod.merge_duplicates(
        jnp.asarray(vals[:, perm]), jnp.asarray(idxs[:, perm])
    )
    np.testing.assert_allclose(
        _densify(mv, mi, n), _densify(pv, pi, n), rtol=1e-6, atol=1e-6
    )


@given(candidate_rows(), st.integers(1, 16), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_compact_permutation_invariant_and_true_topk(cand, k, perm_seed):
    """Full compaction (merge -> top-K) keeps exactly the top-K of the
    *merged* per-column mass, independent of candidate order."""
    vals, idxs, n = cand
    cv, ci = frontier_mod.compact_arrays(
        jnp.asarray(vals), jnp.asarray(idxs), k
    )
    # permutation invariance of the kept mass
    perm = np.random.default_rng(perm_seed).permutation(vals.shape[1])
    pv, pi = frontier_mod.compact_arrays(
        jnp.asarray(vals[:, perm]), jnp.asarray(idxs[:, perm]), k
    )
    np.testing.assert_allclose(
        _densify(cv, ci, n), _densify(pv, pi, n), rtol=1e-6, atol=1e-6
    )
    # the kept entries are the true per-row top-k of the dense merge
    dense = _densify(vals, idxs, n)
    want = np.sort(dense, axis=1)[:, ::-1][:, : min(k, n)].sum(axis=1)
    np.testing.assert_allclose(
        np.asarray(cv).sum(axis=1), want, rtol=1e-5, atol=1e-6
    )


@given(graphs(), st.integers(1, 8), st.booleans())
@settings(**SETTINGS)
def test_hub_splitting_preserves_pushed_mass(g, h, truncate):
    """ELL row splitting moves candidates between sub-slots but the pushed
    multiset — hence the densified push — is exactly preserved, in the
    exact regime (cap = max degree) and the truncating one (cap below)."""
    cap = verd_mod.resolve_degree_cap(g)
    if truncate:
        cap = max(cap // 2, 1)  # cap < max deg: both paths drop the tail
    rng = np.random.default_rng(0)
    q, k = 2, min(6, g.n)
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, g.n, (q, k)), jnp.int32)
    srcs = jnp.asarray(rng.integers(0, g.n, q), jnp.int32)
    base_v, base_i = verd_mod.sparse_push_candidates(
        g, fv, fi, srcs, c=0.15, degree_cap=cap
    )
    split_v, split_i = verd_mod.sparse_push_candidates(
        g, fv, fi, srcs, c=0.15, degree_cap=cap, hub_split_degree=h
    )
    # total mass exactly preserved, and per-destination mass too
    np.testing.assert_allclose(
        np.asarray(split_v).sum(), np.asarray(base_v).sum(), rtol=1e-6
    )
    np.testing.assert_allclose(
        _densify(np.asarray(split_v), np.asarray(split_i), g.n),
        _densify(np.asarray(base_v), np.asarray(base_i), g.n),
        rtol=1e-6, atol=1e-6,
    )
    # and the emitted candidate width is K sub-slot groups of width h (+1
    # dangling slot) — i.e. no gather axis exceeded the split width
    hh, s = verd_mod.resolve_hub_splits(cap, h)
    assert split_v.shape[1] == k * s * hh + 1
    assert base_v.shape[1] == k * cap + 1


@st.composite
def prefetch_push_cases(draw):
    """Random CSR graphs with hubs planted at the gather boundaries.

    Hubs sit on vertex 0 and vertex n-1, so one hub row opens ``col_idx``
    and one closes it — the row whose last DMA gather window gets clipped
    against the end of the edge array (the ``d > 0`` shift path of
    ``verd.masked_push_from_windows``).  The frontier additionally plants
    the hubs in the first and last slot of every ``q_tile`` tile, so hub
    gathers straddle the kernel's grid-step boundaries, and Q is often
    ragged against ``q_tile``.
    """
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    n = draw(st.integers(8, 32))
    q_tile = draw(st.sampled_from([1, 2, 4]))
    q = draw(st.integers(1, 3)) * q_tile + draw(st.integers(0, q_tile - 1))
    hub_deg = draw(st.integers(5, 20))
    hub_split = draw(st.sampled_from([0, 1, 2, 3, 7]))
    src = np.concatenate([
        np.full(hub_deg, 0), np.full(hub_deg, n - 1),
        rng.integers(1, n - 1, n * draw(st.integers(1, 4))),
    ])
    dst = rng.integers(0, n, src.shape[0])
    keep = src != dst
    g = Graph.from_edges(src[keep], dst[keep], n=n)
    k = draw(st.integers(1, 4))
    fv = rng.random((q, k)).astype(np.float32)
    fi = rng.integers(0, n, (q, k)).astype(np.int32)
    for t in range(0, q, q_tile):       # hubs at every tile boundary
        fi[t, 0] = 0
        fi[min(t + q_tile, q) - 1, -1] = n - 1
    srcs = rng.integers(0, n, q).astype(np.int32)
    return (
        g, jnp.asarray(fv), jnp.asarray(fi), jnp.asarray(srcs),
        q_tile, hub_split,
    )


@given(prefetch_push_cases())
@settings(**SETTINGS)
def test_prefetch_gather_push_matches_core_bitwise(case):
    """The DMA-gather Pallas push is the same math as the jnp core op: on
    hub-at-boundary CSR graphs the kernel's compacted frontier matches
    ``verd.gather_push_candidates`` + ``frontier.compact_arrays``
    bit-for-bit (values AND indices), for every ``hub_split_degree``, and
    the pushed mass is preserved through compaction."""
    from repro.kernels import ops as kernel_ops

    g, fv, fi, srcs, q_tile, hub_split = case
    cap = verd_mod.resolve_degree_cap(g)
    cand_v, cand_i = verd_mod.gather_push_candidates(
        fv, fi, srcs, g.row_ptr, g.out_deg, g.col_idx,
        c=0.15, degree_cap=cap, hub_split_degree=hub_split,
    )
    k_out = int(min(cand_v.shape[1], g.n))
    want_v, want_i = frontier_mod.compact_arrays(cand_v, cand_i, k_out)
    f0 = frontier_mod.SparseFrontier(
        values=fv, indices=fi, k=fv.shape[1], n=g.n
    )
    got = kernel_ops.frontier_push(
        f0, g, srcs, c=0.15, degree_cap=cap, k_out=k_out, q_tile=q_tile,
        hub_split_degree=hub_split, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got.values), np.asarray(want_v))
    np.testing.assert_array_equal(
        np.asarray(got.indices), np.asarray(want_i)
    )
    # covering cap + covering k_out: compaction only merges, so the pushed
    # mass survives exactly (up to f32 merge rounding)
    np.testing.assert_allclose(
        np.asarray(got.values, np.float64).sum(axis=1),
        np.asarray(cand_v, np.float64).sum(axis=1),
        rtol=1e-6, atol=1e-6,
    )


@given(candidate_rows(max_n=12), st.integers(1, 3), st.integers(1, 12))
@settings(**SETTINGS)
def test_bucket_by_owner_partitions_mass(cand, ep, k):
    """Owner bucketing with covering k: per-owner densified buckets tile the
    global densified candidates exactly (nothing lost, nothing mixed)."""
    vals, idxs, n = cand
    ns = max((n + ep - 1) // ep, 1)
    n_pad = ns * ep
    bv, bi = frontier_mod.bucket_by_owner(
        jnp.asarray(vals), jnp.asarray(idxs), ep, ns, max(k, ns)
    )
    got = np.zeros((vals.shape[0], n_pad), np.float32)
    for o in range(ep):
        got[:, o * ns: (o + 1) * ns] += _densify(
            np.asarray(bv[:, o]), np.asarray(bi[:, o]), ns
        )
    np.testing.assert_allclose(
        got[:, :n], _densify(vals, idxs, n), rtol=1e-6, atol=1e-6
    )


def test_walk_lengths_match_geometric_distribution(key):
    lens = np.asarray(sample_walk_lengths(key, 50000, c=0.2, max_steps=300))
    # P(len = k) = c (1-c)^{k-1}: check mean and P(1)
    assert abs(lens.mean() - 5.0) < 0.15
    assert abs((lens == 1).mean() - 0.2) < 0.01


@given(graphs(max_n=16), st.integers(1, 3))
@settings(**SETTINGS)
def test_mcfp_error_shrinks_with_r(g, seed):
    key = jax.random.PRNGKey(seed)
    exact = exact_ppr_dense(g)[:1]
    src = jnp.asarray([0], jnp.int32)
    e_small = np.abs(np.asarray(
        mcfp.estimate_ppr(g, src, 50, key)) - exact).sum()
    e_big = np.abs(np.asarray(
        mcfp.estimate_ppr(g, src, 800, key)) - exact).sum()
    assert e_big <= e_small + 0.05


# one fixed graph for the respawn property: every drawn seed then reuses
# the same compiled engines instead of re-jitting per example
_RESPAWN_G = synthetic.erdos_renyi(24, 4.0, seed=5)


@given(st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_respawn_equals_schedule_in_distribution(seed):
    """Respawn-mode scheduling is a slot-reuse transform, not a different
    estimator: for any key, both modes finish exactly R walks whose counts
    close the conservation ledger, realize the same geometric(c) length law
    (up to the drain-truncation tail), and their MCFP estimates differ by
    no more than Monte-Carlo noise."""
    from repro.core.walks import simulate_walks_sparse

    key = jax.random.PRNGKey(seed)
    src = jnp.asarray([0, 7], jnp.int32)
    r = 1500
    est = {}
    for respawn in (False, True):
        counts = simulate_walks_sparse(
            _RESPAWN_G, src, r, key, l=_RESPAWN_G.n, respawn=respawn
        )
        np.testing.assert_allclose(np.asarray(counts.walks), float(r))
        np.testing.assert_allclose(
            np.asarray(counts.fp.mass() + counts.fp_dropped),
            np.asarray(counts.moves), rtol=1e-6,
        )
        mean_len = float(counts.moves.sum() / counts.walks.sum())
        assert abs(mean_len - 1 / 0.15) < 0.7
        est[respawn] = np.asarray(counts.fp.densify()) / np.asarray(
            counts.moves
        )[:, None]
    diff = np.abs(est[True] - est[False]).sum(axis=1).max()
    assert diff < 0.2
