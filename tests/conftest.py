import os

# Tests run on the single real CPU device; the 512-device dry-run sets its
# own XLA_FLAGS in a subprocess (launch/dryrun.py) and must NOT leak here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import numpy as np
import pytest

import jax


def densify_rows(values, indices, n):
    """Independent numpy scatter oracle for fixed-width sparse rows: the one
    definition of "densified equal" the sparse-path suites assert against
    (deliberately NOT SparseFrontier.densify — the library under test).
    ``tests/parity_check.py`` keeps a private copy because it runs as a
    plain subprocess outside pytest's path setup."""
    values = np.asarray(values)
    q = values.shape[0]
    out = np.zeros((q, n), np.float32)
    np.add.at(
        out, (np.arange(q)[:, None], np.asarray(indices)), values
    )
    return out


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``tpu``-marked tests off-TPU: they run the Pallas kernels
    with ``interpret=False``, which only a real TPU backend can compile."""
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="needs a real TPU backend (interpret=False kernels)"
    )
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
