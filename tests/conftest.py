import os

# Tests run on the single real CPU device; the 512-device dry-run sets its
# own XLA_FLAGS in a subprocess (launch/dryrun.py) and must NOT leak here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
