"""Unit coverage for ``repro.distributed.checkpoint``: atomic commit,
checksums, dtype round-trips, pruning, and the async-writer error path.

The build-level resume contract (bitwise resumed == uninterrupted) lives
in ``tests/test_checkpoint_resume.py``; this file pins the store itself.
"""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import (
    CheckpointCorruptionError, Checkpointer, deserialize_key, serialize_key,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        vals=jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32)),
        idxs=jnp.asarray(rng.integers(0, 100, size=(6, 4)).astype(np.int32)),
        mask=jnp.asarray(rng.integers(0, 2, size=(6,)).astype(bool)),
    )


def test_save_restore_roundtrip_flat_dict(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(3, tree, dict(note="x"))
    got, extra = ck.restore(3)          # no `like`: restored by meta keys
    assert extra == dict(note="x")
    assert sorted(got) == sorted(tree)
    for k in tree:
        assert np.array_equal(np.asarray(got[k]), np.asarray(tree[k]))
        assert got[k].dtype == tree[k].dtype


def test_bf16_uint16_view_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    x = jnp.asarray(
        np.linspace(-3, 3, 16, dtype=np.float32)).astype(jnp.bfloat16)
    ck.save(0, dict(x=x))
    got, _ = ck.restore(0)
    assert got["x"].dtype == jnp.bfloat16
    assert np.array_equal(
        np.asarray(got["x"]).view(np.uint16),
        np.asarray(x).view(np.uint16),
    )
    # the on-disk shard is the uint16 view (npy has no native bfloat16) but
    # meta records the logical dtype, and its checksum still verifies
    meta = ck.read_meta(0)
    assert meta["dtypes"] == ["bfloat16"]
    assert ck.verify_step(0)


def test_tmp_dirs_invisible_to_latest_step(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    # a crash mid-write leaves a .tmp dir; it must never be a candidate
    os.makedirs(tmp_path / "step_9.tmp")
    (tmp_path / "step_9.tmp" / "meta.json").write_text("{}")
    assert ck.all_steps() == [1]
    assert ck.latest_step() == 1
    assert ck.restore_latest()[0] == 1


def test_keep_prunes_old_steps(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]
    got, _ = ck.restore(4)
    assert np.array_equal(
        np.asarray(got["vals"]), np.asarray(_tree(4)["vals"]))


def test_checksum_corruption_detected_and_fallback(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1), dict(step=1))
    ck.save(2, _tree(2), dict(step=2))
    # flip committed shard bytes of the newest step (past the npy header)
    shard = tmp_path / "step_2" / "arr_0.npy"
    raw = bytearray(shard.read_bytes())
    raw[-8:] = b"\x55" * 8
    shard.write_bytes(bytes(raw))
    assert not ck.verify_step(2)
    assert ck.verify_step(1)
    with pytest.raises(CheckpointCorruptionError):
        ck.restore(2)
    # restore_latest falls back to the prior committed step
    step, got, extra = ck.restore_latest()
    assert step == 1 and extra == dict(step=1)
    assert np.array_equal(
        np.asarray(got["vals"]), np.asarray(_tree(1)["vals"]))


def test_shape_mismatch_is_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(0, dict(a=jnp.zeros((4, 3))))
    np.save(tmp_path / "step_0" / "arr_0.npy", np.zeros((2, 3), np.float32))
    assert not ck.verify_step(0)
    with pytest.raises(CheckpointCorruptionError):
        ck.restore(0)


def test_restore_with_like_tree(tmp_path):
    ck = Checkpointer(str(tmp_path))
    like = (jnp.zeros((3, 2)), dict(b=jnp.zeros(5, jnp.int32)))
    tree = (jnp.ones((3, 2)), dict(b=jnp.arange(5, dtype=jnp.int32)))
    ck.save(7, tree)
    got, _ = ck.restore(7, like=like)
    assert np.array_equal(np.asarray(got[0]), np.ones((3, 2)))
    assert np.array_equal(np.asarray(got[1]["b"]), np.arange(5))


def test_restore_latest_predicate_skips_steps(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1), dict(complete=True))
    ck.save(2, _tree(2), dict(complete=False))
    step, _, extra = ck.restore_latest(
        predicate=lambda e: e.get("complete"))
    assert step == 1 and extra["complete"] is True


def test_async_writer_error_reraised_on_next_save_and_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    boom = RuntimeError("disk full")

    def exploding_pre_commit(step):
        raise boom

    ck.pre_commit = exploding_pre_commit
    ck.save(0, _tree(), blocking=False)   # error lands on the writer thread
    ck._thread.join()
    ck.pre_commit = None
    # surfaced on the *next* save (which first waits on the writer) …
    with pytest.raises(RuntimeError, match="disk full"):
        ck.save(1, _tree(), blocking=False)
    # … and the error is consumed, not raised forever
    ck.wait()
    ck.save(1, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 1
    # the failed step 0 never committed (only its .tmp remains)
    assert 0 not in ck.all_steps()


def test_async_save_overlaps_and_commits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    gate = threading.Event()
    ck.pre_commit = lambda step: gate.wait(5)
    ck.save(0, _tree(), blocking=False)
    assert ck.latest_step() is None       # still mid-write
    gate.set()
    ck.wait()
    ck.pre_commit = None
    assert ck.latest_step() == 0
    assert ck.verify_step(0)


def test_key_serialization_roundtrip_raw_and_typed():
    raw = jax.random.PRNGKey(42)
    fp = serialize_key(raw)
    json.dumps(fp)                        # must be JSON-safe
    back = deserialize_key(fp)
    assert np.array_equal(np.asarray(back), np.asarray(raw))

    typed = jax.random.key(42)
    fp_t = serialize_key(typed)
    json.dumps(fp_t)
    back_t = deserialize_key(fp_t)
    assert jnp.issubdtype(back_t.dtype, jax.dtypes.prng_key)
    assert np.array_equal(
        np.asarray(jax.random.key_data(back_t)),
        np.asarray(jax.random.key_data(typed)),
    )
    # identical streams after reconstruction
    assert np.array_equal(
        np.asarray(jax.random.uniform(back_t, (4,))),
        np.asarray(jax.random.uniform(typed, (4,))),
    )
