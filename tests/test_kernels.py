"""Pallas-kernel validation: interpret-mode sweeps vs pure-jnp oracles,
plus the HBM-residency kernel contract (no CSR/index whole-array VMEM
blocks; boundary cases the resident-block kernels never exercised)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import push_forward
from repro.graphs import formats, synthetic
from repro.kernels import frontier_push as push_mod
from repro.kernels import index_combine as comb_mod
from repro.kernels import ops, ref
from repro.kernels import walk_step as walk_mod
from repro.kernels.ell_spmm import ell_spmm, vmem_bytes
from repro.kernels.embedding_bag import embedding_bag as bag_kernel
from repro.kernels.index_combine import index_combine as comb_kernel

TOL = dict(
    float32=dict(rtol=1e-5, atol=1e-6),
    bfloat16=dict(rtol=2e-2, atol=2e-2),
)


def _tols(dtype):
    return TOL[jnp.dtype(dtype).name]


# ---------------------------------------------------------------------------
# ell_spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,rows,k,n", [
    (8, 256, 8, 64),
    (16, 512, 16, 128),
    (8, 256, 4, 32),
    (24, 768, 32, 200),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ell_spmm_matches_ref(q, rows, k, n, dtype, rng):
    f = jnp.asarray(rng.random((q, n)), dtype)
    nbr = jnp.asarray(rng.integers(0, n, (rows, k)), jnp.int32)
    w = jnp.asarray(rng.random((rows, k)), dtype)
    got = ell_spmm(f, nbr, w, q_tile=8, r_tile=256, interpret=True)
    want = ref.ell_spmm_ref(f, nbr, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tols(dtype),
    )


def test_ell_spmm_bf16(rng):
    f = jnp.asarray(rng.random((8, 64)), jnp.bfloat16)
    nbr = jnp.asarray(rng.integers(0, 64, (256, 8)), jnp.int32)
    w = jnp.asarray(rng.random((256, 8)), jnp.bfloat16)
    got = ell_spmm(f, nbr, w, q_tile=8, r_tile=256, interpret=True)
    want = ref.ell_spmm_ref(
        f.astype(jnp.float32), nbr, w.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), **TOL["bfloat16"]
    )


def test_ell_push_equals_graph_push(rng):
    """End-to-end: Pallas ELL push == edge-parallel push_forward."""
    g = synthetic.rmat(8, avg_deg=6.0, seed=5)
    ell = formats.to_ell_chunks(g, k=8)
    f = jnp.asarray(rng.random((5, g.n)), jnp.float32)
    got = ops.ell_push(f, ell, q_tile=8, r_tile=256, interpret=True)
    want = push_forward(g, f)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_ell_pull_pure_jnp_equals_push(rng):
    g = synthetic.erdos_renyi(100, 5.0, seed=4)
    ell = formats.to_ell_chunks(g, k=4)
    f = jnp.asarray(rng.random((3, g.n)), jnp.float32)
    got = formats.ell_pull(ell, f)
    want = push_forward(g, f)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_ell_hub_splitting():
    """A hub with in-degree >> k must fold correctly across chunk rows."""
    g = synthetic.star(50)  # every spoke points at vertex 0
    ell = formats.to_ell_chunks(g, k=4)
    f = jnp.ones((1, g.n), jnp.float32)
    got = ops.ell_push(f, ell, interpret=True)
    want = push_forward(g, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_vmem_budget_accounting():
    assert vmem_bytes(8, 256, 16, 4096) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# index_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,l", [(8, 128, 8), (16, 256, 16), (4, 64, 4)])
def test_index_combine_matches_ref(q, n, l, rng):
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    f = jnp.asarray(rng.random((q, n)), jnp.float32)
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    got = comb_kernel(s, f, vals, idx, q_tile=4, v_tile=64, interpret=True)
    want = ref.index_combine_ref(s, f, vals, idx)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_index_combine_wrapper_pads(rng):
    q, n, l = 5, 100, 7  # deliberately unaligned
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    f = jnp.asarray(rng.random((q, n)), jnp.float32)
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    got = ops.index_combine(s, f, vals, idx, interpret=True)
    want = ref.index_combine_ref(s, f, vals, idx)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_index_combine_matches_core_combine(rng):
    """Kernel == the chunked-scan implementation in core.verd."""
    from repro.core.index import index_from_dense
    from repro.core.verd import combine_with_index

    q, n, l = 6, 96, 12
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    f = jnp.asarray(rng.random((q, n)), jnp.float32)
    dense = jnp.asarray(rng.random((n, n)), jnp.float32)
    idx = index_from_dense(dense, l=l)
    want = combine_with_index(s, f, idx, vertex_chunk=32)
    got = ops.index_combine(s, f, idx.values, idx.indices, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


# ---------------------------------------------------------------------------
# frontier_push + index_combine_sparse (sparse online path)
# ---------------------------------------------------------------------------

def _frontier_fixture(rng, n=60, q=5):
    from repro.core import verd as verd_mod

    g = synthetic.erdos_renyi(n, 4.0, seed=11)
    srcs = jnp.asarray(rng.integers(0, n, q), jnp.int32)
    cap = verd_mod.resolve_degree_cap(g)
    return g, srcs, cap


def test_frontier_push_kernel_matches_ref(rng):
    from repro.core import frontier as F

    g, srcs, cap = _frontier_fixture(rng)
    f0 = F.from_sources(srcs, g.n)
    got = ops.frontier_push(
        f0, g, srcs, c=0.15, degree_cap=cap, k_out=16, interpret=True
    )
    rv, ri = ref.frontier_push_ref(
        f0.values, f0.indices, srcs, g.row_ptr, g.out_deg, g.col_idx,
        c=0.15, degree_cap=cap, k_out=16,
    )
    want = F.SparseFrontier(values=rv, indices=ri, k=16, n=g.n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-5, atol=1e-6,
    )


def test_frontier_push_kernel_two_iterations(rng):
    """Kernel iterated == verd_iterate_sparse's f after two pushes."""
    from repro.core import frontier as F
    from repro.core import verd as verd_mod

    g, srcs, cap = _frontier_fixture(rng)
    k = g.n
    f = F.from_sources(srcs, g.n)
    for _ in range(2):
        f = ops.frontier_push(
            f, g, srcs, c=0.15, degree_cap=cap, k_out=k, interpret=True
        )
    _, f_want = verd_mod.verd_iterate_sparse(g, srcs, t=2, k=k, c=0.15)
    np.testing.assert_allclose(
        np.asarray(f.densify()), np.asarray(f_want.densify()),
        rtol=1e-5, atol=1e-6,
    )


def test_index_combine_sparse_kernel_matches_ref(rng):
    from repro.core import frontier as F
    from repro.core import verd as verd_mod
    from repro.core.index import index_from_dense

    g, srcs, cap = _frontier_fixture(rng)
    dense = jnp.asarray(rng.random((g.n, g.n)), jnp.float32)
    idx = index_from_dense(dense, l=12)
    s, f = verd_mod.verd_iterate_sparse(g, srcs, t=2, k=g.n, degree_cap=cap)
    got = ops.index_combine_sparse(
        s, f, idx.values, idx.indices, k_out=10, interpret=True
    )
    rv, ri = ref.index_combine_sparse_ref(
        s.values, s.indices, f.values, f.indices, idx.values, idx.indices,
        k_out=10,
    )
    want = F.SparseFrontier(values=rv, indices=ri, k=10, n=g.n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-5, atol=1e-6,
    )
    # the fused sparse combine also equals the jnp core implementation
    core = verd_mod.combine_with_index_sparse(s, f, idx, out_k=10)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(core.densify()),
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# sharded_frontier_push (distributed sparse-exchange half-iteration)
# ---------------------------------------------------------------------------

def _dens_buckets(vals, idx, ep, ns):
    """Scatter per-owner buckets back to dense [Q, ep, ns] for comparison
    (bucket top-k order may tie-break differently than the oracle's)."""
    from conftest import densify_rows

    return np.stack(
        [densify_rows(np.asarray(vals)[:, o], np.asarray(idx)[:, o], ns)
         for o in range(ep)],
        axis=1,
    )


@pytest.mark.parametrize("q,k,shards,hub_split_degree", [
    (5, 8, 1, 0),      # degenerate 1-shard case
    (5, 8, 1, 2),
    (8, 16, 2, 0),
    (8, 16, 2, 3),
    (3, 4, 4, 0),
    (3, 4, 4, 1),
])
def test_sharded_push_kernel_matches_ref(q, k, shards, hub_split_degree, rng):
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    cap = verd_mod.resolve_degree_cap(g)
    n_pad = 64
    cfg = DistConfig(n=n_pad, ep=shards, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, ns, (q, k)), jnp.int32)
    for s in range(shards):
        got_v, got_i = ops.sharded_frontier_push(
            fv, fi, slabs.row_ptr[s], slabs.col_idx[s],
            c=0.15, degree_cap=cap, ep=shards, n_shard=ns, wire_k=ns,
            hub_split_degree=hub_split_degree, q_tile=1, interpret=True,
        )
        ref_v, ref_i = ref.sharded_push_ref(
            fv, fi, slabs.row_ptr[s], slabs.col_idx[s],
            c=0.15, ep=shards, n_shard=ns, wire_k=ns,
        )
        np.testing.assert_allclose(
            _dens_buckets(got_v, got_i, shards, ns),
            _dens_buckets(ref_v, ref_i, shards, ns),
            rtol=1e-5, atol=1e-6,
        )


def test_sharded_push_truncated_wire_is_top_k(rng):
    """wire_k below the owner support keeps exactly the per-owner top-k."""
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(n=64, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((4, 8)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, ns, (4, 8)), jnp.int32)
    wire_k = 4
    got_v, _ = ops.sharded_frontier_push(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, degree_cap=cap, ep=2, n_shard=ns, wire_k=wire_k,
        q_tile=4, interpret=True,
    )
    full_v, full_i = ref.sharded_push_ref(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, ep=2, n_shard=ns, wire_k=ns,
    )
    want = np.sort(np.asarray(full_v), axis=2)[:, :, ::-1][:, :, :wire_k]
    np.testing.assert_allclose(
        np.sort(np.asarray(got_v), axis=2)[:, :, ::-1], want,
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,bag,v,d", [
    (64, 4, 100, 128),
    (128, 16, 50, 256),
    (64, 1, 10, 128),
])
def test_embedding_bag_matches_ref(b, bag, v, d, rng):
    ids = jnp.asarray(rng.integers(0, v, (b, bag)), jnp.int32)
    mask = jnp.asarray(rng.random((b, bag)) > 0.3, jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    got = bag_kernel(ids, mask, table, b_tile=64, d_tile=128, interpret=True)
    want = ref.embedding_bag_ref(ids, mask, table)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_embedding_bag_wrapper_unaligned(rng):
    b, bag, v, d = 37, 3, 20, 48  # unaligned batch and dim
    ids = jnp.asarray(rng.integers(0, v, (b, bag)), jnp.int32)
    mask = jnp.ones((b, bag), jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    got = ops.embedding_bag(ids, mask, table, interpret=True)
    want = ref.embedding_bag_ref(ids, mask, table)
    assert got.shape == (b, d)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# HBM-residency kernel contract (the DMA-gather rewrite)
#
# Two halves: (a) a mechanical memory contract — tracing each DMA kernel
# and asserting that no CSR/index array enters as a whole-array VMEM block
# (only `pltpu.ANY`/HBM refs + tile-sized VMEM blocks), (b) the boundary
# cases the old resident-block kernels never exercised: ragged last q_tile,
# k_out wider than the candidate set, empty frontiers, all-dangling rows,
# single-row grids.
# ---------------------------------------------------------------------------

# The jaxpr-walking logic lives in repro.analysis.jaxpr (PR 10) — the same
# engine `python -m repro.analysis` runs; these aliases keep the test bodies
# unchanged while guaranteeing the contract logic cannot drift across copies.
from repro.analysis.jaxpr import (  # noqa: E402
    assert_hbm_contract as _assert_hbm_contract,
    pallas_block_specs as _pallas_block_specs,
)


def _contract_fixture(rng, n=2048, avg_deg=6.0, q=16, k=8):
    from repro.core import verd as verd_mod

    g = synthetic.erdos_renyi(n, avg_deg, seed=7)
    cap = verd_mod.resolve_degree_cap(g)
    srcs = jnp.asarray(rng.integers(0, n, q), jnp.int32)
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32)
    return g, srcs, cap, fv, fi


@pytest.mark.parametrize("hub_split_degree", [0, 2])
def test_frontier_push_memory_contract(rng, hub_split_degree):
    """CSR arrays never enter the kernel as VMEM blocks: col_idx is an
    ANY/HBM ref, row_ptr/out_deg only feed O(Q*K) offset gathers outside,
    and every VMEM block is tile-sized (independent of n and m)."""
    from repro.core import verd as verd_mod

    g, srcs, cap, fv, fi = _contract_fixture(rng)
    q_tile, k_out = 8, 16
    blocks = _pallas_block_specs(
        push_mod.frontier_push, fv, fi, srcs,
        g.row_ptr, g.out_deg, g.col_idx,
        c=0.15, degree_cap=cap, k_out=k_out, q_tile=q_tile,
        hub_split_degree=hub_split_degree, interpret=True,
    )
    h, s = verd_mod.resolve_hub_splits(cap, hub_split_degree)
    budget = q_tile * fv.shape[1] * s * h + q_tile * max(fv.shape[1], k_out)
    assert budget < g.m and budget < g.n  # the assertion below is meaningful
    _assert_hbm_contract(
        blocks, hbm_shapes={(g.m,)}, vmem_budget=budget
    )
    # and the CSR arrays specifically never appear as VMEM blocks
    for csr_shape in [(g.n + 1,), (g.n,), (g.m,)]:
        assert all(
            space == "any" for shape, space in blocks if shape == csr_shape
        )


def test_sharded_push_memory_contract(rng):
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g, _, cap, fv, fi = _contract_fixture(rng)
    cfg = DistConfig(n=2048, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fi_local = jnp.clip(fi, 0, ns - 1)
    q_tile, wire_k = 4, 8
    m_shard = slabs.col_idx.shape[1]
    blocks = _pallas_block_specs(
        push_mod.sharded_frontier_push, fv, fi_local,
        slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, degree_cap=cap, ep=2, n_shard=ns, wire_k=wire_k,
        q_tile=q_tile, interpret=True,
    )
    h, s = verd_mod.resolve_hub_splits(cap, 0)
    budget = q_tile * fv.shape[1] * s * h + q_tile * 2 * wire_k
    assert budget < m_shard and budget < ns
    _assert_hbm_contract(blocks, hbm_shapes={(m_shard,)}, vmem_budget=budget)


def test_index_combine_sparse_memory_contract(rng):
    n, l, q, k, s_w = 600, 16, 16, 8, 8
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    sv = jnp.asarray(rng.random((q, s_w)), jnp.float32)
    si = jnp.asarray(rng.integers(0, n, (q, s_w)), jnp.int32)
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32)
    q_tile, k_out = 8, 16
    blocks = _pallas_block_specs(
        comb_mod.index_combine_sparse, sv, si, fv, fi, vals, idx,
        k_out=k_out, q_tile=q_tile, interpret=True,
    )
    budget = q_tile * k * l + q_tile * max(s_w, k, k_out) * 2
    assert budget < n * l
    _assert_hbm_contract(blocks, hbm_shapes={(n, l)}, vmem_budget=budget)
    # both [n, L] index arrays must be HBM refs
    assert sum(
        1 for shape, space in blocks if shape == (n, l) and space == "any"
    ) == 2


# -- boundary cases vs the dense oracles ------------------------------------

def _push_vs_ref(f0, g, srcs, *, k_out, q_tile=4, threshold=0.0, c=0.15,
                 hub_split_degree=0):
    from repro.core import frontier as F
    from repro.core import verd as verd_mod

    cap = verd_mod.resolve_degree_cap(g)
    got = ops.frontier_push(
        f0, g, srcs, c=c, degree_cap=cap, k_out=k_out, q_tile=q_tile,
        threshold=threshold, hub_split_degree=hub_split_degree,
        interpret=True,
    )
    rv, ri = ref.frontier_push_ref(
        f0.values, f0.indices, srcs, g.row_ptr, g.out_deg, g.col_idx,
        c=c, degree_cap=cap, k_out=k_out, threshold=threshold,
    )
    want = F.SparseFrontier(values=rv, indices=ri, k=k_out, n=g.n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-5, atol=1e-6,
    )
    return got


@pytest.mark.parametrize("q", [1, 3, 5, 7])
def test_frontier_push_ragged_last_tile(q, rng):
    """Q not a multiple of q_tile: the wrapper pads, pad rows stay empty."""
    from repro.core import frontier as F

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    srcs = jnp.asarray(rng.integers(0, g.n, q), jnp.int32)
    f0 = F.from_sources(srcs, g.n)
    got = _push_vs_ref(f0, g, srcs, k_out=12, q_tile=4)
    assert got.values.shape == (q, 12)


def test_frontier_push_k_out_wider_than_candidates(rng):
    """k_out beyond the candidate width: right-padded with empty slots."""
    from repro.core import frontier as F

    g = synthetic.erdos_renyi(30, 3.0, seed=2)
    srcs = jnp.asarray(rng.integers(0, g.n, 4), jnp.int32)
    f0 = F.from_sources(srcs, g.n)  # width-1 frontier: few candidates
    got = _push_vs_ref(f0, g, srcs, k_out=g.n, q_tile=4)
    # the padded tail obeys the empty-slot convention (0.0 at index 0)
    tail_mask = np.asarray(got.values) == 0
    assert (np.asarray(got.indices)[tail_mask] == 0).all()


def test_frontier_push_empty_frontier(rng):
    """All-zero frontier rows push nothing — not even dangling mass."""
    from repro.core import frontier as F

    g = synthetic.erdos_renyi(40, 4.0, seed=3)
    q, k = 5, 6
    f0 = F.SparseFrontier(
        values=jnp.zeros((q, k), jnp.float32),
        indices=jnp.zeros((q, k), jnp.int32), k=k, n=g.n,
    )
    srcs = jnp.asarray(rng.integers(0, g.n, q), jnp.int32)
    got = _push_vs_ref(f0, g, srcs, k_out=8)
    assert float(jnp.abs(got.values).max()) == 0.0
    assert int(jnp.abs(got.indices).max()) == 0


def test_frontier_push_all_dangling_rows(rng):
    """Frontier entirely on dangling vertices: every row's mass returns to
    its source as one (1-c)-weighted entry."""
    from repro.core import frontier as F

    # vertices 0..3 have edges; 4..9 are dangling
    src_e = np.array([0, 0, 1, 2, 3], np.int32)
    dst_e = np.array([1, 2, 3, 0, 1], np.int32)
    from repro.core.graph import Graph

    g = Graph.from_edges(src_e, dst_e, n=10)
    q = 3
    srcs = jnp.asarray([4, 5, 6], jnp.int32)
    fi = jnp.asarray(rng.integers(4, 10, (q, 4)), jnp.int32)
    fv = jnp.asarray(rng.random((q, 4)), jnp.float32)
    f0 = F.SparseFrontier(values=fv, indices=fi, k=4, n=g.n)
    got = _push_vs_ref(f0, g, srcs, k_out=6)
    dense = np.asarray(got.densify())
    want = np.zeros_like(dense)
    want[np.arange(q), np.asarray(srcs)] = 0.85 * np.asarray(fv).sum(axis=1)
    np.testing.assert_allclose(dense, want, rtol=1e-5, atol=1e-6)


def test_frontier_push_single_row_grid(rng):
    """Q == q_tile == 1: a one-step grid with a one-query tile."""
    from repro.core import frontier as F

    g = synthetic.erdos_renyi(50, 4.0, seed=5)
    srcs = jnp.asarray([7], jnp.int32)
    f0 = F.from_sources(srcs, g.n)
    got = _push_vs_ref(f0, g, srcs, k_out=10, q_tile=1)
    assert got.values.shape == (1, 10)


def test_sharded_push_ragged_and_empty(rng):
    """Sharded push: ragged Q + an all-zero frontier row in the same run."""
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(n=64, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    q, k = 5, 8  # ragged vs q_tile=4
    fv = jnp.asarray(rng.random((q, k)), jnp.float32).at[2].set(0.0)
    fi = jnp.asarray(rng.integers(0, ns, (q, k)), jnp.int32)
    got_v, got_i = ops.sharded_frontier_push(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, degree_cap=cap, ep=2, n_shard=ns, wire_k=ns,
        q_tile=4, interpret=True,
    )
    ref_v, ref_i = ref.sharded_push_ref(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, ep=2, n_shard=ns, wire_k=ns,
    )
    np.testing.assert_allclose(
        _dens_buckets(got_v, got_i, 2, ns),
        _dens_buckets(ref_v, ref_i, 2, ns),
        rtol=1e-5, atol=1e-6,
    )
    assert got_v.shape == (q, 2, ns)
    assert float(jnp.abs(got_v[2]).max()) == 0.0  # empty row stays empty


def test_sharded_push_wire_k_above_owner_support(rng):
    """wire_k > n_shard: buckets are right-padded, never truncated."""
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(24, 3.0, seed=4)
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(n=24, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((4, 4)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, ns, (4, 4)), jnp.int32)
    wire_k = ns + 5
    got_v, got_i = ops.sharded_frontier_push(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, degree_cap=cap, ep=2, n_shard=ns, wire_k=wire_k,
        q_tile=4, interpret=True,
    )
    ref_v, ref_i = ref.sharded_push_ref(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, ep=2, n_shard=ns, wire_k=wire_k,
    )
    np.testing.assert_allclose(
        _dens_buckets(got_v, got_i, 2, ns),
        _dens_buckets(ref_v, ref_i, 2, ns),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize("q,q_tile,k_out", [(3, 4, 40), (1, 1, 5), (6, 4, 7)])
def test_index_combine_sparse_boundaries(q, q_tile, k_out, rng):
    """Ragged Q, single-row grid, and k_out beyond the candidate width."""
    from repro.core import frontier as F

    n, l, k, s_w = 30, 6, 4, 5
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    sv = jnp.asarray(rng.random((q, s_w)), jnp.float32)
    si = jnp.asarray(rng.integers(0, n, (q, s_w)), jnp.int32)
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32)
    s = F.SparseFrontier(values=sv, indices=si, k=s_w, n=n)
    f = F.SparseFrontier(values=fv, indices=fi, k=k, n=n)
    got = ops.index_combine_sparse(
        s, f, vals, idx, k_out=k_out, q_tile=q_tile, interpret=True
    )
    rv, ri = ref.index_combine_sparse_ref(
        sv, si, fv, fi, vals, idx, k_out=k_out
    )
    want = F.SparseFrontier(values=rv, indices=ri, k=k_out, n=n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-5, atol=1e-6,
    )
    assert got.values.shape == (q, k_out)


def test_index_combine_sparse_empty_frontier(rng):
    """Zero frontier: the combine degenerates to compacting s alone."""
    from repro.core import frontier as F

    n, l, q, k = 20, 4, 4, 3
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    sv = jnp.asarray(rng.random((q, 5)), jnp.float32)
    si = jnp.asarray(rng.integers(0, n, (q, 5)), jnp.int32)
    s = F.SparseFrontier(values=sv, indices=si, k=5, n=n)
    f = F.SparseFrontier(
        values=jnp.zeros((q, k), jnp.float32),
        indices=jnp.zeros((q, k), jnp.int32), k=k, n=n,
    )
    got = ops.index_combine_sparse(s, f, vals, idx, k_out=8, interpret=True)
    from repro.core.frontier import compact

    want = compact(sv, si, 8, n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-6, atol=1e-7,
    )


@pytest.mark.parametrize("hub_split_degree", [0, 3])
def test_frontier_push_window_clip_at_csr_end(rng, hub_split_degree):
    """A hub whose row *closes* col_idx forces the last gather window past
    ``m - h``: the clip-shift path (``d > 0`` in masked_push_from_windows)
    must still deliver exactly the dense oracle's push.  (Hypothesis sweeps
    this with random hub placements in test_properties.py; this is the
    deterministic in-container regression.)"""
    from repro.core import frontier as F
    from repro.core.graph import Graph

    n, hub_deg = 12, 7
    src_e = np.concatenate([
        np.array([0, 1, 2, 3], np.int32),
        np.full(hub_deg, n - 1, np.int32),   # hub row ends the edge array
    ])
    dst_e = np.concatenate([
        np.array([1, 2, 3, 0], np.int32),
        np.arange(hub_deg, dtype=np.int32),
    ])
    g = Graph.from_edges(src_e, dst_e, n=n)
    q = 3
    fv = jnp.asarray(rng.random((q, 2)), jnp.float32)
    fi = jnp.asarray([[n - 1, 0], [1, n - 1], [n - 1, n - 1]], jnp.int32)
    srcs = jnp.asarray(rng.integers(0, n, q), jnp.int32)
    f0 = F.SparseFrontier(values=fv, indices=fi, k=2, n=n)
    _push_vs_ref(
        f0, g, srcs, k_out=n, q_tile=1, hub_split_degree=hub_split_degree
    )


# -- VMEM accounting + compiled-mode (real TPU) gates -----------------------

def test_push_vmem_accounting_independent_of_graph_size():
    """HBM-resident per-step VMEM must not grow with n or m; the legacy
    accounting (whole-array CSR blocks) must."""
    small = push_mod.vmem_bytes(8, 64, 32, degree_cap=16)
    assert small == push_mod.vmem_bytes(8, 64, 32, degree_cap=16)
    legacy_small = push_mod.vmem_bytes_legacy(
        8, 64, 32, n=1_000, m=8_000, degree_cap=16
    )
    legacy_big = push_mod.vmem_bytes_legacy(
        8, 64, 32, n=1_000_000, m=8_000_000, degree_cap=16
    )
    assert legacy_big > legacy_small > small
    # hub splitting bounds the scratch: splitting a cap-4096 gather into
    # width-64 sub-slots leaves the byte count unchanged (s*h == cap) but a
    # truncating split never grows it
    assert push_mod.vmem_bytes(
        8, 64, 32, degree_cap=4096, hub_split_degree=64
    ) == push_mod.vmem_bytes(8, 64, 32, degree_cap=4096)
    comb_small = comb_mod.sparse_vmem_bytes(8, 64, 16, 32, 32)
    comb_legacy = comb_mod.sparse_vmem_bytes_legacy(
        8, 64, 16, 32, 32, n=1_000_000
    )
    assert comb_legacy > comb_small


@pytest.mark.tpu
def test_frontier_push_compiled(rng):
    """interpret=False compile + run — the real-TPU gate for the DMA path."""
    from repro.core import frontier as F

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    srcs = jnp.asarray(rng.integers(0, g.n, 8), jnp.int32)
    f0 = F.from_sources(srcs, g.n)
    from repro.core import verd as verd_mod

    cap = verd_mod.resolve_degree_cap(g)
    got = ops.frontier_push(
        f0, g, srcs, c=0.15, degree_cap=cap, k_out=16, interpret=False
    )
    rv, ri = ref.frontier_push_ref(
        f0.values, f0.indices, srcs, g.row_ptr, g.out_deg, g.col_idx,
        c=0.15, degree_cap=cap, k_out=16,
    )
    np.testing.assert_allclose(
        np.asarray(got.densify()),
        np.asarray(F.SparseFrontier(
            values=rv, indices=ri, k=16, n=g.n).densify()),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.tpu
def test_sharded_push_compiled(rng):
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(n=64, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((8, 8)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, ns, (8, 8)), jnp.int32)
    got_v, got_i = ops.sharded_frontier_push(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, degree_cap=cap, ep=2, n_shard=ns, wire_k=ns,
        interpret=False,
    )
    ref_v, ref_i = ref.sharded_push_ref(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, ep=2, n_shard=ns, wire_k=ns,
    )
    np.testing.assert_allclose(
        _dens_buckets(got_v, got_i, 2, ns),
        _dens_buckets(ref_v, ref_i, 2, ns),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.tpu
def test_index_combine_sparse_compiled(rng):
    from repro.core import frontier as F

    n, l, q, k = 64, 8, 8, 4
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    s = F.SparseFrontier(
        values=jnp.asarray(rng.random((q, 4)), jnp.float32),
        indices=jnp.asarray(rng.integers(0, n, (q, 4)), jnp.int32),
        k=4, n=n,
    )
    f = F.SparseFrontier(
        values=jnp.asarray(rng.random((q, k)), jnp.float32),
        indices=jnp.asarray(rng.integers(0, n, (q, k)), jnp.int32),
        k=k, n=n,
    )
    got = ops.index_combine_sparse(s, f, vals, idx, k_out=8, interpret=False)
    rv, ri = ref.index_combine_sparse_ref(
        s.values, s.indices, f.values, f.indices, vals, idx, k_out=8
    )
    np.testing.assert_allclose(
        np.asarray(got.densify()),
        np.asarray(F.SparseFrontier(
            values=rv, indices=ri, k=8, n=n).densify()),
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# walk_step: the offline walk engine's fused bulk advance
# ---------------------------------------------------------------------------

def _walk_fixture(rng, n=512, avg_deg=5.0, w=256):
    g = synthetic.erdos_renyi(n, avg_deg, seed=13)
    cur = jnp.asarray(rng.integers(0, n, w), jnp.int32)
    src = jnp.asarray(rng.integers(0, n, w), jnp.int32)
    u = jnp.asarray(rng.random(w), jnp.float32)
    return g, cur, src, u


@pytest.mark.parametrize("w", [128, 256, 384])
def test_walk_step_matches_ref_bitwise(w, rng):
    """int outputs: the kernel must equal the oracle exactly, not approx."""
    g, cur, src, u = _walk_fixture(rng, w=w)
    got = walk_mod.walk_step(
        cur, src, u, g.row_ptr, g.out_deg, g.col_idx, interpret=True
    )
    want = ref.walk_step_ref(cur, src, u, g.row_ptr, g.out_deg, g.col_idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("w", [1, 5, 130])
def test_walk_step_wrapper_pads_ragged(w, rng):
    """W not a multiple of w_tile: ops.walk_step pads and slices."""
    g, cur, src, u = _walk_fixture(rng, w=max(w, 1))
    cur, src, u = cur[:w], src[:w], u[:w]
    got = ops.walk_step(cur, src, u, g.row_ptr, g.out_deg, g.col_idx)
    want = ref.walk_step_ref(cur, src, u, g.row_ptr, g.out_deg, g.col_idx)
    assert got.shape == (w,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_walk_step_wrapper_keeps_2d_shape(rng):
    g, cur, src, u = _walk_fixture(rng, w=96)
    cur2 = cur.reshape(8, 12)
    src2 = src.reshape(8, 12)
    u2 = u.reshape(8, 12)
    got = ops.walk_step(cur2, src2, u2, g.row_ptr, g.out_deg, g.col_idx)
    assert got.shape == (8, 12)
    want = ref.walk_step_ref(cur, src, u, g.row_ptr, g.out_deg, g.col_idx)
    np.testing.assert_array_equal(np.asarray(got).reshape(-1),
                                  np.asarray(want))


def test_walk_step_dangling_rows_jump_home(rng):
    """Dangling cursors must land on their walk's source, not a gather."""
    from repro.core.graph import Graph

    # vertices 3, 4 dangling; 0-2 form a cycle
    g = Graph.from_edges([0, 1, 2], [1, 2, 0], n=5)
    cur = jnp.asarray([3, 4, 0, 3] * 32, jnp.int32)
    src = jnp.asarray([1, 2, 4, 0] * 32, jnp.int32)
    u = jnp.asarray(np.linspace(0, 0.999, 128), jnp.float32)
    got = np.asarray(ops.walk_step(
        cur, src, u, g.row_ptr, g.out_deg, g.col_idx
    ))
    np.testing.assert_array_equal(got[0::4], 1)   # dangling -> source
    np.testing.assert_array_equal(got[1::4], 2)
    np.testing.assert_array_equal(got[2::4], 1)   # 0's only edge -> 1
    np.testing.assert_array_equal(got[3::4], 0)


def test_walk_step_clip_at_csr_end(rng):
    """The last CSR row's sampled address must stay inside col_idx even at
    u -> 1 (the clipped-window boundary the DMA reads)."""
    from repro.core.graph import Graph

    g = Graph.from_edges([0, 1, 1, 1], [1, 0, 0, 0], n=2)
    cur = jnp.full((128,), 1, jnp.int32)          # the last row, deg 3
    src = jnp.zeros((128,), jnp.int32)
    u = jnp.full((128,), 0.999999, jnp.float32)   # samples the last edge
    got = np.asarray(ops.walk_step(
        cur, src, u, g.row_ptr, g.out_deg, g.col_idx
    ))
    np.testing.assert_array_equal(got, 0)


def test_walk_step_edgeless_fallback(rng):
    from repro.core.graph import Graph

    g = Graph.from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), n=4)
    cur = jnp.asarray([0, 1, 2, 3], jnp.int32)
    src = jnp.asarray([3, 2, 1, 0], jnp.int32)
    u = jnp.zeros((4,), jnp.float32)
    got = ops.walk_step(cur, src, u, g.row_ptr, g.out_deg, g.col_idx)
    np.testing.assert_array_equal(np.asarray(got), [3, 2, 1, 0])


def test_walk_step_memory_contract(rng):
    """col_idx must ride as an ANY/HBM ref; every VMEM block stays O(w_tile)
    — independent of n and nnz (the DMA-gather discipline)."""
    g, cur, src, u = _walk_fixture(rng, n=4096, w=256)
    blocks = _pallas_block_specs(
        walk_mod.walk_step, cur, src, u, g.row_ptr, g.out_deg, g.col_idx,
        w_tile=128, interpret=True,
    )
    budget = walk_mod.vmem_bytes(128) // 4 + 128  # elements, not bytes
    assert budget < g.m and budget < g.n
    _assert_hbm_contract(blocks, hbm_shapes={(g.m,)}, vmem_budget=budget)
    for csr_shape in [(g.n + 1,), (g.n,), (g.m,)]:
        assert all(
            space == "any" for shape, space in blocks if shape == csr_shape
        )


def test_walk_step_vmem_accounting():
    assert walk_mod.vmem_bytes(128) < 16 * 1024
    assert walk_mod.vmem_bytes(128) == walk_mod.vmem_bytes(128)


@pytest.mark.tpu
def test_walk_step_compiled(rng):
    """interpret=False compile + run — the real-TPU gate for the DMA path."""
    g, cur, src, u = _walk_fixture(rng, w=256)
    got = walk_mod.walk_step(
        cur, src, u, g.row_ptr, g.out_deg, g.col_idx, interpret=False
    )
    want = ref.walk_step_ref(cur, src, u, g.row_ptr, g.out_deg, g.col_idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
