"""Pallas-kernel validation: interpret-mode sweeps vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import push_forward
from repro.graphs import formats, synthetic
from repro.kernels import ops, ref
from repro.kernels.ell_spmm import ell_spmm, vmem_bytes
from repro.kernels.embedding_bag import embedding_bag as bag_kernel
from repro.kernels.index_combine import index_combine as comb_kernel

TOL = dict(
    float32=dict(rtol=1e-5, atol=1e-6),
    bfloat16=dict(rtol=2e-2, atol=2e-2),
)


def _tols(dtype):
    return TOL[jnp.dtype(dtype).name]


# ---------------------------------------------------------------------------
# ell_spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,rows,k,n", [
    (8, 256, 8, 64),
    (16, 512, 16, 128),
    (8, 256, 4, 32),
    (24, 768, 32, 200),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ell_spmm_matches_ref(q, rows, k, n, dtype, rng):
    f = jnp.asarray(rng.random((q, n)), dtype)
    nbr = jnp.asarray(rng.integers(0, n, (rows, k)), jnp.int32)
    w = jnp.asarray(rng.random((rows, k)), dtype)
    got = ell_spmm(f, nbr, w, q_tile=8, r_tile=256, interpret=True)
    want = ref.ell_spmm_ref(f, nbr, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tols(dtype),
    )


def test_ell_spmm_bf16(rng):
    f = jnp.asarray(rng.random((8, 64)), jnp.bfloat16)
    nbr = jnp.asarray(rng.integers(0, 64, (256, 8)), jnp.int32)
    w = jnp.asarray(rng.random((256, 8)), jnp.bfloat16)
    got = ell_spmm(f, nbr, w, q_tile=8, r_tile=256, interpret=True)
    want = ref.ell_spmm_ref(
        f.astype(jnp.float32), nbr, w.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), **TOL["bfloat16"]
    )


def test_ell_push_equals_graph_push(rng):
    """End-to-end: Pallas ELL push == edge-parallel push_forward."""
    g = synthetic.rmat(8, avg_deg=6.0, seed=5)
    ell = formats.to_ell_chunks(g, k=8)
    f = jnp.asarray(rng.random((5, g.n)), jnp.float32)
    got = ops.ell_push(f, ell, q_tile=8, r_tile=256, interpret=True)
    want = push_forward(g, f)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_ell_pull_pure_jnp_equals_push(rng):
    g = synthetic.erdos_renyi(100, 5.0, seed=4)
    ell = formats.to_ell_chunks(g, k=4)
    f = jnp.asarray(rng.random((3, g.n)), jnp.float32)
    got = formats.ell_pull(ell, f)
    want = push_forward(g, f)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_ell_hub_splitting():
    """A hub with in-degree >> k must fold correctly across chunk rows."""
    g = synthetic.star(50)  # every spoke points at vertex 0
    ell = formats.to_ell_chunks(g, k=4)
    f = jnp.ones((1, g.n), jnp.float32)
    got = ops.ell_push(f, ell, interpret=True)
    want = push_forward(g, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_vmem_budget_accounting():
    assert vmem_bytes(8, 256, 16, 4096) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# index_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,l", [(8, 128, 8), (16, 256, 16), (4, 64, 4)])
def test_index_combine_matches_ref(q, n, l, rng):
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    f = jnp.asarray(rng.random((q, n)), jnp.float32)
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    got = comb_kernel(s, f, vals, idx, q_tile=4, v_tile=64, interpret=True)
    want = ref.index_combine_ref(s, f, vals, idx)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_index_combine_wrapper_pads(rng):
    q, n, l = 5, 100, 7  # deliberately unaligned
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    f = jnp.asarray(rng.random((q, n)), jnp.float32)
    vals = jnp.asarray(rng.random((n, l)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (n, l)), jnp.int32)
    got = ops.index_combine(s, f, vals, idx, interpret=True)
    want = ref.index_combine_ref(s, f, vals, idx)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_index_combine_matches_core_combine(rng):
    """Kernel == the chunked-scan implementation in core.verd."""
    from repro.core.index import index_from_dense
    from repro.core.verd import combine_with_index

    q, n, l = 6, 96, 12
    s = jnp.asarray(rng.random((q, n)), jnp.float32)
    f = jnp.asarray(rng.random((q, n)), jnp.float32)
    dense = jnp.asarray(rng.random((n, n)), jnp.float32)
    idx = index_from_dense(dense, l=l)
    want = combine_with_index(s, f, idx, vertex_chunk=32)
    got = ops.index_combine(s, f, idx.values, idx.indices, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


# ---------------------------------------------------------------------------
# frontier_push + index_combine_sparse (sparse online path)
# ---------------------------------------------------------------------------

def _frontier_fixture(rng, n=60, q=5):
    from repro.core import verd as verd_mod

    g = synthetic.erdos_renyi(n, 4.0, seed=11)
    srcs = jnp.asarray(rng.integers(0, n, q), jnp.int32)
    cap = verd_mod.resolve_degree_cap(g)
    return g, srcs, cap


def test_frontier_push_kernel_matches_ref(rng):
    from repro.core import frontier as F

    g, srcs, cap = _frontier_fixture(rng)
    f0 = F.from_sources(srcs, g.n)
    got = ops.frontier_push(
        f0, g, srcs, c=0.15, degree_cap=cap, k_out=16, interpret=True
    )
    rv, ri = ref.frontier_push_ref(
        f0.values, f0.indices, srcs, g.row_ptr, g.out_deg, g.col_idx,
        c=0.15, degree_cap=cap, k_out=16,
    )
    want = F.SparseFrontier(values=rv, indices=ri, k=16, n=g.n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-5, atol=1e-6,
    )


def test_frontier_push_kernel_two_iterations(rng):
    """Kernel iterated == verd_iterate_sparse's f after two pushes."""
    from repro.core import frontier as F
    from repro.core import verd as verd_mod

    g, srcs, cap = _frontier_fixture(rng)
    k = g.n
    f = F.from_sources(srcs, g.n)
    for _ in range(2):
        f = ops.frontier_push(
            f, g, srcs, c=0.15, degree_cap=cap, k_out=k, interpret=True
        )
    _, f_want = verd_mod.verd_iterate_sparse(g, srcs, t=2, k=k, c=0.15)
    np.testing.assert_allclose(
        np.asarray(f.densify()), np.asarray(f_want.densify()),
        rtol=1e-5, atol=1e-6,
    )


def test_index_combine_sparse_kernel_matches_ref(rng):
    from repro.core import frontier as F
    from repro.core import verd as verd_mod
    from repro.core.index import index_from_dense

    g, srcs, cap = _frontier_fixture(rng)
    dense = jnp.asarray(rng.random((g.n, g.n)), jnp.float32)
    idx = index_from_dense(dense, l=12)
    s, f = verd_mod.verd_iterate_sparse(g, srcs, t=2, k=g.n, degree_cap=cap)
    got = ops.index_combine_sparse(
        s, f, idx.values, idx.indices, k_out=10, interpret=True
    )
    rv, ri = ref.index_combine_sparse_ref(
        s.values, s.indices, f.values, f.indices, idx.values, idx.indices,
        k_out=10,
    )
    want = F.SparseFrontier(values=rv, indices=ri, k=10, n=g.n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()),
        rtol=1e-5, atol=1e-6,
    )
    # the fused sparse combine also equals the jnp core implementation
    core = verd_mod.combine_with_index_sparse(s, f, idx, out_k=10)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(core.densify()),
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# sharded_frontier_push (distributed sparse-exchange half-iteration)
# ---------------------------------------------------------------------------

def _dens_buckets(vals, idx, ep, ns):
    """Scatter per-owner buckets back to dense [Q, ep, ns] for comparison
    (bucket top-k order may tie-break differently than the oracle's)."""
    from conftest import densify_rows

    return np.stack(
        [densify_rows(np.asarray(vals)[:, o], np.asarray(idx)[:, o], ns)
         for o in range(ep)],
        axis=1,
    )


@pytest.mark.parametrize("q,k,shards,hub_split_degree", [
    (5, 8, 1, 0),      # degenerate 1-shard case
    (5, 8, 1, 2),
    (8, 16, 2, 0),
    (8, 16, 2, 3),
    (3, 4, 4, 0),
    (3, 4, 4, 1),
])
def test_sharded_push_kernel_matches_ref(q, k, shards, hub_split_degree, rng):
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    cap = verd_mod.resolve_degree_cap(g)
    n_pad = 64
    cfg = DistConfig(n=n_pad, ep=shards, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, ns, (q, k)), jnp.int32)
    for s in range(shards):
        got_v, got_i = ops.sharded_frontier_push(
            fv, fi, slabs.row_ptr[s], slabs.col_idx[s],
            c=0.15, degree_cap=cap, ep=shards, n_shard=ns, wire_k=ns,
            hub_split_degree=hub_split_degree, q_tile=1, interpret=True,
        )
        ref_v, ref_i = ref.sharded_push_ref(
            fv, fi, slabs.row_ptr[s], slabs.col_idx[s],
            c=0.15, ep=shards, n_shard=ns, wire_k=ns,
        )
        np.testing.assert_allclose(
            _dens_buckets(got_v, got_i, shards, ns),
            _dens_buckets(ref_v, ref_i, shards, ns),
            rtol=1e-5, atol=1e-6,
        )


def test_sharded_push_truncated_wire_is_top_k(rng):
    """wire_k below the owner support keeps exactly the per-owner top-k."""
    from repro.core import verd as verd_mod
    from repro.core.distributed_engine import DistConfig, build_sharded_graph

    g = synthetic.erdos_renyi(60, 4.0, seed=11)
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(n=64, ep=2, degree_cap=cap)
    slabs = build_sharded_graph(g, cfg)
    ns = cfg.n_shard
    fv = jnp.asarray(rng.random((4, 8)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, ns, (4, 8)), jnp.int32)
    wire_k = 4
    got_v, _ = ops.sharded_frontier_push(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, degree_cap=cap, ep=2, n_shard=ns, wire_k=wire_k,
        q_tile=4, interpret=True,
    )
    full_v, full_i = ref.sharded_push_ref(
        fv, fi, slabs.row_ptr[0], slabs.col_idx[0],
        c=0.15, ep=2, n_shard=ns, wire_k=ns,
    )
    want = np.sort(np.asarray(full_v), axis=2)[:, :, ::-1][:, :, :wire_k]
    np.testing.assert_allclose(
        np.sort(np.asarray(got_v), axis=2)[:, :, ::-1], want,
        rtol=1e-5, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,bag,v,d", [
    (64, 4, 100, 128),
    (128, 16, 50, 256),
    (64, 1, 10, 128),
])
def test_embedding_bag_matches_ref(b, bag, v, d, rng):
    ids = jnp.asarray(rng.integers(0, v, (b, bag)), jnp.int32)
    mask = jnp.asarray(rng.random((b, bag)) > 0.3, jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    got = bag_kernel(ids, mask, table, b_tile=64, d_tile=128, interpret=True)
    want = ref.embedding_bag_ref(ids, mask, table)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_embedding_bag_wrapper_unaligned(rng):
    b, bag, v, d = 37, 3, 20, 48  # unaligned batch and dim
    ids = jnp.asarray(rng.integers(0, v, (b, bag)), jnp.int32)
    mask = jnp.ones((b, bag), jnp.float32)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    got = ops.embedding_bag(ids, mask, table, interpret=True)
    want = ref.embedding_bag_ref(ids, mask, table)
    assert got.shape == (b, d)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
