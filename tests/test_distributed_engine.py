"""Distributed-engine equivalence: runs the 4-device check in a subprocess
(the main test process must keep seeing exactly 1 device)."""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # spawns a 4-device subprocess


def test_distributed_engine_matches_dense():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(os.path.dirname(__file__), "dist_engine_check.py")
    res = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL OK" in res.stdout
