"""Cross-path parity suite: distributed-sparse vs single-device-sparse vs
dense oracle (the three-path test matrix of docs/query_path.md).

The multi-shard half runs in a 4-fake-device subprocess
(``tests/parity_check.py``, marked ``slow``); the degenerate 1-shard case
and the wire-byte accounting run in-process on the single real CPU device.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import densify_rows
from repro.core import verd as verd_mod
from repro.core.distributed_engine import (
    DistConfig, build_sharded_graph, exchange_bytes_per_iteration,
    make_verd_tile_step,
)
from repro.core.index import index_from_dense
from repro.core.power_iteration import exact_ppr_dense
from repro.graphs import synthetic


@pytest.fixture(scope="module")
def setup():
    g = synthetic.erdos_renyi(60, 4.0, seed=9)
    exact = exact_ppr_dense(g)
    n_pad = 64
    dense = np.zeros((n_pad, n_pad), np.float32)
    dense[: g.n, : g.n] = exact
    return g, jnp.asarray(dense), n_pad


_densify = densify_rows


@pytest.mark.parametrize("hub_split_degree", [0, 2])
def test_one_shard_matches_single_device_sparse(setup, hub_split_degree):
    """Degenerate ep=1 mesh: the sharded engine *is* the sparse path."""
    g, dense, n_pad = setup
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(
        n=n_pad, ep=1, q_tile=4, t_iterations=2, index_l=16, top_k=n_pad,
        frontier_k=n_pad, degree_cap=cap, hub_split_degree=hub_split_degree,
    )
    slabs = build_sharded_graph(g, cfg)
    idx = index_from_dense(dense, l=cfg.index_l)
    ivals = idx.values.reshape(1, cfg.n_shard, cfg.index_l)
    iidx = idx.indices.reshape(1, cfg.n_shard, cfg.index_l)
    sources = jnp.asarray([0, 5, 17, 42], jnp.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_verd_tile_step(cfg, mesh)
    with mesh:
        tv, ti = jax.jit(step)(slabs, sources, ivals, iidx)
    got = _densify(tv, ti, n_pad)

    idx_small = index_from_dense(dense[: g.n, : g.n], l=cfg.index_l)
    sp = verd_mod.verd_query_sparse(
        g, sources, idx_small, t=2, k=g.n, out_k=n_pad
    )
    want = np.zeros_like(got)
    want[:, : g.n] = np.asarray(sp.densify())
    assert np.abs(got - want).sum(axis=1).max() <= 1e-5

    # and the dense oracle agrees too (three-path closure)
    oracle = np.asarray(verd_mod.verd_query(g, sources, idx_small, t=2))
    assert np.abs(got[:, : g.n] - oracle).sum(axis=1).max() <= 1e-5


def test_one_shard_truncated_wire_bounded(setup):
    g, dense, n_pad = setup
    cap = verd_mod.resolve_degree_cap(g)
    base = dict(n=n_pad, ep=1, q_tile=4, t_iterations=2, index_l=16,
                top_k=n_pad, degree_cap=cap)
    idx = index_from_dense(dense, l=16)
    ivals = idx.values.reshape(1, n_pad, 16)
    iidx = idx.indices.reshape(1, n_pad, 16)
    sources = jnp.asarray([0, 5, 17, 42], jnp.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    outs = {}
    for name, kw in [("exact", dict(frontier_k=n_pad)),
                     ("trunc", dict(frontier_k=4, wire_k=4))]:
        cfg = DistConfig(**base, **kw)
        slabs = build_sharded_graph(g, cfg)
        step = make_verd_tile_step(cfg, mesh)
        with mesh:
            tv, ti = jax.jit(step)(slabs, sources, ivals, iidx)
        outs[name] = _densify(tv, ti, n_pad)
    exact, trunc = outs["exact"], outs["trunc"]
    assert (trunc <= exact + 1e-6).all()
    dropped = exact.sum(axis=1) - trunc.sum(axis=1)
    l1 = np.abs(exact - trunc).sum(axis=1)
    assert (l1 <= dropped + 1e-5).all()


def test_wire_bytes_reduction_at_acceptance_point():
    """Acceptance gate: >= 5x fewer wire bytes/iteration than the dense
    exchange at n=100k, Q=256, K=512 (the bench_query report)."""
    cfg = DistConfig(n=100_000, ep=4, q_tile=256, frontier_k=512,
                     wire_k=512, degree_cap=1)
    bytes_ = exchange_bytes_per_iteration(cfg)
    assert bytes_["reduction"] >= 5.0, bytes_
    # dense slab: qt * n * 4B; sparse: qt * ep * wire_k * 8B
    assert bytes_["dense"] == 256 * 100_000 * 4
    assert bytes_["sparse"] == 256 * 4 * 512 * 8


def test_compress_k_is_deprecated():
    with pytest.warns(DeprecationWarning, match="compress_k"):
        cfg = DistConfig(n=64, ep=2, compress_k=16)
    # the knob now only feeds the sparse wire width when wire_k is unset
    assert cfg.resolved_wire_k == 16


def test_engine_routes_through_fused_kernel(setup):
    """Routing regression: the sparse tile step must go through the fused
    Pallas wrapper ``kernels.ops.sharded_frontier_push`` (once per VERD
    iteration at trace time), not a duplicated jnp path — while still
    matching the dense oracle."""
    from repro.kernels import ops as kernel_ops

    g, dense, n_pad = setup
    cap = verd_mod.resolve_degree_cap(g)
    cfg = DistConfig(
        n=n_pad, ep=1, q_tile=4, t_iterations=2, index_l=16, top_k=n_pad,
        frontier_k=n_pad, degree_cap=cap,
    )
    slabs = build_sharded_graph(g, cfg)
    idx = index_from_dense(dense, l=cfg.index_l)
    ivals = idx.values.reshape(1, cfg.n_shard, cfg.index_l)
    iidx = idx.indices.reshape(1, cfg.n_shard, cfg.index_l)
    sources = jnp.asarray([0, 5, 17, 42], jnp.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_verd_tile_step(cfg, mesh)
    kernel_ops.reset_kernel_invocations()
    with mesh:
        tv, ti = jax.jit(step)(slabs, sources, ivals, iidx)
    counts = kernel_ops.kernel_invocations()
    assert counts.get("sharded_frontier_push", 0) == cfg.t_iterations, counts

    idx_small = index_from_dense(dense[: g.n, : g.n], l=cfg.index_l)
    oracle = np.asarray(verd_mod.verd_query(g, sources, idx_small, t=2))
    got = _densify(tv, ti, n_pad)
    assert np.abs(got[:, : g.n] - oracle).sum(axis=1).max() <= 1e-5


def test_kernel_interpret_resolution():
    """Off-TPU the engine defaults the fused kernel to interpret mode; an
    explicit setting wins either way."""
    assert DistConfig(n=64, ep=1).resolved_kernel_interpret is True  # CPU here
    assert DistConfig(
        n=64, ep=1, kernel_interpret=False
    ).resolved_kernel_interpret is False


def test_sparse_exchange_requires_degree_cap():
    cfg = DistConfig(n=64, ep=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="degree_cap"):
        make_verd_tile_step(cfg, mesh)


def test_rejects_unknown_exchange():
    with pytest.raises(ValueError, match="exchange"):
        DistConfig(n=64, ep=2, exchange="bogus")


@pytest.mark.slow  # spawns a 4-device subprocess
def test_four_shard_parity_suite():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(os.path.dirname(__file__), "parity_check.py")
    res = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL OK" in res.stdout
