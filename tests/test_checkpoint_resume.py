"""Crash-safe build contract: a resumed build equals an uninterrupted one
**bitwise** — values, indices, touch filters, and conservation ledgers —
single-device and sharded/padded, with ``.tmp`` dirs and checksum-corrupted
steps never restored.

In-process halves inject clean Python faults (``repro.testing.faults``);
the ``slow`` half drives real SIGKILLs through a subprocess
(``tests/fault_injection_check.py``).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core.index import (build_index, build_index_sharded,
                              load_index_checkpoint)
from repro.core.updates import (apply_updates, build_maintainable_index,
                                load_maintainable_index)
from repro.distributed.checkpoint import Checkpointer
from repro.graphs import synthetic
from repro.testing import FaultPlan, InjectedFault

BUILD = dict(c=0.25, max_steps=24, source_batch=8, touch_bits=16)
R, L = 2, 4


@pytest.fixture(scope="module")
def graph():
    return synthetic.erdos_renyi(48, 5.0, seed=7)


@pytest.fixture(scope="module")
def reference(graph):
    """Uninterrupted, checkpoint-free single-device build."""
    return build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse", **BUILD)


def _assert_index_equal(index, stats, ref_index, ref_stats):
    assert np.array_equal(
        np.asarray(index.values), np.asarray(ref_index.values))
    assert np.array_equal(
        np.asarray(index.indices), np.asarray(ref_index.indices))
    assert np.array_equal(
        np.asarray(stats["touch"]), np.asarray(ref_stats["touch"]))
    assert stats["kept_mass"] == ref_stats["kept_mass"]
    assert stats["dropped_mass"] == ref_stats["dropped_mass"]


def test_checkpointed_build_matches_plain_build(graph, reference, tmp_path):
    ref_index, ref_stats = reference
    index, stats = build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), checkpoint_every=2, **BUILD)
    _assert_index_equal(index, stats, ref_index, ref_stats)
    assert stats["checkpoint_commits"] == 2      # 6 chunks, partials at 2,4
    assert Checkpointer(str(tmp_path)).latest_step() == 6  # final commit


@pytest.mark.parametrize("crash_chunk", [1, 3, 5])
def test_resume_after_crash_is_bitwise(graph, reference, tmp_path,
                                       crash_chunk):
    ref_index, ref_stats = reference
    with pytest.raises(InjectedFault):
        build_index(
            graph, R, L, jax.random.PRNGKey(5), engine="sparse",
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            fault_plan=FaultPlan(raise_at_chunks=(crash_chunk,)), **BUILD)
    index, stats = build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), checkpoint_every=1, resume=True,
        **BUILD)
    assert stats["resumed_at_chunk"] == crash_chunk
    _assert_index_equal(index, stats, ref_index, ref_stats)


def test_mid_commit_crash_leaves_only_tmp(graph, reference, tmp_path):
    """A crash between write-out and the atomic rename must leave a ``.tmp``
    dir that restore ignores, falling back to the prior committed step."""
    ref_index, ref_stats = reference
    with pytest.raises(InjectedFault):
        build_index(
            graph, R, L, jax.random.PRNGKey(5), engine="sparse",
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            fault_plan=FaultPlan(raise_mid_commit=(3,)), **BUILD)
    names = sorted(os.listdir(tmp_path))
    assert "step_3.tmp" in names and "step_3" not in names
    index, stats = build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), checkpoint_every=1, resume=True,
        **BUILD)
    assert stats["resumed_at_chunk"] == 2        # step 3 never committed
    _assert_index_equal(index, stats, ref_index, ref_stats)


def test_corrupted_step_falls_back_never_restores(graph, reference,
                                                  tmp_path):
    ref_index, ref_stats = reference
    with pytest.raises(InjectedFault):
        build_index(
            graph, R, L, jax.random.PRNGKey(5), engine="sparse",
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            fault_plan=FaultPlan(raise_at_chunks=(4,)), **BUILD)
    # bit-rot the newest committed step's shard bytes
    shard = tmp_path / "step_4" / "arr_0.npy"
    raw = bytearray(shard.read_bytes())
    raw[-16:] = b"\xaa" * 16
    shard.write_bytes(bytes(raw))
    assert not Checkpointer(str(tmp_path)).verify_step(4)
    index, stats = build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), checkpoint_every=1, resume=True,
        **BUILD)
    assert stats["resumed_at_chunk"] == 3        # fell back past step 4
    _assert_index_equal(index, stats, ref_index, ref_stats)


def test_resume_refuses_foreign_signature(graph, tmp_path):
    """Resuming a different build (other key, other graph) into the same
    directory must fail loudly, not splice RNG streams."""
    with pytest.raises(InjectedFault):
        build_index(
            graph, R, L, jax.random.PRNGKey(5), engine="sparse",
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            fault_plan=FaultPlan(raise_at_chunks=(2,)), **BUILD)
    with pytest.raises(ValueError, match="signature mismatch"):
        build_index(
            graph, R, L, jax.random.PRNGKey(6), engine="sparse",
            checkpoint_dir=str(tmp_path), checkpoint_every=1, resume=True,
            **BUILD)
    other = synthetic.erdos_renyi(48, 5.0, seed=8)
    with pytest.raises(ValueError, match="signature mismatch"):
        build_index(
            other, R, L, jax.random.PRNGKey(5), engine="sparse",
            checkpoint_dir=str(tmp_path), checkpoint_every=1, resume=True,
            **BUILD)


def test_resume_of_complete_build_short_circuits(graph, reference, tmp_path):
    ref_index, ref_stats = reference
    build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), checkpoint_every=2, **BUILD)
    index, stats = build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), resume=True, **BUILD)
    assert stats.get("resumed_complete") is True
    _assert_index_equal(index, stats, ref_index, ref_stats)
    # and the serving boot path reads the same bits
    lindex, lstats = load_index_checkpoint(str(tmp_path))
    assert np.array_equal(
        np.asarray(lindex.values), np.asarray(ref_index.values))
    assert np.array_equal(
        np.asarray(lstats["touch"]), np.asarray(ref_stats["touch"]))
    assert lstats["touch_bits"] == BUILD["touch_bits"]


# -- sharded / padded --------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_setup():
    # 50 vertices on a 1-shard model axis pads to 56 (7 chunks of 8): the
    # padded tail exercises the pad-row zeroing through commit/resume
    g = synthetic.erdos_renyi(50, 5.0, seed=11)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    kw = dict(mesh=mesh, c=0.25, max_steps=24, source_batch=8,
              touch_bits=16)
    ref = build_index_sharded(g, R, L, jax.random.PRNGKey(5), **kw)
    return g, mesh, kw, ref


def test_sharded_checkpointed_matches_plain(sharded_setup, tmp_path):
    g, mesh, kw, (ref_index, ref_stats) = sharded_setup
    index, stats = build_index_sharded(
        g, R, L, jax.random.PRNGKey(5),
        checkpoint_dir=str(tmp_path), checkpoint_every=2, **kw)
    assert index.n == ref_index.n == 56          # padded row space
    _assert_index_equal(index, stats, ref_index, ref_stats)
    # the index comes back device-placed equivalently to the plain build
    assert index.values.sharding.is_equivalent_to(
        ref_index.values.sharding, 2)


def test_sharded_resume_is_bitwise(sharded_setup, tmp_path):
    g, mesh, kw, (ref_index, ref_stats) = sharded_setup
    with pytest.raises(InjectedFault):
        build_index_sharded(
            g, R, L, jax.random.PRNGKey(5), checkpoint_dir=str(tmp_path),
            checkpoint_every=2,
            fault_plan=FaultPlan(raise_at_chunks=(4,)), **kw)
    index, stats = build_index_sharded(
        g, R, L, jax.random.PRNGKey(5), checkpoint_dir=str(tmp_path),
        checkpoint_every=2, resume=True, **kw)
    assert stats["resumed_at_chunk"] == 4
    _assert_index_equal(index, stats, ref_index, ref_stats)
    # resumed-of-complete short circuit, still bitwise
    index2, stats2 = build_index_sharded(
        g, R, L, jax.random.PRNGKey(5), checkpoint_dir=str(tmp_path),
        resume=True, **kw)
    assert stats2.get("resumed_complete") is True
    assert np.array_equal(
        np.asarray(index2.values), np.asarray(ref_index.values))


def test_sharded_mid_commit_tmp_ignored(sharded_setup, tmp_path):
    g, mesh, kw, (ref_index, ref_stats) = sharded_setup
    with pytest.raises(InjectedFault):
        build_index_sharded(
            g, R, L, jax.random.PRNGKey(5), checkpoint_dir=str(tmp_path),
            checkpoint_every=1,
            fault_plan=FaultPlan(raise_mid_commit=(3,)), **kw)
    names = sorted(os.listdir(tmp_path))
    assert "step_3.tmp" in names and "step_3" not in names
    index, stats = build_index_sharded(
        g, R, L, jax.random.PRNGKey(5), checkpoint_dir=str(tmp_path),
        checkpoint_every=1, resume=True, **kw)
    assert stats["resumed_at_chunk"] == 2
    _assert_index_equal(index, stats, ref_index, ref_stats)


# -- maintainable index / repair on a resumed index --------------------------

def test_maintainable_resume_and_repair_parity(graph, tmp_path):
    key = jax.random.PRNGKey(13)
    kw = dict(c=0.25, max_steps=24, source_batch=8, touch_bits=64)
    ref_m, _ = build_maintainable_index(graph, R, L, key, **kw)
    ins = np.array([[1, 5], [7, 2]])
    _, ref_m2, _ = apply_updates(ref_m, graph, inserts=ins)

    with pytest.raises(InjectedFault):
        build_maintainable_index(
            graph, R, L, key, checkpoint_dir=str(tmp_path),
            checkpoint_every=1,
            fault_plan=FaultPlan(raise_at_chunks=(3,)), **kw)
    m, stats = build_maintainable_index(
        graph, R, L, key, checkpoint_dir=str(tmp_path),
        checkpoint_every=1, resume=True, **kw)
    assert stats["resumed_at_chunk"] == 3
    assert np.array_equal(
        np.asarray(m.touch.bits), np.asarray(ref_m.touch.bits))
    # repair on the resumed index replays the same chunks bit-identically
    _, m2, _ = apply_updates(m, graph, inserts=ins)
    assert np.array_equal(
        np.asarray(m2.index.values), np.asarray(ref_m2.index.values))
    assert np.array_equal(
        np.asarray(m2.index.indices), np.asarray(ref_m2.index.indices))

    # the reload path reconstructs key + chunk grid and repairs identically
    mL, _ = load_maintainable_index(str(tmp_path))
    assert mL.params == ref_m.params
    assert np.array_equal(np.asarray(mL.key), np.asarray(ref_m.key))
    _, m2L, _ = apply_updates(mL, graph, inserts=ins)
    assert np.array_equal(
        np.asarray(m2L.index.values), np.asarray(ref_m2.index.values))


def test_load_maintainable_requires_touch(graph, tmp_path):
    build_index(
        graph, R, L, jax.random.PRNGKey(5), engine="sparse",
        checkpoint_dir=str(tmp_path), c=0.25, max_steps=24, source_batch=8)
    with pytest.raises(ValueError, match="touch"):
        load_maintainable_index(str(tmp_path))


def test_service_boots_from_checkpoint(graph, tmp_path):
    from repro.serving.engine import PPRService

    key = jax.random.PRNGKey(13)
    m, _ = build_maintainable_index(
        graph, R, L, key, c=0.25, max_steps=24, source_batch=8,
        touch_bits=64, checkpoint_dir=str(tmp_path))
    svc = PPRService.from_checkpoint(graph, str(tmp_path))
    assert svc.maintainer is not None
    svc.submit(3)
    answers = svc.poll(force=True)
    assert len(answers) == 1 and not answers[0].rejected
    # updates keep working across the restart boundary
    report = svc.apply_updates(inserts=np.array([[0, 5]]))
    assert report["dirty_rows"] >= 1
    assert svc.stats["updates_applied"] == 1


def test_checkpointing_requires_sparse_engine(graph, tmp_path):
    with pytest.raises(ValueError, match="sparse"):
        build_index(
            graph, R, L, jax.random.PRNGKey(5), engine="dense",
            checkpoint_dir=str(tmp_path))


@pytest.mark.slow  # several subprocess JAX startups + SIGKILL round-trips
def test_sigkill_crash_resume_suite():
    """Real preemption: the subprocess driver SIGKILLs builds at chunk
    boundaries and mid-commit, corrupts a committed shard, resumes, and
    asserts bitwise parity with the uninterrupted build (both engines)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(
        os.path.dirname(__file__), "fault_injection_check.py")
    res = subprocess.run(
        [sys.executable, script], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL OK" in res.stdout
