"""Serving-pipeline suite: batching tiers/padding, the async completion
queue, answer parity across pipeline depths, and the open-loop harness.

The parity tests are the PR's contract: pipelining changes *when* answers
materialize, never *what* they are — any depth must produce the same
arrays as the depth=1 blocking path, on the dense and sparse frontier
routes and against a padded (sharded-build-shaped) index.
"""

import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import query as query_mod
from repro.core.index import PPRIndex
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.graphs import synthetic
from repro.serving import (PipelineConfig, PPRService, ServiceConfig,
                           run_closed_loop, run_open_loop)
from repro.serving.batching import (BatchingConfig, BufferOverloadError,
                                    RequestBuffer, TierPolicy)
from repro.serving.pipeline import CompletionQueue, PendingBatch, ServingPipeline


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph():
    return synthetic.rmat(11, avg_deg=8.0, seed=2)  # n = 2048


def _random_index(n: int, l: int, seed: int) -> PPRIndex:
    kv, ki = jax.random.split(jax.random.PRNGKey(seed))
    vals = jax.random.uniform(kv, (n, l), jnp.float32)
    vals = jnp.sort(vals / vals.sum(axis=1, keepdims=True), axis=1)[:, ::-1]
    idxs = jax.random.randint(ki, (n, l), 0, n, jnp.int32)
    return PPRIndex(values=vals, indices=idxs, l=l, n=n)


@pytest.fixture(scope="module")
def index(graph):
    return _random_index(graph.n, 16, seed=4)


@pytest.fixture(scope="module")
def padded_index(graph, index):
    """Sharded-build-shaped index: zeroed pad rows beyond graph.n."""
    pad = 37
    vals = jnp.concatenate(
        [index.values, jnp.zeros((pad, index.l), jnp.float32)])
    idxs = jnp.concatenate(
        [index.indices, jnp.zeros((pad, index.l), jnp.int32)])
    return PPRIndex(values=vals, indices=idxs, l=index.l, n=graph.n + pad)


def _service(graph, index, *, depth=1, dispatch="fused", frontier_path="sparse",
             max_batch=64, clock=None, **batching):
    cfg = ServiceConfig(
        query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=32,
                          frontier_k=128, frontier_path=frontier_path),
        batching=BatchingConfig(max_batch=max_batch, **batching),
        pipeline=PipelineConfig(depth=depth, dispatch=dispatch),
    )
    return PPRService(graph, index, cfg, clock=clock)


def _serve_all(svc, vertices):
    """Submit everything, then flush; answers stacked by request id."""
    for v in vertices:
        svc.submit(int(v))
    answers = svc.poll(force=True)
    assert len(answers) == len(vertices)
    answers.sort(key=lambda a: a.request_id)
    return (np.stack([a.top_scores for a in answers]),
            np.stack([a.top_vertices for a in answers]))


# ---------------------------------------------------------------------------
# satellite: bucketed padding clamped to max_batch
# ---------------------------------------------------------------------------

def test_pad_clamped_to_max_batch():
    # regression: max_batch=3000 used to pad a full drain to 4096, a jit
    # shape wider than the configured limit
    buf = RequestBuffer(BatchingConfig(max_batch=3000), clock=lambda: 0.0)
    for v in range(3000):
        buf.submit(v)
    reqs, padded = buf.drain()
    assert len(reqs) == 3000 and padded == 3000
    # partial drains round up to the next pad_quantum multiple (the old
    # pow2 rule padded 2500 all the way to the 3000 clamp)
    for v in range(2500):
        buf.submit(v)
    reqs, padded = buf.drain()
    assert len(reqs) == 2500 and padded == 2560


def test_bucketed_padding_reduces_pad_fraction():
    """Regression for the PR 6 open-loop histogram: drains in the
    (quantum, 2*quantum] .. (max/2, max] bands used to double to the next
    power of two; bucketing pads them to the next multiple of 64."""
    cfg = BatchingConfig(max_batch=256)
    for n, want in [(1, 1), (2, 2), (33, 64), (64, 64), (65, 128),
                    (128, 128), (129, 192), (200, 256), (256, 256)]:
        assert cfg.pad_width(n) == want, (n, want, cfg.pad_width(n))
    # the shape a saturated service lives at: 129..192 real rows used to
    # pad to 256; bucketing halves the wasted pad rows (127 -> 63) and
    # drops pad_fraction from ~0.50 to ~0.33 at the worst point
    old_pow2 = 256
    assert cfg.pad_width(129) - 129 <= (old_pow2 - 129) / 2
    pad_old = (old_pow2 - 129) / old_pow2
    pad_new = (cfg.pad_width(129) - 129) / cfg.pad_width(129)
    assert pad_new < pad_old
    # closed shape set: pow2 up to the quantum, then quantum multiples —
    # with the serving bench's min_pad=64 floor the set is 4 shapes
    assert cfg.padded_shapes() == [1, 2, 4, 8, 16, 32, 64, 128, 192, 256]
    bench = BatchingConfig(max_batch=256, min_pad=64)
    assert bench.padded_shapes() == [64, 128, 192, 256]


def test_padding_disabled_passthrough():
    cfg = BatchingConfig(max_batch=256, pad_to_power_of_two=False)
    assert cfg.pad_width(129) == 129


def test_pad_min_floor():
    buf = RequestBuffer(BatchingConfig(max_batch=256, min_pad=64),
                        clock=lambda: 0.0)
    for v in range(5):
        buf.submit(v)
    reqs, padded = buf.drain()
    assert len(reqs) == 5 and padded == 64
    # the floor itself is clamped to max_batch
    buf2 = RequestBuffer(BatchingConfig(max_batch=8, min_pad=64),
                         clock=lambda: 0.0)
    buf2.submit(0)
    _, padded = buf2.drain()
    assert padded == 8


# ---------------------------------------------------------------------------
# satellite: tiers and deadlines with an injected clock
# ---------------------------------------------------------------------------

def test_tier_drain_interactive_first():
    buf = RequestBuffer(BatchingConfig(max_batch=16), clock=lambda: 0.0)
    b0 = buf.submit(10, tier="bulk")
    b1 = buf.submit(11, tier="bulk")
    i0 = buf.submit(20, tier="interactive")
    i1 = buf.submit(21, tier="interactive")
    reqs, _ = buf.drain()
    assert [r.request_id for r in reqs] == [i0, i1, b0, b1]
    assert [r.tier for r in reqs] == ["interactive"] * 2 + ["bulk"] * 2


def test_tier_deadline_with_empty_opposite_tier():
    t = [0.0]
    cfg = BatchingConfig(
        max_batch=100, max_wait_s=10.0,
        interactive=TierPolicy(max_wait_s=0.01),
        bulk=TierPolicy(max_wait_s=1.0),
    )
    buf = RequestBuffer(cfg, clock=lambda: t[0])
    buf.submit(1, tier="bulk")      # interactive tier stays empty
    assert not buf.ready()
    t[0] = 0.5
    assert not buf.ready()          # bulk deadline (1.0s) not yet crossed
    t[0] = 1.01
    assert buf.ready()              # fires on bulk's own deadline
    reqs, _ = buf.drain()
    assert len(reqs) == 1 and reqs[0].tier == "bulk"
    # and the interactive deadline fires alone too
    buf.submit(2, tier="interactive")
    assert not buf.ready()
    t[0] = 1.03
    assert buf.ready()


def test_ready_honors_oldest_request_per_tier():
    t = [0.0]
    buf = RequestBuffer(BatchingConfig(max_batch=100, max_wait_s=0.01),
                        clock=lambda: t[0])
    buf.submit(1)
    t[0] = 0.008
    buf.submit(2)                   # young request must not reset the clock
    assert not buf.ready()
    t[0] = 0.0101                   # oldest crossed its deadline
    assert buf.ready()


def test_tier_batch_limit_applies_per_tier():
    cfg = BatchingConfig(max_batch=16, interactive=TierPolicy(max_batch=2))
    buf = RequestBuffer(cfg, clock=lambda: 0.0)
    ids = [buf.submit(v) for v in range(3)]                  # interactive
    bids = [buf.submit(v, tier="bulk") for v in (7, 8)]
    assert buf.ready()              # interactive tier hit its batch size
    reqs, _ = buf.drain()
    # 2 interactive (tier cap) + bulk fills the remaining global room
    assert [r.request_id for r in reqs] == [ids[0], ids[1], bids[0], bids[1]]
    assert len(buf) == 1            # third interactive waits for next batch


def test_submit_rejects_unknown_tier():
    buf = RequestBuffer(BatchingConfig(), clock=lambda: 0.0)
    with pytest.raises(ValueError):
        buf.submit(0, tier="batch")


def test_bulk_aging_bound_prevents_starvation():
    """Satellite bugfix: the interactive-first drain used to starve bulk —
    under sustained interactive load every drain filled with interactive
    requests and the bulk request aged in the buffer forever.  A fired bulk
    deadline now outranks fresher interactive traffic (oldest-deadline-
    first), so ``max_wait_s`` is an aging bound."""
    t = [0.0]
    cfg = BatchingConfig(
        max_batch=4, max_wait_s=0.01,
        bulk=TierPolicy(max_wait_s=0.045),
        pad_to_power_of_two=False,
    )
    buf = RequestBuffer(cfg, clock=lambda: t[0])
    b0 = buf.submit(99, tier="bulk")          # deadline: t = 0.045
    bulk_served_round = None
    for rnd in range(5):
        for v in range(4):                    # sustained: a full batch of
            buf.submit(v)                     # interactive every round
        t[0] += 0.02
        reqs, _ = buf.drain()
        if any(r.request_id == b0 for r in reqs):
            bulk_served_round = rnd
            # the fired bulk deadline outranked interactive traffic that
            # was itself past deadline — pre-fix, interactive always won
            assert reqs[0].request_id == b0
            assert len(buf) > 0               # interactive left waiting
            break
    # served within one drain period of its 0.045s deadline (round 2 ends
    # at t=0.06), not starved through all 5 rounds
    assert bulk_served_round == 2
    # latency bound: deadline + one drain period, not 5 rounds
    assert t[0] - 0.0 <= cfg.tier_policy("bulk")[1] + 0.02


def test_drain_order_keeps_interactive_first_when_nothing_fired():
    t = [0.0]
    cfg = BatchingConfig(max_batch=4, max_wait_s=10.0)
    buf = RequestBuffer(cfg, clock=lambda: t[0])
    buf.submit(9, tier="bulk")
    buf.submit(1, tier="interactive")
    t[0] = 0.001                              # no deadline fired
    reqs, _ = buf.drain()
    assert [r.tier for r in reqs] == ["interactive", "bulk"]


# ---------------------------------------------------------------------------
# satellite: admission control — bounded queue depth, shed counter,
# rejected-answer path (injected clock throughout)
# ---------------------------------------------------------------------------

def test_buffer_admission_control_sheds_at_depth():
    buf = RequestBuffer(BatchingConfig(max_batch=16, max_queue_depth=2),
                        clock=lambda: 0.0)
    buf.submit(0)
    buf.submit(1)
    with pytest.raises(BufferOverloadError):
        buf.submit(2)
    assert buf.stats["shed"] == 1
    assert len(buf) == 2                # the overload submit enqueued nothing
    reqs, _ = buf.drain()
    assert [r.vertex for r in reqs] == [0, 1]
    buf.submit(3)                       # drain freed the queue: admitted again
    assert len(buf) == 1
    # unbounded by default: no depth configured, nothing ever sheds
    unb = RequestBuffer(BatchingConfig(max_batch=4), clock=lambda: 0.0)
    for v in range(100):
        unb.submit(v)
    assert unb.stats["shed"] == 0 and len(unb) == 100


def test_service_sheds_overload_with_rejected_answers(graph, index):
    t = [0.0]
    svc = _service(graph, index, clock=lambda: t[0], max_batch=16,
                   max_wait_s=10.0, max_queue_depth=3)
    rids = [svc.submit(v) for v in range(5)]       # last 2 shed
    assert len(set(rids)) == 5                     # shed requests keep an id
    assert svc.stats["shed"] == 2 and len(svc.buffer) == 3
    t[0] = 0.25
    answers = svc.poll(force=True)
    assert len(answers) == 5
    rej = {a.request_id: a for a in answers if a.rejected}
    assert set(rej) == set(rids[3:])
    for a in rej.values():
        # empty top-k, never dispatched, latency still measured from arrival
        assert a.top_vertices.size == 0 and a.top_scores.size == 0
        assert a.latency_s == pytest.approx(0.25)
    served = [a for a in answers if not a.rejected]
    assert {a.request_id for a in served} == set(rids[:3])
    assert all(a.top_scores.size > 0 for a in served)
    s = svc.snapshot_stats()
    # shed traffic never occupied a batch row: out of the served ledger
    assert s["served"] == 3
    assert s["shed"] == 2 and s["buffer_shed"] == 2
    assert s["max_queue_depth"] == 3
    # the drain freed the buffer: traffic is admitted again
    svc.submit(7)
    assert svc.stats["shed"] == 2 and len(svc.buffer) == 1


# ---------------------------------------------------------------------------
# pipeline mechanics (stub engine: no device work)
# ---------------------------------------------------------------------------

class _StubEngine:
    """Returns recognizable host arrays; numpy has no ``is_ready`` so every
    ticket reports ready immediately."""

    def __init__(self, k=4):
        self.k = k
        self.calls = 0

    def dispatch_key(self, seq):
        return seq

    def query_topk_async(self, verts, *, key=None):
        self.calls += 1
        q = len(verts)
        vals = np.full((q, self.k), float(self.calls), np.float32)
        idx = np.tile(np.asarray(verts, np.int32)[:, None], (1, self.k))
        return vals, idx


def test_completion_queue_is_bounded():
    q = CompletionQueue(depth=2)
    mk = lambda s: PendingBatch(s, [], 0, np.zeros(1), np.zeros(1), 0.0)
    q.push(mk(0)), q.push(mk(1))
    assert q.full()
    with pytest.raises(RuntimeError):
        q.push(mk(2))
    assert q.pop().seq == 0         # FIFO
    q.push(mk(2))
    assert [q.pop(block=True).seq for _ in range(2)] == [1, 2]


def test_queue_pop_waits_for_unready_head():
    class NotReady:
        def is_ready(self):
            return False

    q = CompletionQueue(depth=2)
    q.push(PendingBatch(0, [], 0, NotReady(), NotReady(), 0.0))
    assert q.pop(block=False) is None   # head not finished, nothing harvested
    assert len(q) == 1


def test_pipeline_depth_bound_and_backpressure():
    buf = RequestBuffer(BatchingConfig(max_batch=4, pad_to_power_of_two=False),
                        clock=lambda: 0.0)
    pl = ServingPipeline(_StubEngine(), buf, PipelineConfig(depth=2),
                         clock=lambda: 0.0)
    for v in range(20):
        buf.submit(v)
    completed = pl.dispatch(force=True)          # 5 batches through depth 2
    completed += pl.harvest(drain=True)
    assert pl.stats["dispatched"] == 5 and pl.stats["harvested"] == 5
    assert pl.stats["in_flight_peak"] == 2       # never exceeded depth
    assert pl.stats["queue_full_stalls"] == 3    # batches 3..5 had to wait
    served = [r.vertex for b in completed for r in b.requests]
    assert sorted(served) == list(range(20))
    # completion order preserved dispatch order (FIFO stream semantics)
    assert [b.seq for b in completed] == [0, 1, 2, 3, 4]


def test_pipeline_batch_histogram():
    buf = RequestBuffer(BatchingConfig(max_batch=8), clock=lambda: 0.0)
    pl = ServingPipeline(_StubEngine(), buf, PipelineConfig(depth=1),
                         clock=lambda: 0.0)
    for v in range(13):
        buf.submit(v)
    pl.flush()
    assert dict(pl.batch_hist) == {8: 2}         # 8 full + 5 padded to 8


def test_deadline_dispatch_deferred_while_busy():
    """A deadline-fired partial batch must not launch behind an in-flight
    batch (it would start no sooner and its pad rows burn capacity); it
    launches once the pipeline drains.  Size-fired batches always launch."""
    buf = RequestBuffer(BatchingConfig(max_batch=8, max_wait_s=0.0),
                        clock=lambda: 1.0)
    pl = ServingPipeline(_StubEngine(), buf, PipelineConfig(depth=2),
                         clock=lambda: 1.0)
    for v in range(3):
        buf.submit(v)
    assert buf.ready() and not buf.size_ready()
    pl.dispatch()                                # idle -> deadline batch goes
    assert pl.stats["dispatched"] == 1 and pl.in_flight == 1
    for v in range(3):
        buf.submit(v)
    pl.dispatch()                                # busy -> deferred, fills up
    assert pl.stats["dispatched"] == 1 and len(buf) == 3
    for v in range(8):
        buf.submit(v)                            # one tier hits max_batch
    pl.dispatch()                                # size-fired: launches anyway
    assert pl.stats["dispatched"] == 2 and len(buf) == 3
    pl.harvest(drain=True)
    pl.dispatch()                                # idle again -> deferred goes
    assert pl.stats["dispatched"] == 3 and len(buf) == 0


# ---------------------------------------------------------------------------
# satellite: stuck-ticket watchdog (injected clock, never-ready tickets)
# ---------------------------------------------------------------------------

class _NeverReady:
    """Device-array stand-in whose ticket never reports ready — a wedged
    device stream as far as the completion queue can tell."""

    def is_ready(self):
        return False


class _StuckEngine:
    def dispatch_key(self, seq):
        return seq

    def query_topk_async(self, verts, *, key=None, out=None):
        return _NeverReady(), _NeverReady()


def test_stall_watchdog_counts_and_warns_once():
    t = [0.0]
    buf = RequestBuffer(BatchingConfig(max_batch=4, pad_to_power_of_two=False),
                        clock=lambda: t[0])
    pl = ServingPipeline(_StuckEngine(), buf,
                         PipelineConfig(depth=2, stall_timeout_s=1.0),
                         clock=lambda: t[0])
    for v in range(4):
        buf.submit(v)
    pl.dispatch()
    assert pl.in_flight == 1
    # young ticket: harvest returns nothing and stays silent
    t[0] = 0.5
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert pl.harvest() == []
    assert pl.stats["stalled"] == 0
    # past the deadline: counted + warned
    t[0] = 1.5
    with pytest.warns(RuntimeWarning, match="in flight for"):
        assert pl.harvest() == []
    assert pl.stats["stalled"] == 1
    # detection only — the ticket stays in flight, and each stuck batch
    # warns exactly once however often harvest polls it
    assert pl.in_flight == 1
    t[0] = 50.0
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert pl.harvest() == []
    assert pl.stats["stalled"] == 1


def test_stall_watchdog_disabled_by_default():
    t = [0.0]
    buf = RequestBuffer(BatchingConfig(max_batch=2, pad_to_power_of_two=False),
                        clock=lambda: t[0])
    pl = ServingPipeline(_StuckEngine(), buf, PipelineConfig(depth=2),
                         clock=lambda: t[0])
    buf.submit(0), buf.submit(1)
    pl.dispatch()
    t[0] = 1e6                          # ancient ticket, watchdog off
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert pl.harvest() == []
    assert pl.stats["stalled"] == 0
    with pytest.raises(ValueError, match="stall_timeout_s"):
        PipelineConfig(stall_timeout_s=0.0)


def test_stalled_counter_in_service_snapshot(graph, index):
    svc = _service(graph, index)
    s = svc.snapshot_stats()
    assert s["pipeline_stalled"] == 0


# ---------------------------------------------------------------------------
# satellite: apply_updates is atomic — failure leaves the service untouched
# ---------------------------------------------------------------------------

def test_apply_updates_rolls_back_on_repair_failure(graph, index, monkeypatch):
    from repro.core import updates as updates_mod

    svc = _service(graph, index)
    svc.maintainer = object()           # sentinel; repair fails before use

    def boom(*a, **k):
        raise RuntimeError("injected repair failure")

    monkeypatch.setattr(updates_mod, "apply_updates", boom)
    before = (svc.graph, svc.engine, svc.maintainer, svc.pipeline.engine)
    with pytest.raises(RuntimeError, match="injected repair failure"):
        svc.apply_updates(inserts=[[0, 1]])
    # nothing swapped: same graph, same engine, same maintainer
    assert (svc.graph, svc.engine, svc.maintainer,
            svc.pipeline.engine) == before
    assert svc.stats["update_rollbacks"] == 1
    assert svc.stats["updates_applied"] == 0
    assert svc.cache.epoch == 0         # no invalidation happened either
    # the rolled-back service still serves
    svc.submit(3)
    answers = svc.poll(force=True)
    assert len(answers) == 1 and not answers[0].rejected


def test_apply_updates_rolls_back_on_engine_failure(graph, index, monkeypatch):
    """Repair succeeds but the replacement engine fails to construct —
    the dangerous half-applied window (new graph, old engine) must not
    exist: everything is built before anything is assigned."""
    import repro.serving.engine as engine_mod
    from repro.core import updates as updates_mod

    svc = _service(graph, index)
    old_maintainer = object()
    svc.maintainer = old_maintainer
    fake_m = types.SimpleNamespace(index=index)
    monkeypatch.setattr(
        updates_mod, "apply_updates",
        lambda *a, **k: (graph, fake_m,
                         dict(dirty_rows=0, dirty_row_ids=[])))

    def bad_engine(*a, **k):
        raise ValueError("injected engine failure")

    monkeypatch.setattr(engine_mod, "BatchQueryEngine", bad_engine)
    old_engine = svc.engine
    with pytest.raises(ValueError, match="injected engine failure"):
        svc.apply_updates(inserts=[[0, 1]])
    assert svc.maintainer is old_maintainer     # not fake_m
    assert svc.engine is old_engine
    assert svc.pipeline.engine is old_engine
    assert svc.stats["update_rollbacks"] == 1
    assert svc.stats["updates_applied"] == 0


# ---------------------------------------------------------------------------
# satellite: answer parity at every depth, both routes, padded index
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("frontier_path", ["sparse", "dense"])
@pytest.mark.parametrize("depth", [2, 4])
def test_async_depth_parity(graph, index, frontier_path, depth):
    rng = np.random.default_rng(3)
    verts = rng.integers(0, graph.n, size=165)   # 64 + 64 + 37(pad 64)
    base = _service(graph, index, depth=1, frontier_path=frontier_path)
    v0, i0 = _serve_all(base, verts)
    svc = _service(graph, index, depth=depth, frontier_path=frontier_path)
    v1, i1 = _serve_all(svc, verts)
    # identical arrays, not merely close: same fused computation, same
    # per-dispatch keys, only the harvest timing differs
    np.testing.assert_array_equal(v0, v1)
    np.testing.assert_array_equal(i0, i1)
    assert svc.pipeline.stats["in_flight_peak"] <= depth


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_async_parity_padded_index(graph, index, padded_index, depth):
    rng = np.random.default_rng(5)
    verts = rng.integers(0, graph.n, size=100)
    ref = _service(graph, index, depth=1)
    v0, i0 = _serve_all(ref, verts)
    svc = _service(graph, padded_index, depth=depth)
    v1, i1 = _serve_all(svc, verts)
    # pad rows carry no mass, so a sharded-shaped index serves the same
    # answers at any depth
    np.testing.assert_allclose(v0, v1, rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(i0, i1)


@pytest.mark.parametrize("frontier_path", ["sparse", "dense"])
def test_fused_matches_legacy_blocking(graph, index, frontier_path):
    rng = np.random.default_rng(7)
    verts = rng.integers(0, graph.n, size=130)
    leg = _service(graph, index, depth=1, dispatch="legacy",
                   frontier_path=frontier_path)
    v0, i0 = _serve_all(leg, verts)
    fus = _service(graph, index, depth=1, dispatch="fused",
                   frontier_path=frontier_path)
    v1, i1 = _serve_all(fus, verts)
    np.testing.assert_allclose(v0, v1, rtol=1e-6, atol=1e-7)
    # equal scores can permute within ties; compare the score multisets and
    # the (vertex -> score) maps instead of raw index order
    for r in range(len(verts)):
        m0 = dict(zip(i0[r].tolist(), v0[r].tolist()))
        m1 = dict(zip(i1[r].tolist(), v1[r].tolist()))
        for k in set(m0) | set(m1):
            assert abs(m0.get(k, 0.0) - m1.get(k, 0.0)) < 1e-6


def test_service_matches_engine_rows(graph, index):
    """A full no-pad batch through the service equals the engine's own
    fused answers row for row."""
    verts = np.arange(64)
    svc = _service(graph, index, depth=2)
    v_srv, i_srv = _serve_all(svc, verts)
    eng = svc.engine
    v_ref, i_ref = eng.query_topk_async(
        jnp.asarray(verts, jnp.int32), key=eng.dispatch_key(0))
    np.testing.assert_array_equal(v_srv, np.asarray(v_ref))
    np.testing.assert_array_equal(i_srv, np.asarray(i_ref))


# ---------------------------------------------------------------------------
# satellite: per-dispatch result buffers ring instead of allocating
# ---------------------------------------------------------------------------

def test_buffer_ring_no_allocation_growth(graph, index):
    """Satellite bugfix: each fused dispatch used to allocate a fresh
    [padded, k] result pair.  With the buffer ring, a long run at a fixed
    shape set allocates at most ``depth`` pairs per shape and re-donates
    them forever after — allocation count plateaus, reuse count grows."""
    svc = _service(graph, index, depth=2, max_batch=16, min_pad=16)
    rng = np.random.default_rng(11)
    _, s = run_closed_loop(svc, rng.integers(0, graph.n, 16 * 12).tolist())
    assert s["served"] == 16 * 12
    dispatched = s["pipeline_dispatched"]
    assert dispatched >= 10                   # long run, many dispatches
    # single padded shape (min_pad == max_batch == 16): the ring bounds
    # allocations by pipeline depth, everything else reuses
    assert set(s["batch_hist"]) == {16}
    assert s["pipeline_buffers_allocated"] <= 2
    assert s["pipeline_buffers_reused"] == dispatched - s["pipeline_buffers_allocated"]


def test_buffer_ring_reuses_device_memory(graph, index):
    """The ring actually re-donates device buffers: a dispatch that pops a
    ringed pair writes its answer into the same device memory."""
    eng = BatchQueryEngine(graph, index, QueryConfig(
        mode="powerwalk", t_iterations=2, top_k=32, frontier_k=128,
        frontier_path="sparse"))
    verts = jnp.arange(8, dtype=jnp.int32)
    v0, i0 = eng.query_topk_async(verts)
    v0.block_until_ready()
    ptr_v = v0.unsafe_buffer_pointer()
    ref_vals = np.asarray(v0).copy()
    v1, i1 = eng.query_topk_async(verts + 1, out=(v0, i0))
    v1.block_until_ready()
    assert v1.unsafe_buffer_pointer() == ptr_v   # same device memory
    # and the answers are the fresh query's, not the donor's
    v_ref, _ = eng.query_topk_async(jnp.arange(8, dtype=jnp.int32) + 1)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v_ref))
    assert not np.array_equal(np.asarray(v1), ref_vals)


def test_buffer_ring_disabled_never_reuses(graph, index):
    svc = _service(graph, index, depth=1, max_batch=16, min_pad=16)
    svc.cfg.pipeline.reuse_buffers = False
    rng = np.random.default_rng(12)
    _, s = run_closed_loop(svc, rng.integers(0, graph.n, 48).tolist())
    assert s["pipeline_buffers_reused"] == 0


# ---------------------------------------------------------------------------
# scatter-combine routing + parity (the fused path's perf lever)
# ---------------------------------------------------------------------------

def test_scatter_combine_routing(graph, index, monkeypatch):
    eng = BatchQueryEngine(graph, index, QueryConfig(
        mode="powerwalk", frontier_k=128, frontier_path="sparse"))
    assert eng.uses_scatter_combine(64)          # fits the default budget
    monkeypatch.setattr(query_mod, "SCATTER_COMBINE_BUDGET_BYTES", 100)
    assert not eng.uses_scatter_combine(64)      # auto respects the budget
    eng.config.combine_path = "scatter"
    assert eng.uses_scatter_combine(64)          # explicit overrides budget
    eng.config.combine_path = "sparse"
    assert not eng.uses_scatter_combine(1)
    # only the powerwalk sparse route has an index combine
    dense_eng = BatchQueryEngine(graph, index, QueryConfig(
        mode="powerwalk", frontier_path="dense"))
    assert not dense_eng.uses_scatter_combine(1)


def test_scatter_combine_matches_sparse_combine(graph, index):
    verts = jnp.arange(48, dtype=jnp.int32)
    answers = {}
    for path in ("scatter", "sparse"):
        eng = BatchQueryEngine(graph, index, QueryConfig(
            mode="powerwalk", t_iterations=2, top_k=32, frontier_k=128,
            frontier_path="sparse", combine_path=path))
        answers[path] = eng.query_topk_async(verts)
    np.testing.assert_allclose(
        np.asarray(answers["scatter"][0]), np.asarray(answers["sparse"][0]),
        rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(
        np.asarray(answers["scatter"][1]), np.asarray(answers["sparse"][1]))


# ---------------------------------------------------------------------------
# open-loop harness
# ---------------------------------------------------------------------------

def _virtual_clock_service(graph, index, **kw):
    t = [0.0]
    svc = _service(graph, index, clock=lambda: t[0], **kw)
    return svc, t


def test_open_loop_latency_from_scheduled_arrival(graph, index):
    svc, t = _virtual_clock_service(graph, index, max_batch=16,
                                    max_wait_s=1.0)
    def sleep(dt):
        t[0] += dt
    answers, stats = run_open_loop(
        svc, list(range(16)), qps=100.0, sleep=sleep)
    assert len(answers) == 16
    # all 16 complete in one final batch at the same (virtual) instant, so
    # latency must decrease with request id: arrival was backdated to the
    # *scheduled* offer time i/qps, not the submit time
    by_id = sorted(answers, key=lambda a: a.request_id)
    lats = [a.latency_s for a in by_id]
    assert all(lats[i] > lats[i + 1] for i in range(len(lats) - 1))
    np.testing.assert_allclose(lats[0] - lats[-1], 15 / 100.0, rtol=1e-6)
    assert stats["offered_qps"] == 100.0
    assert stats["latency_p99"] >= stats["latency_p50"] > 0


def test_open_loop_tiered_workload(graph, index):
    svc, t = _virtual_clock_service(graph, index, max_batch=16)
    work = [(5, "bulk"), (6, "interactive"), (7, "bulk"), (8, "interactive")]
    answers, _ = run_open_loop(svc, work, qps=None)
    got = {a.vertex: a.tier for a in answers}
    assert got == {5: "bulk", 6: "interactive", 7: "bulk", 8: "interactive"}


def test_closed_loop_wrapper_keeps_stats_contract(graph, index):
    svc = _service(graph, index, depth=2, max_batch=16)
    answers, s = svc.run_closed_loop(list(range(40)))
    assert len(answers) == 40
    for key in ("served", "batches", "pad_rows", "wall_s", "qps",
                "mean_latency", "pad_fraction", "frontier_path", "answer_k",
                "index_rows", "index_sharded", "wall_s_excl_first_batch",
                "latency_p50", "latency_p99", "pipeline_depth",
                "batch_hist", "first_batch_service_s"):
        assert key in s, key
    assert s["served"] == 40
    assert 0.0 <= s["pad_fraction"] < 1.0
    # cold service: the first (compile-bearing) batch is excluded from the
    # adjusted wall, so the adjusted qps can only improve
    assert s["first_batch_service_s"] > 0.0
    assert s["wall_s_excl_first_batch"] <= s["wall_s"]
    assert s["qps_excl_first_batch"] >= s["qps"]


def test_poll_without_traffic_is_empty(graph, index):
    svc = _service(graph, index)
    assert svc.poll() == []
    assert svc.poll(force=True) == []


# ---------------------------------------------------------------------------
# slow end-to-end: real clock, sparse route, pipelined vs blocking
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_pipelined_serving_matches_blocking(graph, index):
    rng = np.random.default_rng(13)
    verts = rng.integers(0, graph.n, size=400).tolist()

    leg = _service(graph, index, depth=1, dispatch="legacy", max_batch=128)
    _, s_leg = run_closed_loop(leg, verts)
    pip = _service(graph, index, depth=4, dispatch="fused", max_batch=128)
    answers, s_pip = run_open_loop(pip, verts, qps=2000.0)

    assert s_leg["served"] == s_pip["served"] == 400
    assert len({a.request_id for a in answers}) == 400
    # batching differs between the two runs, but per-vertex answers are a
    # pure function of the vertex on the powerwalk route — collect by
    # vertex and compare across serving stacks
    leg_by_vertex = {}
    leg2 = _service(graph, index, depth=1, dispatch="legacy", max_batch=128)
    for a in run_closed_loop(leg2, sorted(set(verts)))[0]:
        leg_by_vertex[a.vertex] = (a.top_scores, a.top_vertices)
    for a in answers:
        v_ref, i_ref = leg_by_vertex[a.vertex]
        np.testing.assert_allclose(a.top_scores, v_ref, rtol=1e-5, atol=1e-6)
    assert s_pip["pipeline_in_flight_peak"] >= 1
