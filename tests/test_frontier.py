"""Sparse-frontier pipeline: primitives, sparse==dense equivalence, bounded
truncation drift, and the engine/serving routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier as F
from repro.core import verd as verd_mod
from repro.core.graph import Graph
from repro.core.index import build_index, index_from_dense
from repro.core.query import AUTO_SPARSE_MIN_N, BatchQueryEngine, QueryConfig
from repro.graphs import synthetic


@pytest.fixture(scope="module")
def graph():
    # ER keeps a mix of dangling and multi-out-degree vertices
    return synthetic.erdos_renyi(48, 4.0, seed=7)


@pytest.fixture(scope="module")
def index(graph):
    idx, _ = build_index(graph, r=100, l=16, key=jax.random.PRNGKey(0))
    return idx


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def test_merge_duplicates_matches_numpy(rng):
    q, w, n = 5, 40, 12
    vals = jnp.asarray(rng.random((q, w)), jnp.float32)
    idxs = jnp.asarray(rng.integers(0, n, (q, w)), jnp.int32)
    mv, mi = F.merge_duplicates(vals, idxs)
    # densified mass per column must be preserved exactly
    got = np.zeros((q, n), np.float32)
    np.add.at(got, (np.arange(q)[:, None], np.asarray(mi)), np.asarray(mv))
    want = np.zeros((q, n), np.float32)
    np.add.at(want, (np.arange(q)[:, None], np.asarray(idxs)), np.asarray(vals))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # and each column appears in at most one nonzero slot per row
    for r in range(q):
        nz = np.asarray(mi[r])[np.asarray(mv[r]) > 0]
        assert len(nz) == len(set(nz.tolist()))


def test_topk_compact_pads_and_truncates(rng):
    vals = jnp.asarray([[0.5, 0.0, 0.9]], jnp.float32)
    idxs = jnp.asarray([[3, 7, 1]], jnp.int32)
    v, i = F.topk_compact(vals, idxs, 5)  # pad
    assert v.shape == (1, 5)
    np.testing.assert_allclose(np.asarray(v[0, :2]), [0.5, 0.9][::-1])
    assert int(i[0, 1]) == 3 and int(i[0, 0]) == 1
    assert float(v[0, 4]) == 0.0 and int(i[0, 4]) == 0
    v, i = F.topk_compact(vals, idxs, 2)  # truncate
    np.testing.assert_allclose(np.asarray(v[0]), [0.9, 0.5])


def test_densify_sparsify_roundtrip(rng):
    dense = jnp.asarray(rng.random((4, 30)), jnp.float32)
    sf = F.from_dense(dense, 30)
    np.testing.assert_allclose(
        np.asarray(sf.densify()), np.asarray(dense), rtol=1e-6
    )
    # truncating keeps exactly the top-k mass
    sf5 = F.from_dense(dense, 5)
    want = np.sort(np.asarray(dense), axis=1)[:, -5:].sum(axis=1)
    np.testing.assert_allclose(np.asarray(sf5.mass()), want, rtol=1e-6)


def test_from_sources_one_hot(graph):
    srcs = jnp.asarray([0, 5, 11], jnp.int32)
    sf = F.from_sources(srcs, graph.n)
    d = np.asarray(sf.densify())
    assert d.sum() == 3.0
    assert (d[np.arange(3), np.asarray(srcs)] == 1.0).all()


# ---------------------------------------------------------------------------
# sparse VERD == dense VERD when K covers the support
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t", [0, 1, 2, 3])
def test_iterate_sparse_equals_dense(graph, t):
    srcs = jnp.asarray([0, 5, 11, 40], jnp.int32)
    s_d, f_d = verd_mod.verd_iterate(graph, srcs, t=t)
    s_s, f_s = verd_mod.verd_iterate_sparse(graph, srcs, t=t, k=graph.n)
    np.testing.assert_allclose(
        np.asarray(s_s.densify()), np.asarray(s_d), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(f_s.densify()), np.asarray(f_d), atol=1e-6
    )


def test_query_sparse_equals_dense(graph, index):
    """Acceptance gate: sparse == dense to <= 1e-5 L1 at covering K."""
    srcs = jnp.asarray([0, 5, 11, 40], jnp.int32)
    dense = verd_mod.verd_query(graph, srcs, index, t=2)
    sparse = verd_mod.verd_query_sparse(graph, srcs, index, t=2, k=graph.n)
    l1 = np.abs(np.asarray(sparse.densify()) - np.asarray(dense)).sum(axis=1)
    assert l1.max() <= 1e-5, l1
    # and the served top-k agrees with the dense top-k
    sp = verd_mod.verd_query_sparse(
        graph, srcs, index, t=2, k=graph.n, out_k=10
    )
    dv, _ = jax.lax.top_k(dense, 10)
    np.testing.assert_allclose(np.asarray(sp.values), np.asarray(dv), atol=1e-6)


def test_query_sparse_no_index_equals_dense(graph):
    srcs = jnp.asarray([3, 17], jnp.int32)
    dense = verd_mod.verd_query(graph, srcs, None, t=4)
    sparse = verd_mod.verd_query_sparse(graph, srcs, None, t=4, k=graph.n)
    np.testing.assert_allclose(
        np.asarray(sparse.densify()), np.asarray(dense), atol=1e-6
    )


def test_sparse_push_dangling_mass_returns_to_source():
    # 0 -> 1, 1 dangling: pushing from 1 must return mass to the source
    g = Graph.from_edges([0], [1], n=3)
    srcs = jnp.asarray([0], jnp.int32)
    s, f = verd_mod.verd_iterate_sparse(g, srcs, t=2, k=3)
    s_d, f_d = verd_mod.verd_iterate(g, srcs, t=2)
    np.testing.assert_allclose(np.asarray(f.densify()), np.asarray(f_d),
                               atol=1e-6)
    # total mass conserved: s + f carries the full unit of probability
    np.testing.assert_allclose(
        np.asarray(s.mass() + f.mass()), 1.0, rtol=1e-6
    )


def test_degree_cap_below_max_drops_only_tail_edges(graph):
    """cap < max out-degree loses at most the capped-away edge fraction."""
    srcs = jnp.asarray([0, 5], jnp.int32)
    cap = verd_mod.resolve_degree_cap(graph)
    s_e, f_e = verd_mod.verd_iterate_sparse(
        graph, srcs, t=2, k=graph.n, degree_cap=cap)
    s_c, f_c = verd_mod.verd_iterate_sparse(
        graph, srcs, t=2, k=graph.n, degree_cap=max(cap // 2, 1))
    full = np.asarray(f_e.densify())
    capped = np.asarray(f_c.densify())
    assert (capped <= full + 1e-6).all()          # monotone: only drops mass
    deficit = (full - capped).sum(axis=1)
    assert (deficit >= -1e-6).all()


# ---------------------------------------------------------------------------
# truncation drift is bounded by the dropped mass
# ---------------------------------------------------------------------------

def test_truncation_drift_bounded_by_dropped_mass(graph, index):
    """Small K answers are elementwise <= exact and lose exactly the
    un-accumulated mass (every op is monotone non-negative, index rows are
    sub-stochastic)."""
    srcs = jnp.asarray([0, 5, 11, 40], jnp.int32)
    k_small = 4
    s_e, f_e = verd_mod.verd_iterate_sparse(graph, srcs, t=3, k=graph.n)
    s_s, f_s = verd_mod.verd_iterate_sparse(graph, srcs, t=3, k=k_small)
    exact = verd_mod.combine_with_index_sparse(s_e, f_e, index)
    trunc = verd_mod.combine_with_index_sparse(s_s, f_s, index)
    ex_d = np.asarray(exact.densify())
    tr_d = np.asarray(trunc.densify())
    assert (tr_d <= ex_d + 1e-6).all()
    l1 = np.abs(ex_d - tr_d).sum(axis=1)
    dropped = np.asarray(
        (s_e.mass() - s_s.mass()) + (f_e.mass() - f_s.mass())
    )
    assert (l1 <= dropped + 1e-5).all(), (l1, dropped)


def test_threshold_loses_at_most_thresholded_mass(graph, index):
    """Satellite: dense verd_query with threshold>0 drifts by at most the
    frontier mass the epsilon-sparsification dropped."""
    from repro.core.graph import transition_with_dangling

    eps = 2e-3
    srcs = jnp.asarray([0, 5, 11], jnp.int32)
    t = 3
    p0 = np.asarray(verd_mod.verd_query(graph, srcs, index, t=t))
    pe = np.asarray(
        verd_mod.verd_query(graph, srcs, index, t=t, threshold=eps)
    )
    # replay the thresholded iteration, accounting the dropped frontier mass
    q = srcs.shape[0]
    f = jnp.zeros((q, graph.n)).at[jnp.arange(q), srcs].set(1.0)
    dropped = np.zeros(q)
    for _ in range(t):
        f = 0.85 * transition_with_dangling(graph, f, srcs)
        f_cut = jnp.where(f >= eps, f, 0.0)
        dropped += np.asarray(jnp.sum(f - f_cut, axis=1))
        f = f_cut
    assert (pe <= p0 + 1e-6).all()
    l1 = np.abs(p0 - pe).sum(axis=1)
    assert (l1 <= dropped + 1e-5).all(), (l1, dropped)


# ---------------------------------------------------------------------------
# combine_with_index chunking (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vertex_chunk", [7, 17, 33])
def test_combine_chunking_unaligned(graph, rng, vertex_chunk):
    """n=48 not divisible by the chunk: padding must not change the result."""
    l = 8
    dense = jnp.asarray(rng.random((graph.n, graph.n)), jnp.float32)
    idx = index_from_dense(dense, l=l)
    s = jnp.asarray(rng.random((3, graph.n)), jnp.float32)
    f = jnp.asarray(rng.random((3, graph.n)), jnp.float32)
    want = verd_mod.combine_with_index(s, f, idx, vertex_chunk=graph.n)
    got = verd_mod.combine_with_index(s, f, idx, vertex_chunk=vertex_chunk)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# engine routing
# ---------------------------------------------------------------------------

def test_engine_sparse_path_matches_dense(graph, index):
    srcs = np.arange(12, dtype=np.int32)
    kw = dict(mode="powerwalk", t_iterations=2, top_k=8)
    dense = BatchQueryEngine(
        graph, index, QueryConfig(frontier_path="dense", **kw)).run(srcs)
    sparse = BatchQueryEngine(
        graph, index, QueryConfig(frontier_path="sparse", **kw)).run(srcs)
    np.testing.assert_allclose(
        dense["values"], sparse["values"], atol=1e-6
    )


def test_engine_sparse_path_top_k_wider_than_candidates(graph):
    """top_k exceeding the sparse candidate width (s + K*L) must pad, not
    shrink the answer buffer."""
    idx, _ = build_index(graph, r=20, l=4, key=jax.random.PRNGKey(1))
    eng = BatchQueryEngine(graph, idx, QueryConfig(
        mode="powerwalk", top_k=40, frontier_k=4, frontier_path="sparse"))
    out = eng.run(np.arange(3, dtype=np.int32))
    assert out["values"].shape == (3, 40)
    assert (out["values"][:, -1] == 0.0).all()  # padded tail slots


def test_engine_auto_rule(graph, index):
    eng = BatchQueryEngine(graph, index, QueryConfig(mode="powerwalk"))
    assert not eng.uses_sparse_path()  # n=48 is far below the auto floor
    assert AUTO_SPARSE_MIN_N > graph.n
    eng2 = BatchQueryEngine(
        graph, index, QueryConfig(mode="fppr", frontier_path="sparse"))
    assert not eng2.uses_sparse_path()  # only VERD modes have a frontier
    with pytest.raises(ValueError):    # and query_sparse refuses them too
        eng2.query_sparse(jnp.asarray([0], jnp.int32))


def test_engine_auto_avoids_hub_graphs():
    """Unsplit hub graphs must stay dense: the [Q, K, degree_cap] gather
    would dwarf the [Q, n] state sparse is meant to replace.  (With
    ``hub_split_degree`` set the guard relaxes to the split width — see
    ``tests/test_golden_auto.py::GOLDEN_SPLIT`` — backed by the streamed
    push below.)"""
    n = AUTO_SPARSE_MIN_N
    hub = synthetic.star(n)  # max out-degree = n - 1
    eng = BatchQueryEngine(hub, None, QueryConfig(mode="verd"))
    assert eng.degree_cap() == n - 1
    assert not eng.uses_sparse_path()
    flat = synthetic.cycle(n)  # max out-degree 1: sparse is safe
    eng2 = BatchQueryEngine(flat, None, QueryConfig(mode="verd"))
    assert eng2.uses_sparse_path()


@pytest.mark.parametrize("hub_split_degree,threshold", [
    (0, 0.0), (3, 0.0), (0, 1e-3),
])
def test_streamed_push_equals_one_shot(graph, hub_split_degree, threshold):
    """sparse_push_compact with a tiny stream target (many slot-chunk
    folds) must match the one-shot gather+compact at covering k_out."""
    rng = np.random.default_rng(4)
    q, k = 3, 10
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, graph.n, (q, k)), jnp.int32)
    srcs = jnp.asarray(rng.integers(0, graph.n, q), jnp.int32)
    cap = verd_mod.resolve_degree_cap(graph)
    kw = dict(
        c=0.15, degree_cap=cap, k_out=graph.n,
        hub_split_degree=hub_split_degree, threshold=threshold,
    )
    one_shot = verd_mod.sparse_push_compact(graph, fv, fi, srcs, **kw)
    streamed = verd_mod.sparse_push_compact(
        graph, fv, fi, srcs, stream_width=1, **kw
    )
    np.testing.assert_allclose(
        np.asarray(streamed.densify()), np.asarray(one_shot.densify()),
        rtol=1e-5, atol=1e-6,
    )


def test_streamed_push_truncation_is_monotone(graph):
    """Mid-stream folds only drop mass: a truncated k_out under-counts
    elementwise vs the covering run, drift bounded by the dropped mass."""
    rng = np.random.default_rng(5)
    q, k = 2, 12
    fv = jnp.asarray(rng.random((q, k)), jnp.float32)
    fi = jnp.asarray(rng.integers(0, graph.n, (q, k)), jnp.int32)
    srcs = jnp.asarray(rng.integers(0, graph.n, q), jnp.int32)
    cap = verd_mod.resolve_degree_cap(graph)
    kw = dict(c=0.15, degree_cap=cap, stream_width=1)
    full = verd_mod.sparse_push_compact(
        graph, fv, fi, srcs, k_out=graph.n, **kw
    ).densify()
    trunc = verd_mod.sparse_push_compact(
        graph, fv, fi, srcs, k_out=4, **kw
    ).densify()
    full, trunc = np.asarray(full), np.asarray(trunc)
    assert (trunc <= full + 1e-6).all()
    dropped = full.sum(axis=1) - trunc.sum(axis=1)
    l1 = np.abs(full - trunc).sum(axis=1)
    assert (l1 <= dropped + 1e-5).all()


def test_hub_graph_sparse_query_streams_bounded(monkeypatch):
    """The relaxed hub routing end to end: a star-hub graph with
    hub_split_degree set routes sparse, the push streams (never the
    [Q, K*degree_cap] one-shot tensor), and the answers match dense."""
    n = 4096
    hub = synthetic.star(n)                  # one vertex with n-1 out-edges
    srcs = jnp.asarray([0, 1, 17], jnp.int32)
    cfg = QueryConfig(
        mode="verd", top_k=8, frontier_k=16, frontier_path="sparse",
        hub_split_degree=64,
    )
    eng = BatchQueryEngine(hub, None, cfg)
    # guard the guard: one-shot would be K*cap ~ 65k wide; the streamed
    # fold keeps live width at the stream target
    seen = []
    orig = verd_mod.gather_push_edges

    def spy(fv, fi, *args, **kwargs):
        out = orig(fv, fi, *args, **kwargs)
        seen.append(out[0].shape[1])
        return out

    monkeypatch.setattr(verd_mod, "gather_push_edges", spy)
    vals, idx = eng.query_topk(srcs)
    assert seen, "sparse push never ran"
    assert max(seen) < 16 * eng.degree_cap(), seen  # chunked, not one-shot
    dense_eng = BatchQueryEngine(
        hub, None, QueryConfig(mode="verd", top_k=8, frontier_path="dense")
    )
    dv, di = dense_eng.query_topk(srcs)
    np.testing.assert_allclose(
        np.asarray(vals), np.asarray(dv), rtol=1e-5, atol=1e-6
    )


def test_engine_auto_k_covers_expected_support():
    """Auto K must scale with mean_degree**t so auto-routed sparse answers
    aren't silently truncated below the typical frontier support."""
    g = synthetic.erdos_renyi(1000, 6.0, seed=2)
    shallow = BatchQueryEngine(
        g, None, QueryConfig(mode="verd", t_iterations=1, top_k=10))
    assert shallow.frontier_k == 256          # support ~6 « floor
    deep = BatchQueryEngine(
        g, None, QueryConfig(mode="verd", t_iterations=4, top_k=10))
    assert deep.frontier_k == g.n             # support ~6**4 > n: full width
    explicit = BatchQueryEngine(
        g, None, QueryConfig(mode="verd", t_iterations=4, frontier_k=64))
    assert explicit.frontier_k == 64          # user override wins


def test_ops_frontier_push_edgeless_graph():
    """m == 0 must take the jnp dangling path, matching the core op."""
    from repro.kernels import ops

    g = Graph.from_edges([], [], n=8)
    srcs = jnp.asarray([2, 5], jnp.int32)
    f0 = F.from_sources(srcs, g.n)
    got = ops.frontier_push(
        f0, g, srcs, c=0.15, degree_cap=1, k_out=4, interpret=True)
    cv, ci = verd_mod.sparse_push_candidates(
        g, f0.values, f0.indices, srcs, c=0.15, degree_cap=1)
    want = F.compact(cv, ci, 4, g.n)
    np.testing.assert_allclose(
        np.asarray(got.densify()), np.asarray(want.densify()), atol=1e-7)


def test_engine_rejects_bad_path(graph, index):
    with pytest.raises(ValueError):
        BatchQueryEngine(
            graph, index, QueryConfig(frontier_path="bogus"))


def test_service_sparse_path_and_pad_stats(graph, index):
    from repro.serving.batching import BatchingConfig
    from repro.serving.engine import PPRService, ServiceConfig

    t = [0.0]
    cfg = ServiceConfig(
        query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=5,
                          frontier_path="sparse"),
        batching=BatchingConfig(max_batch=16, max_wait_s=0.0),
    )
    svc = PPRService(graph, index, cfg, clock=lambda: t[0])
    for v in range(5):
        svc.submit(v)
    answers = svc.poll(force=True)
    assert len(answers) == 5                 # pad rows never surface
    assert svc.stats["pad_rows"] == 3        # padded 5 -> 8
    assert svc.stats["served"] == 5
    answers2, stats = svc.run_closed_loop(range(7))
    assert stats["served"] == 12
    assert 0.0 <= stats["pad_fraction"] < 1.0
