"""Accuracy/semantics tests for MCFP, MCEP, VERD, PI, index, query engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mcep, mcfp, metrics, theory
from repro.core import verd as verd_mod
from repro.core.graph import Graph
from repro.core.index import (
    PPRIndex,
    build_index,
    index_from_dense,
    plan_for_budget,
)
from repro.core.power_iteration import exact_ppr_dense, power_iteration
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.core.walks import sample_walk_lengths, simulate_walks, walks_for_sources
from repro.graphs import synthetic


@pytest.fixture(scope="module")
def small_graph():
    return synthetic.erdos_renyi(48, 4.0, seed=7)


@pytest.fixture(scope="module")
def exact_small(small_graph):
    return exact_ppr_dense(small_graph)


def test_power_iteration_matches_solve(small_graph, exact_small):
    sources = jnp.arange(8, dtype=jnp.int32)
    got = np.asarray(power_iteration(small_graph, sources, n_iter=200))
    np.testing.assert_allclose(got, exact_small[:8], atol=2e-5)


def test_pi_rows_stochastic(small_graph):
    sources = jnp.asarray([0, 5, 11], dtype=jnp.int32)
    p = power_iteration(small_graph, sources, n_iter=100)
    assert metrics.is_stochastic(p).all()


def test_mcfp_converges(small_graph, exact_small, key):
    sources = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    est = mcfp.estimate_ppr(small_graph, sources, r=3000, key=key)
    rag = metrics.mean_rag(jnp.asarray(exact_small[:4], jnp.float32), est, k=10)
    assert rag > 0.97
    assert metrics.is_stochastic(est, atol=1e-3).all()


def test_mcep_converges_but_slower(small_graph, exact_small, key):
    sources = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    r = 800
    fp = mcfp.estimate_ppr(small_graph, sources, r=r, key=key)
    ep = mcep.estimate_ppr(small_graph, sources, r=r, key=key)
    ex = jnp.asarray(exact_small[:4], jnp.float32)
    l1_fp = float(metrics.l1_error(ex, fp).mean())
    l1_ep = float(metrics.l1_error(ex, ep).mean())
    # Full-path uses ~1/c more samples; must be clearly better at equal R.
    assert l1_fp < l1_ep


def test_walk_lengths_geometric(key):
    lens = np.asarray(sample_walk_lengths(key, 20000, c=0.15, max_steps=200))
    mean = lens.mean()
    assert abs(mean - 1 / 0.15) < 0.4  # 1/c = 6.67


def test_walk_counts_consistency(small_graph, key):
    sources = jnp.asarray([0, 1], dtype=jnp.int32)
    ws, wr = walks_for_sources(sources, 50)
    counts = simulate_walks(
        small_graph, ws, wr, key, n_rows=2, max_steps=64
    )
    # every walk terminates exactly once
    np.testing.assert_allclose(np.asarray(counts.walks), 50.0)
    # moves >= walks (every walk has at least one position)
    assert (np.asarray(counts.moves) >= 50.0).all()
    # endpoint counts sum to R per row
    np.testing.assert_allclose(
        np.asarray(counts.ep_counts.sum(axis=1)), 50.0
    )
    # full-path counts sum to moves
    np.testing.assert_allclose(
        np.asarray(counts.fp_counts.sum(axis=1)),
        np.asarray(counts.moves),
    )


def test_dangling_walk_returns_to_source(key):
    # 0 -> 1, 1 dangling: PPR(0) must put all non-teleport mass on {0, 1}
    g = Graph.from_edges([0], [1], n=3)
    est = mcfp.estimate_ppr(g, jnp.asarray([0], jnp.int32), r=500, key=key)
    assert float(est[0, 2]) == 0.0
    assert float(est[0, 0] + est[0, 1]) == pytest.approx(1.0, abs=1e-5)


def test_dangling_ppr_is_self(key):
    # dangling source: p_u = e_u exactly (walk always returns home)
    g = Graph.from_edges([0], [1], n=2)
    est = mcfp.estimate_ppr(g, jnp.asarray([1], jnp.int32), r=200, key=key)
    np.testing.assert_allclose(np.asarray(est[0]), [0.0, 1.0], atol=1e-6)
    p = power_iteration(g, jnp.asarray([1], jnp.int32), n_iter=50)
    np.testing.assert_allclose(np.asarray(p[0]), [0.0, 1.0], atol=1e-6)


# ---------------------------------------------------------------------------
# VERD
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def nodangle_graph():
    """Strongly-connected-ish graph with no dangling vertices."""
    g = synthetic.erdos_renyi(40, 5.0, seed=3)
    # add a cycle so every vertex has out-degree >= 1
    src = np.concatenate([np.asarray(g.src), np.arange(40)])
    dst = np.concatenate([np.asarray(g.col_idx), (np.arange(40) + 1) % 40])
    return Graph.from_edges(src, dst, n=40)


def test_decomposition_theorem_exact(nodangle_graph):
    """Theorem 2.2: p_u = c e_u + (1-c)/|O(u)| sum p_v for exact vectors."""
    ex = exact_ppr_dense(nodangle_graph)
    g = nodangle_graph
    for u in [0, 7, 13]:
        nbrs = g.out_neighbors(u)
        rhs = 0.15 * np.eye(g.n)[u] + 0.85 / len(nbrs) * sum(
            ex[int(v)] for v in nbrs
        )
        np.testing.assert_allclose(ex[u], rhs, atol=1e-10)


def test_verd_equals_recursive_decomp(nodangle_graph):
    """Theorem 2.3: vc-decomp(u, T) == decomp(u, T) with shared base."""
    g = nodangle_graph
    rng = np.random.default_rng(0)
    base = rng.random((g.n, g.n)).astype(np.float64)
    base /= base.sum(axis=1, keepdims=True)
    sources = jnp.asarray([0, 5, 9], dtype=jnp.int32)
    for t in [0, 1, 2, 3]:
        s, f = verd_mod.verd_iterate(g, sources, t=t)
        idx = index_from_dense(jnp.asarray(base, jnp.float32), l=g.n)
        got = np.asarray(verd_mod.combine_with_index(s, f, idx))
        for row, u in enumerate([0, 5, 9]):
            want = verd_mod.recursive_decomp(g, u, t, base)
            np.testing.assert_allclose(got[row], want, atol=1e-5)


def test_verd_with_exact_index_is_exact(nodangle_graph):
    """Combining with exact PPR vectors reproduces them exactly (any T)."""
    g = nodangle_graph
    ex = jnp.asarray(exact_ppr_dense(g), jnp.float32)
    idx = index_from_dense(ex, l=g.n)
    sources = jnp.asarray([1, 2, 3], dtype=jnp.int32)
    for t in [0, 2, 4]:
        got = verd_mod.verd_query(g, sources, idx, t=t)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ex[1:4]), atol=1e-4
        )


def test_verd_no_index_converges_to_ppr(nodangle_graph):
    g = nodangle_graph
    ex = exact_ppr_dense(g)
    sources = jnp.asarray([0, 4], dtype=jnp.int32)
    prev_err = None
    for t in [2, 8, 48]:
        got = np.asarray(verd_mod.verd_query(g, sources, None, t=t))
        err = np.abs(got - ex[[0, 4]]).sum(axis=1).mean()
        # residual frontier mass is exactly (1-c)^t
        assert err < 2.0 * 0.85 ** t + 1e-4
        if prev_err is not None:
            assert err < prev_err
        prev_err = err
    assert prev_err < 1e-3


def test_verd_improves_on_raw_index(small_graph, exact_small, key):
    """The paper's key claim: VERD(T) on a low-R index beats the index."""
    g = small_graph
    idx, _ = build_index(g, r=30, l=32, key=key, source_batch=64)
    ex = jnp.asarray(exact_small, jnp.float32)
    sources = jnp.arange(16, dtype=jnp.int32)
    raw = idx.lookup_dense(sources)
    refined = verd_mod.verd_query(g, sources, idx, t=2)
    rag_raw = metrics.mean_rag(ex[:16], raw, k=10)
    rag_ref = metrics.mean_rag(ex[:16], refined, k=10)
    assert rag_ref > rag_raw - 1e-6
    assert rag_ref > 0.98


# ---------------------------------------------------------------------------
# Index + planner
# ---------------------------------------------------------------------------

def test_index_build_and_lookup(small_graph, key):
    idx, stats = build_index(small_graph, r=100, l=16, key=key)
    assert idx.values.shape == (small_graph.n, 16)
    assert stats["drop_fraction"] < 0.5
    dense = idx.lookup_dense(jnp.asarray([0, 1], jnp.int32))
    assert dense.shape == (2, small_graph.n)
    # kept mass is a sub-probability
    assert float(dense.sum(axis=1).max()) <= 1.0 + 1e-4


def test_truncation_drops_recorded(small_graph, key):
    idx_wide, s_wide = build_index(small_graph, r=200, l=48, key=key)
    idx_narrow, s_narrow = build_index(small_graph, r=200, l=4, key=key)
    assert s_narrow["drop_fraction"] > s_wide["drop_fraction"]


def test_plan_for_budget_monotone():
    p1 = plan_for_budget(n=1000, budget_bytes=1 << 20)
    p2 = plan_for_budget(n=1000, budget_bytes=1 << 24)
    assert p2.l > p1.l and p2.r >= p1.r and p2.t_online <= p1.t_online
    assert p1.index_bytes <= p1.budget_bytes


# ---------------------------------------------------------------------------
# Theory
# ---------------------------------------------------------------------------

def test_theorem_bound_monotone():
    assert theory.overestimate_bound(0.1, 2000) < theory.overestimate_bound(
        0.1, 500
    )
    assert theory.overestimate_bound(0.2, 500) < theory.overestimate_bound(
        0.1, 500
    )


def test_walks_required_inverts_bound():
    r = theory.walks_required(gamma=0.1, delta=0.01)
    assert theory.two_sided_bound(0.1, r) <= 0.01
    assert theory.two_sided_bound(0.1, r // 2) > 0.01


def test_mcep_equivalent_ratio_matches_paper():
    # paper: 1000 MCFP walks ~ 6700 MCEP walks at c=0.15
    assert theory.mcep_equivalent_walks(1000) == pytest.approx(6667, abs=40)


def test_empirical_error_within_bound(small_graph, exact_small, key):
    """Monte-Carlo error should respect Theorem 2.1 at small failure prob."""
    sources = jnp.arange(8, dtype=jnp.int32)
    r = 1600
    est = np.asarray(mcfp.estimate_ppr(small_graph, sources, r=r, key=key))
    err = np.abs(est - exact_small[:8]).max()
    # pick gamma where the bound is tiny; empirical max error must be below
    gamma = 0.35
    assert theory.two_sided_bound(gamma, r) < 0.01
    assert err < gamma


# ---------------------------------------------------------------------------
# Query engine
# ---------------------------------------------------------------------------

def test_query_engine_modes(small_graph, exact_small, key):
    idx, _ = build_index(small_graph, r=100, l=32, key=key)
    ex = jnp.asarray(exact_small, jnp.float32)
    sources = np.arange(12, dtype=np.int32)
    for mode, min_rag in [
        ("powerwalk", 0.97),
        ("verd", 0.90),
        ("fppr", 0.80),
        ("mcfp", 0.97),
        ("pi", 0.999),
    ]:
        cfg = QueryConfig(mode=mode, t_iterations=3, top_k=10)
        eng = BatchQueryEngine(small_graph, idx, cfg)
        out = eng.run(sources)
        assert out["values"].shape == (12, 10)
        dense = eng.query_dense(jnp.asarray(sources))
        rag = metrics.mean_rag(ex[:12], dense, k=10)
        assert rag > min_rag, (mode, rag)


def test_query_engine_requires_index():
    g = synthetic.cycle(8)
    with pytest.raises(ValueError):
        BatchQueryEngine(g, None, QueryConfig(mode="powerwalk"))


def test_query_engine_rejects_short_index(small_graph, key):
    """An index with fewer rows than the graph has vertices can't answer
    every query; a *longer* (padded, sharded-build) index is accepted."""
    idx, _ = build_index(
        small_graph, r=10, l=4, key=key,
        sources=np.arange(4, dtype=np.int32),
    )
    short = dataclasses.replace(
        idx, values=idx.values[:4], indices=idx.indices[:4], n=4
    )
    with pytest.raises(ValueError):
        BatchQueryEngine(small_graph, short, QueryConfig(mode="powerwalk"))
    padded = dataclasses.replace(
        idx,
        values=jnp.pad(idx.values, ((0, 8), (0, 0))),
        indices=jnp.pad(idx.indices, ((0, 8), (0, 0))),
        n=idx.n + 8,
    )
    eng = BatchQueryEngine(
        small_graph, padded, QueryConfig(mode="powerwalk", top_k=5)
    )
    base = BatchQueryEngine(
        small_graph, idx, QueryConfig(mode="powerwalk", top_k=5)
    )
    np.testing.assert_allclose(
        eng.run([0, 1, 2])["values"], base.run([0, 1, 2])["values"],
        rtol=1e-6,
    )


def test_top_k_clamped_to_graph(key):
    """ISSUE 5 bugfix: top_k > n (or > frontier_k on the sparse route) must
    clamp in one place so every route returns the width the host buffers
    were allocated for."""
    from repro.core.graph import Graph
    from repro.serving.engine import PPRService, ServiceConfig

    g = Graph.from_edges(
        [0, 1, 2, 3, 4, 5, 6, 0], [1, 2, 3, 4, 5, 6, 0, 3], n=8
    )
    idx, _ = build_index(g, r=50, l=8, key=key)
    for path in ("dense", "sparse"):
        eng = BatchQueryEngine(
            g, idx,
            QueryConfig(mode="powerwalk", top_k=200, frontier_path=path),
        )
        assert eng.effective_top_k == 8
        out = eng.run([0, 3, 5])
        assert out["values"].shape == (3, 8), path
        assert out["indices"].shape == (3, 8), path
        assert out["top_k"] == 8
    # the served product: poll() answers carry the clamped width too
    svc = PPRService(
        g, idx, ServiceConfig(query=QueryConfig(mode="powerwalk", top_k=200))
    )
    svc.submit(0)
    answers = svc.poll(force=True)
    assert svc.answer_k == 8
    assert answers[0].top_vertices.shape == (8,)
    assert answers[0].top_scores.shape == (8,)


def test_mcfp_seed_reproducible_per_chunk(small_graph):
    """ISSUE 5 bugfix: mcfp answers fold (seed, chunk offset) so re-running
    an engine — or rebuilding one with the same seed — replays identical
    Monte-Carlo noise chunk by chunk, while distinct seeds decorrelate."""
    cfg = QueryConfig(mode="mcfp", top_k=10, seed=7, max_batch=2,
                      r_online=500)
    srcs = np.arange(4, dtype=np.int32)
    a = BatchQueryEngine(small_graph, None, cfg).run(srcs)
    b = BatchQueryEngine(small_graph, None, cfg).run(srcs)
    np.testing.assert_array_equal(a["values"], b["values"])
    eng = BatchQueryEngine(small_graph, None, cfg)
    np.testing.assert_array_equal(
        eng.run(srcs)["values"], eng.run(srcs)["values"]
    )
    other = BatchQueryEngine(
        small_graph, None, dataclasses.replace(cfg, seed=8)
    ).run(srcs)
    assert not np.array_equal(a["values"], other["values"])


def test_batching_equivalence(small_graph, key):
    """Chunked execution must equal single-shot (shared decomposition is
    exact, not approximate)."""
    idx, _ = build_index(small_graph, r=50, l=16, key=key)
    cfg = QueryConfig(mode="powerwalk", t_iterations=2, top_k=5, max_batch=4)
    eng = BatchQueryEngine(small_graph, idx, cfg)
    srcs = np.arange(10, dtype=np.int32)
    out_chunked = eng.run(srcs)
    cfg2 = QueryConfig(mode="powerwalk", t_iterations=2, top_k=5, max_batch=64)
    out_single = BatchQueryEngine(small_graph, idx, cfg2).run(srcs)
    np.testing.assert_allclose(
        out_chunked["values"], out_single["values"], rtol=1e-6
    )
