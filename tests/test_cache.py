"""Answer-cache suite: seed-set canonicalization, LRU/invalidation
mechanics, and the service integration contract — cached answers are
byte-identical to uncached ones because a cache miss dispatches the
*canonical* spelling (serving/engine.py), so every spelling of a key
computes the same bytes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.index import PPRIndex
from repro.core.query import QueryConfig
from repro.graphs import synthetic
from repro.serving import PPRService, ServiceConfig, zipf_seed_workload
from repro.serving.batching import BatchingConfig
from repro.serving.cache import AnswerCache, CacheConfig, canonicalize_seed_set
from repro.serving.pipeline import PipelineConfig


# ---------------------------------------------------------------------------
# canonicalization
# ---------------------------------------------------------------------------

def test_canonical_key_sorts_by_vertex():
    key = canonicalize_seed_set([9, 3, 7], [0.2, 0.5, 0.3])
    assert key[0] == (3, 7, 9)
    assert len(key[1]) == 3


def test_permutation_invariance():
    a = canonicalize_seed_set([1, 2, 3], [0.1, 0.2, 0.7])
    b = canonicalize_seed_set([3, 1, 2], [0.7, 0.1, 0.2])
    assert a == b


def test_rescale_invariance():
    a = canonicalize_seed_set([4, 8], [1.0, 3.0])
    b = canonicalize_seed_set([4, 8], [2.5, 7.5])
    assert a == b


def test_duplicate_seeds_dedup_sum():
    # [a, a, b] with weights (1, 1, 2) is the distribution {a: 2, b: 2}
    a = canonicalize_seed_set([5, 5, 6], [1.0, 1.0, 2.0])
    b = canonicalize_seed_set([5, 6], [2.0, 2.0])
    assert a == b
    assert a[0] == (5, 6)
    # equal quantized weights after normalization
    assert a[1][0] == a[1][1]


def test_uniform_default_and_zero_slots():
    # weights=None means uniform; weight-0 slots are pad, dropped
    assert canonicalize_seed_set([3, 1]) == canonicalize_seed_set(
        [1, 3], [5.0, 5.0])
    assert canonicalize_seed_set([1, 2, 0], [0.5, 0.5, 0.0]) == \
        canonicalize_seed_set([1, 2], [1.0, 1.0])


def test_empty_and_all_zero_map_to_empty_key():
    assert canonicalize_seed_set([]) == ((), ())
    assert canonicalize_seed_set([1, 2], [0.0, 0.0]) == ((), ())


def test_quantization_merges_near_identical_weights():
    a = canonicalize_seed_set([1, 2], [0.5, 0.5], weight_quantum=1e-4)
    b = canonicalize_seed_set([1, 2], [0.500004, 0.499996],
                              weight_quantum=1e-4)
    assert a == b
    c = canonicalize_seed_set([1, 2], [0.51, 0.49], weight_quantum=1e-4)
    assert a != c


def test_single_vertex_key():
    assert canonicalize_seed_set([7]) == ((7,), (10000,))  # 1.0 / 1e-4


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        canonicalize_seed_set([1, 2], [1.0])


# ---------------------------------------------------------------------------
# AnswerCache mechanics
# ---------------------------------------------------------------------------

def _ans(tag):
    return (np.full(4, tag, np.int32), np.full(4, float(tag), np.float32))


def _key(*verts):
    return canonicalize_seed_set(list(verts))


def test_lru_eviction_order():
    c = AnswerCache(CacheConfig(capacity=2))
    c.put(_key(1), *_ans(1))
    c.put(_key(2), *_ans(2))
    assert c.get(_key(1)) is not None         # freshen 1: LRU is now 2
    c.put(_key(3), *_ans(3))                  # evicts 2, not 1
    assert c.get(_key(2)) is None
    assert c.get(_key(1)) is not None
    assert c.get(_key(3)) is not None
    assert c.stats["evictions"] == 1
    assert len(c) == 2


def test_stats_counters():
    c = AnswerCache(CacheConfig(capacity=4))
    assert c.get(_key(1)) is None
    c.put(_key(1), *_ans(1))
    assert c.get(_key(1)) is not None
    assert c.stats == dict(hits=1, misses=1, evictions=0, invalidated=0)


def test_put_copies_arrays():
    c = AnswerCache(CacheConfig(capacity=2))
    idx, vals = _ans(1)
    c.put(_key(1), idx, vals)
    idx[:] = -1                               # mutate the caller's buffer
    vals[:] = -1.0
    got_i, got_v = c.get(_key(1))
    np.testing.assert_array_equal(got_i, np.full(4, 1, np.int32))
    np.testing.assert_array_equal(got_v, np.full(4, 1.0, np.float32))


def test_invalidate_exactly_touched_entries():
    c = AnswerCache(CacheConfig(capacity=8))
    c.put(_key(1, 2), *_ans(1))
    c.put(_key(2, 3), *_ans(2))
    c.put(_key(4, 5), *_ans(3))
    assert c.invalidate([2]) == 2             # both entries containing 2
    assert c.get(_key(1, 2)) is None
    assert c.get(_key(2, 3)) is None
    assert c.get(_key(4, 5)) is not None      # untouched entry survives
    assert c.stats["invalidated"] == 2
    # the reverse index was cleaned up: re-invalidating removes nothing
    assert c.invalidate([1, 2, 3]) == 0


def test_invalidate_then_reinsert():
    c = AnswerCache(CacheConfig(capacity=8))
    c.put(_key(1, 2), *_ans(1))
    c.invalidate([1])
    c.put(_key(1, 2), *_ans(9))
    got_i, _ = c.get(_key(1, 2))
    assert got_i[0] == 9


def test_eviction_unindexes():
    c = AnswerCache(CacheConfig(capacity=1))
    c.put(_key(1), *_ans(1))
    c.put(_key(2), *_ans(2))                  # evicts key(1)
    assert c.invalidate([1]) == 0             # stale index entry is gone


def test_disabled_cache_is_inert():
    c = AnswerCache(CacheConfig(capacity=0))
    assert not c.enabled
    c.put(_key(1), *_ans(1))
    assert c.get(_key(1)) is None
    assert len(c) == 0
    assert c.stats["misses"] == 0             # disabled get doesn't count


def test_clear():
    c = AnswerCache(CacheConfig(capacity=4))
    c.put(_key(1), *_ans(1))
    c.clear()
    assert len(c) == 0
    assert c.invalidate([1]) == 0


# ---------------------------------------------------------------------------
# service integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph():
    return synthetic.rmat(11, avg_deg=8.0, seed=2)  # n = 2048


@pytest.fixture(scope="module")
def index(graph):
    kv, ki = jax.random.split(jax.random.PRNGKey(4))
    vals = jax.random.uniform(kv, (graph.n, 16), jnp.float32)
    vals = jnp.sort(vals / vals.sum(axis=1, keepdims=True), axis=1)[:, ::-1]
    idxs = jax.random.randint(ki, (graph.n, 16), 0, graph.n, jnp.int32)
    return PPRIndex(values=vals, indices=idxs, l=16, n=graph.n)


def _service(graph, index, *, capacity, max_seeds=4, max_batch=16, depth=1):
    cfg = ServiceConfig(
        query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=32,
                          frontier_k=128, max_seeds=max_seeds),
        batching=BatchingConfig(max_batch=max_batch),
        pipeline=PipelineConfig(depth=depth),
        cache=CacheConfig(capacity=capacity),
    )
    return PPRService(graph, index, cfg)


def test_cache_hit_skips_dispatch(graph, index):
    svc = _service(graph, index, capacity=32)
    svc.submit(seeds=[3, 5], weights=[1.0, 1.0])
    first = svc.poll(force=True)
    assert len(first) == 1 and not first[0].cached
    batches_before = svc.stats["batches"]
    # same distribution, different spelling: permuted + rescaled
    rid = svc.submit(seeds=[5, 3], weights=[2.0, 2.0])
    hits = svc.poll(force=True)
    assert len(hits) == 1 and hits[0].request_id == rid
    assert hits[0].cached
    assert svc.stats["batches"] == batches_before      # no dispatch
    np.testing.assert_array_equal(hits[0].top_vertices,
                                  first[0].top_vertices)
    np.testing.assert_array_equal(hits[0].top_scores, first[0].top_scores)
    s = svc.snapshot_stats()
    assert s["cache_served"] == 1 and s["cache_hits"] == 1
    assert s["cache_hit_rate"] > 0


def test_single_vertex_requests_share_cache_with_s1_sets(graph, index):
    svc = _service(graph, index, capacity=32)
    svc.submit(77)
    svc.poll(force=True)
    svc.submit(seeds=[77])                    # S=1 set, same canonical key
    a = svc.poll(force=True)
    assert a[0].cached


def test_service_invalidate_hook(graph, index):
    svc = _service(graph, index, capacity=32)
    svc.submit(seeds=[3, 5])
    svc.submit(seeds=[8, 9])
    svc.poll(force=True)
    assert svc.invalidate([5]) == 1           # exactly the touched entry
    svc.submit(seeds=[3, 5])                  # recomputes
    a = svc.poll(force=True)
    assert not a[0].cached
    svc.submit(seeds=[9, 8])                  # untouched entry still hits
    a = svc.poll(force=True)
    assert a[0].cached
    assert svc.snapshot_stats()["cache_invalidated"] == 1


def test_cached_answers_byte_identical_to_uncached(graph, index):
    """The acceptance property: run Zipf hot-seed traffic (permuted and
    rescaled spellings) through a cache-on service; every answer must be
    byte-identical to a cache-off service answering the same canonical
    query.  Holds because misses dispatch the canonical spelling."""
    items = zipf_seed_workload(graph.n, 90, skew=1.2, max_seeds=4, pool=16,
                               seed=9)
    svc = _service(graph, index, capacity=64)
    rid_to_item = {}
    answers = {}
    for i, it in enumerate(items):
        rid = svc.submit(seeds=it["seeds"], weights=it["weights"])
        rid_to_item[rid] = it
        if i % 6 == 5:                        # absorb so later repeats hit
            for a in svc.poll(force=True):
                answers[a.request_id] = a
    for a in svc.poll(force=True):
        answers[a.request_id] = a
    assert len(answers) == len(items)
    assert svc.snapshot_stats()["cache_hits"] > 0      # traffic was hot
    assert any(a.cached for a in answers.values())

    # uncached reference: a cache-off service answering each distinct
    # canonical query once
    ref = _service(graph, index, capacity=0)
    ref_rids = {}
    for it in items:
        key = canonicalize_seed_set(it["seeds"], it["weights"])
        if key not in ref_rids:
            ref_rids[key] = ref.submit(
                seeds=list(key[0]), weights=[q * 1e-4 for q in key[1]])
    ref_answers = {a.request_id: a for a in ref.poll(force=True)}
    for rid, it in rid_to_item.items():
        key = canonicalize_seed_set(it["seeds"], it["weights"])
        a, r = answers[rid], ref_answers[ref_rids[key]]
        np.testing.assert_array_equal(a.top_vertices, r.top_vertices)
        np.testing.assert_array_equal(a.top_scores, r.top_scores)


def test_cache_off_by_default(graph, index):
    svc = _service(graph, index, capacity=0)
    svc.submit(seeds=[3, 5])
    svc.poll(force=True)
    svc.submit(seeds=[3, 5])
    a = svc.poll(force=True)
    assert not a[0].cached
    s = svc.snapshot_stats()
    assert s["cache_served"] == 0 and s["cache_capacity"] == 0


# ---------------------------------------------------------------------------
# reverse-index hygiene + epoch fencing (ISSUE 8 satellites)
# ---------------------------------------------------------------------------

def test_epoch_bumps_on_invalidate_and_clear():
    c = AnswerCache(CacheConfig(capacity=4))
    assert c.epoch == 0
    c.put(_key(1), *_ans(1))
    c.get(_key(1))
    assert c.epoch == 0                       # reads/writes never fence
    assert c.invalidate([1]) == 1
    assert c.epoch == 1
    assert c.invalidate([99]) == 0            # nothing removed...
    assert c.epoch == 2                       # ...but the fence still moves
    c.clear()
    assert c.epoch == 3


def test_invalidate_counts_only_live_entries():
    c = AnswerCache(CacheConfig(capacity=4))
    c.put(_key(1, 2), *_ans(1))
    c.put(_key(2, 3), *_ans(2))
    assert c.invalidate([2]) == 2             # both entries seed vertex 2
    assert c.stats["invalidated"] == 2
    assert c.invalidate([2]) == 0             # idempotent: nothing doubles
    assert c.stats["invalidated"] == 2
    c.check_integrity()


def test_reverse_index_integrity_under_churn():
    """Random put/get/invalidate churn against a tiny capacity (so LRU
    eviction fires constantly): after every operation the reverse index
    must exactly mirror the live entries — the eviction/invalidation
    hygiene assertion snapshot_stats runs in production."""
    rng = np.random.default_rng(3)
    c = AnswerCache(CacheConfig(capacity=6))
    c.check_integrity()
    for step in range(400):
        verts = rng.integers(0, 10, size=int(rng.integers(1, 4)))
        op = int(rng.integers(0, 6))
        if op <= 2:
            c.put(_key(*verts), *_ans(step))
        elif op == 3:
            c.get(_key(*verts))
        elif op == 4:
            c.invalidate(verts)
        else:
            c.put(_key(*verts), *_ans(step))  # refresh an existing key
        c.check_integrity()
    assert c.stats["evictions"] > 0
    assert c.stats["invalidated"] > 0
    assert c.reverse_index_entries() == sum(len(k[0]) for k in c._data)


def test_check_integrity_detects_injected_corruption():
    c = AnswerCache(CacheConfig(capacity=4))
    c.put(_key(1, 2), *_ans(1))
    c.check_integrity()
    c._by_vertex[5] = {_key(1, 2)}            # bucket for a non-seed vertex
    with pytest.raises(AssertionError):
        c.check_integrity()


# ---------------------------------------------------------------------------
# invalidate-vs-in-flight race (epoch fencing through the pipeline)
# ---------------------------------------------------------------------------

class _LatchArr:
    """Numpy result wrapper whose readiness is an injected latch: lets a
    test hold a dispatched batch 'on the device' while the cache mutates,
    then release it — deterministic completion-order injection."""

    def __init__(self, arr, latch):
        self._arr = np.asarray(arr)
        self._latch = latch

    def is_ready(self):
        return self._latch["ready"]

    def __getitem__(self, s):
        return self._arr[s]


class _LatchEngine:
    def __init__(self, k, latch):
        self.k, self.latch = k, latch

    def dispatch_key(self, seq):
        return seq

    def query_topk_async(self, verts, *, key=None, **kw):
        q = len(verts)
        vals = np.tile(np.linspace(1.0, 0.1, self.k, dtype=np.float32),
                       (q, 1))
        idx = np.tile(np.arange(self.k, dtype=np.int32), (q, 1))
        return _LatchArr(vals, self.latch), _LatchArr(idx, self.latch)


def _latched_service(graph, index, latch):
    cfg = ServiceConfig(
        query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=4,
                          frontier_k=16, max_seeds=4),
        batching=BatchingConfig(max_batch=1),
        pipeline=PipelineConfig(depth=2, reuse_buffers=False),
        cache=CacheConfig(capacity=8),
    )
    svc = PPRService(graph, index, cfg, clock=lambda: 0.0)
    svc.pipeline.engine = _LatchEngine(4, latch)
    return svc


def test_invalidate_while_in_flight_drops_stale_absorb(graph, index):
    """The race: a batch is dispatched, then the entry's vertices are
    invalidated *before* the batch completes.  The harvested answer (which
    was computed on the pre-update index) must be returned to its client
    but never absorbed into the cache, where it would outlive the
    invalidation as a stale hit."""
    latch = {"ready": False}
    svc = _latched_service(graph, index, latch)
    svc.submit(7)
    assert svc.poll() == [] and svc.in_flight == 1  # held on the "device"
    svc.invalidate([7])                   # epoch 0 -> 1 while in flight
    latch["ready"] = True
    ans = svc.poll(force=True)
    assert len(ans) == 1 and not ans[0].cached      # client still answered
    assert svc.stats["cache_stale_drops"] == 1
    assert len(svc.cache) == 0                      # stale bytes not cached
    # recomputation under the new epoch caches normally again
    svc.submit(7)
    assert not svc.poll(force=True)[0].cached
    assert len(svc.cache) == 1
    svc.submit(7)
    assert svc.poll(force=True)[0].cached
    s = svc.snapshot_stats()
    assert s["cache_epoch"] == 1 and s["cache_stale_drops"] == 1


def test_in_flight_batch_absorbed_without_invalidate(graph, index):
    """Control path: same injected completion order, no invalidate — the
    late-completing batch is absorbed normally."""
    latch = {"ready": False}
    svc = _latched_service(graph, index, latch)
    svc.submit(7)
    assert svc.poll() == [] and svc.in_flight == 1
    latch["ready"] = True
    ans = svc.poll(force=True)
    assert len(ans) == 1
    assert svc.stats["cache_stale_drops"] == 0
    assert len(svc.cache) == 1
    svc.submit(7)
    assert svc.poll(force=True)[0].cached
