import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    Graph,
    dangling_mass,
    push_forward,
    reverse,
    transition_with_dangling,
    bucket_sample_sources,
    degree_histogram,
)
from repro.graphs import synthetic


def test_from_edges_csr_structure():
    g = Graph.from_edges([0, 0, 1, 2], [1, 2, 2, 0], n=3)
    assert g.n == 3 and g.m == 4
    np.testing.assert_array_equal(np.asarray(g.out_deg), [2, 1, 1])
    np.testing.assert_array_equal(np.asarray(g.row_ptr), [0, 2, 3, 4])
    assert set(map(int, g.out_neighbors(0))) == {1, 2}


def test_dangling_detection():
    g = synthetic.figure2_graph()
    dang = np.asarray(g.dangling_mask)
    # v5..v8 (ids 4..7) are dangling in our figure-2 rendering
    assert dang[4] and dang[5] and dang[6] and dang[7]
    assert not dang[0]


def test_push_forward_matches_dense():
    g = synthetic.erdos_renyi(32, 4.0, seed=1)
    a = g.dense_transition(source=None)
    f = np.random.default_rng(0).random((5, 32)).astype(np.float32)
    got = np.asarray(push_forward(g, jnp.asarray(f)))
    want = f @ a.astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_transition_with_dangling_conserves_mass():
    g = synthetic.figure2_graph()
    sources = jnp.asarray([0, 3], dtype=jnp.int32)
    f = jnp.zeros((2, g.n)).at[jnp.arange(2), sources].set(1.0)
    for _ in range(5):
        f = transition_with_dangling(g, f, sources)
        np.testing.assert_allclose(np.asarray(f.sum(axis=1)), 1.0, rtol=1e-5)


def test_dangling_mass_value():
    g = Graph.from_edges([0], [1], n=2)  # 1 is dangling
    f = jnp.asarray([[0.25, 0.75]])
    assert float(dangling_mass(g, f)[0]) == pytest.approx(0.75)


def test_reverse_roundtrip():
    g = synthetic.erdos_renyi(64, 3.0, seed=2)
    rg = reverse(g)
    assert rg.m == g.m
    rrg = reverse(rg)
    # same edge multiset
    e1 = sorted(zip(np.asarray(g.src).tolist(), np.asarray(g.col_idx).tolist()))
    e2 = sorted(zip(np.asarray(rrg.src).tolist(), np.asarray(rrg.col_idx).tolist()))
    assert e1 == e2


def test_degree_histogram_and_bucket_sampling():
    g = synthetic.rmat(10, avg_deg=8.0, seed=3)
    hist = degree_histogram(g)
    assert hist.sum() == g.n
    srcs = bucket_sample_sources(g, per_bucket=5, seed=0)
    assert len(srcs) > 0
    deg = np.asarray(g.out_deg)[srcs]
    assert (deg >= 0).all()


def test_rmat_power_law_ish():
    g = synthetic.rmat(12, avg_deg=8.0, seed=0)
    deg = np.asarray(g.out_deg)
    # heavy tail: max degree far above mean
    assert deg.max() > 10 * max(deg.mean(), 1.0)


def test_bipartite_shapes():
    g = synthetic.bipartite_recsys(100, 50, avg_deg=4.0, seed=0)
    assert g.n == 150
    src = np.asarray(g.src)
    dst = np.asarray(g.col_idx)
    users = src < 100
    assert (dst[users] >= 100).all()  # user edges go to items
