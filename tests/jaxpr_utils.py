"""Shared jaxpr-walking helper for the memory-contract tests.

Lives as a plain module (not a fixture) so both the pytest suites and the
subprocess harnesses (``dist_engine_check.py``, which run with the tests
directory as ``sys.path[0]``) can import one copy — recursive jaxpr
iteration has to track JAX's ``ClosedJaxpr``/params layout, and that must
not drift across copies.
"""

import jax.core as jcore


def iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr``, recursing into sub-jaxpr params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                if isinstance(u, jcore.ClosedJaxpr):
                    yield from iter_eqns(u.jaxpr)
                elif isinstance(u, jcore.Jaxpr):
                    yield from iter_eqns(u)
