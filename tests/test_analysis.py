"""The auditor audited: each rule must fire on its violation fixture with
the right file:line anchor, suppression must work exactly as documented,
and (slow) the full runner must come back clean over the real codebase —
the no-false-positive gate `make lint-contracts` relies on.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import registry
from repro.analysis.jaxpr import (
    dense_state_findings,
    hbm_contract_findings,
    iter_eqns,
    pallas_block_specs,
    replicated_index_findings,
)
from repro.analysis.lint import (
    BARE_TIME,
    HOST_SYNC,
    RNG_DISCIPLINE,
    lint_file,
)

FIXTURES = Path(__file__).parent / "analysis_fixtures"
REPO_ROOT = Path(__file__).resolve().parents[1]


def _marker_line(path: Path, marker: str) -> int:
    """1-based line of the unique ``# [viol:<marker>]`` tag in a fixture."""
    hits = [
        i for i, line in enumerate(path.read_text().splitlines(), start=1)
        if f"[viol:{marker}]" in line
    ]
    assert len(hits) == 1, (path, marker, hits)
    return hits[0]


# -- jaxpr rules fire on their violation fixtures ---------------------------

def test_hbm_residency_fires_on_vmem_kernel():
    from analysis_fixtures import bad_kernel

    args = bad_kernel.make_args(m=4096)
    blocks = pallas_block_specs(bad_kernel.vmem_resident_gather, *args)
    assert blocks, "fixture kernel produced no pallas_call blocks"
    findings = hbm_contract_findings(
        blocks, hbm_shapes=[(4096,)], vmem_budget=256,
        anchor="tests/analysis_fixtures/bad_kernel.py",
    )
    assert findings, blocks
    assert any("VMEM" in f.message for f in findings)
    assert all(f.rule == "hbm-residency" for f in findings)
    assert findings[0].file == "tests/analysis_fixtures/bad_kernel.py"


def test_hbm_residency_passes_on_real_kernel(rng):
    """Control: the real frontier_push entry point yields zero findings."""
    from repro.kernels import frontier_push as push_mod

    spec = push_mod._contract_spec_frontier_push()
    blocks = pallas_block_specs(spec["fn"], *spec["args"])
    assert hbm_contract_findings(
        blocks, hbm_shapes=spec["hbm_shapes"],
        vmem_budget=spec["vmem_budget"],
    ) == []


def test_no_replicated_index_fires_on_replicated_step():
    from analysis_fixtures import bad_build_step

    jaxpr = bad_build_step.trace(n=64, l=16)
    findings = replicated_index_findings(
        jaxpr, n=64, l=16, anchor="tests/analysis_fixtures/bad_build_step.py"
    )
    assert findings
    assert any("(64, 16)" in f.message for f in findings)
    assert all(f.rule == "no-replicated-index" for f in findings)


def test_dense_state_bound_fires_on_dense_intermediate():
    def dense_chunk(rows):
        # a [rows, n]-dense accumulator: what the sparse build must never hold
        return jnp.zeros((rows.shape[0], 4096), jnp.float32) + 1.0

    jaxpr = jax.make_jaxpr(dense_chunk)(jnp.arange(64, dtype=jnp.int32))
    findings = dense_state_findings(jaxpr, budget=10_000, floor=64 * 4096)
    assert findings
    assert any("exceeds the sparse-state budget" in f.message
               for f in findings)


def test_dense_state_bound_budget_needs_teeth():
    jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(8, jnp.float32))
    findings = dense_state_findings(jaxpr, budget=100, floor=100)
    assert findings and "no teeth" in findings[0].message


def test_retrace_guard_fires_on_weak_type_wobble():
    """A dispatcher that feeds the same width as f32 one call and as int32
    the next compiles two entries per width."""
    from repro.analysis import rules as rules_mod

    @jax.jit
    def fused(x):
        return x * 2.0

    def call(width, variant):
        if variant == 0:
            fused(np.zeros(width, np.float32))
        else:
            fused(np.zeros(width, np.int32))     # dtype wobble: retraces

    saved = registry.entry_points()
    registry.clear_entry_points()
    try:
        registry.register_entry_point(
            "bad-dispatch", "retrace-guard", "tests/test_analysis.py",
            lambda: dict(jit_fn=fused, widths=[1, 2, 4], variants=2,
                         call=call),
        )
        res = rules_mod._run_retrace_guard()
    finally:
        registry.clear_entry_points()
        for ep in saved:
            registry.register_entry_point(ep.name, ep.rule, ep.module,
                                          ep.build)
    assert res.status == "FAIL"
    assert "retracing" in res.findings[0].message


# -- lint rules fire with the right file:line -------------------------------

def test_host_sync_fixture_lines():
    path = FIXTURES / "bad_hot_path.py"
    anchor = "tests/analysis_fixtures/bad_hot_path.py"
    findings = lint_file(path, anchor, [HOST_SYNC])
    unsuppressed = {f.line for f in findings if not f.suppressed}
    for marker in ("truthiness", "float", "item", "asarray", "bool"):
        assert _marker_line(path, marker) in unsuppressed, marker
    assert all(f.file == anchor for f in findings)


def test_host_sync_suppression_and_missing_justification():
    path = FIXTURES / "bad_hot_path.py"
    findings = lint_file(path, "x.py", [HOST_SYNC])
    ok_line = next(
        i for i, line in enumerate(path.read_text().splitlines(), start=1)
        if "[ok:suppressed]" in line
    )
    sup = [f for f in findings if f.line == ok_line]
    assert len(sup) == 1 and sup[0].suppressed
    assert "post-is_ready harvest" in sup[0].justification
    missing = [f for f in findings
               if not f.suppressed and "missing the required justification"
               in f.message]
    assert len(missing) == 1


def test_rng_discipline_fixture_lines():
    path = FIXTURES / "bad_rng.py"
    findings = lint_file(path, "bad_rng.py", [RNG_DISCIPLINE])
    lines = {f.line for f in findings}
    assert _marker_line(path, "split-state") in lines
    assert _marker_line(path, "fold-data") in lines
    assert all(not f.suppressed for f in findings)


def test_bare_time_fixture_line():
    path = FIXTURES / "bad_rng.py"
    findings = lint_file(path, "bad_rng.py", [BARE_TIME])
    assert {f.line for f in findings} == {_marker_line(path, "bare-time")}


# -- runner plumbing --------------------------------------------------------

def test_run_rules_only_subset():
    from repro.analysis import rules as rules_mod

    results = rules_mod.run_rules(only=["bare-time"])
    assert [r.rule for r in results] == ["bare-time"]
    with pytest.raises(ValueError, match="unknown rule"):
        rules_mod.run_rules(only=["no-such-rule"])


def test_report_json_shape():
    from repro.analysis import report as report_mod
    from repro.analysis import rules as rules_mod

    results = rules_mod.run_rules(only=["rng-discipline"])
    payload = json.loads(report_mod.render_json(results))
    assert payload["exit_code"] == 0
    (entry,) = payload["results"]
    assert entry["rule"] == "rng-discipline"
    assert entry["status"] == "PASS"
    assert entry["audited"]


# -- the no-false-positive gate over the real codebase ----------------------

@pytest.mark.slow
def test_auditor_clean_on_real_codebase():
    """`python -m repro.analysis --json` must exit 0 with every rule PASS
    (not SKIP: the runner forces a 4-device host platform, so even the
    no-replicated-index rule runs) and zero unsuppressed findings."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # the runner sets its own device split
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["exit_code"] == 0
    by_rule = {r["rule"]: r for r in payload["results"]}
    assert set(by_rule) == {
        "hbm-residency", "no-replicated-index", "dense-state-bound",
        "retrace-guard", "host-sync", "rng-discipline", "bare-time",
    }
    for rule, entry in by_rule.items():
        assert entry["status"] == "PASS", (rule, entry)
        assert entry["audited"], rule
        assert [f for f in entry["findings"] if not f["suppressed"]] == []
    # the four kernels are all audited under hbm-residency
    assert len(by_rule["hbm-residency"]["audited"]) == 4
