"""Golden regression: pin the ``frontier_path=auto`` selector decisions.

The selector (``BatchQueryEngine.uses_sparse_path`` + the auto-``K``
derivation) is a tuned heuristic over ``(n, mean_degree, degree_cap)``.
This file pins its decisions across a grid so that retuning
``AUTO_SPARSE_MIN_N`` / the auto-``K`` rule later shows up as an explicit
golden diff instead of a silent routing change.

The graphs are shape-only stubs: the selector reads ``n``, ``m`` and the
max out-degree, never the edges, so a uniform ``out_deg`` array is enough
and the grid stays cheap to build.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core.query import AUTO_SPARSE_MIN_N, BatchQueryEngine, QueryConfig


def selector_graph(n: int, mean_deg: int, hub_deg: int = 0) -> Graph:
    """Shape-only graph: uniform out-degree, optional single hub."""
    out_deg = np.full(n, mean_deg, np.int32)
    if hub_deg:
        out_deg[0] = hub_deg
    stub = jnp.zeros(1, jnp.int32)
    return Graph(
        row_ptr=stub, col_idx=stub, src=stub,
        out_deg=jnp.asarray(out_deg), n=n, m=int(out_deg.sum()),
    )


# (n, mean_deg, hub_deg) -> (path, chosen K) at the default QueryConfig
# (mode=verd, t=2, top_k=200).  Regenerate deliberately when retuning:
#   PYTHONPATH=src python -c "from tests.test_golden_auto import dump; dump()"
#
# Retuned for the HBM-resident kernel PR: AUTO_SPARSE_MIN_N dropped
# 1<<15 -> 1<<14 (the recorded bench_query sweep shows the sparse path
# winning 6-8x at n = 16k-20k, docs/query_path.md), which flips the
# n=16_384 row sparse while n=8_192 stays dense.
GOLDEN = {
    (1_024, 4, 0): ("dense", 800),
    (1_024, 16, 0): ("dense", 800),
    (1_024, 64, 0): ("dense", 1_024),
    (8_192, 4, 0): ("dense", 800),           # below the retuned MIN_N
    (16_384, 4, 0): ("sparse", 800),         # newly sparse at MIN_N = 1<<14
    (32_768, 4, 0): ("sparse", 800),
    (32_768, 16, 0): ("sparse", 800),
    (32_768, 64, 0): ("dense", 4_096),       # K*cap blows past n: stay dense
    (262_144, 4, 0): ("sparse", 800),
    (262_144, 16, 0): ("sparse", 800),
    (262_144, 64, 0): ("sparse", 4_096),
    (32_768, 4, 16_384): ("dense", 800),     # hub graph: gather would dwarf n
    (262_144, 4, 131_072): ("dense", 800),
}

# Relaxed hub guard: with ELL splitting on (hub_split_degree = h > 0) the
# selector bounds the gather term by h instead of the max out-degree, so a
# hub graph routes sparse as soon as K * h fits under n — the kernels'
# per-step VMEM is O(q_tile * K * h) regardless of hub size.  Keyed
# (n, mean_deg, hub_deg, hub_split_degree).
GOLDEN_SPLIT = {
    (32_768, 4, 16_384, 32): ("sparse", 800),   # K*h = 25_600 <= n
    (32_768, 4, 16_384, 64): ("dense", 800),    # K*h = 51_200 > n: stay dense
    (262_144, 4, 131_072, 64): ("sparse", 800), # flipped by the relaxation
    (32_768, 64, 0, 8): ("sparse", 4_096),      # K*h = n exactly: boundary
}


@pytest.mark.parametrize("point,want", sorted(GOLDEN.items()))
def test_auto_selector_golden(point, want):
    n, mean_deg, hub_deg = point
    g = selector_graph(n, mean_deg, hub_deg)
    eng = BatchQueryEngine(g, None, QueryConfig(mode="verd"))
    got = ("sparse" if eng.uses_sparse_path() else "dense", eng.frontier_k)
    assert got == want, f"selector drifted at {point}: {got} != {want}"


@pytest.mark.parametrize("point,want", sorted(GOLDEN_SPLIT.items()))
def test_auto_selector_golden_hub_split(point, want):
    n, mean_deg, hub_deg, split = point
    g = selector_graph(n, mean_deg, hub_deg)
    eng = BatchQueryEngine(
        g, None, QueryConfig(mode="verd", hub_split_degree=split)
    )
    got = ("sparse" if eng.uses_sparse_path() else "dense", eng.frontier_k)
    assert got == want, f"selector drifted at {point}: {got} != {want}"


def test_hub_split_relaxes_guard():
    """The acceptance behavior in one line: the same hub-heavy graph routes
    dense unsplit and sparse once a split width bounds the gather axis."""
    g = selector_graph(262_144, 4, 131_072)
    dense_eng = BatchQueryEngine(g, None, QueryConfig(mode="verd"))
    split_eng = BatchQueryEngine(
        g, None, QueryConfig(mode="verd", hub_split_degree=64)
    )
    assert not dense_eng.uses_sparse_path()
    assert split_eng.uses_sparse_path()
    assert split_eng.effective_gather_width() == 64


@pytest.mark.parametrize("q", [1, 64, 4096])
def test_auto_selector_is_batch_size_invariant(q):
    """The route depends on the graph, never on the batch size: a selector
    change that keys on Q would break jit-cache reuse across batches."""
    g = selector_graph(65_536, 8)
    eng = BatchQueryEngine(g, None, QueryConfig(mode="verd", max_batch=q))
    assert eng.uses_sparse_path()
    assert eng.frontier_k == 800


def test_auto_floor_is_pinned():
    """AUTO_SPARSE_MIN_N itself is part of the golden surface (retuned
    1<<15 -> 1<<14 with the HBM-resident kernels, see docs/query_path.md)."""
    assert AUTO_SPARSE_MIN_N == 1 << 14


def dump():  # pragma: no cover - regeneration helper
    for (n, d, h) in sorted(GOLDEN):
        g = selector_graph(n, d, h)
        eng = BatchQueryEngine(g, None, QueryConfig(mode="verd"))
        path = "sparse" if eng.uses_sparse_path() else "dense"
        print(f"    ({n:_}, {d}, {h:_}): ({path!r}, {eng.frontier_k:_}),")
    for (n, d, h, split) in sorted(GOLDEN_SPLIT):
        g = selector_graph(n, d, h)
        eng = BatchQueryEngine(
            g, None, QueryConfig(mode="verd", hub_split_degree=split)
        )
        path = "sparse" if eng.uses_sparse_path() else "dense"
        print(
            f"    ({n:_}, {d}, {h:_}, {split}): "
            f"({path!r}, {eng.frontier_k:_}),"
        )
