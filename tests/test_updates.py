"""Incremental index maintenance (core/updates.py) — fast tier.

Four layers:

* edge-update mechanics — ``apply_edge_updates`` insert/delete semantics,
  strict-delete errors, and the determinism contract (untouched sources'
  CSR windows byte-identical after an update);
* the walks-through touch sketch — hash determinism, and the no-false-
  negative guarantee (every fingerprint-support vertex of a row is a
  member of that row's Bloom filter);
* repair parity — after a random edge batch, ``apply_updates`` on the old
  index equals a from-scratch ``build_index`` on the mutated graph
  *bitwise*, single-device and sharded/padded (the chunk-keyed repair
  replays the build's exact RNG streams);
* the respawn-aware cost model — ``walk_state_cost`` prices the same
  slot-area formula ``test_respawn_schedule_halves_device_work`` pins,
  and ``plan_for_budget`` charges it against the budget.
"""

import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import updates, walks
from repro.core.graph import Graph, apply_edge_updates
from repro.core.index import (build_index, build_index_sharded,
                              plan_for_budget, preprocessing_cost_model,
                              walk_state_cost)
from repro.graphs import synthetic


def _edges(g: Graph) -> np.ndarray:
    return np.stack(
        [np.asarray(g.src, np.int64), np.asarray(g.col_idx, np.int64)],
        axis=1,
    )


def _sample_batch(g, rng, n_del=3, n_ins=3):
    """A random update batch: deletes of distinct existing edge rows
    (deduped so strict-delete multiplicity always holds) + random inserts."""
    e = _edges(g)
    dels = np.unique(e[rng.choice(len(e), size=n_del, replace=False)], axis=0)
    ins = rng.integers(0, g.n, size=(n_ins, 2), dtype=np.int64)
    return ins, dels


# ---------------------------------------------------------------------------
# apply_edge_updates mechanics
# ---------------------------------------------------------------------------

def test_apply_edge_updates_insert_delete():
    g = synthetic.erdos_renyi(64, 3.0, seed=1)
    e = _edges(g)
    dels = np.unique(e[[3, 10, 25]], axis=0)
    ins = np.array([[0, 63], [5, 7]], dtype=np.int64)
    g2, touched = apply_edge_updates(g, inserts=ins, deletes=dels)
    assert g2.n == g.n
    assert g2.m == g.m + len(ins) - len(dels)
    before = collections.Counter(map(tuple, e))
    after = collections.Counter(map(tuple, _edges(g2)))
    for s, d in ins:
        assert after[(s, d)] == before[(s, d)] + 1
    for s, d in dels:
        assert after[(s, d)] == before[(s, d)] - 1
    expect = np.unique(np.concatenate([ins[:, 0], dels[:, 0]]))
    np.testing.assert_array_equal(touched, expect)


def test_apply_edge_updates_strict_delete_raises():
    g = synthetic.erdos_renyi(32, 2.0, seed=4)
    missing = None
    have = set(map(tuple, _edges(g)))
    for s in range(32):
        for d in range(32):
            if (s, d) not in have:
                missing = (s, d)
                break
        if missing:
            break
    with pytest.raises(ValueError, match="not present"):
        apply_edge_updates(g, deletes=np.array([missing]))
    # deleting one more occurrence than exists is also strict
    e0 = tuple(_edges(g)[0])
    k = sum(1 for x in map(tuple, _edges(g)) if x == e0)
    with pytest.raises(ValueError):
        apply_edge_updates(g, deletes=np.array([e0] * (k + 1)))


def test_apply_edge_updates_untouched_csr_windows_identical():
    """The determinism contract repair relies on: sources outside
    ``touched`` keep byte-identical CSR adjacency windows."""
    g = synthetic.erdos_renyi(64, 3.0, seed=2)
    rng = np.random.default_rng(0)
    ins, dels = _sample_batch(g, rng)
    g2, touched = apply_edge_updates(g, inserts=ins, deletes=dels)
    rp, ci = np.asarray(g.row_ptr), np.asarray(g.col_idx)
    rp2, ci2 = np.asarray(g2.row_ptr), np.asarray(g2.col_idx)
    tset = set(int(t) for t in touched)
    assert tset  # batch really touched something
    for v in range(g.n):
        if v in tset:
            continue
        np.testing.assert_array_equal(
            ci[rp[v]:rp[v + 1]], ci2[rp2[v]:rp2[v + 1]],
            err_msg=f"untouched source {v} window changed")


def test_apply_edge_updates_rejects_out_of_range():
    g = synthetic.erdos_renyi(16, 2.0, seed=0)
    with pytest.raises(ValueError):
        apply_edge_updates(g, inserts=np.array([[0, 16]]))
    with pytest.raises(ValueError):
        apply_edge_updates(g, inserts=np.array([[-1, 0]]))


# ---------------------------------------------------------------------------
# touch sketch
# ---------------------------------------------------------------------------

def test_touch_hash_bits_deterministic_in_range():
    v = jnp.arange(200, dtype=jnp.int32)
    b1 = np.asarray(walks.touch_hash_bits(v, 512))
    b2 = np.asarray(walks.touch_hash_bits(v, 512))
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (200, walks.TOUCH_HASHES)
    assert b1.min() >= 0 and b1.max() < 512
    # the k hash functions are distinct (not all columns identical)
    assert any(
        not np.array_equal(b1[:, 0], b1[:, j])
        for j in range(1, walks.TOUCH_HASHES)
    )


def test_default_touch_bits_sizing():
    assert updates.default_touch_bits(1) == 1024
    assert updates.default_touch_bits(16) == 4096
    assert updates.default_touch_bits(10 ** 6) == 65536
    b = updates.default_touch_bits(100)
    assert b & (b - 1) == 0  # power of two


def test_touch_sketch_covers_fingerprint_support(key):
    """No false negatives: every vertex a row's fingerprint puts mass on
    was a counted walk position, so it must hit that row's filter."""
    g = synthetic.erdos_renyi(128, 3.0, seed=2)
    m, _ = updates.build_maintainable_index(
        g, r=4, l=8, key=key, touch_bits=2048, source_batch=32, c=0.25)
    vals = np.asarray(m.index.values)
    idxs = np.asarray(m.index.indices)
    for row in range(0, g.n, 7):
        support = np.unique(idxs[row][vals[row] > 0])
        if not support.size:
            continue
        for v in support:
            dirty = m.touch.dirty_rows([int(v)])
            assert row in dirty, (row, int(v))


def test_plan_repair_includes_touched_sources(key):
    g = synthetic.erdos_renyi(128, 3.0, seed=2)
    m, _ = updates.build_maintainable_index(
        g, r=4, l=8, key=key, touch_bits=2048, source_batch=32, c=0.25)
    plan = updates.plan_repair(m, [5, 77, 5])
    assert {5, 77} <= set(plan["dirty_rows"].tolist())
    sb = m.params.source_batch
    covered = set()
    for ch in plan["chunks"]:
        covered |= set(range(int(ch) * sb, (int(ch) + 1) * sb))
    assert set(plan["dirty_rows"].tolist()) <= covered
    empty = updates.plan_repair(m, [])
    assert empty["dirty_rows"].size == 0 and empty["chunks"].size == 0


# ---------------------------------------------------------------------------
# repair parity vs from-scratch rebuild
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_repair_matches_rebuild_single_device(seed):
    """Property: after a random edge batch, chunk-keyed repair equals a
    from-scratch build on the mutated graph bitwise — dirty rows because
    the repair replays the build's exact per-chunk RNG streams, untouched
    rows because their CSR windows (and streams) never changed."""
    g = synthetic.erdos_renyi(512, 3.0, seed=3)
    key = jax.random.PRNGKey(seed)
    m, _ = updates.build_maintainable_index(
        g, r=2, l=4, key=key, touch_bits=512, source_batch=8, c=0.25)
    rng = np.random.default_rng(seed)
    ins, dels = _sample_batch(g, rng)
    g2, m2, report = updates.apply_updates(m, g, inserts=ins, deletes=dels)
    assert report["rows_replaced"] >= report["dirty_rows"] > 0
    # the invalidation is partial: repair swept strictly fewer chunks
    assert 0 < report["repaired_chunks"] < report["total_chunks"]
    assert report["resample_ratio"] > 1.0
    assert report["resampled_positions"] < report["rebuild_positions"]
    ref, _ = build_index(
        g2, r=2, l=4, key=key, engine="sparse", source_batch=8, c=0.25)
    assert jnp.array_equal(m2.index.values, ref.values)
    assert jnp.array_equal(m2.index.indices, ref.indices)
    # inputs not mutated: the old maintainable still matches the old graph
    old_ref, _ = build_index(
        g, r=2, l=4, key=key, engine="sparse", source_batch=8, c=0.25)
    assert jnp.array_equal(m.index.values, old_ref.values)


def test_repair_matches_rebuild_sharded_padded():
    """Same parity through the sharded build path: the index carries pad
    rows (n=100 -> 112 at source_batch=16) and P(model, None) sharding;
    repair sweeps the padded grid with the build's keys."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = synthetic.erdos_renyi(100, 3.0, seed=5)
    key = jax.random.PRNGKey(0)
    m, stats = updates.build_maintainable_index(
        g, r=4, l=8, key=key, mesh=mesh, touch_bits=1024,
        source_batch=16, c=0.25, respawn=True)
    assert m.index.n > g.n  # padded
    rng = np.random.default_rng(7)
    ins, dels = _sample_batch(g, rng, n_del=2, n_ins=2)
    g2, m2, report = updates.apply_updates(m, g, inserts=ins, deletes=dels)
    assert report["dirty_rows"] > 0
    # dirty_row_ids never name pad rows (the cache-invalidation contract)
    assert report["dirty_row_ids"].max() < g.n
    ref, ref_stats = build_index_sharded(
        g2, r=4, l=8, key=key, mesh=mesh, source_batch=16, c=0.25,
        respawn=True, touch_bits=1024)
    assert jnp.array_equal(m2.index.values, ref.values)
    assert jnp.array_equal(m2.index.indices, ref.indices)
    # the repaired touch sketch matches the rebuild's too, so a second
    # update on the repaired index plans from the same filters
    assert jnp.array_equal(m2.touch.bits, ref_stats["touch"])


def test_apply_updates_noop_returns_same_index(key):
    g = synthetic.erdos_renyi(64, 3.0, seed=1)
    m, _ = updates.build_maintainable_index(
        g, r=2, l=4, key=key, touch_bits=512, source_batch=16, c=0.25)
    g2, m2, report = updates.apply_updates(m, g)
    assert m2 is m
    assert report["repaired_chunks"] == 0
    assert report["dirty_rows"] == 0
    assert g2.m == g.m


def test_apply_updates_rejects_wrong_graph(key):
    g = synthetic.erdos_renyi(64, 3.0, seed=1)
    other = synthetic.erdos_renyi(65, 3.0, seed=1)
    m, _ = updates.build_maintainable_index(
        g, r=2, l=4, key=key, touch_bits=512, source_batch=16, c=0.25)
    with pytest.raises(ValueError, match="built on"):
        updates.apply_updates(m, other, inserts=np.array([[0, 1]]))


# ---------------------------------------------------------------------------
# respawn-aware cost model
# ---------------------------------------------------------------------------

def _device_slots(widths, total_steps, compact_every=8):
    """Same oracle as test_walks_sparse.py: slot positions one pass runs."""
    t0, slots = 0, 0
    for w in widths:
        steps = min(compact_every, total_steps - t0)
        slots += w * steps
        t0 += steps
    return slots


def test_walk_state_cost_prices_actual_schedules():
    r = 16
    decay = walk_state_cost(r, c=0.25, respawn=False)
    resp = walk_state_cost(r, c=0.25, respawn=True)
    assert decay["slot_area"] == _device_slots(
        walks.compaction_schedule(r, c=0.25), 64)
    widths, total = walks.respawn_schedule(r, c=0.25)
    assert resp["slot_area"] == _device_slots(widths, total)
    assert resp["max_width"] == max(widths)
    assert decay["max_width"] == r
    # the contract test_respawn_schedule_halves_device_work pins, now
    # visible to the planner
    assert 2 * resp["slot_area"] <= decay["slot_area"]
    assert resp["walk_state_bytes"] < decay["walk_state_bytes"]
    zero = walk_state_cost(0)
    assert zero["walk_state_bytes"] == 0 and zero["slot_area"] == 0


def test_plan_for_budget_charges_walk_state():
    p = plan_for_budget(n=100_000, budget_bytes=1 << 24)
    assert p.index_bytes + p.walk_state_bytes <= p.budget_bytes
    assert p.walk_state_bytes > 0 and p.respawn
    # respawn's narrower slots afford at least as wide an index
    p_decay = plan_for_budget(n=100_000, budget_bytes=1 << 24, respawn=False)
    assert p_decay.index_bytes + p_decay.walk_state_bytes <= p.budget_bytes
    assert p.l >= p_decay.l
    # degenerate budgets stay sane
    assert plan_for_budget(n=100, budget_bytes=0).l == 0


def test_preprocessing_cost_model_respawn_fields():
    base = preprocessing_cost_model(10_000, 16, respawn=False)
    resp = preprocessing_cost_model(10_000, 16, respawn=True)
    # walk-position totals are schedule-independent...
    assert base["walk_positions"] == resp["walk_positions"]
    # ...but device slot-work and occupancy are not
    assert resp["slot_positions"] < base["slot_positions"]
    assert resp["slot_occupancy"] > base["slot_occupancy"]
    assert resp["max_slot_width"] < base["max_slot_width"]
