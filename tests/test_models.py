"""Model-level correctness: decode/forward consistency, KV quant accuracy,
attention oracle checks, GCN numerics, recsys invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.attention import chunked_attention, decode_attention
from repro.models.recsys import dcn, dlrm, mind, sasrec

pytestmark = pytest.mark.slow  # whole-model steps dominate suite runtime


@pytest.fixture(scope="module")
def small_cfg():
    return T.TransformerConfig(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=211, compute_dtype=jnp.float32, attn_chunk=16, remat=False,
    )


@pytest.fixture(scope="module")
def small_params(small_cfg):
    return T.init(small_cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _ref_attention(q, k, v, n_kv, causal):
    b, s, hq, hd = q.shape
    g = hq // n_kv
    qg = q.reshape(b, s, n_kv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / jnp.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(b, s, hq, hd)


@pytest.mark.parametrize("s,chunk,causal", [
    (32, 8, True), (32, 32, True), (64, 16, False), (48, 16, True),
])
def test_chunked_attention_matches_reference(s, chunk, causal, rng):
    b, hq, hkv, hd = 2, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    got = chunked_attention(q, k, v, n_kv_heads=hkv, causal=causal,
                            chunk=chunk)
    want = _ref_attention(q, k, v, hkv, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_decode_attention_matches_full(rng):
    """One-token decode == last row of full causal attention."""
    b, s, hq, hkv, hd = 2, 24, 4, 2, 16
    q_full = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    full = _ref_attention(q_full, k, v, hkv, causal=True)
    got = decode_attention(q_full[:, -1:], k, v, jnp.asarray(s),
                           n_kv_heads=hkv)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# decode == forward (the KV-cache consistency contract)
# ---------------------------------------------------------------------------

def test_decode_matches_forward(small_cfg, small_params):
    """Token-by-token decode reproduces the parallel forward's logits."""
    cfg, params = small_cfg, small_params
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    h, _ = T.forward(cfg, params, toks)
    logits_full = L.dense_apply(params["lm_head"], h)

    cache = T.init_cache(cfg, b, 16, jnp.float32)
    outs = []
    for t in range(s):
        logits, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1])
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits_full),
                               rtol=5e-3, atol=5e-3)


def test_decode_int8_kv_close_to_fp(small_cfg, small_params):
    """int8 KV quantization must stay close to the fp cache path."""
    cfg, params = small_cfg, small_params
    qcfg = T.TransformerConfig(
        **{**cfg.__dict__, "kv_quant": True}
    )
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab)
    cache_f = T.init_cache(cfg, b, 12, jnp.float32)
    cache_q = T.init_cache(qcfg, b, 12)
    for t in range(s):
        lf, cache_f = T.decode_step(cfg, params, cache_f, toks[:, t:t + 1])
        lq, cache_q = T.decode_step(qcfg, params, cache_q, toks[:, t:t + 1])
    assert cache_q["k"].dtype == jnp.int8
    # logits agree to int8-quantization tolerance
    pf = jax.nn.softmax(lf[:, 0].astype(jnp.float32))
    pq = jax.nn.softmax(lq[:, 0].astype(jnp.float32))
    assert float(jnp.abs(pf - pq).max()) < 0.05
    # top-1 prediction preserved
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(lf, -1)), np.asarray(jnp.argmax(lq, -1)))


def test_moe_decode_matches_forward():
    cfg = T.TransformerConfig(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=101,
        moe=T.MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0),
        compute_dtype=jnp.float32, attn_chunk=8, remat=False,
    )
    params = T.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)
    h, _ = T.forward(cfg, params, toks)
    logits_full = L.dense_apply(params["lm_head"], h)
    cache = T.init_cache(cfg, b, 8, jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    # generous capacity => no token drops => decode == forward
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits_full),
                               rtol=5e-3, atol=5e-3)


def test_loss_chunking_equivalence(small_cfg, small_params):
    cfg0 = small_cfg
    cfg1 = T.TransformerConfig(**{**cfg0.__dict__, "loss_chunk": 5})
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 10), 0, cfg0.vocab)
    batch = dict(tokens=toks, labels=toks, mask=jnp.ones((2, 10)))
    l0, _ = T.loss_fn(cfg0, small_params, batch)
    l1, _ = T.loss_fn(cfg1, small_params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """At capacity_factor 1.0 some tokens drop; output stays finite and
    the kept fraction is >= 1/top_k of slots."""
    cfg = T.TransformerConfig(
        n_layers=1, d_model=16, n_heads=2, n_kv_heads=2, d_ff=32, vocab=50,
        moe=T.MoEConfig(n_experts=2, top_k=2, capacity_factor=1.0),
        compute_dtype=jnp.float32, attn_chunk=8, remat=False,
    )
    params = T.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    layer0 = jax.tree.map(lambda p: p[0], params["layers"])
    out, aux = T._moe_ffn(cfg, layer0, x)
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.0


# ---------------------------------------------------------------------------
# recsys invariants
# ---------------------------------------------------------------------------

def test_dcn_cross_is_not_linear(rng):
    """The cross tower must be quadratic in x0 (its whole point)."""
    cfg = dcn.DCNConfig(n_dense=4, n_sparse=3, embed_dim=4,
                        n_cross_layers=2, mlp=(8,), vocab_per_field=10)
    p = dcn.init(cfg, jax.random.PRNGKey(0))
    base = dict(
        dense=jnp.asarray(rng.standard_normal((2, 4)), jnp.float32),
        sparse_ids=jnp.asarray(rng.integers(0, 10, (2, 3)), jnp.int32),
    )
    y1 = dcn.forward(cfg, p, base)
    y2 = dcn.forward(cfg, p, dict(dense=2 * base["dense"],
                                  sparse_ids=base["sparse_ids"]))
    # not homogeneous of degree 1 in the dense features
    assert not np.allclose(np.asarray(y2), 2 * np.asarray(y1), rtol=0.2)


def test_dlrm_interaction_count():
    cfg = dlrm.DLRMConfig(n_sparse=5, embed_dim=8, bot_mlp=(13, 8),
                          top_mlp=(4, 1), vocab_per_field=10)
    assert cfg.n_vectors == 6 and cfg.n_interactions == 15


def test_sasrec_causality(rng):
    """Future items must not influence earlier positions."""
    cfg = sasrec.SASRecConfig(n_items=50, embed_dim=16, n_blocks=1,
                              n_heads=1, seq_len=8, d_ff=32)
    p = sasrec.init(cfg, jax.random.PRNGKey(0))
    seq1 = jnp.asarray(rng.integers(0, 50, (1, 8)), jnp.int32)
    seq2 = seq1.at[0, -1].set((seq1[0, -1] + 7) % 50)
    h1 = sasrec.encode(cfg, p, seq1)
    h2 = sasrec.encode(cfg, p, seq2)
    np.testing.assert_allclose(np.asarray(h1[:, :-1]),
                               np.asarray(h2[:, :-1]), atol=1e-5)
    assert not np.allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]))


def test_mind_interests_shape_and_masking(rng):
    cfg = mind.MINDConfig(n_items=40, embed_dim=8, n_interests=3,
                          capsule_iters=2, hist_len=6)
    p = mind.init(cfg, jax.random.PRNGKey(0))
    hist = jnp.asarray(rng.integers(0, 40, (2, 6)), jnp.int32)
    mask = jnp.asarray([[1, 1, 1, 0, 0, 0], [1, 1, 1, 1, 1, 1]], jnp.float32)
    ints = mind.user_interests(cfg, p, hist, mask)
    assert ints.shape == (2, 3, 8)
    # fully-masked history still finite
    ints0 = mind.user_interests(cfg, p, hist, jnp.zeros_like(mask))
    assert bool(jnp.isfinite(ints0).all())


def test_rope_relative_property(rng):
    """RoPE: <q_m, k_n> depends only on m - n."""
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    def dot_at(m, n):
        qm = L.apply_rope(q, jnp.asarray([[m]]))
        kn = L.apply_rope(k, jnp.asarray([[n]]))
        return float(jnp.sum(qm * kn))
    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)
