"""Crash-safety end-to-end check: SIGKILL the index build, resume, compare.

Runs as a plain subprocess (``tests/test_checkpoint_resume.py`` drives it;
``make test-faults`` runs it via pytest).  Two process roles:

* **victim** (``build`` / ``build-sharded`` argv modes) — runs one
  checkpointed build of a fixed deterministic workload; a
  ``--kill-chunk N`` / ``--kill-commit N`` flag arms a
  :class:`repro.testing.faults.FaultPlan` that SIGKILLs the process at
  that chunk boundary / mid-checkpoint-write (no ``finally`` blocks, no
  atexit — real preemption).  On completion it prints the index digest
  and where it resumed from.
* **driver** (no argv) — for each engine: builds the uninterrupted
  reference in-process, then SIGKILLs a victim mid-build, SIGKILLs a
  second victim mid-commit (leaving a ``.tmp``), corrupts the newest
  committed step's shard bytes, and finally resumes a third victim to
  completion.  Asserts: the ``.tmp`` dir is never restored, the
  corrupted step fails verification and restore falls back past it, and
  the resumed index digest equals the uninterrupted one **bitwise**.
  Prints ``ALL OK`` iff everything held.
"""

import hashlib
import os
import signal
import subprocess
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np

N = 48
SOURCE_BATCH = 8          # -> 6 chunks both single-device and 1-shard mesh
BUILD = dict(c=0.25, max_steps=24, compact_every=4, touch_bits=16)
R, L = 2, 4
CHECKPOINT_EVERY = 1      # commit every chunk: every boundary is resumable


def make_graph():
    from repro.core.graph import Graph

    rng = np.random.default_rng(1234)
    m = 6 * N
    return Graph.from_edges(
        rng.integers(0, N, m), rng.integers(0, N, m), n=N
    )


def digest(index, stats) -> str:
    h = hashlib.sha256()
    h.update(np.asarray(index.values).tobytes())
    h.update(np.asarray(index.indices).tobytes())
    h.update(np.asarray(stats["touch"]).tobytes())
    return h.hexdigest()


def run_build(sharded: bool, ckpt_dir, fault_plan=None, resume=False):
    from repro.core.index import build_index, build_index_sharded

    g = make_graph()
    key = jax.random.PRNGKey(99)
    kwargs = dict(
        checkpoint_dir=ckpt_dir, checkpoint_every=CHECKPOINT_EVERY,
        resume=resume, fault_plan=fault_plan,
        source_batch=SOURCE_BATCH, **BUILD,
    )
    if sharded:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return build_index_sharded(g, R, L, key, mesh=mesh, **kwargs)
    return build_index(g, R, L, key, engine="sparse", **kwargs)


def victim(argv):
    from repro.testing import FaultPlan

    mode, ckpt_dir = argv[0], argv[1]
    plan = None
    resume = False
    args = argv[2:]
    while args:
        flag = args.pop(0)
        if flag == "--kill-chunk":
            plan = FaultPlan(kill_at_chunks=(int(args.pop(0)),))
        elif flag == "--kill-commit":
            plan = FaultPlan(kill_mid_commit=(int(args.pop(0)),))
        elif flag == "--resume":
            resume = True
        else:
            raise SystemExit(f"unknown flag {flag}")
    index, stats = run_build(
        mode == "build-sharded", ckpt_dir, fault_plan=plan, resume=resume)
    print(f"DIGEST {digest(index, stats)}")
    print(f"RESUMED_AT {stats.get('resumed_at_chunk', 0)}")


def spawn(args):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, os.path.abspath(__file__)] + args,
        env=env, capture_output=True, text=True, timeout=600,
    )


def driver():
    import tempfile

    from repro.distributed.checkpoint import Checkpointer

    for mode in ("build", "build-sharded"):
        # uninterrupted reference, no checkpointing at all
        with tempfile.TemporaryDirectory() as d:
            ref_index, ref_stats = run_build(mode == "build-sharded", d)
            ref = digest(ref_index, ref_stats)
        with tempfile.TemporaryDirectory() as d:
            # 1) SIGKILL before chunk 3: committed progress survives
            res = spawn([mode, d, "--kill-chunk", "3"])
            assert res.returncode == -signal.SIGKILL, (
                f"{mode}: expected SIGKILL death, got rc={res.returncode}\n"
                f"{res.stdout}\n{res.stderr}")
            ck = Checkpointer(d)
            steps = ck.all_steps()
            assert steps and max(steps) == 3, (mode, steps)

            # 2) SIGKILL mid-commit of step 4: only a .tmp appears
            res = spawn([mode, d, "--resume", "--kill-commit", "4"])
            assert res.returncode == -signal.SIGKILL, (mode, res.returncode)
            names = sorted(os.listdir(d))
            assert "step_4.tmp" in names, (mode, names)
            assert "step_4" not in names, (mode, names)
            assert max(Checkpointer(d).all_steps()) == 3, mode

            # 3) corrupt the newest committed step's first shard: restore
            #    must reject it by checksum and fall back to step 2
            with open(os.path.join(d, "step_3", "arr_0.npy"), "r+b") as f:
                f.seek(120)
                f.write(b"\xff" * 32)
            assert not Checkpointer(d).verify_step(3), mode

            # 4) resume to completion: .tmp ignored, corrupt step skipped,
            #    final index bitwise equal to the uninterrupted build
            res = spawn([mode, d, "--resume"])
            assert res.returncode == 0, (
                f"{mode}: resume failed\n{res.stdout}\n{res.stderr}")
            lines = dict(
                ln.split(" ", 1) for ln in res.stdout.splitlines()
                if " " in ln)
            assert lines["DIGEST"] == ref, f"{mode}: resumed digest differs"
            assert int(lines["RESUMED_AT"]) == 2, (mode, lines)
        print(f"{mode}: kill/kill-mid-commit/corrupt/resume OK")
    print("ALL OK")


if __name__ == "__main__":
    if len(sys.argv) > 1:
        victim(sys.argv[1:])
    else:
        driver()
