"""Weighted seed-set query parity suite (PR 7's tentpole contract).

PPR is linear in its restart distribution, so a seed-set answer must equal
the weighted sum of the single-vertex answers — that is the oracle every
route is held to here: sparse == dense == weighted singles to <= 1e-5 L1,
including the padded (sharded-build-shaped) index and both index-combine
paths.  The strict bound needs dangling-free graphs: with dangling
vertices, a seed-set query returns leaked mass to the normalized seed
*distribution* while the weighted-singles oracle returns each single's
mass to its own seed — the same convention only once no mass leaks, so the
fixtures close every dangling vertex with a self-loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core.index import PPRIndex
from repro.core.query import BatchQueryEngine, QueryConfig
from repro.graphs import synthetic


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _dangling_free(g: Graph) -> Graph:
    """Close dangling vertices with self-loops (see module docstring)."""
    src = np.asarray(g.src)
    dst = np.asarray(g.col_idx)
    dang = np.flatnonzero(np.asarray(g.dangling_mask))
    if dang.size:
        src = np.concatenate([src, dang])
        dst = np.concatenate([dst, dang])
    return Graph.from_edges(src, dst, n=g.n)


def _random_index(n: int, l: int, seed: int) -> PPRIndex:
    kv, ki = jax.random.split(jax.random.PRNGKey(seed))
    vals = jax.random.uniform(kv, (n, l), jnp.float32)
    vals = jnp.sort(vals / vals.sum(axis=1, keepdims=True), axis=1)[:, ::-1]
    idxs = jax.random.randint(ki, (n, l), 0, n, jnp.int32)
    return PPRIndex(values=vals, indices=idxs, l=l, n=n)


@pytest.fixture(scope="module")
def small_graph():
    # small enough that frontier_k = out_k = n makes the sparse route
    # exact (no truncation anywhere), so full-vector L1 bounds apply
    return _dangling_free(synthetic.erdos_renyi(256, avg_deg=4.0, seed=1))


@pytest.fixture(scope="module")
def small_index(small_graph):
    return _random_index(small_graph.n, 8, seed=3)


@pytest.fixture(scope="module")
def graph():
    return _dangling_free(synthetic.rmat(11, avg_deg=8.0, seed=2))  # n=2048


@pytest.fixture(scope="module")
def index(graph):
    return _random_index(graph.n, 16, seed=4)


def _engine(graph, index, **kw):
    cfg = dict(mode="powerwalk", t_iterations=2, top_k=32, frontier_k=128,
               max_seeds=4)
    cfg.update(kw)
    return BatchQueryEngine(graph, index, QueryConfig(**cfg))


def _seed_sets(n, q=6, s=4, seed=0):
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, n, (q, s)).astype(np.int32)
    weights = (rng.random((q, s)) + 0.1).astype(np.float32)
    return jnp.asarray(seeds), jnp.asarray(weights)


def _densify(sf, n):
    vals = np.asarray(sf.values, np.float64)
    idx = np.asarray(sf.indices)
    out = np.zeros((vals.shape[0], n))
    np.add.at(out, (np.arange(vals.shape[0])[:, None], idx), vals)
    return out


def _topk_map(vals, idx):
    return dict(zip(np.asarray(idx).tolist(), np.asarray(vals).tolist()))


def _assert_topk_close(a, b, atol=1e-6):
    """Top-k rows as (vertex -> score) maps; robust to ties permuting."""
    va, ia = a
    vb, ib = b
    for r in range(np.asarray(va).shape[0]):
        ma = _topk_map(va[r], ia[r])
        mb = _topk_map(vb[r], ib[r])
        for k in set(ma) | set(mb):
            assert abs(ma.get(k, 0.0) - mb.get(k, 0.0)) < atol, (r, k)


# ---------------------------------------------------------------------------
# the parity oracle chain: sparse == dense == weighted singles (<= 1e-5 L1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["powerwalk", "verd"])
def test_dense_seed_set_equals_weighted_singles(small_graph, small_index, mode):
    eng = _engine(small_graph, small_index, mode=mode,
                  frontier_k=small_graph.n)
    seeds, weights = _seed_sets(small_graph.n)
    dense = np.asarray(eng.query_dense(seeds, weights=weights), np.float64)
    wn = np.asarray(weights, np.float64)
    wn /= wn.sum(axis=1, keepdims=True)
    oracle = np.zeros_like(dense)
    for j in range(seeds.shape[1]):
        single = np.asarray(eng.query_dense(seeds[:, j]), np.float64)
        oracle += wn[:, j, None] * single
    l1 = np.abs(dense - oracle).sum(axis=1)
    assert l1.max() <= 1e-5, l1


def test_sparse_seed_set_matches_dense_oracle(small_graph, small_index):
    """Full chain at full width: the sparse route's densified answer, the
    dense route, and the weighted-singles oracle all agree to <= 1e-5 L1."""
    n = small_graph.n
    eng = _engine(small_graph, small_index, frontier_k=n)
    seeds, weights = _seed_sets(n)
    sf = eng.query_sparse(seeds, out_k=n, weights=weights)
    sparse = _densify(sf, n)
    dense = np.asarray(eng.query_dense(seeds, weights=weights), np.float64)
    assert np.abs(sparse - dense).sum(axis=1).max() <= 1e-5
    wn = np.asarray(weights, np.float64)
    wn /= wn.sum(axis=1, keepdims=True)
    oracle = np.zeros_like(dense)
    for j in range(seeds.shape[1]):
        sf_j = eng.query_sparse(seeds[:, j], out_k=n)
        oracle += wn[:, j, None] * _densify(sf_j, n)
    assert np.abs(sparse - oracle).sum(axis=1).max() <= 1e-5


def test_seed_set_parity_on_padded_index(small_graph, small_index):
    """A sharded-build-shaped index (zeroed pad rows, index.n > graph.n)
    serves identical seed-set answers on both routes."""
    pad = 19
    padded = PPRIndex(
        values=jnp.concatenate(
            [small_index.values, jnp.zeros((pad, small_index.l), jnp.float32)]),
        indices=jnp.concatenate(
            [small_index.indices, jnp.zeros((pad, small_index.l), jnp.int32)]),
        l=small_index.l, n=small_graph.n + pad)
    seeds, weights = _seed_sets(small_graph.n, seed=5)
    for path in ("sparse", "dense"):
        a = _engine(small_graph, small_index, frontier_path=path).query_topk(
            seeds, weights=weights)
        b = _engine(small_graph, padded, frontier_path=path).query_topk(
            seeds, weights=weights)
        _assert_topk_close(a, b, atol=1e-6)


def test_combine_paths_agree_on_seed_sets(graph, index):
    """scatter-combine vs sparse-combine: identical seed-set answers (the
    acceptance criterion's "both combine paths")."""
    seeds, weights = _seed_sets(graph.n, q=8, seed=7)
    answers = {}
    for path in ("scatter", "sparse"):
        eng = _engine(graph, index, frontier_path="sparse",
                      combine_path=path)
        answers[path] = eng.query_topk_async(seeds, weights=weights)
    np.testing.assert_allclose(
        np.asarray(answers["scatter"][0]), np.asarray(answers["sparse"][0]),
        rtol=1e-6, atol=1e-7)
    _assert_topk_close(answers["scatter"], answers["sparse"])


# ---------------------------------------------------------------------------
# reductions and invariances
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("frontier_path", ["sparse", "dense"])
def test_single_seed_reduces_to_single_vertex(graph, index, frontier_path):
    """S=1 with weight 1 is *bit-identical* to the classic single-vertex
    query — the seed-set path is a strict generalization, not a parallel
    implementation."""
    eng = _engine(graph, index, frontier_path=frontier_path, max_seeds=1)
    verts = jnp.arange(16, dtype=jnp.int32)
    v0, i0 = eng.query_topk(verts)
    v1, i1 = eng.query_topk(
        verts[:, None], weights=jnp.ones((16, 1), jnp.float32))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


@pytest.mark.parametrize("frontier_path", ["sparse", "dense"])
def test_duplicate_seeds_dedup_sum(graph, index, frontier_path):
    """A vertex listed twice carries the sum of its weights — same answer
    as the deduped spelling (scatter-add seeding on the dense route,
    dedup-merge in the sparse frontier)."""
    eng = _engine(graph, index, frontier_path=frontier_path)
    a, b = 17, 400
    dup = eng.query_topk(
        jnp.asarray([[a, a, b, 0]], jnp.int32),
        weights=jnp.asarray([[0.25, 0.25, 0.5, 0.0]], jnp.float32))
    ded = eng.query_topk(
        jnp.asarray([[a, b, 0, 0]], jnp.int32),
        weights=jnp.asarray([[0.5, 0.5, 0.0, 0.0]], jnp.float32))
    np.testing.assert_allclose(
        np.asarray(dup[0]), np.asarray(ded[0]), rtol=1e-6, atol=1e-7)
    _assert_topk_close(dup, ded)


def test_rescale_invariance(graph, index):
    """Weights are normalized per row: rescaling changes nothing.  A
    power-of-two rescale is bit-exact (f32 division rounds identically);
    arbitrary scales agree to float tolerance."""
    eng = _engine(graph, index)
    seeds, weights = _seed_sets(graph.n, seed=9)
    v0, i0 = eng.query_topk(seeds, weights=weights)
    v2, i2 = eng.query_topk(seeds, weights=2.0 * weights)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i2))
    v3, i3 = eng.query_topk(seeds, weights=3.0 * weights)
    _assert_topk_close((v0, i0), (v3, i3))


@pytest.mark.parametrize("frontier_path", ["sparse", "dense"])
def test_zero_weight_row_yields_zero_answers(graph, index, frontier_path):
    """All-zero weight rows (the pipeline's pad rows) produce all-zero
    answers instead of NaNs — the contract ``_batch_arrays`` relies on."""
    eng = _engine(graph, index, frontier_path=frontier_path)
    seeds = jnp.asarray([[5, 9, 2, 0], [0, 0, 0, 0]], jnp.int32)
    weights = jnp.asarray(
        [[0.5, 0.3, 0.2, 0.0], [0.0, 0.0, 0.0, 0.0]], jnp.float32)
    vals, _ = eng.query_topk(seeds, weights=weights)
    vals = np.asarray(vals)
    assert np.all(np.isfinite(vals))
    assert vals[0].max() > 0.0
    np.testing.assert_array_equal(vals[1], np.zeros_like(vals[1]))


# ---------------------------------------------------------------------------
# mode coverage and validation
# ---------------------------------------------------------------------------

def test_fppr_seed_set_is_weighted_row_sum(graph, index):
    """fppr mode: a seed-set answer is the weighted sum of the seeds'
    index rows (pure lookup, no online iterations)."""
    eng = _engine(graph, index, mode="fppr")
    seeds, weights = _seed_sets(graph.n, q=4, seed=11)
    vals, idx = eng.query_topk(seeds, weights=weights)
    wn = np.asarray(weights, np.float64)
    wn /= wn.sum(axis=1, keepdims=True)
    iv = np.asarray(index.values, np.float64)
    ii = np.asarray(index.indices)
    s_np = np.asarray(seeds)
    for r in range(s_np.shape[0]):
        dense = np.zeros(graph.n)
        for j in range(s_np.shape[1]):
            np.add.at(dense, ii[s_np[r, j]], wn[r, j] * iv[s_np[r, j]])
        got = _topk_map(vals[r], idx[r])
        for k, v in got.items():
            assert abs(v - dense[k]) < 1e-6, (r, k)


def test_nonlinear_modes_reject_seed_sets(graph, index):
    for mode in ("mcfp", "pi"):
        with pytest.raises(ValueError):
            BatchQueryEngine(graph, index, QueryConfig(mode=mode, max_seeds=4))
        eng = BatchQueryEngine(graph, index, QueryConfig(mode=mode))
        with pytest.raises(ValueError):
            eng.query_dense(jnp.asarray([[1, 2]], jnp.int32),
                            weights=jnp.ones((1, 2), jnp.float32))
        with pytest.raises(ValueError):
            eng.query_topk_async(jnp.asarray([[1, 2]], jnp.int32),
                                 weights=jnp.ones((1, 2), jnp.float32))


def test_run_chunks_seed_sets(graph, index):
    """The batched driver chunks weights alongside sources and matches the
    one-shot answer."""
    eng = _engine(graph, index, max_batch=8)
    seeds, weights = _seed_sets(graph.n, q=20, seed=13)
    out = eng.run(np.asarray(seeds), weights=np.asarray(weights))
    assert out["queries"] == 20
    ref_v, ref_i = _engine(graph, index).query_topk(seeds, weights=weights)
    _assert_topk_close((out["values"], out["indices"]),
                       (np.asarray(ref_v), np.asarray(ref_i)))


# ---------------------------------------------------------------------------
# serving integration: seed sets end to end through the service
# ---------------------------------------------------------------------------

def test_service_seed_sets_end_to_end(graph, index):
    from repro.serving import PPRService, ServiceConfig
    from repro.serving.batching import BatchingConfig
    from repro.serving.pipeline import PipelineConfig

    cfg = ServiceConfig(
        query=QueryConfig(mode="powerwalk", t_iterations=2, top_k=32,
                          frontier_k=128, max_seeds=4),
        batching=BatchingConfig(max_batch=16),
        pipeline=PipelineConfig(depth=2),
    )
    svc = PPRService(graph, index, cfg)
    rng = np.random.default_rng(17)
    sets = [
        (rng.integers(0, graph.n, rng.integers(1, 5)).tolist(),
         (rng.random(4) + 0.1).tolist())
        for _ in range(9)
    ]
    rids = {}
    for s, w in sets:
        rids[svc.submit(seeds=s, weights=w[: len(s)])] = (s, w[: len(s)])
    single = svc.submit(42)                   # mixed traffic
    answers = {a.request_id: a for a in svc.poll(force=True)}
    assert len(answers) == 10
    eng = svc.engine
    for rid, (s, w) in rids.items():
        row_s = np.zeros(4, np.int32)
        row_w = np.zeros(4, np.float32)
        row_s[: len(s)] = s
        row_w[: len(s)] = w
        v_ref, i_ref = eng.query_topk_async(
            jnp.asarray(row_s[None]), weights=jnp.asarray(row_w[None]))
        # batch width differs between the service dispatch and this Q=1
        # reference (which can even flip the combine-path auto-route), so
        # compare answers as (vertex -> score) maps, not bytes
        _assert_topk_close(
            (answers[rid].top_scores[None], answers[rid].top_vertices[None]),
            (np.asarray(v_ref), np.asarray(i_ref)))
        assert answers[rid].vertex == s[0]    # primary seed labels answers
    v_ref, i_ref = eng.query_topk_async(
        jnp.asarray([[42, 0, 0, 0]], jnp.int32),
        weights=jnp.asarray([[1.0, 0, 0, 0]], jnp.float32))
    _assert_topk_close(
        (answers[single].top_scores[None], answers[single].top_vertices[None]),
        (np.asarray(v_ref), np.asarray(i_ref)))


def test_service_rejects_oversized_seed_set(graph, index):
    from repro.serving import PPRService, ServiceConfig

    svc = PPRService(graph, index, ServiceConfig(
        query=QueryConfig(mode="powerwalk", max_seeds=2)))
    with pytest.raises(ValueError):
        svc.submit(seeds=[1, 2, 3])
