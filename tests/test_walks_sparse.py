"""Compacted sparse-sketch walk engine vs the legacy oracle.

Four layers (ISSUE 4's property checklist):

* exact conservation — walk counts and move counts must close to the unit,
  including under schedule-overflow truncation and sketch truncation;
* estimator parity — MCFP/MCEP from the compacted engine match the legacy
  ``simulate_walks`` estimates to Monte-Carlo tolerance at a matched walk
  budget (and both match exact PPR);
* the ``sample_walk_lengths`` geometric(c) law holds for the compacted
  engine's realized lengths;
* memory contract — the sparse index-build chunk traces with no
  ``f32[rows, n]`` intermediate (the acceptance gate that legacy
  ``build_index`` fails by construction).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mcep, mcfp, metrics, walks
from repro.core.graph import Graph
from repro.core.index import build_index, sparse_chunk_estimates
from repro.core.power_iteration import exact_ppr_dense
from repro.graphs import synthetic


@pytest.fixture(scope="module")
def small_graph():
    return synthetic.erdos_renyi(48, 4.0, seed=7)


@pytest.fixture(scope="module")
def exact_small(small_graph):
    return exact_ppr_dense(small_graph)


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

def test_schedule_shape_and_monotonicity():
    for r in (1, 7, 32, 100, 3000):
        sched = walks.compaction_schedule(r, max_steps=64, compact_every=8)
        assert len(sched) == 8
        assert sched[0] == r                     # every walk launches
        assert all(w <= r for w in sched)
        assert all(a >= b for a, b in zip(sched, sched[1:]))  # nonincreasing
        assert all(w >= 1 for w in sched)


def test_schedule_tracks_decay():
    sched = walks.compaction_schedule(
        3000, max_steps=64, compact_every=8, margin=1.35
    )
    for j, w in enumerate(sched):
        live = 3000 * 0.85 ** (8 * j)
        assert w >= min(3000, live)              # never below the mean
        assert w <= max(16, 2.0 * live + 8)      # tracks the decay


def test_schedule_rejects_bad_r():
    with pytest.raises(ValueError):
        walks.compaction_schedule(0)


# ---------------------------------------------------------------------------
# respawn-mode scheduling (ISSUE 5)
# ---------------------------------------------------------------------------

def _device_slots(widths, total_steps, compact_every=8):
    """Walk-slot positions one pass processes (the device-work unit)."""
    t0, slots = 0, 0
    for w in widths:
        steps = min(compact_every, total_steps - t0)
        slots += w * steps
        t0 += steps
    return slots


def test_respawn_schedule_shape():
    for r in (1, 8, 16, 100, 3000):
        widths, total = walks.respawn_schedule(r)
        assert widths, r
        assert all(1 <= w <= max(r, 4) for w in widths)
        assert widths[0] <= max(r, 4)
        # fixed-width launch plateau, then non-increasing drain
        assert all(a >= b for a, b in zip(widths, widths[1:]))
        assert total >= 8
    with pytest.raises(ValueError):
        walks.respawn_schedule(0)


def test_respawn_schedule_halves_device_work():
    """The perf contract behind the >= 2x positions/sec bench gate: at the
    floor-dominated small-R regime, respawn processes <= half the walk-slot
    positions of the decay schedule for the same R walks (and stays well
    ahead as R grows)."""
    sched16 = _device_slots(walks.compaction_schedule(16), 64)
    widths, total = walks.respawn_schedule(16)
    assert 2 * _device_slots(widths, total) <= sched16
    for r, margin in ((32, 1.5), (100, 1.3)):
        decay = _device_slots(walks.compaction_schedule(r), 64)
        widths, total = walks.respawn_schedule(r)
        assert margin * _device_slots(widths, total) <= decay, r


@pytest.mark.parametrize("r,l", [(40, 48), (40, 4), (257, 16)])
def test_respawn_conservation_exact(small_graph, key, r, l):
    sources = jnp.asarray([0, 5, 11], jnp.int32)
    counts = walks.simulate_walks_sparse(
        small_graph, sources, r, key, l=l, respawn=True
    )
    # every walk finishes exactly once, respawns and flushes included
    np.testing.assert_allclose(np.asarray(counts.walks), float(r))
    np.testing.assert_allclose(
        np.asarray(counts.fp.mass() + counts.fp_dropped),
        np.asarray(counts.moves), rtol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(counts.ep.mass() + counts.ep_dropped),
        np.asarray(counts.walks), rtol=1e-6,
    )
    assert (np.asarray(counts.moves) >= r).all()


def test_respawn_quota_flush_still_conserves(small_graph, key):
    """A pass too short to launch the whole quota flushes the remainder as
    length-1 walks: walks == R must still hold exactly, with the flush
    ledgered in ``truncated``."""
    sources = jnp.asarray([0, 5, 11], jnp.int32)
    counts = walks.simulate_walks_sparse(
        small_graph, sources, 257, key, l=48, respawn=True,
        respawn_width=4, max_steps=8,
    )
    np.testing.assert_allclose(np.asarray(counts.walks), 257.0)
    assert float(np.asarray(counts.truncated).sum()) > 0.0
    np.testing.assert_allclose(
        np.asarray(counts.fp.mass() + counts.fp_dropped),
        np.asarray(counts.moves), rtol=1e-6,
    )


def test_respawn_matches_schedule_mode_in_distribution(
    small_graph, exact_small, key
):
    sources = jnp.asarray([0, 1, 2, 3], jnp.int32)
    r = 3000
    ests = {}
    for respawn in (False, True):
        counts = walks.simulate_walks_sparse(
            small_graph, sources, r, key, l=small_graph.n, respawn=respawn
        )
        ests[respawn] = np.asarray(counts.fp.densify()) / np.asarray(
            counts.moves
        )[:, None]
        # realized mean length follows the same geometric(c) law
        mean_len = float(counts.moves.sum() / counts.walks.sum())
        assert abs(mean_len - 1 / 0.15) < 0.4, respawn
    ex = np.asarray(exact_small[:4])
    for est in ests.values():
        assert np.abs(est - ex).sum(axis=1).mean() < 0.06
    # and the two modes agree to within twice the MC noise
    diff = np.abs(ests[True] - ests[False]).sum(axis=1).mean()
    assert diff < 0.12


# ---------------------------------------------------------------------------
# conservation (exact, not statistical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,l", [(40, 48), (40, 4), (257, 16)])
def test_conservation_exact(small_graph, key, r, l):
    sources = jnp.asarray([0, 5, 11], jnp.int32)
    counts = walks.simulate_walks_sparse(small_graph, sources, r, key, l=l)
    # every walk finishes exactly once: terminated + truncated == R
    np.testing.assert_allclose(np.asarray(counts.walks), float(r))
    # every counted position is in the sketch or in the dropped ledger
    np.testing.assert_allclose(
        np.asarray(counts.fp.mass() + counts.fp_dropped),
        np.asarray(counts.moves), rtol=1e-6,
    )
    # every endpoint likewise
    np.testing.assert_allclose(
        np.asarray(counts.ep.mass() + counts.ep_dropped),
        np.asarray(counts.walks), rtol=1e-6,
    )
    assert (np.asarray(counts.moves) >= r).all()   # >= one position per walk
    assert (np.asarray(counts.truncated) >= 0).all()


def test_ragged_max_steps_respects_cap(small_graph, key):
    """max_steps not a multiple of compact_every: the last round is ragged
    and no walk may take more than max_steps positions."""
    sources = jnp.asarray([0, 5, 11], jnp.int32)
    counts = walks.simulate_walks_sparse(
        small_graph, sources, 40, key, l=48, max_steps=12, compact_every=8
    )
    np.testing.assert_allclose(np.asarray(counts.walks), 40.0)
    assert (np.asarray(counts.moves) <= 40 * 12).all()
    np.testing.assert_allclose(
        np.asarray(counts.fp.mass() + counts.fp_dropped),
        np.asarray(counts.moves), rtol=1e-6,
    )


def test_narrow_sketch_drops_mass(small_graph, key):
    # (40, 48) / (40, 4) reuse the compiled engines of the test above
    sources = jnp.asarray([0, 5, 11], jnp.int32)
    wide = walks.simulate_walks_sparse(small_graph, sources, 40, key, l=48)
    narrow = walks.simulate_walks_sparse(small_graph, sources, 40, key, l=4)
    assert float(narrow.fp_dropped.sum()) > float(wide.fp_dropped.sum())
    # same walks either way: the sketch width is a memory knob, not a
    # sampling knob
    np.testing.assert_allclose(
        np.asarray(wide.moves), np.asarray(narrow.moves)
    )


def test_dangling_walks_return_to_source(key):
    # 0 -> 1, 1 dangling: all non-teleport mass stays on {0, 1}
    g = Graph.from_edges([0], [1], n=3)
    counts = walks.simulate_walks_sparse(
        g, jnp.asarray([0], jnp.int32), 50, key, l=3
    )
    dense = np.asarray(counts.fp.densify())[0]
    assert dense[2] == 0.0
    assert dense.sum() == float(counts.moves[0])


def test_edgeless_graph(key):
    g = Graph.from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64), n=4)
    counts = walks.simulate_walks_sparse(
        g, jnp.asarray([1, 2], jnp.int32), 50, key, l=4
    )
    np.testing.assert_allclose(np.asarray(counts.walks), 50.0)
    # every position is the source itself
    dense = np.asarray(counts.fp.densify())
    assert dense[0, 1] == float(counts.moves[0])
    assert dense[1, 2] == float(counts.moves[1])


# ---------------------------------------------------------------------------
# estimator parity vs the legacy oracle (matched walk budget)
# ---------------------------------------------------------------------------

def test_mcfp_matches_legacy_to_mc_tolerance(small_graph, exact_small, key):
    sources = jnp.asarray([0, 1, 2, 3], jnp.int32)
    r = 3000
    legacy = mcfp.estimate_ppr(small_graph, sources, r=r, key=key)
    sparse = mcfp.estimate_ppr_sparse(
        small_graph, sources, r=r, key=key, l=small_graph.n
    ).densify()
    ex = jnp.asarray(exact_small[:4], jnp.float32)
    # both estimators converge to exact PPR at the same MC rate
    for est in (legacy, sparse):
        assert metrics.mean_rag(ex, est, k=10) > 0.97
        assert float(metrics.l1_error(ex, est).mean()) < 0.06
    # and to each other within twice the MC noise
    diff = float(jnp.abs(legacy - sparse).sum(axis=1).mean())
    assert diff < 0.12


def test_mcep_matches_legacy_to_mc_tolerance(small_graph, exact_small, key):
    # same (rows, r, l) as the MCFP test: both engines are already compiled
    sources = jnp.asarray([0, 1, 2, 3], jnp.int32)
    r = 3000
    legacy = mcep.estimate_ppr(small_graph, sources, r=r, key=key)
    sparse = mcep.estimate_ppr_sparse(
        small_graph, sources, r=r, key=key, l=small_graph.n
    ).densify()
    ex = jnp.asarray(exact_small[:4], jnp.float32)
    l1_legacy = float(metrics.l1_error(ex, legacy).mean())
    l1_sparse = float(metrics.l1_error(ex, sparse).mean())
    assert l1_sparse < max(2.0 * l1_legacy, 0.2)
    diff = float(jnp.abs(legacy - sparse).sum(axis=1).mean())
    assert diff < 0.25


def test_realized_lengths_follow_geometric_law(small_graph, key):
    """moves/walks is the mean realized walk length: 1/c up to truncation
    bias — the same law ``sample_walk_lengths`` certifies.  (Shapes chosen
    to reuse the MCFP parity test's compiled engine.)"""
    sources = jnp.arange(4, dtype=jnp.int32)
    counts = walks.simulate_walks_sparse(
        small_graph, sources, 3000, key, l=small_graph.n
    )
    mean_len = float(counts.moves.sum() / counts.walks.sum())
    assert abs(mean_len - 1 / 0.15) < 0.4
    lens = np.asarray(
        walks.sample_walk_lengths(key, 20000, c=0.15, max_steps=200)
    )
    assert abs(mean_len - lens.mean()) < 0.5


def test_kernel_routed_engine_is_bitwise_identical(key):
    g = synthetic.erdos_renyi(200, 4.0, seed=3)
    sources = jnp.asarray([0, 5, 9], jnp.int32)
    a = walks.simulate_walks_sparse(g, sources, 64, key, l=64)
    b = walks.simulate_walks_sparse(
        g, sources, 64, key, l=64, use_kernel=True
    )
    for x, y in (
        (a.fp.values, b.fp.values), (a.fp.indices, b.fp.indices),
        (a.ep.values, b.ep.values), (a.ep.indices, b.ep.indices),
        (a.moves, b.moves), (a.walks, b.walks),
    ):
        assert bool((x == y).all())


def test_compact_slots_preserves_live_walks():
    cursors = jnp.asarray([[7, 3, 9, 4, 6, 2]], jnp.int32)
    alive = jnp.asarray([[False, True, False, True, True, True]])
    new_c, new_a, ov_w, ov_i = walks._compact_slots(cursors, alive, 3)
    # survivors packed into the low slots in order
    np.testing.assert_array_equal(np.asarray(new_c)[0], [3, 4, 6])
    np.testing.assert_array_equal(np.asarray(new_a)[0], [True, True, True])
    # the 4th survivor (cursor 2) overflows
    assert float(ov_w.sum()) == 1.0
    assert int(np.asarray(ov_i)[0, np.asarray(ov_w)[0] > 0][0]) == 2


def test_fold_width_only_changes_truncation_order(small_graph, key):
    """Fold batching is a perf knob: with a full-support sketch the result
    is independent of the fold cadence."""
    sources = jnp.asarray([0, 1], jnp.int32)
    a = walks.simulate_walks_sparse(
        small_graph, sources, 64, key, l=small_graph.n, fold_width=64
    )
    b = walks.simulate_walks_sparse(
        small_graph, sources, 64, key, l=small_graph.n, fold_width=4096
    )
    np.testing.assert_allclose(
        np.asarray(a.fp.densify()), np.asarray(b.fp.densify()), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# index build: streaming sparse path + memory contract
# ---------------------------------------------------------------------------

def test_build_index_sparse_matches_legacy_quality(
    small_graph, exact_small, key
):
    idx_s, stats_s = build_index(small_graph, r=100, l=16, key=key)
    idx_l, stats_l = build_index(
        small_graph, r=100, l=16, key=key, engine="legacy"
    )
    assert stats_s["engine"] == "sparse" and stats_l["engine"] == "legacy"
    assert abs(stats_s["drop_fraction"] - stats_l["drop_fraction"]) < 0.05
    ex = jnp.asarray(exact_small, jnp.float32)
    verts = jnp.arange(12, dtype=jnp.int32)
    rag_s = metrics.mean_rag(ex[:12], idx_s.lookup_dense(verts), k=10)
    rag_l = metrics.mean_rag(ex[:12], idx_l.lookup_dense(verts), k=10)
    assert rag_s > rag_l - 0.03
    assert rag_s > 0.9


def test_build_index_rejects_unknown_engine(small_graph, key):
    with pytest.raises(ValueError):
        build_index(small_graph, r=10, l=4, key=key, engine="nope")


@pytest.mark.parametrize("engine", ["sparse", "legacy"])
def test_build_index_empty_sources(small_graph, key, engine):
    idx, stats = build_index(
        small_graph, r=10, l=4, key=key, engine=engine,
        sources=np.zeros(0, np.int32),
    )
    assert idx.values.shape == (small_graph.n, 4)
    np.testing.assert_allclose(np.asarray(idx.values), 0.0)
    assert stats["kept_mass"] == 0.0 and stats["dropped_mass"] == 0.0


def test_build_index_dedups_duplicate_sources(small_graph, key):
    """Regression (ISSUE 5): a repeated source id used to last-writer-win in
    the subset scatter and double-count the kept/dropped ledger; the builder
    now dedups up front and reports the count."""
    dup = np.asarray([3, 17, 3, 40, 17, 3], np.int32)
    uniq = np.asarray([3, 17, 40], np.int32)
    idx_d, st_d = build_index(
        small_graph, r=50, l=8, key=key, sources=dup, source_batch=2
    )
    idx_u, st_u = build_index(
        small_graph, r=50, l=8, key=key, sources=uniq, source_batch=2
    )
    assert st_d["duplicate_sources"] == 3
    assert st_u["duplicate_sources"] == 0
    np.testing.assert_array_equal(
        np.asarray(idx_d.values), np.asarray(idx_u.values)
    )
    np.testing.assert_array_equal(
        np.asarray(idx_d.indices), np.asarray(idx_u.indices)
    )
    # the mass ledger counts each source once, not once per duplicate
    assert st_d["kept_mass"] == pytest.approx(st_u["kept_mass"])
    assert st_d["dropped_mass"] == pytest.approx(st_u["dropped_mass"])


def test_build_index_legacy_reports_duplicates(small_graph, key):
    _, st = build_index(
        small_graph, r=10, l=4, key=key, engine="legacy",
        sources=np.asarray([1, 1, 2], np.int32),
    )
    assert st["duplicate_sources"] == 1


def test_build_index_r_splits_deterministic(small_graph, exact_small, key):
    """r_splits replays the sharded builder's per-chunk key fold on one
    device: deterministic, conservation intact, quality unchanged."""
    idx_a, st_a = build_index(small_graph, r=100, l=16, key=key, r_splits=2)
    idx_b, _ = build_index(small_graph, r=100, l=16, key=key, r_splits=2)
    np.testing.assert_array_equal(
        np.asarray(idx_a.values), np.asarray(idx_b.values)
    )
    idx_1, st_1 = build_index(small_graph, r=100, l=16, key=key)
    assert abs(st_a["drop_fraction"] - st_1["drop_fraction"]) < 0.05
    ex = jnp.asarray(exact_small, jnp.float32)
    verts = jnp.arange(12, dtype=jnp.int32)
    assert metrics.mean_rag(ex[:12], idx_a.lookup_dense(verts), k=10) > 0.9
    with pytest.raises(ValueError):
        build_index(small_graph, r=100, l=16, key=key, r_splits=3)


def test_build_index_respawn_matches_schedule_quality(
    small_graph, exact_small, key
):
    idx_r, st_r = build_index(small_graph, r=100, l=16, key=key, respawn=True)
    idx_s, st_s = build_index(small_graph, r=100, l=16, key=key)
    assert st_r["respawn"] and not st_s["respawn"]
    assert abs(st_r["drop_fraction"] - st_s["drop_fraction"]) < 0.05
    ex = jnp.asarray(exact_small, jnp.float32)
    verts = jnp.arange(12, dtype=jnp.int32)
    rag_r = metrics.mean_rag(ex[:12], idx_r.lookup_dense(verts), k=10)
    rag_s = metrics.mean_rag(ex[:12], idx_s.lookup_dense(verts), k=10)
    assert rag_r > rag_s - 0.03
    assert rag_r > 0.9


def test_build_index_sparse_subset_sources(small_graph, key):
    subset = np.asarray([3, 17, 40], np.int32)
    idx, stats = build_index(
        small_graph, r=50, l=8, key=key, sources=subset, source_batch=2
    )
    assert stats["pad_rows"] == 1              # 3 sources -> 2 chunks of 2
    row_mass = np.asarray(idx.values.sum(axis=1))
    assert (row_mass[subset] > 0).all()
    others = np.setdiff1d(np.arange(small_graph.n), subset)
    np.testing.assert_allclose(row_mass[others], 0.0)


def test_build_index_sparse_memory_contract(key):
    """The acceptance gate: the sparse build's per-chunk computation holds
    no ``f32[rows, n]``-sized intermediate — peak device memory is
    O(rows * sketch_l), independent of ``n`` beyond the CSR itself."""
    g = synthetic.rmat(12, avg_deg=6.0, seed=5)      # n = 4096
    rows, r, l = 64, 16, 32
    sketch_l = max(2 * l, l + 32)
    chunk = jnp.arange(rows, dtype=jnp.int32)
    fn = functools.partial(
        sparse_chunk_estimates, r=r, l=l, sketch_l=sketch_l
    )
    jaxpr = jax.make_jaxpr(fn)(g, chunk, key)
    # widest fold candidate row: sketch + a full pending buffer + the last
    # event segment that tipped it over (<= compact_every * r wide).  The
    # check itself is the auditor's dense-state-bound rule (repro.analysis);
    # the same budget/floor pair also runs under `make lint-contracts`.
    from repro.analysis.jaxpr import assert_dense_state_bound

    budget = rows * (sketch_l + max(4 * sketch_l, 512) + 8 * r + 8)
    assert_dense_state_bound(jaxpr, budget=budget, floor=rows * g.n)


@pytest.mark.slow
def test_build_index_sparse_smoke_4k():
    """End-to-end smoke on a 4k-vertex power-law graph: the new sparse path
    builds a working index whose truncation cost matches the legacy
    builder's (ISSUE 4 satellite)."""
    g = synthetic.rmat(12, avg_deg=8.0, seed=5)      # n = 4096
    key = jax.random.PRNGKey(9)
    idx_s, stats_s = build_index(g, r=16, l=32, key=key, source_batch=512)
    idx_l, stats_l = build_index(
        g, r=16, l=32, key=key, source_batch=512, engine="legacy"
    )
    assert idx_s.values.shape == (g.n, 32)
    assert abs(stats_s["drop_fraction"] - stats_l["drop_fraction"]) < 0.03
    # respawn-mode sweep: same estimator in distribution — its truncation
    # cost must match the schedule-mode build's at the smoke point
    idx_r, stats_r = build_index(
        g, r=16, l=32, key=key, source_batch=512, respawn=True
    )
    assert abs(stats_r["drop_fraction"] - stats_s["drop_fraction"]) < 0.03
    # spot-check quality parity on a few vertices (PI ground truth: the
    # dense 4096^2 solve would dwarf the builds under test)
    from repro.core.power_iteration import power_iteration

    verts = jnp.asarray([1, 100, 2000], jnp.int32)
    ex_rows = power_iteration(g, verts, n_iter=100)
    rag_s = metrics.mean_rag(ex_rows, idx_s.lookup_dense(verts), k=10)
    rag_l = metrics.mean_rag(ex_rows, idx_l.lookup_dense(verts), k=10)
    rag_r = metrics.mean_rag(ex_rows, idx_r.lookup_dense(verts), k=10)
    assert rag_s > rag_l - 0.1
    assert rag_r > rag_s - 0.1
