"""Distributed-runtime substrate tests: checkpoint, elastic, compression,
optimizer, serving/batching, partitioner."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import compression as comp
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.elastic import StepTimer, degraded_sequence, plan_mesh
from repro.graphs import partition, synthetic
from repro.serving.batching import BatchingConfig, RequestBuffer
from repro.training import optimizer as opt_mod
from repro.training.optimizer import AdamWConfig


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "b": {"c": jnp.arange(10, dtype=jnp.int32),
              "d": jax.random.normal(k, (4,), jnp.float32).astype(jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree()
    ckpt.save(5, tree, extra=dict(data_step=5))
    restored, extra = ckpt.restore(5, tree)
    assert extra["data_step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_async_and_gc(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ckpt.save(step, _tree(step), blocking=False)
    ckpt.wait()
    assert ckpt.all_steps() == [3, 4]


def test_checkpoint_ignores_partial(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(7, _tree())
    os.makedirs(str(tmp_path / "step_9.tmp"))  # simulated crash mid-write
    assert ckpt.latest_step() == 7


def test_checkpoint_reshard_hook(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree()
    ckpt.save(1, tree)
    calls = []
    restored, _ = ckpt.restore(1, tree, shard_fn=lambda t: (calls.append(1), t)[1])
    assert calls == [1]


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------

def test_plan_mesh_full():
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16) and p.devices_idle == 0


def test_plan_mesh_degraded_keeps_model_axis():
    p = plan_mesh(240, model_parallel=16, prior_data_parallel=16)
    assert p.shape == (15, 16)
    assert p.microbatch_scale == 2  # 16 -> 15 data ranks: accumulate more


def test_plan_mesh_catastrophic():
    p = plan_mesh(8, model_parallel=16)
    assert p.shape[1] <= 8 and p.devices_used <= 8


def test_degraded_sequence_monotone():
    plans = degraded_sequence(256, [16, 16, 32], model_parallel=16)
    used = [p.devices_used for p in plans]
    assert used == sorted(used, reverse=True)


def test_step_timer_flags_stragglers():
    t = StepTimer(window=16, threshold=2.0)
    advice = [t.record(0.1) for _ in range(10)]
    assert all(a is None for a in advice)
    assert t.record(0.5) == "rebalance"
    t.record(0.5)
    assert t.record(0.5) == "checkpoint"


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_bf16_error_feedback_unbiased():
    cfg = comp.CompressionConfig(method="bf16_ef")
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)) * 1e-3, jnp.float32)}
    residual = comp.init(g)
    total_q = np.zeros((64, 64), np.float32)
    steps = 50
    for _ in range(steps):
        q, residual = comp.compress(cfg, g, residual)
        total_q += np.asarray(q["w"])
    # accumulated quantized grads converge to accumulated true grads
    want = np.asarray(g["w"]) * steps
    np.testing.assert_allclose(total_q, want, rtol=2e-2, atol=1e-4)


def test_plain_bf16_is_biased_relative_to_ef():
    cfg_plain = comp.CompressionConfig(method="bf16")
    cfg_ef = comp.CompressionConfig(method="bf16_ef")
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(128,)) * 1e-4, jnp.float32)}
    r_p, r_e = comp.init(g), comp.init(g)
    acc_p = np.zeros(128, np.float32)
    acc_e = np.zeros(128, np.float32)
    for _ in range(100):
        qp, r_p = comp.compress(cfg_plain, g, r_p)
        qe, r_e = comp.compress(cfg_ef, g, r_e)
        acc_p += np.asarray(qp["w"])
        acc_e += np.asarray(qe["w"])
    want = np.asarray(g["w"]) * 100
    err_p = np.abs(acc_p - want).mean()
    err_e = np.abs(acc_e - want).mean()
    assert err_e <= err_p + 1e-9


def test_int8_ef_roundtrip():
    cfg = comp.CompressionConfig(method="int8_ef")
    g = {"w": jnp.asarray(np.linspace(-1, 1, 256), jnp.float32)}
    q, r = comp.compress(cfg, g, comp.init(g))
    assert float(jnp.abs(q["w"] - g["w"]).max()) < 0.1
    assert comp.wire_bytes(g, cfg) == 256


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=100.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt_mod.init(cfg, params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, m = opt_mod.update(cfg, grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_adamw_mixed_moment_dtypes():
    cfg = AdamWConfig(mu_dtype=jnp.float8_e4m3fn, nu_dtype=jnp.bfloat16)
    params = {"x": jnp.ones((32,), jnp.float32)}
    state = opt_mod.init(cfg, params)
    assert state.mu["x"].dtype == jnp.float8_e4m3fn
    assert state.nu["x"].dtype == jnp.bfloat16
    grads = {"x": jnp.full((32,), 0.5)}
    p2, s2, _ = opt_mod.update(cfg, grads, state, params)
    assert bool(jnp.isfinite(p2["x"]).all())
    assert float(p2["x"][0]) < 1.0


def test_grad_clipping():
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, warmup_steps=0)
    params = {"x": jnp.zeros((4,))}
    state = opt_mod.init(cfg, params)
    _, _, m = opt_mod.update(cfg, {"x": jnp.full((4,), 100.0)}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# serving buffer
# ---------------------------------------------------------------------------

def test_buffer_flush_on_size():
    clock = iter(np.arange(0, 100, 0.001)).__next__
    buf = RequestBuffer(BatchingConfig(max_batch=4, max_wait_s=10.0),
                        clock=clock)
    for v in range(3):
        buf.submit(v)
    assert not buf.ready()
    buf.submit(3)
    assert buf.ready()
    reqs, padded = buf.drain()
    assert len(reqs) == 4 and padded == 4


def test_buffer_flush_on_deadline():
    t = [0.0]
    buf = RequestBuffer(BatchingConfig(max_batch=100, max_wait_s=0.01),
                        clock=lambda: t[0])
    buf.submit(1)
    assert not buf.ready()
    t[0] = 0.02
    assert buf.ready()
    reqs, padded = buf.drain()
    assert len(reqs) == 1 and padded == 1


def test_buffer_pads_to_power_of_two():
    clock = lambda: 0.0
    buf = RequestBuffer(BatchingConfig(max_batch=16), clock=clock)
    for v in range(5):
        buf.submit(v)
    reqs, padded = buf.drain()
    assert len(reqs) == 5 and padded == 8


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------

def test_edge_balanced_beats_vertex_balanced_on_skew():
    g = synthetic.rmat(11, avg_deg=16.0, seed=3)
    v_parts = partition.vertex_intervals(g, 8)
    e_parts = partition.edge_balanced_intervals(g, 8)
    _, v_imb = partition.balance_stats(v_parts)
    _, e_imb = partition.balance_stats(e_parts)
    assert e_imb <= v_imb
    assert sum(p.size for p in e_parts) == g.n


def test_source_round_robin():
    shards = partition.assign_sources_to_shards(np.arange(10), 3)
    assert sorted(np.concatenate(shards).tolist()) == list(range(10))
